#!/usr/bin/env python
"""Offline autotuner CLI: sweep → tuning table → calibrate → inspect.

    PYTHONPATH=src python tools/autotune.py sweep [--smoke] [--out F]
        [--parts kernel,schedule,paged] [--seqs 1024,2048] [--calibrate]
        [--check-roundtrip]
    PYTHONPATH=src python tools/autotune.py calibrate --table F [--out F2]
    PYTHONPATH=src python tools/autotune.py show [--table F]
    PYTHONPATH=src python tools/autotune.py diff TABLE_A TABLE_B

``sweep`` measures kernel tile shapes, distributed-schedule wall times,
and paged block sizes on *this* host (see repro/tune/sweep.py) and
persists winners into a schema-versioned JSON table.  ``calibrate`` fits
the schedule cost-model coefficients to the measured rows and records
fit diagnostics.  The checked-in CPU default lives at
``src/repro/tune/tables/default_cpu.json``; regenerate it with::

    PYTHONPATH=src python tools/autotune.py sweep --calibrate \
        --out src/repro/tune/tables/default_cpu.json

``--check-roundtrip`` re-loads the produced table and asserts every
persisted winner is returned by the lookup API (the CI smoke gate).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.tune import calibrate as cal  # noqa: E402
from repro.tune.table import TuningTable, active_table  # noqa: E402


def _load(path):
    return TuningTable.load(path)      # raises TableError with the reason


def check_roundtrip(tab: TuningTable) -> None:
    """Every persisted winner must come back out of the lookup API."""
    for r in tab.data["kernel"]:
        got = tab.best_blocks(backend=r["backend"], platform=r["platform"],
                              mask_kind=r["mask_kind"],
                              head_dim=r["head_dim"], seq=r["seq"],
                              op=r["op"])
        assert got == (r["block_q"], r["block_kv"]), \
            f"kernel row {r} lookup returned {got}"
    for r in tab.data["schedule"]:
        got = tab.best_schedule(mask_kind=r["mask_kind"], P=r["P"],
                                seq=r["seq"])
        assert got == r["best"], f"schedule row {r} lookup returned {got}"
    for r in tab.data["paged"]:
        got = tab.best_block_size(layout=r["layout"], sharding=r["sharding"])
        assert got == r["block_size"], f"paged row {r} lookup returned {got}"
    if tab.coeffs() is not None:
        feats = cal.schedule_features("ring", mask_kind="causal", P=8,
                                      seq=2048)
        assert cal.predict_s(feats, tab.coeffs()) >= 0.0
    print(f"roundtrip OK: {len(tab.data['kernel'])} kernel, "
          f"{len(tab.data['schedule'])} schedule, "
          f"{len(tab.data['paged'])} paged rows"
          + (", calibrated" if tab.coeffs() else ""))


def cmd_sweep(args) -> int:
    from repro.tune.sweep import run_sweep
    parts = tuple(p for p in args.parts.split(",") if p)
    seqs = tuple(int(s) for s in args.seqs.split(",")) if args.seqs else None
    data = run_sweep(smoke=args.smoke, parts=parts, seqs=seqs)
    if args.calibrate:
        if data["schedule"]:
            data["calibration"] = cal.calibrate(data["schedule"])
        else:
            print("calibrate: no schedule rows swept, skipping",
                  file=sys.stderr)
    tab = TuningTable(data)
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    tab.save(args.out)
    print(f"wrote {args.out}")
    if args.check_roundtrip:
        check_roundtrip(_load(args.out))
    return 0


def cmd_calibrate(args) -> int:
    tab = _load(args.table)
    if not tab.data["schedule"]:
        print("no schedule rows in table — run `sweep` with the schedule "
              "part first", file=sys.stderr)
        return 1
    tab.data["calibration"] = cal.calibrate(tab.data["schedule"])
    out = args.out or args.table
    tab.save(out)
    fit = tab.fit()
    print(f"wrote {out}: spearman={fit['spearman']} "
          f"(roofline {fit['spearman_roofline']}), "
          f"best-match {fit['best_match']} "
          f"(roofline {fit['best_match_roofline']}), "
          f"rel_rms={fit['rel_rms']} over {fit['n_points']} points")
    return 0


def cmd_show(args) -> int:
    tab = _load(args.table) if args.table else active_table()
    if tab is None:
        print("no active tuning table (set REPRO_TUNE_TABLE or pass "
              "--table)", file=sys.stderr)
        return 1
    h = tab.data.get("host", {})
    print(f"table: {tab.path or '<memory>'}  "
          f"(platform={h.get('platform')}, jax={h.get('jax')})")
    for r in tab.data["kernel"]:
        print(f"  kernel   {r['backend']:16s} {r['mask_kind']:15s} "
              f"seq={r['seq']:5d} D={r['head_dim']:3d} {r['op']}: "
              f"{r['block_q']}x{r['block_kv']}")
    for r in tab.data["schedule"]:
        walls = " ".join(f"{s}={u / 1e3:.0f}ms"
                         for s, u in sorted(r["wall_us"].items()))
        print(f"  schedule {r['mask_kind']:15s} P={r['P']} "
              f"seq={r['seq']:5d}: best={r['best']}  {walls}")
    for r in tab.data["paged"]:
        print(f"  paged    {r['layout']:4s} sharding={r['sharding']}: "
              f"block_size={r['block_size']}")
    fit = tab.fit()
    if fit:
        print(f"  calibration: spearman={fit.get('spearman')} "
              f"(roofline {fit.get('spearman_roofline')}), "
              f"best-match {fit.get('best_match')} "
              f"(roofline {fit.get('best_match_roofline')})")
    return 0


def cmd_diff(args) -> int:
    a, b = _load(args.table_a), _load(args.table_b)

    def key_map(rows, keys):
        return {tuple(r[k] for k in keys): r for r in rows}

    n = 0
    specs = [("kernel", ("backend", "platform", "mask_kind", "head_dim",
                         "seq", "op"), ("block_q", "block_kv")),
             ("schedule", ("mask_kind", "P", "seq"), ("best",)),
             ("paged", ("layout", "sharding"), ("block_size",))]
    for section, keys, vals in specs:
        ma = key_map(a.data[section], keys)
        mb = key_map(b.data[section], keys)
        for k in sorted(set(ma) | set(mb), key=str):
            ra, rb = ma.get(k), mb.get(k)
            va = tuple(ra[v] for v in vals) if ra else None
            vb = tuple(rb[v] for v in vals) if rb else None
            if va != vb:
                n += 1
                print(f"  {section} {k}: {va} -> {vb}")
    ca, cb = a.coeffs(), b.coeffs()
    if ca != cb:
        n += 1
        print(f"  calibration: {json.dumps(ca)} -> {json.dumps(cb)}")
    print(f"{n} difference(s)" if n else "tables agree")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)

    sp = sub.add_parser("sweep", help="measure and persist a tuning table")
    sp.add_argument("--smoke", action="store_true",
                    help="tiny shapes / few iters (CI)")
    sp.add_argument("--out", default="tuning_table.json")
    sp.add_argument("--parts", default="kernel,schedule,paged",
                    help="comma list of sweeps to run")
    sp.add_argument("--seqs", default=None,
                    help="comma list of schedule-sweep seq lengths")
    sp.add_argument("--calibrate", action="store_true",
                    help="fit cost-model coefficients after sweeping")
    sp.add_argument("--check-roundtrip", action="store_true",
                    help="assert persisted winners survive lookup")
    sp.set_defaults(fn=cmd_sweep)

    cp = sub.add_parser("calibrate",
                        help="(re)fit coefficients on an existing table")
    cp.add_argument("--table", required=True)
    cp.add_argument("--out", default=None)
    cp.set_defaults(fn=cmd_calibrate)

    hp = sub.add_parser("show", help="print a table (default: active)")
    hp.add_argument("--table", default=None)
    hp.set_defaults(fn=cmd_show)

    dp = sub.add_parser("diff", help="compare two tables' winners")
    dp.add_argument("table_a")
    dp.add_argument("table_b")
    dp.set_defaults(fn=cmd_diff)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
