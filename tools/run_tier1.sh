#!/usr/bin/env bash
# Tier-1 test runner: pins PYTHONPATH=src and runs the suite on CPU.
#
#   tools/run_tier1.sh            # default run (slow-marked params skipped)
#   tools/run_tier1.sh --all      # include slow-marked params
#   tools/run_tier1.sh tests/test_kernels.py   # extra args pass through
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

MARK="not slow"
if [[ "${1:-}" == "--all" ]]; then
    MARK=""
    shift
fi
exec python -m pytest -q --durations=10 -m "$MARK" "$@"
