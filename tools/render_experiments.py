"""Render the §Dry-run / §Roofline tables in EXPERIMENTS.md from
results/dryrun/*.json (idempotent; replaces the marker sections)."""
import glob
import json
import os
import re
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")


def fmt(x, nd=4):
    return f"{x:.{nd}f}" if isinstance(x, (int, float)) else str(x)


def roofline_table():
    rows = ["| arch | shape | bound | step_lb (s) | compute (s) | "
            "memory (s) | collective (s) | useful | peak GB/chip | "
            "compile (s) |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    files = sorted(glob.glob(os.path.join(ROOT, "results/dryrun/pod1_*.json")))
    for f in files:
        d = json.load(open(f))
        r = d.get("roofline", {})
        adj = d.get("adjusted", {})
        ur = adj.get("useful_flops_ratio")
        rows.append(
            f"| {d['arch']} | {d['shape']} | {r.get('bound','?')} | "
            f"{fmt(r.get('step_s_lower_bound', 0))} | "
            f"{fmt(r.get('compute_s', 0))} | {fmt(r.get('memory_s', 0))} | "
            f"{fmt(r.get('collective_s', 0))} | "
            f"{fmt(ur, 3) if ur is not None else '—'} | "
            f"{d['memory']['peak_device_bytes'] / 1e9:.1f} | "
            f"{d['compile_s']} |")
    n1 = len(files)
    files2 = sorted(glob.glob(os.path.join(ROOT, "results/dryrun/pod2_*.json")))
    pod2 = ["", f"Multi-pod (512-chip) pass: **{len(files2)}/40 pairs "
            "lowered + compiled** (sharding over the `pod` axis proven; "
            "memory recorded per JSON)."]
    hdr = [f"Single-pod baseline table — **{n1}/40 pairs compiled**. "
           "Terms are kernel-adjusted (§Dry-run methodology); "
           "`roofline_as_lowered` in each JSON keeps raw values.", ""]
    return "\n".join(hdr + rows + pod2)


def main():
    p = os.path.join(ROOT, "EXPERIMENTS.md")
    s = open(p).read()
    table = roofline_table()
    s = re.sub(r"<!-- ROOFLINE_TABLE -->.*?(?=\n## |\Z)",
               "<!-- ROOFLINE_TABLE -->\n" + table + "\n\n", s,
               flags=re.S)
    open(p, "w").write(s)
    print(f"rendered {p}")


if __name__ == "__main__":
    main()
