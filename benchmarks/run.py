"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Wall-clock rows are measured
on this host (CPU; 8 forced host devices in subprocess benches) as the
median over iterations (robust to CPU timing noise); derived rows are
analytic or HLO-derived quantities that reproduce the paper's comparisons
where real multi-GPU wall time is unavailable. ``--json OUT`` additionally
writes the rows to a machine-readable JSON file.

    PYTHONPATH=src python -m benchmarks.run [--only fig4,table5] [--json F]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import statistics
import subprocess
import sys
import time

import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from repro import compat
from benchmarks.schedule_sim import (balanced_schedule, coverage_ok,
                                     expected_speedup, idle_fraction,
                                     ring_schedule)

ROWS = []


def row(name, us, derived=""):
    ROWS.append((name, us, derived))
    print(f"{name},{us},{derived}", flush=True)


def _timeit(fn, iters=5):
    fn()  # warmup/compile
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts) * 1e6  # median-of-iters: noise-robust


# ---------------------------------------------------------------- figure 4

def bench_fig4_load_balance():
    """Paper Fig. 1/4 + Eq. 2: idle fractions and expected speedups of ring
    vs balanced scheduling, from the schedule simulator (coverage-proved)."""
    for P in (4, 7, 8, 16, 32):
        rp, rb = ring_schedule(P)
        bp, bb = balanced_schedule(P)
        assert coverage_ok(rp, P) and coverage_ok(bp, P), P
        row(f"fig4/ring_idle_P{P}", 0, f"{idle_fraction(rb, P):.4f}")
        row(f"fig4/balanced_idle_P{P}", 0, f"{idle_fraction(bb, P):.4f}")
        row(f"fig4/ring_speedup_P{P}", 0, f"{expected_speedup(rb, P):.2f}")
        row(f"fig4/balanced_speedup_P{P}", 0,
            f"{expected_speedup(bb, P):.2f}")
    # paper's Eq.2 closed forms (even P)
    for P in (8, 16):
        row(f"fig4/eq2_theory_P{P}", 0, f"{1 / (2 * P):.4f}")


# ---------------------------------------------------------------- table 5

def bench_table5_checkpointing():
    """Remat-aware vs HF checkpointing: wall-clock per train step (tiny
    LLaMA-family model on CPU) + backward-pass HLO FLOPs ratio."""
    from repro.core.config import (TrainConfig, get_config, smoke_config,
                                   ShapeSpec)
    from repro.data.pipeline import SyntheticTokens
    from repro.models.transformer import Runtime, build_model
    from repro.optim import adamw
    from repro.parallel.sharding import make_parallel_config
    from repro.train.step import make_train_step

    cfg = smoke_config(get_config("llama-7b")).replace(n_layers=4)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeSpec("b5", 512, 2, "train")
    results = {}
    for remat in ("none", "hf", "remat_aware"):
        par = make_parallel_config(mesh, shape, remat=remat)
        model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw.init(params)
        batch = SyntheticTokens(cfg, shape, par, mesh).batch(0)
        step = jax.jit(make_train_step(model, TrainConfig()))
        flops = compat.cost_analysis(
            step.lower(params, opt, batch).compile()).get("flops", 0)

        def run(step=step, params=params, opt=opt, batch=batch):
            jax.block_until_ready(step(params, opt, batch))

        us = _timeit(run, iters=3)
        results[remat] = (us, flops)
        row(f"table5/train_step_{remat}", f"{us:.0f}", f"flops={flops:.3e}")
    hf_us, hf_f = results["hf"]
    ra_us, ra_f = results["remat_aware"]
    row("table5/speedup_remat_aware_vs_hf", 0, f"{hf_us / ra_us:.3f}x")
    row("table5/flops_ratio_hf_over_remat_aware", 0, f"{hf_f / ra_f:.3f}")


# ---------------------------------------------------------------- table 3

def bench_table3_rsa():
    """RSA vs DISTFLASHATTN: peak attention memory (compiled temp bytes)
    and wall time, 8 host devices, seq-parallel attention only."""
    code = """
import time, jax, jax.numpy as jnp
from repro.core import mask as mk
from repro.core.dist_attention import DistAttnSpec, dist_attn_fwd
mesh = jax.make_mesh((1,8), ("data","model"))
B,N,H,D = 1,4096,8,64
ks = jax.random.split(jax.random.PRNGKey(0),3)
q,k,v = (jax.random.normal(kk,(B,N,H,D),jnp.float32) for kk in ks)
for sched in ("rsa","balanced"):
    spec = DistAttnSpec(axis="model", axis_size=8, schedule=sched, mask=mk.causal())
    f = jax.jit(lambda q,k,v: dist_attn_fwd(q,k,v,mesh=mesh,spec=spec,batch_axes=None)[0])
    co = f.lower(q,k,v).compile()
    mem = co.memory_analysis().temp_size_in_bytes
    jax.block_until_ready(f(q,k,v))
    t0=time.perf_counter()
    for _ in range(3): jax.block_until_ready(f(q,k,v))
    us=(time.perf_counter()-t0)/3*1e6
    print(f"RESULT {sched} {us:.0f} {mem}")
"""
    out = _subproc(code)
    vals = {}
    for line in out.splitlines():
        if line.startswith("RESULT"):
            _, sched, us, mem = line.split()
            vals[sched] = (float(us), int(mem))
            row(f"table3/attn_fwd_{sched}_seq4k_8dev", f"{float(us):.0f}",
                f"temp_bytes={mem}")
    if len(vals) == 2:
        row("table3/rsa_temp_bytes_ratio", 0,
            f"{vals['rsa'][1] / max(vals['balanced'][1], 1):.2f}x")
        row("table3/rsa_time_ratio", 0,
            f"{vals['rsa'][0] / max(vals['balanced'][0], 1):.2f}x")


# ---------------------------------------------------------------- table 4

def bench_table4_ulysses():
    """DISTFLASHATTN vs DeepSpeed-Ulysses: collective bytes per attention
    layer from compiled HLO (8 host devices) + head-divisibility failures."""
    code = """
import jax, jax.numpy as jnp
from repro.core import mask as mk
from repro.core.dist_attention import DistAttnSpec, dist_attn_fwd
from repro.analysis.roofline import collective_stats
mesh = jax.make_mesh((1,8), ("data","model"))
B,N,D = 1,4096,64
for name, H, Hkv, sched in [("balanced_mha",8,8,"balanced"),
                            ("ulysses_mha",8,8,"ulysses"),
                            ("balanced_gqa",8,2,"balanced")]:
    ks = jax.random.split(jax.random.PRNGKey(0),3)
    q = jax.random.normal(ks[0],(B,N,H,D)); k = jax.random.normal(ks[1],(B,N,Hkv,D)); v = jax.random.normal(ks[2],(B,N,Hkv,D))
    spec = DistAttnSpec(axis="model", axis_size=8, schedule=sched, mask=mk.causal())
    f = jax.jit(lambda q,k,v: dist_attn_fwd(q,k,v,mesh=mesh,spec=spec,batch_axes=None)[0])
    txt = f.lower(q,k,v).compile().as_text()
    st = collective_stats(txt)
    print(f"RESULT {name} coll_bytes={st.total_bytes:.0f}")
# irregular heads: ulysses must fail, balanced must work (paper 4.2/4.6)
q = jax.random.normal(jax.random.PRNGKey(0),(B,N,33,32))
spec = DistAttnSpec(axis="model", axis_size=8, schedule="ulysses", mask=mk.causal())
try:
    dist_attn_fwd(q,q,q,mesh=mesh,spec=spec,batch_axes=None)
    print("RESULT ulysses_33h ok")
except ValueError:
    print("RESULT ulysses_33h infeasible_head_padding_required")
spec = DistAttnSpec(axis="model", axis_size=8, schedule="balanced", mask=mk.causal())
o,_ = jax.jit(lambda q: dist_attn_fwd(q,q,q,mesh=mesh,spec=spec,batch_axes=None))(q)
print("RESULT balanced_33h ok_no_padding")
"""
    for line in _subproc(code).splitlines():
        if line.startswith("RESULT"):
            parts = line.split()
            row(f"table4/{parts[1]}", 0, " ".join(parts[2:]))


# ------------------------------------------------- schedule-level tracking

def bench_schedules_plans():
    """Tracked static schedule-plan rows (BENCH_schedules.json): per
    schedule × mask regime, the plan's executed/total ring steps, kernel
    calls, and the cost-model predictions that drive schedule="auto" —
    pure python, no devices.  The windowed rows are the step-skipping
    acceptance surface: windowed balanced/zigzag must execute strictly
    fewer steps than their causal plans."""
    from repro.core import mask as mkm
    from repro.core import schedule as spm

    B, N, P, H, D = 1, 2048, 8, 8, 64
    Tl = N // P
    bnd = mkm.doc_boundaries(N, 8)
    regimes = [
        ("causal", mkm.causal(), False),
        ("windowed", mkm.sliding_window(N // 8), False),
        ("document", mkm.document(boundaries=bnd), False),
        ("doc_dynamic", mkm.document(), True),
    ]
    for rname, m, dyn in regimes:
        for sched in ("ring", "balanced", "zigzag"):
            if not spm.plan_capable(sched, m):
                continue
            plan = spm.build_plan(sched, m, P, Tl)
            cost = plan.cost(B=B, Hq=H, Hkv=H, Dqk=D, Dv=D, bpe=4,
                             dynamic_seg=dyn)
            t = cost.time_estimate()
            row(f"schedules/plan_{sched}_{rname}", 0,
                f"steps={plan.exec_steps}/{plan.total_steps} "
                f"calls={plan.kernel_calls} "
                f"pred_compute_s={t['compute_s']:.3e} "
                f"pred_collective_s={t['collective_s']:.3e} "
                f"pred_bound={t['bound']}")
        auto = spm.choose_schedule(m, P, Tl=Tl, B=B, Hq=H, Hkv=H, Dqk=D,
                                   Dv=D, bpe=4, dynamic_seg=dyn)
        row(f"schedules/auto_{rname}", 0, f"resolved={auto}")


def bench_schedules_wall():
    """Tracked schedule-level benchmark (BENCH_schedules.json): forward
    wall-clock of each sequence-parallel schedule on 8 host devices, for
    the dense causal mask, a packed (document) batch, the windowed regime
    (plan step skipping — new ring steps matrix), and schedule="auto" —
    so the perf trajectory covers the schedules, not just the kernels."""
    code = """
import time, statistics, numpy as np, jax, jax.numpy as jnp
from repro.core import mask as mk
from repro.core.dist_attention import DistAttnSpec, dist_attn_fwd, zigzag_perm
mesh = jax.make_mesh((1,8), ("data","model"))
B,N,H,D = 1,2048,8,64
ks = jax.random.split(jax.random.PRNGKey(0),3)
q,k,v = (jax.random.normal(kk,(B,N,H,D),jnp.float32) for kk in ks)
bnd = mk.doc_boundaries(N, 8)
seg = jnp.asarray(np.tile(mk.segments_from_boundaries(N, bnd), (B,1)))
perm = zigzag_perm(N, 8)
win = mk.sliding_window(N // 8)
def timeit(f, *a):
    jax.block_until_ready(f(*a))
    ts = []
    for _ in range(5):
        t0 = time.perf_counter(); jax.block_until_ready(f(*a))
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts) * 1e6
for sched in ("auto","ring","balanced","zigzag","ulysses","rsa"):
    qq, kk_, vv, ss = (q[:,perm],k[:,perm],v[:,perm],seg[:,perm]) \\
        if sched == "zigzag" else (q,k,v,seg)
    spec = DistAttnSpec(axis="model", axis_size=8, schedule=sched, mask=mk.causal())
    f = jax.jit(lambda a,b,c: dist_attn_fwd(a,b,c,mesh=mesh,spec=spec,batch_axes=None)[0])
    us = timeit(f, qq, kk_, vv)
    print(f"RESULT {sched}/causal {us:.0f}")
    specd = DistAttnSpec(axis="model", axis_size=8, schedule=sched, mask=mk.document())
    fd = jax.jit(lambda a,b,c,s: dist_attn_fwd(a,b,c,mesh=mesh,spec=specd,batch_axes=None,segments=s)[0])
    usd = timeit(fd, qq, kk_, vv, ss)
    print(f"RESULT {sched}/document {usd:.0f}")
    if sched != "rsa":   # rsa has no sliding-window path
        specw = DistAttnSpec(axis="model", axis_size=8, schedule=sched, mask=win)
        fw = jax.jit(lambda a,b,c: dist_attn_fwd(a,b,c,mesh=mesh,spec=specw,batch_axes=None)[0])
        usw = timeit(fw, qq, kk_, vv)
        print(f"RESULT {sched}/windowed {usw:.0f}")
"""
    for line in _subproc(code).splitlines():
        if line.startswith("RESULT"):
            _, name, us = line.split()
            row(f"schedules/attn_fwd_{name}_seq2k_8dev", f"{float(us):.0f}",
                "wall us, CPU host mesh")


def bench_schedules_plans2d():
    """Tracked 2D (seq×head) factored-plan rows (BENCH_schedules.json):
    for the GQA regime the 1D schedules serve poorly (Hq=8, Hkv=2 — the
    bespoke ulysses is infeasible), the factorized chooser's pick per
    mask regime, the analytic cost of every (r, u) factorization, and the
    measured acceptance walls: the chosen r>1∧u>1 factorization vs the
    pure-ring (r=8) and head-parallel (r=1, u=8) extremes on 8 host
    devices."""
    from repro.core import mask as mkm
    from repro.core import schedule as spm

    B, N, P, Hq, Hkv, D = 1, 2048, 8, 8, 2, 64
    Tl = N // P
    bnd = mkm.doc_boundaries(N, 8)
    regimes = [
        ("causal", mkm.causal(), False),
        ("windowed", mkm.sliding_window(N // 8), False),
        ("document", mkm.document(boundaries=bnd), False),
    ]
    picks = {}
    for rname, m, dyn in regimes:
        name, r, u = spm.choose_schedule(m, P, Tl=Tl, B=B, Hq=Hq,
                                         Hkv=Hkv, Dqk=D, Dv=D, bpe=4,
                                         dynamic_seg=dyn, factorize=True)
        picks[rname] = (m, name, r, u)
        row(f"plans2d/auto_{rname}_gqa8x2", 0, f"resolved={name}@r{r}u{u}")
        for rr, uu in spm.factorizations(P):
            for sched in ("ring", "balanced"):
                if uu == 1:
                    if not spm.plan_capable(sched, m):
                        continue
                    cost = spm.plan_cost(
                        spm.build_plan(sched, m, P, Tl), B=B, Hq=Hq,
                        Hkv=Hkv, Dqk=D, Dv=D, bpe=4, dynamic_seg=dyn)
                else:
                    if not spm.plan2d_capable(sched, m, r=rr, u=uu,
                                              Hq=Hq, Hkv=Hkv):
                        continue
                    cost = spm.plan2d_cost(
                        spm.build_plan2d(sched, m, rr, uu, Tl, Hq=Hq,
                                         Hkv=Hkv), B=B, Dqk=D, Dv=D,
                        bpe=4, dynamic_seg=dyn)
                t = cost.time_estimate()
                row(f"plans2d/cost_{sched}_r{rr}u{uu}_{rname}", 0,
                    f"pred_total_s={t['step_s_lower_bound']:.3e} "
                    f"pred_bound={t['bound']}")

    # measured acceptance walls: regimes whose pick is a genuine 2D
    # factorization (r > 1 and u > 1) race against both 1D extremes.
    # fwd + grads — the horizon the chooser ranked on (include_bwd=True)
    for rname, (m, name, r, u) in picks.items():
        if r == 1 or u == 1:
            continue
        code = f"""
import time, statistics, jax, jax.numpy as jnp
from repro.core import mask as mk
from repro.core.dist_attention import DistAttnSpec, Mesh2DSpec, dist_flash_attn
B,N,Hq,Hkv,D = {B},{N},{Hq},{Hkv},{D}
ks = jax.random.split(jax.random.PRNGKey(0),3)
q = jax.random.normal(ks[0],(B,N,Hq,D),jnp.float32)
k = jax.random.normal(ks[1],(B,N,Hkv,D),jnp.float32)
v = jax.random.normal(ks[2],(B,N,Hkv,D),jnp.float32)
m = mk.{m!r}
def timeit(f,*a):
    jax.block_until_ready(f(*a)); ts=[]
    for _ in range(5):
        t0=time.perf_counter(); jax.block_until_ready(f(*a))
        ts.append(time.perf_counter()-t0)
    return statistics.median(ts)*1e6
for label, sched, r, u in (("chosen",{name!r},{r},{u}),
                           ("pure_ring","ring",8,1),
                           ("head_parallel","ring",1,8)):
    if u == 1:
        mesh = jax.make_mesh((1,8), ("data","model"))
        spec = DistAttnSpec(axis="model", axis_size=8, schedule=sched, mask=m)
    else:
        mesh = jax.make_mesh((1,r,u), ("data","seq","head"))
        spec = DistAttnSpec(axis="seq", axis_size=8, schedule=sched,
                            mask=m, mesh2d=Mesh2DSpec(r=r,u=u))
    def loss(a,b,c,mesh=mesh,spec=spec):
        o,_ = dist_flash_attn(a,b,c,mesh,spec,batch_axes=None)
        return jnp.sum(o*o)
    f = jax.jit(jax.value_and_grad(loss, argnums=(0,1,2)))
    print(f"RESULT {{label}} {{timeit(f,q,k,v):.0f}}")
"""
        walls = {}
        for line in _subproc(code).splitlines():
            if line.startswith("RESULT"):
                _, label, us = line.split()
                walls[label] = float(us)
                row(f"plans2d/attn_step_{label}_{rname}_gqa8x2_seq2k_8dev",
                    f"{float(us):.0f}", "fwd+bwd wall us, CPU host mesh")
        if len(walls) == 3:
            row(f"plans2d/accept_{rname}", 0,
                f"chosen={name}@r{r}u{u} "
                f"beats_ring={'yes' if walls['chosen'] < walls['pure_ring'] else 'NO'} "
                f"beats_head_parallel="
                f"{'yes' if walls['chosen'] < walls['head_parallel'] else 'NO'}")


# --------------------------------------------------------------- autotune

def bench_autotune_ab():
    """Tuning-table A/B (tracked): derived rows only — no timing — so the
    tracked file is deterministic across CI hosts.  For every schedule row
    in the active table, resolve ``schedule="auto"`` through the consumer
    chain (table hit → calibrated coeffs → roofline) and record whether it
    returns the measured winner; then replay the calibration fit stored in
    the table (per-regime roofline pick vs calibrated pick vs measured
    best, Spearman of each cost model against wall time)."""
    from repro.core.schedule import choose_schedule, plan_capable
    from repro.tune.calibrate import mask_for_kind
    from repro.tune.table import active_table
    tab = active_table()
    if tab is None:
        row("autotune/table", 0, "none active (run tools/autotune.py sweep)")
        return
    row("autotune/table", 0, os.path.basename(tab.path or "<in-memory>"))
    n_match = n_rows = 0
    for r in tab.schedule_rows():
        seq, P = r["seq"], r["P"]
        m = mask_for_kind(r["mask_kind"], T=seq, window=r.get("window"))
        Hq = r.get("Hq", 8)
        Hkv = r.get("Hkv") or Hq
        pick = choose_schedule(m, P, Tl=seq // P, B=r.get("B", 1),
                               Hq=Hq, Hkv=Hkv, Dqk=r.get("Dqk", 64),
                               bpe=r.get("bpe", 4),
                               dynamic_seg=bool(r.get("dynamic_seg")),
                               include_bwd=False)
        # auto's candidate set excludes zigzag (needs the caller's layout
        # permutation) — judge the pick against the fastest *capable*
        # schedule, and report the global winner alongside
        names = [n for n in ("balanced", "ring") if plan_capable(n, m)]
        if Hq % P == 0 and Hkv % P == 0:
            names.append("ulysses")
        best_cap = tab.best_schedule(mask_kind=r["mask_kind"], P=P, seq=seq,
                                     candidates=names)
        ok = pick == best_cap
        n_rows += 1
        n_match += ok
        row(f"autotune/auto_{r['mask_kind']}_P{P}_seq{seq}", 0,
            f"auto={pick} best_capable={best_cap} global_best={r['best']} "
            f"match={'yes' if ok else 'NO'}")
    row("autotune/auto_match", 0, f"{n_match}/{n_rows}")
    fit = tab.data.get("calibration", {}).get("fit")
    if not fit:
        row("autotune/calibration", 0, "absent")
        return
    for reg in fit.get("regimes", []):
        row(f"autotune/costmodel_{reg['mask_kind']}_P{reg['P']}"
            f"_seq{reg['seq']}", 0,
            f"measured_best={reg['measured_best']} "
            f"calibrated_pick={reg['calibrated_pick']} "
            f"roofline_pick={reg['roofline_pick']}")
    row("autotune/spearman_calibrated", 0, f"{fit['spearman']:.4f}")
    row("autotune/spearman_roofline", 0,
        f"{fit['spearman_roofline']:.4f}")
    row("autotune/best_match_calibrated", 0, fit["best_match"])
    row("autotune/best_match_roofline", 0, fit["best_match_roofline"])
    from benchmarks.kernel_bench import tuned_tile_rows
    tiles = tuned_tile_rows()
    for t in tiles["rows"]:
        row(f"autotune/tiles_{t['backend']}_{t['mask_kind']}_seq{t['seq']}"
            f"_{t['op']}", 0,
            f"resolved={t['resolved'][0]}x{t['resolved'][1]} "
            f"measured_best={t['measured_best'][0]}x{t['measured_best'][1]} "
            f"match={'yes' if t['match'] else 'NO'}")
    if tiles["rows"]:
        row("autotune/tiles_all_match", 0,
            "yes" if tiles["all_match"] else "NO")


# ------------------------------------------------------------- appendix D

def bench_appendixD_comm_volume():
    """Analytic communication volume (paper App. D): DISTFLASHATTN 3Nd vs
    Megatron-LM 14Nd (with remat recompute)."""
    d = 4096
    row("appD/distflashattn_comm_per_token", 0, f"{3 * d * 2}B (3Nd bf16)")
    row("appD/megatron_remat_comm_per_token", 0, f"{14 * d * 2}B (14Nd)")
    row("appD/reduction", 0, f"{14 / 3:.2f}x")


# ---------------------------------------------------------------- table 2

def bench_table2_max_seqlen():
    """Max per-device sequence model (paper Table 2): LLaMA-nH ladder on
    16×A100-40G. Sequence parallelism scales to all 16 devices regardless
    of head count; Megatron TP is capped at `heads` (+ DP which does not
    reduce per-sequence memory)."""
    HBM = 40e9
    devs = 16
    for name, d, L, heads in [("16H", 2048, 64, 16), ("8H", 2048, 64, 8),
                              ("4H", 2048, 64, 4), ("2H", 2048, 64, 2)]:
        act_per_tok_layer = 2 * 2 * d + 4        # saved (x, o, lse) bf16
        peak_layer = 18 * d * 2                   # live working set, 1 layer
        per_tok = act_per_tok_layer * L + peak_layer * 4
        ours = devs * (HBM * 0.6) / per_tok
        tp = min(heads, devs)
        meg = tp * (HBM * 0.6) / per_tok
        row(f"table2/ours_max_seq_{name}", 0, f"{int(ours // 1024)}K")
        row(f"table2/megatron_tp_dp_max_seq_{name}", 0,
            f"{int(meg // 1024)}K")
        row(f"table2/ratio_{name}", 0, f"{ours / meg:.1f}x")


# --------------------------------------------------------------- roofline

def bench_roofline_table():
    """§Roofline: dump the dry-run table (if results/dryrun exists)."""
    files = sorted(glob.glob(os.path.join(
        os.path.dirname(__file__), "..", "results", "dryrun",
        "pod1_*.json")))
    for f in files:
        d = json.load(open(f))
        r = d.get("roofline", {})
        adj = d.get("adjusted", {})
        ur = adj.get("useful_flops_ratio")
        row(f"roofline/{d['arch']}/{d['shape']}", 0,
            f"bound={r.get('bound')} "
            f"step_lb={r.get('step_s_lower_bound', 0):.4f}s "
            f"C={r.get('compute_s', 0):.4f} M={r.get('memory_s', 0):.4f} "
            f"K={r.get('collective_s', 0):.4f} "
            f"useful={ur:.3f}" if ur else "pending")


def _subproc(code):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=1800)
    if r.returncode != 0:
        print(r.stderr[-2000:], file=sys.stderr)
    return r.stdout


BENCHES = {
    "fig4": bench_fig4_load_balance,
    "table5": bench_table5_checkpointing,
    "table3": bench_table3_rsa,
    "table4": bench_table4_ulysses,
    "table2": bench_table2_max_seqlen,
    "appD": bench_appendixD_comm_volume,
    "plans": bench_schedules_plans,
    "plans2d": bench_schedules_plans2d,
    "schedules": bench_schedules_wall,
    "autotune": bench_autotune_ab,
    "roofline": bench_roofline_table,
}

# the subset tracked in BENCH_schedules.json (CI smoke + in-repo history):
# deterministic derived rows + static plan/step-count/cost rows + the
# schedule-level wall rows + the tuning-table A/B resolution rows
TRACKED = ("fig4", "appD", "table2", "plans", "plans2d", "schedules",
           "autotune")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names, or 'tracked' for "
                         "the BENCH_schedules.json subset")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write rows to a machine-readable JSON file")
    args = ap.parse_args()
    if args.only == "tracked":
        names = list(TRACKED)
    else:
        names = args.only.split(",") if args.only else list(BENCHES)
    print("name,us_per_call,derived")
    for n in names:
        BENCHES[n]()
    if args.json:
        rows = [dict(name=n, us_per_call=us, derived=d)
                for n, us, d in ROWS]
        with open(args.json, "w") as f:
            json.dump(dict(version=1, generated_by="benchmarks/run.py",
                           benches=names, rows=rows), f, indent=1)
            f.write("\n")
        print(f"wrote {os.path.abspath(args.json)}", file=sys.stderr)


if __name__ == "__main__":
    main()
