"""Tracked serving benchmark: the paged continuous-batching engine under a
seeded synthetic arrival trace.

Requests arrive by a deterministic pseudo-Poisson process (seeded numpy
RNG) with mixed prompt lengths, generation budgets, and temperatures; the
engine is stepped until drained while per-token wall times are recorded.

Reported (CSV rows like benchmarks/run.py, JSON via ``--json``):

  * serving/tokens_per_s           — end-to-end decode throughput
  * serving/p50|p99_token_ms       — per-token latency percentiles
    (token wall-time = its engine-step duration; TTFT separately)
  * serving/ttft_p50_ms            — median time-to-first-token
  * serving/steps, preemptions, occupancy — scheduler behavior
  * serving/pred_*                 — analytic paged-decode roofline terms
    (analysis/roofline.paged_decode_terms) at the trace's mean context

Results are written to ``BENCH_serving.json`` (repo root by default) so
the serving-perf trajectory is tracked in-repo; CI runs
``python -m benchmarks.serving_bench --smoke`` and uploads the file.

    PYTHONPATH=src python -m benchmarks.serving_bench [--smoke] [--out F]
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import time

import jax
import numpy as np

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_serving.json")

ROWS = []


def row(name, us, derived=""):
    ROWS.append(dict(name=name, us_per_call=us, derived=derived))
    print(f"{name},{us},{derived}", flush=True)


def _trace(rng, n_requests, prompt_lens, budgets, mean_gap):
    """Seeded arrival trace: (arrive_step, prompt_len, n_new, temperature)."""
    t = 0
    out = []
    for i in range(n_requests):
        t += int(rng.poisson(mean_gap))
        out.append((t, int(rng.choice(prompt_lens)),
                    int(rng.choice(budgets)),
                    float(rng.choice([0.0, 0.0, 0.8]))))
    return out


def run_trace(*, arch="smollm-360m", n_requests=8, max_batch=4,
              block_size=8, n_blocks=17, prompt_lens=(16, 24, 32),
              budgets=(6, 10, 14), mean_gap=1, seed=0):
    # 16 usable blocks against bursty arrivals and long budgets: the
    # tracked trace exercises queueing AND pool-pressure preemption
    from repro.analysis import roofline as R
    from repro.core.config import ShapeSpec, get_config, smoke_config
    from repro.data.pipeline import SyntheticTokens
    from repro.models.transformer import Runtime, build_model
    from repro.parallel.sharding import make_parallel_config
    from repro.serve.engine import Engine

    cfg = smoke_config(get_config(arch))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeSpec("bench", max(prompt_lens), max(4, n_requests),
                      "prefill")
    par = make_parallel_config(mesh, shape)
    model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.asarray(
        SyntheticTokens(cfg, shape, par, mesh).batch(0)["tokens"])

    rng = np.random.default_rng(seed)
    trace = _trace(rng, n_requests, prompt_lens, budgets, mean_gap)
    eng = Engine(model, params, max_batch=max_batch, block_size=block_size,
                 n_blocks=n_blocks)

    # warmup outside timing: every prefill bucket the trace can reach
    # (prompts AND preemption re-prefills, which land at arbitrary context
    # lengths) plus the jitted decode step — so the tracked latencies
    # measure serving, not XLA compilation
    max_ctx = max(prompt_lens) + max(budgets)
    b = eng._prefill_bucket
    for tb in range(b, max_ctx + b, b):
        eng._prefill(np.zeros((tb,), np.int32))
    w = eng.submit(prompts[0][:prompt_lens[0]], max_new_tokens=2)
    eng.run()
    del eng.requests[w]
    warm_steps = eng.sched.step_count
    warm_preempt = eng.sched.n_preemptions

    submit_t, first_t = {}, {}
    token_ms = []
    occupancy = []
    pending = sorted(trace, key=lambda x: x[0])
    step = 0
    i = 0
    rids = []
    t_start = time.perf_counter()
    while pending[len(rids):] or not eng.sched.idle:
        while len(rids) < len(pending) and pending[len(rids)][0] <= step:
            _, plen, n_new, temp = pending[len(rids)]
            r = eng.submit(prompts[i % len(prompts)][:plen],
                           max_new_tokens=n_new, temperature=temp, seed=i)
            submit_t[r] = time.perf_counter()
            rids.append(r)
            i += 1
        t0 = time.perf_counter()
        events = eng.step()
        dt_ms = (time.perf_counter() - t0) * 1e3
        n_tok = sum(len(v) for v in events.values())
        occupancy.append(len(eng.sched.running))
        for r, toks in events.items():
            if r not in first_t and toks:
                first_t[r] = time.perf_counter()
            token_ms.extend([dt_ms / max(n_tok, 1)] * len(toks))
        step += 1
        if step > 100_000:
            raise RuntimeError("trace did not drain")
    wall = time.perf_counter() - t_start

    total_tokens = sum(len(eng.requests[r].emitted) for r in rids)
    ttft = sorted((first_t[r] - submit_t[r]) * 1e3
                  for r in rids if r in first_t)
    mean_ctx = int(np.mean([len(eng.requests[r].prompt)
                            + len(eng.requests[r].emitted) for r in rids]))
    stats = eng.stats
    return {
        "arch": cfg.name,
        "n_requests": n_requests,
        "total_tokens": total_tokens,
        "wall_s": wall,
        "tokens_per_s": total_tokens / wall,
        "p50_token_ms": statistics.median(token_ms),
        "p99_token_ms": (sorted(token_ms)[max(0, int(0.99 * len(token_ms))
                                              - 1)]),
        "ttft_p50_ms": ttft[len(ttft) // 2],
        "steps": stats["steps"] - warm_steps,          # trace only, not warmup
        "preemptions": stats["n_preemptions"] - warm_preempt,
        "mean_occupancy": float(np.mean(occupancy)),
        "mean_context": mean_ctx,
        "pred": R.paged_decode_terms(cfg, batch=max_batch,
                                     mean_len=mean_ctx,
                                     block_size=block_size, bpe=4),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized trace (fewer, shorter requests)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)

    kw = {}
    if args.smoke:
        kw = dict(n_requests=5, prompt_lens=(16, 24), budgets=(3, 4),
                  n_blocks=24)   # small pool: exercises queueing on CI
    res = run_trace(**kw)

    row("serving/tokens_per_s", 0, f"{res['tokens_per_s']:.2f}")
    row("serving/p50_token_ms", f"{res['p50_token_ms'] * 1e3:.0f}",
        f"{res['p50_token_ms']:.1f}ms")
    row("serving/p99_token_ms", f"{res['p99_token_ms'] * 1e3:.0f}",
        f"{res['p99_token_ms']:.1f}ms")
    row("serving/ttft_p50_ms", f"{res['ttft_p50_ms'] * 1e3:.0f}",
        f"{res['ttft_p50_ms']:.1f}ms")
    row("serving/trace", 0,
        f"requests={res['n_requests']} tokens={res['total_tokens']} "
        f"steps={res['steps']} preemptions={res['preemptions']} "
        f"occupancy={res['mean_occupancy']:.2f}")
    p = res["pred"]
    row("serving/pred_roofline", 0,
        f"bound={p['bound']} tok_s_bound={p['tok_s_bound']:.0f} "
        f"block_waste={p['block_waste']:.2f} "
        f"step_lb={p['step_s_lower_bound']:.2e}s "
        f"(mean_ctx={res['mean_context']})")

    out = dict(version=1, generated_by="benchmarks/serving_bench.py",
               smoke=bool(args.smoke), result=res, rows=ROWS)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")
    print(f"wrote {os.path.abspath(args.out)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
