"""Tracked serving benchmark: the paged continuous-batching engine under a
seeded synthetic arrival trace.

Requests arrive by a deterministic pseudo-Poisson process (seeded numpy
RNG) with mixed prompt lengths, generation budgets, and temperatures; the
engine is stepped until drained while per-token wall times are recorded.

Reported (CSV rows like benchmarks/run.py, JSON via ``--json``):

  * serving/tokens_per_s           — end-to-end decode throughput
  * serving/p50|p99_token_ms       — per-token latency percentiles
    (token wall-time = its engine-step duration; TTFT separately)
  * serving/ttft_p50_ms            — median time-to-first-token
  * serving/steps, preemptions, occupancy — scheduler behavior
  * serving/pred_*                 — analytic paged-decode roofline terms
    (analysis/roofline.paged_decode_terms) at the trace's mean context
  * serving/shared_prefix_*        — the shared-system-prompt A/B: the
    same staggered trace of requests sharing one long prefix, run with
    the prefix cache off (cold) and on (cached) — cache-hit rate, median
    TTFT (steps and ms), peak pool blocks in use, and tokens/s for both
    regimes, plus the analytic cold/warm TTFT lower bounds
    (analysis/roofline.prefix_cache_terms)
  * serving/chaos_*                — the degraded-mode A/B: the same
    arrival trace with a seeded fault storm off (calm) and on (storm),
    through an engine with admission control + always-on auditing —
    tokens/s, shed rate, quarantine count, p99 TTFT, and the storm's
    throughput retention
  * serving/spec_*                 — the speculative-decoding A/B: the
    same arrival trace with speculation off and on (ModelDraft sharing
    the target's params — the acceptance ceiling regime) — tokens/step,
    acceptance rate, tokens/s, p99 TTFT, plus the analytic
    expected-tokens/step and speedup bounds
    (analysis/roofline.speculative_terms); byte-identical streams across
    the two regimes are asserted, not assumed

Results are written to ``BENCH_serving.json`` (repo root by default) so
the serving-perf trajectory is tracked in-repo; CI runs
``python -m benchmarks.serving_bench --smoke`` and uploads the file.

    PYTHONPATH=src python -m benchmarks.serving_bench [--smoke] [--out F]
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import time

import jax
import numpy as np

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_serving.json")

ROWS = []


def row(name, us, derived=""):
    ROWS.append(dict(name=name, us_per_call=us, derived=derived))
    print(f"{name},{us},{derived}", flush=True)


def _trace(rng, n_requests, prompt_lens, budgets, mean_gap):
    """Seeded arrival trace: (arrive_step, prompt_len, n_new, temperature)."""
    t = 0
    out = []
    for i in range(n_requests):
        t += int(rng.poisson(mean_gap))
        out.append((t, int(rng.choice(prompt_lens)),
                    int(rng.choice(budgets)),
                    float(rng.choice([0.0, 0.0, 0.8]))))
    return out


def run_trace(*, arch="smollm-360m", n_requests=8, max_batch=4,
              block_size=8, n_blocks=17, prompt_lens=(16, 24, 32),
              budgets=(6, 10, 14), mean_gap=1, seed=0):
    # 16 usable blocks against bursty arrivals and long budgets: the
    # tracked trace exercises queueing AND pool-pressure preemption
    from repro.analysis import roofline as R
    from repro.core.config import ShapeSpec, get_config, smoke_config
    from repro.data.pipeline import SyntheticTokens
    from repro.models.transformer import Runtime, build_model
    from repro.parallel.sharding import make_parallel_config
    from repro.serve.engine import Engine

    cfg = smoke_config(get_config(arch))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeSpec("bench", max(prompt_lens), max(4, n_requests),
                      "prefill")
    par = make_parallel_config(mesh, shape)
    model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.asarray(
        SyntheticTokens(cfg, shape, par, mesh).batch(0)["tokens"])

    rng = np.random.default_rng(seed)
    trace = _trace(rng, n_requests, prompt_lens, budgets, mean_gap)
    eng = Engine(model, params, max_batch=max_batch, block_size=block_size,
                 n_blocks=n_blocks)

    # warmup outside timing: every chunk shape the trace can reach
    # (prompts AND preemption re-prefills, which land at arbitrary context
    # lengths) plus the jitted decode step — so the tracked latencies
    # measure serving, not XLA compilation
    max_ctx = max(prompt_lens) + max(budgets)
    eng.warm_prefill(max_ctx)
    w = eng.submit(prompts[0][:prompt_lens[0]], max_new_tokens=2)
    eng.run()
    del eng.requests[w]
    warm_steps = eng.sched.step_count
    warm_preempt = eng.sched.n_preemptions

    submit_t, first_t = {}, {}
    token_ms = []
    occupancy = []
    pending = sorted(trace, key=lambda x: x[0])
    step = 0
    i = 0
    rids = []
    t_start = time.perf_counter()
    while pending[len(rids):] or not eng.sched.idle:
        while len(rids) < len(pending) and pending[len(rids)][0] <= step:
            _, plen, n_new, temp = pending[len(rids)]
            r = eng.submit(prompts[i % len(prompts)][:plen],
                           max_new_tokens=n_new, temperature=temp, seed=i)
            submit_t[r] = time.perf_counter()
            rids.append(r)
            i += 1
        t0 = time.perf_counter()
        events = eng.step()
        dt_ms = (time.perf_counter() - t0) * 1e3
        n_tok = sum(len(v) for v in events.values())
        occupancy.append(len(eng.sched.running))
        for r, toks in events.items():
            if r not in first_t and toks:
                first_t[r] = time.perf_counter()
            token_ms.extend([dt_ms / max(n_tok, 1)] * len(toks))
        step += 1
        if step > 100_000:
            raise RuntimeError("trace did not drain")
    wall = time.perf_counter() - t_start

    total_tokens = sum(len(eng.requests[r].emitted) for r in rids)
    ttft = sorted((first_t[r] - submit_t[r]) * 1e3
                  for r in rids if r in first_t)
    mean_ctx = int(np.mean([len(eng.requests[r].prompt)
                            + len(eng.requests[r].emitted) for r in rids]))
    stats = eng.stats()
    return {
        "arch": cfg.name,
        "n_requests": n_requests,
        "total_tokens": total_tokens,
        "wall_s": wall,
        "tokens_per_s": total_tokens / wall,
        "p50_token_ms": statistics.median(token_ms),
        "p99_token_ms": (sorted(token_ms)[max(0, int(0.99 * len(token_ms))
                                              - 1)]),
        "ttft_p50_ms": ttft[len(ttft) // 2],
        "steps": stats["steps"] - warm_steps,          # trace only, not warmup
        "preemptions": stats["n_preemptions"] - warm_preempt,
        "mean_occupancy": float(np.mean(occupancy)),
        "mean_context": mean_ctx,
        "pred": R.paged_decode_terms(cfg, batch=max_batch,
                                     mean_len=mean_ctx,
                                     block_size=block_size, bpe=4),
    }


def run_shared_prefix(*, arch="smollm-360m", n_requests=6, prefix_len=48,
                      tail_len=7, budget=4, gap=4, max_batch=4,
                      block_size=8, n_blocks=96, chunk_tokens=8, seed=0):
    """Shared-system-prompt A/B: ``n_requests`` staggered requests share
    one ``prefix_len``-token prefix (distinct short tails).  The same
    trace runs twice — prefix cache off (every request re-prefills and
    re-stores the prefix) and on (later arrivals share the first
    request's blocks) — measuring cache-hit rate, TTFT, peak blocks in
    use, and throughput."""
    from repro.analysis import roofline as R
    from repro.core.config import ShapeSpec, get_config, smoke_config
    from repro.data.pipeline import SyntheticTokens
    from repro.models.transformer import Runtime, build_model
    from repro.parallel.sharding import make_parallel_config
    from repro.serve.engine import Engine

    cfg = smoke_config(get_config(arch))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeSpec("bench", prefix_len + tail_len, 4, "prefill")
    par = make_parallel_config(mesh, shape)
    model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))
    params = model.init(jax.random.PRNGKey(0))
    rows = np.asarray(
        SyntheticTokens(cfg, shape, par, mesh).batch(0)["tokens"])
    system = rows[0][:prefix_len]
    reqs = [np.concatenate([system, rows[1 + i % 3][:tail_len]])
            for i in range(n_requests)]

    def drive(prefix_cache):
        eng = Engine(model, params, max_batch=max_batch,
                     block_size=block_size, n_blocks=n_blocks,
                     prefill_chunk_tokens=chunk_tokens,
                     prefix_cache=prefix_cache)
        eng.warm_prefill(prefix_len + tail_len + budget)
        # compile the decode step too (a 2-token prompt registers no full
        # block, so the cached run's stats stay clean), then zero the
        # counters so hit-rate reflects the measured trace only
        w = eng.submit(rows[1][:3], max_new_tokens=2)
        eng.run()
        del eng.requests[w]
        for k in eng.cache.counters:
            eng.cache.counters[k] = 0
        submit_t, submit_step, first_t, first_step = {}, {}, {}, {}
        peak_blocks = 0
        t_start = time.perf_counter()
        step = 0
        rids = []
        while len(rids) < len(reqs) or not eng.sched.idle:
            if len(rids) < len(reqs) and step >= gap * len(rids):
                r = eng.submit(reqs[len(rids)], max_new_tokens=budget)
                submit_t[r], submit_step[r] = time.perf_counter(), step
                rids.append(r)
            events = eng.step()
            for r, toks in events.items():
                if r not in first_t and toks:
                    first_t[r] = time.perf_counter()
                    first_step[r] = step
            peak_blocks = max(peak_blocks, eng.cache.allocator.n_usable
                              - eng.cache.allocator.n_free)
            step += 1
            if step > 100_000:
                raise RuntimeError("shared-prefix trace did not drain")
        wall = time.perf_counter() - t_start
        total = sum(len(eng.requests[r].emitted) for r in rids)
        ttft_ms = sorted((first_t[r] - submit_t[r]) * 1e3 for r in rids)
        ttft_steps = sorted(first_step[r] - submit_step[r] for r in rids)
        n_prefill = sum(len(q) - 1 for q in reqs)
        return {
            "ttft_p50_ms": ttft_ms[len(ttft_ms) // 2],
            "ttft_p50_steps": ttft_steps[len(ttft_steps) // 2],
            "peak_blocks": peak_blocks,
            "tokens_per_s": total / wall,
            "hit_rate": eng.stats()["hit_tokens"] / n_prefill,
            "forks": eng.stats()["forks"],
            "dedup_swaps": eng.stats()["dedup_swaps"],
            "stored_prefix_copies": (eng.stats()["cache_blocks"]
                                     if prefix_cache else None),
        }

    cold = drive(False)
    cached = drive(True)
    hit = cached["hit_rate"]
    return {
        "n_requests": n_requests, "prefix_len": prefix_len,
        "tail_len": tail_len, "chunk_tokens": chunk_tokens,
        "cold": cold, "cached": cached,
        "cache_hit_rate": hit,
        "ttft_reduction": 1 - cached["ttft_p50_ms"] / cold["ttft_p50_ms"],
        "peak_blocks_reduction": 1 - cached["peak_blocks"]
                                     / cold["peak_blocks"],
        "pred": R.prefix_cache_terms(cfg, prompt_len=prefix_len + tail_len,
                                     hit_rate=hit,
                                     chunk_tokens=chunk_tokens, bpe=4),
    }


def run_chaos(*, arch="smollm-360m", n_requests=8, max_batch=4,
              block_size=8, n_blocks=24, prompt_lens=(16, 24),
              budgets=(4, 6), mean_gap=1, chaos_seed=1234,
              storm_steps=24, storm_rate=0.5, seed=0):
    """Degraded-mode A/B: the same seeded arrival trace driven twice —
    fault storm off, then on (``FaultInjector.seeded(chaos_seed)``) —
    through an engine with admission control + auditing enabled.
    Reports per-regime tokens/s, shed rate, quarantine count, and p99
    TTFT: the cost of surviving the storm."""
    from repro.core.config import ShapeSpec, get_config, smoke_config
    from repro.data.pipeline import SyntheticTokens
    from repro.models.transformer import Runtime, build_model
    from repro.parallel.sharding import make_parallel_config
    from repro.serve.engine import Engine
    from repro.serve.faults import FaultInjector

    cfg = smoke_config(get_config(arch))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeSpec("bench", max(prompt_lens), max(4, n_requests),
                      "prefill")
    par = make_parallel_config(mesh, shape)
    model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.asarray(
        SyntheticTokens(cfg, shape, par, mesh).batch(0)["tokens"])
    trace = _trace(np.random.default_rng(seed), n_requests, prompt_lens,
                   budgets, mean_gap)

    def drive(faulty):
        eng = Engine(model, params, max_batch=max_batch,
                     block_size=block_size, n_blocks=n_blocks,
                     prefill_chunk_tokens=8, max_queue=2 * max_batch,
                     max_retries=6, audit=True)
        eng.warm_prefill(max(prompt_lens) + max(budgets))
        w = eng.submit(prompts[0][:prompt_lens[0]], max_new_tokens=2)
        eng.run()
        del eng.requests[w]
        if faulty:
            # timeline starts after warmup: the storm hits the trace
            eng.install_faults(FaultInjector.seeded(
                chaos_seed, n_steps=storm_steps, rate=storm_rate))
        submit_t, first_t = {}, {}
        pending = sorted(trace, key=lambda x: x[0])
        rids = []
        step, i = 0, 0
        t_start = time.perf_counter()
        while len(rids) < len(pending) or not eng.sched.idle:
            while len(rids) < len(pending) and pending[len(rids)][0] <= step:
                _, plen, n_new, temp = pending[len(rids)]
                r = eng.submit(prompts[i % len(prompts)][:plen],
                               max_new_tokens=n_new, temperature=temp,
                               seed=i)
                submit_t[r] = time.perf_counter()
                rids.append(r)
                i += 1
            for r, toks in eng.step().items():
                if r not in first_t and toks:
                    first_t[r] = time.perf_counter()
            step += 1
            if step > 100_000:
                raise RuntimeError("chaos trace did not drain")
        wall = time.perf_counter() - t_start
        eng.release_faults()
        eng.cache.allocator.check_conservation()   # survives the storm
        s = eng.stats()
        total = sum(len(eng.requests[r].emitted) for r in rids)
        ttft = sorted((first_t[r] - submit_t[r]) * 1e3
                      for r in rids if r in first_t)
        return {
            "tokens_per_s": total / wall,
            "total_tokens": total,
            "shed": s["shed"],
            "shed_rate": s["shed"] / n_requests,
            "quarantined": s["quarantined"],
            "expired": s["expired"],
            "failed": s["failed"],
            "retried": s["retried"],
            "watchdog_trips": s["watchdog_trips"],
            "preemptions": s["n_preemptions"],
            "ttft_p99_ms": (ttft[max(0, int(0.99 * len(ttft)) - 1)]
                            if ttft else None),
            "terminal_states": {
                st: sum(1 for r in rids if eng.requests[r].state == st)
                for st in ("finished", "rejected", "expired", "failed")},
            "faults_applied": dict(eng.injector.counts) if faulty else None,
        }

    calm = drive(False)
    storm = drive(True)
    return {"chaos_seed": chaos_seed, "storm_steps": storm_steps,
            "storm_rate": storm_rate, "n_requests": n_requests,
            "calm": calm, "storm": storm,
            "throughput_retention": (storm["tokens_per_s"]
                                     / calm["tokens_per_s"])}


def run_speculative(*, arch="smollm-360m", n_requests=6, max_batch=4,
                    block_size=8, n_blocks=48, prompt_lens=(16, 24),
                    budgets=(6, 8), mean_gap=1, depth=4, seed=0):
    """Speculative-decoding A/B: the same seeded arrival trace driven
    twice — spec off (vanilla one-token decode) and spec on (a
    ``ModelDraft`` sharing the target's params: the acceptance ceiling
    regime, limited only by draft-side chunked-prefill numerics) —
    measuring tokens/step, acceptance rate, tokens/s, and p99 TTFT.  The
    determinism contract is asserted, not assumed: both regimes must emit
    byte-identical streams."""
    from repro.analysis import roofline as R
    from repro.core.config import ShapeSpec, get_config, smoke_config
    from repro.data.pipeline import SyntheticTokens
    from repro.models.transformer import Runtime, build_model
    from repro.parallel.sharding import make_parallel_config
    from repro.serve.engine import Engine
    from repro.serve.speculative import ModelDraft, SpecConfig

    cfg = smoke_config(get_config(arch))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeSpec("bench", max(prompt_lens), max(4, n_requests),
                      "prefill")
    par = make_parallel_config(mesh, shape)
    model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.asarray(
        SyntheticTokens(cfg, shape, par, mesh).batch(0)["tokens"])
    trace = _trace(np.random.default_rng(seed), n_requests, prompt_lens,
                   budgets, mean_gap)

    def drive(spec_on):
        spec = draft = None
        if spec_on:
            spec = SpecConfig(depth=depth, mode="model",
                              draft_arch=cfg.name)
            draft = ModelDraft(model, params, block_size=block_size,
                               n_blocks=64, max_batch=max_batch)
        eng = Engine(model, params, max_batch=max_batch,
                     block_size=block_size, n_blocks=n_blocks,
                     spec=spec, draft=draft)
        eng.warm_prefill(max(prompt_lens) + max(budgets))
        w = eng.submit(prompts[0][:prompt_lens[0]], max_new_tokens=2)
        eng.run()
        del eng.requests[w]
        if draft is not None:
            draft.release(w)
        warm_steps = eng.sched.step_count
        warm_counters = dict(eng.counters)
        submit_t, first_t = {}, {}
        pending = sorted(trace, key=lambda x: x[0])
        rids = []
        step, i = 0, 0
        t_start = time.perf_counter()
        while len(rids) < len(pending) or not eng.sched.idle:
            while len(rids) < len(pending) and pending[len(rids)][0] <= step:
                _, plen, n_new, temp = pending[len(rids)]
                r = eng.submit(prompts[i % len(prompts)][:plen],
                               max_new_tokens=n_new, temperature=temp,
                               seed=i)
                submit_t[r] = time.perf_counter()
                rids.append(r)
                i += 1
            for r, toks in eng.step().items():
                if r not in first_t and toks:
                    first_t[r] = time.perf_counter()
            step += 1
            if step > 100_000:
                raise RuntimeError("speculative trace did not drain")
        wall = time.perf_counter() - t_start
        s = eng.stats()
        total = sum(len(eng.requests[r].emitted) for r in rids)
        steps = s["steps"] - warm_steps
        ttft = sorted((first_t[r] - submit_t[r]) * 1e3
                      for r in rids if r in first_t)
        proposed = s["spec_proposed"] - warm_counters.get("spec_proposed", 0)
        accepted = s["spec_accepted"] - warm_counters.get("spec_accepted", 0)
        return {
            "tokens_per_s": total / wall,
            "total_tokens": total,
            "steps": steps,
            "tokens_per_step": total / max(steps, 1),
            "ttft_p99_ms": ttft[max(0, int(0.99 * len(ttft)) - 1)],
            "spec_proposed": proposed,
            "spec_accepted": accepted,
            "acceptance": accepted / max(proposed, 1),
            "rollbacks": s["spec_rollbacks"]
            - warm_counters.get("spec_rollbacks", 0),
        }, {r: [int(t) for t in eng.requests[r].emitted] for r in rids}

    off, streams_off = drive(False)
    on, streams_on = drive(True)
    identical = list(streams_off.values()) == list(streams_on.values())
    if not identical:
        raise AssertionError("speculative streams diverged from vanilla")
    mean_ctx = int(np.mean([p + b for _, p, b, _ in trace]))
    return {
        "depth": depth, "n_requests": n_requests,
        "draft": "target-params (ceiling regime)",
        "off": off, "on": on,
        "streams_identical": identical,
        "tokens_per_step_gain": on["tokens_per_step"]
        / max(off["tokens_per_step"], 1e-12),
        "pred": R.speculative_terms(cfg, batch=max_batch,
                                    mean_len=mean_ctx, depth=depth,
                                    acceptance=on["acceptance"],
                                    block_size=block_size, bpe=4),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized trace (fewer, shorter requests)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)

    kw, spkw, chkw, spec_kw = {}, {}, {}, {}
    if args.smoke:
        kw = dict(n_requests=5, prompt_lens=(16, 24), budgets=(3, 4),
                  n_blocks=24)   # small pool: exercises queueing on CI
        spkw = dict(n_requests=4, prefix_len=32, n_blocks=64)
        chkw = dict(n_requests=5, budgets=(3, 4), storm_steps=16)
        spec_kw = dict(n_requests=4, budgets=(4, 6), depth=3)
    res = run_trace(**kw)
    sp = run_shared_prefix(**spkw)
    res["shared_prefix"] = sp
    ch = run_chaos(**chkw)
    res["chaos"] = ch
    spc = run_speculative(**spec_kw)
    res["speculative"] = spc

    row("serving/tokens_per_s", 0, f"{res['tokens_per_s']:.2f}")
    row("serving/p50_token_ms", f"{res['p50_token_ms'] * 1e3:.0f}",
        f"{res['p50_token_ms']:.1f}ms")
    row("serving/p99_token_ms", f"{res['p99_token_ms'] * 1e3:.0f}",
        f"{res['p99_token_ms']:.1f}ms")
    row("serving/ttft_p50_ms", f"{res['ttft_p50_ms'] * 1e3:.0f}",
        f"{res['ttft_p50_ms']:.1f}ms")
    row("serving/trace", 0,
        f"requests={res['n_requests']} tokens={res['total_tokens']} "
        f"steps={res['steps']} preemptions={res['preemptions']} "
        f"occupancy={res['mean_occupancy']:.2f}")
    p = res["pred"]
    row("serving/pred_roofline", 0,
        f"bound={p['bound']} tok_s_bound={p['tok_s_bound']:.0f} "
        f"block_waste={p['block_waste']:.2f} "
        f"step_lb={p['step_s_lower_bound']:.2e}s "
        f"(mean_ctx={res['mean_context']})")
    row("serving/shared_prefix_hit_rate", 0,
        f"{sp['cache_hit_rate']:.2f} (forks={sp['cached']['forks']} "
        f"dedup_swaps={sp['cached']['dedup_swaps']})")
    row("serving/shared_prefix_ttft_ms",
        f"{sp['cached']['ttft_p50_ms'] * 1e3:.0f}",
        f"cached={sp['cached']['ttft_p50_ms']:.1f}ms "
        f"cold={sp['cold']['ttft_p50_ms']:.1f}ms "
        f"(-{sp['ttft_reduction'] * 100:.0f}%; steps "
        f"{sp['cached']['ttft_p50_steps']} vs {sp['cold']['ttft_p50_steps']})")
    row("serving/shared_prefix_peak_blocks", 0,
        f"cached={sp['cached']['peak_blocks']} "
        f"cold={sp['cold']['peak_blocks']} "
        f"(-{sp['peak_blocks_reduction'] * 100:.0f}%)")
    sps = sp["pred"]
    row("serving/shared_prefix_pred", 0,
        f"prefill_flops_saved={sps['prefill_flops_saved_frac']:.2f} "
        f"ttft_lb_cold={sps['ttft_s_lower_bound_cold']:.2e}s "
        f"ttft_lb_cached={sps['ttft_s_lower_bound_cached']:.2e}s")
    for regime in ("calm", "storm"):
        c = ch[regime]
        ttft = (f"{c['ttft_p99_ms']:.1f}ms" if c["ttft_p99_ms"] is not None
                else "n/a")
        row(f"serving/chaos_{regime}", 0,
            f"tok_s={c['tokens_per_s']:.2f} shed_rate={c['shed_rate']:.2f} "
            f"quarantined={c['quarantined']} expired={c['expired']} "
            f"retried={c['retried']} watchdog_trips={c['watchdog_trips']} "
            f"p99_ttft={ttft}")
    row("serving/chaos_retention", 0,
        f"{ch['throughput_retention']:.2f} of calm tokens/s under a "
        f"rate={ch['storm_rate']} seed={ch['chaos_seed']} fault storm")
    for regime in ("off", "on"):
        c = spc[regime]
        row(f"serving/spec_{regime}", 0,
            f"tok_s={c['tokens_per_s']:.2f} "
            f"tok_step={c['tokens_per_step']:.2f} "
            f"p99_ttft={c['ttft_p99_ms']:.1f}ms"
            + (f" acceptance={c['acceptance']:.2f} "
               f"proposed={c['spec_proposed']} "
               f"rollbacks={c['rollbacks']}" if regime == "on" else ""))
    sppred = spc["pred"]
    row("serving/spec_ab", 0,
        f"depth={spc['depth']} tok_step_gain="
        f"{spc['tokens_per_step_gain']:.2f} "
        f"streams_identical={spc['streams_identical']} "
        f"pred_E_tok_step={sppred['expected_tokens_per_step']:.2f} "
        f"pred_speedup_bound={sppred['speedup_bound']:.2f}")

    out = dict(version=1, generated_by="benchmarks/serving_bench.py",
               smoke=bool(args.smoke), result=res, rows=ROWS)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")
    print(f"wrote {os.path.abspath(args.out)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
