"""Schedule simulator: per-worker logical workload of the ring vs the
load-balanced schedule (paper Figure 1 / Figure 4 / Eq. 2).

A pure-python model of who computes which (q-chunk, kv-chunk) pair at which
step. Used both as a benchmark (idle fractions, expected speedups) and as a
coverage proof (every causal pair computed exactly once — the property the
SPMD masks in core/dist_attention implement).
"""
from __future__ import annotations


def ring_schedule(P):
    """steps -> list per step of set of busy workers; returns (work, steps).
    Worker p (0-indexed) computes (p, p−t) at step t if p ≥ t."""
    pairs = {}
    busy = []
    for t in range(0, P):
        b = set()
        for p in range(P):
            if p >= t:
                pairs.setdefault((p, p - t), []).append((t, p))
                b.add(p)
        busy.append(b)
    return pairs, busy


def balanced_schedule(P):
    """Paper Alg. 2 (0-indexed). Returns (pairs, busy-sets per step)."""
    pairs = {}
    busy = []
    # step 0: local causal chunk
    pairs0 = {(p, p): [(0, p)] for p in range(P)}
    pairs.update(pairs0)
    busy.append(set(range(P)))
    T = P // 2
    for t in range(1, T + 1):
        helpers_active = (t != T) or (P % 2 == 1)
        b = set()
        for p in range(P):
            if p >= t:                      # worker path
                pairs.setdefault((p, p - t), []).append((t, p))
                b.add(p)
            elif helpers_active:            # helper computes for w=(p−t)%P
                w = (p - t) % P
                pairs.setdefault((w, p), []).append((t, p))
                b.add(p)
        busy.append(b)
    return pairs, busy


def coverage_ok(pairs, P):
    """Every causal (q, kv) pair computed exactly once."""
    want = {(p, r) for p in range(P) for r in range(p + 1)}
    got = set(pairs)
    dup = [k for k, v in pairs.items() if len(v) != 1]
    return got == want and not dup


def idle_fraction(busy, P):
    steps = len(busy)
    total = steps * P
    active = sum(len(b) for b in busy)
    return (total - active) / total


def expected_speedup(busy, P):
    """Speedup over 1 worker doing all causal work, where each step costs
    one chunk-attention unit (paper Fig. 4 analysis: total work P(P+1)/2
    units; parallel time = #steps)."""
    total_work = P * (P + 1) / 2
    return total_work / len(busy)
