"""Tracked microbenchmark for the chunk-attention kernels.

Measures, per mask regime (a static MaskSpec: causal × window × rel_offset
× packed-document) and backend (``pallas-interpret``, ``chunked-lax``),
forward and backward:

  * the static grid-work profile of the block-sparse pruning — dense steps,
    launched steps, executed steps, work ratio — derived from the *same*
    ``block_sparse`` ranges the kernels size their grids with;
  * median wall-clock of the pruned kernel vs the dense (``prune=False``)
    sweep on this host.

Results are written to ``BENCH_kernels.json`` (repo root by default) so the
kernel perf trajectory is tracked in-repo from PR 2 onward; CI runs
``python -m benchmarks.kernel_bench --smoke`` and uploads the file as an
artifact per PR.

    PYTHONPATH=src python -m benchmarks.kernel_bench [--smoke] [--out F]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp

from repro.core import mask as mk
from repro.kernels import ops
from repro.kernels.block_sparse import kv_profile, q_profile
from repro.kernels.chunked import chunked_bwd, chunked_fwd

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..",
                           "BENCH_kernels.json")


def _regimes(T):
    """Mask regimes keyed to the distributed schedules' chunk_attn sites
    (DESIGN.md §2): T is the per-device chunk length."""
    return {
        # step 0 of every schedule: the local causal chunk (~2x dense work)
        "local_causal": mk.causal(),
        # local chunk under a sliding window (Appendix F variant)
        "local_causal_window": mk.sliding_window(T // 4),
        # ring step t=2: strictly causal pair, mask-free — nothing to prune,
        # tracked to show pruning adds no overhead where it can't win
        "ring_step_full": mk.full(rel_offset=2 * T),
        # windowed ring step t=1: only the trailing window band is live
        "ring_step_window": mk.sliding_window(T // 2, causal=False,
                                              rel_offset=T),
        # packed batch (4 uneven documents, static layout): causal AND
        # same-document — cross-document blocks are pruned at trace time
        "local_causal_document": mk.document(
            boundaries=mk.doc_boundaries(T, 4)),
    }


# interleaved-median A/B clock — shared with the offline tile sweeps
# (repro.tune.sweep) so the table and the tracked bench use one ruler
from repro.tune.timing import timeit_pair as _timeit_pair  # noqa: E402


def _mk(B, T, H, D, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, T, H, D), dtype)
    k = jax.random.normal(ks[1], (B, T, H, D), dtype)
    v = jax.random.normal(ks[2], (B, T, H, D), dtype)
    do = jax.random.normal(ks[3], (B, T, H, D), dtype)
    return q, k, v, do


def _grid_metrics(prof):
    return dict(full_steps=prof.full_steps, launched_steps=prof.launched_steps,
                executed_steps=prof.executed_steps, seq_grid=prof.seq_grid,
                work_ratio=round(prof.work_ratio, 4)
                if prof.executed_steps else None)


def _pallas_runners(q, k, v, do, mask, bq, bk):
    def fwd(prune):
        def run():
            o, lse = ops.flash_fwd(q, k, v, mask=mask, block_q=bq,
                                   block_kv=bk, interpret=True, prune=prune)
            jax.block_until_ready(o)
        return run

    o, lse = ops.flash_fwd(q, k, v, mask=mask, block_q=bq, block_kv=bk,
                           interpret=True)

    def bwd(prune):
        def run():
            g = ops.flash_bwd(q, k, v, o, lse, do, mask=mask, block_q=bq,
                              block_kv=bk, interpret=True, prune=prune)
            jax.block_until_ready(g)
        return run
    return fwd, bwd


def _chunked_runners(q, k, v, do, mask, bk):
    def fwd(prune):
        fn = jax.jit(lambda q, k, v: chunked_fwd(q, k, v, mask=mask,
                                                 block_kv=bk, prune=prune))

        def run():
            jax.block_until_ready(fn(q, k, v))
        return run

    o, lse = chunked_fwd(q, k, v, mask=mask, block_kv=bk)

    def bwd(prune):
        fn = jax.jit(lambda q, k, v, o, lse, do: chunked_bwd(
            q, k, v, o, lse, do, mask=mask, block_kv=bk, prune=prune))

        def run():
            jax.block_until_ready(fn(q, k, v, o, lse, do))
        return run
    return fwd, bwd


def run_bench(*, T, B, H, D, bq, bk, iters, backends):
    q, k, v, do = _mk(B, T, H, D)
    nq, nk = T // bq, T // bk
    cases = []
    for regime, mask in _regimes(T).items():
        fwd_prof = kv_profile(nq=nq, nk=nk, br=bq, bc=bk, mask=mask)
        dkv_prof = q_profile(nq=nq, nk=nk, br=bq, bc=bk, mask=mask)
        bwd_grid = dict(  # dq sweeps the kv grid, dkv the transposed q grid
            full_steps=fwd_prof.full_steps + dkv_prof.full_steps,
            launched_steps=fwd_prof.launched_steps + dkv_prof.launched_steps,
            executed_steps=fwd_prof.executed_steps + dkv_prof.executed_steps,
            seq_grid=max(fwd_prof.seq_grid, dkv_prof.seq_grid))
        ex = bwd_grid["executed_steps"]
        bwd_grid["work_ratio"] = (round(bwd_grid["full_steps"] / ex, 4)
                                  if ex else None)
        # chunked-lax has a single q block (the whole chunk), so its scan
        # can only prune whole-KV-chunk extremes — profile it as such
        scan_prof = kv_profile(nq=1, nk=nk, br=T, bc=bk, mask=mask)
        for backend in backends:
            if backend == "pallas-interpret":
                mk_fwd, mk_bwd = _pallas_runners(q, k, v, do, mask, bq, bk)
                grids = (_grid_metrics(fwd_prof), bwd_grid)
            else:
                mk_fwd, mk_bwd = _chunked_runners(q, k, v, do, mask, bk)
                grids = (_grid_metrics(scan_prof), _grid_metrics(scan_prof))
            for op, mk_run, grid in (("fwd", mk_fwd, grids[0]),
                                     ("bwd", mk_bwd, grids[1])):
                pruned_us, dense_us = _timeit_pair(mk_run(True), mk_run(False),
                                                   iters)
                case = dict(
                    name=f"{regime}/{op}/{backend}",
                    regime=dataclasses.asdict(mask), op=op, backend=backend,
                    shape=dict(B=B, T=T, H=H, D=D, block_q=bq, block_kv=bk,
                               nq=nq, nk=nk),
                    grid=grid,
                    wall_us=dict(pruned=round(pruned_us, 1),
                                 dense=round(dense_us, 1),
                                 speedup=round(dense_us / pruned_us, 3)),
                )
                cases.append(case)
                print(f"{case['name']:52s} steps {grid['executed_steps']:4d}"
                      f"/{grid['full_steps']:4d}"
                      f" (x{grid['work_ratio'] or 1:.2f})"
                      f"  wall {pruned_us/1e3:8.1f}ms vs {dense_us/1e3:8.1f}ms"
                      f" (x{dense_us / pruned_us:.2f})", flush=True)
    return cases


def tuned_tile_rows():
    """Tuning-table A/B (tracked): for every kernel row the active table
    holds for this platform, resolve tile shapes through the consumer
    chain (``registry.block_tuning_kw`` with call context and no explicit
    kwargs) and record whether the table-backed resolution returns the
    measured winner.  Pure lookup, no timing — deterministic across CI
    hosts."""
    from repro.kernels.registry import block_tuning_kw
    from repro.tune.table import active_table
    tab = active_table()
    if tab is None:
        return dict(table=None, all_match=None, rows=[])
    plat = jax.default_backend()
    rows = []
    for r in tab.data.get("kernel", []):
        if r["platform"] != plat:
            continue
        kw = block_tuning_kw(None, None, backend=r["backend"],
                             platform=plat, mask_kind=r["mask_kind"],
                             head_dim=r["head_dim"], seq=r["seq"],
                             op=r["op"])
        got = (kw.get("block_q"), kw.get("block_kv"))
        rows.append(dict(
            backend=r["backend"], mask_kind=r["mask_kind"], seq=r["seq"],
            head_dim=r["head_dim"], op=r["op"],
            measured_best=[r["block_q"], r["block_kv"]],
            resolved=list(got),
            match=got == (r["block_q"], r["block_kv"]),
            sweep=r.get("sweep")))
    return dict(table=os.path.basename(tab.path or ""),
                all_match=all(x["match"] for x in rows), rows=rows)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes / few iters (CI per-PR tracking)")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--iters", type=int, default=None)
    args = ap.parse_args(argv)

    if args.smoke:
        shape = dict(T=256, B=1, H=2, D=32, bq=32, bk=32)   # nq = nk = 8
        iters = args.iters or 2
    else:
        shape = dict(T=1024, B=1, H=2, D=64, bq=128, bk=128)  # nq = nk = 8
        iters = args.iters or 5

    cases = run_bench(**shape, iters=iters,
                      backends=("pallas-interpret", "chunked-lax"))

    # headline numbers tracked across PRs: the grid-step work ratios of the
    # local causal chunk (the step every schedule executes on every device)
    # and the packed-document chunk (must beat plain causal — the packed
    # batch acceptance criterion), plus the pruned-vs-dense wall median
    # ratio of the local causal chunk. The wall figure is computed from
    # the same medians at every shape (smoke values carry more noise than
    # the full shapes, but a measured ratio beats the former null).
    local_fwd = next(c for c in cases
                     if c["name"] == "local_causal/fwd/pallas-interpret")
    doc_fwd = next(c for c in cases if c["name"] ==
                   "local_causal_document/fwd/pallas-interpret")
    assert doc_fwd["grid"]["executed_steps"] < \
        local_fwd["grid"]["executed_steps"], "packed must prune below causal"
    summary = dict(
        local_causal_step_ratio=local_fwd["grid"]["work_ratio"],
        document_step_ratio=doc_fwd["grid"]["work_ratio"],
        local_causal_wall_speedup=round(
            local_fwd["wall_us"]["dense"] / local_fwd["wall_us"]["pruned"],
            3),
    )
    out = dict(version=2, generated_by="benchmarks/kernel_bench.py",
               smoke=bool(args.smoke),
               host=dict(platform=jax.default_backend(), jax=jax.__version__),
               shape=shape, iters=iters, summary=summary,
               tuning=tuned_tile_rows(), cases=cases)
    path = os.path.abspath(args.out)
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
        f.write("\n")
    print(f"wrote {path}")
    tuning = out["tuning"]
    print(f"summary: local causal chunk executes "
          f"{summary['local_causal_step_ratio']}x fewer grid steps; packed "
          f"document chunk {summary['document_step_ratio']}x; "
          f"wall x{summary['local_causal_wall_speedup']}"
          + (f"; tuned tiles {'all match' if tuning['all_match'] else 'MISMATCH'}"
             f" ({len(tuning['rows'])} table rows)" if tuning["table"]
             else "; no tuning table active"))


if __name__ == "__main__":
    main()
