"""Pytree checkpointing: sharding-aware save/restore to an .npz + JSON
manifest. Single-host implementation (multi-host would write per-process
shards keyed by addressable devices; the manifest format already records
the PartitionSpec for that)."""
from __future__ import annotations

import json
import os

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keyed = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, 'key', getattr(k, 'idx', k)))
                       for k in path)
        keyed[key] = leaf
    return keyed, treedef


def save(path: str, tree, step: int = 0) -> None:
    os.makedirs(path, exist_ok=True)
    keyed, _ = _flatten(tree)
    arrays, dtypes = {}, {}
    for k, v in keyed.items():
        a = np.asarray(v)
        dtypes[k] = str(v.dtype)
        if dtypes[k] == "bfloat16":          # npz has no bf16: store bits
            a = a.view(np.uint16)
        arrays[k] = a
    np.savez(os.path.join(path, "weights.npz"), **arrays)
    manifest = {
        "step": step,
        "tensors": {k: {"shape": list(arrays[k].shape), "dtype": dtypes[k]}
                    for k in arrays},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def restore(path: str, like_tree, shardings=None):
    """Restore into the structure of ``like_tree`` (with optional
    NamedShardings applied on device_put)."""
    data = np.load(os.path.join(path, "weights.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    keyed, treedef = _flatten(like_tree)
    sh_keyed = None
    if shardings is not None:
        sh_keyed, _ = _flatten(shardings)
    leaves = []
    for key in keyed:
        arr = data[key]
        if manifest["tensors"][key]["dtype"] == "bfloat16":
            import jax.numpy as jnp
            arr = arr.view(jnp.bfloat16.dtype)
        if sh_keyed is not None:
            arr = jax.device_put(arr, sh_keyed[key])
        leaves.append(arr)
    flat, _ = jax.tree_util.tree_flatten_with_path(like_tree)
    order = ["/".join(str(getattr(k, 'key', getattr(k, 'idx', k)))
                      for k in p) for p, _ in flat]
    by_key = dict(zip(list(keyed.keys()), leaves))
    return jax.tree_util.tree_unflatten(treedef, [by_key[k] for k in order])


def latest_step(path: str) -> int:
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)["step"]
