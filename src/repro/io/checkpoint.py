"""Pytree checkpointing: sharding-aware save/restore to an .npz + JSON
manifest. Single-host implementation (multi-host would write per-process
shards keyed by addressable devices; the manifest format already records
the PartitionSpec for that).

Integrity: ``save`` records a CRC32 + byte-length footer for every file it
writes in the manifest's ``integrity`` section (the manifest carries its
own payload checksum too), and ``restore``/``latest_step`` verify them
before deserializing — a bit-flipped, truncated, or half-written
checkpoint surfaces as a structured :class:`CheckpointCorrupt` naming the
damaged file, not as a cryptic unpickling failure deep in numpy."""
from __future__ import annotations

import json
import os
import zlib

import jax
import numpy as np


class CheckpointCorrupt(RuntimeError):
    """A checkpoint failed integrity verification.

    ``path`` is the checkpoint directory, ``file`` the damaged member,
    ``reason`` what failed (``missing`` / ``truncated`` / ``checksum`` /
    ``no_integrity``).
    """

    def __init__(self, path: str, file: str, reason: str, detail: str = ""):
        self.path = path
        self.file = file
        self.reason = reason
        super().__init__(
            f"corrupt checkpoint {path!r}: {file} — {reason}"
            + (f" ({detail})" if detail else ""))


def _crc(path: str) -> tuple:
    """(crc32, n_bytes) of a file, streamed."""
    crc, n = 0, 0
    with open(path, "rb") as f:
        while True:
            chunk = f.read(1 << 20)
            if not chunk:
                break
            crc = zlib.crc32(chunk, crc)
            n += len(chunk)
    return crc & 0xFFFFFFFF, n


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keyed = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, 'key', getattr(k, 'idx', k)))
                       for k in path)
        keyed[key] = leaf
    return keyed, treedef


def save(path: str, tree, step: int = 0) -> None:
    os.makedirs(path, exist_ok=True)
    keyed, _ = _flatten(tree)
    arrays, dtypes = {}, {}
    for k, v in keyed.items():
        a = np.asarray(v)
        dtypes[k] = str(v.dtype)
        if dtypes[k] == "bfloat16":          # npz has no bf16: store bits
            a = a.view(np.uint16)
        arrays[k] = a
    np.savez(os.path.join(path, "weights.npz"), **arrays)
    crc, n = _crc(os.path.join(path, "weights.npz"))
    manifest = {
        "step": step,
        "tensors": {k: {"shape": list(arrays[k].shape), "dtype": dtypes[k]}
                    for k in arrays},
        "integrity": {"weights.npz": {"crc32": crc, "bytes": n}},
    }
    # the manifest checks itself: its payload checksum is computed over the
    # serialization WITHOUT the manifest_crc32 field, then appended
    body = json.dumps(manifest, indent=1, sort_keys=True)
    manifest["manifest_crc32"] = zlib.crc32(body.encode()) & 0xFFFFFFFF
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)


def verify(path: str) -> dict:
    """Integrity-check a checkpoint directory and return its (trusted)
    manifest; raises :class:`CheckpointCorrupt` naming the damaged file.
    Pre-integrity checkpoints (no footer) fail closed with reason
    ``no_integrity``."""
    mpath = os.path.join(path, "manifest.json")
    if not os.path.exists(mpath):
        raise CheckpointCorrupt(path, "manifest.json", "missing")
    try:
        with open(mpath) as f:
            manifest = json.load(f)
    except ValueError as e:
        raise CheckpointCorrupt(path, "manifest.json", "truncated",
                                str(e)) from e
    stored = manifest.pop("manifest_crc32", None)
    if stored is None or "integrity" not in manifest:
        raise CheckpointCorrupt(path, "manifest.json", "no_integrity",
                                "checkpoint predates integrity footers")
    body = json.dumps(manifest, indent=1, sort_keys=True)
    got = zlib.crc32(body.encode()) & 0xFFFFFFFF
    if got != stored:
        raise CheckpointCorrupt(path, "manifest.json", "checksum",
                                f"stored {stored:#010x} != {got:#010x}")
    for fname, foot in manifest["integrity"].items():
        fpath = os.path.join(path, fname)
        if not os.path.exists(fpath):
            raise CheckpointCorrupt(path, fname, "missing")
        crc, n = _crc(fpath)
        if n != foot["bytes"]:
            raise CheckpointCorrupt(
                path, fname, "truncated",
                f"{n} bytes on disk, footer says {foot['bytes']}")
        if crc != foot["crc32"]:
            raise CheckpointCorrupt(
                path, fname, "checksum",
                f"stored {foot['crc32']:#010x} != {crc:#010x}")
    return manifest


def restore(path: str, like_tree, shardings=None):
    """Restore into the structure of ``like_tree`` (with optional
    NamedShardings applied on device_put).  Verifies the integrity
    footers first — raises :class:`CheckpointCorrupt` instead of feeding
    damaged bytes to the deserializer."""
    manifest = verify(path)
    data = np.load(os.path.join(path, "weights.npz"))
    keyed, treedef = _flatten(like_tree)
    sh_keyed = None
    if shardings is not None:
        sh_keyed, _ = _flatten(shardings)
    leaves = []
    for key in keyed:
        arr = data[key]
        if manifest["tensors"][key]["dtype"] == "bfloat16":
            import jax.numpy as jnp
            arr = arr.view(jnp.bfloat16.dtype)
        if sh_keyed is not None:
            arr = jax.device_put(arr, sh_keyed[key])
        leaves.append(arr)
    flat, _ = jax.tree_util.tree_flatten_with_path(like_tree)
    order = ["/".join(str(getattr(k, 'key', getattr(k, 'idx', k)))
                      for k in p) for p, _ in flat]
    by_key = dict(zip(list(keyed.keys()), leaves))
    return jax.tree_util.tree_unflatten(treedef, [by_key[k] for k in order])


def latest_step(path: str) -> int:
    return verify(path)["step"]
