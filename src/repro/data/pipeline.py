"""Deterministic synthetic data pipeline + shape-only input specs.

Two consumers:
  * training/examples — :class:`SyntheticTokens` generates reproducible
    pseudo-text (a mixed-order Markov stream, so the loss actually
    decreases) and places batches with the correct NamedSharding;
  * the dry-run — :func:`input_specs` returns ``jax.ShapeDtypeStruct``
    stand-ins for every model input (no allocation).

Modality stubs (the one permitted carve-out): audio frame embeddings and
VLM patch embeddings arrive pre-computed with the right shapes.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.config import ModelConfig, ParallelConfig, ShapeSpec
from repro.parallel.sharding import act_spec, batch_spec


# --------------------------------------------------------------------------
# shape-only specs (dry-run)
# --------------------------------------------------------------------------

def _bs(par: ParallelConfig):
    return tuple(par.batch_axes) if par.batch_axes else None


def _seq(par: ParallelConfig):
    return par.seq_axes if len(par.seq_axes) > 1 else par.seq_axis


def input_specs(cfg: ModelConfig, shape: ShapeSpec, par: ParallelConfig,
                mesh):
    """ShapeDtypeStructs (+ shardings) for one (arch × input-shape) pair.

    Returns (batch_struct_pytree, shardings_pytree) for the step kind:
    train/prefill get token batches; decode gets a single token + the full
    sequence-sharded cache.
    """
    B, T = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    tok = P(_bs(par), _seq(par))
    rep2 = P(_bs(par), None)
    kind = shape.kind

    def sds(s, d):
        return jax.ShapeDtypeStruct(s, d)

    if kind in ("train", "prefill"):
        if cfg.arch_type == "vlm":
            n_img = cfg.n_image_tokens
            batch = {"tokens": sds((B, T - n_img), jnp.int32),
                     "labels": sds((B, T - n_img), jnp.int32),
                     "image_embeds": sds((B, n_img, cfg.d_model), dt)}
            shard = {"tokens": tok, "labels": tok,
                     "image_embeds": P(_bs(par), None, None)}
        elif cfg.arch_type == "audio":
            F = cfg.n_audio_frames
            batch = {"tokens": sds((B, T), jnp.int32),
                     "labels": sds((B, T), jnp.int32),
                     "frames": sds((B, F, cfg.d_model), dt)}
            shard = {"tokens": tok, "labels": tok,
                     "frames": P(_bs(par), None, None)}
        else:
            batch = {"tokens": sds((B, T), jnp.int32),
                     "labels": sds((B, T), jnp.int32)}
            shard = {"tokens": tok, "labels": tok}
            if kind == "train" and shape.docs > 1:
                # packed-sequence training: per-token document IDs
                batch["segment_ids"] = sds((B, T), jnp.int32)
                shard["segment_ids"] = tok
        return batch, shard

    # ---- decode: one token + per-request positions + cache of T context
    batch = {"token": sds((B, 1), jnp.int32),
             "pos": sds((B,), jnp.int32)}
    shard = {"token": rep2, "pos": P(_bs(par))}
    cache, cshard = cache_specs(cfg, shape, par)
    return {**batch, "cache": cache}, {**shard, "cache": cshard}


def cache_specs(cfg: ModelConfig, shape: ShapeSpec, par: ParallelConfig):
    """Decode cache ShapeDtypeStructs + PartitionSpecs per architecture."""
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    L_ = cfg.n_layers
    bs, seq = _bs(par), _seq(par)
    a = cfg.attn

    def sds(s, d=dt):
        return jax.ShapeDtypeStruct(s, d)

    if cfg.arch_type in ("dense", "vlm"):
        sh = P(None, bs, seq, None, None)
        return ({"k": sds((L_, B, S, a.n_kv_heads, a.head_dim)),
                 "v": sds((L_, B, S, a.n_kv_heads, a.head_dim))},
                {"k": sh, "v": sh})
    if cfg.arch_type == "moe":
        if a.is_mla:
            sh = P(None, bs, seq, None)
            d_lat = a.kv_lora_rank + a.qk_rope_head_dim
            return ({"ckv": sds((L_, B, S, d_lat))}, {"ckv": sh})
        sh = P(None, bs, seq, None, None)
        return ({"k": sds((L_, B, S, a.n_kv_heads, a.head_dim)),
                 "v": sds((L_, B, S, a.n_kv_heads, a.head_dim))},
                {"k": sh, "v": sh})
    if cfg.arch_type == "ssm":
        s = cfg.ssm
        nh = s.n_heads(cfg.d_model)
        hd = s.head_dim
        ch = s.d_inner(cfg.d_model) + 2 * s.d_state
        return ({"state": sds((L_, B, nh, s.d_state, hd), jnp.float32),
                 "conv": sds((L_, B, s.d_conv - 1, ch))},
                {"state": P(None, bs, None, None, None),
                 "conv": P(None, bs, None, None)})
    if cfg.arch_type == "hybrid":
        s = cfg.ssm
        nh = s.n_heads(cfg.d_model)
        ch = s.d_inner(cfg.d_model) + 2 * s.d_state
        G = cfg.n_layers // cfg.hybrid_period
        return ({"state": sds((cfg.n_layers, B, nh, s.d_state, s.head_dim),
                              jnp.float32),
                 "conv": sds((cfg.n_layers, B, s.d_conv - 1, ch)),
                 "shared_k": sds((G, B, S, a.n_kv_heads, a.head_dim)),
                 "shared_v": sds((G, B, S, a.n_kv_heads, a.head_dim))},
                {"state": P(None, bs, None, None, None),
                 "conv": P(None, bs, None, None),
                 "shared_k": P(None, bs, seq, None, None),
                 "shared_v": P(None, bs, seq, None, None)})
    if cfg.arch_type == "audio":
        F = cfg.n_audio_frames
        sh = P(None, bs, seq, None, None)
        rep = P(None, bs, None, None, None)
        return ({"k": sds((L_, B, S, a.n_kv_heads, a.head_dim)),
                 "v": sds((L_, B, S, a.n_kv_heads, a.head_dim)),
                 "ek": sds((L_, B, F, a.n_heads, a.head_dim)),
                 "ev": sds((L_, B, F, a.n_heads, a.head_dim))},
                {"k": sh, "v": sh, "ek": rep, "ev": rep})
    raise ValueError(cfg.arch_type)


# --------------------------------------------------------------------------
# synthetic training data
# --------------------------------------------------------------------------

@dataclasses.dataclass
class SyntheticTokens:
    """Reproducible pseudo-text stream: a hash-mixed Markov chain over the
    vocabulary. Learnable (loss drops quickly) and fully deterministic in
    (seed, step).

    When ``shape.docs > 1`` the stream is **packed**: each sequence holds
    ``docs`` independent documents (uneven static layout from
    ``mask.doc_boundaries``), the batch gains a ``segment_ids`` array, the
    Markov chain restarts at every boundary, and the label at each
    document's last token is ``-100`` (no cross-document next-token loss).
    """
    cfg: ModelConfig
    shape: ShapeSpec
    par: ParallelConfig
    mesh: object
    seed: int = 0

    def _tokens(self, step: int, B: int, T: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed * 1_000_003 + step)
        v = min(self.cfg.vocab, 1024)
        x = np.empty((B, T + 1), np.int64)
        x[:, 0] = rng.integers(0, v, B)
        mult = rng.integers(1, v)
        for t in range(T):
            noise = rng.integers(0, v, B)
            x[:, t + 1] = np.where(rng.random(B) < 0.8,
                                   (x[:, t] * 31 + 7) % v, noise)
        return x.astype(np.int32)

    def _packed(self, step: int, B: int, T: int):
        """(tokens, labels, segment_ids), all (B, T) int32."""
        from repro.core.mask import doc_boundaries, segments_from_boundaries
        bnd = doc_boundaries(T, self.shape.docs)
        seg = np.tile(segments_from_boundaries(T, bnd), (B, 1))
        tokens = np.empty((B, T), np.int32)
        labels = np.full((B, T), -100, np.int32)
        ends = list(bnd[1:]) + [T]
        for d, (b0, b1) in enumerate(zip(bnd, ends)):
            # independent stream per document (chain restarts at boundary)
            stream = self._tokens(step * 8191 + d, B, b1 - b0 - 1)
            tokens[:, b0:b1] = stream
            labels[:, b0:b1 - 1] = stream[:, 1:]     # last token: no target
        return tokens, labels, seg

    def batch(self, step: int):
        cfg, shape, par = self.cfg, self.shape, self.par
        B, T = shape.global_batch, shape.seq_len
        dt = jnp.dtype(cfg.dtype)
        tok_sh = NamedSharding(self.mesh, P(_bs(par), _seq(par)))
        if cfg.arch_type == "vlm":
            Tt = T - cfg.n_image_tokens
            x = self._tokens(step, B, Tt)
            rng = np.random.default_rng(step)
            img = rng.standard_normal(
                (B, cfg.n_image_tokens, cfg.d_model)).astype(np.float32)
            return {
                "tokens": jax.device_put(x[:, :-1], tok_sh),
                "labels": jax.device_put(x[:, 1:], tok_sh),
                "image_embeds": jax.device_put(
                    jnp.asarray(img, dt),
                    NamedSharding(self.mesh, P(_bs(par), None, None))),
            }
        if cfg.arch_type == "audio":
            x = self._tokens(step, B, T)
            rng = np.random.default_rng(step)
            fr = rng.standard_normal(
                (B, cfg.n_audio_frames, cfg.d_model)).astype(np.float32)
            return {
                "tokens": jax.device_put(x[:, :-1][:, :T], tok_sh),
                "labels": jax.device_put(x[:, 1:][:, :T], tok_sh),
                "frames": jax.device_put(
                    jnp.asarray(fr, dt),
                    NamedSharding(self.mesh, P(_bs(par), None, None))),
            }
        if self.shape.kind == "train" and self.shape.docs > 1:
            tokens, labels, seg = self._packed(step, B, T)
            return {"tokens": jax.device_put(tokens, tok_sh),
                    "labels": jax.device_put(labels, tok_sh),
                    "segment_ids": jax.device_put(seg, tok_sh)}
        x = self._tokens(step, B, T)
        return {"tokens": jax.device_put(x[:, :-1], tok_sh),
                "labels": jax.device_put(x[:, 1:], tok_sh)}
