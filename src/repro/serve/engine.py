"""Batched serving engine: prefill + greedy/temperature decode loop over a
sequence-sharded KV cache (distributed flash-decoding, core/dist_attention).

The engine keeps requests in fixed batch slots; ``generate`` runs prefill
once and then steps the decode jit in a Python loop (one token per step —
the decode step itself is the unit the dry-run lowers).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import ModelConfig
from repro.models.transformer import Runtime, build_model


@dataclasses.dataclass
class Engine:
    model: object
    params: dict

    def __post_init__(self):
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode)

    def generate(self, batch, n_tokens: int, rng=None, temperature=0.0):
        """batch: prefill inputs. Returns (tokens (B, n_tokens), last logits)."""
        logits, cache = self._prefill(self.params, batch)
        pos0 = batch["tokens"].shape[1]
        outs = []
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        for i in range(n_tokens):
            outs.append(tok)
            logits, cache = self._decode(
                self.params, cache,
                {"token": tok, "pos": jnp.int32(pos0 + i)})
            lf = logits[:, -1].astype(jnp.float32)
            if temperature > 0 and rng is not None:
                rng, k = jax.random.split(rng)
                tok = jax.random.categorical(k, lf / temperature)[:, None]
                tok = tok.astype(jnp.int32)
            else:
                tok = jnp.argmax(lf, axis=-1)[:, None].astype(jnp.int32)
        return jnp.concatenate(outs, axis=1), logits
