"""Serving engines.

:class:`Engine` — the continuous-batching step-loop engine over a paged KV
cache (serve/cache.py + serve/scheduler.py):

  * ``submit(prompt, max_new_tokens=…, temperature=…, seed=…,
    stop_tokens=…, deadline_steps=…) -> rid`` — enqueue a request
    (per-request sampling params, stop conditions, and an optional TTL on
    the scheduler clock).  Admission control may *shed* the request: the
    returned rid's request is then terminal ``REJECTED`` with a structured
    reason, never having touched the block pool;
  * ``step() -> {rid: [new tokens]}`` — one engine step: admit waiting
    requests into free batch slots (sharing prefix-cache blocks when
    their prompt prefix is already pooled), run each mid-prefill
    request's next *chunk* (``model.prefill_chunk`` writes straight into
    pool blocks — no dense intermediate), then ONE jitted decode step
    over the decode-ready slots — per-request ``(B,)`` positions,
    block-table gather attention, in-step sampling.  Chunked prefill
    (Sarathi-style, ``prefill_chunk_tokens``) bounds per-step latency
    and kills head-of-line blocking; ``prefill_chunk_tokens=0`` prefills
    whole prompts in one chunk;
  * ``stream(rid)`` / ``run()`` — drive ``step`` until a request / all
    requests reach a terminal state.

Robustness machinery (see serve/faults.py and the chaos suite):

  * **fault injection** — ``Engine(faults=FaultInjector(...))`` threads a
    deterministic, seeded fault schedule through the step loop: pool
    squeezes, NaN-poisoned logits, dropped/slow decode steps, corrupted
    pool blocks, preemption storms — all replayable byte-for-byte;
  * **NaN/Inf quarantine** — the decode step returns a per-row finite
    flag; a poisoned row is terminally ``FAILED`` (its exclusive blocks
    scrubbed then freed, shared refcounts intact) while the rest of the
    batch streams on — batch invariance means the survivors' tokens are
    unchanged;
  * **retry with capped backoff** — a dropped decode step advances no
    request; the engine backs off exponentially (capped) and retries,
    failing a request only after ``max_retries`` dropped attempts;
  * **forward-progress watchdog** — repeated preempt/readmit with no
    emitted tokens degrades admission to serial until pressure clears
    (scheduler-side; see Scheduler.record_progress);
  * **invariant auditing** — ``Engine(audit=True)`` re-checks allocator
    conservation, prefix-trie integrity, and block-table ownership after
    every step, raising a structured :class:`AuditFailure` naming the
    violated invariant.

Determinism: sampling keys are ``fold_in(PRNGKey(seed), position)`` — a
request's token stream depends only on its own (prompt, params), never on
what else is in the batch, which is the batch-invariance property the test
suite asserts.  Preemption (pool pressure) is recompute-style: the
victim's blocks are freed and its context is re-prefilled on re-admission,
so no emitted token is lost or re-sampled.  Faults perturb *scheduling*,
never a surviving request's numerics — fault-free requests stream
token-identical to a zero-fault run.

:class:`FixedSlotEngine` — the seed engine's fixed-slot ``generate`` API
(one prefill + a dense contiguous cache), upgraded to per-request
positions and a capacity-padded cache (the seed version silently
ring-overwrote the oldest prompt tokens once ``pos`` wrapped).  It is the
dense-cache oracle the paged engine is differentially tested against.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.cache import PagedKVCache
from repro.serve.faults import FAULT_OWNER, FaultInjector
from repro.serve.scheduler import (DECODE, PREFILL, Request, SamplingParams,
                                   Scheduler)
from repro.serve.speculative import (AdaptiveDepth, DraftSource, SpecConfig,
                                     make_draft)

# dense-cache keys whose seq axis (2) gets decode headroom padding.
# ssm/hybrid are absent: their prefill builds no decode cache (seed
# behavior), so neither engine can serve them.
_PAD_KEYS = ("k", "v", "ckv")


def _sample(logits, temps, keys):
    """Per-request sampling: greedy at temperature 0, else categorical
    under the request's own key. logits (B, V) f32; temps (B,); keys
    (B,) PRNG keys (uint32 (B, 2) key data)."""
    greedy = jnp.argmax(logits, axis=-1)
    scaled = logits / jnp.maximum(temps, 1e-6)[:, None]
    drawn = jax.vmap(lambda k, l: jax.random.categorical(k, l))(keys, scaled)
    return jnp.where(temps > 0, drawn, greedy).astype(jnp.int32)


class Engine:
    """Paged continuous-batching serving engine (see module docstring)."""

    def __init__(self, model, params, *, max_batch: int = 8,
                 block_size: Optional[int] = None, n_blocks: int = 128,
                 max_blocks_per_req: Optional[int] = None,
                 use_mesh_sharding: bool = True,
                 prefill_chunk_tokens: int = 32,
                 prefix_cache: bool = True,
                 max_queue: Optional[int] = None,
                 admit_watermark: float = 0.0,
                 max_retries: int = 8,
                 backoff_cap: int = 8,
                 watchdog_window: int = 8,
                 watchdog_threshold: int = 3,
                 audit: bool = False,
                 faults: Optional[FaultInjector] = None,
                 spec: Optional[SpecConfig] = None,
                 draft: Optional[DraftSource] = None):
        cfg = model.cfg
        if cfg.arch_type not in ("dense", "moe"):
            raise ValueError(
                f"the paged engine serves dense/moe decoders "
                f"(got {cfg.arch_type!r}); use FixedSlotEngine")
        if model.rt.par.batch_axes:
            # serving shapes are ragged (B=1 prefills, a fixed slot batch
            # for decode): run the model batch-replicated — the sequence
            # axis keeps its sharding
            from repro.models.transformer import build_model
            model = build_model(cfg, dataclasses.replace(
                model.rt, par=dataclasses.replace(model.rt.par,
                                                  batch_axes=())))
        self.model = model
        self.params = params
        self.cfg = cfg
        mesh = model.rt.mesh if use_mesh_sharding else None
        self.cache = PagedKVCache.create(
            cfg, block_size=block_size, n_blocks=n_blocks,
            max_reqs=max_batch, max_blocks_per_req=max_blocks_per_req,
            mesh=mesh, seq_axis=model.rt.par.seq_axis,
            prefix_cache=prefix_cache)
        # speculative decoding: the scheduler reserves the draft write
        # span (lookahead), the engine swaps its one-token decode for the
        # multi-token verify step (see serve/speculative.py)
        self.spec = spec
        self.draft = (draft if draft is not None
                      else make_draft(spec) if spec is not None else None)
        self._adepth = (AdaptiveDepth(spec)
                        if spec is not None and spec.adaptive else None)
        # {effective draft budget: spec-step row count} — how deep the
        # controller actually lets each request draft
        self.spec_depth_hist: Dict[int, int] = {}
        self.sched = Scheduler(self.cache, max_batch,
                               prefill_chunk_tokens=prefill_chunk_tokens,
                               max_queue=max_queue,
                               admit_watermark=admit_watermark,
                               watchdog_window=watchdog_window,
                               watchdog_threshold=watchdog_threshold,
                               lookahead=spec.depth if spec else 0)
        self.max_batch = max_batch
        self.prefill_chunk_tokens = int(prefill_chunk_tokens)
        self.requests: Dict[int, Request] = {}
        # chunk lengths are padded up to a bucket — the fixed chunk size,
        # or (whole-prompt mode) a multiple of the block size and the
        # sequence-shard count — so the number of prefill compilations is
        # bounded by the number of buckets, not by the number of distinct
        # prompt/requeue lengths.  Chunk logits are never computed (the
        # last context token enters via decode), and padded rows write to
        # the null block, so tail padding is free
        self._prefill_bucket = math.lcm(self.cache.block_size,
                                        max(self.model.rt.seq_size, 1))
        # the block pools are donated: every step's scatters update them
        # in place instead of copying the whole pool every token
        self._chunk_jit = jax.jit(self._chunk_step_fn, donate_argnums=(1,))
        self._decode_jit = jax.jit(self._decode_step_fn, donate_argnums=(1,))
        self._verify_jit = jax.jit(self._verify_step_fn, donate_argnums=(1,))
        self._base_keys: Dict[int, jax.Array] = {}
        # robustness state
        self.audit_mode = bool(audit)
        self.max_retries = int(max_retries)
        self.backoff_cap = int(backoff_cap)
        self.injector = faults
        self.step_idx = 0                 # fault-schedule timeline
        self._squeezes: List[Tuple[int, List[int]]] = []  # (release, ids)
        self._backoff_until = 0
        self._consec_drops = 0
        self.counters = dict(quarantined=0, retried=0, backoff_steps=0,
                             audit_passes=0, spec_proposed=0,
                             spec_accepted=0, spec_rejected=0,
                             spec_rollbacks=0)

    def install_faults(self, injector: Optional[FaultInjector]) -> None:
        """(Re-)attach a fault schedule with its timeline starting at the
        *next* step — lets benches warm up fault-free, then storm."""
        self.release_faults()
        self.injector = injector
        self.step_idx = 0

    # -------------------------------------------------------------- intake
    def submit(self, prompt, *, max_new_tokens: int = 16,
               temperature: float = 0.0, seed: int = 0,
               stop_tokens: Tuple[int, ...] = (),
               deadline_steps: Optional[int] = None) -> int:
        params = SamplingParams(max_new_tokens=max_new_tokens,
                                temperature=float(temperature),
                                seed=int(seed),
                                stop_tokens=tuple(int(t)
                                                  for t in stop_tokens))
        req = self.sched.submit(prompt, params,
                                deadline_steps=deadline_steps)
        self.requests[req.rid] = req
        if not req.done:                  # shed requests never run
            self._base_keys[req.rid] = jax.random.PRNGKey(params.seed)
        return req.rid

    def status(self, rid: int) -> Tuple[str, Optional[str]]:
        """(state, finish_reason) of a request — terminal states are
        definite: finished / rejected / expired / failed."""
        req = self.requests[rid]
        return req.state, req.finish_reason

    # ------------------------------------------------------------- prefill
    _NKV_BUCKET = 4          # table-width shape bucket for the chunk jit

    def _chunk_pad(self, n: int) -> int:
        """Padded chunk length: the fixed chunk size, or (whole-prompt
        mode) ``n`` rounded up to the prefill bucket."""
        if self.prefill_chunk_tokens:
            return self.prefill_chunk_tokens
        b = self._prefill_bucket
        return max(b, -(-n // b) * b)

    def _nkv_for(self, end: int) -> int:
        """Block-table width shipped to the chunk jit: covers the chunk's
        last written position, bucketed to bound recompilation.  Depends
        only on ``end`` (absolute context position), so a request's chunk
        shapes never depend on batch composition or cache hits."""
        need = -(-end // self.cache.block_size)
        return min(self.cache.max_blocks_per_req,
                   -(-need // self._NKV_BUCKET) * self._NKV_BUCKET)

    def _chunk_step_fn(self, params, pools, bt, start, n_valid, tokens):
        out = self.model.prefill_chunk(
            params, {**pools, "block_table": bt},
            {"tokens": tokens, "start": start, "n_valid": n_valid})
        return {k: out[k] for k in pools}

    def _run_chunk(self, req: Request, start: int, n: int) -> None:
        """Run one prefill chunk: context positions [start, start+n) of
        ``req`` are forwarded and their KV scattered into the slot's
        blocks (the scheduler already forked any shared block the chunk
        writes)."""
        end = start + n
        C = self._chunk_pad(n)
        toks = np.zeros((C,), np.int32)
        toks[:n] = req.context[start:end]
        nkv = self._nkv_for(end)
        bt = jnp.asarray(self.cache.table[req.slot:req.slot + 1, :nkv])
        self.cache.pools = self._chunk_jit(
            self.params, self.cache.pools, bt, jnp.int32(start),
            jnp.int32(n), jnp.asarray(toks)[None])

    def warm_prefill(self, max_ctx: int) -> int:
        """Pre-compile every (chunk length, table width) shape a trace of
        up to ``max_ctx`` context tokens can reach, by running dummy
        chunks against an all-null block table (writes land in the
        reserved null block; no allocator state is touched).  Returns the
        number of shapes compiled — bench warmup aid."""
        shapes = {(self._chunk_pad(min(e, self.prefill_chunk_tokens or e)),
                   self._nkv_for(e)) for e in range(1, max_ctx + 1)}
        for C, nkv in sorted(shapes):
            self.cache.pools = self._chunk_jit(
                self.params, self.cache.pools,
                jnp.zeros((1, nkv), jnp.int32), jnp.int32(0), jnp.int32(0),
                jnp.zeros((1, C), jnp.int32))
        return len(shapes)

    # -------------------------------------------------------------- decode
    def _decode_step_fn(self, params, pools, table, pos, tok, temps, keys,
                        poison):
        cache = {**pools, "block_table": table}
        logits, cache2 = self.model.decode(params, cache,
                                           {"token": tok, "pos": pos})
        # poison is all-zero in normal operation (adding 0 is exact in
        # f32, so the fault hook costs nothing numerically); the NaN
        # guard's per-row finite flag is computed AFTER it so injected
        # and organic non-finites take the same quarantine path
        lf = logits[:, -1].astype(jnp.float32) + poison[:, None]
        ok = jnp.all(jnp.isfinite(lf), axis=-1)
        nxt = _sample(lf, temps, keys)
        return nxt, ok, {k: cache2[k] for k in pools}

    def _verify_step_fn(self, params, pools, table, pos, toks, n_write,
                        temps, base_keys, poison):
        """Speculative verify: score T = 1 + depth rows per request in one
        forward (row 0 = pending token, rows 1.. = draft proposals) and
        sample the target token for EVERY row under its own per-position
        key — the same ``fold_in(seed_key, position)`` keys the vanilla
        decode step uses, so the accept/reject walk on the host commits
        exactly the tokens the non-speculative engine would have."""
        cache = {**pools, "block_table": table}
        logits, cache2 = self.model.verify(
            params, cache, {"tokens": toks, "pos": pos, "n_write": n_write})
        lf = logits.astype(jnp.float32) + poison[:, None, None]
        ok = jnp.all(jnp.isfinite(lf), axis=-1)             # (B, T)
        offs = jnp.arange(toks.shape[1], dtype=jnp.int32)
        keys = jax.vmap(lambda k, p: jax.vmap(
            lambda t: jax.random.fold_in(k, p + 1 + t))(offs))(
                base_keys, pos)                             # (B, T) keys
        tgt = jax.vmap(_sample, in_axes=(1, None, 1), out_axes=1)(
            lf, temps, keys)                                # (B, T)
        return tgt, ok, {k: cache2[k] for k in pools}

    def _key_for(self, req: Request, position: int) -> jax.Array:
        """Sampling key of the token that will sit at context
        ``position`` — a pure function of (seed, position), so streams are
        batch- and preemption-invariant."""
        return jax.random.fold_in(self._base_keys[req.rid], position)

    # ------------------------------------------------------- fault plumbing
    def _release_due_squeezes(self) -> None:
        keep = []
        for release_step, ids in self._squeezes:
            if self.step_idx >= release_step:
                self.cache.allocator.free(ids, FAULT_OWNER)
            else:
                keep.append((release_step, ids))
        self._squeezes = keep

    def release_faults(self) -> None:
        """Return every fault-held (squeezed) block to the pool — called
        automatically when ``run`` drains; manual steppers may call it
        before checking conservation-at-exit."""
        for _, ids in self._squeezes:
            self.cache.allocator.free(ids, FAULT_OWNER)
        self._squeezes = []

    def _apply_pre_plan_faults(self, events) -> Tuple[bool, list]:
        """Apply squeeze / storm / corrupt / slow faults (they act on
        scheduler/cache state the upcoming plan must see).  Returns
        (decode_dropped, nan_events)."""
        inj, drop, nan_events = self.injector, False, []
        for e in events:
            if e.kind == "squeeze":
                take = min(e.magnitude, self.cache.allocator.n_free)
                if take:
                    ids = self.cache.allocator.alloc(FAULT_OWNER, take)
                    self._squeezes.append((self.step_idx + e.duration, ids))
                    inj.fired(self.step_idx, e.kind,
                              f"held {take} blocks for {e.duration} steps")
                else:
                    inj.fired(self.step_idx, e.kind, "no free blocks")
            elif e.kind == "preempt_storm":
                victims = self.sched.force_preempt(e.magnitude)
                inj.fired(self.step_idx, e.kind,
                          f"preempted rids {[v.rid for v in victims]}")
            elif e.kind == "slow_step":
                self.sched.advance_clock(e.magnitude)
                inj.fired(self.step_idx, e.kind,
                          f"+{e.magnitude} clock ticks")
            elif e.kind == "corrupt_block":
                victim, block = self._corruption_victim(e)
                if victim is None:
                    inj.fired(self.step_idx, e.kind, "no candidate")
                else:
                    self.cache.corrupt_block(block)
                    inj.fired(self.step_idx, e.kind,
                              f"rid={victim.rid} block={block}")
            elif e.kind == "drop_step":
                drop = True
                inj.fired(self.step_idx, e.kind, "decode step dropped")
            elif e.kind == "nan_logits":
                nan_events.append(e)      # resolved once live rows known
        return drop, nan_events

    def _corruption_victim(self, event):
        """Deterministic corruption target: a decode-phase request's last
        block, exclusively owned (never a shared/prefix-indexed block —
        corruption must poison exactly one request)."""
        cands = []
        for slot in sorted(self.sched.running):
            r = self.sched.running[slot]
            if r.cached < r.n_prefill:
                continue
            n = int(self.cache.n_assigned[slot])
            b = int(self.cache.table[slot, n - 1]) if n else 0
            if b and self.cache.allocator.owners(b) == (r.rid,):
                cands.append((r, b))
        pick = self.injector.pick(event, cands)
        return pick if pick is not None else (None, None)

    def _quarantine(self, req: Request, reason: str) -> None:
        """Terminally fail one poisoned request: scrub its exclusively
        owned blocks (NaN content must not survive into the free list),
        release its refs (shared blocks stay intact under their other
        owners), and keep its clean partial stream."""
        self.cache.scrub_slot(req.slot, req.rid)
        self.sched.fail(req, reason)
        self.counters["quarantined"] += 1

    def _release_draft(self, rid: int) -> None:
        """Terminal-state hook: drop draft-model state AND the adaptive
        depth controller's acceptance history for this request (rids are
        never reused, but the dicts must not grow unboundedly)."""
        if self.draft is not None:
            self.draft.release(rid)
        if self._adepth is not None:
            self._adepth.release(rid)

    # ---------------------------------------------------------- the loop
    def _emit(self, req: Request, token: int, events) -> None:
        req.emitted.append(int(token))
        events.setdefault(req.rid, []).append(int(token))
        if token in req.params.stop_tokens:
            self.sched.finish(req, "stop")
        elif len(req.emitted) >= req.params.max_new_tokens:
            self.sched.finish(req, "length")

    def step(self) -> Dict[int, List[int]]:
        """One engine step. Returns {rid: [tokens emitted this step]}."""
        self._release_due_squeezes()
        drop, nan_events = False, []
        if self.injector is not None:
            drop, nan_events = self._apply_pre_plan_faults(
                self.injector.events_for(self.step_idx))

        plan = self.sched.plan()
        events: Dict[int, List[int]] = {}
        for r in plan.expired:
            self._release_draft(r.rid)

        for req, start, n in plan.chunks:
            if req.state != PREFILL:       # preempted after planning
                continue
            self._run_chunk(req, start, n)
            req.cached = start + n
            if req.cached >= req.n_prefill:
                req.state = DECODE
            # index the newly completed full blocks so later arrivals
            # (and this request's own re-admissions) can share them
            self.cache.register_prefix(req.slot, req.rid, req.context,
                                       req.cached)

        live = [r for r in plan.decode if r.state == DECODE]
        n_tokens = 0
        if nan_events and (not live or drop
                           or self.step_idx < self._backoff_until):
            for e in nan_events:
                self.injector.fired(self.step_idx, e.kind,
                                    "no live decode row")
            nan_events = []
        if live and (drop or self.step_idx < self._backoff_until):
            # transient step fault (or backoff window): no request
            # advances — next attempt re-samples the same positions, so
            # streams are unchanged.  Capped exponential backoff between
            # attempts; a request fails only after max_retries drops.
            if drop:
                self._consec_drops += 1
                self._backoff_until = self.step_idx + 1 + min(
                    2 ** (self._consec_drops - 1), self.backoff_cap)
                for r in live:
                    r.retries += 1
                    self.counters["retried"] += 1
                    if r.retries > self.max_retries:
                        self.sched.fail(r, "retries_exhausted")
                        self._release_draft(r.rid)
            else:
                self.counters["backoff_steps"] += 1
        elif live and self.spec is not None:
            n_tokens = self._spec_step(live, nan_events, events)
        elif live:
            B = self.max_batch
            tok = np.zeros((B, 1), np.int32)
            pos = np.zeros((B,), np.int32)
            temps = np.zeros((B,), np.float32)
            keys = [jax.random.PRNGKey(0)] * B
            poison = np.zeros((B,), np.float32)
            for e in nan_events:
                victim = self.injector.pick(
                    e, sorted(live, key=lambda r: r.rid))
                poison[victim.slot] = np.nan
                self.injector.fired(self.step_idx, e.kind,
                                    f"rid={victim.rid}")
            # non-live rows (idle slots AND mid-prefill requests) still flow
            # through the decode step with pos=0/tok=0 — and decode *writes*
            # KV at pos through the table.  Ship them an all-null table row
            # so those writes land in the reserved null block instead of a
            # mid-prefill request's (possibly cache-shared) block 0
            tbl = np.zeros_like(self.cache.table)
            for r in live:
                tok[r.slot, 0] = r.pending
                pos[r.slot] = r.cached
                temps[r.slot] = r.params.temperature
                keys[r.slot] = self._key_for(r, r.cached + 1)
                tbl[r.slot] = self.cache.table[r.slot]
            nxt, ok, pools = self._decode_jit(
                self.params, self.cache.pools, jnp.asarray(tbl),
                jnp.asarray(pos), jnp.asarray(tok), jnp.asarray(temps),
                jnp.stack(keys), jnp.asarray(poison))
            self.cache.pools = pools
            nxt, ok = np.asarray(nxt), np.asarray(ok)
            self._consec_drops = 0
            for r in live:
                if not ok[r.slot]:
                    # NaN/Inf logits: quarantine exactly this row; the
                    # poisoned sample is discarded, the clean prefix of
                    # its stream is kept, and everyone else streams on
                    self._quarantine(r, "nan_logits")
                    continue
                r.retries = 0
                r.cached += 1
                self._emit(r, int(nxt[r.slot]), events)
                n_tokens += 1

        self.sched.record_progress(n_tokens)
        self.step_idx += 1
        if self.audit_mode:
            self.cache.audit(self.sched.running)
            self.counters["audit_passes"] += 1
        return events

    def _spec_step(self, live, nan_events, events) -> int:
        """One speculative decode step over the live rows: draft, verify,
        accept/reject walk.  Shapes are fixed at T = 1 + depth (short
        proposal lists are padded; ``n_write`` null-redirects the padding
        rows' KV writes and the walk never reads their samples), so the
        verify jit compiles once."""
        B, T = self.max_batch, 1 + self.spec.depth
        toks = np.zeros((B, T), np.int32)
        pos = np.zeros((B,), np.int32)
        n_write = np.zeros((B,), np.int32)
        temps = np.zeros((B,), np.float32)
        bkeys = [jax.random.PRNGKey(0)] * B
        poison = np.zeros((B,), np.float32)
        for e in nan_events:
            victim = self.injector.pick(
                e, sorted(live, key=lambda r: r.rid))
            poison[victim.slot] = np.nan
            self.injector.fired(self.step_idx, e.kind, f"rid={victim.rid}")
        tbl = np.zeros_like(self.cache.table)
        props: Dict[int, List[int]] = {}
        for r in live:
            k = self.sched.spec_budget(r)
            if self._adepth is not None:
                k = min(k, self._adepth.depth_for(r.rid))
            self.spec_depth_hist[max(k, 0)] = \
                self.spec_depth_hist.get(max(k, 0), 0) + 1
            pr = [int(t) for t in self.draft.propose(r, k)][:max(k, 0)]
            props[r.rid] = pr
            toks[r.slot, 0] = r.pending
            toks[r.slot, 1:1 + len(pr)] = pr
            pos[r.slot] = r.cached
            n_write[r.slot] = 1 + len(pr)
            temps[r.slot] = r.params.temperature
            bkeys[r.slot] = self._base_keys[r.rid]
            tbl[r.slot] = self.cache.table[r.slot]
        tgt, ok, pools = self._verify_jit(
            self.params, self.cache.pools, jnp.asarray(tbl),
            jnp.asarray(pos), jnp.asarray(toks), jnp.asarray(n_write),
            jnp.asarray(temps), jnp.stack(bkeys), jnp.asarray(poison))
        self.cache.pools = pools
        tgt, ok = np.asarray(tgt), np.asarray(ok)
        self._consec_drops = 0
        n_tokens = 0
        for r in live:
            pr = props[r.rid]
            if not ok[r.slot, :1 + len(pr)].all():
                # NaN/Inf anywhere in the rows this walk could consume:
                # quarantine the whole row set, as vanilla decode would
                self._quarantine(r, "nan_logits")
                self._release_draft(r.rid)
                continue
            r.retries = 0
            n_acc = 0
            for i in range(len(pr) + 1):
                # the target's own sample for position cached + 1 + i —
                # identical to what i sequential decode steps would emit
                t_i = int(tgt[r.slot, i])
                r.cached += 1
                self._emit(r, t_i, events)
                n_tokens += 1
                acc = i < len(pr) and pr[i] == t_i
                if acc:
                    n_acc += 1
                if r.state != DECODE or not acc:
                    break
            # rejected rows need no undo: cached simply didn't advance
            # over them, their KV sits masked above the valid length in
            # blocks this request exclusively owns
            self.counters["spec_proposed"] += len(pr)
            self.counters["spec_accepted"] += n_acc
            self.counters["spec_rejected"] += len(pr) - n_acc
            if len(pr) > n_acc:
                self.counters["spec_rollbacks"] += 1
            if self._adepth is not None:
                self._adepth.observe(r.rid, n_acc, len(pr))
            if r.done:
                self._release_draft(r.rid)
            else:
                self.draft.observe(r, n_acc, len(pr))
        return n_tokens

    def run(self, max_steps: int = 100_000) -> Dict[int, np.ndarray]:
        """Drive ``step`` until every submitted request reaches a terminal
        state; returns {rid: emitted token array} (partial streams for
        expired/failed requests, empty for rejected)."""
        for _ in range(max_steps):
            if self.sched.idle:
                break
            self.step()
        else:
            raise RuntimeError("engine did not drain (scheduling bug?)")
        self.release_faults()
        return {rid: np.asarray(r.emitted, np.int32)
                for rid, r in self.requests.items()}

    def stream(self, rid: int):
        """Yield ``rid``'s tokens as they are produced (drives step())."""
        req = self.requests[rid]
        emitted = 0
        while True:
            while emitted < len(req.emitted):
                yield req.emitted[emitted]
                emitted += 1
            if req.done:
                break
            self.step()

    # ------------------------------------------------------ legacy facade
    def generate(self, batch, n_tokens: int, rng=None, temperature=0.0):
        """Fixed-slot-compatible convenience: submit every row of
        ``batch["tokens"]``, drain, return (B, n_tokens) tokens."""
        toks = np.asarray(batch["tokens"])
        seeds = []
        for b in range(toks.shape[0]):
            if rng is None:
                seeds.append(b)
            else:
                seeds.append(int(np.asarray(
                    jax.random.fold_in(rng, b))[-1]) & 0x7FFFFFFF)
        rids = [self.submit(toks[b], max_new_tokens=n_tokens,
                            temperature=float(temperature), seed=seeds[b])
                for b in range(toks.shape[0])]
        out = self.run()
        return jnp.asarray(np.stack([out[r][:n_tokens] for r in rids]))

    # ---------------------------------------------------------- telemetry
    def stats(self) -> dict:
        """One flat counter dict: scheduler occupancy, pool/cache
        counters, and the robustness counters (shed, retried, quarantined,
        expired, watchdog trips, audit passes, per-kind injected
        faults)."""
        sc = self.sched.counters
        out = {
            "n_preemptions": self.sched.n_preemptions,
            "steps": self.sched.step_count,
            "running": len(self.sched.running),
            "waiting": len(self.sched.waiting),
            "free_blocks": self.cache.allocator.n_free,
            "usable_blocks": self.cache.allocator.n_usable,
            "cache_blocks": self.cache.n_cache_blocks,
            **self.cache.counters,
            "shed": sc["shed"],
            "expired": sc["expired"],
            "failed": sc["failed"],
            "storm_preempts": sc["storm_preempts"],
            "watchdog_trips": sc["watchdog_trips"],
            "serial_admission": self.sched.serial_admission,
            **self.counters,
            "spec_acceptance": (self.counters["spec_accepted"]
                                / max(self.counters["spec_proposed"], 1)),
            "spec_depth_hist": dict(sorted(self.spec_depth_hist.items())),
        }
        if self.injector is not None:
            out["faults"] = dict(self.injector.counts)
        if self.cache.prefix is not None:
            out["prefix_cache"] = dict(self.cache.prefix.stats)
        return out


# ==========================================================================
# Legacy fixed-slot engine (dense contiguous cache) — the paged engine's
# differential oracle
# ==========================================================================

@dataclasses.dataclass
class FixedSlotEngine:
    """Batched fixed-slot serving: one prefill + a dense contiguous KV
    cache, stepped one token at a time.  The cache is padded with
    ``n_tokens`` of headroom and decode gets per-request ``(B,)``
    positions, fixing the seed behavior (ring-buffer wrap silently
    overwrote the oldest prompt tokens, and the shared scalar position
    mis-masked mixed-length batches)."""
    model: object
    params: dict

    def __post_init__(self):
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode)

    def generate(self, batch, n_tokens: int, rng=None, temperature=0.0):
        """batch: prefill inputs. Returns (tokens (B, n_tokens), last
        logits)."""
        logits, cache = self._prefill(self.params, batch)
        if not cache or "state" in cache:
            raise ValueError("FixedSlotEngine serves attention-cache "
                             "decoders only")
        S0 = next(cache[k].shape[2] for k in _PAD_KEYS if k in cache)
        # headroom so the ring buffer never wraps, rounded up so the padded
        # seq length stays divisible by the sequence shards
        n_sh = 1
        for ax in self.model.rt.par.seq_axes:
            n_sh *= dict(zip(self.model.rt.mesh.axis_names,
                             self.model.rt.mesh.devices.shape))[ax]
        pad = -(-(S0 + n_tokens) // n_sh) * n_sh - S0
        cache = {k: (jnp.pad(v, [(0, 0), (0, 0), (0, pad)] +
                             [(0, 0)] * (v.ndim - 3))
                     if k in _PAD_KEYS else v)
                 for k, v in cache.items()}
        B = batch["tokens"].shape[0]
        outs = []
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        for i in range(n_tokens):
            outs.append(tok)
            pos = jnp.full((B,), S0 + i, jnp.int32)
            logits, cache = self._decode(
                self.params, cache, {"token": tok, "pos": pos})
            lf = logits[:, -1].astype(jnp.float32)
            if temperature > 0 and rng is not None:
                rng, k = jax.random.split(rng)
                tok = jax.random.categorical(k, lf / temperature)[:, None]
                tok = tok.astype(jnp.int32)
            else:
                tok = jnp.argmax(lf, axis=-1)[:, None].astype(jnp.int32)
        return jnp.concatenate(outs, axis=1), logits
