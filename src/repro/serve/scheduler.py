"""Continuous-batching scheduler: admission queue, per-request state, and
block-pool-pressure preemption over a :class:`repro.serve.cache.PagedKVCache`.

Per engine step the scheduler produces a :class:`StepPlan`:

  1. **window reclamation** — when the model has a sliding window, every
     running request drops its refs on blocks wholly below the window of
     its next write position (freed storage instead of masked storage).
  2. **decode growth** — every running request about to write a token at a
     block boundary gets one more block; when the pool is exhausted the
     *youngest* running request (highest admission sequence) is preempted:
     its block refs are dropped (shared prefix blocks survive under their
     other owners) and it requeues at the *front* of the admission queue
     (recompute-style preemption — on re-admission its full context
     ``prompt ++ emitted[:-1]`` is re-prefilled, usually mostly from the
     prefix cache, and its pending last token re-enters decode, so no
     output token is ever lost or re-sampled).
  3. **admission** — FIFO: while a batch slot is free and the pool can hold
     the head request's prefill blocks, it is admitted; cached prefix
     blocks are *shared* instead of allocated (``Request.cached`` starts
     at the hit length).  Head-of-line blocking keeps admission
     deterministic and starvation-free.
  4. **chunk planning** — each mid-prefill request contributes one prefill
     chunk of at most ``prefill_chunk_tokens`` tokens, *aligned to
     absolute context positions* (chunk boundaries are multiples of the
     chunk size), so a request's chunk layout — and hence its numerics —
     never depends on what else is in the batch or on how much of its
     prefix was cached.  Copy-on-write forks for every block the step will
     write run here, under the same preempt-on-exhaustion loop as decode
     growth.

Everything is host-side and deterministic in the submit/step sequence —
the property the batch-invariance suite (tests/test_serving_engine.py)
checks against solo runs.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.serve.cache import PagedKVCache, PoolExhausted


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling/stop configuration."""
    max_new_tokens: int = 16
    temperature: float = 0.0
    seed: int = 0                      # per-request PRNG stream
    stop_tokens: Tuple[int, ...] = ()


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (T,) int32
    params: SamplingParams
    state: str = "waiting"             # waiting | running | finished
    slot: int = -1
    seq: int = -1                      # admission sequence (preempt victim
    #                                    order; re-assigned on re-admission)
    emitted: List[int] = dataclasses.field(default_factory=list)
    cached: int = 0                    # tokens with KV in the pool
    finish_reason: Optional[str] = None
    n_preemptions: int = 0
    n_hit: int = 0                     # prefix-cache tokens at last admission
    submit_step: int = -1
    finish_step: int = -1

    @property
    def pending(self) -> int:
        """The context token whose KV is not yet cached — the next decode
        step's input.  For a fresh request this is the *last prompt
        token*: prefill stops one short, so prefill logits are never
        consumed and prefill lengths can be freely bucket-padded (the
        first sampled token comes out of the first decode step)."""
        return int(self.emitted[-1] if self.emitted else self.prompt[-1])

    @property
    def context(self) -> np.ndarray:
        """prompt ++ emitted (the full token sequence so far)."""
        return np.concatenate([self.prompt,
                               np.asarray(self.emitted, np.int32)])

    @property
    def n_prefill(self) -> int:
        """Prefill length: everything but the pending token."""
        return len(self.prompt) + len(self.emitted) - 1

    @property
    def prefill_tokens(self) -> np.ndarray:
        """What (re-)admission must prefill: everything but the pending
        token (whose KV the next decode step writes). May be empty
        (single-token prompt)."""
        return self.context[:-1]


@dataclasses.dataclass
class StepPlan:
    admitted: List[Request]
    decode: List[Request]              # requests decode-ready this step
    preempted: List[Request]
    chunks: List[Tuple[Request, int, int]] = dataclasses.field(
        default_factory=list)          # (request, start, n_tokens)


class Scheduler:
    def __init__(self, cache: PagedKVCache, max_batch: Optional[int] = None,
                 *, prefill_chunk_tokens: int = 0):
        self.cache = cache
        self.max_batch = max_batch or cache.max_reqs
        if self.max_batch > cache.max_reqs:
            raise ValueError("max_batch exceeds the cache's table rows")
        if prefill_chunk_tokens < 0:
            raise ValueError("prefill_chunk_tokens must be >= 0 "
                             "(0 = whole-prompt prefill)")
        self.prefill_chunk_tokens = int(prefill_chunk_tokens)
        self.window = int((cache.cfg.attn.window or 0)
                          if cache.cfg.attn else 0)
        self.waiting: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}      # slot -> request
        self._next_rid = 0
        self._adm_seq = 0
        self.n_preemptions = 0
        self.step_count = 0

    # ------------------------------------------------------------- intake
    def submit(self, prompt, params: SamplingParams) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        total = prompt.size + params.max_new_tokens
        if not self.cache.fits(total):
            raise ValueError(
                f"request of {total} tokens can never fit: needs "
                f"{self.cache.blocks_for(total)} blocks, pool has "
                f"{self.cache.allocator.n_usable} usable "
                f"(max {self.cache.max_blocks_per_req}/req)")
        req = Request(rid=self._next_rid, prompt=prompt, params=params,
                      submit_step=self.step_count)
        self._next_rid += 1
        self.waiting.append(req)
        return req

    # ------------------------------------------------------------ helpers
    def _free_slot(self) -> Optional[int]:
        for s in range(self.max_batch):
            if s not in self.running:
                return s
        return None

    def _preempt_youngest(self) -> Optional[Request]:
        if not self.running:
            return None
        victim = max(self.running.values(), key=lambda r: r.seq)
        self.cache.release(victim.slot, victim.rid)
        del self.running[victim.slot]
        victim.state = "waiting"
        victim.slot = -1
        victim.cached = 0
        victim.n_preemptions += 1
        self.n_preemptions += 1
        self.waiting.appendleft(victim)
        return victim

    def _with_preempt(self, req: Request, op, preempted) -> bool:
        """Run a pool-consuming cache op, preempting the youngest request
        on exhaustion until it succeeds; returns False when ``req`` itself
        was the last victim (it left the running set)."""
        while True:
            try:
                op()
                return True
            except PoolExhausted:
                victim = self._preempt_youngest()
                if victim is not None:
                    preempted.append(victim)
                if victim is None or victim is req:
                    return False

    def finish(self, req: Request, reason: str) -> None:
        self.cache.release(req.slot, req.rid)
        del self.running[req.slot]
        req.state = "finished"
        req.finish_reason = reason
        req.finish_step = self.step_count
        req.slot = -1

    def _chunk_end(self, req: Request) -> int:
        """End position of the request's next prefill chunk: aligned to
        absolute multiples of the chunk size (so chunk boundaries — and
        the numerics they shape — are independent of cache hits and batch
        composition), capped at the prefill length."""
        C = self.prefill_chunk_tokens
        if not C:
            return req.n_prefill
        return min(req.n_prefill, (req.cached // C + 1) * C)

    # --------------------------------------------------------------- plan
    def plan(self) -> StepPlan:
        """One scheduling round: reclaim, grow/preempt, admit, plan
        chunks + copy-on-write forks.  The caller (engine) runs the
        ``chunks`` (prefill), then one decode step over ``decode``."""
        self.step_count += 1
        preempted: List[Request] = []

        # 1. sliding-window reclamation — blocks wholly below the window
        # of the next write position are freed, not merely masked
        if self.window:
            for slot in sorted(self.running):
                req = self.running[slot]
                self.cache.reclaim_window(slot, req.rid, req.cached,
                                          self.window)

        # 2. decode growth — ascending slot order is the deterministic tie
        # break; a victim drops out of this step's plan entirely.
        for slot in sorted(self.running):
            req = self.running.get(slot)
            if req is None:
                continue                         # preempted below this step
            if req.cached >= req.n_prefill \
                    and self.cache.needs_block(slot, req.cached):
                self._with_preempt(
                    req, lambda: self.cache.extend(slot, req.rid),
                    preempted)

        # 3. admission (FIFO, head-of-line blocking); prefix-cache hits
        # start the request part-prefilled
        admitted: List[Request] = []
        while self.waiting:
            head = self.waiting[0]
            slot = self._free_slot()
            if slot is None:
                break
            toks = head.prefill_tokens
            try:
                # +1: the first decode write lands at position n_prefill,
                # so the slot must own the block covering it up front
                n_hit = self.cache.assign(slot, head.rid, len(toks) + 1,
                                          tokens=toks)
            except PoolExhausted:
                break
            self.waiting.popleft()
            head.state = "running"
            head.slot = slot
            head.seq = self._adm_seq
            self._adm_seq += 1
            head.cached = n_hit                  # hit KV is already pooled
            head.n_hit = n_hit
            self.running[slot] = head
            admitted.append(head)

        # 4. chunk planning + copy-on-write forks for this step's writes
        chunks: List[Tuple[Request, int, int]] = []
        decode: List[Request] = []
        for slot in sorted(self.running):
            req = self.running.get(slot)
            if req is None:
                continue
            n_pref = req.n_prefill
            if req.cached < n_pref:              # mid-prefill: one chunk
                end = self._chunk_end(req)
                w1 = end + 1 if end == n_pref else end
                if not self._with_preempt(
                        req, lambda: self.cache.ensure_writable(
                            slot, req.rid, req.cached, w1), preempted):
                    continue
                chunks.append((req, req.cached, end - req.cached))
                if end == n_pref:                # finishes prefill: decode
                    decode.append(req)           # in the same step
            else:                                # decode-phase
                if self._with_preempt(
                        req, lambda: self.cache.ensure_writable(
                            slot, req.rid, req.cached, req.cached + 1),
                        preempted):
                    decode.append(req)

        return StepPlan(admitted=admitted, decode=decode,
                        preempted=[p for p in preempted if p is not None],
                        chunks=chunks)

    @property
    def idle(self) -> bool:
        return not self.waiting and not self.running
