"""Continuous-batching scheduler: admission queue, per-request lifecycle
state machine, and block-pool-pressure preemption over a
:class:`repro.serve.cache.PagedKVCache`.

**Request lifecycle** — every request reaches exactly one terminal state::

    QUEUED ──admit──> PREFILL ──chunks done──> DECODE ──stop/length──> FINISHED
      │  ▲              │   │                    │   │
      │  └── preempt ───┴───│──── preempt ───────┘   ├──> EXPIRED  (deadline)
      │                     │                        └──> FAILED   (quarantine,
      ├──> REJECTED (shed at submit)                            retries exhausted)
      └──> EXPIRED  (deadline while queued)          PREFILL can also EXPIRE

Terminal states are *structured statuses*, not exceptions: ``submit`` on a
full queue / exhausted headroom / never-fitting request returns a
``REJECTED`` request (``finish_reason`` says why) without touching the
block pool, and deadline expiry releases a running request's blocks while
keeping its partial ``emitted`` stream.

Per engine step the scheduler produces a :class:`StepPlan`:

  0. **deadline expiry** — requests (queued or running) whose TTL elapsed
     on the scheduler *clock* (one tick per step, plus slow-step fault
     penalties) terminate ``EXPIRED``; running victims release their
     blocks but keep their partial stream.
  1. **window reclamation** — when the model has a sliding window, every
     running request drops its refs on blocks wholly below the window of
     its next write position (freed storage instead of masked storage).
  2. **decode growth** — every running request about to write a token at a
     block boundary gets one more block; when the pool is exhausted the
     *youngest* running request (highest admission sequence) is preempted:
     its block refs are dropped (shared prefix blocks survive under their
     other owners) and it requeues at the *front* of the admission queue
     (recompute-style preemption — on re-admission its full context
     ``prompt ++ emitted[:-1]`` is re-prefilled, usually mostly from the
     prefix cache, and its pending last token re-enters decode, so no
     output token is ever lost or re-sampled).
  3. **admission** — FIFO: while a batch slot is free and the pool can hold
     the head request's prefill blocks, it is admitted; cached prefix
     blocks are *shared* instead of allocated (``Request.cached`` starts
     at the hit length).  Head-of-line blocking keeps admission
     deterministic and starvation-free.  When the **forward-progress
     watchdog** has tripped (a window of repeated preempt/readmit with no
     emitted tokens — preemption livelock), admission degrades to *serial*
     (at most one running request) until a full window passes with
     progress and no preemptions.
  4. **chunk planning** — each mid-prefill request contributes one prefill
     chunk of at most ``prefill_chunk_tokens`` tokens, *aligned to
     absolute context positions* (chunk boundaries are multiples of the
     chunk size), so a request's chunk layout — and hence its numerics —
     never depends on what else is in the batch or on how much of its
     prefix was cached.  Copy-on-write forks for every block the step will
     write run here, under the same preempt-on-exhaustion loop as decode
     growth.

Everything is host-side and deterministic in the submit/step sequence —
the property the batch-invariance suite (tests/test_serving_engine.py)
checks against solo runs, and the chaos suite (tests/test_chaos.py)
checks under seeded fault schedules.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.serve.cache import PagedKVCache, PoolExhausted

# ----------------------------------------------------------- request states
QUEUED = "queued"          # in the admission queue
PREFILL = "prefill"        # admitted, context KV still being written
DECODE = "decode"          # fully prefilled, emitting tokens
FINISHED = "finished"      # terminal: stop token / length budget
REJECTED = "rejected"      # terminal: shed at submit (never touched pool)
EXPIRED = "expired"        # terminal: deadline elapsed (partial stream kept)
FAILED = "failed"          # terminal: quarantined / retries exhausted

RUNNING_STATES = (PREFILL, DECODE)
TERMINAL_STATES = (FINISHED, REJECTED, EXPIRED, FAILED)


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling/stop configuration."""
    max_new_tokens: int = 16
    temperature: float = 0.0
    seed: int = 0                      # per-request PRNG stream
    stop_tokens: Tuple[int, ...] = ()


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (T,) int32
    params: SamplingParams
    state: str = QUEUED
    slot: int = -1
    seq: int = -1                      # admission sequence (preempt victim
    #                                    order; re-assigned on re-admission)
    emitted: List[int] = dataclasses.field(default_factory=list)
    cached: int = 0                    # tokens with KV in the pool
    finish_reason: Optional[str] = None
    deadline: Optional[int] = None     # absolute scheduler-clock tick
    retries: int = 0                   # transient-step-fault retries so far
    n_preemptions: int = 0
    n_hit: int = 0                     # prefix-cache tokens at last admission
    submit_step: int = -1
    finish_step: int = -1

    @property
    def done(self) -> bool:
        return self.state in TERMINAL_STATES

    @property
    def pending(self) -> int:
        """The context token whose KV is not yet cached — the next decode
        step's input.  For a fresh request this is the *last prompt
        token*: prefill stops one short, so prefill logits are never
        consumed and prefill lengths can be freely bucket-padded (the
        first sampled token comes out of the first decode step)."""
        return int(self.emitted[-1] if self.emitted else self.prompt[-1])

    @property
    def context(self) -> np.ndarray:
        """prompt ++ emitted (the full token sequence so far)."""
        return np.concatenate([self.prompt,
                               np.asarray(self.emitted, np.int32)])

    @property
    def n_prefill(self) -> int:
        """Prefill length: everything but the pending token."""
        return len(self.prompt) + len(self.emitted) - 1

    @property
    def prefill_tokens(self) -> np.ndarray:
        """What (re-)admission must prefill: everything but the pending
        token (whose KV the next decode step writes). May be empty
        (single-token prompt)."""
        return self.context[:-1]


@dataclasses.dataclass
class StepPlan:
    admitted: List[Request]
    decode: List[Request]              # requests decode-ready this step
    preempted: List[Request]
    chunks: List[Tuple[Request, int, int]] = dataclasses.field(
        default_factory=list)          # (request, start, n_tokens)
    expired: List[Request] = dataclasses.field(default_factory=list)


class Scheduler:
    def __init__(self, cache: PagedKVCache, max_batch: Optional[int] = None,
                 *, prefill_chunk_tokens: int = 0,
                 max_queue: Optional[int] = None,
                 admit_watermark: float = 0.0,
                 watchdog_window: int = 8,
                 watchdog_threshold: int = 3,
                 lookahead: int = 0):
        self.cache = cache
        self.max_batch = max_batch or cache.max_reqs
        if self.max_batch > cache.max_reqs:
            raise ValueError("max_batch exceeds the cache's table rows")
        if prefill_chunk_tokens < 0:
            raise ValueError("prefill_chunk_tokens must be >= 0 "
                             "(0 = whole-prompt prefill)")
        if not 0.0 <= admit_watermark <= 1.0:
            raise ValueError("admit_watermark is a free-block fraction "
                             "in [0, 1]")
        if lookahead < 0:
            raise ValueError("lookahead must be >= 0")
        self.prefill_chunk_tokens = int(prefill_chunk_tokens)
        # speculative-decoding write span: each decode step may write up
        # to ``lookahead`` draft rows beyond the pending token, so block
        # growth / COW forks / admission reservations all cover them
        self.lookahead = int(lookahead)
        self.window = int((cache.cfg.attn.window or 0)
                          if cache.cfg.attn else 0)
        # admission control: bounded queue + block-headroom watermark —
        # both shed with a structured REJECTED status instead of blocking
        self.max_queue = max_queue
        self.admit_watermark = float(admit_watermark)
        self.waiting: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}      # slot -> request
        self._next_rid = 0
        self._adm_seq = 0
        self.n_preemptions = 0
        self.step_count = 0
        # virtual clock: one tick per plan(); slow-step faults add extra
        # ticks, so deadlines are deterministic AND fault-sensitive
        self.clock = 0
        # forward-progress watchdog over a sliding window of recent steps
        self.watchdog_window = int(watchdog_window)
        self.watchdog_threshold = int(watchdog_threshold)
        self.serial_admission = False
        self._history: Deque[Tuple[int, int]] = deque(
            maxlen=self.watchdog_window)           # (preempts, tokens)
        self._step_preempts = 0
        self.counters = dict(shed=0, expired=0, failed=0, watchdog_trips=0,
                             storm_preempts=0)

    # ------------------------------------------------------------- intake
    def _headroom(self) -> float:
        """Fraction of usable blocks that admission could still claim —
        free blocks plus cache-pinned blocks (LRU eviction reclaims those
        under pressure)."""
        a = self.cache.allocator
        return (a.n_free + self.cache.n_cache_blocks) / a.n_usable

    def _reject(self, req: Request, reason: str) -> Request:
        req.state = REJECTED
        req.finish_reason = reason
        req.finish_step = self.step_count
        self.counters["shed"] += 1
        return req

    def submit(self, prompt, params: SamplingParams,
               deadline_steps: Optional[int] = None) -> Request:
        """Enqueue a request — or shed it: the returned request is
        ``REJECTED`` (with a reason, having never touched the block pool)
        when it can never fit, the queue is at ``max_queue`` depth, or
        free-block headroom is below ``admit_watermark``."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        req = Request(rid=self._next_rid, prompt=prompt, params=params,
                      submit_step=self.step_count)
        self._next_rid += 1
        if deadline_steps is not None:
            if deadline_steps <= 0:
                raise ValueError("deadline_steps must be positive")
            req.deadline = self.clock + int(deadline_steps)
        total = prompt.size + params.max_new_tokens
        if not self.cache.fits(total):
            return self._reject(req, "never_fits")
        if self.max_queue is not None and len(self.waiting) >= self.max_queue:
            return self._reject(req, "queue_full")
        if self.admit_watermark and self._headroom() < self.admit_watermark:
            return self._reject(req, "no_headroom")
        self.waiting.append(req)
        return req

    # ------------------------------------------------------------ helpers
    def _free_slot(self) -> Optional[int]:
        for s in range(self.max_batch):
            if s not in self.running:
                return s
        return None

    def _preempt_youngest(self) -> Optional[Request]:
        if not self.running:
            return None
        victim = max(self.running.values(), key=lambda r: r.seq)
        self._preempt(victim)
        return victim

    def _preempt(self, victim: Request) -> None:
        self.cache.release(victim.slot, victim.rid)
        del self.running[victim.slot]
        victim.state = QUEUED
        victim.slot = -1
        victim.cached = 0
        victim.n_preemptions += 1
        self.n_preemptions += 1
        self._step_preempts += 1
        self.waiting.appendleft(victim)

    def force_preempt(self, n: int) -> List[Request]:
        """Fault hook (preempt storm): preempt the ``n`` youngest running
        requests regardless of pool pressure."""
        victims = []
        for _ in range(n):
            v = self._preempt_youngest()
            if v is None:
                break
            victims.append(v)
        self.counters["storm_preempts"] += len(victims)
        return victims

    def _with_preempt(self, req: Request, op, preempted) -> bool:
        """Run a pool-consuming cache op, preempting the youngest request
        on exhaustion until it succeeds; returns False when ``req`` itself
        was the last victim (it left the running set)."""
        while True:
            try:
                op()
                return True
            except PoolExhausted:
                victim = self._preempt_youngest()
                if victim is not None:
                    preempted.append(victim)
                if victim is None or victim is req:
                    return False

    def _terminate(self, req: Request, state: str, reason: str) -> None:
        """Move a request to a terminal state, releasing its blocks if it
        was running and dequeueing it if it was waiting."""
        if req.slot >= 0:
            self.cache.release(req.slot, req.rid)
            del self.running[req.slot]
            req.slot = -1
        elif req in self.waiting:
            self.waiting.remove(req)
        req.state = state
        req.finish_reason = reason
        req.finish_step = self.step_count

    def finish(self, req: Request, reason: str) -> None:
        self._terminate(req, FINISHED, reason)

    def expire(self, req: Request) -> None:
        """Deadline elapsed: blocks released, partial ``emitted`` kept."""
        self._terminate(req, EXPIRED, "deadline")
        self.counters["expired"] += 1

    def fail(self, req: Request, reason: str) -> None:
        """Terminal failure (NaN quarantine, retries exhausted): blocks
        released — refcounts on shared blocks stay intact — and the
        request never re-enters the queue."""
        self._terminate(req, FAILED, reason)
        self.counters["failed"] += 1

    # ----------------------------------------------------------- watchdog
    def advance_clock(self, ticks: int) -> None:
        """Fault hook (slow step): the step took ``ticks`` extra virtual
        time — deadlines feel it."""
        self.clock += int(ticks)

    def record_progress(self, n_tokens: int) -> None:
        """Engine calls this at the end of every step with the number of
        tokens it emitted; drives the forward-progress watchdog."""
        self._history.append((self._step_preempts, n_tokens))
        self._step_preempts = 0
        if len(self._history) < self.watchdog_window:
            return
        preempts = sum(p for p, _ in self._history)
        tokens = sum(t for _, t in self._history)
        if not self.serial_admission:
            # livelock signature: the batch keeps churning through
            # preempt/readmit without emitting anything
            if preempts >= self.watchdog_threshold and tokens == 0:
                self.serial_admission = True
                self.counters["watchdog_trips"] += 1
                self._history.clear()
        else:
            # pressure cleared: a full window with progress, no preemption
            if preempts == 0 and tokens > 0:
                self.serial_admission = False
                self._history.clear()

    # --------------------------------------------------------- speculation
    def spec_budget(self, req: Request) -> int:
        """Draft tokens ``req`` may verify this step: capped by the
        configured ``lookahead``, the remaining token budget (a draft
        beyond the last committable token is wasted verify work), and the
        per-request block capacity (every draft row's KV write at
        ``cached + 1 + i`` must be tableable)."""
        if not self.lookahead:
            return 0
        rem = req.params.max_new_tokens - len(req.emitted)
        cap = self.cache.max_blocks_per_req * self.cache.block_size
        return max(0, min(self.lookahead, rem - 1, cap - 1 - req.cached))

    # --------------------------------------------------------------- plan
    def plan(self) -> StepPlan:
        """One scheduling round: expire, reclaim, grow/preempt, admit,
        plan chunks + copy-on-write forks.  The caller (engine) runs the
        ``chunks`` (prefill), then one decode step over ``decode``."""
        self.step_count += 1
        self.clock += 1
        preempted: List[Request] = []

        # 0. deadline expiry — queued and running requests past their TTL
        # terminate EXPIRED (running victims keep their partial stream)
        expired: List[Request] = []
        for req in [r for r in self.waiting
                    if r.deadline is not None and self.clock >= r.deadline]:
            self.expire(req)
            expired.append(req)
        for slot in sorted(self.running):
            req = self.running[slot]
            if req.deadline is not None and self.clock >= req.deadline:
                self.expire(req)
                expired.append(req)

        # 1. sliding-window reclamation — blocks wholly below the window
        # of the next write position are freed, not merely masked
        if self.window:
            for slot in sorted(self.running):
                req = self.running[slot]
                self.cache.reclaim_window(slot, req.rid, req.cached,
                                          self.window)

        # 2. decode growth — ascending slot order is the deterministic tie
        # break; a victim drops out of this step's plan entirely.
        for slot in sorted(self.running):
            req = self.running.get(slot)
            if req is None:
                continue                         # preempted below this step
            if req.cached < req.n_prefill:
                continue
            # the step's write span is the pending token plus any
            # speculative draft rows — growth must cover all of it
            top = req.cached + self.spec_budget(req)
            while self.running.get(slot) is req \
                    and self.cache.needs_block(slot, top):
                if not self._with_preempt(
                        req, lambda: self.cache.extend(slot, req.rid),
                        preempted):
                    break

        # 3. admission (FIFO, head-of-line blocking); prefix-cache hits
        # start the request part-prefilled.  Watchdog-degraded mode admits
        # serially: at most one running request until pressure clears.
        admitted: List[Request] = []
        while self.waiting:
            if self.serial_admission and self.running:
                break
            head = self.waiting[0]
            slot = self._free_slot()
            if slot is None:
                break
            toks = head.prefill_tokens
            # +1: the first decode write lands at position n_prefill, so
            # the slot must own the block covering it up front; +lk: the
            # speculative write span too.  lk's remaining-budget cap keeps
            # the total < prompt + max_new_tokens, so submit's fits()
            # check still guarantees a solo request can always admit
            lk = min(self.lookahead,
                     max(head.params.max_new_tokens
                         - len(head.emitted) - 1, 0))
            try:
                n_hit = self.cache.assign(slot, head.rid,
                                          len(toks) + 1 + lk, tokens=toks)
            except PoolExhausted:
                break
            self.waiting.popleft()
            head.slot = slot
            head.seq = self._adm_seq
            self._adm_seq += 1
            head.cached = n_hit                  # hit KV is already pooled
            head.n_hit = n_hit
            head.state = PREFILL if n_hit < head.n_prefill else DECODE
            self.running[slot] = head
            admitted.append(head)

        # 4. chunk planning + copy-on-write forks for this step's writes
        chunks: List[Tuple[Request, int, int]] = []
        decode: List[Request] = []
        for slot in sorted(self.running):
            req = self.running.get(slot)
            if req is None:
                continue
            n_pref = req.n_prefill
            if req.cached < n_pref:              # mid-prefill: one chunk
                req.state = PREFILL
                end = self._chunk_end(req)
                # a chunk that finishes prefill enters decode in the same
                # step, so its write span includes the decode write (and
                # the speculative rows — admission reserved their blocks)
                w1 = end + 1 + self.spec_budget(req) if end == n_pref \
                    else end
                if not self._with_preempt(
                        req, lambda: self.cache.ensure_writable(
                            slot, req.rid, req.cached, w1), preempted):
                    continue
                chunks.append((req, req.cached, end - req.cached))
                if end == n_pref:                # finishes prefill: decode
                    decode.append(req)           # in the same step
            else:                                # decode-phase
                req.state = DECODE
                w1 = req.cached + 1 + self.spec_budget(req)
                if self._with_preempt(
                        req, lambda: self.cache.ensure_writable(
                            slot, req.rid, req.cached, w1), preempted):
                    decode.append(req)

        return StepPlan(admitted=admitted, decode=decode,
                        preempted=[p for p in preempted if p is not None],
                        chunks=chunks, expired=expired)

    def _chunk_end(self, req: Request) -> int:
        """End position of the request's next prefill chunk: aligned to
        absolute multiples of the chunk size (so chunk boundaries — and
        the numerics they shape — are independent of cache hits and batch
        composition), capped at the prefill length."""
        C = self.prefill_chunk_tokens
        if not C:
            return req.n_prefill
        return min(req.n_prefill, (req.cached // C + 1) * C)

    @property
    def idle(self) -> bool:
        return not self.waiting and not self.running
