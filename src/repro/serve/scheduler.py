"""Continuous-batching scheduler: admission queue, per-request state, and
block-pool-pressure preemption over a :class:`repro.serve.cache.PagedKVCache`.

Per engine step the scheduler produces a :class:`StepPlan`:

  1. **decode growth** — every running request about to write a token at a
     block boundary gets one more block; when the pool is exhausted the
     *youngest* running request (highest admission sequence) is preempted:
     its blocks are freed and it requeues at the *front* of the admission
     queue (recompute-style preemption — on re-admission its full context
     ``prompt ++ emitted[:-1]`` is re-prefilled and its pending last token
     re-enters decode, so no output token is ever lost or re-sampled).
  2. **admission** — FIFO: while a batch slot is free and the pool can hold
     the head request's prefill blocks, it is admitted (head-of-line
     blocking keeps admission deterministic and starvation-free: the oldest
     request eventually runs solo).

Everything is host-side and deterministic in the submit/step sequence —
the property the batch-invariance suite (tests/test_serving_engine.py)
checks against solo runs.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.serve.cache import PagedKVCache, PoolExhausted


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling/stop configuration."""
    max_new_tokens: int = 16
    temperature: float = 0.0
    seed: int = 0                      # per-request PRNG stream
    stop_tokens: Tuple[int, ...] = ()


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (T,) int32
    params: SamplingParams
    state: str = "waiting"             # waiting | running | finished
    slot: int = -1
    seq: int = -1                      # admission sequence (preempt victim
    #                                    order; re-assigned on re-admission)
    emitted: List[int] = dataclasses.field(default_factory=list)
    cached: int = 0                    # tokens with KV in the pool
    finish_reason: Optional[str] = None
    n_preemptions: int = 0
    submit_step: int = -1
    finish_step: int = -1

    @property
    def pending(self) -> int:
        """The context token whose KV is not yet cached — the next decode
        step's input.  For a fresh request this is the *last prompt
        token*: prefill stops one short, so prefill logits are never
        consumed and prefill lengths can be freely bucket-padded (the
        first sampled token comes out of the first decode step)."""
        return int(self.emitted[-1] if self.emitted else self.prompt[-1])

    @property
    def context(self) -> np.ndarray:
        """prompt ++ emitted (the full token sequence so far)."""
        return np.concatenate([self.prompt,
                               np.asarray(self.emitted, np.int32)])

    @property
    def prefill_tokens(self) -> np.ndarray:
        """What (re-)admission must prefill: everything but the pending
        token (whose KV the next decode step writes). May be empty
        (single-token prompt)."""
        return self.context[:-1]


@dataclasses.dataclass
class StepPlan:
    admitted: List[Request]
    decode: List[Request]              # running requests for this step
    preempted: List[Request]


class Scheduler:
    def __init__(self, cache: PagedKVCache, max_batch: Optional[int] = None):
        self.cache = cache
        self.max_batch = max_batch or cache.max_reqs
        if self.max_batch > cache.max_reqs:
            raise ValueError("max_batch exceeds the cache's table rows")
        self.waiting: Deque[Request] = deque()
        self.running: Dict[int, Request] = {}      # slot -> request
        self._next_rid = 0
        self._adm_seq = 0
        self.n_preemptions = 0
        self.step_count = 0

    # ------------------------------------------------------------- intake
    def submit(self, prompt, params: SamplingParams) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        total = prompt.size + params.max_new_tokens
        if not self.cache.fits(total):
            raise ValueError(
                f"request of {total} tokens can never fit: needs "
                f"{self.cache.blocks_for(total)} blocks, pool has "
                f"{self.cache.allocator.n_usable} usable "
                f"(max {self.cache.max_blocks_per_req}/req)")
        req = Request(rid=self._next_rid, prompt=prompt, params=params,
                      submit_step=self.step_count)
        self._next_rid += 1
        self.waiting.append(req)
        return req

    # ------------------------------------------------------------ helpers
    def _free_slot(self) -> Optional[int]:
        for s in range(self.max_batch):
            if s not in self.running:
                return s
        return None

    def _preempt_youngest(self) -> Optional[Request]:
        if not self.running:
            return None
        victim = max(self.running.values(), key=lambda r: r.seq)
        self.cache.release(victim.slot, victim.rid)
        del self.running[victim.slot]
        victim.state = "waiting"
        victim.slot = -1
        victim.n_preemptions += 1
        self.n_preemptions += 1
        self.waiting.appendleft(victim)
        return victim

    def finish(self, req: Request, reason: str) -> None:
        self.cache.release(req.slot, req.rid)
        del self.running[req.slot]
        req.state = "finished"
        req.finish_reason = reason
        req.finish_step = self.step_count
        req.slot = -1

    # --------------------------------------------------------------- plan
    def plan(self) -> StepPlan:
        """One scheduling round: grow/preempt, then admit. The caller
        (engine) prefills ``admitted`` and runs one decode step over
        ``decode``."""
        self.step_count += 1
        preempted: List[Request] = []

        # 1. decode growth — ascending slot order is the deterministic tie
        # break; a victim drops out of this step's decode batch entirely.
        for slot in sorted(self.running):
            req = self.running.get(slot)
            if req is None:
                continue                         # preempted below this step
            if self.cache.needs_block(slot, req.cached):
                while True:
                    try:
                        self.cache.extend(slot, req.rid)
                        break
                    except PoolExhausted:
                        victim = self._preempt_youngest()
                        preempted.append(victim)
                        if victim is None or victim is req:
                            break                # requester itself evicted

        # 2. admission (FIFO, head-of-line blocking)
        admitted: List[Request] = []
        while self.waiting:
            head = self.waiting[0]
            slot = self._free_slot()
            if slot is None:
                break
            n_pref = len(head.prefill_tokens)
            try:
                # +1: the first decode write lands at position n_pref, so
                # the slot must already own the block covering it (decode
                # growth ran before admission this step)
                self.cache.assign(slot, head.rid, n_pref + 1)
            except PoolExhausted:
                break
            self.waiting.popleft()
            head.state = "running"
            head.slot = slot
            head.seq = self._adm_seq
            self._adm_seq += 1
            head.cached = 0                      # set after prefill/page-in
            self.running[slot] = head
            admitted.append(head)

        decode = [self.running[s] for s in sorted(self.running)]
        return StepPlan(admitted=admitted, decode=decode,
                        preempted=[p for p in preempted if p is not None])

    @property
    def idle(self) -> bool:
        return not self.waiting and not self.running
