"""Deterministic, seeded fault injection for the serving stack — plus the
structured failure types the hardened engine raises.

The serving engine built in PRs 5–6 assumed a fault-free world; this
module supplies the *failure pressure* analogue of the paper's memory
pressure: a :class:`FaultInjector` carries a static, seeded schedule of
:class:`FaultEvent`\\ s that the engine applies at the matching step
indices.  Because the schedule is pure data and every fault is applied at
a deterministic point of the (host-side, deterministic) engine step loop,
any fault sequence is replayable byte-for-byte: the same seed produces the
same schedule, the same quarantines, the same preemptions, and the same
token streams.

Fault kinds (``FaultEvent.kind``):

  ``squeeze``         steal up to ``magnitude`` free pool blocks for
                      ``duration`` steps (pool-exhaustion pressure: forces
                      preemption / admission stalls / shedding);
  ``nan_logits``      poison one live decode row's logits with NaN this
                      step (the engine's NaN guard must quarantine exactly
                      that request);
  ``drop_step``       the decode step is dropped (transient compute
                      fault): no tokens land, the engine retries with
                      capped exponential backoff;
  ``slow_step``       the step takes ``magnitude`` extra virtual clock
                      ticks (deadline pressure: TTLs are measured on the
                      scheduler clock, so slow faults can expire requests);
  ``corrupt_block``   scribble NaN over one live request's exclusively
                      owned pool block (detected downstream as NaN logits
                      → quarantine);
  ``preempt_storm``   force-preempt the ``magnitude`` youngest running
                      requests (livelock pressure: repeated storms with no
                      forward progress must trip the watchdog).

``target`` is not a request id — it is a deterministic *pick index* into
the sorted list of eligible victims at fire time, so a schedule stays
meaningful (and replayable) across traces with different request counts.

The injector never mutates engine state itself; the engine asks
``events_for(step)`` and applies each event through the normal
cache/scheduler APIs, recording what actually happened via ``fired()`` —
``injector.log`` is the ground-truth fault trace a test can diff across
runs.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List, Sequence, Tuple

import numpy as np

#: every fault kind the injector can schedule
KINDS = ("squeeze", "nan_logits", "drop_step", "slow_step",
         "corrupt_block", "preempt_storm")

#: allocator owner id under which squeezed (fault-held) blocks are parked —
#: they stay *owned*, so allocator conservation holds mid-squeeze
FAULT_OWNER = -2


class AuditFailure(AssertionError):
    """A serving invariant was violated (``Engine(audit=True)``).

    Structured: ``invariant`` names the violated check (e.g.
    ``allocator_conservation``, ``prefix_trie``, ``table_ownership``) and
    ``detail`` carries the failing evidence.
    """

    def __init__(self, invariant: str, detail: str = ""):
        self.invariant = invariant
        self.detail = detail
        super().__init__(f"audit failed: {invariant}"
                         + (f" — {detail}" if detail else ""))


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault (see module docstring for kind semantics)."""
    step: int                 # engine step index (0-based) at which it fires
    kind: str
    target: int = 0           # pick index into the sorted victim candidates
    magnitude: int = 1        # blocks squeezed / clock ticks / storm size
    duration: int = 1         # steps a squeeze is held

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(kinds: {KINDS})")
        if self.step < 0 or self.magnitude < 1 or self.duration < 1:
            raise ValueError(f"malformed fault event: {self}")


class FaultInjector:
    """A static schedule of :class:`FaultEvent`\\ s plus the fire log.

    Construct from an explicit event list (engineered scenarios) or with
    :meth:`seeded` (chaos storms).  The engine consumes the schedule via
    :meth:`events_for` and reports applied faults via :meth:`fired`; the
    resulting ``log`` is deterministic given (schedule, submit/step
    sequence) — byte-for-byte replayability is asserted by the chaos
    suite.
    """

    def __init__(self, events: Iterable[FaultEvent] = ()):
        self.events: Tuple[FaultEvent, ...] = tuple(
            sorted(events, key=lambda e: (e.step, e.kind, e.target)))
        self.log: List[Tuple[int, str, str]] = []   # (step, kind, detail)
        self.counts = {k: 0 for k in KINDS}

    # ------------------------------------------------------------ creation
    @classmethod
    def seeded(cls, seed: int, *, n_steps: int = 32, rate: float = 0.3,
               kinds: Sequence[str] = KINDS,
               max_magnitude: int = 3,
               max_duration: int = 3) -> "FaultInjector":
        """A seeded chaos storm: each step in ``[0, n_steps)`` fires one
        fault with probability ``rate``, with kind/target/magnitude drawn
        from ``numpy.random.default_rng(seed)`` — same seed, same storm."""
        for k in kinds:
            if k not in KINDS:
                raise ValueError(f"unknown fault kind {k!r}")
        rng = np.random.default_rng(seed)
        events = []
        for s in range(n_steps):
            if rng.random() >= rate:
                continue
            events.append(FaultEvent(
                step=s,
                kind=kinds[int(rng.integers(len(kinds)))],
                target=int(rng.integers(0, 8)),
                magnitude=1 + int(rng.integers(0, max_magnitude)),
                duration=1 + int(rng.integers(0, max_duration))))
        return cls(events)

    # ------------------------------------------------------------- queries
    @property
    def horizon(self) -> int:
        """First step index past every scheduled fault (incl. squeeze
        holds) — after this the storm is over and the engine must drain."""
        return max((e.step + e.duration for e in self.events), default=0)

    def events_for(self, step: int) -> List[FaultEvent]:
        return [e for e in self.events if e.step == step]

    # ----------------------------------------------------------- reporting
    def fired(self, step: int, kind: str, detail: str) -> None:
        """Record a fault the engine actually applied (or skipped for lack
        of a victim — the detail says which)."""
        self.log.append((step, kind, detail))
        self.counts[kind] += 1

    def pick(self, event: FaultEvent, candidates: Sequence) -> object:
        """Deterministic victim choice: ``target`` modulo the (sorted by
        the caller) candidate list; ``None`` when there is none."""
        if not candidates:
            return None
        return candidates[event.target % len(candidates)]
