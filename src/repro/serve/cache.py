"""Paged KV cache: a fixed pool of ``block_size``-token KV blocks plus
per-request block tables — the serving-side analogue of the paper's
memory-efficiency discipline (no O(max_seq · max_batch) contiguous cache;
fragmentation-free growth one block at a time).

Layout (one pool entry per transformer layer, stacked on a leading L dim):

  MHA / GQA   k_pool, v_pool : (L, N, block_size, n_kv_heads, head_dim)
  MLA latent  ckv_pool       : (L, N, block_size, kv_lora + rope_dim)

Block id 0 is the **reserved null block**: unused table entries and idle
batch rows point at it, so gathers are always in-bounds and garbage is
masked by ``lengths`` (kernels/paged.py).  The :class:`BlockAllocator`
free-list therefore hands out ids ``1..N−1`` and enforces the allocator
invariants the test suite checks (no double-alloc, owner-checked frees,
conservation, deterministic exhaustion).

Sharding: pools are placed with a NamedSharding when a mesh is given —
the kv-head axis shards over the sequence-parallel ``model`` axis when the
head count divides it (head-parallel decode, zero-communication gather),
otherwise the pool-block axis shards (sequence-sharded pool, GSPMD inserts
the gather collectives), otherwise the pool replicates.  The math is
identical in all three placements, which is what the 8-device differential
tests assert.

The block *tables* are host-side numpy (the scheduler mutates them every
step); a device copy ships with each decode step's inputs.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import ModelConfig


class PoolExhausted(RuntimeError):
    """No free blocks — the scheduler preempts and requeues on this."""


class BlockAllocator:
    """Host-side free-list over block ids ``1..n_blocks−1`` (0 = null).

    LIFO free-list with deterministic order: the same alloc/free sequence
    always yields the same block ids (batch-invariance tests rely on the
    *masking*, not the placement — but determinism keeps runs replayable).
    """

    def __init__(self, n_blocks: int):
        if n_blocks < 2:
            raise ValueError("need at least 2 blocks (one is the reserved "
                             "null block)")
        self.n_blocks = n_blocks
        self._free: List[int] = list(range(n_blocks - 1, 0, -1))
        self._owner: Dict[int, int] = {}

    @property
    def n_usable(self) -> int:
        return self.n_blocks - 1

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self, owner: int, n: int = 1) -> List[int]:
        """Allocate ``n`` blocks for ``owner`` (a request id) — atomic:
        raises :class:`PoolExhausted` without side effects if fewer than
        ``n`` are free."""
        if len(self._free) < n:
            raise PoolExhausted(
                f"need {n} blocks, {len(self._free)} free "
                f"(pool {self.n_usable})")
        ids = [self._free.pop() for _ in range(n)]
        for b in ids:
            assert b not in self._owner          # free-list integrity
            self._owner[b] = owner
        return ids

    def free(self, ids, owner: int) -> None:
        """Return blocks to the pool; owner-checked (a double free or a
        foreign free raises instead of corrupting the list)."""
        for b in ids:
            if self._owner.get(b) != owner:
                raise ValueError(
                    f"block {b} not owned by {owner} "
                    f"(owner: {self._owner.get(b)})")
            del self._owner[b]
            self._free.append(b)

    def owned(self, owner: int) -> List[int]:
        return sorted(b for b, o in self._owner.items() if o == owner)

    def check_conservation(self) -> None:
        """Every usable block is exactly once either free or owned."""
        owned = set(self._owner)
        free = set(self._free)
        assert not (owned & free), f"blocks both free and owned: {owned & free}"
        assert owned | free == set(range(1, self.n_blocks)), \
            f"lost blocks: {set(range(1, self.n_blocks)) - owned - free}"


@dataclasses.dataclass
class PagedKVCache:
    """Device block pools + per-slot block tables + the allocator."""
    cfg: ModelConfig
    block_size: int
    n_blocks: int                    # incl. the reserved null block 0
    max_reqs: int                    # batch slots == block-table rows
    max_blocks_per_req: int
    pools: Dict[str, jax.Array]
    allocator: BlockAllocator
    table: np.ndarray                # (max_reqs, max_blocks_per_req) int32
    n_assigned: np.ndarray           # (max_reqs,) blocks assigned per slot

    # ------------------------------------------------------------ creation
    @classmethod
    def create(cls, cfg: ModelConfig, *, block_size: int = 16,
               n_blocks: int = 64, max_reqs: int = 8,
               max_blocks_per_req: Optional[int] = None,
               mesh=None, seq_axis: str = "model") -> "PagedKVCache":
        a = cfg.attn
        if a is None:
            raise ValueError(f"paged KV cache needs an attention config "
                             f"(arch {cfg.arch_type!r} has none)")
        if max_blocks_per_req is None:
            max_blocks_per_req = n_blocks - 1
        dt = jnp.dtype(cfg.dtype)
        L = cfg.n_layers
        if a.is_mla:
            d_lat = a.kv_lora_rank + a.qk_rope_head_dim
            shapes = {"ckv_pool": (L, n_blocks, block_size, d_lat)}
        else:
            s = (L, n_blocks, block_size, a.n_kv_heads, a.head_dim)
            shapes = {"k_pool": s, "v_pool": s}
        pools = {k: jnp.zeros(s, dt) for k, s in shapes.items()}
        if mesh is not None:
            from jax.sharding import NamedSharding
            pools = {k: jax.device_put(v, NamedSharding(
                mesh, cls._pool_pspec(v.shape, mesh, seq_axis)))
                for k, v in pools.items()}
        return cls(cfg=cfg, block_size=block_size, n_blocks=n_blocks,
                   max_reqs=max_reqs, max_blocks_per_req=max_blocks_per_req,
                   pools=pools, allocator=BlockAllocator(n_blocks),
                   table=np.zeros((max_reqs, max_blocks_per_req), np.int32),
                   n_assigned=np.zeros((max_reqs,), np.int32))

    @staticmethod
    def _pool_pspec(shape: Tuple[int, ...], mesh, seq_axis: str):
        """Head-parallel when the kv-head axis divides the mesh axis, else
        pool-block-sharded, else replicated (see module docstring)."""
        from jax.sharding import PartitionSpec as P
        size = dict(zip(mesh.axis_names, mesh.devices.shape))[seq_axis]
        spec = [None] * len(shape)
        if size > 1:
            if len(shape) == 5 and shape[3] % size == 0:
                spec[3] = seq_axis               # kv heads
            elif shape[1] % size == 0:
                spec[1] = seq_axis               # pool blocks
        return P(*spec)

    # ------------------------------------------------------------- queries
    @property
    def layout(self) -> str:
        return "mla" if self.cfg.attn.is_mla else "mha"

    def blocks_for(self, n_tokens: int) -> int:
        return max(1, math.ceil(n_tokens / self.block_size))

    def fits(self, n_tokens: int) -> bool:
        """Could a request of this total length *ever* run (alone)?"""
        n = self.blocks_for(n_tokens)
        return n <= min(self.allocator.n_usable, self.max_blocks_per_req)

    def needs_block(self, slot: int, write_pos: int) -> bool:
        """Writing a token at context position ``write_pos`` needs a block
        that slot doesn't own yet?"""
        return write_pos // self.block_size >= int(self.n_assigned[slot])

    def device_table(self) -> jax.Array:
        return jnp.asarray(self.table)

    # ---------------------------------------------------------- alloc/free
    def assign(self, slot: int, rid: int, n_tokens: int) -> List[int]:
        """Allocate and table the blocks for a fresh ``n_tokens`` context
        (admission/prefill). Atomic w.r.t. PoolExhausted."""
        n = self.blocks_for(n_tokens)
        if n > self.max_blocks_per_req:
            raise ValueError(f"request needs {n} blocks > "
                             f"max_blocks_per_req={self.max_blocks_per_req}")
        ids = self.allocator.alloc(rid, n)           # raises before mutation
        assert int(self.n_assigned[slot]) == 0, f"slot {slot} not empty"
        self.table[slot, :n] = ids
        self.n_assigned[slot] = n
        return ids

    def extend(self, slot: int, rid: int) -> int:
        """Append one block to a slot's table (decode growth)."""
        n = int(self.n_assigned[slot])
        if n >= self.max_blocks_per_req:
            raise ValueError(f"slot {slot} at max_blocks_per_req")
        (b,) = self.allocator.alloc(rid, 1)
        self.table[slot, n] = b
        self.n_assigned[slot] = n + 1
        return b

    def release(self, slot: int, rid: int) -> None:
        """Free a slot's blocks (finish or preemption) and null its row."""
        n = int(self.n_assigned[slot])
        self.allocator.free([int(b) for b in self.table[slot, :n]], rid)
        self.table[slot, :] = 0
        self.n_assigned[slot] = 0

    # ------------------------------------------------------------- page io
    def page_in(self, slot: int, dense_cache: Dict[str, jax.Array],
                n_tokens: int) -> None:
        """Scatter a prefill's dense cache (leading layer dim, B=1, seq on
        axis 2) into the slot's blocks.  The dense seq length may exceed
        ``n_tokens`` (padded prefill); only the first ``n_tokens`` page in."""
        n = self.blocks_for(n_tokens)
        assert n <= int(self.n_assigned[slot])
        ids = jnp.asarray(self.table[slot, :n], jnp.int32)
        bs = self.block_size
        for dk, pk in (("k", "k_pool"), ("v", "v_pool"), ("ckv", "ckv_pool")):
            if dk not in dense_cache:
                continue
            x = dense_cache[dk][:, 0]                 # (L, T, ...)
            L, T = x.shape[0], x.shape[1]
            pad = n * bs - min(T, n * bs)
            x = x[:, :n * bs]
            if pad:
                x = jnp.pad(x, [(0, 0), (0, pad)] +
                            [(0, 0)] * (x.ndim - 2))
            blocks = x.reshape(L, n, bs, *x.shape[2:])
            self.pools[pk] = _scatter_blocks(self.pools[pk], ids, blocks)

    def gather(self, slot: int, length: int) -> Dict[str, jax.Array]:
        """Contiguous (L, length, ...) view of a slot's cache — test /
        debugging aid (the decode path never materializes this)."""
        n = self.blocks_for(length)
        ids = jnp.asarray(self.table[slot, :n], jnp.int32)
        out = {}
        for pk in self.pools:
            p = self.pools[pk][:, ids]                # (L, n, bs, ...)
            out[pk[:-5]] = p.reshape(p.shape[0], -1,
                                     *p.shape[3:])[:, :length]
        return out


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_blocks(pool, ids, blocks):
    """pool (L, N, bs, ...) — donated, updated in place; ids (n,);
    blocks (L, n, bs, ...)."""
    return pool.at[:, ids].set(blocks.astype(pool.dtype))
