"""Paged KV cache: a fixed pool of ``block_size``-token KV blocks plus
per-request block tables — the serving-side analogue of the paper's
memory-efficiency discipline (no O(max_seq · max_batch) contiguous cache;
fragmentation-free growth one block at a time).

Layout (one pool entry per transformer layer, stacked on a leading L dim):

  MHA / GQA   k_pool, v_pool : (L, N, block_size, n_kv_heads, head_dim)
  MLA latent  ckv_pool       : (L, N, block_size, kv_lora + rope_dim)

Block id 0 is the **reserved null block**: unused table entries and idle
batch rows point at it, so gathers are always in-bounds and garbage is
masked by ``lengths`` (kernels/paged.py).  The :class:`BlockAllocator`
free-list therefore hands out ids ``1..N−1`` and enforces the allocator
invariants the test suite checks (no double-alloc, owner-checked frees,
conservation, deterministic exhaustion).

**Content addressing / copy-on-write** (vLLM-style prefix caching): blocks
are *refcounted* — several owners (request ids, plus the cache's own
sentinel owner) may hold the same block, and it returns to the free list
only when the last ref drops.  :class:`PrefixCache` indexes *full* blocks
in a radix trie over token prefixes, each node carrying a chained content
hash ``H(parent_hash, block_tokens, salt)`` where the salt is the
MaskSpec-relevant config (block size, sliding window).  Admission looks up
the longest cached prefix (including a *partial tail* match inside the
last block) and shares those blocks instead of re-prefilling them; a
writer forks a private copy of a shared block only on first divergence
(:meth:`PagedKVCache.ensure_writable`).  Windowed requests additionally
*reclaim* blocks that fall wholly outside the sliding window
(:meth:`PagedKVCache.reclaim_window`) instead of merely masking them.

Sharding: pools are placed with a NamedSharding when a mesh is given —
the kv-head axis shards over the sequence-parallel ``model`` axis when the
head count divides it (head-parallel decode, zero-communication gather),
otherwise the pool-block axis shards (sequence-sharded pool, GSPMD inserts
the gather collectives), otherwise the pool replicates.  The math is
identical in all three placements, which is what the 8-device differential
tests assert.

The block *tables* are host-side numpy (the scheduler mutates them every
step); a device copy ships with each decode step's inputs.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import ModelConfig
from repro.serve.faults import AuditFailure


class PoolExhausted(RuntimeError):
    """No free blocks — the scheduler preempts and requeues on this."""


class BlockAllocator:
    """Host-side refcounted free-list over block ids ``1..n_blocks−1``
    (0 = null).

    LIFO free-list with deterministic order: the same alloc/share/free
    sequence always yields the same block ids (batch-invariance tests rely
    on the *masking*, not the placement — but determinism keeps runs
    replayable).  Every op is owner-checked: an owner (a request id, or
    the prefix cache's sentinel) can hold at most one ref per block, a
    free by a non-owner raises, and a block returns to the free list
    exactly when its last owner releases it.
    """

    def __init__(self, n_blocks: int):
        if n_blocks < 2:
            raise ValueError("need at least 2 blocks (one is the reserved "
                             "null block)")
        self.n_blocks = n_blocks
        self._free: List[int] = list(range(n_blocks - 1, 0, -1))
        self._owners: Dict[int, Set[int]] = {}

    @property
    def n_usable(self) -> int:
        return self.n_blocks - 1

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self, owner: int, n: int = 1) -> List[int]:
        """Allocate ``n`` fresh blocks for ``owner`` (a request id) —
        atomic: raises :class:`PoolExhausted` without side effects if
        fewer than ``n`` are free."""
        if len(self._free) < n:
            raise PoolExhausted(
                f"need {n} blocks, {len(self._free)} free "
                f"(pool {self.n_usable})")
        ids = [self._free.pop() for _ in range(n)]
        for b in ids:
            assert b not in self._owners         # free-list integrity
            self._owners[b] = {owner}
        return ids

    def share(self, ids: Sequence[int], owner: int) -> None:
        """Add ``owner`` as a referent of already-allocated blocks
        (content-addressed reuse).  Sharing a free block, or a block the
        owner already holds, raises."""
        for b in ids:
            owners = self._owners.get(b)
            if owners is None:
                raise ValueError(f"cannot share free block {b}")
            if owner in owners:
                raise ValueError(f"owner {owner} already holds block {b}")
        for b in ids:
            self._owners[b].add(owner)

    def free(self, ids: Sequence[int], owner: int) -> None:
        """Drop ``owner``'s ref on each block; a block returns to the pool
        exactly when its last ref drops.  Owner-checked (a double free or
        a foreign free raises instead of corrupting the list)."""
        for b in ids:
            owners = self._owners.get(b)
            if owners is None or owner not in owners:
                raise ValueError(
                    f"block {b} not owned by {owner} "
                    f"(owners: {sorted(owners) if owners else None})")
            owners.discard(owner)
            if not owners:
                del self._owners[b]
                self._free.append(b)

    def refcount(self, b: int) -> int:
        return len(self._owners.get(b, ()))

    def owners(self, b: int) -> Tuple[int, ...]:
        return tuple(sorted(self._owners.get(b, ())))

    def owned(self, owner: int) -> List[int]:
        return sorted(b for b, o in self._owners.items() if owner in o)

    def check_conservation(self) -> None:
        """Every usable block is exactly once either free or referenced
        (by ≥ 1 owner) — never both, never lost."""
        owned = set(self._owners)
        free = set(self._free)
        assert all(self._owners[b] for b in owned), \
            f"blocks with empty owner sets: {[b for b in owned if not self._owners[b]]}"
        assert not (owned & free), f"blocks both free and owned: {owned & free}"
        assert owned | free == set(range(1, self.n_blocks)), \
            f"lost blocks: {set(range(1, self.n_blocks)) - owned - free}"


# ==========================================================================
# Content-addressed prefix index (radix trie over full token blocks)
# ==========================================================================

class _TrieNode:
    __slots__ = ("key", "block", "chain_hash", "children", "parent", "lru")

    def __init__(self, key, block, chain_hash, parent):
        self.key = key                    # tuple of block_size token ids
        self.block = block                # pool block id holding the KV
        self.chain_hash = chain_hash      # H(parent_hash, key, salt)
        self.children: Dict[tuple, "_TrieNode"] = {}
        self.parent = parent
        self.lru = 0


class PrefixCache:
    """Radix trie over *full* KV blocks, keyed by the block's token ids
    chained from the root — so a node's identity is its whole token
    prefix, and its ``chain_hash`` is the content address
    ``H(parent_hash, tokens, salt)``.  The trie holds one allocator ref
    (owner :data:`OWNER`) per indexed block, which keeps finished
    requests' prefixes alive for later arrivals until LRU eviction
    reclaims them under pool pressure.
    """

    OWNER = -1                            # the cache's allocator owner id

    def __init__(self, allocator: BlockAllocator, block_size: int,
                 salt: tuple = ()):
        self.allocator = allocator
        self.block_size = block_size
        self.salt = tuple(salt)
        self.root = _TrieNode((), 0, hash(("prefix-root", self.salt)), None)
        self._clock = 0
        self.stats = dict(lookups=0, hit_tokens=0, hit_blocks=0,
                          partial_hits=0, inserted=0, deduped=0, evicted=0)

    # ------------------------------------------------------------ internal
    def _touch(self, node: _TrieNode) -> None:
        self._clock += 1
        node.lru = self._clock

    @property
    def n_blocks(self) -> int:
        """Blocks currently indexed (== allocator refs held by OWNER)."""
        return len(self.allocator.owned(self.OWNER))

    # -------------------------------------------------------------- lookup
    def lookup(self, tokens: Sequence[int]) -> Tuple[int, List[int]]:
        """Longest cached prefix of ``tokens``: returns ``(n_hit,
        block_ids)`` where the first ``n_hit`` tokens' KV lives in
        ``block_ids`` (in table order).  The last returned block may be a
        *partial tail* match — a cached full block whose first ``j``
        tokens extend the prefix (``n_hit`` counts only those ``j``); the
        caller must copy-on-write before writing positions ≥ ``n_hit``
        into it."""
        bs = self.block_size
        tokens = [int(t) for t in tokens]
        self.stats["lookups"] += 1
        node, i, ids = self.root, 0, []
        while i + bs <= len(tokens):
            child = node.children.get(tuple(tokens[i:i + bs]))
            if child is None:
                break
            ids.append(child.block)
            self._touch(child)
            node, i = child, i + bs
        rem = tuple(tokens[i:])
        if rem:                            # partial tail inside one block
            best, best_len = None, 0
            for key, child in sorted(node.children.items()):
                m = 0
                while m < len(rem) and key[m] == rem[m]:
                    m += 1
                if m > best_len:
                    best, best_len = child, m
            if best is not None:
                ids.append(best.block)
                self._touch(best)
                i += best_len
                self.stats["partial_hits"] += 1
        self.stats["hit_tokens"] += i
        self.stats["hit_blocks"] += len(ids)
        return i, ids

    # ------------------------------------------------------------ register
    def register(self, tokens: Sequence[int],
                 blocks: Sequence[int]) -> List[Tuple[int, int]]:
        """Index the full blocks of ``tokens`` (``len(blocks)`` ==
        ``len(tokens) // block_size``), whose KV lives in ``blocks``.

        For each depth, either the trie gains a node for our block (the
        cache takes a ref), or an *equal* block is already indexed — then
        ``(depth, canonical_block)`` is returned so the caller can
        dedupe-swap its table entry onto the canonical copy.  A zero
        (reclaimed) entry ends the walk: its content is gone.
        """
        bs = self.block_size
        tokens = [int(t) for t in tokens]
        node, swaps = self.root, []
        for d, b in enumerate(blocks):
            key = tuple(tokens[d * bs:(d + 1) * bs])
            child = node.children.get(key)
            if child is not None:
                if b != 0 and b != child.block:
                    swaps.append((d, child.block))
                node = child
                continue
            if b == 0:                     # reclaimed: no content to index
                break
            self.allocator.share([b], self.OWNER)
            child = _TrieNode(key, b, hash((node.chain_hash, key,
                                            self.salt)), node)
            node.children[key] = child
            self._touch(child)
            self.stats["inserted"] += 1
            node = child
        self.stats["deduped"] += len(swaps)
        return swaps

    # -------------------------------------------------------------- evict
    def evict(self, n: int) -> int:
        """Drop up to ``n`` LRU *leaf* blocks whose only referent is the
        cache itself (blocks shared with live requests are pinned).
        Returns how many were freed to the pool."""
        freed = 0
        while freed < n:
            victim = None
            for node in self._iter_leaves():
                if self.allocator.refcount(node.block) != 1:
                    continue               # shared with a live request
                if victim is None or node.lru < victim.lru:
                    victim = node
            if victim is None:
                break
            self.allocator.free([victim.block], self.OWNER)
            del victim.parent.children[victim.key]
            self.stats["evicted"] += 1
            freed += 1
        return freed

    def _iter_leaves(self):
        stack = [self.root]
        while stack:
            node = stack.pop()
            if node is not self.root and not node.children:
                yield node
            stack.extend(node.children.values())

    def check_integrity(self) -> None:
        """Every indexed block holds exactly one cache ref; the trie is
        acyclic with consistent parent links (test aid)."""
        seen = set()
        stack = [self.root]
        while stack:
            node = stack.pop()
            for key, child in node.children.items():
                assert child.parent is node and child.key == key
                assert child.block not in seen, "block indexed twice"
                seen.add(child.block)
                assert self.OWNER in self.allocator.owners(child.block)
                stack.append(child)
        assert seen == set(self.allocator.owned(self.OWNER)), \
            "trie blocks and cache-owned allocator refs diverge"


@dataclasses.dataclass
class PagedKVCache:
    """Device block pools + per-slot block tables + the allocator."""
    cfg: ModelConfig
    block_size: int
    n_blocks: int                    # incl. the reserved null block 0
    max_reqs: int                    # batch slots == block-table rows
    max_blocks_per_req: int
    pools: Dict[str, jax.Array]
    allocator: BlockAllocator
    table: np.ndarray                # (max_reqs, max_blocks_per_req) int32
    n_assigned: np.ndarray           # (max_reqs,) blocks assigned per slot
    prefix: Optional[PrefixCache] = None
    counters: Dict[str, int] = dataclasses.field(
        default_factory=lambda: dict(forks=0, reclaimed=0, hit_tokens=0,
                                     hit_blocks=0, evicted=0, dedup_swaps=0))

    # ------------------------------------------------------------ creation
    @classmethod
    def create(cls, cfg: ModelConfig, *, block_size: Optional[int] = None,
               n_blocks: int = 64, max_reqs: int = 8,
               max_blocks_per_req: Optional[int] = None,
               mesh=None, seq_axis: str = "model",
               prefix_cache: bool = False) -> "PagedKVCache":
        a = cfg.attn
        if a is None:
            raise ValueError(f"paged KV cache needs an attention config "
                             f"(arch {cfg.arch_type!r} has none)")
        if block_size is None:
            block_size = cls.default_block_size(a, mesh, seq_axis)
        if max_blocks_per_req is None:
            max_blocks_per_req = n_blocks - 1
        dt = jnp.dtype(cfg.dtype)
        L = cfg.n_layers
        if a.is_mla:
            d_lat = a.kv_lora_rank + a.qk_rope_head_dim
            shapes = {"ckv_pool": (L, n_blocks, block_size, d_lat)}
        else:
            s = (L, n_blocks, block_size, a.n_kv_heads, a.head_dim)
            shapes = {"k_pool": s, "v_pool": s}
        pools = {k: jnp.zeros(s, dt) for k, s in shapes.items()}
        if mesh is not None:
            from jax.sharding import NamedSharding
            pools = {k: jax.device_put(v, NamedSharding(
                mesh, cls._pool_pspec(v.shape, mesh, seq_axis)))
                for k, v in pools.items()}
        allocator = BlockAllocator(n_blocks)
        prefix = None
        if prefix_cache:
            # the salt is the MaskSpec-relevant config: a block's content
            # address must distinguish caches whose KV would differ for
            # the same token ids
            salt = (cfg.name, block_size, int(a.window or 0))
            prefix = PrefixCache(allocator, block_size, salt)
        return cls(cfg=cfg, block_size=block_size, n_blocks=n_blocks,
                   max_reqs=max_reqs, max_blocks_per_req=max_blocks_per_req,
                   pools=pools, allocator=allocator,
                   table=np.zeros((max_reqs, max_blocks_per_req), np.int32),
                   n_assigned=np.zeros((max_reqs,), np.int32),
                   prefix=prefix)

    @staticmethod
    def default_block_size(a, mesh=None, seq_axis: str = "model") -> int:
        """Default pool granularity when the caller passes none:
        ``REPRO_TUNE_BLOCK_SIZE`` env > the active tuning table's winner
        for this (kv layout, pool sharding) > the historical 16."""
        from repro.tune import table as _tt
        bs = _tt.env_int("REPRO_TUNE_BLOCK_SIZE")
        if bs is not None:
            return bs
        tab = _tt.active_table()
        if tab is not None:
            size = 1
            if mesh is not None:
                size = dict(zip(mesh.axis_names,
                                mesh.devices.shape)).get(seq_axis, 1)
            hit = tab.best_block_size(
                layout="mla" if a.is_mla else "mha",
                sharding="none" if size <= 1 else "pool")
            if hit is not None:
                return hit
        return 16

    @staticmethod
    def _pool_pspec(shape: Tuple[int, ...], mesh, seq_axis: str):
        """Head-parallel when the kv-head axis divides the mesh axis, else
        pool-block-sharded, else replicated (see module docstring)."""
        from jax.sharding import PartitionSpec as P
        size = dict(zip(mesh.axis_names, mesh.devices.shape))[seq_axis]
        spec = [None] * len(shape)
        if size > 1:
            if len(shape) == 5 and shape[3] % size == 0:
                spec[3] = seq_axis               # kv heads
            elif shape[1] % size == 0:
                spec[1] = seq_axis               # pool blocks
        return P(*spec)

    # ------------------------------------------------------------- queries
    @property
    def layout(self) -> str:
        return "mla" if self.cfg.attn.is_mla else "mha"

    def blocks_for(self, n_tokens: int) -> int:
        return max(1, math.ceil(n_tokens / self.block_size))

    def fits(self, n_tokens: int) -> bool:
        """Could a request of this total length *ever* run (alone)?"""
        n = self.blocks_for(n_tokens)
        return n <= min(self.allocator.n_usable, self.max_blocks_per_req)

    def needs_block(self, slot: int, write_pos: int) -> bool:
        """Writing a token at context position ``write_pos`` needs a block
        that slot doesn't own yet?"""
        return write_pos // self.block_size >= int(self.n_assigned[slot])

    def device_table(self) -> jax.Array:
        return jnp.asarray(self.table)

    @property
    def n_cache_blocks(self) -> int:
        """Blocks pinned by the prefix cache only (0 when disabled)."""
        return self.prefix.n_blocks if self.prefix is not None else 0

    # ---------------------------------------------------------- alloc/free
    def _alloc(self, rid: int, n: int) -> List[int]:
        """Allocate with prefix-cache eviction as the fallback: cache-only
        blocks are LRU-evicted to make room before PoolExhausted
        propagates (and triggers scheduler preemption)."""
        while True:
            try:
                return self.allocator.alloc(rid, n)
            except PoolExhausted:
                if self.prefix is None:
                    raise
                short = n - self.allocator.n_free
                evicted = self.prefix.evict(short)
                self.counters["evicted"] += evicted
                if evicted < short:
                    raise

    def assign(self, slot: int, rid: int, n_tokens: int,
               tokens: Optional[Sequence[int]] = None) -> int:
        """Table the blocks for a fresh ``n_tokens`` context (admission).
        When ``tokens`` (the prefill token ids) are given and the prefix
        cache is enabled, cached prefix blocks are *shared* instead of
        allocated; returns the number of prefix tokens whose KV is already
        cached (0 without a hit).  Atomic w.r.t. PoolExhausted."""
        n = self.blocks_for(n_tokens)
        if n > self.max_blocks_per_req:
            raise ValueError(f"request needs {n} blocks > "
                             f"max_blocks_per_req={self.max_blocks_per_req}")
        assert int(self.n_assigned[slot]) == 0, f"slot {slot} not empty"
        n_hit, hit_ids = 0, []
        if self.prefix is not None and tokens is not None:
            n_hit, hit_ids = self.prefix.lookup(tokens)
        # ref the hits FIRST so the eviction fallback can never free them,
        # then allocate; roll the refs back on exhaustion (atomicity)
        self.allocator.share(hit_ids, rid)
        try:
            fresh = self._alloc(rid, n - len(hit_ids))
        except PoolExhausted:
            self.allocator.free(hit_ids, rid)
            raise
        self.table[slot, :n] = hit_ids + fresh
        self.n_assigned[slot] = n
        self.counters["hit_tokens"] += n_hit
        self.counters["hit_blocks"] += len(hit_ids)
        return n_hit

    def extend(self, slot: int, rid: int) -> int:
        """Append one block to a slot's table (decode growth)."""
        n = int(self.n_assigned[slot])
        if n >= self.max_blocks_per_req:
            raise ValueError(f"slot {slot} at max_blocks_per_req")
        (b,) = self._alloc(rid, 1)
        self.table[slot, n] = b
        self.n_assigned[slot] = n + 1
        return b

    def release(self, slot: int, rid: int) -> None:
        """Drop a slot's refs (finish or preemption) and null its row.
        Zero table entries (window-reclaimed blocks) are already free;
        shared blocks survive under their other owners."""
        n = int(self.n_assigned[slot])
        ids = [int(b) for b in self.table[slot, :n] if b != 0]
        self.allocator.free(ids, rid)
        self.table[slot, :] = 0
        self.n_assigned[slot] = 0

    # ------------------------------------------------- copy-on-write fork
    def ensure_writable(self, slot: int, rid: int, p0: int, p1: int) -> int:
        """Before writing context positions ``[p0, p1)``: fork a private
        copy of every covered block that is shared (refcount > 1), so the
        write never mutates another owner's (or the cache's) KV.  Returns
        the number of blocks forked."""
        if p1 <= p0:
            return 0
        bs = self.block_size
        forks = 0
        for i in range(p0 // bs, (p1 - 1) // bs + 1):
            b = int(self.table[slot, i])
            assert b != 0 and i < int(self.n_assigned[slot]), \
                f"write into unassigned/reclaimed block {i} of slot {slot}"
            if self.allocator.refcount(b) == 1:
                continue
            (nb,) = self._alloc(rid, 1)
            for pk in self.pools:
                self.pools[pk] = _copy_block(self.pools[pk], b, nb)
            self.table[slot, i] = nb
            self.allocator.free([b], rid)
            forks += 1
        self.counters["forks"] += forks
        return forks

    # ------------------------------------------------- windowed reclamation
    def reclaim_window(self, slot: int, rid: int, next_pos: int,
                       window: int) -> int:
        """Drop the slot's refs on blocks wholly below the sliding window
        of the next write position (every kv position the request can
        still attend is ≥ ``next_pos + 1 - window``).  Table entries are
        zeroed — the paged kernels' window masking never reads them — and
        ``n_assigned`` stays a high-water mark so decode growth is
        unaffected.  Returns how many refs were dropped."""
        if not window:
            return 0
        bs = self.block_size
        floor_pos = next_pos + 1 - window
        hi = min(floor_pos // bs, int(self.n_assigned[slot]))
        freed = 0
        for i in range(hi):
            b = int(self.table[slot, i])
            if b == 0:
                continue
            self.allocator.free([b], rid)
            self.table[slot, i] = 0
            freed += 1
        self.counters["reclaimed"] += freed
        return freed

    # --------------------------------------------------- prefix indexing
    def register_prefix(self, slot: int, rid: int, tokens: Sequence[int],
                        upto: int) -> None:
        """Index the slot's *full* blocks covering ``tokens[:upto]``
        (positions whose KV has been written) into the prefix cache; on a
        content-equal duplicate, swap our table entry onto the canonical
        block and drop the duplicate ref (dedupe)."""
        if self.prefix is None:
            return
        nfull = min(upto // self.block_size, int(self.n_assigned[slot]))
        if nfull <= 0:
            return
        blocks = [int(b) for b in self.table[slot, :nfull]]
        for d, canonical in self.prefix.register(tokens[:nfull *
                                                        self.block_size],
                                                 blocks):
            ours = int(self.table[slot, d])
            self.allocator.share([canonical], rid)
            self.allocator.free([ours], rid)
            self.table[slot, d] = canonical
            self.counters["dedup_swaps"] += 1

    # ------------------------------------------------- fault / audit hooks
    def corrupt_block(self, b: int) -> None:
        """Scribble NaN over block ``b`` in every layer pool (fault
        injection: a corrupted block is detected downstream as NaN logits
        in the row that attends it)."""
        for pk in self.pools:
            self.pools[pk] = _poison_block(self.pools[pk], b)

    def scrub_slot(self, slot: int, rid: int) -> int:
        """Zero every block of ``slot`` that ``rid`` owns exclusively —
        quarantine hygiene: poisoned content must never survive into the
        free list (shared blocks are other owners' clean data and are left
        alone).  Returns the number of blocks scrubbed."""
        n = int(self.n_assigned[slot])
        scrubbed = 0
        for i in range(n):
            b = int(self.table[slot, i])
            if b and self.allocator.owners(b) == (rid,):
                for pk in self.pools:
                    self.pools[pk] = _zero_block(self.pools[pk], b)
                scrubbed += 1
        return scrubbed

    def audit(self, running: Optional[Dict[int, object]] = None) -> None:
        """Run the allocator / prefix-trie / block-table invariants and
        raise a structured :class:`AuditFailure` naming the first violated
        one.  ``running`` is the scheduler's slot→request map; when given,
        table ownership is cross-checked against it."""
        try:
            self.allocator.check_conservation()
        except AssertionError as e:
            raise AuditFailure("allocator_conservation", str(e)) from e
        if self.prefix is not None:
            try:
                self.prefix.check_integrity()
            except AssertionError as e:
                raise AuditFailure("prefix_trie", str(e)) from e
        if running is None:
            return
        for slot in range(self.max_reqs):
            n = int(self.n_assigned[slot])
            req = running.get(slot)
            if req is None:
                if n:
                    raise AuditFailure(
                        "table_ownership",
                        f"idle slot {slot} still holds {n} blocks")
                continue
            for i in range(n):
                b = int(self.table[slot, i])
                if b and req.rid not in self.allocator.owners(b):
                    raise AuditFailure(
                        "table_ownership",
                        f"slot {slot} tables block {b} not owned by "
                        f"rid {req.rid} (owners {self.allocator.owners(b)})")
            if np.any(self.table[slot, n:]):
                raise AuditFailure(
                    "table_ownership",
                    f"slot {slot} has table entries beyond "
                    f"n_assigned={n}")

    # ------------------------------------------------------------- page io
    def page_in(self, slot: int, dense_cache: Dict[str, jax.Array],
                n_tokens: int) -> None:
        """Scatter a prefill's dense cache (leading layer dim, B=1, seq on
        axis 2) into the slot's blocks.  The dense seq length may exceed
        ``n_tokens`` (padded prefill); only the first ``n_tokens`` page in."""
        n = self.blocks_for(n_tokens)
        assert n <= int(self.n_assigned[slot])
        ids = jnp.asarray(self.table[slot, :n], jnp.int32)
        bs = self.block_size
        for dk, pk in (("k", "k_pool"), ("v", "v_pool"), ("ckv", "ckv_pool")):
            if dk not in dense_cache:
                continue
            x = dense_cache[dk][:, 0]                 # (L, T, ...)
            L, T = x.shape[0], x.shape[1]
            pad = n * bs - min(T, n * bs)
            x = x[:, :n * bs]
            if pad:
                x = jnp.pad(x, [(0, 0), (0, pad)] +
                            [(0, 0)] * (x.ndim - 2))
            blocks = x.reshape(L, n, bs, *x.shape[2:])
            self.pools[pk] = _scatter_blocks(self.pools[pk], ids, blocks)

    def gather(self, slot: int, length: int) -> Dict[str, jax.Array]:
        """Contiguous (L, length, ...) view of a slot's cache — test /
        debugging aid (the decode path never materializes this)."""
        n = self.blocks_for(length)
        ids = jnp.asarray(self.table[slot, :n], jnp.int32)
        out = {}
        for pk in self.pools:
            p = self.pools[pk][:, ids]                # (L, n, bs, ...)
            out[pk[:-5]] = p.reshape(p.shape[0], -1,
                                     *p.shape[3:])[:, :length]
        return out


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_blocks(pool, ids, blocks):
    """pool (L, N, bs, ...) — donated, updated in place; ids (n,);
    blocks (L, n, bs, ...)."""
    return pool.at[:, ids].set(blocks.astype(pool.dtype))


@functools.partial(jax.jit, donate_argnums=(0,))
def _copy_block(pool, src, dst):
    """Copy-on-write fork: duplicate one block across all layers in the
    donated pool (L, N, bs, ...)."""
    return pool.at[:, dst].set(pool[:, src])


@functools.partial(jax.jit, donate_argnums=(0,))
def _poison_block(pool, b):
    """Fault injection: fill one block with NaN across all layers."""
    return pool.at[:, b].set(jnp.nan)


@functools.partial(jax.jit, donate_argnums=(0,))
def _zero_block(pool, b):
    """Quarantine scrub: zero one block across all layers."""
    return pool.at[:, b].set(0)
