"""Speculative decoding: draft sources + the acceptance rule.

The engine's speculative step (``Engine(spec=SpecConfig(...))``) replaces
the one-token decode with a *verify* pass: a draft source proposes up to
``depth`` next tokens for each decode-ready request, the target model
scores the pending token plus all proposals in ONE multi-token forward
over the paged cache (``model.verify`` — the chunked-prefill
write-then-attend pattern turned batched), and an accept/reject walk
commits the longest prefix of proposals the target itself would have
sampled, plus one target-sampled token (the "bonus" token when every
proposal is accepted, the correction otherwise).

**Determinism contract.**  The engine samples the token for context
position ``p`` with key ``fold_in(PRNGKey(seed), p)`` — a pure function
of (seed, position, logits at p).  The verify pass computes exactly those
per-position samples for all rows at once; a proposal is *accepted* iff it
equals the target's own sample at its position.  This is Leviathan-style
residual acceptance specialised to deterministic per-position sampling:
the residual distribution after a reject is the point mass at the
target's sample, so the emitted stream is token-identical to the
non-speculative engine **no matter what the draft proposes** — drafts
only change how many tokens commit per step, never which tokens.
Rejected rows roll back by simply not advancing ``Request.cached``: their
KV sits above the valid length in COW-forked, exclusively-owned blocks
(``Scheduler.spec_budget`` reserved them), masked until overwritten — no
allocator state to unwind, no block leaked.

Draft sources:

  * :class:`NGramDraft` — self-speculation via prompt-lookup [arXiv:
    2304.04487-style]: find the longest trailing n-gram of the request's
    context earlier in that same context and propose the tokens that
    followed it.  No second model, no state — a pure function of the
    context, hence trivially batch- and preemption-invariant.
  * :class:`ModelDraft` — a paired smaller model from the config zoo
    (e.g. ``smollm-360m`` drafting for ``llama-7b``; see
    configs/spec_pairs.py) with its *own* paged cache and block tables,
    caught up incrementally and stepped greedily ``depth`` tokens ahead.
    Draft-pool exhaustion degrades to proposing nothing — the draft can
    never preempt or stall the target.
  * :class:`NullDraft` — proposes nothing; with ``depth=0`` the verify
    pass is a single-node tree that collapses bitwise to vanilla decode
    (the degenerate-tree equivalence test).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.cache import PagedKVCache, PoolExhausted

_MODES = ("none", "ngram", "model")


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative-decoding knobs.

    ``depth``: max draft tokens verified per step (the tree depth; 0
    disables drafting but keeps the verify path — useful for the
    degenerate-equivalence test).  ``mode``: ``"ngram"`` (self-
    speculation), ``"model"`` (paired draft model — pass the engine a
    :class:`ModelDraft`), or ``"none"`` (NullDraft).  ``ngram``: longest
    n-gram length the prompt-lookup matcher tries.

    ``adaptive`` turns on the acceptance-aware depth controller
    (:class:`AdaptiveDepth`): each request's draft budget shrinks from
    ``depth`` toward ``min_depth`` as its own recent acceptance rate
    (sliding window of ``adapt_window`` verify steps) drops — drafting
    deep into a context the draft keeps getting wrong just burns verify
    FLOPs.  ``adapt_floor`` is the minimum expected acceptance
    probability a draft position must have to be worth proposing.  The
    verify jit shape stays ``1 + depth`` (the cap) — adaptivity only
    shortens proposal lists, never changes compiled shapes."""
    depth: int = 4
    mode: str = "ngram"
    ngram: int = 3
    draft_arch: Optional[str] = None   # bookkeeping: which zoo config
    adaptive: bool = False
    adapt_window: int = 8
    adapt_floor: float = 0.25
    min_depth: int = 1

    def __post_init__(self):
        if self.depth < 0:
            raise ValueError("depth must be >= 0")
        if self.mode not in _MODES:
            raise ValueError(f"mode must be one of {_MODES}")
        if self.ngram < 1:
            raise ValueError("ngram must be >= 1")
        if self.adapt_window < 1:
            raise ValueError("adapt_window must be >= 1")
        if not 0.0 < self.adapt_floor < 1.0:
            raise ValueError("adapt_floor must be in (0, 1)")
        if not 0 <= self.min_depth <= max(self.depth, 1):
            raise ValueError("min_depth must be in [0, depth]")


class AdaptiveDepth:
    """Acceptance-aware per-request draft budget.

    Keeps, per request id, a sliding window of its last
    ``adapt_window`` verify outcomes ``(n_accepted, n_proposed)`` and
    turns the windowed acceptance rate ``a`` into a depth: under the
    standard independence approximation the i-th draft position commits
    with probability ``a^i``, so positions past
    ``d* = floor(log(adapt_floor) / log(a))`` are more likely wasted
    than useful.  The result is clamped to ``[min_depth, depth]`` and a
    request with no history yet gets the full cap (optimistic start —
    the ceiling-acceptance regimes behave exactly as non-adaptive).

    Determinism: the depth is a pure function of the request's OWN
    acceptance history — never batch composition — so adaptivity
    preserves the engine's batch/preemption-invariant token streams
    (which tokens commit is decided by the verify walk regardless)."""

    def __init__(self, spec: "SpecConfig"):
        from collections import deque
        self.cap = spec.depth
        self.min_depth = min(spec.min_depth, spec.depth)
        self.window = spec.adapt_window
        self.floor = spec.adapt_floor
        self._deque = deque
        self._hist: Dict[int, object] = {}

    def depth_for(self, rid: int) -> int:
        h = self._hist.get(rid)
        if not h:
            return self.cap
        prop = sum(p for _, p in h)
        acc = sum(a for a, _ in h)
        if prop <= 0 or acc >= prop:
            return self.cap
        if acc <= 0:
            return self.min_depth
        rate = acc / prop
        d = int(math.log(self.floor) / math.log(rate))
        return max(self.min_depth, min(self.cap, d))

    def observe(self, rid: int, n_acc: int, proposed: int) -> None:
        if proposed <= 0:
            return                      # nothing proposed — no signal
        self._hist.setdefault(
            rid, self._deque(maxlen=self.window)).append((n_acc, proposed))

    def release(self, rid: int) -> None:
        self._hist.pop(rid, None)


class DraftSource:
    """Interface the engine drives each speculative step."""

    def propose(self, req, k: int) -> List[int]:
        """Up to ``k`` draft tokens continuing ``req.context``.  Must be a
        deterministic function of the request's own state — never of
        batch composition — or target-stream invariance still holds but
        tokens/step becomes run-dependent."""
        raise NotImplementedError

    def observe(self, req, n_acc: int, proposed: int) -> None:
        """Commit hook: ``n_acc`` of ``proposed`` drafts were accepted
        (the target also committed one more sampled token)."""

    def release(self, rid: int) -> None:
        """The request reached a terminal state — drop any draft state."""


class NullDraft(DraftSource):
    def propose(self, req, k: int) -> List[int]:
        return []


class NGramDraft(DraftSource):
    """Prompt-lookup self-speculation: propose the continuation of the
    most recent earlier occurrence of the context's longest trailing
    n-gram.  Stateless — proposals depend only on ``req.context``."""

    def __init__(self, ngram: int = 3):
        if ngram < 1:
            raise ValueError("ngram must be >= 1")
        self.ngram = int(ngram)

    def propose(self, req, k: int) -> List[int]:
        if k <= 0:
            return []
        ctx = np.asarray(req.context)
        L = len(ctx)
        for n in range(min(self.ngram, L - 1), 0, -1):
            tail = ctx[L - n:]
            # rightmost earlier occurrence → the freshest continuation
            for s in range(L - n - 1, -1, -1):
                if np.array_equal(ctx[s:s + n], tail):
                    cont = ctx[s + n:s + n + k]
                    if len(cont):
                        return [int(t) for t in cont]
                    break          # match flush against the tail: no cont
        return []


class ModelDraft(DraftSource):
    """Paired-draft-model source: its own paged cache + block tables,
    caught up to each request's context with ``prefill_chunk`` and rolled
    ``k`` tokens ahead with greedy ``decode`` steps (B=1 per request —
    proposals are a pure function of the request's context).

    Bookkeeping mirrors the target's rollback-free design: ``_dlen[rid]``
    counts draft-cache positions that hold the *committed* context's KV;
    rejected draft KV above it is overwritten by later writes and masked
    until then.  Any pool exhaustion degrades to proposing nothing for
    that request (its draft state is dropped) — the draft never preempts
    the target."""

    def __init__(self, model, params, *, block_size: int = 16,
                 n_blocks: int = 128, max_batch: int = 8):
        cfg = model.cfg
        if cfg.arch_type not in ("dense", "vlm", "moe"):
            raise ValueError(f"draft model must have a paged decode path "
                             f"(got arch_type={cfg.arch_type!r})")
        self.model = model
        self.params = params
        self.cache = PagedKVCache.create(
            cfg, block_size=block_size, n_blocks=n_blocks,
            max_reqs=max_batch, prefix_cache=False)
        self.max_batch = int(max_batch)
        self._slots: Dict[int, int] = {}           # rid -> draft slot
        self._dlen: Dict[int, int] = {}            # rid -> cached positions
        self._chunk = jax.jit(self._chunk_fn, donate_argnums=(1,))
        self._decode = jax.jit(self._decode_fn, donate_argnums=(1,))

    # ------------------------------------------------------- jitted steps
    def _chunk_fn(self, params, pools, bt, start, n_valid, tokens):
        out = self.model.prefill_chunk(
            params, {**pools, "block_table": bt},
            {"tokens": tokens, "start": start, "n_valid": n_valid})
        return {k: out[k] for k in pools}

    def _decode_fn(self, params, pools, bt, pos, tok):
        logits, cache2 = self.model.decode(
            params, {**pools, "block_table": bt},
            {"token": tok, "pos": pos})
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt, {k: cache2[k] for k in pools}

    # --------------------------------------------------------- lifecycle
    def _drop(self, rid: int) -> None:
        slot = self._slots.pop(rid, None)
        self._dlen.pop(rid, None)
        if slot is not None:
            self.cache.release(slot, rid)

    def release(self, rid: int) -> None:
        self._drop(rid)

    def _ensure_slot(self, req, k: int) -> Optional[int]:
        rid = req.rid
        if rid in self._slots:
            return self._slots[rid]
        used = set(self._slots.values())
        slot = next((s for s in range(self.max_batch) if s not in used),
                    None)
        if slot is None:
            return None
        total = len(req.prompt) + req.params.max_new_tokens + k + 1
        try:
            self.cache.assign(slot, rid, total)
        except PoolExhausted:
            return None
        self._slots[rid] = slot
        self._dlen[rid] = 0
        return slot

    # ----------------------------------------------------------- propose
    _PAD = 32                                     # chunk shape bucket

    def propose(self, req, k: int) -> List[int]:
        if k <= 0:
            return []
        slot = self._ensure_slot(req, k)
        if slot is None:
            return []
        rid = req.rid
        ctx = np.asarray(req.context)
        L = len(ctx)
        bt = jnp.asarray(self.cache.table[slot:slot + 1])
        try:
            # catch up: prefill context[dlen : L-1] (pending token's KV is
            # written by the first decode step, as in the target engine)
            start = self._dlen[rid]
            while start < L - 1:
                n = min(L - 1 - start, self._PAD)
                toks = np.zeros((self._PAD,), np.int32)
                toks[:n] = ctx[start:start + n]
                self.cache.pools = self._chunk(
                    self.params, self.cache.pools, bt, jnp.int32(start),
                    jnp.int32(n), jnp.asarray(toks)[None])
                start += n
            # roll k greedy steps ahead
            out: List[int] = []
            tok = int(ctx[-1])
            for i in range(k):
                nxt, self.cache.pools = self._decode(
                    self.params, self.cache.pools, bt,
                    jnp.full((1,), L - 1 + i, jnp.int32),
                    jnp.full((1, 1), tok, jnp.int32))
                tok = int(nxt[0])
                out.append(tok)
            # positions [0, L) now hold committed-context KV (the decode
            # roll wrote the pending token at L-1); draft KV above L is
            # provisional — observe() extends validity over accepted drafts
            self._dlen[rid] = L
            return out
        except PoolExhausted:
            self._drop(rid)
            return []

    def observe(self, req, n_acc: int, proposed: int) -> None:
        rid = req.rid
        if proposed == 0 or rid not in self._slots:
            return            # no roll happened: draft cache is unchanged
        # accepted drafts ARE the committed tokens, so their draft-cache
        # KV (written during propose's roll) is valid context KV now; the
        # one extra target-sampled token is the new pending token, whose
        # KV the next roll writes — hence exactly len(context) - 1
        self._dlen[rid] = len(req.context) - 1


def make_draft(spec: SpecConfig) -> DraftSource:
    """Engine-side factory for the stateless modes; ``"model"`` drafts
    need params, so the caller constructs :class:`ModelDraft` itself."""
    if spec.mode == "ngram":
        return NGramDraft(spec.ngram)
    if spec.mode == "none":
        return NullDraft()
    raise ValueError('mode="model" needs an explicit ModelDraft '
                     '(draft params are caller-owned)')
