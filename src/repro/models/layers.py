"""Shared transformer building blocks (pure-JAX, no flax).

Parameters are plain dicts of jnp arrays. Every attention block is split
into the three stages consumed by the rematerialization-aware checkpointing
combinator (core/remat.py): ``pre_attn`` → ``attn`` → ``post_attn``.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.config import AttnConfig, ModelConfig


# ------------------------------------------------------------------ init

def dense_init(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab, d, dtype):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ------------------------------------------------------------------ norms

def rms_norm(x, w, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def head_rms_norm(x, w, eps=1e-5):
    """Qwen3 qk-norm: RMSNorm over the head dim of (B,T,H,D)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


# ------------------------------------------------------------------ rope

def rope_tables(positions, dim, theta=10_000.0):
    """cos/sin tables: positions (T,) -> (T, dim/2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2).astype(jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[:, None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B,T,H,D); cos/sin: (T, D/2) shared tables, or (B, T, D/2)
    per-request tables (decode with per-request positions).
    Rotates pairs (x[2i], x[2i+1])."""
    xf = x.astype(jnp.float32)
    x1 = xf[..., 0::2]
    x2 = xf[..., 1::2]
    if cos.ndim == 3:                    # (B, T, D/2) per-request positions
        c = cos[:, :, None, :]
        s = sin[:, :, None, :]
    else:
        c = cos[None, :, None, :]
        s = sin[None, :, None, :]
    r1 = x1 * c - x2 * s
    r2 = x2 * c + x1 * s
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ------------------------------------------------------- dense attention

def attn_params(key, cfg: ModelConfig, dtype):
    """GQA attention projections (optionally biased / qk-normed)."""
    a = cfg.attn
    d, hd = cfg.d_model, a.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, d, a.n_heads * hd, dtype),
        "wk": dense_init(k2, d, a.n_kv_heads * hd, dtype),
        "wv": dense_init(k3, d, a.n_kv_heads * hd, dtype),
        "wo": dense_init(k4, a.n_heads * hd, d, dtype),
        "ln": jnp.ones((d,), dtype),
    }
    if a.qkv_bias:
        p["bq"] = jnp.zeros((a.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((a.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((a.n_kv_heads * hd,), dtype)
    if a.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def attn_qkv(p, x, cfg: ModelConfig, cos, sin):
    """pre_attn stage: norm → qkv proj → qk-norm → rope. x: (B,T,d)."""
    a = cfg.attn
    B, T, _ = x.shape
    hd = a.head_dim
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q = h @ p["wq"]
    k = h @ p["wk"]
    v = h @ p["wv"]
    if a.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, T, a.n_heads, hd)
    k = k.reshape(B, T, a.n_kv_heads, hd)
    v = v.reshape(B, T, a.n_kv_heads, hd)
    if a.qk_norm:
        q = head_rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = head_rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def attn_out(p, x, o, cfg: ModelConfig):
    """post_attn residual add. o: (B,T,H,hd)."""
    B, T = x.shape[:2]
    return x + (o.reshape(B, T, -1) @ p["wo"]).astype(x.dtype)


# ---------------------------------------------------------- MLA attention

def mla_params(key, cfg: ModelConfig, dtype):
    """DeepSeek multi-head latent attention [arXiv:2405.04434]."""
    a = cfg.attn
    d = cfg.d_model
    nh, dn, dr = a.n_heads, a.qk_nope_head_dim, a.qk_rope_head_dim
    dv = a.v_head_dim or a.head_dim
    ks = jax.random.split(key, 8)
    p = {"ln": jnp.ones((d,), dtype)}
    if a.q_lora_rank:
        p["wq_a"] = dense_init(ks[0], d, a.q_lora_rank, dtype)
        p["q_ln"] = jnp.ones((a.q_lora_rank,), dtype)
        p["wq_b"] = dense_init(ks[1], a.q_lora_rank, nh * (dn + dr), dtype)
    else:
        p["wq"] = dense_init(ks[0], d, nh * (dn + dr), dtype)
    p["wkv_a"] = dense_init(ks[2], d, a.kv_lora_rank + dr, dtype)
    p["kv_ln"] = jnp.ones((a.kv_lora_rank,), dtype)
    p["wkv_b"] = dense_init(ks[3], a.kv_lora_rank, nh * (dn + dv), dtype)
    p["wo"] = dense_init(ks[4], nh * dv, d, dtype)
    return p


def mla_qkv(p, x, cfg: ModelConfig, cos, sin, return_latent=False):
    """MLA pre_attn: produces per-head K/V materialized from the latent
    (flash-compatible path; the latent-ring comm optimization ships the
    compressed kv instead — see core/dist_attention latent variant).
    ``return_latent`` additionally yields the (c_kv ⊕ roped k_pe) latent
    used as the decode-time cache entry."""
    a = cfg.attn
    B, T, _ = x.shape
    nh, dn, dr = a.n_heads, a.qk_nope_head_dim, a.qk_rope_head_dim
    dv = a.v_head_dim or a.head_dim
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    if a.q_lora_rank:
        qc = rms_norm(h @ p["wq_a"], p["q_ln"], cfg.norm_eps)
        q = (qc @ p["wq_b"]).reshape(B, T, nh, dn + dr)
    else:
        q = (h @ p["wq"]).reshape(B, T, nh, dn + dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    kv_a = h @ p["wkv_a"]
    c_kv = rms_norm(kv_a[..., :a.kv_lora_rank], p["kv_ln"], cfg.norm_eps)
    k_pe = kv_a[..., a.kv_lora_rank:].reshape(B, T, 1, dr)
    q_pe = apply_rope(q_pe, cos, sin)
    k_pe = apply_rope(k_pe, cos, sin)
    kv = (c_kv @ p["wkv_b"]).reshape(B, T, nh, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    k_pe_b = jnp.broadcast_to(k_pe, (B, T, nh, dr))
    q_full = jnp.concatenate([q_nope, q_pe], axis=-1)
    k_full = jnp.concatenate([k_nope, k_pe_b], axis=-1)
    if return_latent:
        latent = jnp.concatenate([c_kv, k_pe[:, :, 0, :]], axis=-1)
        return q_full, k_full, v, latent
    return q_full, k_full, v            # head dims: qk = dn+dr, v = dv


def mla_scale(cfg: ModelConfig) -> float:
    a = cfg.attn
    return 1.0 / math.sqrt(a.qk_nope_head_dim + a.qk_rope_head_dim)


# ------------------------------------------------------------------ MLP

def mlp_params(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wg": dense_init(k1, d_model, d_ff, dtype),
        "wu": dense_init(k2, d_model, d_ff, dtype),
        "wd": dense_init(k3, d_ff, d_model, dtype),
        "ln": jnp.ones((d_model,), dtype),
    }


def mlp_apply(p, x, eps=1e-5):
    h = rms_norm(x, p["ln"], eps)
    return x + ((jax.nn.silu(h @ p["wg"]) * (h @ p["wu"])) @ p["wd"]).astype(x.dtype)


# --------------------------------------------------------------- softmax-CE

def cross_entropy(logits, labels, mask=None):
    """Mean token cross-entropy in f32. labels == -100 are ignored."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(
        lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - ll
    valid = labels >= 0
    if mask is not None:
        valid = valid & mask
    w = valid.astype(jnp.float32)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


def mla_expand(latent, w_up, cfg: ModelConfig):
    """Up-project the MLA latent (c_kv ⊕ roped k_pe) into per-head K/V —
    the receive-side of the latent ring (core/dist_attention)."""
    a = cfg.attn
    B, T, _ = latent.shape
    nh, dn, dr = a.n_heads, a.qk_nope_head_dim, a.qk_rope_head_dim
    dv = a.v_head_dim or a.head_dim
    c_kv = latent[..., :a.kv_lora_rank]
    k_pe = latent[..., a.kv_lora_rank:]
    kv = (c_kv @ w_up).reshape(B, T, nh, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    k_pe_b = jnp.broadcast_to(k_pe[:, :, None, :], (B, T, nh, dr))
    return jnp.concatenate([k_nope, k_pe_b], axis=-1), v
