"""Model assembly: decoder LMs (dense / GQA / MLA / MoE / SSM / hybrid /
VLM), Whisper-style encoder–decoder, and DeepSeek MTP — all built on
DISTFLASHATTN sequence parallelism with the rematerialization-aware
checkpointing combinator.

Every architecture exposes the same surface:
  * ``init(rng) -> params``
  * ``loss(params, batch) -> (scalar, metrics)``       (training forward)
  * ``prefill(params, batch) -> (last_logits, cache)`` (inference prefill)
  * ``decode(params, cache, batch) -> (logits, cache)``(one-token decode)

Layers are stacked and scanned (``lax.scan``) so the HLO stays compact for
the 61-layer/671B dry-runs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import mask as mk
from repro.core.config import ModelConfig, ParallelConfig
from repro.core.dist_attention import (DistAttnSpec, Mesh2DSpec,
                                       dist_attn_bwd, dist_attn_fwd,
                                       dist_decode_attn, dist_flash_attn)
from repro.core.mask import MaskSpec
from repro.core.remat import remat_aware
from repro.core.attention import chunk_attn, paged_decode_attn
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.parallel.sharding import act_spec, constrain, mesh_axis_size



# Scan-unroll switch: the dry-run's cost-measurement compiles flip this so
# XLA's cost_analysis sees every layer (a while-loop body is only counted
# once). Production lowering keeps rolled scans (compact HLO).
_SCAN_UNROLL = [False]


def set_scan_unroll(v: bool) -> None:
    _SCAN_UNROLL[0] = bool(v)


def xscan(f, init, xs):
    return lax.scan(f, init, xs, unroll=True if _SCAN_UNROLL[0] else 1)


@dataclass(frozen=True)
class Runtime:
    mesh: Mesh
    par: ParallelConfig
    impl: Optional[str] = None          # attention backend override
    latent_ring: bool = False           # MLA: ship the latent, not K/V

    @property
    def seq_size(self) -> int:
        """Total sequence-parallel workers P — the (seq × head) product
        on a factored 2D mesh."""
        return mesh_axis_size(self.mesh, self.par.seq_axis) \
            * self.head_size

    @property
    def head_size(self) -> int:
        """Size u of the head sub-axis (1 without a 2D mesh)."""
        if self.par.head_axis is None:
            return 1
        return mesh_axis_size(self.mesh, self.par.head_axis)


def _zigzag_ok(cfg: ModelConfig) -> bool:
    """Zigzag relayout is valid only for purely positionwise decoders:
    no cross-position ops outside attention (SSM scan/conv, MTP roll) and
    no windowed masks (window masks assume contiguous shard positions)."""
    return (cfg.arch_type in ("dense", "vlm", "moe")
            and not cfg.mtp_depth
            and cfg.attn is not None and not cfg.attn.window)


def _attn_spec(cfg: ModelConfig, rt: Runtime, *, causal=True, window=None,
               scale=None, document=False) -> DistAttnSpec:
    w = cfg.attn.window if window is None else window
    sched = rt.par.schedule
    if sched == "zigzag" and not _zigzag_ok(cfg):
        sched = "balanced"                      # graceful fallback
    mask = MaskSpec(causal=causal, window=int(w or 0), document=document)
    mesh2d = None
    if rt.head_size > 1:
        # factored 2D mesh: ring-family plans on the seq sub-axis after
        # the head scatter; baselines don't exist on the axis pair
        mesh2d = Mesh2DSpec(
            r=rt.seq_size // rt.head_size, u=rt.head_size,
            seq_axis=rt.par.seq_axis, head_axis=rt.par.head_axis)
        if sched not in ("auto", "ring", "balanced", "zigzag"):
            sched = "balanced" if (causal and mesh2d.r > 1) else "ring"
        if mesh2d.r > 1 and not causal and sched != "ring":
            sched = "ring"                       # bidirectional encoders
    elif sched != "auto":                        # auto defers to the plans
        if not causal and sched not in ("ulysses", "rsa"):
            # bidirectional encoders; a non-causal *window* has future-
            # direction bands only absolute-position schedules can see
            sched = "ulysses" if w else "ring"
        elif causal and w and sched not in ("balanced", "ring", "ulysses"):
            sched = "balanced"                   # windowed plans truncate
    return DistAttnSpec(
        axis=rt.par.seq_axis, axis_size=rt.seq_size, schedule=sched,
        mask=mask, scale=scale, impl=rt.impl, mesh2d=mesh2d)


def _decode_mask(window) -> MaskSpec:
    """Decode-time mask: the new token is last, so the only kinds are the
    whole cache (causal) or a sliding window."""
    return mk.sliding_window(int(window)) if window else mk.causal()


def _norm_pos(pos, B):
    """Per-request decode positions: (B,) int32.  A scalar (the pre-paged
    shared position — it silently mis-masks mixed-length batches once
    requests are admitted at different times) broadcasts with a one-shot
    DeprecationWarning."""
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        mk.warn_legacy_once('decode(batch={"pos": <scalar>})',
                            'a (B,) per-request position vector')
        pos = jnp.broadcast_to(pos, (B,))
    return pos.astype(jnp.int32)


def _decode_rope(pos, dim, theta):
    """Per-request rope tables for the decode token: (B, 1, dim/2)."""
    cos, sin = L.rope_tables(pos, dim, theta)
    return cos[:, None], sin[:, None]


def _is_paged(cache) -> bool:
    return isinstance(cache, dict) and "block_table" in cache


# ==========================================================================
# Layer builders (stage functions feed the remat-aware combinator)
# ==========================================================================

def _dense_stages(cfg, rt, is_mla, document=False):
    """Stage functions take x = (h, cos, sin, seg): custom_vjp functions
    must not close over traced values, so the rope tables — and the packed-
    sequence segment IDs (``seg``; None when the batch is unpacked) —
    travel in the input pytree."""
    spec = _attn_spec(cfg, rt, scale=L.mla_scale(cfg) if is_mla else None,
                      document=document)
    batch_axes = rt.par.batch_axes

    def pre(p, x):
        h, cos, sin, seg = x
        if is_mla:
            return L.mla_qkv(p["attn"], h, cfg, cos, sin) + (seg,)
        return L.attn_qkv(p["attn"], h, cfg, cos, sin) + (seg,)

    def attn_fwd(qkv):
        q, k, v, seg = qkv
        return dist_attn_fwd(q, k, v, mesh=rt.mesh, spec=spec,
                             batch_axes=batch_axes, segments=seg)

    def attn_bwd(qkv, o, lse, do):
        q, k, v, seg = qkv
        dq, dk, dv = dist_attn_bwd(q, k, v, o, lse, do, mesh=rt.mesh,
                                   spec=spec, batch_axes=batch_axes,
                                   segments=seg)
        dseg = None if seg is None else np.zeros(seg.shape,
                                                 jax.dtypes.float0)
        return dq, dk, dv, dseg

    def attn_diff(qkv):
        q, k, v, seg = qkv
        return dist_flash_attn(q, k, v, rt.mesh, spec, batch_axes, seg)

    return pre, attn_fwd, attn_bwd, attn_diff


def build_dense_layer(cfg, rt, *, is_mla=False, use_moe=False,
                      d_ff=None, document=False):
    """layer(params, (h, cos, sin, seg)) -> (h', aux)."""
    pre, attn_fwd, attn_bwd, attn_diff = _dense_stages(cfg, rt, is_mla,
                                                       document)

    def post(p, x, o):
        h = x[0]
        h2 = L.attn_out(p["attn"], h, o, cfg)
        h2 = constrain(h2, rt.mesh, act_spec(rt.par))
        if use_moe:
            h3, aux = M.moe_apply(p["moe"], h2, cfg, mesh=rt.mesh,
                                  seq_axis=rt.par.seq_axis,
                                  batch_axes=rt.par.batch_axes)
        else:
            h3, aux = L.mlp_apply(p["mlp"], h2, cfg.norm_eps), jnp.float32(0)
        h3 = constrain(h3, rt.mesh, act_spec(rt.par))
        return (h3, aux)

    if rt.par.remat == "remat_aware":
        return remat_aware(pre, attn_fwd, attn_bwd, post)

    def plain(p, x):
        o, _ = attn_diff(pre(p, x))
        return post(p, x, o)

    if rt.par.remat == "hf":
        return jax.checkpoint(plain)
    return plain


def dense_layer_params(key, cfg, dtype, *, is_mla=False, use_moe=False,
                       d_ff=None):
    k1, k2 = jax.random.split(key)
    p = {"attn": (L.mla_params(k1, cfg, dtype) if is_mla
                  else L.attn_params(k1, cfg, dtype))}
    if use_moe:
        p["moe"] = M.moe_params(k2, cfg, dtype)
    else:
        p["mlp"] = L.mlp_params(k2, cfg.d_model, d_ff or cfg.d_ff, dtype)
    return p


def _stack(key, n, make):
    return compat.tree_map(lambda *xs: jnp.stack(xs),
                        *[make(k) for k in jax.random.split(key, max(n, 1))])


def _scan_layers(layer_fn, h, stacked, rt, cos=None, sin=None, seg=None):
    def body(carry, lp):
        h, aux = carry
        h2, aux2 = layer_fn(lp, (h, cos, sin, seg))
        return (h2, aux + aux2), None
    (h, aux), _ = xscan(body, (h, jnp.float32(0)), stacked)
    return h, aux


# ==========================================================================
# DecoderLM — dense / moe / ssm / hybrid / vlm
# ==========================================================================

class DecoderLM:
    def __init__(self, cfg: ModelConfig, rt: Runtime):
        self.cfg = cfg
        self.rt = rt
        self.dtype = jnp.dtype(cfg.dtype)

    # ------------------------------------------------------------- init
    def init(self, rng):
        cfg, dt = self.cfg, self.dtype
        ks = jax.random.split(rng, 8)
        p = {"embed": L.embed_init(ks[0], cfg.vocab, cfg.d_model, dt),
             "ln_f": jnp.ones((cfg.d_model,), dt)}
        if not cfg.tie_embeddings:
            p["head"] = L.dense_init(ks[7], cfg.d_model, cfg.vocab, dt)
        at = cfg.arch_type
        if at in ("dense", "vlm"):
            p["layers"] = _stack(ks[1], cfg.n_layers, lambda k:
                                 dense_layer_params(k, cfg, dt))
        elif at == "moe":
            is_mla = cfg.attn.is_mla
            nd = cfg.moe.n_dense_layers
            p["dense_layers"] = _stack(ks[1], nd, lambda k:
                                       dense_layer_params(
                                           k, cfg, dt, is_mla=is_mla,
                                           d_ff=cfg.moe.d_dense_ff))
            p["moe_layers"] = _stack(ks[2], cfg.n_layers - nd, lambda k:
                                     dense_layer_params(k, cfg, dt,
                                                        is_mla=is_mla,
                                                        use_moe=True))
            if cfg.mtp_depth:
                p["mtp"] = {
                    "proj": L.dense_init(ks[3], 2 * cfg.d_model,
                                         cfg.d_model, dt),
                    "ln_h": jnp.ones((cfg.d_model,), dt),
                    "ln_e": jnp.ones((cfg.d_model,), dt),
                    "layer": dense_layer_params(ks[4], cfg, dt,
                                                is_mla=is_mla, use_moe=True),
                    "ln_f": jnp.ones((cfg.d_model,), dt),
                }
        elif at == "ssm":
            p["layers"] = _stack(ks[1], cfg.n_layers,
                                 lambda k: {"ssm": S.ssm_params(k, cfg, dt)})
        elif at == "hybrid":
            p["layers"] = _stack(ks[1], cfg.n_layers,
                                 lambda k: {"ssm": S.ssm_params(k, cfg, dt)})
            p["shared"] = self._shared_block_params(ks[2])
        else:
            raise ValueError(at)
        return p

    def _shared_cfg(self):
        """Zamba2 shared attention block operates on concat(h, emb) = 2d
        [arXiv:2411.15242]. The config's attn.head_dim must already satisfy
        n_heads · head_dim == 2·d_model (see configs/zamba2_2_7b.py)."""
        cfg = self.cfg
        assert cfg.attn.n_heads * cfg.attn.head_dim == 2 * cfg.d_model
        return cfg.replace(d_model=2 * cfg.d_model, arch_type="dense")

    def _shared_block_params(self, key):
        scfg = self._shared_cfg()
        k1, k2 = jax.random.split(key)
        p = dense_layer_params(k1, scfg, self.dtype)
        p["down"] = L.dense_init(k2, scfg.d_model, self.cfg.d_model,
                                 self.dtype)
        return p

    # ------------------------------------------------------- embeddings
    def _embed(self, p, batch):
        cfg, rt = self.cfg, self.rt
        toks = batch["tokens"]
        h = p["embed"][toks].astype(self.dtype)
        if cfg.arch_type == "vlm":
            img = batch["image_embeds"].astype(self.dtype)
            h = jnp.concatenate([img, h], axis=1)
        h = constrain(h, rt.mesh, act_spec(rt.par))
        return h

    def _head(self, p, h):
        cfg = self.cfg
        h = L.rms_norm(h, p["ln_f"], cfg.norm_eps)
        w = p["embed"].T if cfg.tie_embeddings else p["head"]
        return h @ w.astype(h.dtype)

    # ------------------------------------------------------------ train
    def _backbone(self, p, h, cos, sin, seg=None):
        """Shared trunk: returns (h, aux). ``seg`` = packed-sequence
        document IDs (B, T) or None."""
        cfg, rt = self.cfg, self.rt
        at = cfg.arch_type
        doc = seg is not None
        if at in ("dense", "vlm"):
            layer = build_dense_layer(cfg, rt, document=doc)
            return _scan_layers(layer, h, p["layers"], rt, cos, sin, seg)
        if at == "moe":
            is_mla = cfg.attn.is_mla
            dl = build_dense_layer(cfg, rt, is_mla=is_mla,
                                   d_ff=cfg.moe.d_dense_ff, document=doc)
            ml = build_dense_layer(cfg, rt, is_mla=is_mla, use_moe=True,
                                   document=doc)
            h, a1 = _scan_layers(dl, h, p["dense_layers"], rt, cos, sin, seg)
            h, a2 = _scan_layers(ml, h, p["moe_layers"], rt, cos, sin, seg)
            return h, a1 + a2
        if at == "ssm":
            layer = self._ssm_layer()
            def body(carry, lp):
                return layer(lp, carry), None
            h, _ = xscan(body, h, p["layers"])
            return h, jnp.float32(0)
        if at == "hybrid":
            return self._hybrid_backbone(p, h, cos, sin)
        raise ValueError(at)

    def _ssm_layer(self):
        cfg, rt = self.cfg, self.rt
        def layer(lp, h):
            y = S.ssm_apply(lp["ssm"], h, cfg, mesh=rt.mesh,
                            seq_axis=rt.par.seq_axis,
                            batch_axes=rt.par.batch_axes)
            return constrain(y, rt.mesh, act_spec(rt.par))
        if rt.par.remat in ("hf", "remat_aware"):
            # remat-aware boundary shift is attention-specific (§3.3); SSD
            # layers use layer-boundary checkpointing (DESIGN.md §5)
            return jax.checkpoint(layer)
        return layer

    def _shared_block(self, p, h, emb0, cos, sin):
        """Zamba2 shared attention+MLP on concat(h, emb)."""
        cfg, rt = self.cfg, self.rt
        scfg = self._shared_cfg()
        layer = build_dense_layer(scfg, rt)
        x2 = jnp.concatenate([h, emb0], axis=-1)
        y2, _ = layer(p, (x2, cos, sin, None))
        return h + (y2 @ p["down"]).astype(h.dtype)

    def _hybrid_backbone(self, p, h, cos, sin):
        cfg, rt = self.cfg, self.rt
        period = cfg.hybrid_period
        G = cfg.n_layers // period
        stacked = compat.tree_map(
            lambda a: a.reshape(G, period, *a.shape[1:]), p["layers"])
        ssm_layer = self._ssm_layer()
        emb0 = h

        def group(carry, gp):
            hh = carry
            def inner(c, lp):
                return ssm_layer(lp, c), None
            hh, _ = xscan(inner, hh, gp)
            hh = self._shared_block(p["shared"], hh, emb0, cos, sin)
            return hh, None
        h, _ = xscan(group, h, stacked)
        return h, jnp.float32(0)

    def loss(self, p, batch):
        cfg, rt = self.cfg, self.rt
        h = self._embed(p, batch)
        T = h.shape[1]
        cos, sin = (None, None)
        if cfg.uses_attention:
            pos = jnp.arange(T)
            dim = (cfg.attn.qk_rope_head_dim if cfg.attn.is_mla
                   else cfg.attn.head_dim)
            cos, sin = L.rope_tables(pos, dim, cfg.attn.rope_theta)
        labels = batch["labels"]
        seg = batch.get("segment_ids")      # packed-sequence document IDs
        if seg is not None:
            if cfg.arch_type not in ("dense", "moe"):
                raise ValueError(
                    f"packed (segment_ids) training is supported for "
                    f"dense/moe decoders, not {cfg.arch_type!r}")
            if cfg.mtp_depth:
                raise ValueError("packed training does not compose with "
                                 "MTP (the t+2 roll crosses documents)")
        if cfg.arch_type == "vlm":      # image positions carry no loss
            pad = jnp.full(batch["image_embeds"].shape[:2], -100,
                           labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        if rt.par.schedule == "zigzag" and _zigzag_ok(cfg) \
                and rt.seq_size > 1:
            # zigzag relayout (beyond-paper, see core/dist_attention.py):
            # one global gather after the embedding; rope tables, labels
            # and segment IDs follow. Loss is positionwise so no inverse
            # permutation needed.
            from repro.core.dist_attention import zigzag_perm
            perm = zigzag_perm(T, rt.seq_size)
            h = h[:, perm]
            labels = labels[:, perm]
            cos, sin = cos[perm], sin[perm]
            if seg is not None:
                seg = seg[:, perm]
            h = constrain(h, rt.mesh, act_spec(rt.par))
        h, aux = self._backbone(p, h, cos, sin, seg)
        logits = self._head(p, h)
        ce = L.cross_entropy(logits, labels)
        total = ce + aux
        metrics = {"ce": ce, "aux": aux}
        if cfg.mtp_depth and "mtp" in p:
            mtp_ce = self._mtp_loss(p, h, batch, cos, sin)
            total = total + 0.3 * mtp_ce
            metrics["mtp_ce"] = mtp_ce
        return total, metrics

    def _mtp_loss(self, p, h, batch, cos, sin):
        """DeepSeek-V3 multi-token prediction [arXiv:2412.19437]: one extra
        transformer block predicts token t+2 from (h_t, emb_{t+1})."""
        cfg, rt = self.cfg, self.rt
        mp = p["mtp"]
        toks = batch["tokens"]
        emb = p["embed"][toks].astype(self.dtype)
        emb_next = jnp.roll(emb, -1, axis=1)         # emb_{t+1} (last invalid)
        hcat = jnp.concatenate([
            L.rms_norm(h, mp["ln_h"], cfg.norm_eps),
            L.rms_norm(emb_next, mp["ln_e"], cfg.norm_eps)], axis=-1)
        h2 = (hcat @ mp["proj"]).astype(self.dtype)
        h2 = constrain(h2, rt.mesh, act_spec(rt.par))
        layer = build_dense_layer(cfg, rt, is_mla=cfg.attn.is_mla,
                                  use_moe=True)
        h2, _aux = layer(mp["layer"], (h2, cos, sin, None))
        h2 = L.rms_norm(h2, mp["ln_f"], cfg.norm_eps)
        logits = h2 @ p["embed"].T.astype(h2.dtype)
        labels = jnp.roll(batch["labels"], -1, axis=1)
        labels = labels.at[:, -1].set(-100)          # t+2 shift boundary
        return L.cross_entropy(logits, labels)

    # ======================================================== inference
    def _infer_layer_dense(self, p, h, cos, sin, *, is_mla, use_moe,
                           collect_cache):
        """Plain (no-vjp) layer that also returns the KV cache entry."""
        cfg, rt = self.cfg, self.rt
        spec = _attn_spec(cfg, rt, scale=L.mla_scale(cfg) if is_mla else None)
        if is_mla:
            q, k, v, latent = L.mla_qkv(p["attn"], h, cfg, cos, sin,
                                        return_latent=True)
        else:
            q, k, v = L.attn_qkv(p["attn"], h, cfg, cos, sin)
        if is_mla and rt.latent_ring and spec.schedule == "zigzag":
            from repro.core.dist_attention import dist_attn_fwd_latent
            o, _ = dist_attn_fwd_latent(
                q, k, v, latent, p["attn"]["wkv_b"],
                partial(L.mla_expand, cfg=cfg), mesh=rt.mesh, spec=spec,
                batch_axes=rt.par.batch_axes)
        else:
            o, _ = dist_attn_fwd(q, k, v, mesh=rt.mesh, spec=spec,
                                 batch_axes=rt.par.batch_axes)
        h2 = L.attn_out(p["attn"], h, o, cfg)
        if use_moe:
            h3, _ = M.moe_apply(p["moe"], h2, cfg, mesh=rt.mesh,
                                seq_axis=rt.par.seq_axis,
                                batch_axes=rt.par.batch_axes)
        else:
            h3 = L.mlp_apply(p["mlp"], h2, cfg.norm_eps)
        h3 = constrain(h3, rt.mesh, act_spec(rt.par))
        cache = None
        if collect_cache:
            cache = (latent,) if is_mla else (k, v)
        return h3, cache

    def prefill(self, p, batch):
        """Full-context forward; returns (last-token logits, cache)."""
        cfg, rt = self.cfg, self.rt
        h = self._embed(p, batch)
        T = h.shape[1]
        cos = sin = None
        if cfg.uses_attention:
            dim = (cfg.attn.qk_rope_head_dim if cfg.attn.is_mla
                   else cfg.attn.head_dim)
            cos, sin = L.rope_tables(jnp.arange(T), dim, cfg.attn.rope_theta)
        last = T - 1
        if rt.par.schedule == "zigzag" and _zigzag_ok(cfg) \
                and rt.seq_size > 1:
            from repro.core.dist_attention import zigzag_perm
            perm = zigzag_perm(T, rt.seq_size)
            h = h[:, perm]
            cos, sin = cos[perm], sin[perm]
            last = int(np.nonzero(perm == T - 1)[0][0])
            h = constrain(h, rt.mesh, act_spec(rt.par))
        at = cfg.arch_type
        caches = {}
        if at in ("dense", "vlm"):
            def body(h, lp):
                h2, c = self._infer_layer_dense(h=h, p=lp, cos=cos, sin=sin,
                                                is_mla=False, use_moe=False,
                                                collect_cache=True)
                return h2, c
            h, (ck, cv) = xscan(body, h, p["layers"])
            caches = {"k": ck, "v": cv}
        elif at == "moe":
            is_mla = cfg.attn.is_mla
            def bodyd(h, lp):
                return self._infer_layer_dense(
                    h=h, p=lp, cos=cos, sin=sin, is_mla=is_mla,
                    use_moe=False, collect_cache=True)
            def bodym(h, lp):
                return self._infer_layer_dense(
                    h=h, p=lp, cos=cos, sin=sin, is_mla=is_mla,
                    use_moe=True, collect_cache=True)
            h, c1 = xscan(bodyd, h, p["dense_layers"])
            h, c2 = xscan(bodym, h, p["moe_layers"])
            if is_mla:
                caches = {"ckv": jnp.concatenate([c1[0], c2[0]])}
            else:
                caches = {"k": jnp.concatenate([c1[0], c2[0]]),
                          "v": jnp.concatenate([c1[1], c2[1]])}
        elif at in ("ssm", "hybrid"):
            # SSM prefill produces O(1) state, not a KV cache; reuse the
            # training backbone then rebuild decode state token-free.
            h, _ = self._backbone(p, h, cos, sin)
        logits = self._head(p, h[:, last:last + 1])
        return logits, caches

    def prefill_chunk(self, p, cache, batch):
        """Chunked paged prefill (Sarathi-style): forward a B=1 chunk
        ``batch = {"tokens": (1, C), "start": scalar, "n_valid": scalar}``
        occupying context positions ``[start, start + n_valid)`` through
        every layer, scattering the chunk's K/V (or MLA latent) into the
        slot's pool blocks (write-then-attend) and attending over the
        already-cached context gathered through the block table —
        ``chunk_attn`` with a *dynamic* ``q_offset = start`` (the MaskSpec
        offset machinery from the packed-sequence work).  Rows past
        ``n_valid`` (shape-bucket padding) are written to the reserved
        null block and their outputs are causal-masked garbage that is
        never read.  No logits are returned: the pending-token design
        keeps the last context token for decode.  ``cache`` is a paged
        view {k_pool, v_pool | ckv_pool, block_table (1, nkv)} whose
        updated pools are returned."""
        cfg, rt = self.cfg, self.rt
        at = cfg.arch_type
        if at not in ("dense", "moe"):
            raise ValueError(f"chunked paged prefill serves dense/moe "
                             f"decoders (got {at!r})")
        a = cfg.attn
        is_mla = a.is_mla
        tok = batch["tokens"]
        start = jnp.asarray(batch["start"], jnp.int32)
        end = start + jnp.asarray(batch["n_valid"], jnp.int32)
        bt = cache["block_table"]
        h = p["embed"][tok].astype(self.dtype)             # (1, C, d)
        C = tok.shape[1]
        dim = a.qk_rope_head_dim if is_mla else a.head_dim
        cos, sin = L.rope_tables(start + jnp.arange(C), dim, a.rope_theta)
        spec = _decode_mask(a.window)      # the chunk is a context suffix

        def gather(pool):
            # (N, bs, ...) -> (1, nkv·bs, ...) context view via the table
            g = pool[bt[0]]
            return g.reshape(1, g.shape[0] * g.shape[1], *g.shape[2:])

        def one(lp, h, kp, vp):
            if is_mla:
                h2, kp = self._chunk_mla(lp, h, kp, cos, sin, start, end,
                                         bt)
                return h2, kp, vp
            q, k, v = L.attn_qkv(lp["attn"], h, cfg, cos, sin)
            kp = _paged_write_chunk(kp, k, bt, start, end)
            vp = _paged_write_chunk(vp, v, bt, start, end)
            o, _ = chunk_attn(q, gather(kp), gather(vp), mask=spec,
                              impl=rt.impl, q_offset=start)
            h2 = L.attn_out(lp["attn"], h, o, cfg)
            return h2, kp, vp

        if at == "moe":
            nd = cfg.moe.n_dense_layers

            def moe_mlp(lp, h2):
                h3, _ = M.moe_apply(lp["moe"], h2, cfg, mesh=rt.mesh,
                                    seq_axis=rt.par.seq_axis,
                                    batch_axes=rt.par.batch_axes)
                return h3
            if is_mla:
                def bodyd(h, xs):
                    lp, cp = xs
                    h2, cp = self._chunk_mla(lp, h, cp, cos, sin, start,
                                             end, bt)
                    return L.mlp_apply(lp["mlp"], h2, cfg.norm_eps), cp

                def bodym(h, xs):
                    lp, cp = xs
                    h2, cp = self._chunk_mla(lp, h, cp, cos, sin, start,
                                             end, bt)
                    return moe_mlp(lp, h2), cp
                h, c1 = xscan(bodyd, h, (p["dense_layers"],
                                         cache["ckv_pool"][:nd]))
                h, c2 = xscan(bodym, h, (p["moe_layers"],
                                         cache["ckv_pool"][nd:]))
                return {"ckv_pool": jnp.concatenate([c1, c2]),
                        "block_table": bt}

            def bodyd(h, xs):
                lp, kp, vp = xs
                h2, kp, vp = one(lp, h, kp, vp)
                return L.mlp_apply(lp["mlp"], h2, cfg.norm_eps), (kp, vp)

            def bodym(h, xs):
                lp, kp, vp = xs
                h2, kp, vp = one(lp, h, kp, vp)
                return moe_mlp(lp, h2), (kp, vp)
            h, (k1, v1) = xscan(bodyd, h, (p["dense_layers"],
                                           cache["k_pool"][:nd],
                                           cache["v_pool"][:nd]))
            h, (k2, v2) = xscan(bodym, h, (p["moe_layers"],
                                           cache["k_pool"][nd:],
                                           cache["v_pool"][nd:]))
            return {"k_pool": jnp.concatenate([k1, k2]),
                    "v_pool": jnp.concatenate([v1, v2]),
                    "block_table": bt}

        def body(h, xs):
            lp, kp, vp = xs
            h2, kp, vp = one(lp, h, kp, vp)
            return L.mlp_apply(lp["mlp"], h2, cfg.norm_eps), (kp, vp)
        h, (kp, vp) = xscan(body, h, (p["layers"], cache["k_pool"],
                                      cache["v_pool"]))
        return {"k_pool": kp, "v_pool": vp, "block_table": bt}

    def _chunk_mla(self, lp, h, cp, cos, sin, start, end, bt):
        """One layer of chunked paged absorbed-MLA prefill: write the
        chunk's latents, then latent-space attention over the gathered
        context (the value view is the latent's first ``kv_lora`` dims)."""
        cfg, rt = self.cfg, self.rt
        a = cfg.attn
        c = a.kv_lora_rank
        q_full, new, w_uv = self._mla_decode_parts(lp, h, cos, sin)
        cp = _paged_write_chunk(cp, new, bt, start, end)
        g = cp[bt[0]]
        g = g.reshape(1, g.shape[0] * g.shape[1], 1, g.shape[2])
        o_lat, _ = chunk_attn(q_full, g, g[..., :c],
                              mask=_decode_mask(a.window),
                              scale=L.mla_scale(cfg), impl=rt.impl,
                              q_offset=start)
        h2 = self._mla_out(lp, h, o_lat, w_uv)
        return h2, cp

    # -------------------------------------------------------------- decode
    def decode(self, p, cache, batch):
        """One decode step: batch = {"token": (B,1) int32, "pos": (B,)}.

        ``pos`` holds each request's current context length (its new token's
        position); a scalar is a deprecated broadcast shim.  ``cache`` is
        either the dense contiguous cache from :meth:`prefill` or a *paged
        view* (``k_pool``/``v_pool`` or ``ckv_pool`` block pools +
        ``block_table`` — see serve/cache.py), in which case the new
        token's K/V is scattered into the request's current block and
        attention gathers through the block table."""
        cfg, rt = self.cfg, self.rt
        at = cfg.arch_type
        tok = batch["token"]
        pos = _norm_pos(batch["pos"], tok.shape[0])
        h = p["embed"][tok].astype(self.dtype)        # (B,1,d)
        cos = sin = None
        if cfg.uses_attention:
            dim = (cfg.attn.qk_rope_head_dim if cfg.attn.is_mla
                   else cfg.attn.head_dim)
            cos, sin = _decode_rope(pos, dim, cfg.attn.rope_theta)
        if at in ("dense", "vlm", "moe"):
            if _is_paged(cache):
                h, cache = self._decode_attn_stack_paged(p, cache, h, cos,
                                                         sin, pos)
            else:
                h, cache = self._decode_attn_stack(p, cache, h, cos, sin,
                                                   pos)
        elif at == "ssm":
            def body(h, xs):
                lp, st, cv = xs
                h2, st2, cv2 = S.ssm_decode_step(lp["ssm"], h, st, cv, cfg)
                return h2, (st2, cv2)
            h, (st, cv) = xscan(body, h,
                                   (p["layers"], cache["state"],
                                    cache["conv"]))
            cache = {"state": st, "conv": cv}
        elif at == "hybrid":
            h, cache = self._decode_hybrid(p, cache, h, cos, sin, pos)
        logits = self._head(p, h)
        return logits, cache

    def verify(self, p, cache, batch):
        """Multi-token speculative verification over a *paged* cache:
        batch = {"tokens": (B, T) int32, "pos": (B,), "n_write": (B,)}.

        Token t of request b sits at context position ``pos_b + t`` — row 0
        is the request's pending token, rows 1.. are draft proposals.  All
        T rows write-then-attend in one pass (the chunked-prefill pattern
        turned batched), but only the first ``n_write_b`` rows scatter into
        real blocks; the rest null-redirect so rejected drafts leave no
        trace that masking doesn't already hide.  With T = 1 and
        ``n_write = 1`` this is the vanilla :meth:`decode` computation.
        Returns (logits (B, T, V), cache)."""
        cfg, rt = self.cfg, self.rt
        if not _is_paged(cache):
            raise ValueError("verify() requires a paged cache view")
        if cfg.arch_type not in ("dense", "vlm", "moe"):
            raise NotImplementedError(
                f"verify(): arch_type={cfg.arch_type!r} has no paged "
                f"decode path")
        toks = jnp.asarray(batch["tokens"], jnp.int32)
        B, T = toks.shape
        pos = _norm_pos(batch["pos"], B)
        n_write = jnp.asarray(batch["n_write"], jnp.int32)
        h = p["embed"][toks].astype(self.dtype)       # (B,T,d)
        cos = sin = None
        if cfg.uses_attention:
            dim = (cfg.attn.qk_rope_head_dim if cfg.attn.is_mla
                   else cfg.attn.head_dim)
            flat = (pos[:, None]
                    + jnp.arange(T, dtype=jnp.int32)[None, :]).reshape(-1)
            c, s = L.rope_tables(flat, dim, cfg.attn.rope_theta)
            cos, sin = c.reshape(B, T, -1), s.reshape(B, T, -1)
        h, cache = self._decode_attn_stack_paged(p, cache, h, cos, sin,
                                                 pos, n_write=n_write)
        logits = self._head(p, h)
        return logits, cache

    def _decode_attn_stack(self, p, cache, h, cos, sin, pos):
        cfg, rt = self.cfg, self.rt
        a = cfg.attn
        is_mla = a is not None and a.is_mla

        def one(lp, h, ck, cv):
            if is_mla:
                return self._decode_mla(lp, h, ck, cv, cos, sin, pos)
            q, k, v = L.attn_qkv(lp["attn"], h, cfg, cos, sin)
            o = dist_decode_attn(q, ck, cv, k, v, mesh=rt.mesh,
                                 seq_axes=rt.par.seq_axes,
                                 batch_axes=rt.par.batch_axes,
                                 mask=_decode_mask(a.window), pos=pos)
            ck = _cache_write(ck, k, pos, rt)
            cv = _cache_write(cv, v, pos, rt)
            h2 = L.attn_out(lp["attn"], h, o, cfg)
            return h2, ck, cv

        if cfg.arch_type == "moe":
            nd = cfg.moe.n_dense_layers
            if is_mla:
                def bodyd(h, xs):
                    lp, ck = xs
                    h2, ck, _ = self._decode_mla(lp, h, ck, None, cos, sin,
                                                 pos)
                    return L.mlp_apply(lp["mlp"], h2, cfg.norm_eps), ck
                def bodym(h, xs):
                    lp, ck = xs
                    h2, ck, _ = self._decode_mla(lp, h, ck, None, cos, sin,
                                                 pos)
                    h3 = M.moe_decode_apply(lp["moe"], h2, cfg,
                                            mesh=rt.mesh,
                                            seq_axis=rt.par.seq_axis,
                                            batch_axes=rt.par.batch_axes)
                    return h3, ck
                h, c1 = xscan(bodyd, h, (p["dense_layers"],
                                            cache["ckv"][:nd]))
                h, c2 = xscan(bodym, h, (p["moe_layers"],
                                            cache["ckv"][nd:]))
                return h, {"ckv": jnp.concatenate([c1, c2])}
            def bodyd(h, xs):
                lp, ck, cv = xs
                h2, ck, cv = one(lp, h, ck, cv)
                h3 = L.mlp_apply(lp["mlp"], h2, cfg.norm_eps)
                return h3, (ck, cv)
            def bodym(h, xs):
                lp, ck, cv = xs
                h2, ck, cv = one(lp, h, ck, cv)
                h3 = M.moe_decode_apply(lp["moe"], h2, cfg, mesh=rt.mesh,
                                        seq_axis=rt.par.seq_axis,
                                        batch_axes=rt.par.batch_axes)
                return h3, (ck, cv)
            h, (k1, v1) = xscan(bodyd, h, (p["dense_layers"],
                                              cache["k"][:nd],
                                              cache["v"][:nd]))
            h, (k2, v2) = xscan(bodym, h, (p["moe_layers"],
                                              cache["k"][nd:],
                                              cache["v"][nd:]))
            cache = {"k": jnp.concatenate([k1, k2]),
                     "v": jnp.concatenate([v1, v2])}
            return h, cache

        def body(h, xs):
            lp, ck, cv = xs
            h2, ck, cv = one(lp, h, ck, cv)
            h3 = L.mlp_apply(lp["mlp"], h2, cfg.norm_eps)
            return h3, (ck, cv)
        h, (ck, cv) = xscan(body, h, (p["layers"], cache["k"],
                                         cache["v"]))
        return h, {"k": ck, "v": cv}

    def _decode_attn_stack_paged(self, p, cache, h, cos, sin, pos,
                                 n_write=None):
        """Decode through a paged cache view: per layer, the new tokens'
        K/V (or MLA latent) is scattered into the request's current block
        (write-then-attend), then attention gathers the context through the
        block table (``paged_decode_attn``).  ``cache`` = {"k_pool",
        "v_pool"} or {"ckv_pool"} pools with leading layer dim +
        "block_table" (B, nb); ``pos`` (B,) per-request context lengths.

        ``h`` carries T tokens (T = 1 for vanilla decode; T = K + 1 for a
        speculative verify pass, where row t sits at context position
        ``pos + t``).  ``n_write`` (B,) caps how many rows each request
        scatters into real blocks (the rest null-redirect); None means the
        single-token decode write path."""
        cfg, rt = self.cfg, self.rt
        a = cfg.attn
        is_mla = a is not None and a.is_mla
        bt = cache["block_table"]
        T = h.shape[1]
        lengths = pos + T                # incl. all written/draft tokens
        if n_write is None:              # vanilla decode: T = 1
            write = lambda pool, new: _paged_write(pool, new, bt, pos)
        else:
            write = lambda pool, new: _paged_write_multi(pool, new, bt,
                                                         pos, n_write)

        def one(lp, h, kp, vp):
            if is_mla:
                h2, kp = self._decode_mla_paged(lp, h, kp, cos, sin,
                                                bt, lengths, write)
                return h2, kp, vp
            q, k, v = L.attn_qkv(lp["attn"], h, cfg, cos, sin)
            kp = write(kp, k)
            vp = write(vp, v)
            o = paged_decode_attn(q, kp, vp, bt, lengths,
                                  mask=_decode_mask(a.window), impl=rt.impl)
            h2 = L.attn_out(lp["attn"], h, o, cfg)
            return h2, kp, vp

        if cfg.arch_type == "moe":
            nd = cfg.moe.n_dense_layers
            if is_mla:
                def bodyd(h, xs):
                    lp, cp = xs
                    h2, cp = self._decode_mla_paged(lp, h, cp, cos, sin,
                                                    bt, lengths, write)
                    return L.mlp_apply(lp["mlp"], h2, cfg.norm_eps), cp
                def bodym(h, xs):
                    lp, cp = xs
                    h2, cp = self._decode_mla_paged(lp, h, cp, cos, sin,
                                                    bt, lengths, write)
                    h3 = M.moe_decode_apply(lp["moe"], h2, cfg,
                                            mesh=rt.mesh,
                                            seq_axis=rt.par.seq_axis,
                                            batch_axes=rt.par.batch_axes)
                    return h3, cp
                h, c1 = xscan(bodyd, h, (p["dense_layers"],
                                         cache["ckv_pool"][:nd]))
                h, c2 = xscan(bodym, h, (p["moe_layers"],
                                         cache["ckv_pool"][nd:]))
                return h, {"ckv_pool": jnp.concatenate([c1, c2]),
                           "block_table": bt}
            def bodyd(h, xs):
                lp, kp, vp = xs
                h2, kp, vp = one(lp, h, kp, vp)
                return L.mlp_apply(lp["mlp"], h2, cfg.norm_eps), (kp, vp)
            def bodym(h, xs):
                lp, kp, vp = xs
                h2, kp, vp = one(lp, h, kp, vp)
                h3 = M.moe_decode_apply(lp["moe"], h2, cfg, mesh=rt.mesh,
                                        seq_axis=rt.par.seq_axis,
                                        batch_axes=rt.par.batch_axes)
                return h3, (kp, vp)
            h, (k1, v1) = xscan(bodyd, h, (p["dense_layers"],
                                           cache["k_pool"][:nd],
                                           cache["v_pool"][:nd]))
            h, (k2, v2) = xscan(bodym, h, (p["moe_layers"],
                                           cache["k_pool"][nd:],
                                           cache["v_pool"][nd:]))
            return h, {"k_pool": jnp.concatenate([k1, k2]),
                       "v_pool": jnp.concatenate([v1, v2]),
                       "block_table": bt}

        def body(h, xs):
            lp, kp, vp = xs
            h2, kp, vp = one(lp, h, kp, vp)
            return L.mlp_apply(lp["mlp"], h2, cfg.norm_eps), (kp, vp)
        h, (kp, vp) = xscan(body, h, (p["layers"], cache["k_pool"],
                                      cache["v_pool"]))
        return h, {"k_pool": kp, "v_pool": vp, "block_table": bt}

    def _mla_decode_parts(self, lp, h, cos, sin):
        """Shared absorbed-MLA decode projections for ``T`` tokens (T = 1
        for decode, a chunk for paged prefill): effective latent-space
        query ``q_full`` (B,T,nh,c+dr), the tokens' latent cache entries
        ``new`` (B,T,c+dr), and the value up-projection ``w_uv``."""
        cfg = self.cfg
        a = cfg.attn
        p_ = lp["attn"]
        B, T = h.shape[0], h.shape[1]
        nh, dn, dr, c = a.n_heads, a.qk_nope_head_dim, a.qk_rope_head_dim, \
            a.kv_lora_rank
        dv = a.v_head_dim or a.head_dim
        hn = L.rms_norm(h, p_["ln"], cfg.norm_eps)
        if a.q_lora_rank:
            qc = L.rms_norm(hn @ p_["wq_a"], p_["q_ln"], cfg.norm_eps)
            q = (qc @ p_["wq_b"]).reshape(B, T, nh, dn + dr)
        else:
            q = (hn @ p_["wq"]).reshape(B, T, nh, dn + dr)
        q_nope, q_pe = q[..., :dn], q[..., dn:]
        q_pe = L.apply_rope(q_pe, cos, sin)
        wkv_b = p_["wkv_b"].reshape(c, nh, dn + dv)
        w_uk, w_uv = wkv_b[..., :dn], wkv_b[..., dn:]
        q_eff = jnp.einsum("bthn,chn->bthc", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32)).astype(h.dtype)
        q_full = jnp.concatenate([q_eff, q_pe], axis=-1)     # (B,T,nh,c+dr)
        kv_a = hn @ p_["wkv_a"]
        ckv1 = L.rms_norm(kv_a[..., :c], p_["kv_ln"], cfg.norm_eps)
        kpe1 = L.apply_rope(kv_a[..., c:].reshape(B, T, 1, dr), cos, sin)
        new = jnp.concatenate([ckv1, kpe1[:, :, 0, :]], axis=-1)  # (B,T,c+dr)
        return q_full, new, w_uv

    def _mla_out(self, lp, h, o_lat, w_uv):
        cfg = self.cfg
        a = cfg.attn
        nh = a.n_heads
        dv = a.v_head_dim or a.head_dim
        B = h.shape[0]
        o = jnp.einsum("bthc,chv->bthv", o_lat.astype(jnp.float32),
                       w_uv.astype(jnp.float32)).astype(h.dtype)
        return h + (o.reshape(B, o.shape[1], nh * dv) @
                    lp["attn"]["wo"]).astype(h.dtype)

    def _decode_mla(self, lp, h, ck, cv, cos, sin, pos):
        """Absorbed MLA decode: the cache stores the compressed latent
        (c_kv ⊕ rope-key), 576 dims/token instead of n_heads·(192+128) —
        the MLA memory saving [arXiv:2405.04434]."""
        cfg, rt = self.cfg, self.rt
        a = cfg.attn
        c = a.kv_lora_rank
        q_full, new, w_uv = self._mla_decode_parts(lp, h, cos, sin)
        new4 = new[:, :, None, :]
        o_lat = dist_decode_attn(
            q_full, ck[:, :, None, :], ck[:, :, None, :c], new4,
            new4[..., :c],
            mesh=rt.mesh, seq_axes=rt.par.seq_axes,
            batch_axes=rt.par.batch_axes, mask=_decode_mask(a.window),
            scale=L.mla_scale(cfg), pos=pos)                 # (B,1,nh,c)
        ck = _cache_write(ck, new, pos, rt)
        h2 = self._mla_out(lp, h, o_lat, w_uv)
        return h2, ck, cv

    def _decode_mla_paged(self, lp, h, cp, cos, sin, bt, lengths, write):
        """Paged absorbed-MLA decode: one latent pool (N, bs, c+dr); the
        value view is a narrow slice of the key view (Hkv = 1)."""
        cfg, rt = self.cfg, self.rt
        a = cfg.attn
        c = a.kv_lora_rank
        q_full, new, w_uv = self._mla_decode_parts(lp, h, cos, sin)
        cp = write(cp, new)
        kview = cp[:, :, None, :]                  # (N, bs, 1, c+dr)
        o_lat = paged_decode_attn(
            q_full, kview, kview[..., :c], bt, lengths,
            mask=_decode_mask(a.window), scale=L.mla_scale(cfg),
            impl=rt.impl)
        h2 = self._mla_out(lp, h, o_lat, w_uv)
        return h2, cp

    def _decode_hybrid(self, p, cache, h, cos, sin, pos):
        cfg, rt = self.cfg, self.rt
        period = cfg.hybrid_period
        G = cfg.n_layers // period
        stacked = compat.tree_map(
            lambda a: a.reshape(G, period, *a.shape[1:]), p["layers"])
        emb0 = h
        scfg = self._shared_cfg()
        sa = scfg.attn

        def group(carry, xs):
            hh = carry
            gp, st, cv, sk, sv = xs
            def inner(c, ys):
                lp, st1, cv1 = ys
                h2, st2, cv2 = S.ssm_decode_step(lp["ssm"], c, st1, cv1, cfg)
                return h2, (st2, cv2)
            hh, (st, cv) = xscan(inner, hh, (gp, st, cv))
            # shared attention block decode
            x2 = jnp.concatenate([hh, emb0], axis=-1)
            q, k, v = L.attn_qkv(p["shared"]["attn"], x2, scfg, cos, sin)
            o = dist_decode_attn(q, sk, sv, k, v, mesh=rt.mesh,
                                 seq_axes=rt.par.seq_axes,
                                 batch_axes=rt.par.batch_axes,
                                 mask=_decode_mask(0), pos=pos)
            sk = _cache_write(sk, k, pos, rt)
            sv = _cache_write(sv, v, pos, rt)
            y2 = L.attn_out(p["shared"]["attn"], x2, o, scfg)
            y2 = L.mlp_apply(p["shared"]["mlp"], y2, cfg.norm_eps)
            hh = hh + (y2 @ p["shared"]["down"]).astype(hh.dtype)
            return hh, (st, cv, sk, sv)
        st_g = cache["state"].reshape(G, period, *cache["state"].shape[1:])
        cv_g = cache["conv"].reshape(G, period, *cache["conv"].shape[1:])
        h, (st, cv, sk, sv) = xscan(
            group, h, (stacked, st_g, cv_g,
                       cache["shared_k"], cache["shared_v"]))
        st = st.reshape(cfg.n_layers, *st.shape[2:])
        cv = cv.reshape(cfg.n_layers, *cv.shape[2:])
        return h, {"state": st, "conv": cv, "shared_k": sk, "shared_v": sv}


# --------------------------------------------------------------------------
# Paged-cache write: scatter the new token's K/V through the block table
# --------------------------------------------------------------------------

def _paged_write(pool, new, block_table, pos):
    """Scatter ``new`` (B, 1, ...) into one layer's block ``pool``
    (N, bs, ...) at each request's slot for context position ``pos`` (B,):
    block ``block_table[b, pos_b // bs]``, offset ``pos_b % bs``.  Idle
    batch rows (all-zero table rows) land in the reserved null block 0,
    which ``lengths`` masking keeps unread."""
    bs = pool.shape[1]
    bidx = jnp.take_along_axis(block_table, (pos // bs)[:, None],
                               axis=1)[:, 0]
    return pool.at[bidx, pos % bs].set(new[:, 0].astype(pool.dtype))


def _paged_write_multi(pool, new, block_table, pos, n_write):
    """Scatter ``new`` (B, T, ...) into one layer's block ``pool``: row t
    holds context position ``pos_b + t``.  Rows with ``t >= n_write_b``
    (draft slack beyond a request's write budget, or idle batch rows with
    ``n_write = 0``) are redirected to the reserved null block 0 — never
    gathered unmasked, so collisions there are harmless."""
    bs, nb = pool.shape[1], block_table.shape[1]
    T = new.shape[1]
    idx = pos[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]   # (B,T)
    col = jnp.clip(idx // bs, 0, nb - 1)
    bidx = jnp.take_along_axis(block_table, col, axis=1)
    live = jnp.arange(T, dtype=jnp.int32)[None, :] < n_write[:, None]
    bidx = jnp.where(live, bidx, 0)
    return pool.at[bidx, idx % bs].set(new.astype(pool.dtype))


def _paged_write_chunk(pool, new, block_table, start, end):
    """Scatter a B=1 prefill chunk ``new`` (1, C, ...) into one layer's
    block ``pool`` (N, bs, ...): row ``i`` holds context position
    ``start + i``.  Rows at positions ≥ ``end`` (shape-bucket padding)
    are redirected to the reserved null block 0 — they can never clobber
    a real block, and the null block's garbage is never gathered
    unmasked."""
    bs = pool.shape[1]
    C = new.shape[1]
    idx = start + jnp.arange(C)
    col = jnp.clip(idx // bs, 0, block_table.shape[1] - 1)
    bidx = jnp.where(idx < end, block_table[0, col], 0)
    return pool.at[bidx, idx % bs].set(new[0].astype(pool.dtype))


# --------------------------------------------------------------------------
# KV-cache write: ring-buffer update of the sequence-sharded cache
# --------------------------------------------------------------------------

def _cache_write(cache, new, pos, rt: Runtime):
    """Write ``new`` (B,1,...) into the S-sharded ``cache`` (B,S,...) at
    per-request ring-buffer slot ``pos[b] % S`` (``pos``: (B,) int32, or a
    scalar that broadcasts). Done in a small shard_map: only the owner
    shard of each request's slot scatters (no gather of the cache)."""
    par = rt.par
    seq_axes = par.seq_axes
    n = 1
    for a in seq_axes:
        n *= mesh_axis_size(rt.mesh, a)
    S_loc = cache.shape[1] // n
    bspec = tuple(par.batch_axes) if par.batch_axes else None
    seq = seq_axes if len(seq_axes) > 1 else seq_axes[0]
    nd = cache.ndim
    cspec = P(bspec, seq, *([None] * (nd - 2)))
    rspec = P(bspec, None, *([None] * (nd - 2)))
    pos = jnp.broadcast_to(jnp.asarray(pos), (cache.shape[0],))

    def upd(c, x, pv):
        idx = jnp.int32(0)
        for ax in seq_axes:
            idx = idx * compat.axis_size(ax) + lax.axis_index(ax)
        slot = pv % (n * S_loc)                       # (B,)
        owner = slot // S_loc
        local = slot % S_loc
        hit = ((jnp.arange(S_loc)[None, :] == local[:, None])
               & (owner == idx)[:, None])             # (B, S_loc)
        hit = hit.reshape(hit.shape + (1,) * (c.ndim - 2))
        return jnp.where(hit, x.astype(c.dtype), c)   # x (B,1,...) bcasts

    fn = compat.shard_map(upd, mesh=rt.mesh, in_specs=(cspec, rspec,
                                                       P(bspec)),
                       out_specs=cspec, check_vma=False)
    return fn(cache, new, pos)


# ==========================================================================
# Whisper-style encoder–decoder (audio backbone; conv frontend is a stub —
# batch["frames"] are precomputed frame embeddings) [arXiv:2212.04356]
# ==========================================================================

class EncDecLM:
    """Encoder runs replicated over the sequence axis (n_frames ≪ decoder
    seq — DESIGN.md §5); decoder self-attention uses DISTFLASHATTN; decoder
    cross-attention attends the replicated encoder output locally (zero
    ring communication). Both attention sites sit at remat-aware
    checkpoint boundaries."""

    def __init__(self, cfg: ModelConfig, rt: Runtime):
        self.cfg = cfg
        self.rt = rt
        self.dtype = jnp.dtype(cfg.dtype)

    # ---------------------------------------------------------------- init
    def _cross_params(self, key):
        cfg = self.cfg
        a = cfg.attn
        d, hd = cfg.d_model, a.head_dim
        ks = jax.random.split(key, 4)
        return {"wq": L.dense_init(ks[0], d, a.n_heads * hd, self.dtype),
                "wk": L.dense_init(ks[1], d, a.n_heads * hd, self.dtype),
                "wv": L.dense_init(ks[2], d, a.n_heads * hd, self.dtype),
                "wo": L.dense_init(ks[3], a.n_heads * hd, d, self.dtype),
                "ln": jnp.ones((d,), self.dtype)}

    def init(self, rng):
        cfg, dt = self.cfg, self.dtype
        ks = jax.random.split(rng, 6)
        return {
            "embed": L.embed_init(ks[0], cfg.vocab, cfg.d_model, dt),
            "enc_layers": _stack(ks[1], cfg.n_enc_layers, lambda k: {
                "attn": L.attn_params(k, cfg, dt),
                "mlp": L.mlp_params(jax.random.fold_in(k, 1), cfg.d_model,
                                    cfg.d_ff, dt)}),
            "dec_layers": _stack(ks[2], cfg.n_layers, lambda k: {
                "attn": L.attn_params(k, cfg, dt),
                "cross": self._cross_params(jax.random.fold_in(k, 1)),
                "mlp": L.mlp_params(jax.random.fold_in(k, 2), cfg.d_model,
                                    cfg.d_ff, dt)}),
            "ln_enc": jnp.ones((cfg.d_model,), dt),
            "ln_f": jnp.ones((cfg.d_model,), dt),
        }

    # ------------------------------------------------------------- encoder
    def encode(self, p, frames):
        cfg, rt = self.cfg, self.rt
        h = frames.astype(self.dtype)
        h = constrain(h, rt.mesh, act_spec(rt.par, seq_sharded=False))
        T = h.shape[1]
        cos, sin = L.rope_tables(jnp.arange(T), cfg.attn.head_dim,
                                 cfg.attn.rope_theta)

        def layer(lp, h):
            q, k, v = L.attn_qkv(lp["attn"], h, cfg, cos, sin)
            o, _ = chunk_attn(q, k, v, mask=mk.full(), impl=rt.impl)
            h2 = L.attn_out(lp["attn"], h, o, cfg)
            return L.mlp_apply(lp["mlp"], h2, cfg.norm_eps)

        def body(h, lp):
            return jax.checkpoint(layer)(lp, h), None
        h, _ = xscan(body, h, p["enc_layers"])
        return L.rms_norm(h, p["ln_enc"], cfg.norm_eps)

    # ----------------------------------------------------- decoder layers
    def _dec_layer(self):
        """Two chained remat-aware sub-layers: self-attn, then cross+MLP.
        x = (h, enc, cos, sin)."""
        cfg, rt = self.cfg, self.rt
        spec = _attn_spec(cfg, rt, causal=True)
        a = cfg.attn

        def pre_self(lp, x):
            h, enc, cos, sin = x
            return L.attn_qkv(lp["attn"], h, cfg, cos, sin)

        def self_fwd(qkv):
            return dist_attn_fwd(*qkv, mesh=rt.mesh, spec=spec,
                                 batch_axes=rt.par.batch_axes)

        def self_bwd(qkv, o, lse, do):
            return dist_attn_bwd(*qkv, o, lse, do, mesh=rt.mesh, spec=spec,
                                 batch_axes=rt.par.batch_axes)

        def post_self(lp, x, o):
            h, enc, cos, sin = x
            return (L.attn_out(lp["attn"], h, o, cfg), enc, cos, sin)

        def pre_cross(lp, x):
            h, enc = x[0], x[1]
            B, T, _ = h.shape
            F = enc.shape[1]
            c = lp["cross"]
            hn = L.rms_norm(h, c["ln"], cfg.norm_eps)
            q = (hn @ c["wq"]).reshape(B, T, a.n_heads, a.head_dim)
            k = (enc @ c["wk"]).reshape(B, F, a.n_heads, a.head_dim)
            v = (enc @ c["wv"]).reshape(B, F, a.n_heads, a.head_dim)
            return q, k, v

        def cross_fwd(qkv):
            return chunk_attn(*qkv, mask=mk.full(), impl=rt.impl)

        def cross_bwd(qkv, o, lse, do):
            from repro.core.attention import chunk_attn_bwd
            return chunk_attn_bwd(*qkv, o, lse, do, mask=mk.full(),
                                  impl=rt.impl)

        def post_cross(lp, x, o):
            h, enc = x[0], x[1]
            B, T, _ = h.shape
            h2 = h + (o.reshape(B, T, -1) @ lp["cross"]["wo"]).astype(h.dtype)
            h3 = L.mlp_apply(lp["mlp"], h2, cfg.norm_eps)
            h3 = constrain(h3, rt.mesh, act_spec(rt.par))
            return (h3,) + tuple(x[1:])

        if rt.par.remat == "remat_aware":
            sub_a = remat_aware(pre_self, self_fwd, self_bwd, post_self)
            sub_b = remat_aware(pre_cross, cross_fwd, cross_bwd, post_cross)
            return lambda lp, x: sub_b(lp, sub_a(lp, x))

        def plain(lp, x):
            o, _ = dist_flash_attn(*pre_self(lp, x), rt.mesh, spec,
                                   rt.par.batch_axes)
            x = post_self(lp, x, o)
            qkv = pre_cross(lp, x)
            o2, _ = chunk_attn(*qkv, mask=mk.full(), impl=rt.impl)
            return post_cross(lp, x, o2)
        return jax.checkpoint(plain) if rt.par.remat == "hf" else plain

    # ----------------------------------------------------------- training
    def loss(self, p, batch):
        cfg, rt = self.cfg, self.rt
        enc = self.encode(p, batch["frames"])
        toks = batch["tokens"]
        h = p["embed"][toks].astype(self.dtype)
        h = constrain(h, rt.mesh, act_spec(rt.par))
        T = h.shape[1]
        cos, sin = L.rope_tables(jnp.arange(T), cfg.attn.head_dim,
                                 cfg.attn.rope_theta)
        layer = self._dec_layer()

        def body(carry, lp):
            return layer(lp, carry), None
        (h, *_rest), _ = xscan(body, (h, enc, cos, sin), p["dec_layers"])
        logits = L.rms_norm(h, p["ln_f"], cfg.norm_eps) @ \
            p["embed"].T.astype(h.dtype)
        ce = L.cross_entropy(logits, batch["labels"])
        return ce, {"ce": ce}

    # ---------------------------------------------------------- inference
    def prefill(self, p, batch):
        cfg, rt = self.cfg, self.rt
        enc = self.encode(p, batch["frames"])
        toks = batch["tokens"]
        h = p["embed"][toks].astype(self.dtype)
        h = constrain(h, rt.mesh, act_spec(rt.par))
        T = h.shape[1]
        a = cfg.attn
        cos, sin = L.rope_tables(jnp.arange(T), a.head_dim, a.rope_theta)
        spec = _attn_spec(cfg, rt, causal=True)

        def body(h, lp):
            q, k, v = L.attn_qkv(lp["attn"], h, cfg, cos, sin)
            o, _ = dist_attn_fwd(q, k, v, mesh=rt.mesh, spec=spec,
                                 batch_axes=rt.par.batch_axes)
            h2 = L.attn_out(lp["attn"], h, o, cfg)
            c = lp["cross"]
            B, F = enc.shape[0], enc.shape[1]
            hn = L.rms_norm(h2, c["ln"], cfg.norm_eps)
            qc = (hn @ c["wq"]).reshape(B, T, a.n_heads, a.head_dim)
            ek = (enc @ c["wk"]).reshape(B, F, a.n_heads, a.head_dim)
            ev = (enc @ c["wv"]).reshape(B, F, a.n_heads, a.head_dim)
            o2, _ = chunk_attn(qc, ek, ev, mask=mk.full(), impl=rt.impl)
            h3 = h2 + (o2.reshape(B, T, -1) @ c["wo"]).astype(h2.dtype)
            h4 = L.mlp_apply(lp["mlp"], h3, cfg.norm_eps)
            return h4, (k, v, ek, ev)
        h, (ck, cv, ek, ev) = xscan(body, h, p["dec_layers"])
        logits = L.rms_norm(h[:, -1:], p["ln_f"], cfg.norm_eps) @ \
            p["embed"].T.astype(h.dtype)
        return logits, {"k": ck, "v": cv, "ek": ek, "ev": ev}

    def decode(self, p, cache, batch):
        cfg, rt = self.cfg, self.rt
        a = cfg.attn
        tok = batch["token"]
        pos = _norm_pos(batch["pos"], tok.shape[0])
        h = p["embed"][tok].astype(self.dtype)
        cos, sin = _decode_rope(pos, a.head_dim, a.rope_theta)

        def body(h, xs):
            lp, ck, cv, ek, ev = xs
            B = h.shape[0]
            q, k, v = L.attn_qkv(lp["attn"], h, cfg, cos, sin)
            o = dist_decode_attn(q, ck, cv, k, v, mesh=rt.mesh,
                                 seq_axes=rt.par.seq_axes,
                                 batch_axes=rt.par.batch_axes,
                                 mask=_decode_mask(a.window), pos=pos)
            ck = _cache_write(ck, k, pos, rt)
            cv = _cache_write(cv, v, pos, rt)
            h2 = L.attn_out(lp["attn"], h, o, cfg)
            c = lp["cross"]
            hn = L.rms_norm(h2, c["ln"], cfg.norm_eps)
            qc = (hn @ c["wq"]).reshape(B, 1, a.n_heads, a.head_dim)
            o2, _ = chunk_attn(qc, ek, ev, mask=mk.full(), impl=rt.impl)
            h3 = h2 + (o2.reshape(B, 1, -1) @ c["wo"]).astype(h2.dtype)
            h4 = L.mlp_apply(lp["mlp"], h3, cfg.norm_eps)
            return h4, (ck, cv)
        h, (ck, cv) = xscan(body, h, (p["dec_layers"], cache["k"],
                                         cache["v"], cache["ek"],
                                         cache["ev"]))
        logits = L.rms_norm(h, p["ln_f"], cfg.norm_eps) @ \
            p["embed"].T.astype(h.dtype)
        return logits, {"k": ck, "v": cv, "ek": cache["ek"],
                        "ev": cache["ev"]}


def build_model(cfg: ModelConfig, rt: Runtime):
    if cfg.arch_type == "audio":
        return EncDecLM(cfg, rt)
    return DecoderLM(cfg, rt)
