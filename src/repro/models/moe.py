"""Mixture-of-Experts FFN with expert parallelism over the sequence axis.

DeepSeek-style MoE [arXiv:2405.04434, 2412.19437]: ``n_shared`` always-on
experts + ``n_routed`` routed experts with top-k softmax gating and a
load-balance auxiliary loss. Routed experts are sharded over the ``model``
mesh axis (expert parallelism composes with DISTFLASHATTN's sequence
parallelism on the same axis — tokens are already sequence-local when they
hit the router). Dispatch/return are two ``lax.all_to_all``s with fixed
per-expert capacity (dropped tokens fall back to the shared experts +
residual path). Expert weights are additionally FSDP-sharded on their FFN
dim over the batch axes in GSPMD land; the shard_map ``in_specs`` declare
the gathered layout, so XLA inserts the ZeRO-3 gather-on-use automatically.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.config import ModelConfig
from repro.models.layers import dense_init, rms_norm


def moe_params(key, cfg: ModelConfig, dtype):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    p = {
        "ln": jnp.ones((d,), dtype),
        "router": dense_init(ks[0], d, m.n_routed, jnp.float32),
        # routed experts, stacked: (E, d, d_e) / (E, d_e, d)
        "wg": jax.vmap(lambda k: dense_init(k, d, m.d_expert, dtype))(
            jax.random.split(ks[1], m.n_routed)),
        "wu": jax.vmap(lambda k: dense_init(k, d, m.d_expert, dtype))(
            jax.random.split(ks[2], m.n_routed)),
        "wd": jax.vmap(lambda k: dense_init(k, m.d_expert, d, dtype))(
            jax.random.split(ks[3], m.n_routed)),
    }
    if m.n_shared:
        ds = m.n_shared * m.d_expert     # fused shared experts (equivalent)
        p["sh_wg"] = dense_init(ks[4], d, ds, dtype)
        p["sh_wu"] = dense_init(ks[5], d, ds, dtype)
        p["sh_wd"] = dense_init(ks[6], ds, d, dtype)
    return p


def _expert_ffn(p, x):
    """x: (E_loc, n, d); weights (E_loc, d, de)/(E_loc, de, d)."""
    h = jax.nn.silu(jnp.einsum("end,edf->enf", x, p["wg"])) * \
        jnp.einsum("end,edf->enf", x, p["wu"])
    return jnp.einsum("enf,efd->end", h, p["wd"])


def _moe_local(cfg: ModelConfig, seq_axis, all_axes, p, x):
    """Per-device MoE body (inside shard_map). x: (b, t, d) local."""
    m = cfg.moe
    b, t, d = x.shape
    n = b * t
    S = compat.axis_size(seq_axis)
    e_loc = m.n_routed // S
    h = rms_norm(x, p["ln"], cfg.norm_eps).reshape(n, d)

    # ---- router (fp32) + top-k
    logits = h.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)                   # (n, E)
    top_p, top_e = lax.top_k(probs, m.top_k)                  # (n, K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)    # renormalize

    # ---- load-balance aux loss (replicated scalar)
    counts = jnp.zeros((m.n_routed,), jnp.float32).at[
        top_e.reshape(-1)].add(1.0)
    f = lax.psum(counts, all_axes)
    f = f / jnp.maximum(jnp.sum(f), 1.0)
    pm = lax.pmean(jnp.mean(probs, axis=0), all_axes)
    aux = m.n_routed * jnp.sum(f * pm) * m.aux_loss_coef

    # ---- capacity-based dispatch
    cap = int(max(4, -(-n * m.top_k * m.capacity_factor // m.n_routed)))
    flat_e = top_e.reshape(-1)                                # (n*K,)
    onehot = jax.nn.one_hot(flat_e, m.n_routed, dtype=jnp.int32)
    pos = jnp.sum((jnp.cumsum(onehot, axis=0) - 1) * onehot, axis=-1)
    keep = pos < cap
    slot = jnp.where(keep, pos, cap)                          # overflow slot
    xk = jnp.repeat(h, m.top_k, axis=0)                       # (n*K, d)
    buf = jnp.zeros((m.n_routed, cap + 1, d), h.dtype)
    buf = buf.at[flat_e, slot].add(xk)[:, :cap]               # (E, cap, d)

    # ---- all_to_all: ship per-expert slices to their owner shard
    buf = lax.all_to_all(buf.reshape(S, e_loc * cap, d), seq_axis,
                         split_axis=0, concat_axis=0, tiled=True)
    buf = buf.reshape(S, e_loc, cap, d).transpose(1, 0, 2, 3) \
             .reshape(e_loc, S * cap, d)

    out = _expert_ffn(p, buf)                                 # local experts

    # ---- return all_to_all + weighted combine
    out = out.reshape(e_loc, S, cap, d).transpose(1, 0, 2, 3) \
             .reshape(S, e_loc * cap, d)
    out = lax.all_to_all(out, seq_axis, split_axis=0, concat_axis=0,
                         tiled=True)
    out = jnp.pad(out.reshape(m.n_routed, cap, d),
                  ((0, 0), (0, 1), (0, 0)))                   # overflow → 0
    got = out[flat_e, slot]                                   # (n*K, d)
    got = got * (keep.astype(got.dtype) * top_p.reshape(-1).astype(
        got.dtype))[:, None]
    y = jnp.sum(got.reshape(n, m.top_k, d), axis=1)

    # ---- shared experts (dense, local)
    if m.n_shared:
        sh = (jax.nn.silu(h @ p["sh_wg"]) * (h @ p["sh_wu"])) @ p["sh_wd"]
        y = y + sh
    return x + y.reshape(b, t, d).astype(x.dtype), aux


def moe_apply(p, x, cfg: ModelConfig, *, mesh, seq_axis="model",
              batch_axes=("data",)):
    """Global-array MoE layer. Returns (y, aux_loss_scalar)."""
    bspec = tuple(batch_axes) if batch_axes else None
    all_axes = tuple(batch_axes) + (seq_axis,) if batch_axes else (seq_axis,)
    x_s = P(bspec, seq_axis, None)
    e_spec = P(seq_axis, None, None)
    pspec = {k: (e_spec if k in ("wg", "wu", "wd")
                 else P(*(None,) * p[k].ndim)) for k in p}
    fn = compat.shard_map(
        partial(_moe_local, cfg, seq_axis, all_axes),
        mesh=mesh, in_specs=(pspec, x_s), out_specs=(x_s, P()),
        check_vma=False)
    return fn(p, x)


# --------------------------------------------------------------------------
# decode path: tokens are replicated over the sequence axis (a single new
# token cannot be sequence-sharded), so instead of an all_to_all each shard
# evaluates its LOCAL experts for all tokens and the partial outputs are
# psum-combined — expert parallelism without dispatch.
# --------------------------------------------------------------------------

def _moe_decode_local(cfg: ModelConfig, seq_axis, p, x):
    m = cfg.moe
    b, t, d = x.shape
    n = b * t
    S = compat.axis_size(seq_axis)
    e_loc = m.n_routed // S
    sh = lax.axis_index(seq_axis)
    h = rms_norm(x, p["ln"], cfg.norm_eps).reshape(n, d)
    logits = h.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, m.top_k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    # per-token weight for every expert (n, E), zero if not in top-k
    w = jnp.zeros((n, m.n_routed), jnp.float32)
    w = w.at[jnp.arange(n)[:, None], top_e].set(top_p)
    w_loc = lax.dynamic_slice_in_dim(w, sh * e_loc, e_loc, axis=1)
    xe = jnp.broadcast_to(h[None], (e_loc, n, d))
    oe = _expert_ffn(p, xe)                               # (e_loc, n, d)
    y = jnp.einsum("ne,end->nd", w_loc, oe.astype(jnp.float32))
    y = lax.psum(y, seq_axis)
    if m.n_shared:
        sh_out = (jax.nn.silu(h @ p["sh_wg"]) * (h @ p["sh_wu"])) @ p["sh_wd"]
        y = y + sh_out.astype(jnp.float32)
    return x + y.reshape(b, t, d).astype(x.dtype)


def moe_decode_apply(p, x, cfg: ModelConfig, *, mesh, seq_axis="model",
                     batch_axes=("data",)):
    bspec = tuple(batch_axes) if batch_axes else None
    x_s = P(bspec, None, None)
    e_spec = P(seq_axis, None, None)
    pspec = {k: (e_spec if k in ("wg", "wu", "wd")
                 else P(*(None,) * p[k].ndim)) for k in p}
    fn = compat.shard_map(partial(_moe_decode_local, cfg, seq_axis),
                       mesh=mesh, in_specs=(pspec, x_s), out_specs=x_s,
                       check_vma=False)
    return fn(p, x)
