"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) with
sequence-parallel cross-device state relay.

The paper's attention scheduling is inapplicable to an attention-free SSM
(DESIGN.md §5); what transfers is the *sequence-parallel decomposition*:
tokens are sharded over the ``model`` axis, each shard runs the chunked SSD
algorithm locally, and the (tiny, O(d_state·d_head)) inter-shard recurrent
state is combined with a log₂(P)-step Hillis–Steele parallel prefix over
``ppermute`` — the recurrent-scan analogue of the paper's ring.

Chunked SSD (exact, matches the sequential recurrence):
  y_i  = Σ_{j≤i} (C_i·B_j) · exp(cum_i − cum_j) · dt_j · x_j   (intra-chunk)
       + C_i · exp(cum_i) · S_init                              (inter-chunk)
  S'   = exp(cum_L) · S_init + Σ_j exp(cum_L − cum_j) dt_j B_j ⊗ x_j
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.config import ModelConfig
from repro.models.layers import dense_init, rms_norm


def ssm_params(key, cfg: ModelConfig, dtype):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    conv_ch = di + 2 * s.d_state
    ks = jax.random.split(key, 4)
    return {
        "ln": jnp.ones((d,), dtype),
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * s.d_state + nh, dtype),
        "conv_w": (jax.random.normal(ks[1], (conv_ch, s.d_conv)) * 0.2
                   ).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),          # A = −exp(A_log) = −1
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "gln": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[2], di, d, dtype),
    }


def _causal_conv(xbc, w, b, tail):
    """Depthwise causal conv. xbc: (b,t,ch); w: (ch,k); tail: (b,k-1,ch)
    carry from the previous sequence shard (zeros on shard 0)."""
    k = w.shape[1]
    xp = jnp.concatenate([tail, xbc], axis=1)            # (b, t+k-1, ch)
    # w[:, k-1] multiplies the current token, w[:, 0] the oldest
    out = jnp.zeros_like(xbc)
    for i in range(k):
        out = out + xp[:, i:i + xbc.shape[1]] * w[:, i][None, None, :]
    return out + b[None, None, :]


def _ssd_chunked(x, B, C, dt, adt, s_init, chunk):
    """Exact chunked SSD. x: (b,t,nh,hd); B,C: (b,t,N); dt,adt: (b,t,nh);
    s_init: (b,nh,N,hd) carry-in. Returns (y (b,t,nh,hd), s_out)."""
    b, t, nh, hd = x.shape
    N = B.shape[-1]
    L = min(chunk, t)
    assert t % L == 0, (t, L)
    c = t // L
    f32 = jnp.float32
    xc = x.reshape(b, c, L, nh, hd).astype(f32)
    Bc = B.reshape(b, c, L, N).astype(f32)
    Cc = C.reshape(b, c, L, N).astype(f32)
    dtc = dt.reshape(b, c, L, nh).astype(f32)
    adtc = adt.reshape(b, c, L, nh).astype(f32)
    cum = jnp.cumsum(adtc, axis=2)                        # inclusive (b,c,L,nh)
    # intra-chunk (dual / attention-like form)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)        # (b,c,L,L)
    dd = cum[:, :, :, None, :] - cum[:, :, None, :, :]    # (b,c,i,j,nh)
    ii = jnp.arange(L)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    w = jnp.where(causal, jnp.exp(dd), 0.0) * dtc[:, :, None, :, :]
    y = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, w, xc)
    # chunk summaries
    decay_out = jnp.exp(cum[:, :, -1, :])                 # (b,c,nh)
    wS = jnp.exp(cum[:, :, -1:, :] - cum) * dtc           # (b,c,L,nh)
    S_chunk = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Bc, wS, xc)
    # inter-chunk: log-depth associative prefix over chunks (TPU-friendly,
    # and fully visible to cost_analysis unlike a while-loop scan)
    def comb(a, b):
        da, sa = a
        db, sb = b
        return da * db, sa * db[:, :, :, None, None] + sb
    d_inc, s_inc = lax.associative_scan(
        comb, (decay_out, S_chunk), axis=1)               # inclusive (b,c,..)
    s0 = s_init.astype(f32)[:, None]                      # (b,1,nh,N,hd)
    # exclusive prefix with carry-in: E_0 = s0; E_c = I_{c−1} + s0·D_{c−1}
    s_shift = jnp.concatenate([jnp.zeros_like(s_inc[:, :1]),
                               s_inc[:, :-1]], axis=1)
    d_shift = jnp.concatenate([jnp.ones_like(d_inc[:, :1]),
                               d_inc[:, :-1]], axis=1)
    s_prefix = s_shift + s0 * d_shift[:, :, :, None, None]
    y = y + jnp.einsum("bcin,bcih,bchnp->bcihp", Cc, jnp.exp(cum), s_prefix)
    # final state: full inclusive combine with the carry-in
    s_last = s_inc[:, -1] + s0[:, 0] * d_inc[:, -1, :, None, None]
    return y.reshape(b, t, nh, hd), s_last


def _device_prefix(axis, decay, state):
    """Hillis–Steele exclusive prefix of (decay, state) over the sequence
    axis. decay: (b,nh); state: (b,nh,N,hd). Monoid: apply segment2 after
    segment1 → (d1·d2, s1·d2 + s2)."""
    P_ = compat.axis_size(axis)
    p = lax.axis_index(axis)
    d_acc, s_acc = decay, state                           # inclusive running
    shift = 1
    while shift < P_:
        perm = [(i, (i + shift) % P_) for i in range(P_)]
        d_in = lax.ppermute(d_acc, axis, perm)
        s_in = lax.ppermute(s_acc, axis, perm)
        valid = (p >= shift).astype(decay.dtype)
        # combine: incoming (earlier) segment before ours
        s_acc = s_in * valid[..., None, None] * d_acc[:, :, None, None] + s_acc
        d_acc = jnp.where(p >= shift, d_in * d_acc, d_acc)
        shift *= 2
    # exclusive = inclusive of device p−1 (identity on device 0)
    perm1 = [(i, (i + 1) % P_) for i in range(P_)]
    d_ex = lax.ppermute(d_acc, axis, perm1)
    s_ex = lax.ppermute(s_acc, axis, perm1)
    first = (p == 0)
    s_ex = jnp.where(first, jnp.zeros_like(s_ex), s_ex)
    return s_ex


def _ssm_local(cfg: ModelConfig, seq_axis, p, x):
    """Mamba2 mixer, per-shard (inside shard_map). x: (b,t,d) local."""
    s = cfg.ssm
    b, t, d = x.shape
    di = s.d_inner(d)
    nh = s.n_heads(d)
    N = s.d_state
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = h @ p["in_proj"]
    z, xin, B, C, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    # causal depthwise conv with cross-shard halo
    xbc = jnp.concatenate([xin, B, C], axis=-1)
    k = s.d_conv
    P_ = compat.axis_size(seq_axis)
    if P_ > 1:
        perm = [(i, (i + 1) % P_) for i in range(P_)]
        tail = lax.ppermute(xbc[:, -(k - 1):], seq_axis, perm)
        tail = jnp.where(lax.axis_index(seq_axis) == 0,
                         jnp.zeros_like(tail), tail)
    else:
        tail = jnp.zeros((b, k - 1, xbc.shape[-1]), xbc.dtype)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"], tail))
    xin, B, C = jnp.split(xbc, [di, di + N], axis=-1)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    adt = a * dtf                                          # (b,t,nh)
    xh = xin.reshape(b, t, nh, -1)
    # cross-device recurrent prefix: local totals first
    f32 = jnp.float32
    decay_tot = jnp.exp(jnp.sum(adt, axis=1))              # (b,nh)
    zero_state = jnp.zeros((b, nh, N, di // nh), f32)
    _, s_total = _ssd_chunked(xh, B, C, dtf, adt, zero_state, s.chunk)
    if P_ > 1:
        s_init = _device_prefix(seq_axis, decay_tot, s_total)
    else:
        s_init = zero_state
    y, _ = _ssd_chunked(xh, B, C, dtf, adt, s_init, s.chunk)
    y = y + p["D"][None, None, :, None] * xh.astype(f32)
    y = y.reshape(b, t, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gln"], cfg.norm_eps)
    return x + (y @ p["out_proj"]).astype(x.dtype)


def ssm_apply(p, x, cfg: ModelConfig, *, mesh, seq_axis="model",
              batch_axes=("data",)):
    """Global-array Mamba2 layer (residual included)."""
    bspec = tuple(batch_axes) if batch_axes else None
    x_s = P(bspec, seq_axis, None)
    pspec = {k: P(*(None,) * p[k].ndim) for k in p}
    fn = compat.shard_map(partial(_ssm_local, cfg, seq_axis), mesh=mesh,
                       in_specs=(pspec, x_s), out_specs=x_s, check_vma=False)
    return fn(p, x)


# ----------------------------------------------------------------- decode

def ssm_decode_step(p, x, state, conv_tail, cfg: ModelConfig):
    """Single-token recurrent update. x: (b,1,d); state: (b,nh,N,hd);
    conv_tail: (b,k−1,conv_ch). Returns (y, state', conv_tail')."""
    s = cfg.ssm
    b, _, d = x.shape
    di = s.d_inner(d)
    nh = s.n_heads(d)
    N = s.d_state
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    zxbcdt = h @ p["in_proj"]
    z, xin, B, C, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    xbc = jnp.concatenate([xin, B, C], axis=-1)            # (b,1,ch)
    window = jnp.concatenate([conv_tail, xbc], axis=1)     # (b,k,ch)
    conv = jnp.sum(window * p["conv_w"].T[None], axis=1) + p["conv_b"]
    xbc1 = jax.nn.silu(conv)                               # (b,ch)
    xin1, B1, C1 = jnp.split(xbc1, [di, di + N], axis=-1)
    dtf = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])
    dec = jnp.exp(a * dtf)                                 # (b,nh)
    xh = xin1.reshape(b, nh, -1).astype(jnp.float32)
    state = state * dec[:, :, None, None] + jnp.einsum(
        "bn,bh,bhp->bhnp", B1.astype(jnp.float32), dtf, xh)
    y = jnp.einsum("bn,bhnp->bhp", C1.astype(jnp.float32), state)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gln"], cfg.norm_eps)
    return x + (y @ p["out_proj"]).astype(x.dtype), state, window[:, 1:]


# ------------------------------------------------------------ test oracle

def ssm_sequential_ref(p, x, cfg: ModelConfig):
    """Token-by-token recurrence oracle (single device, for tests)."""
    s = cfg.ssm
    b, t, d = x.shape
    di = s.d_inner(d)
    nh = s.n_heads(d)
    N = s.d_state
    state = jnp.zeros((b, nh, N, di // nh), jnp.float32)
    tail = jnp.zeros((b, s.d_conv - 1, di + 2 * N), x.dtype)
    outs = []
    for i in range(t):
        y, state, tail = ssm_decode_step(p, x[:, i:i + 1], state, tail, cfg)
        outs.append(y)
    return jnp.concatenate(outs, axis=1)
