"""Sharding rules: mesh axes, FSDP parameter layout, activation specs.

Axis roles (DESIGN.md §4):
  * ``pod``, ``data``  — batch / FSDP axes (ZeRO-3 parameter+optimizer
    sharding, gather-on-use), matching the paper's use of FSDP alongside
    DISTFLASHATTN (§E).
  * ``model``          — the sequence-parallel axis (the paper's P workers);
    also hosts expert parallelism for MoE FFNs.

Parameters are sharded by a path/shape rule: routed-expert stacks shard
their expert dim over ``model`` and their FFN dim over the FSDP axes; every
other ≥2-D tensor shards its largest FSDP-divisible dim; small/1-D tensors
replicate.
"""
from __future__ import annotations

from typing import Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.config import ParallelConfig, ShapeSpec

MOE_EXPERT_KEYS = ("wg", "wu", "wd")


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[name]


def make_parallel_config(mesh: Mesh, shape: ShapeSpec,
                         schedule: str = "balanced",
                         remat: str = "remat_aware") -> ParallelConfig:
    """Resolve axis roles for a given input shape on a given mesh.

    Batch shards over as many of (pod, data) as divide it; for long-context
    decode with batch=1 the freed ``data`` axis is folded into the sequence
    sharding (2D sequence sharding — beyond-paper, DESIGN.md §4).
    """
    names = list(mesh.axis_names)
    cand = [a for a in ("pod", "data") if a in names]
    batch_axes, extra_seq = [], []
    b = shape.global_batch
    for a in cand:
        sz = mesh_axis_size(mesh, a)
        if b % sz == 0 and b >= sz:
            batch_axes.append(a)
            b //= sz
        elif shape.kind == "decode" and a == "data":
            extra_seq.append(a)
    fsdp = tuple(a for a in ("pod", "data") if a in names)
    # a 2D (seq × head) mesh (launch.mesh.make_seq2d_mesh) names its
    # sequence sub-axis "seq" and exposes "head" for the ulysses-style
    # head scatter; legacy meshes keep the single "model" axis
    seq_axis = "seq" if "seq" in names else "model"
    head_axis = "head" if "head" in names else None
    return ParallelConfig(batch_axes=tuple(batch_axes), seq_axis=seq_axis,
                          extra_seq_axes=tuple(extra_seq), fsdp_axes=fsdp,
                          schedule=schedule, remat=remat,
                          head_axis=head_axis)


def _largest_divisible_dim(shape, skip, n):
    best, best_size = None, 0
    for i, s in enumerate(shape):
        if i in skip:
            continue
        if s % n == 0 and s > best_size:
            best, best_size = i, s
    return best


def param_spec(path: str, shape: Tuple[int, ...], par: ParallelConfig,
               fsdp_size: int) -> P:
    """FSDP PartitionSpec for one parameter."""
    spec = [None] * len(shape)
    skip = set()
    if "moe" in path and path.split("/")[-1] in MOE_EXPERT_KEYS:
        # (L?, E, d, de): expert dim → seq axis
        e_dim = len(shape) - 3
        spec[e_dim] = par.seq_axis
        skip.add(e_dim)
    if fsdp_size > 1:
        i = _largest_divisible_dim(shape, skip | {j for j, s in
                                                  enumerate(shape) if
                                                  spec[j] is not None}, fsdp_size)
        # never FSDP-shard the stacked-layer dim (dim 0 of stacked params) if
        # another dim qualifies; prefer the last dims
        if i is not None and len(shape) >= 2:
            spec[i] = tuple(par.fsdp_axes) if len(par.fsdp_axes) > 1 \
                else par.fsdp_axes[0]
    return P(*spec)


def param_shardings(params, mesh: Mesh, par: ParallelConfig):
    """NamedShardings for a parameter pytree (keyed by tree path)."""
    fsdp_size = 1
    for a in par.fsdp_axes:
        fsdp_size *= mesh_axis_size(mesh, a)

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        pstr = "/".join(str(getattr(k, 'key', getattr(k, 'idx', k)))
                        for k in path)
        if leaf.ndim <= 1:
            specs.append(P())
        else:
            specs.append(param_spec(pstr, leaf.shape, par, fsdp_size))
    specs = jax.tree_util.tree_unflatten(treedef, specs)
    return compat.tree_map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def act_spec(par: ParallelConfig, seq_sharded=True) -> P:
    b = tuple(par.batch_axes) if par.batch_axes else None
    if not seq_sharded:
        return P(b, None, None)
    s = par.seq_axes if len(par.seq_axes) > 1 else par.seq_axis
    return P(b, s, None)


def batch_spec(par: ParallelConfig) -> P:
    b = tuple(par.batch_axes) if par.batch_axes else None
    s = par.seq_axes if len(par.seq_axes) > 1 else par.seq_axis
    return P(b, s)


def constrain(x, mesh, spec: P):
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
