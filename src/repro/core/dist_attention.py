"""DISTFLASHATTN — the paper's core contribution, as JAX shard_map code.

Sequence-parallel exact attention over the ``model`` mesh axis (the paper's
``P`` workers).  Since the schedule-plan IR rewrite, the ring / balanced /
zigzag schedules (and the MLA latent ring) are **~30-line plan builders**
in :mod:`repro.core.schedule`: each builds a static
:class:`~repro.core.schedule.SchedulePlan` — per ring step, a declarative
list of Work items (q/kv chunk sources, the step's static MaskSpec,
validity predicates, result routing) — and one shared forward executor and
one shared backward executor run any plan with the ppermute-prefetch
overlap, traveling-``dkv`` accumulators, and segment-ID machinery
implemented exactly once.  Schedules (validated in
``DistAttnSpec.__post_init__`` — unknown names raise instead of silently
running the ring):

* ``balanced`` — the paper's load-balanced schedule (§3.2, Alg. 2):
  ``⌊P/2⌋`` ring steps; workers with unfinished causal work compute
  ``attn(q_p, kv_{p−t})`` while *helpers* (workers whose causal prefix is
  done) compute ``attn(q_{(h−t) mod P}, kv_h)`` on behalf of heavy workers
  and ship the partial ``(o, lse)`` back for a ``rescale`` merge. Idle
  fraction ``1/(2P)`` (even P) / ``0`` (odd P). Causal-kind masks
  (document and — new with the plan IR — sliding windows, which truncate
  the plan to its needed steps).
* ``ring`` — vanilla DISTFLASHATTN (§3.1, Alg. 1): ``P−1`` steps, workers
  idle once their causal prefix is exhausted (idle fraction → 1/2). Also
  used for bidirectional encoders (where causal imbalance doesn't exist —
  paper §F discussion); sliding windows truncate the ring tail
  (Appendix F: "change the end condition of the for loop").
* ``zigzag`` — beyond-paper balanced placement (2P half-chunks, device p
  holds (p, 2P−1−p)): exact balance with only the KV ring.  Windowed
  masks run through dynamic-offset step masks and skip the *middle* ring
  steps (both sequence ends are local under the mirror placement).
  Contract: global arrays are pre-permuted with :func:`zigzag_perm`.
* ``ulysses`` — DeepSpeed-Ulysses head-parallel baseline (all-to-all);
  raises on head counts not divisible by P (paper §4.2/§4.6).
* ``rsa`` — Ring Self-Attention baseline (Li et al., 2021): all-gathers
  K and V and materializes the full score matrix (no memory-efficient
  attention). Benchmark baseline only.
* ``auto`` — pick the cheapest capable schedule for the (MaskSpec, P,
  shapes) at trace time via the plans' static comm/compute cost model
  (:func:`repro.core.schedule.choose_schedule`, wired into
  ``analysis/roofline.py``).  Candidates: balanced, ring, and — when the
  head counts divide P — ulysses.  zigzag is excluded (its global-layout
  permutation is a caller contract) and rsa is benchmark-only.

Masking is a declarative :class:`repro.core.mask.MaskSpec` carried by
``DistAttnSpec.mask``; the plan builders derive each step's spec
statically and **skip provably all-masked steps**.  Packed-sequence
(document) masking is first-class: dynamic per-token ``segments`` travel
the ring alongside K/V, while static ``document(boundaries=…)`` layouts
need no arrays at all — the executor derives each chunk's segment IDs
from the boundaries at trace time and the builders prune ring steps no
document spans.  Prefix-LM masks need absolute positions on every chunk —
they are served by ``ulysses``/``rsa`` or a single-shard axis, and
rejected elsewhere at spec-construction time.

Communication/computation overlap (§3.2, Eq. 3) is expressed in dataflow:
the ``ppermute`` producing step ``t+1``'s chunk is issued *before* step
``t``'s compute and has no data dependence on it, so XLA's latency-hiding
scheduler overlaps the ICI transfer with the attention kernel (the TPU
analogue of the paper's second CUDA stream).

The backward pass is hand-written (exposed as :func:`dist_attn_bwd`) so the
rematerialization-aware checkpointing combinator (core/remat.py) can invoke
it directly from saved ``(o, lse)`` — the FlashAttention forward is never
recomputed, and neither is its forward communication (§3.3).

All functions here are *local* (per-shard) code meant to run inside
``jax.shard_map``; :func:`dist_flash_attn` is the user-facing wrapper that
applies shard_map and registers the custom VJP.  The frozen seed
implementations of the hand-written loops live in
``core/legacy_schedules.py`` purely as differential-test references.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import mask as mk
from repro.core import schedule as sp
from repro.core.attention import chunk_attn, chunk_attn_bwd
from repro.core.mask import MaskSpec
from repro.kernels.ref import NEG_INF


# --------------------------------------------------------------------------
# Schedule configuration
# --------------------------------------------------------------------------

SCHEDULES = ("auto", "balanced", "ring", "rsa", "ulysses", "zigzag")

_MASK_HINT = ("mask=repro.core.mask.{full,causal,sliding_window,prefix_lm,"
              "document}(...)")


@dataclasses.dataclass(frozen=True)
class Mesh2DSpec:
    """Factored 2D (sequence × head) mesh axis pair for one distributed-
    attention call: the ``axis_size = r·u`` sequence-parallel workers form
    an (``seq_axis`` = r) × (``head_axis`` = u) grid.  The global sequence
    is sharded over the *pair* (seq major, head minor); the executor
    head-scatters q/k/v over ``head_axis`` (ulysses-style, GQA-aware) and
    runs a ring-family SchedulePlan over ``seq_axis`` — BurstAttention's
    inter-node ring / intra-node head split as a plan wrapper (see
    core/schedule.Plan2D)."""
    r: int
    u: int
    seq_axis: str = "seq"
    head_axis: str = "head"

    def __post_init__(self):
        if self.r < 1 or self.u < 1:
            raise ValueError(f"Mesh2DSpec needs r, u >= 1 "
                             f"(got r={self.r}, u={self.u})")
        if self.seq_axis == self.head_axis:
            raise ValueError("Mesh2DSpec seq_axis and head_axis must be "
                             "distinct mesh axes")


@dataclasses.dataclass(frozen=True)
class DistAttnSpec:
    """Static description of one distributed-attention call site.

    ``schedule`` ∈ ``auto | balanced | ring | rsa | ulysses | zigzag``
    (validated — a typo raises instead of silently running the ring
    schedule).  ``auto`` defers the choice to trace time, where the
    shapes are known and the plans' cost model ranks the candidates.
    ``mask`` is the MaskSpec of the *whole* (unsharded) attention; the
    plan builders derive per-step specs from it.

    ``mesh2d`` factors the ``axis_size`` workers into a (seq = r,
    head = u) grid (:class:`Mesh2DSpec`): the ring-family schedules then
    run on the ``seq`` sub-axis after a head scatter on the ``head``
    sub-axis, and ``axis``/``seq_axes`` is ignored in favor of the pair.
    At ``r == 1`` the inner plan is one local full-sequence kernel, so
    *any* mask kind is servable — including prefix_lm backward, which no
    1D multi-shard schedule can express.

    The pre-MaskSpec ``causal=``/``window=`` constructor kwargs are
    **removed** — passing them raises ``TypeError`` with the migration
    hint (they survived five PRs as deprecation shims with zero in-repo
    callers).
    """
    axis: str = "model"            # sequence-parallel mesh axis
    axis_size: int = 1             # P (= r·u with mesh2d)
    schedule: str = "balanced"     # see SCHEDULES
    mask: Optional[MaskSpec] = None
    # removed legacy kwargs — kept as init-only slots so passing them by
    # name raises our TypeError with the migration hint
    causal: dataclasses.InitVar[Optional[bool]] = None
    window: dataclasses.InitVar[Optional[int]] = None
    scale: Optional[float] = None
    # attention backend name resolved via repro.kernels.registry (None =
    # process default); capability/platform fallback happens at resolve time
    impl: Optional[str] = None
    # per-call-site kernel tile hints, forwarded to tunable backends only
    # (Pallas block shapes / chunked-lax scan chunk). None = backend default.
    block_q: Optional[int] = None
    block_kv: Optional[int] = None
    mesh2d: Optional[Mesh2DSpec] = None

    def __post_init__(self, causal, window):
        if causal is not None or window is not None:
            raise TypeError(
                "DistAttnSpec(causal=, window=) was removed; pass "
                + _MASK_HINT)
        if self.schedule not in SCHEDULES:
            raise ValueError(
                f"unknown schedule {self.schedule!r}; valid: {SCHEDULES}")
        if self.mask is None:
            # the spec-level default mask is causal (unlike chunk_attn's)
            object.__setattr__(self, "mask", mk.causal())
        m = self.mask
        if m.q_offset or m.kv_offset:
            raise ValueError("DistAttnSpec.mask must be offset-free — the "
                             "schedules derive per-step offsets")
        ring_P = self.axis_size
        if self.mesh2d is not None:
            md = self.mesh2d
            if md.r * md.u != self.axis_size:
                raise ValueError(
                    f"mesh2d r·u = {md.r * md.u} must equal "
                    f"axis_size = {self.axis_size}")
            if self.schedule not in ("auto",) + sp.PLAN_SCHEDULES:
                raise ValueError(
                    f"2D (seq×head) attention runs ring-family plans only "
                    f"(got {self.schedule!r}); the ulysses/rsa baselines "
                    f"have their own 1D topology")
            # capability follows the *seq* sub-axis: at r == 1 the inner
            # plan is one local full-sequence kernel — any mask kind goes
            ring_P = md.r
        if ring_P > 1:
            if self.schedule in ("balanced", "zigzag") and \
                    not (m.causal and not m.prefix_len):
                raise ValueError(
                    f"{self.schedule!r} handles causal-kind masks only "
                    f"(got {m.kind!r}); use ring/ulysses")
            # rsa/ulysses serve prefix_lm forward-only (absolute positions
            # exist there); their backward — the ring — rejects it below
            if m.prefix_len and self.schedule == "ring":
                raise ValueError(
                    "prefix_lm needs absolute kv positions, which the "
                    "ring schedule's per-shard chunks don't have; use "
                    "ulysses/rsa, a 2D mesh with r == 1, or a "
                    "single-shard axis")
            if m.window and self.schedule == "rsa":
                raise ValueError("rsa baseline has no sliding-window path")
            if m.window and not m.causal and self.schedule == "ring":
                raise ValueError(
                    "a non-causal sliding window needs future-direction "
                    "band steps the ring's strictly-past step masks can't "
                    "express; use ulysses, a 2D mesh with r == 1, or a "
                    "single-shard axis")

    @property
    def seq_entry(self):
        """The PartitionSpec sequence entry: the 2D axis pair (seq major,
        head minor) when factored, else the single ``axis``."""
        if self.mesh2d is not None:
            return (self.mesh2d.seq_axis, self.mesh2d.head_axis)
        return self.axis


def _tune(spec: DistAttnSpec) -> dict:
    """chunk_attn tuning kwargs carried by the spec (scale + tile hints)."""
    return dict(scale=spec.scale, impl=spec.impl, block_q=spec.block_q,
                block_kv=spec.block_kv)


def _seg_kw(mask: MaskSpec, q_seg, kv_seg) -> dict:
    """Segment operands, only when the mask consumes them."""
    if not mask.document or q_seg is None:
        return {}
    return dict(q_segments=q_seg, kv_segments=kv_seg)


def resolve_schedule(spec: DistAttnSpec, q, k, v, seg=None, *,
                     for_bwd: bool = False) -> str:
    """Concrete schedule for this call.  ``auto`` ranks the capable
    candidates by the static plan cost model; ``for_bwd`` tells the
    capability filter whether the choice must also serve the distributed
    backward (the forward-only baselines are then excluded — the filter
    mirrors the runtime raise conditions exactly, so a resolved name
    never raises at execution time).  On a 2D mesh the factorization is
    fixed by the spec and only the inner seq-axis schedule is chosen."""
    if spec.schedule != "auto":
        return spec.schedule
    kw = dict(B=q.shape[0], Hq=q.shape[2], Hkv=k.shape[2], Dqk=q.shape[3],
              Dv=v.shape[3], bpe=q.dtype.itemsize,
              dynamic_seg=seg is not None, include_bwd=for_bwd)
    if spec.mesh2d is not None:
        return sp.choose_inner_schedule(spec.mask, spec.mesh2d.r,
                                        spec.mesh2d.u, Tl_dev=q.shape[1],
                                        **kw)
    return sp.choose_schedule(spec.mask, spec.axis_size, Tl=q.shape[1],
                              **kw)


# --------------------------------------------------------------------------
# Bespoke baselines (not plan-based: different communication topology)
# --------------------------------------------------------------------------

def _fwd_ulysses(spec, q, k, v, seg=None):
    """DeepSpeed-Ulysses baseline (Jacobs et al., 2023): all-to-all the
    sequence-sharded q/k/v into head-sharded layout, run ordinary (local)
    FlashAttention over the full sequence, all-to-all back. Requires the
    head counts to be divisible by P — exactly the limitation the paper
    targets (§4.2, §4.6); we raise otherwise (Megatron would pad heads)."""
    P_ = spec.axis_size
    Hq, Hkv = q.shape[2], k.shape[2]
    if Hq % P_ or Hkv % P_:
        raise ValueError(
            f"ulysses needs heads % P == 0 (got Hq={Hq}, Hkv={Hkv}, P={P_})"
            " — the head-divisibility limitation of head-parallel attention")
    def a2a(x, fwd=True):
        if fwd:   # scatter heads, gather sequence
            return lax.all_to_all(x, spec.axis, split_axis=2, concat_axis=1,
                                  tiled=True)
        return lax.all_to_all(x, spec.axis, split_axis=1, concat_axis=2,
                              tiled=True)
    qh, kh, vh = a2a(q), a2a(k), a2a(v)          # (B, T_glob, H/P, D)
    m = spec.mask
    skw = {}
    if seg is not None and m.document:
        seg_g = lax.all_gather(seg, spec.axis, axis=1, tiled=True)
        skw = dict(q_segments=seg_g, kv_segments=seg_g)
    o, s = chunk_attn(qh, kh, vh, mask=m, **skw, **_tune(spec))
    # lse (B, T_glob, H/P) -> (B, T_loc, H): split seq, concat heads
    s_back = lax.all_to_all(s, spec.axis, split_axis=1, concat_axis=2,
                            tiled=True)
    return a2a(o, fwd=False), s_back


def _fwd_rsa(spec, q, k, v, seg=None):
    """Ring Self-Attention baseline: all-gather KV, materialize scores."""
    if spec.mask.needs_segments and seg is None:
        raise ValueError("document mask without boundaries needs segments=")
    kg = lax.all_gather(k, spec.axis, axis=1, tiled=True)
    vg = lax.all_gather(v, spec.axis, axis=1, tiled=True)
    p = lax.axis_index(spec.axis)
    Tc = q.shape[1]
    B, Tq, Hq, D = q.shape
    Hkv = kg.shape[2]
    g = Hq // Hkv
    m = spec.mask
    scale = spec.scale or 1.0 / (D ** 0.5)
    kf = jnp.repeat(kg, g, axis=2) if g > 1 else kg
    vf = jnp.repeat(vg, g, axis=2) if g > 1 else vg
    sc = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                    kf.astype(jnp.float32)) * scale
    if m.needs_mask:
        # same MaskSpec.allow semantics as the kernels, with this shard's
        # traced absolute query positions and the gathered global keys
        qpos = p * Tc + jnp.arange(Tq)
        kpos = jnp.arange(kg.shape[1])
        qs = ks = None
        if m.document and seg is not None:
            sg = lax.all_gather(seg, spec.axis, axis=1, tiled=True)
            qs, ks = seg[:, :, None], sg[:, None, :]
        allow = m.allow(qpos[:, None], kpos[None, :], qs, ks)
        allow = allow[None, None] if allow.ndim == 2 else allow[:, None]
        sc = jnp.where(allow, sc, NEG_INF)
    w = jax.nn.softmax(sc, axis=-1)                  # full P×-size matrix
    o = jnp.einsum("bhqk,bkhd->bqhd", w, vf.astype(jnp.float32))
    lse = jax.scipy.special.logsumexp(sc, axis=-1).transpose(0, 2, 1)
    return o.astype(q.dtype), lse


# --------------------------------------------------------------------------
# Public API: explicit fwd/bwd + custom-VJP wrapper, shard_mapped
# --------------------------------------------------------------------------

def _plan2d(spec, sched, q, k):
    md = spec.mesh2d
    # at r == 1 every ring-family schedule degenerates to the same local
    # full-sequence kernel — canonicalize so build stays capability-exact
    sched = "ring" if md.r == 1 else sched
    return sp.build_plan2d(sched, spec.mask, md.r, md.u, q.shape[1],
                           Hq=q.shape[2], Hkv=k.shape[2])


def _fwd_local(spec, q, k, v, seg=None):
    if spec.axis_size == 1:
        m = spec.mask
        return chunk_attn(q, k, v, mask=m, **_seg_kw(m, seg, seg),
                          **_tune(spec))
    sched = resolve_schedule(spec, q, k, v, seg)
    if spec.mesh2d is not None:
        md = spec.mesh2d
        if md.u == 1:       # degenerate factorization: plain 1D seq plan
            plan = sp.build_plan(sched, spec.mask, md.r, q.shape[1])
            return sp.execute_fwd(plan, q, k, v, seg, axis=md.seq_axis,
                                  tune=_tune(spec))
        return sp.execute2d_fwd(_plan2d(spec, sched, q, k), q, k, v, seg,
                                seq_axis=md.seq_axis,
                                head_axis=md.head_axis, tune=_tune(spec))
    if sched == "rsa":
        return _fwd_rsa(spec, q, k, v, seg)
    if sched == "ulysses":
        return _fwd_ulysses(spec, q, k, v, seg)
    plan = sp.build_plan(sched, spec.mask, spec.axis_size, q.shape[1])
    return sp.execute_fwd(plan, q, k, v, seg, axis=spec.axis,
                          tune=_tune(spec))


def _bwd_local(spec, q, k, v, o, s, do, seg=None):
    if spec.axis_size == 1:
        m = spec.mask
        return chunk_attn_bwd(q, k, v, o, s, do, mask=m,
                              **_seg_kw(m, seg, seg), **_tune(spec))
    sched = resolve_schedule(spec, q, k, v, seg, for_bwd=True)
    if spec.mesh2d is not None:
        md = spec.mesh2d
        if md.u == 1:
            plan = sp.build_plan(sched, spec.mask, md.r, q.shape[1])
            return sp.execute_bwd(plan, q, k, v, o, s, do, seg,
                                  axis=md.seq_axis, tune=_tune(spec))
        return sp.execute2d_bwd(_plan2d(spec, sched, q, k), q, k, v, o, s,
                                do, seg, seq_axis=md.seq_axis,
                                head_axis=md.head_axis, tune=_tune(spec))
    if sched in ("rsa", "ulysses"):
        # the baselines reuse the exact ring backward — which cannot
        # express absolute coordinates (prefix masks) in its per-shard
        # chunks; static document boundaries ARE expressible (the plan
        # executor derives per-shard segment IDs from them)
        if spec.mask.prefix_len:
            raise ValueError("prefix_lm distributed backward needs "
                             "axis_size == 1 (fwd-only baselines "
                             "support it)")
        if spec.mask.window and not spec.mask.causal:
            raise ValueError("non-causal sliding-window distributed "
                             "backward needs axis_size == 1 (the ring "
                             "backward the baselines reuse can't see "
                             "future-direction bands)")
        sched = "ring"
    plan = sp.build_plan(sched, spec.mask, spec.axis_size, q.shape[1])
    return sp.execute_bwd(plan, q, k, v, o, s, do, seg, axis=spec.axis,
                          tune=_tune(spec))


def _specs(batch_axes, seq):
    """``seq`` is the sequence-dim PartitionSpec entry: one axis name or
    the 2D (seq, head) axis pair."""
    b = tuple(batch_axes) if batch_axes else None
    qkv = P(b, seq, None, None)
    lse = P(b, seq, None)
    seg = P(b, seq)
    return qkv, lse, seg


def dist_attn_fwd(q, k, v, *, mesh, spec: DistAttnSpec,
                  batch_axes=("data",), segments=None):
    """Distributed forward → (o, lse). Global-array in/out (GSPMD land).
    ``segments`` is a (B, T) int32 document-ID array sharded like the
    activations (document masks only)."""
    qkv_s, lse_s, seg_s = _specs(batch_axes, spec.seq_entry)
    in_specs, args = [qkv_s] * 3, [q, k, v]
    if segments is not None:
        in_specs.append(seg_s)
        args.append(segments)
    fn = compat.shard_map(partial(_fwd_local, spec), mesh=mesh,
                          in_specs=tuple(in_specs),
                          out_specs=(qkv_s, lse_s), check_vma=False)
    return fn(*args)


def dist_attn_bwd(q, k, v, o, lse, do, *, mesh, spec: DistAttnSpec,
                  batch_axes=("data",), segments=None):
    """Distributed backward from saved (o, lse) → (dq, dk, dv)."""
    qkv_s, lse_s, seg_s = _specs(batch_axes, spec.seq_entry)
    in_specs = [qkv_s, qkv_s, qkv_s, qkv_s, lse_s, qkv_s]
    args = [q, k, v, o, lse, do]
    if segments is not None:
        in_specs.append(seg_s)
        args.append(segments)
    fn = compat.shard_map(partial(_bwd_local, spec), mesh=mesh,
                          in_specs=tuple(in_specs),
                          out_specs=(qkv_s, qkv_s, qkv_s), check_vma=False)
    return fn(*args)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _dist_flash_attn(q, k, v, mesh, spec, batch_axes):
    return dist_attn_fwd(q, k, v, mesh=mesh, spec=spec,
                         batch_axes=batch_axes)


def _cvjp_fwd(q, k, v, mesh, spec, batch_axes):
    o, lse = dist_attn_fwd(q, k, v, mesh=mesh, spec=spec,
                           batch_axes=batch_axes)
    return (o, lse), (q, k, v, o, lse)


def _cvjp_bwd(mesh, spec, batch_axes, res, cts):
    q, k, v, o, lse = res
    do, _ = cts
    dq, dk, dv = dist_attn_bwd(q, k, v, o, lse, do, mesh=mesh, spec=spec,
                               batch_axes=batch_axes)
    return dq, dk, dv


_dist_flash_attn.defvjp(_cvjp_fwd, _cvjp_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _dist_flash_attn_seg(q, k, v, segments, mesh, spec, batch_axes):
    return dist_attn_fwd(q, k, v, mesh=mesh, spec=spec,
                         batch_axes=batch_axes, segments=segments)


def _cvjp_seg_fwd(q, k, v, segments, mesh, spec, batch_axes):
    o, lse = dist_attn_fwd(q, k, v, mesh=mesh, spec=spec,
                           batch_axes=batch_axes, segments=segments)
    return (o, lse), (q, k, v, segments, o, lse)


def _cvjp_seg_bwd(mesh, spec, batch_axes, res, cts):
    q, k, v, segments, o, lse = res
    do, _ = cts
    dq, dk, dv = dist_attn_bwd(q, k, v, o, lse, do, mesh=mesh, spec=spec,
                               batch_axes=batch_axes, segments=segments)
    # integer segment IDs take a float0 cotangent
    dseg = np.zeros(segments.shape, jax.dtypes.float0)
    return dq, dk, dv, dseg


_dist_flash_attn_seg.defvjp(_cvjp_seg_fwd, _cvjp_seg_bwd)


def dist_flash_attn(q, k, v, mesh, spec, batch_axes=("data",),
                    segments=None):
    """DISTFLASHATTN with autodiff. Returns (o, lse); lse is a residual
    output (its cotangent is ignored, as in the paper's kernel).
    ``segments`` (document masks) is non-differentiable."""
    if segments is None:
        return _dist_flash_attn(q, k, v, mesh, spec, batch_axes)
    return _dist_flash_attn_seg(q, k, v, segments, mesh, spec, batch_axes)


# --------------------------------------------------------------------------
# Decode-time distributed attention (flash-decoding over sequence shards)
# --------------------------------------------------------------------------

def _decode_local(seq_axes, shard_len, window, scale, has_pos, q, kc, vc,
                  k1, v1, pos=None):
    """q: (B,1,Hq,D) replicated over seq axes; kc/vc: (B,S_loc,Hkv,Dk/Dv)
    local cache shards; k1/v1: (B,1,...) the new token's k/v (replicated).
    ``pos`` (B,) — per-request valid-context lengths: request b's new token
    sits at position pos[b] and only cache slots < pos[b] are attendable
    (window measured from pos[b]). Without ``pos`` (legacy), the whole
    cache is context: S_global cached + 1 new token at position S_global."""
    # linearized shard index over (possibly multiple) sequence axes
    idx = jnp.int32(0)
    for ax in seq_axes:
        idx = idx * compat.axis_size(ax) + lax.axis_index(ax)
    n_shards = 1
    for ax in seq_axes:
        n_shards *= compat.axis_size(ax)
    S_total = n_shards * shard_len
    offset = idx * shard_len
    B, _, Hq, Dq = q.shape
    Hkv = kc.shape[2]
    g = Hq // Hkv
    sc = scale if scale is not None else 1.0 / (Dq ** 0.5)
    qf = q.astype(jnp.float32)
    kf = jnp.repeat(kc, g, axis=2) if g > 1 else kc
    vf = jnp.repeat(vc, g, axis=2) if g > 1 else vc
    s_loc = jnp.einsum("bqhd,bkhd->bhqk", qf, kf.astype(jnp.float32)) * sc
    kpos = (offset + jnp.arange(shard_len))[None, None, None, :]
    if has_pos:
        # per-request masking: slot j attendable iff j < pos_b (and inside
        # the sliding window measured from the new token at pos_b)
        pb = pos[:, None, None, None]
        ok = kpos < pb
        if window and window > 0:
            ok = ok & (kpos > pb - window)
        s_loc = jnp.where(ok, s_loc, NEG_INF)
    elif window and window > 0:
        # legacy: new token position = S_total; attendable cache slots are
        # those with pos > S_total − window
        s_loc = jnp.where(kpos > S_total - window, s_loc, NEG_INF)
    m_loc = jnp.max(s_loc, axis=-1)                      # (B,H,1)
    m_glb = lax.pmax(m_loc, seq_axes)
    m_safe = jnp.maximum(m_glb, NEG_INF / 2)
    p_loc = jnp.exp(s_loc - m_safe[..., None])
    p_loc = jnp.where(m_loc[..., None] <= NEG_INF / 2,
                      jnp.zeros_like(p_loc), p_loc)
    num = jnp.einsum("bhqk,bkhd->bhqd", p_loc, vf.astype(jnp.float32))
    den = jnp.sum(p_loc, axis=-1)                        # (B,H,1)
    num = lax.psum(num, seq_axes)
    den = lax.psum(den, seq_axes)
    lse_c = jnp.where(den == 0.0, NEG_INF, m_safe + jnp.log(
        jnp.where(den == 0.0, 1.0, den)))                # (B,H,1) cache lse
    o_c = num / jnp.where(den == 0.0, 1.0, den)[..., None]
    o_c = jnp.where((den == 0.0)[..., None], 0.0, o_c)
    # merge with the new token's self-attention (replicated, added once —
    # after the cross-shard psum so it isn't multiply counted)
    k1r = jnp.repeat(k1, g, axis=2) if g > 1 else k1
    v1r = jnp.repeat(v1, g, axis=2) if g > 1 else v1
    s1 = jnp.einsum("bqhd,bkhd->bhqk", qf, k1r.astype(jnp.float32)) * sc
    lse1 = s1[..., 0]                                    # (B,H,1): one key
    o1 = v1r.astype(jnp.float32).transpose(0, 2, 1, 3)   # (B,Hq,1,Dv)
    o_m, _ = _merge_bh(o_c, lse_c, o1, lse1)
    return o_m.transpose(0, 2, 1, 3).astype(q.dtype)     # (B,1,Hq,Dv)


def _merge_bh(o1, lse1, o2, lse2):
    """merge in (B,H,1,D)/(B,H,1) layout."""
    mx = jnp.maximum(jnp.maximum(lse1, lse2), NEG_INF)
    w1 = jnp.exp(lse1 - mx)
    w2 = jnp.exp(lse2 - mx)
    den = w1 + w2
    den_s = jnp.where(den == 0.0, 1.0, den)
    o = (o1 * w1[..., None] + o2 * w2[..., None]) / den_s[..., None]
    return o, mx + jnp.log(den_s)


def dist_decode_attn(q, k_cache, v_cache, k_new, v_new, *, mesh,
                     seq_axes=("model",), batch_axes=("data",),
                     mask: Optional[MaskSpec] = None, window=None,
                     scale=None, shard_len=None, pos=None):
    """One-token decode against a sequence-sharded KV cache.

    The cache's sequence dim is sharded over ``seq_axes`` (supports the 2D
    (data, model) sharding used by long_500k); the query and the new token's
    k/v are replicated across them. Exact lse-weighted combine across shards
    (distributed flash-decoding), then a final merge with the new token's
    self-attention.

    ``mask`` is a :class:`~repro.core.mask.MaskSpec` of kind ``causal``
    (attend the whole cache — the default) or ``sliding_window``; the new
    token always sits at the end of the context, so those are the only
    kinds decode can express.  The pre-MaskSpec ``window=`` kwarg is
    removed — passing it raises ``TypeError`` with the migration hint.

    ``pos`` (B,) int32 — per-request valid-context lengths (continuous
    batching admits requests at different times, so each batch row has its
    own position): cache slots ≥ pos[b] are masked for request b and the
    sliding window is measured from pos[b].  ``pos=None`` keeps the legacy
    whole-cache semantics; a scalar ``pos`` is broadcast over the batch
    with a one-shot DeprecationWarning (it silently mis-masks mixed-length
    batches).
    """
    if window is not None:
        raise TypeError(
            "dist_decode_attn(window=) was removed; pass "
            "mask=repro.core.mask.{causal,sliding_window}(...)")
    if mask is None:
        mask = mk.causal()
    if mask.kinds - {"causal", "sliding_window"}:
        raise ValueError(
            f"dist_decode_attn serves causal/sliding_window masks only "
            f"(got {mask.kind!r}) — the decode token is last, so other "
            f"kinds have no decode meaning")
    if mask.q_offset or mask.kv_offset:
        raise ValueError("dist_decode_attn mask must be offset-free — "
                         "decode positions are derived from the cache "
                         "layout")
    w = mask.window
    n = 1
    for ax in seq_axes:
        n *= dict(zip(mesh.axis_names, mesh.devices.shape))[ax]
    if shard_len is None:
        shard_len = k_cache.shape[1] // n
    b = tuple(batch_axes) if batch_axes else None
    seq = tuple(seq_axes) if len(seq_axes) > 1 else seq_axes[0]
    rep = P(b, None, None, None)
    shd = P(b, seq, None, None)
    in_specs = [rep, shd, shd, rep, rep]
    args = [q, k_cache, v_cache, k_new, v_new]
    if pos is not None:
        pos = jnp.asarray(pos)
        if pos.ndim == 0:
            mk.warn_legacy_once(
                "dist_decode_attn(pos=<scalar>)",
                "a (B,) per-request position vector")
            pos = jnp.broadcast_to(pos, (q.shape[0],))
        in_specs.append(P(b))
        args.append(pos)
    fn = compat.shard_map(
        partial(_decode_local, tuple(seq_axes), shard_len, w, scale,
                pos is not None),
        mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=rep, check_vma=False)
    return fn(*args)


# --------------------------------------------------------------------------
# Zigzag layout helpers + the MLA latent ring (plan-based)
# --------------------------------------------------------------------------

def zigzag_perm(T: int, P: int):
    """Natural→zigzag permutation: new global array order is
    [chunk 0, chunk 2P−1 | chunk 1, chunk 2P−2 | …] so contiguous device
    shards hold (p, 2P−1−p). Returns an index array of length T."""
    c = T // (2 * P)
    order = []
    for p in range(P):
        order.append(np.arange(p * c, (p + 1) * c))
        q = 2 * P - 1 - p
        order.append(np.arange(q * c, (q + 1) * c))
    return np.concatenate(order)


# BEYOND-PAPER: MLA latent ring. For DeepSeek MLA the materialized per-head
# K/V chunk is n_heads·(d_qk+d_v) wide (v3: 128·320 = 40960/token) while the
# latent it is deterministically derived from is kv_lora+rope = 576/token —
# a 71× comm reduction if the ring ships the latent and every worker
# up-projects locally (recompute-over-communicate, the same trade the
# paper's §3.3 makes for time). Composed with the zigzag placement the
# schedule is also load-balanced with no helper sends.  Since the plan IR
# rewrite this is the *same zigzag plan* run with a latent payload on the
# KV ring (``execute_fwd(..., latent=...)``).

def _fwd_latent_local(spec, expand, q, k, v, payload, w_up):
    plan = sp.build_plan("zigzag", spec.mask, spec.axis_size, q.shape[1])
    return sp.execute_fwd(plan, q, k, v, None, axis=spec.axis,
                          tune=_tune(spec), latent=(payload, w_up, expand))


def dist_attn_fwd_latent(q, k, v, payload, w_up, expand, *, mesh, spec,
                         batch_axes=("data",)):
    """Latent-ring forward (zigzag schedule). ``payload``: (B, T, d_lat)
    sharded like activations; ``w_up``: replicated up-projection weights;
    ``expand(payload_chunk, w_up) -> (k, v)`` pure."""
    if spec.mask.kinds - {"causal"}:
        raise ValueError("latent ring supports plain causal masks only "
                         f"(got {spec.mask.kind!r})")
    b = tuple(batch_axes) if batch_axes else None
    qkv_s = P(b, spec.axis, None, None)
    pl_s = P(b, spec.axis, None)
    lse_s = P(b, spec.axis, None)
    w_s = compat.tree_map(lambda a: P(*(None,) * a.ndim), w_up)
    fn = compat.shard_map(
        partial(_fwd_latent_local, spec, expand), mesh=mesh,
        in_specs=(qkv_s, qkv_s, qkv_s, pl_s, w_s),
        out_specs=(qkv_s, lse_s), check_vma=False)
    return fn(q, k, v, payload, w_up)
