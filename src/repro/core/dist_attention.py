"""DISTFLASHATTN — the paper's core contribution, as JAX shard_map code.

Sequence-parallel exact attention over the ``model`` mesh axis (the paper's
``P`` workers). Schedules (validated in ``DistAttnSpec.__post_init__`` —
unknown names raise instead of silently running the ring):

* ``balanced`` — the paper's load-balanced schedule (§3.2, Alg. 2):
  ``⌊P/2⌋`` ring steps; workers with unfinished causal work compute
  ``attn(q_p, kv_{p−t})`` while *helpers* (workers whose causal prefix is
  done) compute ``attn(q_{(h−t) mod P}, kv_h)`` on behalf of heavy workers
  and ship the partial ``(o, lse)`` back for a ``rescale`` merge. Idle
  fraction ``1/(2P)`` (even P) / ``0`` (odd P). Causal-kind masks only
  (document included).
* ``ring`` — vanilla DISTFLASHATTN (§3.1, Alg. 1): ``P−1`` steps, workers
  idle once their causal prefix is exhausted (idle fraction → 1/2). Also
  used for bidirectional encoders (where causal imbalance doesn't exist —
  paper §F discussion) and for the sliding-window variant (Appendix F:
  "change the end condition of the for loop").
* ``zigzag`` — beyond-paper balanced placement, see the section below.
* ``ulysses`` — DeepSpeed-Ulysses head-parallel baseline (all-to-all);
  raises on head counts not divisible by P (paper §4.2/§4.6).
* ``rsa`` — Ring Self-Attention baseline (Li et al., 2021): all-gathers
  K and V and materializes the full score matrix (no memory-efficient
  attention). Benchmark baseline only.

Masking is a declarative :class:`repro.core.mask.MaskSpec` carried by
``DistAttnSpec.mask``; every schedule derives each step's spec statically
(``mk.ring_step`` / ``mk.strict_causal_pair``). Packed-sequence (document)
masking is first-class: the per-token ``segments`` array is sharded like
the activations and **travels the ring alongside K/V**, so every step
masks cross-document pairs exactly; the kernels prune what their static
layout allows. Prefix-LM masks need absolute positions, which per-shard
ring steps don't have — they are served by ``ulysses``/``rsa`` or a
single-shard axis, and rejected elsewhere at spec-construction time.

Communication/computation overlap (§3.2, Eq. 3) is expressed in dataflow:
the ``ppermute`` producing step ``t+1``'s chunk is issued *before* step
``t``'s compute and has no data dependence on it, so XLA's latency-hiding
scheduler overlaps the ICI transfer with the attention kernel (the TPU
analogue of the paper's second CUDA stream).

The backward pass is hand-written (exposed as :func:`dist_attn_bwd`) so the
rematerialization-aware checkpointing combinator (core/remat.py) can invoke
it directly from saved ``(o, lse)`` — the FlashAttention forward is never
recomputed, and neither is its forward communication (§3.3).

All functions here are *local* (per-shard) code meant to run inside
``jax.shard_map``; :func:`dist_flash_attn` is the user-facing wrapper that
applies shard_map and registers the custom VJP.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core import mask as mk
from repro.core.attention import (chunk_attn, chunk_attn_bwd, empty_partial,
                                  mask_partial, merge)
from repro.core.mask import MaskSpec
from repro.kernels.ref import NEG_INF


# --------------------------------------------------------------------------
# Schedule configuration
# --------------------------------------------------------------------------

SCHEDULES = ("balanced", "ring", "rsa", "ulysses", "zigzag")


@dataclasses.dataclass(frozen=True)
class DistAttnSpec:
    """Static description of one distributed-attention call site.

    ``schedule`` ∈ ``balanced | ring | rsa | ulysses | zigzag`` (validated —
    a typo raises instead of silently running the ring schedule).
    ``mask`` is the MaskSpec of the *whole* (unsharded) attention; the
    schedules derive per-step specs from it. The pre-MaskSpec ``causal``/
    ``window`` constructor kwargs remain as deprecated shims.
    """
    axis: str = "model"            # sequence-parallel mesh axis
    axis_size: int = 1             # P
    schedule: str = "balanced"     # balanced | ring | rsa | ulysses | zigzag
    mask: Optional[MaskSpec] = None
    # deprecated shims, mapped onto ``mask`` (default: causal, full window)
    causal: dataclasses.InitVar[Optional[bool]] = None
    window: dataclasses.InitVar[Optional[int]] = None
    scale: Optional[float] = None
    # attention backend name resolved via repro.kernels.registry (None =
    # process default); capability/platform fallback happens at resolve time
    impl: Optional[str] = None
    # per-call-site kernel tile hints, forwarded to tunable backends only
    # (Pallas block shapes / chunked-lax scan chunk). None = backend default.
    block_q: Optional[int] = None
    block_kv: Optional[int] = None

    def __post_init__(self, causal, window):
        if self.schedule not in SCHEDULES:
            raise ValueError(
                f"unknown schedule {self.schedule!r}; valid: {SCHEDULES}")
        if self.mask is None:
            if causal is not None or window is not None:
                mk.warn_legacy_once(
                    "DistAttnSpec(causal=, window=)",
                    "mask=repro.core.mask.{causal,sliding_window,full,"
                    "document}(...)")
            # the spec-level legacy default is causal (unlike chunk_attn's)
            m = mk.from_legacy(causal=True if causal is None else causal,
                               window=window or 0)
            object.__setattr__(self, "mask", m)
        elif causal is not None or window is not None:
            raise ValueError("pass either mask= or the legacy causal/window "
                             "kwargs, not both")
        m = self.mask
        if m.q_offset or m.kv_offset:
            raise ValueError("DistAttnSpec.mask must be offset-free — the "
                             "schedules derive per-step offsets")
        if self.axis_size > 1:
            if m.boundaries is not None and self.schedule != "ulysses":
                raise ValueError(
                    f"static document boundaries don't compose with the "
                    f"{self.schedule!r} schedule's per-shard coordinates; "
                    f"pass dynamic segments= arrays instead")
            if self.schedule in ("balanced", "zigzag") and \
                    not (m.causal and not m.window and not m.prefix_len):
                raise ValueError(
                    f"{self.schedule!r} handles causal full-window masks "
                    f"only (got {m.kind!r}); use ring/ulysses")
            # rsa/ulysses serve prefix_lm forward-only (absolute positions
            # exist there); their backward — the ring — rejects it below
            if m.prefix_len and self.schedule == "ring":
                raise ValueError(
                    "prefix_lm needs absolute kv positions, which the "
                    "ring schedule's per-shard chunks don't have; use "
                    "ulysses/rsa or a single-shard axis")
            if m.window and self.schedule == "rsa":
                raise ValueError("rsa baseline has no sliding-window path")


def _tune(spec: DistAttnSpec) -> dict:
    """chunk_attn tuning kwargs carried by the spec (scale + tile hints)."""
    return dict(scale=spec.scale, impl=spec.impl, block_q=spec.block_q,
                block_kv=spec.block_kv)


def _seg_kw(mask: MaskSpec, q_seg, kv_seg) -> dict:
    """Segment operands, only when the mask consumes them."""
    if not mask.document or q_seg is None:
        return {}
    return dict(q_segments=q_seg, kv_segments=kv_seg)


def _shift(x, axis, shift, size):
    """ppermute by a fixed shift: device p receives from (p − shift) mod P."""
    perm = [(i, (i + shift) % size) for i in range(size)]
    return compat.tree_map(lambda a: lax.ppermute(a, axis, perm), x)


def _ring_steps(spec: DistAttnSpec, chunk_len: int) -> int:
    """Number of ring steps; truncated by the sliding window (Appendix F)."""
    P_ = spec.axis_size
    n = P_ - 1
    w = spec.mask.window
    if w and w > 0:
        # step t covers query-key distances [(t-1)*Tc+1, (t+1)*Tc-1];
        # it contributes only if the smallest distance is inside the window.
        n = min(n, max(0, -(-(w - 1) // chunk_len)))
    return n


# --------------------------------------------------------------------------
# Forward schedules (local/per-shard code)
# --------------------------------------------------------------------------

def _fwd_ring(spec, q, k, v, seg=None):
    """Vanilla ring (Alg. 1) — causal, bidirectional, windowed, document."""
    p = lax.axis_index(spec.axis)
    P_, Tc = spec.axis_size, q.shape[1]
    m = spec.mask
    o, s = chunk_attn(q, k, v, mask=m, **_seg_kw(m, seg, seg), **_tune(spec))
    n = _ring_steps(spec, Tc)
    if n == 0:
        return o, s
    kv = _shift((k, v), spec.axis, 1, P_)            # prefetch step 1
    seg_r = _shift(seg, spec.axis, 1, P_) if seg is not None else None
    for t in range(1, n + 1):
        if t < n:                                     # prefetch (overlap)
            kv_next = _shift(kv, spec.axis, 1, P_)
            seg_next = _shift(seg_r, spec.axis, 1, P_) \
                if seg_r is not None else None
        m_t = mk.ring_step(m, t * Tc)
        o_t, s_t = chunk_attn(q, kv[0], kv[1], mask=m_t,
                              **_seg_kw(m_t, seg, seg_r), **_tune(spec))
        if m.causal:
            o_t, s_t = mask_partial(p >= t, o_t, s_t)
        o, s = merge(o, s, o_t, s_t)
        if t < n:
            kv, seg_r = kv_next, seg_next
    return o, s


def _fwd_balanced(spec, q, k, v, seg=None):
    """Load-balanced schedule (Alg. 2). Causal-kind masks, full window."""
    p = lax.axis_index(spec.axis)
    P_, Tc = spec.axis_size, q.shape[1]
    m = spec.mask
    m_x = mk.strict_causal_pair(m)     # off-diagonal pairs: document only
    o, s = chunk_attn(q, k, v, mask=m, **_seg_kw(m, seg, seg), **_tune(spec))
    if P_ == 1:
        return o, s
    T = P_ // 2
    kv = _shift((k, v), spec.axis, 1, P_)            # prefetch step 1
    qb = _shift(q, spec.axis, 1, P_)
    # one traveling segment chunk serves both sides: the helper's q chunk
    # and the worker's kv chunk are the same remote device's tokens
    seg_r = _shift(seg, spec.axis, 1, P_) if seg is not None else None
    for t in range(1, T + 1):
        helpers = (t != T) or (P_ % 2 == 1)
        if t < T:                                     # prefetch step t+1
            kv_next = _shift(kv, spec.axis, 1, P_)
            qb_next = _shift(qb, spec.axis, 1, P_)
            seg_next = _shift(seg_r, spec.axis, 1, P_) \
                if seg_r is not None else None
        is_worker = p >= t
        # one attn kernel per device per step: workers use (q_p, kv_{p−t}),
        # helpers use (q_{(p−t) mod P}, kv_p). No positional mask — strictly
        # causal pairs; document segments still apply.
        q_sel = jnp.where(is_worker, q, qb)
        k_sel = jnp.where(is_worker, kv[0], k)
        v_sel = jnp.where(is_worker, kv[1], v)
        skw = {}
        if seg_r is not None and m.document:
            skw = dict(q_segments=jnp.where(is_worker, seg, seg_r),
                       kv_segments=jnp.where(is_worker, seg_r, seg))
        o_t, s_t = chunk_attn(q_sel, k_sel, v_sel, mask=m_x, **skw,
                              **_tune(spec))
        o_w, s_w = mask_partial(is_worker, o_t, s_t)
        o, s = merge(o, s, o_w, s_w)
        if helpers:
            # helper h computed for worker w=(h−t) mod P: route (o,lse) back
            o_r, s_r = _shift((o_t, s_t), spec.axis, -t, P_)
            o_r, s_r = mask_partial(p >= P_ - t, o_r, s_r)
            o, s = merge(o, s, o_r, s_r)
        if t < T:
            kv, qb = kv_next, qb_next
            seg_r = seg_next if seg_r is not None else None
    return o, s


def _fwd_ulysses(spec, q, k, v, seg=None):
    """DeepSpeed-Ulysses baseline (Jacobs et al., 2023): all-to-all the
    sequence-sharded q/k/v into head-sharded layout, run ordinary (local)
    FlashAttention over the full sequence, all-to-all back. Requires the
    head counts to be divisible by P — exactly the limitation the paper
    targets (§4.2, §4.6); we raise otherwise (Megatron would pad heads)."""
    P_ = spec.axis_size
    Hq, Hkv = q.shape[2], k.shape[2]
    if Hq % P_ or Hkv % P_:
        raise ValueError(
            f"ulysses needs heads % P == 0 (got Hq={Hq}, Hkv={Hkv}, P={P_})"
            " — the head-divisibility limitation of head-parallel attention")
    def a2a(x, fwd=True):
        if fwd:   # scatter heads, gather sequence
            return lax.all_to_all(x, spec.axis, split_axis=2, concat_axis=1,
                                  tiled=True)
        return lax.all_to_all(x, spec.axis, split_axis=1, concat_axis=2,
                              tiled=True)
    qh, kh, vh = a2a(q), a2a(k), a2a(v)          # (B, T_glob, H/P, D)
    m = spec.mask
    skw = {}
    if seg is not None and m.document:
        seg_g = lax.all_gather(seg, spec.axis, axis=1, tiled=True)
        skw = dict(q_segments=seg_g, kv_segments=seg_g)
    o, s = chunk_attn(qh, kh, vh, mask=m, **skw, **_tune(spec))
    # lse (B, T_glob, H/P) -> (B, T_loc, H): split seq, concat heads
    s_back = lax.all_to_all(s, spec.axis, split_axis=1, concat_axis=2,
                            tiled=True)
    return a2a(o, fwd=False), s_back


def _fwd_rsa(spec, q, k, v, seg=None):
    """Ring Self-Attention baseline: all-gather KV, materialize scores."""
    if spec.mask.needs_segments and seg is None:
        raise ValueError("document mask without boundaries needs segments=")
    kg = lax.all_gather(k, spec.axis, axis=1, tiled=True)
    vg = lax.all_gather(v, spec.axis, axis=1, tiled=True)
    p = lax.axis_index(spec.axis)
    Tc = q.shape[1]
    B, Tq, Hq, D = q.shape
    Hkv = kg.shape[2]
    g = Hq // Hkv
    m = spec.mask
    scale = spec.scale or 1.0 / (D ** 0.5)
    kf = jnp.repeat(kg, g, axis=2) if g > 1 else kg
    vf = jnp.repeat(vg, g, axis=2) if g > 1 else vg
    sc = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                    kf.astype(jnp.float32)) * scale
    if m.needs_mask:
        # same MaskSpec.allow semantics as the kernels, with this shard's
        # traced absolute query positions and the gathered global keys
        qpos = p * Tc + jnp.arange(Tq)
        kpos = jnp.arange(kg.shape[1])
        qs = ks = None
        if m.document and seg is not None:
            sg = lax.all_gather(seg, spec.axis, axis=1, tiled=True)
            qs, ks = seg[:, :, None], sg[:, None, :]
        allow = m.allow(qpos[:, None], kpos[None, :], qs, ks)
        allow = allow[None, None] if allow.ndim == 2 else allow[:, None]
        sc = jnp.where(allow, sc, NEG_INF)
    w = jax.nn.softmax(sc, axis=-1)                  # full P×-size matrix
    o = jnp.einsum("bhqk,bkhd->bqhd", w, vf.astype(jnp.float32))
    lse = jax.scipy.special.logsumexp(sc, axis=-1).transpose(0, 2, 1)
    return o.astype(q.dtype), lse


# --------------------------------------------------------------------------
# Backward schedules (explicit; used by remat-aware checkpointing)
# --------------------------------------------------------------------------

def _bwd_ring(spec, q, k, v, o, s, do, seg=None):
    p = lax.axis_index(spec.axis)
    P_, Tc = spec.axis_size, q.shape[1]
    m = spec.mask
    f32 = jnp.float32
    delta = jnp.sum(o.astype(f32) * do.astype(f32), axis=-1)  # (B,T,H)
    dq_l, dk_l, dv_l = chunk_attn_bwd(
        q, k, v, o, s, do, mask=m, **_seg_kw(m, seg, seg), **_tune(spec))
    dq = dq_l.astype(f32)
    dkv_home = (dk_l.astype(f32), dv_l.astype(f32))
    n = _ring_steps(spec, Tc)
    if n == 0:
        return dq.astype(q.dtype), dkv_home[0].astype(k.dtype), \
            dkv_home[1].astype(v.dtype)
    # containers: (k, v) data + (dk, dv) accumulators travel together
    kv = _shift((k, v), spec.axis, 1, P_)
    seg_r = _shift(seg, spec.axis, 1, P_) if seg is not None else None
    dkv = compat.tree_map(lambda a: jnp.zeros(a.shape, f32), kv)
    for t in range(1, n + 1):
        if t < n:                                     # prefetch data (overlap)
            kv_nxt = _shift(kv, spec.axis, 1, P_)
            seg_nxt = _shift(seg_r, spec.axis, 1, P_) \
                if seg_r is not None else None
        m_t = mk.ring_step(m, t * Tc)
        dq_t, dk_t, dv_t = chunk_attn_bwd(
            q, kv[0], kv[1], o, s, do, mask=m_t,
            **_seg_kw(m_t, seg, seg_r), **_tune(spec), delta=delta)
        valid = (p >= t) if m.causal else jnp.bool_(True)
        w = valid.astype(f32)
        dq = dq + dq_t.astype(f32) * w
        dkv = (dkv[0] + dk_t.astype(f32) * w, dkv[1] + dv_t.astype(f32) * w)
        if t < n:                                     # accumulators move late
            kv, seg_r = kv_nxt, (seg_nxt if seg_r is not None else None)
            dkv = _shift(dkv, spec.axis, 1, P_)
    # route accumulated dkv home: container at p holds chunk (p−n) mod P
    dkv = _shift(dkv, spec.axis, -n, P_)
    dk = dkv_home[0] + dkv[0]
    dv = dkv_home[1] + dkv[1]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def _bwd_balanced(spec, q, k, v, o, s, do, seg=None):
    p = lax.axis_index(spec.axis)
    P_, Tc = spec.axis_size, q.shape[1]
    m = spec.mask
    m_x = mk.strict_causal_pair(m)
    f32 = jnp.float32
    dq_l, dk_l, dv_l = chunk_attn_bwd(q, k, v, o, s, do, mask=m,
                                      **_seg_kw(m, seg, seg), **_tune(spec))
    dq = dq_l.astype(f32)
    dk_home = dk_l.astype(f32)
    dv_home = dv_l.astype(f32)
    if P_ == 1:
        return dq.astype(q.dtype), dk_home.astype(k.dtype), \
            dv_home.astype(v.dtype)
    T = P_ // 2
    delta = jnp.sum(o.astype(f32) * do.astype(f32), axis=-1)
    # traveling containers (ring +1): kv side and q-bundle side
    kv = _shift((k, v), spec.axis, 1, P_)
    dkv = (jnp.zeros(k.shape, f32), jnp.zeros(v.shape, f32))
    qb = _shift((q, do, s, delta), spec.axis, 1, P_)
    seg_r = _shift(seg, spec.axis, 1, P_) if seg is not None else None
    dqb = jnp.zeros(q.shape, f32)
    for t in range(1, T + 1):
        helpers = (t != T) or (P_ % 2 == 1)
        if t < T:                                     # prefetch data (overlap)
            kv_nxt = _shift(kv, spec.axis, 1, P_)
            qb_nxt = _shift(qb, spec.axis, 1, P_)
            seg_nxt = _shift(seg_r, spec.axis, 1, P_) \
                if seg_r is not None else None
        is_worker = p >= t
        q_sel = jnp.where(is_worker, q, qb[0])
        do_sel = jnp.where(is_worker, do, qb[1])
        s_sel = jnp.where(is_worker, s, qb[2])
        k_sel = jnp.where(is_worker, kv[0], k)
        v_sel = jnp.where(is_worker, kv[1], v)
        o_unused = jnp.zeros_like(q_sel)  # delta passed explicitly
        d_sel = jnp.where(is_worker, delta, qb[3])
        skw = {}
        if seg_r is not None and m.document:
            skw = dict(q_segments=jnp.where(is_worker, seg, seg_r),
                       kv_segments=jnp.where(is_worker, seg_r, seg))
        dq_t, dk_t, dv_t = chunk_attn_bwd(
            q_sel, k_sel, v_sel, o_unused, s_sel, do_sel, mask=m_x, **skw,
            **_tune(spec), delta=d_sel)
        w_w = is_worker.astype(f32)
        dq = dq + dq_t.astype(f32) * w_w                 # worker: local dq
        dkv = (dkv[0] + dk_t.astype(f32) * w_w,          # worker: traveling dkv
               dkv[1] + dv_t.astype(f32) * w_w)
        if helpers:
            w_h = (p < t).astype(f32)
            dqb = dqb + dq_t.astype(f32) * w_h           # helper: traveling dq
            dk_home = dk_home + dk_t.astype(f32) * w_h   # helper: local dkv
            dv_home = dv_home + dv_t.astype(f32) * w_h
        if t < T:                                     # accumulators move late
            kv, qb = kv_nxt, qb_nxt
            seg_r = seg_nxt if seg_r is not None else None
            dkv = _shift(dkv, spec.axis, 1, P_)
            dqb = _shift(dqb, spec.axis, 1, P_)
    # route containers home (container at p holds chunk (p−T) mod P)
    dkv = _shift(dkv, spec.axis, -T, P_)
    dqb = _shift(dqb, spec.axis, -T, P_)
    dq = dq + dqb
    dk = dk_home + dkv[0]
    dv = dv_home + dkv[1]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


# --------------------------------------------------------------------------
# Public API: explicit fwd/bwd + custom-VJP wrapper, shard_mapped
# --------------------------------------------------------------------------

def _fwd_local(spec, q, k, v, seg=None):
    if spec.axis_size == 1:
        m = spec.mask
        return chunk_attn(q, k, v, mask=m, **_seg_kw(m, seg, seg),
                          **_tune(spec))
    sched = spec.schedule              # validated in __post_init__
    if sched == "balanced":
        return _fwd_balanced(spec, q, k, v, seg)
    if sched == "zigzag":
        return _fwd_zigzag(spec, q, k, v, seg)
    if sched == "rsa":
        return _fwd_rsa(spec, q, k, v, seg)
    if sched == "ulysses":
        return _fwd_ulysses(spec, q, k, v, seg)
    assert sched == "ring", sched
    return _fwd_ring(spec, q, k, v, seg)


def _bwd_local(spec, q, k, v, o, s, do, seg=None):
    if spec.axis_size == 1:
        m = spec.mask
        return chunk_attn_bwd(q, k, v, o, s, do, mask=m,
                              **_seg_kw(m, seg, seg), **_tune(spec))
    sched = spec.schedule
    if sched == "balanced":
        return _bwd_balanced(spec, q, k, v, o, s, do, seg)
    if sched == "zigzag":
        return _bwd_zigzag(spec, q, k, v, o, s, do, seg)
    # rsa / ulysses baselines reuse the exact ring backward — which cannot
    # express absolute coordinates (prefix masks, static doc boundaries)
    # in its per-shard chunks
    if spec.mask.prefix_len:
        raise ValueError("prefix_lm distributed backward needs axis_size"
                         " == 1 (fwd-only baselines support it)")
    if spec.mask.boundaries is not None:
        raise ValueError("static document boundaries have no distributed "
                         "backward (the ring sees per-shard coordinates); "
                         "pass dynamic segments= instead")
    return _bwd_ring(spec, q, k, v, o, s, do, seg)


def _specs(batch_axes, seq_axis):
    b = tuple(batch_axes) if batch_axes else None
    qkv = P(b, seq_axis, None, None)
    lse = P(b, seq_axis, None)
    seg = P(b, seq_axis)
    return qkv, lse, seg


def dist_attn_fwd(q, k, v, *, mesh, spec: DistAttnSpec,
                  batch_axes=("data",), segments=None):
    """Distributed forward → (o, lse). Global-array in/out (GSPMD land).
    ``segments`` is a (B, T) int32 document-ID array sharded like the
    activations (document masks only)."""
    qkv_s, lse_s, seg_s = _specs(batch_axes, spec.axis)
    in_specs, args = [qkv_s] * 3, [q, k, v]
    if segments is not None:
        in_specs.append(seg_s)
        args.append(segments)
    fn = compat.shard_map(partial(_fwd_local, spec), mesh=mesh,
                          in_specs=tuple(in_specs),
                          out_specs=(qkv_s, lse_s), check_vma=False)
    return fn(*args)


def dist_attn_bwd(q, k, v, o, lse, do, *, mesh, spec: DistAttnSpec,
                  batch_axes=("data",), segments=None):
    """Distributed backward from saved (o, lse) → (dq, dk, dv)."""
    qkv_s, lse_s, seg_s = _specs(batch_axes, spec.axis)
    in_specs = [qkv_s, qkv_s, qkv_s, qkv_s, lse_s, qkv_s]
    args = [q, k, v, o, lse, do]
    if segments is not None:
        in_specs.append(seg_s)
        args.append(segments)
    fn = compat.shard_map(partial(_bwd_local, spec), mesh=mesh,
                          in_specs=tuple(in_specs),
                          out_specs=(qkv_s, qkv_s, qkv_s), check_vma=False)
    return fn(*args)


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _dist_flash_attn(q, k, v, mesh, spec, batch_axes):
    return dist_attn_fwd(q, k, v, mesh=mesh, spec=spec,
                         batch_axes=batch_axes)


def _cvjp_fwd(q, k, v, mesh, spec, batch_axes):
    o, lse = dist_attn_fwd(q, k, v, mesh=mesh, spec=spec,
                           batch_axes=batch_axes)
    return (o, lse), (q, k, v, o, lse)


def _cvjp_bwd(mesh, spec, batch_axes, res, cts):
    q, k, v, o, lse = res
    do, _ = cts
    dq, dk, dv = dist_attn_bwd(q, k, v, o, lse, do, mesh=mesh, spec=spec,
                               batch_axes=batch_axes)
    return dq, dk, dv


_dist_flash_attn.defvjp(_cvjp_fwd, _cvjp_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def _dist_flash_attn_seg(q, k, v, segments, mesh, spec, batch_axes):
    return dist_attn_fwd(q, k, v, mesh=mesh, spec=spec,
                         batch_axes=batch_axes, segments=segments)


def _cvjp_seg_fwd(q, k, v, segments, mesh, spec, batch_axes):
    o, lse = dist_attn_fwd(q, k, v, mesh=mesh, spec=spec,
                           batch_axes=batch_axes, segments=segments)
    return (o, lse), (q, k, v, segments, o, lse)


def _cvjp_seg_bwd(mesh, spec, batch_axes, res, cts):
    q, k, v, segments, o, lse = res
    do, _ = cts
    dq, dk, dv = dist_attn_bwd(q, k, v, o, lse, do, mesh=mesh, spec=spec,
                               batch_axes=batch_axes, segments=segments)
    # integer segment IDs take a float0 cotangent
    dseg = np.zeros(segments.shape, jax.dtypes.float0)
    return dq, dk, dv, dseg


_dist_flash_attn_seg.defvjp(_cvjp_seg_fwd, _cvjp_seg_bwd)


def dist_flash_attn(q, k, v, mesh, spec, batch_axes=("data",),
                    segments=None):
    """DISTFLASHATTN with autodiff. Returns (o, lse); lse is a residual
    output (its cotangent is ignored, as in the paper's kernel).
    ``segments`` (document masks) is non-differentiable."""
    if segments is None:
        return _dist_flash_attn(q, k, v, mesh, spec, batch_axes)
    return _dist_flash_attn_seg(q, k, v, segments, mesh, spec, batch_axes)


# --------------------------------------------------------------------------
# Decode-time distributed attention (flash-decoding over sequence shards)
# --------------------------------------------------------------------------

def _decode_local(seq_axes, shard_len, window, scale, q, kc, vc, k1, v1):
    """q: (B,1,Hq,D) replicated over seq axes; kc/vc: (B,S_loc,Hkv,Dk/Dv)
    local cache shards; k1/v1: (B,1,...) the new token's k/v (replicated).
    Total context = S_global cached + 1 new token at position S_global."""
    # linearized shard index over (possibly multiple) sequence axes
    idx = jnp.int32(0)
    for ax in seq_axes:
        idx = idx * compat.axis_size(ax) + lax.axis_index(ax)
    n_shards = 1
    for ax in seq_axes:
        n_shards *= compat.axis_size(ax)
    S_total = n_shards * shard_len
    offset = idx * shard_len
    B, _, Hq, Dq = q.shape
    Hkv = kc.shape[2]
    g = Hq // Hkv
    sc = scale if scale is not None else 1.0 / (Dq ** 0.5)
    qf = q.astype(jnp.float32)
    kf = jnp.repeat(kc, g, axis=2) if g > 1 else kc
    vf = jnp.repeat(vc, g, axis=2) if g > 1 else vc
    s_loc = jnp.einsum("bqhd,bkhd->bhqk", qf, kf.astype(jnp.float32)) * sc
    if window and window > 0:
        # new token position = S_total; attendable cache: pos > S_total−window
        kpos = offset + jnp.arange(shard_len)
        ok = kpos[None, None, None, :] > S_total - window
        s_loc = jnp.where(ok, s_loc, NEG_INF)
    m_loc = jnp.max(s_loc, axis=-1)                      # (B,H,1)
    m_glb = lax.pmax(m_loc, seq_axes)
    m_safe = jnp.maximum(m_glb, NEG_INF / 2)
    p_loc = jnp.exp(s_loc - m_safe[..., None])
    p_loc = jnp.where(m_loc[..., None] <= NEG_INF / 2,
                      jnp.zeros_like(p_loc), p_loc)
    num = jnp.einsum("bhqk,bkhd->bhqd", p_loc, vf.astype(jnp.float32))
    den = jnp.sum(p_loc, axis=-1)                        # (B,H,1)
    num = lax.psum(num, seq_axes)
    den = lax.psum(den, seq_axes)
    lse_c = jnp.where(den == 0.0, NEG_INF, m_safe + jnp.log(
        jnp.where(den == 0.0, 1.0, den)))                # (B,H,1) cache lse
    o_c = num / jnp.where(den == 0.0, 1.0, den)[..., None]
    o_c = jnp.where((den == 0.0)[..., None], 0.0, o_c)
    # merge with the new token's self-attention (replicated, added once —
    # after the cross-shard psum so it isn't multiply counted)
    k1r = jnp.repeat(k1, g, axis=2) if g > 1 else k1
    v1r = jnp.repeat(v1, g, axis=2) if g > 1 else v1
    s1 = jnp.einsum("bqhd,bkhd->bhqk", qf, k1r.astype(jnp.float32)) * sc
    lse1 = s1[..., 0]                                    # (B,H,1): one key
    o1 = v1r.astype(jnp.float32).transpose(0, 2, 1, 3)   # (B,Hq,1,Dv)
    o_m, _ = _merge_bh(o_c, lse_c, o1, lse1)
    return o_m.transpose(0, 2, 1, 3).astype(q.dtype)     # (B,1,Hq,Dv)


def _merge_bh(o1, lse1, o2, lse2):
    """merge in (B,H,1,D)/(B,H,1) layout."""
    mx = jnp.maximum(jnp.maximum(lse1, lse2), NEG_INF)
    w1 = jnp.exp(lse1 - mx)
    w2 = jnp.exp(lse2 - mx)
    den = w1 + w2
    den_s = jnp.where(den == 0.0, 1.0, den)
    o = (o1 * w1[..., None] + o2 * w2[..., None]) / den_s[..., None]
    return o, mx + jnp.log(den_s)


def dist_decode_attn(q, k_cache, v_cache, k_new, v_new, *, mesh,
                     seq_axes=("model",), batch_axes=("data",), window=0,
                     scale=None, shard_len=None):
    """One-token decode against a sequence-sharded KV cache.

    The cache's sequence dim is sharded over ``seq_axes`` (supports the 2D
    (data, model) sharding used by long_500k); the query and the new token's
    k/v are replicated across them. Exact lse-weighted combine across shards
    (distributed flash-decoding), then a final merge with the new token's
    self-attention.
    """
    n = 1
    for ax in seq_axes:
        n *= dict(zip(mesh.axis_names, mesh.devices.shape))[ax]
    if shard_len is None:
        shard_len = k_cache.shape[1] // n
    b = tuple(batch_axes) if batch_axes else None
    seq = tuple(seq_axes) if len(seq_axes) > 1 else seq_axes[0]
    rep = P(b, None, None, None)
    shd = P(b, seq, None, None)
    fn = compat.shard_map(
        partial(_decode_local, tuple(seq_axes), shard_len, window, scale),
        mesh=mesh,
        in_specs=(rep, shd, shd, rep, rep),
        out_specs=rep, check_vma=False)
    return fn(q, k_cache, v_cache, k_new, v_new)


# --------------------------------------------------------------------------
# BEYOND-PAPER: zigzag placement (cf. striped/zigzag context parallelism).
#
# The paper balances causal load by shipping helper queries and partial
# results (Alg. 2) — comm = kv ring + q ring + (o,lse) result sends, and in
# the backward also dq/do containers. Zigzag placement achieves *exact*
# balance with ONLY the kv ring: split the sequence into 2P chunks and give
# device p chunks (p, 2P−1−p). At ring step t every device computes exactly
# two (Tc×Tc) chunk pairs, all strictly causal (mask-free):
#     p ≥ t:  (q_p  × kv_a)  and (q_b̄ × kv_a)
#     p < t:  (q_b̄ × kv_a)  and (q_b̄ × kv_b̄)
# where the received container holds kv chunks (r, 2P−1−r) = (a, b̄) of
# r = (p−t) mod P, and b̄ denotes the device's own mirror chunk 2P−1−p.
# Coverage: 2P(P−1) + 3P = P(2P+1) pairs = all causal chunk pairs, each
# exactly once. The backward ships only (kv, dkv): dq stays local.
# Document segments ride the kv ring exactly like K/V.
#
# Contract: global arrays (tokens AND segment IDs) are already
# zigzag-permuted (models apply the permutation once after the embedding;
# rope tables are permuted for free as trace-time constants — see
# models/transformer.py).
# --------------------------------------------------------------------------

def zigzag_perm(T: int, P: int):
    """Natural→zigzag permutation: new global array order is
    [chunk 0, chunk 2P−1 | chunk 1, chunk 2P−2 | …] so contiguous device
    shards hold (p, 2P−1−p). Returns an index array of length T."""
    c = T // (2 * P)
    order = []
    for p in range(P):
        order.append(np.arange(p * c, (p + 1) * c))
        q = 2 * P - 1 - p
        order.append(np.arange(q * c, (q + 1) * c))
    return np.concatenate(order)


def _fwd_zigzag(spec, q, k, v, seg=None):
    p = lax.axis_index(spec.axis)
    P_ = spec.axis_size
    Tl = q.shape[1]
    c = Tl // 2
    m = spec.mask
    m_x = mk.strict_causal_pair(m)
    doc = seg is not None and m.document

    def sk(qs, ks):
        return dict(q_segments=qs, kv_segments=ks) if doc else {}

    q_a, q_b = q[:, :c], q[:, c:]
    k_a, k_b = k[:, :c], k[:, c:]
    v_a, v_b = v[:, :c], v[:, c:]
    s_a_, s_b_ = (seg[:, :c], seg[:, c:]) if seg is not None else (None, None)
    # local step: a×a causal; b̄×a full; b̄×b̄ causal
    o_a, s_a = chunk_attn(q_a, k_a, v_a, mask=m, **sk(s_a_, s_a_),
                          **_tune(spec))
    o_b1, s_b1 = chunk_attn(q_b, k_a, v_a, mask=m_x, **sk(s_b_, s_a_),
                            **_tune(spec))
    o_b2, s_b2 = chunk_attn(q_b, k_b, v_b, mask=m, **sk(s_b_, s_b_),
                            **_tune(spec))
    o_b, s_b = merge(o_b1, s_b1, o_b2, s_b2)
    if P_ == 1:
        return jnp.concatenate([o_a, o_b], 1), jnp.concatenate([s_a, s_b], 1)
    kv = _shift((k, v), spec.axis, 1, P_)
    seg_r = _shift(seg, spec.axis, 1, P_) if seg is not None else None
    for t in range(1, P_):
        if t < P_ - 1:
            kv_next = _shift(kv, spec.axis, 1, P_)
            seg_next = _shift(seg_r, spec.axis, 1, P_) \
                if seg_r is not None else None
        ka_r, kb_r = kv[0][:, :c], kv[0][:, c:]
        va_r, vb_r = kv[1][:, :c], kv[1][:, c:]
        sa_r, sb_r = (seg_r[:, :c], seg_r[:, c:]) if seg_r is not None \
            else (None, None)
        w = p >= t
        # pair 1 -> (q_a if worker else q_b) × kv_a
        q1 = jnp.where(w, q_a, q_b)
        s1q = jnp.where(w, s_a_, s_b_) if doc else None
        o1, s1 = chunk_attn(q1, ka_r, va_r, mask=m_x, **sk(s1q, sa_r),
                            **_tune(spec))
        o1a, s1a = mask_partial(w, o1, s1)
        o_a, s_a = merge(o_a, s_a, o1a, s1a)
        o1b, s1b = mask_partial(~w, o1, s1)
        o_b, s_b = merge(o_b, s_b, o1b, s1b)
        # pair 2 -> q_b × (kv_a if worker else kv_b̄)
        k2 = jnp.where(w, ka_r, kb_r)
        v2 = jnp.where(w, va_r, vb_r)
        s2k = jnp.where(w, sa_r, sb_r) if doc else None
        o2, s2 = chunk_attn(q_b, k2, v2, mask=m_x, **sk(s_b_, s2k),
                            **_tune(spec))
        o_b, s_b = merge(o_b, s_b, o2, s2)
        if t < P_ - 1:
            kv, seg_r = kv_next, (seg_next if seg_r is not None else None)
    return jnp.concatenate([o_a, o_b], 1), jnp.concatenate([s_a, s_b], 1)


def _bwd_zigzag(spec, q, k, v, o, s, do, seg=None):
    p = lax.axis_index(spec.axis)
    P_ = spec.axis_size
    f32 = jnp.float32
    Tl = q.shape[1]
    c = Tl // 2
    sl_a, sl_b = slice(0, c), slice(c, None)
    m = spec.mask
    m_x = mk.strict_causal_pair(m)
    doc = seg is not None and m.document
    delta = jnp.sum(o.astype(f32) * do.astype(f32), axis=-1)

    def cb(qs, ks, vs, ss, dos, ds, mask, qseg=None, kseg=None):
        skw = dict(q_segments=qseg, kv_segments=kseg) if doc else {}
        return chunk_attn_bwd(qs, ks, vs, jnp.zeros_like(qs), ss, dos,
                              mask=mask, **skw, **_tune(spec), delta=ds)

    # local pairs
    dq = jnp.zeros(q.shape, f32)
    dk_h = jnp.zeros(k.shape, f32)
    dv_h = jnp.zeros(v.shape, f32)
    for (qs, ks, mask) in ((sl_a, sl_a, m), (sl_b, sl_a, m_x),
                           (sl_b, sl_b, m)):
        dq_t, dk_t, dv_t = cb(q[:, qs], k[:, ks], v[:, ks], s[:, qs],
                              do[:, qs], delta[:, qs], mask,
                              seg[:, qs] if doc else None,
                              seg[:, ks] if doc else None)
        dq = dq.at[:, qs].add(dq_t.astype(f32))
        dk_h = dk_h.at[:, ks].add(dk_t.astype(f32))
        dv_h = dv_h.at[:, ks].add(dv_t.astype(f32))
    if P_ == 1:
        return dq.astype(q.dtype), dk_h.astype(k.dtype), dv_h.astype(v.dtype)

    q_a, q_b = q[:, sl_a], q[:, sl_b]
    s_a, s_b = s[:, sl_a], s[:, sl_b]
    do_a, do_b = do[:, sl_a], do[:, sl_b]
    de_a, de_b = delta[:, sl_a], delta[:, sl_b]
    sg_a, sg_b = (seg[:, sl_a], seg[:, sl_b]) if doc else (None, None)
    kv = _shift((k, v), spec.axis, 1, P_)
    seg_r = _shift(seg, spec.axis, 1, P_) if seg is not None else None
    dkv = (jnp.zeros(k.shape, f32), jnp.zeros(v.shape, f32))
    for t in range(1, P_):
        if t < P_ - 1:
            kv_nxt = _shift(kv, spec.axis, 1, P_)
            seg_nxt = _shift(seg_r, spec.axis, 1, P_) \
                if seg_r is not None else None
        ka_r, kb_r = kv[0][:, :c], kv[0][:, c:]
        va_r, vb_r = kv[1][:, :c], kv[1][:, c:]
        sa_r, sb_r = (seg_r[:, :c], seg_r[:, c:]) if seg_r is not None \
            else (None, None)
        w = p >= t
        wf = w.astype(f32)
        # pair 1
        q1 = jnp.where(w, q_a, q_b)
        s1 = jnp.where(w, s_a, s_b)
        do1 = jnp.where(w, do_a, do_b)
        de1 = jnp.where(w, de_a, de_b)
        sg1 = jnp.where(w, sg_a, sg_b) if doc else None
        dq1, dk1, dv1 = cb(q1, ka_r, va_r, s1, do1, de1, m_x, sg1, sa_r)
        dq = dq.at[:, sl_a].add(dq1.astype(f32) * wf)
        dq = dq.at[:, sl_b].add(dq1.astype(f32) * (1 - wf))
        dkv = (dkv[0].at[:, sl_a].add(dk1.astype(f32)),
               dkv[1].at[:, sl_a].add(dv1.astype(f32)))
        # pair 2
        k2 = jnp.where(w, ka_r, kb_r)
        v2 = jnp.where(w, va_r, vb_r)
        sg2 = jnp.where(w, sa_r, sb_r) if doc else None
        dq2, dk2, dv2 = cb(q_b, k2, v2, s_b, do_b, de_b, m_x, sg_b, sg2)
        dq = dq.at[:, sl_b].add(dq2.astype(f32))
        dkv = (dkv[0].at[:, sl_a].add(dk2.astype(f32) * wf),
               dkv[1].at[:, sl_a].add(dv2.astype(f32) * wf))
        dkv = (dkv[0].at[:, sl_b].add(dk2.astype(f32) * (1 - wf)),
               dkv[1].at[:, sl_b].add(dv2.astype(f32) * (1 - wf)))
        if t < P_ - 1:
            kv, seg_r = kv_nxt, (seg_nxt if seg_r is not None else None)
            dkv = _shift(dkv, spec.axis, 1, P_)
    # containers at p hold chunk of (p − (P−1)) mod P = (p+1) mod P
    dkv = _shift(dkv, spec.axis, -(P_ - 1), P_)
    dk = dk_h + dkv[0]
    dv = dv_h + dkv[1]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


# --------------------------------------------------------------------------
# BEYOND-PAPER: MLA latent ring. For DeepSeek MLA the materialized per-head
# K/V chunk is n_heads·(d_qk+d_v) wide (v3: 128·320 = 40960/token) while the
# latent it is deterministically derived from is kv_lora+rope = 576/token —
# a 71× comm reduction if the ring ships the latent and every worker
# up-projects locally (recompute-over-communicate, the same trade the
# paper's §3.3 makes for time). Composed with the zigzag placement the
# schedule is also load-balanced with no helper sends.
# --------------------------------------------------------------------------

def _fwd_zigzag_latent(spec, q, k, v, payload, w_up, expand):
    """Zigzag forward shipping ``payload`` instead of (k, v);
    ``expand(payload, w_up) -> (k, v)`` runs locally on every received
    chunk. Local (k, v) are passed in pre-expanded."""
    p = lax.axis_index(spec.axis)
    P_ = spec.axis_size
    Tl = q.shape[1]
    c = Tl // 2
    m = spec.mask
    m_x = mk.strict_causal_pair(m)
    q_a, q_b = q[:, :c], q[:, c:]
    k_a, k_b = k[:, :c], k[:, c:]
    v_a, v_b = v[:, :c], v[:, c:]
    o_a, s_a = chunk_attn(q_a, k_a, v_a, mask=m, **_tune(spec))
    o_b1, s_b1 = chunk_attn(q_b, k_a, v_a, mask=m_x, **_tune(spec))
    o_b2, s_b2 = chunk_attn(q_b, k_b, v_b, mask=m, **_tune(spec))
    o_b, s_b = merge(o_b1, s_b1, o_b2, s_b2)
    if P_ == 1:
        return jnp.concatenate([o_a, o_b], 1), jnp.concatenate([s_a, s_b], 1)
    pl = _shift(payload, spec.axis, 1, P_)
    for t in range(1, P_):
        pl_next = _shift(pl, spec.axis, 1, P_) if t < P_ - 1 else None
        k_r, v_r = expand(pl, w_up)                  # local up-projection
        ka_r, kb_r = k_r[:, :c], k_r[:, c:]
        va_r, vb_r = v_r[:, :c], v_r[:, c:]
        w = p >= t
        q1 = jnp.where(w, q_a, q_b)
        o1, s1 = chunk_attn(q1, ka_r, va_r, mask=m_x, **_tune(spec))
        o1a, s1a = mask_partial(w, o1, s1)
        o_a, s_a = merge(o_a, s_a, o1a, s1a)
        o1b, s1b = mask_partial(~w, o1, s1)
        o_b, s_b = merge(o_b, s_b, o1b, s1b)
        k2 = jnp.where(w, ka_r, kb_r)
        v2 = jnp.where(w, va_r, vb_r)
        o2, s2 = chunk_attn(q_b, k2, v2, mask=m_x, **_tune(spec))
        o_b, s_b = merge(o_b, s_b, o2, s2)
        pl = pl_next
    return jnp.concatenate([o_a, o_b], 1), jnp.concatenate([s_a, s_b], 1)


def dist_attn_fwd_latent(q, k, v, payload, w_up, expand, *, mesh, spec,
                         batch_axes=("data",)):
    """Latent-ring forward (zigzag schedule). ``payload``: (B, T, d_lat)
    sharded like activations; ``w_up``: replicated up-projection weights;
    ``expand(payload_chunk, w_up) -> (k, v)`` pure."""
    if spec.mask.kinds - {"causal"}:
        raise ValueError("latent ring supports plain causal masks only "
                         f"(got {spec.mask.kind!r})")
    b = tuple(batch_axes) if batch_axes else None
    qkv_s = P(b, spec.axis, None, None)
    pl_s = P(b, spec.axis, None)
    lse_s = P(b, spec.axis, None)
    w_s = compat.tree_map(lambda a: P(*(None,) * a.ndim), w_up)
    fn = compat.shard_map(
        partial(_fwd_zigzag_latent, spec, expand=expand), mesh=mesh,
        in_specs=(qkv_s, qkv_s, qkv_s, pl_s, w_s),
        out_specs=(qkv_s, lse_s), check_vma=False)
    return fn(q, k, v, payload, w_up)
