"""Schedule-plan IR: one step engine for every distributed-attention
schedule.

DISTFLASHATTN's schedule family (ring, load-balanced, zigzag, the MLA
latent ring) differs only in *placement and per-step routing* — which
(q-chunk, kv-chunk) pair each device computes at each ring step, and where
the partial result / its gradients are merged.  That structure is static
at trace time, so this module captures it once as a declarative
:class:`SchedulePlan` and runs any plan through **one forward executor**
(:func:`execute_fwd`) and **one backward executor** (:func:`execute_bwd`)
that implement the shared machinery — ppermute prefetch overlap, traveling
``(dk, dv)`` / ``dq``-bundle accumulators, segment-ID shipping (or
trace-time derivation from static document ``boundaries``), and
``mask_partial``/``merge`` result routing — exactly once.

The IR
------
* :class:`Ref` — one operand chunk: ``src`` ∈ ``local`` (this device's
  shard) | ``ring`` (the traveling KV container) | ``bundle`` (the
  traveling query bundle of the balanced schedule); ``chunk`` indexes the
  shard's ``n_chunks`` sub-chunks (zigzag holds two).
* :class:`Operand` — a Ref, optionally predicate-selected against an
  alternative (``jnp.where`` on the device index — the balanced schedule's
  worker/helper fusion runs one kernel per step).
* :class:`Route` — where one kernel result goes: merge into a local output
  chunk gated by a device predicate, optionally after a ``ship`` ppermute
  (the balanced helper sending ``(o, lse)`` home).
* :class:`Work` — one chunk-attention kernel call: q/kv operands, the
  step's static :class:`~repro.core.mask.MaskSpec`, result routes, and
  whether the mask needs *dynamic position offsets* (zigzag window bands,
  whose chunk distance depends on the device index).
* :class:`Step` — the Work items at one ring step plus the ring ``shift``
  (hops advanced since the previous executed step — >1 when intermediate
  steps were statically skipped).

Step skipping
-------------
Because every Work item's mask and chunk placement are static, the plan
builders prove per step (enumerating the P device indices in python)
whether *any* device has an unmasked (q, kv) pair —
:func:`repro.core.mask.chunk_pair_needed` — and drop provably all-masked
items/steps: sliding windows truncate the ring tail (and, for zigzag,
carve out the middle steps; mirror chunks make both sequence ends local),
and static document ``boundaries`` prune steps no document spans.

The backward pass interprets the *same plan*: each Work's gradient sinks
follow its operand sources (local q → local ``dq``; bundle q → traveling
``dq`` bundle; ring kv → traveling ``(dk, dv)``; local kv → home
``(dk, dv)``), so a schedule is written once and gets both passes.

:func:`plan_coverage` is a pure-numpy simulator of the executor used by
the property tests (every causal pair computed exactly once; skipped steps
provably all-masked), and :func:`plan_cost` is the static comm/compute
model behind ``DistAttnSpec(schedule="auto")`` (see
:func:`choose_schedule`), with time conversion wired into
``analysis/roofline.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro import compat
from repro.core import mask as mk
from repro.core.attention import (chunk_attn, chunk_attn_bwd, empty_partial,
                                  mask_partial, merge)
from repro.core.mask import MaskSpec

# ---------------------------------------------------------------------------
# Predicates on the (traced) device index p — static tuples
# ---------------------------------------------------------------------------

ALWAYS = ("always",)


def _ge(t):
    return ("ge", int(t))


def _lt(t):
    return ("lt", int(t))


def _neg(pred):
    if pred == ALWAYS:
        return ("never",)
    kind, t = pred
    return ("lt", t) if kind == "ge" else ("ge", t)


def _pred_val(pred, p):
    """Traced bool for ``pred`` at device index ``p`` (None = statically
    true)."""
    if pred == ALWAYS:
        return None
    kind, t = pred
    return (p >= t) if kind == "ge" else (p < t)


def _pred_int(pred, p: int) -> bool:
    """Python evaluation (plan simulator)."""
    if pred == ALWAYS:
        return True
    kind, t = pred
    return (p >= t) if kind == "ge" else (p < t)


# ---------------------------------------------------------------------------
# IR dataclasses
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Ref:
    """One operand chunk: which container, which sub-chunk."""
    src: str                        # "local" | "ring" | "bundle"
    chunk: int = 0                  # sub-chunk index (< plan.n_chunks)


@dataclasses.dataclass(frozen=True)
class Operand:
    """A Ref, optionally predicate-selected against an alternative:
    devices where ``pred`` holds use ``ref``, others use ``alt``."""
    ref: Ref
    alt: Optional[Ref] = None
    pred: Tuple = ALWAYS


@dataclasses.dataclass(frozen=True)
class Route:
    """Routing of one kernel result: merge into local output ``chunk``
    where ``pred`` holds; ``ship`` != 0 first ppermutes the raw (o, lse)
    by that shift and gates the merge with ``recv_pred`` on the receiving
    device (the balanced helper send-home)."""
    pred: Tuple = ALWAYS
    chunk: int = 0
    ship: int = 0
    recv_pred: Tuple = ALWAYS


@dataclasses.dataclass(frozen=True)
class Work:
    """One chunk-attention kernel call and its result routing.
    ``dyn_offsets`` marks masks whose chunk distance depends on the device
    index: the executor passes traced absolute q/kv position offsets
    (zigzag window bands) and resolution is restricted to
    ``dynamic_offsets`` backends."""
    q: Operand
    kv: Operand
    mask: MaskSpec
    routes: Tuple[Route, ...]
    dyn_offsets: bool = False


@dataclasses.dataclass(frozen=True)
class Step:
    """Ring step: advance the traveling containers by ``shift`` hops
    (>1 when skipped steps were folded in), then run ``work``."""
    shift: int
    work: Tuple[Work, ...]


@dataclasses.dataclass(frozen=True)
class SchedulePlan:
    """Static trace-time description of one distributed-attention
    schedule.  ``steps[0]`` is the local step (shift 0); ``mask`` is the
    *global* MaskSpec (may carry static ``boundaries`` — work masks are
    always boundary-stripped, the executor derives per-shard segment
    arrays instead)."""
    name: str
    P: int
    Tl: int                          # local shard length (tokens)
    n_chunks: int                    # local shard viewed as n sub-chunks
    layout: str                      # "natural" | "zigzag"
    mask: MaskSpec
    steps: Tuple[Step, ...]
    total_steps: int                 # ring steps before static skipping

    @property
    def chunk_len(self) -> int:
        return self.Tl // self.n_chunks

    @property
    def exec_steps(self) -> int:
        """Ring steps actually executed (local step excluded)."""
        return len(self.steps) - 1

    @property
    def skipped_steps(self) -> int:
        return self.total_steps - self.exec_steps

    @property
    def kernel_calls(self) -> int:
        return sum(len(s.work) for s in self.steps)

    def _uses(self, src: str) -> bool:
        for s in self.steps:
            for w in s.work:
                for op in (w.q, w.kv):
                    if op.ref.src == src or (op.alt and op.alt.src == src):
                        return True
        return False

    @property
    def ship_q(self) -> bool:
        """A query bundle travels the ring (balanced helpers)."""
        return self._uses("bundle")

    @property
    def uses_ring(self) -> bool:
        return self._uses("ring")

    def cost(self, **kw) -> "PlanCost":
        return plan_cost(self, **kw)


# ---------------------------------------------------------------------------
# Plan builders
# ---------------------------------------------------------------------------

PLAN_SCHEDULES = ("ring", "balanced", "zigzag")

_L0 = Operand(Ref("local", 0))
_L1 = Operand(Ref("local", 1))
_R0 = Operand(Ref("ring", 0))
_R1 = Operand(Ref("ring", 1))
_B0 = Operand(Ref("bundle", 0))


def _exec_mask(m: MaskSpec) -> MaskSpec:
    """Kernel-facing variant of the global mask: static ``boundaries`` are
    absolute coordinates the per-shard kernels can't see — strip them (the
    executor derives per-shard segment arrays from them instead)."""
    return m.replace(boundaries=None) if m.boundaries is not None else m


def _any_pair(m: MaskSpec, c: int, pairs) -> bool:
    """Does any device's (q-chunk, kv-chunk) global-index pair have a
    possibly-unmasked position pair?  ``pairs`` iterates (qg, kg) global
    chunk indices; chunks span ``c`` tokens."""
    return any(mk.chunk_pair_needed(m, qg * c, (qg + 1) * c - 1,
                                    kg * c, (kg + 1) * c - 1)
               for qg, kg in pairs)


def _assemble(name, m, P, Tl, n_chunks, layout, local_work, executed,
              total_steps) -> SchedulePlan:
    """Fold the executed (t, works) list into Steps with cumulative
    shifts over skipped ring steps."""
    steps = [Step(0, tuple(local_work))]
    prev = 0
    for t, works in executed:
        steps.append(Step(t - prev, tuple(works)))
        prev = t
    return SchedulePlan(name=name, P=P, Tl=Tl, n_chunks=n_chunks,
                        layout=layout, mask=m, steps=tuple(steps),
                        total_steps=total_steps)


def _ring_plan(m: MaskSpec, P: int, Tl: int) -> SchedulePlan:
    """Vanilla ring (paper Alg. 1): P−1 steps, device p computes
    (q_p × kv_{p−t}); causal devices p < t idle.  Sliding windows truncate
    the tail; static document boundaries prune steps no document spans."""
    me = _exec_mask(m)
    local = [Work(_L0, _L0, me, (Route(),))]
    executed = []
    for t in range(1, P):
        devs = range(t, P) if m.causal else range(P)
        if not _any_pair(m, Tl, [(p, (p - t) % P) for p in devs]):
            continue
        pred = _ge(t) if m.causal else ALWAYS
        executed.append((t, [Work(_L0, _R0, mk.ring_step(me, t * Tl),
                                  (Route(pred=pred),))]))
    return _assemble("ring", m, P, Tl, 1, "natural", local, executed, P - 1)


def _balanced_plan(m: MaskSpec, P: int, Tl: int) -> SchedulePlan:
    """Load-balanced schedule (paper Alg. 2): ⌊P/2⌋ steps; workers with
    causal work left compute (q_p × kv_{p−t}) while helpers compute
    (q_{(p−t) mod P} × kv_p) for distance-(P−t) pairs and ship (o, lse)
    home.  Plain causal (± dynamic document) fuses both roles into one
    predicate-selected kernel per step, as the paper's implementation
    does; windowed / boundary-pruned variants split into separately
    skippable worker and helper items (worker distance t, helper distance
    P−t — a small window truncates to a helper-free, balanced-by-
    construction band)."""
    me = _exec_mask(m)
    local = [Work(_L0, _L0, me, (Route(),))]
    T = P // 2
    fused = m.window == 0 and m.boundaries is None
    executed = []
    for t in range(1, T + 1):
        helpers = (t != T) or (P % 2 == 1)
        if fused:
            routes = [Route(pred=_ge(t))]
            if helpers:
                routes.append(Route(pred=_lt(t), ship=-t,
                                    recv_pred=_ge(P - t)))
            executed.append((t, [Work(
                Operand(Ref("local", 0), Ref("bundle", 0), _ge(t)),
                Operand(Ref("ring", 0), Ref("local", 0), _ge(t)),
                mk.strict_causal_pair(me), tuple(routes))]))
            continue
        works = []
        if _any_pair(m, Tl, [(p, p - t) for p in range(t, P)]):
            works.append(Work(_L0, _R0, mk.ring_step(me, t * Tl),
                              (Route(pred=_ge(t)),)))
        if helpers and _any_pair(m, Tl, [(p + P - t, p) for p in range(t)]):
            works.append(Work(_B0, _L0, mk.ring_step(me, (P - t) * Tl),
                              (Route(pred=_lt(t), ship=-t,
                                     recv_pred=_ge(P - t)),)))
        if works:
            executed.append((t, works))
    return _assemble("balanced", m, P, Tl, 1, "natural", local, executed, T)


def _zigzag_plan(m: MaskSpec, P: int, Tl: int) -> SchedulePlan:
    """Zigzag placement (beyond-paper): 2P half-chunks, device p holds
    (p, 2P−1−p); exact balance with only the KV ring.  At step t the
    received container holds chunks (r, 2P−1−r) of r = (p−t) mod P and
    each device computes two strictly-causal pairs.  Mirror-chunk pair
    distances depend on the device index, so windowed variants use
    dynamic-offset masks — and skipping carves out the *middle* steps
    (both sequence ends are ring-local under the mirror placement)."""
    if Tl % 2:
        raise ValueError(f"zigzag needs an even local shard length, "
                         f"got {Tl}")
    c = Tl // 2
    G = 2 * P

    def gl(p, i):                      # global half-chunk of (device, slot)
        return p if i == 0 else G - 1 - p

    me = _exec_mask(m)
    m_x = mk.strict_causal_pair(me)
    m_dyn = mk.offdiag_step(me)
    win = m.window > 0
    local = [Work(_L0, _L0, me, (Route(chunk=0),))]
    if _any_pair(m, c, [(gl(p, 1), gl(p, 0)) for p in range(P)]):
        local.append(Work(_L1, _L0, m_dyn if win else m_x,
                          (Route(chunk=1),), dyn_offsets=win))
    local.append(Work(_L1, _L1, me, (Route(chunk=1),)))
    fused = m.window == 0 and m.boundaries is None
    executed = []
    for t in range(1, P):
        if fused:
            w1 = Work(Operand(Ref("local", 0), Ref("local", 1), _ge(t)),
                      _R0, m_x,
                      (Route(pred=_ge(t), chunk=0),
                       Route(pred=_lt(t), chunk=1)))
            w2 = Work(_L1,
                      Operand(Ref("ring", 0), Ref("ring", 1), _ge(t)),
                      m_x, (Route(chunk=1),))
            executed.append((t, [w1, w2]))
            continue
        works = []
        # worker a×a_r — static distance t
        if _any_pair(m, c, [(p, p - t) for p in range(t, P)]):
            works.append(Work(_L0, _R0, mk.ring_step(me, t * c),
                              (Route(pred=_ge(t), chunk=0),)))
        # b̄×a_r — distances P−1−2p+t (helpers) / 2P−1−2p+t (workers),
        # device-dependent; both branches are the *same* kernel call
        # (q=local1, kv=ring0, dynamic-offset mask), so when both survive
        # pruning they fuse into one always-routed Work
        need_h = _any_pair(m, c, [(gl(p, 1), p + P - t) for p in range(t)])
        need_w = _any_pair(m, c, [(gl(p, 1), p - t) for p in range(t, P)])
        if need_h or need_w:
            pred = ALWAYS if (need_h and need_w) else \
                (_lt(t) if need_h else _ge(t))
            works.append(Work(_L1, _R0, m_dyn,
                              (Route(pred=pred, chunk=1),),
                              dyn_offsets=True))
        # helper b̄×b̄_r — static distance P−t
        if _any_pair(m, c, [(gl(p, 1), gl(p + P - t, 1))
                            for p in range(t)]):
            works.append(Work(_L1, _R1, mk.ring_step(me, (P - t) * c),
                              (Route(pred=_lt(t), chunk=1),)))
        if works:
            executed.append((t, works))
    return _assemble("zigzag", m, P, Tl, 2, "zigzag", local, executed,
                     P - 1)


_BUILDERS = {"ring": _ring_plan, "balanced": _balanced_plan,
             "zigzag": _zigzag_plan}


def build_plan(schedule: str, mask: MaskSpec, P: int, Tl: int) \
        -> SchedulePlan:
    """Build the SchedulePlan for one schedule × mask × P × shard length.
    Pure python over static ints — runs at trace time."""
    if schedule not in _BUILDERS:
        raise ValueError(f"no plan builder for schedule {schedule!r}; "
                         f"plan schedules: {PLAN_SCHEDULES}")
    return _BUILDERS[schedule](mask, P, Tl)


# ---------------------------------------------------------------------------
# Shared executor machinery
# ---------------------------------------------------------------------------

def _shift(x, axis, shift, size):
    """ppermute by ``shift`` hops: device p receives from (p − shift) mod
    P.  Multi-hop shifts (skipped steps folded together) are one
    collective."""
    perm = [(i, (i + shift) % size) for i in range(size)]
    return compat.tree_map(lambda a: lax.ppermute(a, axis, perm), x)


def _gchunk(layout, P, owner, i):
    """Global chunk index of (owner device, local sub-chunk i); works for
    python ints and traced owners."""
    if layout == "zigzag" and i == 1:
        return 2 * P - 1 - owner
    return owner


class _Ctx:
    """Per-trace executor state: local shards, the traveling containers at
    the current ring distance, and the static plan."""

    def __init__(self, plan, axis, tune, q, k, v, seg, latent=None):
        self.plan, self.axis, self.tune = plan, axis, tune
        self.P = plan.P
        self.p = lax.axis_index(axis)
        self.nc = plan.n_chunks
        self.c = q.shape[1] // self.nc
        self.B = q.shape[0]
        self.q, self.k, self.v, self.seg = q, k, v, seg
        self.latent = latent                  # (payload, w_up, expand)
        m = plan.mask
        self.doc = m.document
        self.derive_seg = (m.document and seg is None
                          and m.boundaries is not None)
        self.d = 0                            # current ring distance
        self.ring_kv = None                   # (k, v) at distance d
        self.ring_seg = None
        self.bundle = None                    # fwd: q; bwd: (q, do, lse, Δ)

    # ------------------------------------------------------------ chunks
    def _cut(self, x, i):
        return x[:, i * self.c:(i + 1) * self.c]

    def owner(self, src):
        return self.p if src == "local" else (self.p - self.d) % self.P

    def offset(self, ref):
        """Traced absolute token offset of a ref's chunk."""
        g = _gchunk(self.plan.layout, self.P, self.owner(ref.src), ref.chunk)
        return (g * self.c).astype(jnp.int32) if hasattr(g, "astype") \
            else jnp.int32(g * self.c)

    def seg_for(self, ref):
        """(B, c) int32 segment IDs for a ref's chunk, or None."""
        if not self.doc:
            return None
        if self.derive_seg:
            g = _gchunk(self.plan.layout, self.P, self.owner(ref.src),
                        ref.chunk)
            pos = g * self.c + jnp.arange(self.c)
            row = self.plan.mask.segment_of(pos)
            return jnp.broadcast_to(row[None, :], (self.B, self.c))
        if self.seg is None:
            return None
        arr = self.seg if ref.src == "local" else self.ring_seg
        return self._cut(arr, ref.chunk)

    # ---------------------------------------------------------- containers
    def data_containers(self, bwd_bundle=None):
        """The pytree of traveling data (built once, before the first
        shift).  ``bwd_bundle`` supplies (do, lse, delta) so the backward
        bundle carries the helper-side statistics next to q."""
        plan = self.plan
        data = {}
        if plan.uses_ring:
            data["kv"] = self.latent[0] if self.latent else (self.k, self.v)
        if plan.ship_q:
            data["bundle"] = (self.q,) if bwd_bundle is None \
                else (self.q,) + tuple(bwd_bundle)
        if self.doc and not self.derive_seg and self.seg is not None \
                and (plan.uses_ring or plan.ship_q):
            data["seg"] = self.seg
        return data

    def install(self, data):
        """Point the ctx at a (shifted) container pytree."""
        if "kv" in data:
            if self.latent:
                _, w_up, expand = self.latent
                self.ring_kv = expand(data["kv"], w_up)
            else:
                self.ring_kv = data["kv"]
        self.ring_seg = data.get("seg")
        self.bundle = data.get("bundle")


def _sel(pv, a, b):
    """Predicate-select two pytrees of arrays/scalars (None passes
    through)."""
    return compat.tree_map(lambda x, y: jnp.where(pv, x, y), a, b)


def _q_side(ctx: _Ctx, ref: Ref, extras):
    """(q, seg, off[, extras...]) for a q-side ref.  ``extras`` names the
    bundle-resident statistics the backward needs (do, lse, delta), pulled
    from the local arrays or the traveling bundle to match the ref."""
    if ref.src == "local":
        vals = [ctx._cut(ctx.q, ref.chunk)]
        vals += [ctx._cut(x, ref.chunk) for x in extras]
    else:
        assert ref.src == "bundle"
        vals = [ctx._cut(ctx.bundle[0], ref.chunk)]
        vals += [ctx._cut(x, ref.chunk) for x in ctx.bundle[1:]]
    return tuple(vals) + (ctx.seg_for(ref), ctx.offset(ref))


def _kv_side(ctx: _Ctx, ref: Ref):
    kk, vv = (ctx.k, ctx.v) if ref.src == "local" else ctx.ring_kv
    return (ctx._cut(kk, ref.chunk), ctx._cut(vv, ref.chunk),
            ctx.seg_for(ref), ctx.offset(ref))


def _resolve(ctx, op: Operand, side_fn):
    a = side_fn(op.ref)
    if op.alt is None:
        return a
    b = side_fn(op.alt)
    pv = _pred_val(op.pred, ctx.p)
    return tuple(None if x is None else _sel(pv, x, y)
                 for x, y in zip(a, b))


def _mask_kw(ctx, w: Work, q_seg, kv_seg, q_off, kv_off):
    kw = dict(ctx.tune)
    if w.mask.document and q_seg is not None:
        kw.update(q_segments=q_seg, kv_segments=kv_seg)
    if w.dyn_offsets:
        kw.update(q_offset=q_off, kv_offset=kv_off)
    return kw


def _wval(ctx, preds):
    """f32 product weight of a predicate list (None = 1)."""
    w = None
    for pr in preds:
        v = _pred_val(pr, ctx.p)
        if v is None:
            continue
        v = v.astype(jnp.float32)
        w = v if w is None else w * v
    return w


def _grad_branches(op: Operand, route_pred):
    """Resolve which operand branch(es) a route's gradient flows to,
    with the predicate weight(s): [(preds, ref), ...]."""
    if op.alt is None or op.pred == ALWAYS:
        return [([route_pred], op.ref)]
    if op.pred == route_pred:
        return [([route_pred], op.ref)]
    if op.pred == _neg(route_pred):
        return [([route_pred], op.alt)]
    return [([route_pred, op.pred], op.ref),
            ([route_pred, _neg(op.pred)], op.alt)]


# ---------------------------------------------------------------------------
# Forward executor
# ---------------------------------------------------------------------------

def execute_fwd(plan: SchedulePlan, q, k, v, seg=None, *, axis, tune,
                latent=None):
    """Run any SchedulePlan forward.  Local (per-shard) code for
    ``shard_map``; returns (o, lse).  ``latent=(payload, w_up, expand)``
    ships the payload on the KV ring and expands it locally on every
    device (the MLA latent ring's recompute-over-communicate trade)."""
    ctx = _Ctx(plan, axis, tune, q, k, v, seg, latent)
    acc = [None] * plan.n_chunks

    def run(step):
        for w in step.work:
            qc, q_seg, q_off = _resolve(ctx, w.q, lambda r: _q_side(ctx, r, ()))
            kc, vc, kv_seg, kv_off = _resolve(ctx, w.kv,
                                              lambda r: _kv_side(ctx, r))
            o_t, s_t = chunk_attn(qc, kc, vc, mask=w.mask,
                                  **_mask_kw(ctx, w, q_seg, kv_seg,
                                             q_off, kv_off))
            for r in w.routes:
                o_r, s_r = o_t, s_t
                pred = r.pred
                if r.ship:
                    o_r, s_r = _shift((o_t, s_t), axis, r.ship, plan.P)
                    pred = r.recv_pred
                pv = _pred_val(pred, ctx.p)
                if pv is not None:
                    o_r, s_r = mask_partial(pv, o_r, s_r)
                acc[r.chunk] = (o_r, s_r) if acc[r.chunk] is None \
                    else merge(*acc[r.chunk], o_r, s_r)

    run(plan.steps[0])
    rest = plan.steps[1:]
    if rest:
        data = ctx.data_containers()
        data = _shift(data, axis, rest[0].shift, plan.P)   # prefetch step 1
        ctx.d = rest[0].shift
        ctx.install(data)
        for i, step in enumerate(rest):
            nxt = _shift(data, axis, rest[i + 1].shift, plan.P) \
                if i + 1 < len(rest) else None               # prefetch (overlap)
            run(step)
            if nxt is not None:
                data = nxt
                ctx.d += rest[i + 1].shift
                ctx.install(data)
    outs = [a if a is not None
            else empty_partial(ctx._cut(q, i))
            for i, a in enumerate(acc)]
    if plan.n_chunks == 1:
        return outs[0]
    return (jnp.concatenate([o for o, _ in outs], axis=1),
            jnp.concatenate([s for _, s in outs], axis=1))


# ---------------------------------------------------------------------------
# Backward executor
# ---------------------------------------------------------------------------

def execute_bwd(plan: SchedulePlan, q, k, v, o, lse, do, seg=None, *,
                axis, tune):
    """Run any SchedulePlan backward from the saved (o, lse) — FA2
    backward per Work item, gradients routed by operand source, traveling
    accumulators returned home with one final multi-hop ppermute.
    Returns (dq, dk, dv)."""
    f32 = jnp.float32
    delta = jnp.sum(o.astype(f32) * do.astype(f32), axis=-1)     # (B,T,H)
    ctx = _Ctx(plan, axis, tune, q, k, v, seg)
    dq = jnp.zeros(q.shape, f32)
    dk_home = jnp.zeros(k.shape, f32)
    dv_home = jnp.zeros(v.shape, f32)
    dkv = (jnp.zeros(k.shape, f32), jnp.zeros(v.shape, f32)) \
        if plan.uses_ring else None
    dqb = jnp.zeros(q.shape, f32) if plan.ship_q else None

    def sl(i):
        return slice(i * ctx.c, (i + 1) * ctx.c)

    def add(base, i, val, wgt):
        val = val.astype(f32) if wgt is None else val.astype(f32) * wgt
        return base.at[:, sl(i)].add(val)

    def run(step):
        nonlocal dq, dk_home, dv_home, dkv, dqb
        for w in step.work:
            qc, do_c, lse_c, dlt_c, q_seg, q_off = _resolve(
                ctx, w.q, lambda r: _q_side(ctx, r, (do, lse, delta)))
            kc, vc, kv_seg, kv_off = _resolve(ctx, w.kv,
                                              lambda r: _kv_side(ctx, r))
            dq_t, dk_t, dv_t = chunk_attn_bwd(
                qc, kc, vc, jnp.zeros_like(qc), lse_c, do_c, mask=w.mask,
                delta=dlt_c,
                **_mask_kw(ctx, w, q_seg, kv_seg, q_off, kv_off))
            for r in w.routes:
                for preds, ref in _grad_branches(w.q, r.pred):
                    wgt = _wval(ctx, preds)
                    if ref.src == "local":
                        dq = add(dq, ref.chunk, dq_t, wgt)
                    else:
                        dqb = add(dqb, ref.chunk, dq_t, wgt)
                for preds, ref in _grad_branches(w.kv, r.pred):
                    wgt = _wval(ctx, preds)
                    if ref.src == "local":
                        dk_home = add(dk_home, ref.chunk, dk_t, wgt)
                        dv_home = add(dv_home, ref.chunk, dv_t, wgt)
                    else:
                        dkv = (add(dkv[0], ref.chunk, dk_t, wgt),
                               add(dkv[1], ref.chunk, dv_t, wgt))

    run(plan.steps[0])
    rest = plan.steps[1:]
    if rest:
        data = ctx.data_containers(bwd_bundle=(do, lse, delta))
        data = _shift(data, axis, rest[0].shift, plan.P)
        ctx.d = rest[0].shift
        ctx.install(data)
        for i, step in enumerate(rest):
            nxt = _shift(data, axis, rest[i + 1].shift, plan.P) \
                if i + 1 < len(rest) else None               # prefetch (overlap)
            run(step)
            if nxt is not None:
                data = nxt
                ctx.install(data)
                s = rest[i + 1].shift
                ctx.d += s
                if dkv is not None:                # accumulators move late
                    dkv = _shift(dkv, axis, s, plan.P)
                if dqb is not None:
                    dqb = _shift(dqb, axis, s, plan.P)
        D = ctx.d                                  # route accumulators home
        if dkv is not None:
            dkv = _shift(dkv, axis, -D, plan.P)
        if dqb is not None:
            dqb = _shift(dqb, axis, -D, plan.P)
    if dkv is not None:
        dk_home = dk_home + dkv[0]
        dv_home = dv_home + dkv[1]
    if dqb is not None:
        dq = dq + dqb
    return dq.astype(q.dtype), dk_home.astype(k.dtype), \
        dv_home.astype(v.dtype)


# ---------------------------------------------------------------------------
# Pure-python plan simulator (property tests: exactly-once coverage)
# ---------------------------------------------------------------------------

def _sim_allow(w: Work, plan: SchedulePlan, qg, kg, c, segments):
    """Boolean (c, c) attend matrix exactly as the kernel would compute it
    for this work item: static mask offsets, plus true global offsets when
    ``dyn_offsets``, plus segment IDs (given or boundary-derived)."""
    m = w.mask
    q_pos = m.q_offset + (qg * c if w.dyn_offsets else 0) + np.arange(c)
    k_pos = m.kv_offset + (kg * c if w.dyn_offsets else 0) + np.arange(c)
    qs = ks = None
    if m.document:
        if segments is not None:
            qs = np.asarray(segments)[qg * c:(qg + 1) * c][:, None]
            ks = np.asarray(segments)[kg * c:(kg + 1) * c][None, :]
        elif plan.mask.boundaries is not None:
            gb = plan.mask
            qs = np.array([gb.segment_index(qg * c + i)
                           for i in range(c)])[:, None]
            ks = np.array([gb.segment_index(kg * c + j)
                           for j in range(c)])[None, :]
    allow = m.allow(q_pos[:, None], k_pos[None, :], qs, ks)
    if allow is None:
        return np.ones((c, c), bool)
    return np.asarray(allow)


def plan_coverage(plan: SchedulePlan, c: Optional[int] = None,
                  segments=None) -> np.ndarray:
    """(T, T) count of how many times each *global* (q, kv) token pair is
    computed-and-merged by the plan — a pure-python walk of the executor's
    routing.  ``c`` overrides tokens per sub-chunk (default: the plan's);
    ``segments`` is an optional (T,) global segment-ID array for dynamic
    document masks.  The exactly-once property: counts equal 1 on the
    global mask's allowed pairs and 0 elsewhere (see
    :func:`global_allow`)."""
    P, nc = plan.P, plan.n_chunks
    c = plan.chunk_len if c is None else c
    T = P * nc * c
    counts = np.zeros((T, T), np.int64)
    for p in range(P):
        d = 0
        for step in plan.steps:
            d += step.shift
            for w in step.work:
                qref = w.q.ref if _pred_int(w.q.pred, p) else w.q.alt
                kref = w.kv.ref if _pred_int(w.kv.pred, p) else w.kv.alt
                q_owner = p if qref.src == "local" else (p - d) % P
                k_owner = p if kref.src == "local" else (p - d) % P
                qg = _gchunk(plan.layout, P, q_owner, qref.chunk)
                kg = _gchunk(plan.layout, P, k_owner, kref.chunk)
                for r in w.routes:
                    if r.ship:
                        recv = (p + r.ship) % P
                        active = (_pred_int(r.pred, p)
                                  and _pred_int(r.recv_pred, recv))
                    else:
                        active = _pred_int(r.pred, p)
                    if not active:
                        continue
                    allow = _sim_allow(w, plan, qg, kg, c, segments)
                    counts[qg * c:(qg + 1) * c,
                           kg * c:(kg + 1) * c] += allow
    return counts


def global_allow(mask: MaskSpec, T: int, segments=None) -> np.ndarray:
    """(T, T) ground-truth attend matrix of the *global* mask at absolute
    positions — what the distributed schedules must jointly reproduce."""
    pos = np.arange(T)
    qs = ks = None
    if mask.document:
        if segments is not None:
            qs = np.asarray(segments)[:, None]
            ks = np.asarray(segments)[None, :]
        elif mask.boundaries is not None:
            seg = np.array([mask.segment_index(i) for i in range(T)])
            qs, ks = seg[:, None], seg[None, :]
        else:
            raise ValueError("document mask needs segments or boundaries")
    allow = mask.allow(pos[:, None], pos[None, :], qs, ks)
    if allow is None:
        return np.ones((T, T), bool)
    return np.asarray(allow)


# ---------------------------------------------------------------------------
# Static comm/compute cost model (drives schedule="auto")
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlanCost:
    """Per-device static cost summary of one plan (or the ulysses
    baseline).  ``comm_bytes_*`` are hop-weighted ring-link bytes;
    ``flops_*`` count kernel matmul FLOPs after static mask pruning
    (dynamic-offset items count dense — their kernels can't prune)."""
    schedule: str
    exec_steps: int
    total_steps: int
    kernel_calls: int
    flops_fwd: float
    flops_bwd: float
    comm_bytes_fwd: float
    comm_bytes_bwd: float

    def time_estimate(self, include_bwd: bool = True) -> dict:
        """Two-term (compute, collective) roofline seconds via
        analysis.roofline constants — no HBM term (schedule-invariant)."""
        from repro.analysis.roofline import schedule_cost_terms
        fl = self.flops_fwd + (self.flops_bwd if include_bwd else 0.0)
        by = self.comm_bytes_fwd + (self.comm_bytes_bwd if include_bwd
                                    else 0.0)
        return schedule_cost_terms(flops=fl, comm_bytes=by)


def _band_pairs(mask: MaskSpec, cq: int, ck: int) -> float:
    """Unmasked (q, kv) pair count of a *static* work mask over a (cq, ck)
    chunk pair (document refinement is dynamic and ignored — an upper
    bound)."""
    if not (mask.causal or (mask.window and mask.window > 0)):
        return float(cq * ck)
    qpos = mask.q_offset - mask.kv_offset + np.arange(cq)
    hi = np.minimum(qpos, ck - 1) if mask.causal \
        else np.full(cq, ck - 1)
    lo = np.maximum(qpos - mask.window + 1, 0) if mask.window \
        else np.zeros(cq)
    return float(np.maximum(hi - lo + 1, 0).sum())


def plan_cost(plan: SchedulePlan, *, B: int = 1, Hq: int = 8,
              Hkv: Optional[int] = None, Dqk: int = 64,
              Dv: Optional[int] = None, bpe: int = 2,
              dynamic_seg: bool = False) -> PlanCost:
    """Static per-device cost of a plan: kernel FLOPs per Work item
    (after static mask pruning) and hop-weighted ring traffic per
    executed shift, fwd and bwd."""
    Hkv = Hq if Hkv is None else Hkv
    Dv = Dqk if Dv is None else Dv
    c = plan.chunk_len
    f_fwd = f_bwd = 0.0
    for s in plan.steps:
        for w in s.work:
            pairs = float(c * c) if w.dyn_offsets \
                else _band_pairs(w.mask, c, c)
            f_fwd += 2.0 * B * Hq * pairs * (Dqk + Dv)
            f_bwd += 2.0 * B * Hq * pairs * (3 * Dqk + 2 * Dv)
    kv_bytes = B * plan.Tl * Hkv * (Dqk + Dv) * bpe if plan.uses_ring \
        else 0.0
    seg_bytes = B * plan.Tl * 4 if (plan.mask.document and dynamic_seg
                                    and (plan.uses_ring or plan.ship_q)) \
        else 0.0
    q_bytes = B * plan.Tl * Hq * Dqk * bpe if plan.ship_q else 0.0
    do_bytes = B * plan.Tl * Hq * Dv * bpe if plan.ship_q else 0.0
    stat_bytes = 2 * B * plan.Tl * Hq * 4 if plan.ship_q else 0.0
    dkv_bytes = B * plan.Tl * Hkv * (Dqk + Dv) * 4 if plan.uses_ring \
        else 0.0
    dqb_bytes = B * plan.Tl * Hq * Dqk * 4 if plan.ship_q else 0.0
    shifts = [s.shift for s in plan.steps[1:]]
    D = sum(shifts)
    c_fwd = (kv_bytes + seg_bytes + q_bytes) * D
    for s in plan.steps:
        for w in s.work:
            for r in w.routes:
                if r.ship:
                    c_fwd += (B * c * Hq * Dv * bpe
                              + B * c * Hq * 4) * abs(r.ship)
    # bwd: data containers travel D hops; traveling accumulators move on
    # every transition after the first executed step (D − s1 hops) and
    # return home with one D-hop shift
    acc_hops = (D - shifts[0] if shifts else 0) + (D if shifts else 0)
    c_bwd = (kv_bytes + seg_bytes + q_bytes + do_bytes + stat_bytes) * D \
        + (dkv_bytes + dqb_bytes) * acc_hops
    return PlanCost(schedule=plan.name, exec_steps=plan.exec_steps,
                    total_steps=plan.total_steps,
                    kernel_calls=plan.kernel_calls,
                    flops_fwd=f_fwd, flops_bwd=f_bwd,
                    comm_bytes_fwd=c_fwd, comm_bytes_bwd=c_bwd)


def ulysses_cost(mask: MaskSpec, P: int, *, Tl: int, B: int = 1,
                 Hq: int = 8, Hkv: Optional[int] = None, Dqk: int = 64,
                 Dv: Optional[int] = None, bpe: int = 2) -> PlanCost:
    """Analytic per-device cost of the DeepSpeed-Ulysses baseline:
    all-to-all q/k/v + o, full-sequence attention over Hq/P heads."""
    Hkv = Hq if Hkv is None else Hkv
    Dv = Dqk if Dv is None else Dv
    Tg = P * Tl
    pairs = _band_pairs(mask, Tg, Tg)
    f_fwd = 2.0 * B * (Hq / P) * pairs * (Dqk + Dv)
    f_bwd = 2.0 * B * (Hq / P) * pairs * (3 * Dqk + 2 * Dv)
    a2a = (P - 1) / P
    io_fwd = B * Tl * (Hq * Dqk + Hkv * (Dqk + Dv) + Hq * Dv) * bpe \
        + B * Tl * Hq * 4                     # q,k,v in; o, lse back
    c_fwd = io_fwd * a2a
    c_bwd = 2.0 * c_fwd                       # dq,dk,dv + do round trips
    return PlanCost(schedule="ulysses", exec_steps=1, total_steps=1,
                    kernel_calls=1, flops_fwd=f_fwd, flops_bwd=f_bwd,
                    comm_bytes_fwd=c_fwd, comm_bytes_bwd=c_bwd)


def plan_capable(schedule: str, mask: MaskSpec) -> bool:
    """Can this plan schedule serve the mask?  (prefix_lm needs absolute
    kv positions on every chunk — ulysses/rsa territory; balanced/zigzag
    additionally need a causal-kind mask for their strictly-causal pair
    placement.  A *non-causal* sliding window needs future-direction band
    steps the ring's strictly-past step masks can't express — ulysses
    only.)"""
    if mask.prefix_len:
        return False
    if mask.window and not mask.causal:
        return False
    if schedule in ("balanced", "zigzag"):
        return bool(mask.causal)
    return schedule == "ring"


def ulysses_capable(mask: MaskSpec, P: int, Hq: int, Hkv: int, *,
                    include_bwd: bool = True) -> bool:
    """Can the bespoke ulysses baseline serve this call *without raising at
    execution time*?  Forward needs both head counts divisible by P
    (``_fwd_ulysses`` raises otherwise); a backward additionally rules out
    prefix_lm and non-causal sliding windows, because the baselines reuse
    the ring backward, whose per-shard chunks cannot see absolute
    positions / future-direction bands (``_bwd_local`` raises).  The
    trace-time filter must mirror those runtime checks exactly —
    ``schedule="auto"`` may never resolve to a name that then raises."""
    if Hq % P or Hkv % P:
        return False
    if include_bwd and mask.prefix_len:
        return False
    if include_bwd and mask.window and not mask.causal:
        return False
    return True


# ---------------------------------------------------------------------------
# 2D sequence×head (ring×ulysses) factored plans
# ---------------------------------------------------------------------------
#
# BurstAttention-style mesh factorization: the P sequence-parallel workers
# are split into a (seq = r) × (head = u) grid, P = r·u.  The global
# sequence is sharded over the *pair* of axes (seq major, head minor), so a
# tiled all-to-all over the head sub-axis — DeepSpeed-Ulysses' head scatter
# — leaves each device with a contiguous T/r sequence shard and Hq/u query
# heads; any ring-family SchedulePlan then runs unchanged on the seq
# sub-axis (windowed/document step pruning intact), and the results travel
# back through the inverse all-to-all.  GQA-aware: query heads always
# scatter; KV heads scatter when ``Hkv % u == 0`` and are otherwise
# all-gathered over the head sub-axis with a per-device head *selection*
# (each device keeps exactly the KV heads its query heads map to, so the
# inner plan is locally MHA).

PLAN2D_SCHEDULES = PLAN_SCHEDULES


@dataclasses.dataclass(frozen=True)
class Plan2D:
    """A factored 2D schedule: head scatter over ``u`` devices wrapping the
    ``inner`` ring-family plan over ``r`` devices (``inner.P == r``,
    ``inner.Tl == u · Tl_dev``).  ``Hq``/``Hkv`` are the *global* head
    counts — the head routing is static."""
    inner: SchedulePlan
    r: int
    u: int
    Hq: int
    Hkv: int
    kv_mode: str                   # "scatter" | "replicate"

    @property
    def name(self) -> str:
        return f"{self.inner.name}@r{self.r}u{self.u}"

    @property
    def P(self) -> int:
        return self.r * self.u

    def cost(self, **kw) -> "PlanCost":
        return plan2d_cost(self, **kw)


def plan2d_capable(schedule: str, mask: MaskSpec, *, r: int, u: int,
                   Hq: int, Hkv: int) -> bool:
    """Can the (schedule, r, u) factorization serve this mask × head
    shape?  Query heads must split evenly over the head sub-axis and the
    GQA group structure must be uniform; the inner schedule follows the 1D
    plan capability rules — except at r == 1, where the 'ring' degenerates
    to one local full-sequence kernel after the head scatter, which can
    express *any* mask kind (absolute positions exist), prefix_lm and
    non-causal windows included."""
    if schedule not in PLAN2D_SCHEDULES:
        return False
    if Hq % u or Hq % Hkv:
        return False
    if r == 1:
        return schedule == "ring"
    return plan_capable(schedule, mask)


def build_plan2d(schedule: str, mask: MaskSpec, r: int, u: int,
                 Tl_dev: int, *, Hq: int, Hkv: int) -> Plan2D:
    """Build the 2D plan for one factorization: the inner seq-axis plan at
    P = r over the post-scatter shard length u·Tl_dev, plus the static KV
    head-routing mode.  Pure python over static ints — trace time."""
    if not plan2d_capable(schedule, mask, r=r, u=u, Hq=Hq, Hkv=Hkv):
        raise ValueError(
            f"2D factorization (schedule={schedule!r}, r={r}, u={u}) "
            f"cannot serve mask {mask.kind!r} with heads ({Hq}, {Hkv}) — "
            f"query heads must divide u and the inner schedule must be "
            f"plan-capable for the mask (any mask goes at r == 1)")
    inner = build_plan(schedule, mask, r, u * Tl_dev)
    kv_mode = "scatter" if Hkv % u == 0 else "replicate"
    return Plan2D(inner=inner, r=r, u=u, Hq=Hq, Hkv=Hkv, kv_mode=kv_mode)


def plan2d_head_map(p2: Plan2D, j: int):
    """Static head routing of head-device ``j`` (python ints — the test
    simulator's view): ``(q_ids, kv_ids)`` global head indices of the
    local slots after the scatter.  In scatter mode the KV slots are the
    device's a2a share; in replicate mode they are the selection
    ``(global q head) // g`` — locally MHA (one KV slot per query slot)."""
    Hql = p2.Hq // p2.u
    q_ids = np.arange(j * Hql, (j + 1) * Hql)
    if p2.kv_mode == "scatter":
        Hkvl = p2.Hkv // p2.u
        kv_ids = np.arange(j * Hkvl, (j + 1) * Hkvl)
    else:
        kv_ids = (j * Hql + np.arange(Hql)) // (p2.Hq // p2.Hkv)
    return q_ids, kv_ids


def _a2a_heads(x, axis):
    """Scatter heads, gather sequence (forward direction of the head
    all-to-all): (B, Tc, H, …) → (B, u·Tc, H/u, …).  Peer-order concat
    over the head sub-axis reassembles a contiguous sequence row because
    the global sequence is sharded (seq major, head minor)."""
    return lax.all_to_all(x, axis, split_axis=2, concat_axis=1, tiled=True)


def _a2a_seq(x, axis):
    """Inverse direction: split sequence, gather heads."""
    return lax.all_to_all(x, axis, split_axis=1, concat_axis=2, tiled=True)


def _scatter_heads(p2: Plan2D, q, k, v, seg, head_axis):
    """Head-scatter the per-device shards into the inner plan's layout.
    Returns (qh, kh, vh, segh, kv_ids); ``kv_ids`` is the traced global-KV
    selection (replicate mode only) the backward scatters gradients back
    through."""
    qh = _a2a_heads(q, head_axis)
    kv_ids = None
    if p2.kv_mode == "scatter":
        kh, vh = _a2a_heads(k, head_axis), _a2a_heads(v, head_axis)
    else:
        j = lax.axis_index(head_axis)
        Hql = p2.Hq // p2.u
        g = p2.Hq // p2.Hkv
        kv_ids = (j * Hql + jnp.arange(Hql)) // g
        kg = lax.all_gather(k, head_axis, axis=1, tiled=True)
        vg = lax.all_gather(v, head_axis, axis=1, tiled=True)
        kh = jnp.take(kg, kv_ids, axis=2)
        vh = jnp.take(vg, kv_ids, axis=2)
    segh = None if seg is None \
        else lax.all_gather(seg, head_axis, axis=1, tiled=True)
    return qh, kh, vh, segh, kv_ids


def execute2d_fwd(p2: Plan2D, q, k, v, seg=None, *, seq_axis, head_axis,
                  tune):
    """Run a 2D plan forward: head scatter over ``head_axis``, the inner
    SchedulePlan over ``seq_axis``, inverse scatter home.  Local
    (per-shard) code for shard_map over the (seq, head) axis pair; returns
    (o, lse) in the caller's (seq-major, head-minor) sharding."""
    qh, kh, vh, segh, _ = _scatter_heads(p2, q, k, v, seg, head_axis)
    o_h, s_h = execute_fwd(p2.inner, qh, kh, vh, segh, axis=seq_axis,
                           tune=tune)
    return _a2a_seq(o_h, head_axis), _a2a_seq(s_h, head_axis)


def execute2d_bwd(p2: Plan2D, q, k, v, o, lse, do, seg=None, *, seq_axis,
                  head_axis, tune):
    """Run a 2D plan backward from saved (o, lse): forward-direction
    scatters for the operands, the inner plan backward on the seq
    sub-axis, then gradients home — all-to-all for dq (and dk/dv in
    scatter mode); in replicate mode the selected-head KV gradients
    scatter-add into the full head dim, psum over the head sub-axis, and
    each device keeps its own token chunk."""
    qh, kh, vh, segh, kv_ids = _scatter_heads(p2, q, k, v, seg, head_axis)
    oh, doh = _a2a_heads(o, head_axis), _a2a_heads(do, head_axis)
    lseh = _a2a_heads(lse, head_axis)
    dqh, dkh, dvh = execute_bwd(p2.inner, qh, kh, vh, oh, lseh, doh, segh,
                                axis=seq_axis, tune=tune)
    dq = _a2a_seq(dqh, head_axis)
    if p2.kv_mode == "scatter":
        return dq, _a2a_seq(dkh, head_axis), _a2a_seq(dvh, head_axis)
    B, Tc = k.shape[0], k.shape[1]
    j = lax.axis_index(head_axis)

    def home(dx, x):
        full = jnp.zeros((B, Tc * p2.u, p2.Hkv) + x.shape[3:], jnp.float32)
        full = full.at[:, :, kv_ids].add(dx.astype(jnp.float32))
        full = lax.psum(full, head_axis)
        return lax.dynamic_slice_in_dim(full, j * Tc, Tc,
                                        axis=1).astype(x.dtype)

    return dq, home(dkh, k), home(dvh, v)


def plan2d_cost(p2: Plan2D, *, B: int = 1, Dqk: int = 64,
                Dv: Optional[int] = None, bpe: int = 2,
                dynamic_seg: bool = False) -> PlanCost:
    """Static per-device cost of a 2D plan: the inner plan's cost at the
    factored shapes (Hq/u heads over T/r tokens) plus the head-axis
    collective traffic (all-to-all factor (u−1)/u, all-gather factor u−1 —
    analysis/roofline constants)."""
    from repro.analysis.roofline import a2a_bytes, allgather_bytes
    Dv = Dqk if Dv is None else Dv
    u = p2.u
    Hql = p2.Hq // u
    Hkv_in = Hql if p2.kv_mode == "replicate" else p2.Hkv // u
    inner = plan_cost(p2.inner, B=B, Hq=Hql, Hkv=Hkv_in, Dqk=Dqk, Dv=Dv,
                      bpe=bpe, dynamic_seg=dynamic_seg)
    Tc = p2.inner.Tl // u                       # per-device tokens
    q_b = B * Tc * p2.Hq * Dqk * bpe
    o_b = B * Tc * p2.Hq * Dv * bpe
    lse_b = B * Tc * p2.Hq * 4
    kv_b = B * Tc * p2.Hkv * (Dqk + Dv) * bpe
    seg_b = B * Tc * 4 if dynamic_seg else 0.0
    if p2.kv_mode == "scatter":
        kv_in = a2a_bytes(kv_b, u)
        kv_grad_home = a2a_bytes(kv_b, u)
    else:
        kv_in = allgather_bytes(kv_b, u)
        # ring-allreduce of the full-row f32 KV grads over the head axis
        kv_grad_home = 2.0 * a2a_bytes(
            B * (Tc * u) * p2.Hkv * (Dqk + Dv) * 4, u)
    c_fwd = inner.comm_bytes_fwd + a2a_bytes(q_b + o_b + lse_b, u) \
        + kv_in + allgather_bytes(seg_b, u)
    c_bwd = inner.comm_bytes_bwd \
        + a2a_bytes(2 * q_b + 2 * o_b + lse_b, u) \
        + kv_in + kv_grad_home + allgather_bytes(seg_b, u)
    return PlanCost(schedule=p2.name, exec_steps=inner.exec_steps,
                    total_steps=inner.total_steps,
                    kernel_calls=inner.kernel_calls,
                    flops_fwd=inner.flops_fwd, flops_bwd=inner.flops_bwd,
                    comm_bytes_fwd=c_fwd, comm_bytes_bwd=c_bwd)


def factorizations(P: int):
    """All (r, u) with r·u == P — the 2D search space of
    ``choose_schedule(..., factorize=True)``."""
    return [(r, P // r) for r in range(1, P + 1) if P % r == 0]


def choose_inner_schedule(mask: MaskSpec, r: int, u: int, *, Tl_dev: int,
                          B: int = 1, Hq: int = 8,
                          Hkv: Optional[int] = None, Dqk: int = 64,
                          Dv: Optional[int] = None, bpe: int = 2,
                          dynamic_seg: bool = False,
                          include_bwd: bool = True) -> str:
    """``schedule="auto"`` for a FIXED (r, u) factorization (the mesh is
    already built, so only the inner seq-axis schedule is free): cheapest
    capable ring-family plan by the analytic 2D cost.  zigzag is excluded
    — its global-layout permutation stays a caller contract."""
    Hkv = Hq if Hkv is None else Hkv
    if r == 1:
        return "ring"
    scored = []
    for i, name in enumerate(("balanced", "ring")):
        if not plan2d_capable(name, mask, r=r, u=u, Hq=Hq, Hkv=Hkv):
            continue
        p2 = build_plan2d(name, mask, r, u, Tl_dev, Hq=Hq, Hkv=Hkv)
        t = plan2d_cost(p2, B=B, Dqk=Dqk, Dv=Dv, bpe=bpe,
                        dynamic_seg=dynamic_seg) \
            .time_estimate(include_bwd)["step_s_lower_bound"]
        scored.append((t, i, name))
    if not scored:
        raise ValueError(
            f"schedule='auto': no capable inner schedule for mask "
            f"{mask.kind!r} on a 2D (r={r}, u={u}) mesh with heads "
            f"({Hq}, {Hkv}) — prefix_lm and non-causal sliding windows "
            f"need r == 1 (head-only scatter) or a single-shard axis")
    return min(scored)[2]


def choose_schedule(mask: MaskSpec, P: int, *, Tl: int, B: int = 1,
                    Hq: int = 8, Hkv: Optional[int] = None, Dqk: int = 64,
                    Dv: Optional[int] = None, bpe: int = 2,
                    dynamic_seg: bool = False, include_bwd: bool = True,
                    factorize: bool = False):
    """``schedule="auto"``: pick the cheapest capable schedule for this
    (mask, P, shapes).  Candidates are the plan schedules (zigzag
    excluded — it requires the caller to pre-permute the global layout,
    so it stays an explicit opt-in) plus the ulysses baseline when the
    head counts divide P.

    Ranking consults the active tuning table (repro.tune) first: a
    measured row at the nearest (mask kind, P, seq) bucket decides
    outright; otherwise the table's calibrated cost-model coefficients
    rank the candidates; only with no table at all does the uncalibrated
    analytic roofline decide.  Deterministic: ties break toward
    balanced > ring > ulysses.

    ``include_bwd`` is both the cost-ranking horizon *and* a capability
    constraint: with it set, candidates that would raise in the
    distributed backward (ulysses under prefix_lm / non-causal windows —
    the baselines reuse the ring backward) are filtered out here, at
    trace time, so the resolved name never raises at execution time.

    ``factorize=True`` widens the search to the 2D (seq=r, head=u)
    factorization space and returns a ``(name, r, u)`` triple instead of
    a name — ranked purely by the analytic cost model (the tuning table's
    measured rows are 1D walls and would be incommensurable)."""
    Hkv = Hq if Hkv is None else Hkv
    if factorize:
        return _choose_factorized(mask, P, Tl=Tl, B=B, Hq=Hq, Hkv=Hkv,
                                  Dqk=Dqk, Dv=Dv, bpe=bpe,
                                  dynamic_seg=dynamic_seg,
                                  include_bwd=include_bwd)
    if P <= 1:
        return "ring"
    names = [n for n in ("balanced", "ring") if plan_capable(n, mask)]
    if ulysses_capable(mask, P, Hq, Hkv, include_bwd=include_bwd):
        names.append("ulysses")
    if not names:
        raise ValueError(
            f"schedule='auto': no capable schedule for mask {mask.kind!r} "
            f"with P={P}, heads=({Hq}, {Hkv}) — prefix_lm and non-causal "
            f"sliding windows need absolute positions (ulysses, which "
            f"needs head counts divisible by P) or a single-shard axis")
    if len(names) == 1:
        return names[0]

    from repro.tune.table import active_table
    tab = active_table()
    if tab is not None:
        hit = tab.best_schedule(mask_kind=mask.kind, P=P, seq=P * Tl,
                                candidates=names)
        if hit is not None:
            return hit
    coeffs = tab.coeffs() if tab is not None else None

    scored = []
    order = {"balanced": 0, "ring": 1, "ulysses": 2}
    for name in names:
        if coeffs is not None:
            from repro.tune.calibrate import (predict_s,
                                              schedule_features)
            feats = schedule_features(
                name, mask_kind=mask.kind, P=P, seq=P * Tl, B=B, Hq=Hq,
                Hkv=Hkv, Dqk=Dqk, bpe=bpe, window=mask.window or None,
                dynamic_seg=dynamic_seg, include_bwd=include_bwd)
            if feats is None:
                continue
            t = predict_s(feats, coeffs)
        elif name == "ulysses":
            cost = ulysses_cost(mask, P, Tl=Tl, B=B, Hq=Hq, Hkv=Hkv,
                                Dqk=Dqk, Dv=Dv, bpe=bpe)
            t = cost.time_estimate(include_bwd)["step_s_lower_bound"]
        else:
            cost = plan_cost(build_plan(name, mask, P, Tl), B=B, Hq=Hq,
                             Hkv=Hkv, Dqk=Dqk, Dv=Dv, bpe=bpe,
                             dynamic_seg=dynamic_seg)
            t = cost.time_estimate(include_bwd)["step_s_lower_bound"]
        scored.append((t, order[name], name))
    return min(scored)[2]


def _choose_factorized(mask: MaskSpec, P: int, *, Tl: int, B: int,
                       Hq: int, Hkv: int, Dqk: int, Dv: Optional[int],
                       bpe: int, dynamic_seg: bool, include_bwd: bool):
    """The 2D branch of ``choose_schedule``: rank every capable
    (schedule, r, u) with r·u == P by the analytic cost model and return
    the cheapest triple.  (r = P, u = 1) entries are today's 1D plans;
    (r = 1, u = P) is pure head parallelism through the plan path — the
    ulysses-equivalent, GQA-capable via KV replication, and backward-
    capable for *any* mask kind because the post-scatter kernel sees the
    whole sequence.  zigzag is excluded (caller-permutation contract);
    ties break toward smaller u (fewer head-axis collectives), then
    balanced > ring."""
    if P <= 1:
        return ("ring", 1, 1)
    order = {"balanced": 0, "ring": 1}
    scored = []
    for r, u in factorizations(P):
        for name in ("balanced", "ring"):
            if u == 1:
                if not plan_capable(name, mask):
                    continue
                cost = plan_cost(build_plan(name, mask, P, Tl), B=B,
                                 Hq=Hq, Hkv=Hkv, Dqk=Dqk, Dv=Dv, bpe=bpe,
                                 dynamic_seg=dynamic_seg)
            else:
                if name == "balanced" and r == 1:
                    continue          # degenerate — identical to ring
                if not plan2d_capable(name, mask, r=r, u=u, Hq=Hq,
                                      Hkv=Hkv):
                    continue
                p2 = build_plan2d(name, mask, r, u, Tl, Hq=Hq, Hkv=Hkv)
                cost = plan2d_cost(p2, B=B, Dqk=Dqk, Dv=Dv, bpe=bpe,
                                   dynamic_seg=dynamic_seg)
            t = cost.time_estimate(include_bwd)["step_s_lower_bound"]
            scored.append((t, u, order[name], (name, r, u)))
    if not scored:
        raise ValueError(
            f"schedule='auto': no capable (schedule, r, u) factorization "
            f"of P={P} for mask {mask.kind!r} with heads ({Hq}, {Hkv}) — "
            f"head-parallel factorizations need Hq % u == 0 and a uniform "
            f"GQA group structure")
    return min(scored)[3]
