"""Chunk-attention API used by the distributed schedules.

A *partial* attention op returns ``(o, lse)`` for one (q-chunk, kv-chunk)
pair; partials merge exactly with :func:`merge` (the paper's ``rescale``).

Key property exploited by the schedules (DESIGN.md §2): in the ring /
balanced schedules, the mask of every step depends only on the **relative**
offset between the q and kv chunks (0 for the local step, ``t·Tc`` for step
``t``), which is static per step — so the Pallas kernels never need dynamic
position scalars.

``impl`` selects the backend:
  * ``ref``               — pure-jnp oracle (CPU tests, dry-run lowering)
  * ``pallas``            — TPU Pallas kernel (compiled)
  * ``pallas_interpret``  — Pallas kernel body interpreted on CPU (tests)
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ref import (NEG_INF, chunk_attn_ref, chunk_attn_bwd_ref,
                               merge_ref)

_IMPL = "ref"  # process-wide default; configs override per call


def set_default_impl(impl: str) -> None:
    global _IMPL
    assert impl in ("ref", "pallas", "pallas_interpret", "null"), impl
    _IMPL = impl


def chunk_attn(q, k, v, *, causal=False, rel_offset=0, window=0, scale=None,
               impl=None):
    """Partial attention. ``rel_offset`` = absolute(q0) − absolute(kv0),
    static per schedule step. Returns (o, lse)."""
    impl = impl or _IMPL
    if impl == "ref":
        return chunk_attn_ref(q, k, v, causal=causal, q_offset=rel_offset,
                              kv_offset=0, window=window, scale=scale)
    if impl == "null":
        # dry-run cost-isolation stub: shape-correct, data-dependent (so XLA
        # cannot fold it away), but O(T) instead of O(T²). Used to isolate
        # the attention kernel's contribution from the rest of the model;
        # the kernel's ideal FLOPs/bytes are then added analytically
        # (analysis/roofline.attention_sites).
        B, Tq, Hq, _ = q.shape
        vm = jnp.mean(v.astype(jnp.float32), axis=(1, 2), keepdims=True)
        o = jnp.broadcast_to(vm, (B, Tq, Hq, v.shape[-1])).astype(q.dtype)
        o = o + 0.0 * q[..., :1] * jnp.mean(k)
        lse = jnp.mean(q.astype(jnp.float32), axis=-1)
        return o, lse
    from repro.kernels import ops
    return ops.flash_fwd(q, k, v, causal=causal, rel_offset=rel_offset,
                         window=window, scale=scale,
                         interpret=(impl == "pallas_interpret"))


def chunk_attn_bwd(q, k, v, o, lse, do, *, causal=False, rel_offset=0,
                   window=0, scale=None, impl=None, delta=None):
    """FA2 backward for one chunk using the saved (o, lse) — no forward
    recompute. ``delta = rowsum(o⊙do)`` may be precomputed (the distributed
    helper path ships delta instead of o). Returns (dq, dk, dv)."""
    impl = impl or _IMPL
    if impl == "ref":
        return chunk_attn_bwd_ref(q, k, v, o, lse, do, causal=causal,
                                  q_offset=rel_offset, kv_offset=0,
                                  window=window, scale=scale, delta=delta)
    if impl == "null":
        s_do = jnp.mean(do.astype(jnp.float32))
        dq = (q.astype(jnp.float32) * 0.0 + s_do).astype(q.dtype)
        dk = (k.astype(jnp.float32) * 0.0 + s_do).astype(k.dtype)
        dv = (v.astype(jnp.float32) * 0.0 + s_do).astype(v.dtype)
        return dq, dk, dv
    from repro.kernels import ops
    return ops.flash_bwd(q, k, v, o, lse, do, causal=causal,
                         rel_offset=rel_offset, window=window, scale=scale,
                         interpret=(impl == "pallas_interpret"), delta=delta)


merge = merge_ref  # (o1, lse1, o2, lse2) -> (o, lse)


def empty_partial(q):
    """Identity element of ``merge`` for a query chunk."""
    B, T, H, _ = q.shape
    o = jnp.zeros(q.shape, q.dtype)
    lse = jnp.full((B, T, H), NEG_INF, jnp.float32)
    return o, lse


def mask_partial(pred, o, lse):
    """Nullify a partial result where ``pred`` is False (e.g. on devices for
    which a schedule step is invalid). pred is a scalar bool."""
    o = jnp.where(pred, o, jnp.zeros_like(o))
    lse = jnp.where(pred, lse, jnp.full_like(lse, NEG_INF))
    return o, lse
