"""Chunk-attention API used by the distributed schedules.

A *partial* attention op returns ``(o, lse)`` for one (q-chunk, kv-chunk)
pair; partials merge exactly with :func:`merge` (the paper's ``rescale``).

Key property exploited by the schedules (DESIGN.md §2): in the ring /
balanced schedules, the mask of every step depends only on the **relative**
offset between the q and kv chunks (0 for the local step, ``t·Tc`` for step
``t``), which is static per step — so the Pallas kernels never need dynamic
position scalars.

``impl`` names a backend in :mod:`repro.kernels.registry` (``ref``,
``chunked-lax``, ``pallas``, ``pallas-interpret``, ``null``); resolution
honors each backend's capability flags and platform support, falling back
down the registry's chain (with a logged downgrade) instead of crashing —
e.g. ``pallas`` on a CPU host runs ``pallas-interpret``/``chunked-lax``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.ref import NEG_INF, merge_ref


def set_default_impl(impl: str) -> None:
    """Set the process-wide default backend (configs override per call)."""
    registry.set_default(impl)


def _tuning_kw(be, block_q, block_kv):
    """block_q/block_kv hints are forwarded only to backends that declare
    ``tunable_blocks`` (Pallas tile shapes, chunked-lax scan chunk); other
    backends silently ignore the hints rather than erroring."""
    if not be.tunable_blocks:
        return {}
    return registry.block_tuning_kw(block_q, block_kv)


def chunk_attn(q, k, v, *, causal=False, rel_offset=0, window=0, scale=None,
               impl=None, block_q=None, block_kv=None):
    """Partial attention. ``rel_offset`` = absolute(q0) − absolute(kv0),
    static per schedule step. ``block_q``/``block_kv`` are optional tile-
    shape hints for tunable backends. Returns (o, lse)."""
    be = registry.resolve(impl, causal=causal, window=window,
                          rel_offset=rel_offset, dtype=q.dtype)
    return be.fwd(q, k, v, causal=causal, rel_offset=rel_offset,
                  window=window, scale=scale,
                  **_tuning_kw(be, block_q, block_kv))


def chunk_attn_bwd(q, k, v, o, lse, do, *, causal=False, rel_offset=0,
                   window=0, scale=None, impl=None, delta=None,
                   block_q=None, block_kv=None):
    """FA2 backward for one chunk using the saved (o, lse) — no forward
    recompute. ``delta = rowsum(o⊙do)`` may be precomputed (the distributed
    helper path ships delta instead of o). Returns (dq, dk, dv)."""
    be = registry.resolve(impl, causal=causal, window=window,
                          rel_offset=rel_offset, dtype=q.dtype)
    return be.bwd(q, k, v, o, lse, do, causal=causal, rel_offset=rel_offset,
                  window=window, scale=scale, delta=delta,
                  **_tuning_kw(be, block_q, block_kv))


merge = merge_ref  # (o1, lse1, o2, lse2) -> (o, lse)


def empty_partial(q):
    """Identity element of ``merge`` for a query chunk."""
    B, T, H, _ = q.shape
    o = jnp.zeros(q.shape, q.dtype)
    lse = jnp.full((B, T, H), NEG_INF, jnp.float32)
    return o, lse


def mask_partial(pred, o, lse):
    """Nullify a partial result where ``pred`` is False (e.g. on devices for
    which a schedule step is invalid). pred is a scalar bool."""
    o = jnp.where(pred, o, jnp.zeros_like(o))
    lse = jnp.where(pred, lse, jnp.full_like(lse, NEG_INF))
    return o, lse
