"""Chunk-attention API used by the distributed schedules.

A *partial* attention op returns ``(o, lse)`` for one (q-chunk, kv-chunk)
pair; partials merge exactly with :func:`merge` (the paper's ``rescale``).

Key property exploited by the schedules (DESIGN.md §2): in the ring /
balanced schedules, the mask of every step depends only on the **relative**
offset between the q and kv chunks (0 for the local step, ``t·Tc`` for step
``t``), which is static per step. The mask is passed as a declarative
:class:`repro.core.mask.MaskSpec` (full / causal / sliding_window /
prefix_lm / document) — so the Pallas kernels never need dynamic position
scalars, and the block-sparse pruner can reason about the whole spec.
Per-token document segment IDs are dynamic and travel as
``q_segments``/``kv_segments`` operands next to q/k/v.

The pre-MaskSpec ``causal``/``rel_offset``/``window`` kwargs remain as
**deprecated shims** (mapped onto a MaskSpec, one DeprecationWarning per
process); new call sites should pass ``mask=``.

``impl`` names a backend in :mod:`repro.kernels.registry` (``ref``,
``chunked-lax``, ``pallas``, ``pallas-interpret``, ``null``); resolution
matches the MaskSpec's kinds against each backend's capability set and
platform support, falling back down the registry's chain (with a logged
downgrade) instead of crashing — e.g. ``pallas`` on a CPU host runs
``pallas-interpret``/``chunked-lax``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import mask as mk
from repro.core.mask import MaskSpec
from repro.kernels import registry
from repro.kernels.ref import NEG_INF, merge_ref


def set_default_impl(impl: str) -> None:
    """Set the process-wide default backend (configs override per call)."""
    registry.set_default(impl)


def _resolve_mask(mask, causal, rel_offset, window) -> MaskSpec:
    """Only ``mask=`` remains: the legacy kwarg triple was removed after
    five PRs as warning shims with zero in-repo callers — passing any of
    them raises with the migration hint.  ``mask=None`` keeps its
    long-standing meaning (full attention, :func:`mk.full`)."""
    if causal is not None or rel_offset is not None or window is not None:
        raise TypeError(
            "chunk_attn(causal=, rel_offset=, window=) was removed; pass "
            "mask=repro.core.mask.{full,causal,sliding_window,prefix_lm,"
            "document}(...)")
    return mk.full() if mask is None else mask


def _tuning_kw(be, block_q, block_kv, *, mask=None, q=None, op="fwd"):
    """block_q/block_kv hints are forwarded only to backends that declare
    ``tunable_blocks`` (Pallas tile shapes, chunked-lax scan chunk); other
    backends silently ignore the hints rather than erroring.  When the
    caller passes no hints, the call context (backend, mask kind, shape)
    lets ``block_tuning_kw`` consult the env overrides and the active
    tuning table (repro.tune) before the kernels' built-in defaults."""
    if not be.tunable_blocks:
        return {}
    return registry.block_tuning_kw(
        block_q, block_kv, backend=be.name,
        mask_kind=mask.kind if mask is not None else None,
        head_dim=int(q.shape[-1]) if q is not None else None,
        seq=int(q.shape[1]) if q is not None else None, op=op)


def _offset_kw(mask, q_offset, kv_offset):
    """Reconcile the dynamic position operands via ``mk.fold_offsets``:
    static ints fold into the (static) MaskSpec — pruning and the Pallas
    kernels keep working — while traced values become backend kwargs that
    only ``dynamic_offsets`` backends accept (resolve() falls back for
    the others). Returns (mask, backend_kwargs, needs_dynamic)."""
    if q_offset is None and kv_offset is None:
        return mask, {}, False
    mask, qo, ko, dyn = mk.fold_offsets(mask, q_offset, kv_offset)
    return mask, (dict(q_offset=qo, kv_offset=ko) if dyn else {}), dyn


def chunk_attn(q, k, v, *, mask: MaskSpec | None = None, causal=None,
               rel_offset=None, window=None, scale=None, impl=None,
               block_q=None, block_kv=None, q_segments=None,
               kv_segments=None, q_offset=None, kv_offset=None):
    """Partial attention under a static ``mask`` (MaskSpec).
    ``q_segments``/``kv_segments`` are (B, Tq)/(B, Tk) int32 document IDs
    (document kind). ``block_q``/``block_kv`` are optional tile-shape hints
    for tunable backends. ``q_offset``/``kv_offset`` are *dynamic position
    operands* added to the mask's own offsets — python ints fold into the
    spec; traced scalars (schedule steps whose chunk distance depends on
    the device index) restrict resolution to ``dynamic_offsets`` backends.
    Returns (o, lse)."""
    mask = _resolve_mask(mask, causal, rel_offset, window)
    mask, okw, dyn = _offset_kw(mask, q_offset, kv_offset)
    be = registry.resolve(impl, mask=mask, dtype=q.dtype,
                          dynamic_offsets=dyn)
    return be.fwd(q, k, v, mask=mask, scale=scale, q_segments=q_segments,
                  kv_segments=kv_segments, **okw,
                  **_tuning_kw(be, block_q, block_kv, mask=mask, q=q))


def chunk_attn_bwd(q, k, v, o, lse, do, *, mask: MaskSpec | None = None,
                   causal=None, rel_offset=None, window=None, scale=None,
                   impl=None, delta=None, block_q=None, block_kv=None,
                   q_segments=None, kv_segments=None, q_offset=None,
                   kv_offset=None):
    """FA2 backward for one chunk using the saved (o, lse) — no forward
    recompute. ``delta = rowsum(o⊙do)`` may be precomputed (the distributed
    helper path ships delta instead of o). ``q_offset``/``kv_offset`` as in
    :func:`chunk_attn`. Returns (dq, dk, dv)."""
    mask = _resolve_mask(mask, causal, rel_offset, window)
    mask, okw, dyn = _offset_kw(mask, q_offset, kv_offset)
    be = registry.resolve(impl, mask=mask, dtype=q.dtype,
                          dynamic_offsets=dyn)
    return be.bwd(q, k, v, o, lse, do, mask=mask, scale=scale, delta=delta,
                  q_segments=q_segments, kv_segments=kv_segments, **okw,
                  **_tuning_kw(be, block_q, block_kv, mask=mask, q=q,
                               op="bwd"))


def paged_decode_attn(q, k_pool, v_pool, block_table, lengths, *,
                      mask: MaskSpec | None = None, scale=None, impl=None):
    """Decode attention through a paged KV cache (serving), T >= 1 query
    tokens per request (T = 1 vanilla decode; T = K + 1 speculative
    verification).

    ``q``: (B, T, Hq, Dq) — query row t of request b sits at context
    position ``lengths[b] - T + t``; ``k_pool``/``v_pool``:
    (N, block_size, Hkv, D) block pools; ``block_table``: (B, nb) int32
    block ids per request; ``lengths``: (B,) int32 attendable context
    lengths (all T tokens' K/V must already be written — serve/cache.py's
    write-then-attend contract). ``mask`` is a causal/sliding_window
    MaskSpec (the decode tokens are last, so those are the only kinds with
    decode meaning); resolution requires the backend's ``paged``
    capability and walks the usual fallback chain (``pallas`` on CPU runs
    ``pallas-interpret`` / ``chunked-lax``). Returns o (B, T, Hq, Dv)."""
    mask = mk.causal() if mask is None else mask
    be = registry.resolve(impl, mask=mask, dtype=q.dtype, paged=True)
    return be.paged_fwd(q, k_pool, v_pool, block_table, lengths, mask=mask,
                        scale=scale)


merge = merge_ref  # (o1, lse1, o2, lse2) -> (o, lse)


def empty_partial(q):
    """Identity element of ``merge`` for a query chunk."""
    B, T, H, _ = q.shape
    o = jnp.zeros(q.shape, q.dtype)
    lse = jnp.full((B, T, H), NEG_INF, jnp.float32)
    return o, lse


def mask_partial(pred, o, lse):
    """Nullify a partial result where ``pred`` is False (e.g. on devices for
    which a schedule step is invalid). pred is a scalar bool."""
    o = jnp.where(pred, o, jnp.zeros_like(o))
    lse = jnp.where(pred, lse, jnp.full_like(lse, NEG_INF))
    return o, lse
