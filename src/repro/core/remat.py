"""Rematerialization-aware gradient checkpointing (paper §3.3).

Standard ("HuggingFace-style") gradient checkpointing puts the checkpoint at
the Transformer-layer boundary: during the backward pass the *entire* layer
forward — including the FlashAttention kernel — is recomputed, even though
the FA backward kernel already rematerializes the softmax internally from
``(q, k, v, o, lse)``. The paper moves the checkpoint boundary to the
attention *output*: save ``(o, lse)``, recompute only the cheap
pre/post-attention projections, and feed the FA backward directly. Zero
numerical difference; the FA forward (and, distributed, its forward
communication) runs exactly once per step.

We implement this as an explicit ``jax.custom_vjp`` *combinator* rather than
relying on ``jax.checkpoint`` policies reaching through ``custom_vjp``
residuals (fragile — see DESIGN.md §6). The combinator takes the three
stages of a layer and hand-assembles fwd/bwd:

    y = post_attn(params, x, o)   where  (o, lse) = attn_fwd(pre_attn(params, x))

* fwd: run all three, save ``(params, x, o, lse)``.
* bwd: ``jax.vjp``-recompute ``pre_attn`` and ``post_attn`` (cheap GEMMs),
  call ``attn_bwd(qkv, o, lse, do)`` — **no attention forward**.

Memory per layer: layer input ``x`` (same as HF checkpointing) plus
``(o, lse)`` — the paper's Figure-3 budget.

Three policies, selectable per run (``ParallelConfig.remat``):
  * ``remat_aware`` — the combinator (paper's strategy)
  * ``hf``          — ``jax.checkpoint`` at layer boundary (the baseline the
                      paper's Table 5 compares against)
  * ``none``        — no checkpointing (store everything)
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
from repro import compat
import jax.numpy as jnp


def _tree_add(a, b):
    def add(x, y):
        # integer leaves (e.g. document segment IDs threaded through the
        # attention stages) carry float0 cotangents — pass them through
        if getattr(x, "dtype", None) == jax.dtypes.float0:
            return x
        return jnp.add(x, y)
    return compat.tree_map(add, a, b)


def remat_aware(pre_attn: Callable, attn_fwd: Callable, attn_bwd: Callable,
                post_attn: Callable) -> Callable:
    """Build ``layer(params, x) -> y`` with the paper's checkpoint placement.

    Args:
      pre_attn:  (params, x) -> qkv_pytree       (projections, norms, rope)
      attn_fwd:  (qkv_pytree) -> (o, lse)        (DISTFLASHATTN forward)
      attn_bwd:  (qkv_pytree, o, lse, do) -> dqkv_pytree  (FA2 backward from
                 saved stats — never reruns the forward)
      post_attn: (params, x, o) -> y             (out-proj, residual, MLP)

    ``x`` and ``y`` may be arbitrary pytrees (e.g. ``(hidden, enc_out)``).
    """

    @jax.custom_vjp
    def layer(params, x):
        qkv = pre_attn(params, x)
        o, _lse = attn_fwd(qkv)
        return post_attn(params, x, o)

    def layer_fwd(params, x):
        qkv = pre_attn(params, x)
        o, lse = attn_fwd(qkv)
        y = post_attn(params, x, o)
        return y, (params, x, o, lse)

    def layer_bwd(res, dy):
        params, x, o, lse = res
        # recompute the cheap stages under vjp; attention fwd is NOT rerun
        qkv, pre_vjp = jax.vjp(pre_attn, params, x)
        _y, post_vjp = jax.vjp(post_attn, params, x, o)
        dparams2, dx2, do = post_vjp(dy)
        dqkv = attn_bwd(qkv, o, lse, do)
        dparams1, dx1 = pre_vjp(dqkv)
        return _tree_add(dparams1, dparams2), _tree_add(dx1, dx2)

    layer.defvjp(layer_fwd, layer_bwd)
    return layer


def apply_policy(layer: Callable, policy: str) -> Callable:
    """Wrap a ``layer(params, x) -> y`` according to the checkpoint policy.

    For ``remat_aware`` the layer must already be built with the combinator
    above (this function is then the identity). ``hf`` wraps with
    layer-boundary ``jax.checkpoint`` — the paper's baseline, which
    recomputes the attention forward. ``none`` stores all activations.
    """
    if policy == "remat_aware" or policy == "none":
        return layer
    if policy == "hf":
        return jax.checkpoint(layer)
    raise ValueError(f"unknown remat policy: {policy}")
