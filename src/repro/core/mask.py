"""Declarative attention-mask specification — the ``MaskSpec`` API.

The schedules exploit that each ring step's mask is a *static* function of
the step (DESIGN.md §2).  Pre-MaskSpec that structure was encoded as three
loose kwargs (``causal``, ``window``, ``rel_offset``) threaded through every
layer, which made new mask regimes (packed-document batches, prefix-LM)
inexpressible.  :class:`MaskSpec` replaces the triple with one declarative
object that the registry, the kernels, the block-sparse pruner, and the
distributed schedules all reason about.

Mask kinds (constructors at module level):

  * ``full()``                 — no mask.
  * ``causal()``               — ``kv_pos <= q_pos``.
  * ``sliding_window(w)``      — causal ∧ ``q_pos − kv_pos < w``.
  * ``prefix_lm(n)``           — bidirectional over the first ``n`` absolute
                                 kv positions, causal after.
  * ``document(boundaries=…)`` — causal ∧ same-segment (packed sequences).

``MaskSpec`` is **static** (a frozen, hashable dataclass): it can be a jit
static argument, a ``BackendSpec`` capability subject, and a field of
``DistAttnSpec``.  The *dynamic* part of document masking — per-token
segment-ID arrays — travels alongside the tensors as explicit
``q_segments``/``kv_segments`` operands (they ride the ring next to KV in
the distributed schedules).  When the packing layout is static,
``document(boundaries=(0, …))`` carries the document start positions so the
block-sparse pruner can drop cross-document blocks at trace time with no
segment arrays at all.

Positions. ``q_offset``/``kv_offset`` are the absolute positions of element
0 of each chunk (``rel_offset == q_offset − kv_offset`` is the legacy
name).  The distributed schedules derive a per-step spec with
:func:`ring_step`; the chunked scan shifts ``kv_offset`` per KV chunk.

Semantics of one (q, kv) position pair — ``attend(qp, kp)``:

    pre  = prefix_len > 0 and kp < prefix_len
    ok   = (not causal  or kp <= qp      or pre)
         ∧ (not window  or qp − kp < w   or pre)
         ∧ (not document or seg(qp) == seg(kp) or pre)

(the prefix relaxes *every* clause: a bidirectional/shared prefix is
attendable across documents — which is also what lets a speculation tree's
independent branches share their committed context, see :func:`tree_spec`).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Tuple

KINDS = ("full", "causal", "sliding_window", "prefix_lm", "document")

_DEPRECATION_WARNED = set()


def warn_legacy_once(site: str, hint: str) -> None:
    """One DeprecationWarning per call site per process — shared by every
    layer that still accepts the pre-MaskSpec kwarg shims."""
    if site in _DEPRECATION_WARNED:
        return
    _DEPRECATION_WARNED.add(site)
    warnings.warn(f"{site} is deprecated; pass {hint}",
                  DeprecationWarning, stacklevel=4)


@dataclasses.dataclass(frozen=True)
class MaskSpec:
    """Static attention-mask description (see module docstring).

    Fields compose (``document`` is causal ∧ same-segment); the
    constructors below build the canonical kinds.  Hashable → usable as a
    jit static argument and inside ``DistAttnSpec``.
    """
    causal: bool = False
    window: int = 0                 # sliding-window width (0 = unlimited)
    prefix_len: int = 0             # bidirectional prefix (absolute kv pos)
    document: bool = False          # same-segment constraint
    q_offset: int = 0               # absolute position of q[0]
    kv_offset: int = 0              # absolute position of kv[0]
    # static document layout: sorted doc start positions, boundaries[0] == 0.
    # None => segment arrays must be supplied at call time.
    boundaries: Optional[Tuple[int, ...]] = None

    def __post_init__(self):
        if self.window < 0:
            raise ValueError(f"window must be >= 0, got {self.window}")
        if self.prefix_len < 0:
            raise ValueError(f"prefix_len must be >= 0, got {self.prefix_len}")
        if self.prefix_len and not (self.causal or self.window):
            raise ValueError(
                "prefix_len only relaxes a causal/window mask; "
                "prefix_len without causal=True (or a window) is a no-op")
        if self.boundaries is not None:
            b = tuple(int(x) for x in self.boundaries)
            if not self.document:
                raise ValueError("boundaries given without document=True")
            if not b or b[0] != 0 or list(b) != sorted(set(b)):
                raise ValueError(
                    f"boundaries must be sorted, unique, and start at 0; "
                    f"got {b}")
            object.__setattr__(self, "boundaries", b)

    # ------------------------------------------------------------ queries
    @property
    def rel_offset(self) -> int:
        """Legacy name: absolute(q0) − absolute(kv0)."""
        return self.q_offset - self.kv_offset

    @property
    def kinds(self) -> frozenset:
        """Capability requirements of this spec (matched by the registry)."""
        s = set()
        if self.causal:
            s.add("causal")
        if self.window:
            s.add("sliding_window")
        if self.prefix_len:
            s.add("prefix_lm")
        if self.document:
            s.add("document")
        return frozenset(s)

    @property
    def kind(self) -> str:
        """Primary label, for logs / bench case names."""
        if self.document:
            return "document"
        if self.prefix_len:
            return "prefix_lm"
        if self.window:
            return "sliding_window"
        if self.causal:
            return "causal"
        return "full"

    @property
    def needs_mask(self) -> bool:
        return bool(self.kinds)

    @property
    def needs_segments(self) -> bool:
        """Dynamic segment-ID arrays required (document without a static
        layout)."""
        return self.document and self.boundaries is None

    @property
    def prunable(self) -> bool:
        """The block-sparse pruner can bound valid KV blocks at trace time."""
        return (self.causal or self.window > 0
                or (self.document and self.boundaries is not None))

    # -------------------------------------------------------- derivations
    def replace(self, **kw) -> "MaskSpec":
        return dataclasses.replace(self, **kw)

    # ----------------------------------------------- position-level masks
    def doc_start(self, p):
        """Start position of the document containing absolute position
        ``p``, from the static ``boundaries``.  ``p`` may be a Python int
        or a traced scalar."""
        assert self.boundaries is not None
        if isinstance(p, int):
            lo = 0
            for b in self.boundaries:
                if b <= p:
                    lo = b
            return lo
        import jax.numpy as jnp
        lo = jnp.int32(0)
        for b in self.boundaries[1:]:
            lo = jnp.where(p >= b, jnp.int32(b), lo)
        return lo

    def doc_end(self, p):
        """Last position of the document containing ``p`` (the position
        after the last boundary extends to +inf, clamped by callers)."""
        assert self.boundaries is not None
        big = 2 ** 30
        if isinstance(p, int):
            hi = big
            for b in reversed(self.boundaries):
                if b > p:
                    hi = b - 1
            return hi
        import jax.numpy as jnp
        hi = jnp.int32(big)
        for b in reversed(self.boundaries[1:]):   # smallest b > p wins
            hi = jnp.where(p < b, jnp.int32(b - 1), hi)
        return hi

    def segment_index(self, p: int) -> int:
        """Segment index of absolute position ``p`` (python int, static
        ``boundaries`` only) — host-side counterpart of :meth:`segment_of`,
        used by the schedule planner's static step pruning."""
        assert self.boundaries is not None
        import bisect
        return bisect.bisect_right(self.boundaries, int(p)) - 1

    def segment_of(self, pos):
        """Segment index of absolute position array ``pos`` (static
        boundaries only) — the trace-time stand-in for segment-ID arrays."""
        assert self.boundaries is not None
        import jax.numpy as jnp
        seg = jnp.zeros(pos.shape, jnp.int32)
        for b in self.boundaries[1:]:
            seg = seg + (pos >= b).astype(jnp.int32)
        return seg

    def allow(self, q_pos, kv_pos, q_segments=None, kv_segments=None):
        """Boolean attend-mask from broadcastable position (and segment)
        arrays, or ``None`` when nothing is masked.  ``q_pos``/``kv_pos``
        are *absolute* positions (the caller adds ``q_offset``/
        ``kv_offset``); segments broadcast against them."""
        import jax.numpy as jnp
        m = None

        def _and(a, b):
            return b if a is None else a & b

        pre = None
        if self.prefix_len:
            pre = kv_pos < self.prefix_len
        if self.causal:
            c = kv_pos <= q_pos
            m = _and(m, c | pre if pre is not None else c)
        if self.window and self.window > 0:
            w = q_pos - kv_pos < self.window
            m = _and(m, w | pre if pre is not None else w)
        if self.document:
            if q_segments is None or kv_segments is None:
                if self.boundaries is None:
                    raise ValueError(
                        "document mask needs q_segments/kv_segments "
                        "(or static boundaries)")
                q_segments = self.segment_of(q_pos)
                kv_segments = self.segment_of(kv_pos)
            d = jnp.asarray(q_segments) == jnp.asarray(kv_segments)
            m = _and(m, d | pre if pre is not None else d)
        return m


# --------------------------------------------------------------------------
# Constructors (the declarative "kinds")
# --------------------------------------------------------------------------

def full(rel_offset: int = 0) -> MaskSpec:
    return MaskSpec(q_offset=rel_offset)


def causal(rel_offset: int = 0) -> MaskSpec:
    return MaskSpec(causal=True, q_offset=rel_offset)


def sliding_window(window: int, *, causal: bool = True,
                   rel_offset: int = 0) -> MaskSpec:
    """Banded mask. ``causal=False`` gives the trailing band alone — the
    shape of a windowed ring step (the received chunk is strictly past, so
    the causal half is statically satisfied)."""
    return MaskSpec(causal=causal, window=window, q_offset=rel_offset)


def prefix_lm(prefix_len: int, rel_offset: int = 0) -> MaskSpec:
    """Bidirectional over absolute kv positions < prefix_len, causal after
    (T5/PaLM-style prefix language modeling)."""
    return MaskSpec(causal=True, prefix_len=prefix_len, q_offset=rel_offset)


def document(*, boundaries: Optional[Tuple[int, ...]] = None,
             causal: bool = True, window: int = 0,
             rel_offset: int = 0) -> MaskSpec:
    """Packed-sequence mask: causal ∧ same-document.  With static
    ``boundaries`` (doc start positions) the block-sparse pruner skips
    cross-document blocks at trace time; without, per-token
    ``q_segments``/``kv_segments`` arrays must accompany the call."""
    return MaskSpec(causal=causal, window=window, document=True,
                    q_offset=rel_offset,
                    boundaries=None if boundaries is None
                    else tuple(boundaries))


def from_legacy(causal: bool = False, window: int = 0,
                rel_offset: int = 0) -> MaskSpec:
    """Map the deprecated (causal, window, rel_offset) kwarg triple."""
    return MaskSpec(causal=bool(causal), window=int(window or 0),
                    q_offset=int(rel_offset))


def as_spec(mask: Optional[MaskSpec], causal=False, window=0,
            rel_offset=0) -> MaskSpec:
    """Shared mask=/legacy-kwarg reconciliation for the kernel entry
    points (ops / chunked / ref): ``mask`` wins; mixing both is an error."""
    if mask is None:
        return from_legacy(causal=causal, window=window,
                           rel_offset=rel_offset)
    if causal or window or rel_offset:
        raise ValueError("pass either mask= or the legacy kwargs, not both")
    return mask


def fold_offsets(mask: MaskSpec, q_offset, kv_offset):
    """Reconcile dynamic position operands with the static spec: python
    ints fold into the MaskSpec's own offsets (static pruning and the
    Pallas kernels keep working); traced values pass through untouched.
    Returns ``(mask, q_offset, kv_offset, dynamic)``.  Shared by
    ``chunk_attn`` and the chunked-lax backend so the fold semantics live
    in one place."""
    qo = 0 if q_offset is None else q_offset
    ko = 0 if kv_offset is None else kv_offset
    if isinstance(qo, int) and isinstance(ko, int):
        if qo or ko:
            mask = mask.replace(q_offset=mask.q_offset + qo,
                                kv_offset=mask.kv_offset + ko)
        return mask, 0, 0, False
    return mask, qo, ko, True


def ring_step(mask: MaskSpec, rel: int) -> MaskSpec:
    """Per-step spec for a ring schedule receiving a strictly-past KV chunk
    at distance ``rel`` (> 0): the causal constraint is statically
    satisfied, so it is dropped; window / document constraints remain.
    Static ``boundaries`` are stripped (they are absolute coordinates,
    meaningless under per-step relative offsets) — the schedule executor
    derives per-shard segment arrays from them instead."""
    return mask.replace(causal=False, q_offset=rel, kv_offset=0,
                        boundaries=None)


def strict_causal_pair(mask: MaskSpec) -> MaskSpec:
    """Per-step spec for a (q-chunk, kv-chunk) pair the schedule proves
    strictly causal (balanced/zigzag off-diagonal pairs): only the
    document constraint survives; positions are irrelevant (``boundaries``
    stripped, as in :func:`ring_step`)."""
    return mask.replace(causal=False, window=0, q_offset=0, kv_offset=0,
                        boundaries=None)


def offdiag_step(mask: MaskSpec) -> MaskSpec:
    """Per-step spec for a strictly-causal pair whose *chunk distance
    varies per device* (zigzag mirror-chunk pairs): the causal constraint
    is statically satisfied and dropped, the window band survives, and the
    positions come from dynamic ``q_offset``/``kv_offset`` operands at
    execution time (so the spec's own offsets stay 0)."""
    return mask.replace(causal=False, q_offset=0, kv_offset=0,
                        boundaries=None)


def chunk_pair_needed(mask: MaskSpec, q_lo: int, q_hi: int,
                      k_lo: int, k_hi: int) -> bool:
    """Static feasibility of one (q-chunk, kv-chunk) token-range pair:
    could *any* ``(qp, kp)`` with ``qp ∈ [q_lo, q_hi]``, ``kp ∈ [k_lo,
    k_hi]`` attend under ``mask`` (absolute positions)?  Conservative —
    ``False`` only when the pair is provably all-masked, which is what
    lets the schedule planner drop steps/work items statically.  Dynamic
    segment arrays are unknowable here and never cause pruning; static
    ``boundaries`` do."""
    if mask.prefix_len:
        return True                      # prefix relaxes; never prune
    if mask.causal and k_lo > q_hi:
        return False                     # strictly future chunk
    if mask.window and mask.window > 0:
        min_dist = max(q_lo - k_hi, 0)   # closest reachable pair
        if min_dist >= mask.window:
            return False                 # whole pair beyond the band
    if mask.document and mask.boundaries is not None:
        # same-document pair exists iff the segment ranges intersect
        if (mask.segment_index(q_hi) < mask.segment_index(k_lo)
                or mask.segment_index(k_hi) < mask.segment_index(q_lo)):
            return False
    return True


# --------------------------------------------------------------------------
# Speculation-tree masks (serve/speculative.py)
# --------------------------------------------------------------------------
#
# A speculative-verification chunk appends a small *tree* of draft tokens
# after a committed context prefix: node i may attend the whole prefix and
# its own ancestors, never a sibling branch.  The tree is static per step
# (its shape is a scheduling decision, not data), so it can — and must —
# be a MaskSpec: the chain (branching factor 1) is plain ``causal``, and a
# star of independent linear branches is ``causal ∧ document`` with one
# document per branch plus ``prefix_len`` spanning the shared committed
# context.  Deeper re-branching topologies are not expressible as a
# MaskSpec (sibling subtrees interleave) and are rejected.

def chain_parents(n: int) -> Tuple[int, ...]:
    """Parent vector of a depth-``n`` speculation chain (node i's parent
    is i−1; the root's parent is −1 = the committed context)."""
    return tuple(range(-1, n - 1))


def tree_ancestor_mask(parents: Tuple[int, ...]):
    """(K, K) bool numpy matrix: ``m[i, j]`` iff node ``i`` may attend
    node ``j`` — j is i itself or an ancestor of i.  The ground truth the
    MaskSpec returned by :func:`tree_spec` must reproduce."""
    import numpy as np
    K = len(parents)
    m = np.zeros((K, K), bool)
    for i, p in enumerate(parents):
        m[i, i] = True
        while p >= 0:
            m[i, p] = True
            p = parents[p]
    return m


def _tree_branches(parents: Tuple[int, ...]) -> Tuple[int, ...]:
    """Branch start indices when ``parents`` is a star of contiguous
    linear branches hanging off the root context (parent −1); raises for
    any other topology."""
    parents = tuple(int(p) for p in parents)
    if not parents:
        raise ValueError("empty speculation tree")
    starts = []
    for i, p in enumerate(parents):
        if p == -1:
            starts.append(i)
        elif p != i - 1:
            raise ValueError(
                f"node {i} has parent {p}; only chains and stars of "
                f"contiguous linear branches are MaskSpec-expressible")
    if starts[0] != 0:
        raise ValueError("node 0 must hang off the context (parent -1)")
    return tuple(starts)


def tree_spec(parents: Tuple[int, ...], *, prefix_len: int = 0,
              window: int = 0) -> MaskSpec:
    """The static MaskSpec of one speculative-verification chunk whose
    draft tokens form the tree described by ``parents`` (``parents[i]`` is
    node i's parent index, −1 = the committed context).

    A chain degenerates to ``causal`` (the single-node tree is exactly a
    vanilla decode step); a star of ``m > 1`` linear branches becomes
    ``causal ∧ document`` with one document per branch — ``boundaries``
    are the branch starts — plus ``prefix_len`` so every branch still
    attends the shared committed context of that length.  ``window``
    carries a sliding-window model's band through verification."""
    starts = _tree_branches(parents)
    if len(starts) == 1:                  # chain (incl. the single node)
        return MaskSpec(causal=True, window=int(window))
    # the committed context shares segment 0 with the first branch: its
    # attendability by the other branches comes from the prefix
    # relaxation, and causality already stops it attending forward
    return MaskSpec(causal=True, window=int(window),
                    prefix_len=int(prefix_len), document=True,
                    boundaries=(0,) + tuple(int(prefix_len) + s
                                            for s in starts[1:]))


def doc_boundaries(T: int, n_docs: int) -> Tuple[int, ...]:
    """Deterministic uneven packing layout: ``n_docs`` documents over a
    length-``T`` sequence with lengths proportional to 1..n (remainder to
    the last doc).  Shared by the data pipeline, the kernel bench, and the
    packed-sequence tests so they all agree on the layout."""
    if n_docs <= 1 or T < n_docs:
        return (0,)
    total = n_docs * (n_docs + 1) // 2
    lens = [max(1, (i + 1) * T // total) for i in range(n_docs - 1)]
    used = sum(lens)
    if used >= T:                      # tiny T: fall back to equal split
        lens = [T // n_docs] * (n_docs - 1)
    starts = [0]
    for ln in lens:
        starts.append(starts[-1] + ln)
    return tuple(starts)


def segments_from_boundaries(T: int, boundaries: Tuple[int, ...]):
    """(T,) int32 segment-ID array for a static layout (numpy, host-side —
    what the data pipeline ships next to the tokens)."""
    import numpy as np
    seg = np.zeros((T,), np.int32)
    for b in boundaries[1:]:
        seg[b:] += 1
    return seg
