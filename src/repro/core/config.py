"""Configuration system for the DISTFLASHATTN reproduction framework.

Every architecture from the assignment pool is expressed as a
:class:`ModelConfig`; input shapes as :class:`ShapeSpec`. Configs are plain
frozen dataclasses so they hash, print, and serialize cleanly and can be
used as jit static arguments.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class AttnConfig:
    """Attention-block configuration (dense / GQA / MLA)."""
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False           # Qwen2-style bias on q,k,v projections
    qk_norm: bool = False            # Qwen3-style RMSNorm on q,k heads
    rope_theta: float = 10_000.0
    # --- MLA (DeepSeek multi-head latent attention) ---
    kv_lora_rank: int = 0            # 0 => standard GQA path
    q_lora_rank: int = 0
    qk_rope_head_dim: int = 0        # decoupled rope key dim (MLA only)
    v_head_dim: int = 0              # MLA value head dim (defaults head_dim)
    # --- windowing (paper Appendix F; used for long-context decode) ---
    window: int = 0                  # 0 => full causal attention

    @property
    def is_mla(self) -> bool:
        return self.kv_lora_rank > 0

    @property
    def qk_nope_head_dim(self) -> int:
        return self.head_dim  # MLA: non-rope part of the query/key head


@dataclass(frozen=True)
class MoEConfig:
    n_routed: int                    # routed experts
    n_shared: int                    # shared (always-on) experts
    top_k: int
    d_expert: int                    # per-expert FFN hidden size
    d_dense_ff: int                  # FFN size of the leading dense layers
    n_dense_layers: int = 1          # leading layers that use a dense FFN
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.001


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD, arXiv:2405.21060)."""
    d_state: int
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128                 # SSD intra-chunk block length

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    d_ff: int
    vocab: int
    attn: Optional[AttnConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (Zamba2): a shared attention block every `hybrid_period` layers
    hybrid_period: int = 0
    # enc-dec (Whisper): encoder layers & fixed frame count (stub frontend)
    n_enc_layers: int = 0
    n_audio_frames: int = 0
    # VLM: number of stub patch-embedding tokens prepended to the text
    n_image_tokens: int = 0
    # DeepSeek-V3 multi-token prediction depth (extra MTP modules)
    mtp_depth: int = 0
    tie_embeddings: bool = True
    norm_eps: float = 1e-5
    citation: str = ""
    dtype: str = "bfloat16"

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def uses_attention(self) -> bool:
        return self.arch_type != "ssm"

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6·N·D)."""
        return _param_count(self)

    def active_param_count(self) -> int:
        """Active params per token (MoE: shared + top_k routed)."""
        return _param_count(self, active_only=True)


def _attn_params(c: ModelConfig) -> int:
    a = c.attn
    if a is None:
        return 0
    d = c.d_model
    if a.is_mla:
        vh = a.v_head_dim or a.head_dim
        q_in = (d * a.q_lora_rank + a.q_lora_rank *
                a.n_heads * (a.qk_nope_head_dim + a.qk_rope_head_dim)) \
            if a.q_lora_rank else d * a.n_heads * (a.qk_nope_head_dim + a.qk_rope_head_dim)
        kv_in = d * (a.kv_lora_rank + a.qk_rope_head_dim)
        kv_up = a.kv_lora_rank * a.n_heads * (a.qk_nope_head_dim + vh)
        out = a.n_heads * vh * d
        return q_in + kv_in + kv_up + out
    hd = a.head_dim
    return d * (a.n_heads * hd + 2 * a.n_kv_heads * hd) + a.n_heads * hd * d


def _ffn_params(d_model: int, d_ff: int) -> int:
    return 3 * d_model * d_ff        # SwiGLU: gate, up, down


def _ssm_params(c: ModelConfig) -> int:
    s = c.ssm
    di = s.d_inner(c.d_model)
    nh = s.n_heads(c.d_model)
    # in_proj: [z, x, B, C, dt] ; out_proj
    zxbcdt = 2 * di + 2 * s.d_state + nh
    return c.d_model * zxbcdt + di * c.d_model + s.d_conv * (di + 2 * s.d_state)


def _param_count(c: ModelConfig, active_only: bool = False) -> int:
    n = c.vocab * c.d_model * (1 if c.tie_embeddings else 2)
    if c.arch_type == "ssm":
        n += c.n_layers * _ssm_params(c)
        return n
    if c.arch_type == "hybrid":
        n += c.n_layers * _ssm_params(c)
        n_shared_blocks = 1
        a = c.attn
        d2 = 2 * c.d_model
        shared = d2 * 3 * a.n_heads * a.head_dim + a.n_heads * a.head_dim * d2 \
            + _ffn_params(d2, c.d_ff) + d2 * c.d_model
        n += n_shared_blocks * shared
        return n
    per_layer_attn = _attn_params(c)
    if c.moe is not None:
        m = c.moe
        dense = _ffn_params(c.d_model, m.d_dense_ff)
        shared = m.n_shared * _ffn_params(c.d_model, m.d_expert)
        routed_total = m.n_routed * _ffn_params(c.d_model, m.d_expert)
        routed_active = m.top_k * _ffn_params(c.d_model, m.d_expert)
        router = c.d_model * m.n_routed
        n_moe_layers = c.n_layers - m.n_dense_layers
        n += c.n_layers * per_layer_attn + m.n_dense_layers * dense
        n += n_moe_layers * (shared + router +
                             (routed_active if active_only else routed_total))
        return n
    n_layers = c.n_layers + c.n_enc_layers
    n += n_layers * (per_layer_attn + _ffn_params(c.d_model, c.d_ff))
    if c.n_enc_layers:   # whisper decoder cross-attention
        n += c.n_layers * per_layer_attn
    return n


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"
    # packed-sequence training: documents packed per sequence. > 1 makes the
    # pipeline emit ``segment_ids`` and the models mask cross-document
    # attention (MaskSpec kind ``document``). 1 = one document per sequence.
    docs: int = 1


SHAPES = {
    "train_4k":    ShapeSpec("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeSpec("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeSpec("long_500k",   524_288, 1,   "decode"),
}

ARCH_IDS = (
    "smollm-360m", "mamba2-2.7b", "qwen2.5-14b", "qwen3-8b", "internvl2-2b",
    "deepseek-v2-lite-16b", "whisper-tiny", "deepseek-v3-671b",
    "qwen1.5-32b", "zamba2-2.7b",
)

# paper's own evaluation models (§4: LLaMA-7B and variants)
PAPER_ARCH_IDS = ("llama-7b", "llama-gqa", "llama-33h", "llama-16h")


def get_config(arch: str) -> ModelConfig:
    """Load ``src/repro/configs/<arch>.py`` and return its CONFIG."""
    mod = importlib.import_module(f"repro.configs.{arch.replace('-', '_').replace('.', '_')}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeSpec:
    return SHAPES[name]


@dataclass(frozen=True)
class ParallelConfig:
    """How the mesh axes are used for a given run."""
    batch_axes: Tuple[str, ...] = ("data",)       # + "pod" when multi-pod
    seq_axis: str = "model"
    extra_seq_axes: Tuple[str, ...] = ()          # 2D sequence sharding
    fsdp_axes: Tuple[str, ...] = ("data",)
    # auto | balanced | ring | rsa | ulysses | zigzag (core/dist_attention).
    # "auto" defers to trace time: the schedule-plan cost model
    # (core/schedule.choose_schedule) picks the cheapest capable schedule
    # for each attention site's MaskSpec, P, and shapes.
    schedule: str = "balanced"
    remat: str = "remat_aware"                    # remat_aware | hf | none
    # factored 2D (seq × head) attention: when a mesh exposes a head
    # sub-axis (launch/mesh.make_seq2d_mesh), activations shard the
    # sequence over the (seq_axis, head_axis) *pair* — head minor — and
    # attention runs the 2D ring×ulysses plans (core/schedule.Plan2D)
    head_axis: Optional[str] = None

    @property
    def seq_axes(self) -> Tuple[str, ...]:
        """All axes the sequence dim is sharded over, minor-most last —
        the 2D head sub-axis is head-minor by layout."""
        axes = tuple(self.extra_seq_axes) + (self.seq_axis,)
        if self.head_axis is not None:
            axes += (self.head_axis,)
        return axes


@dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    warmup_steps: int = 100
    total_steps: int = 1000
    max_grad_norm: float = 1.0
    seed: int = 0


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced variant of the same family for CPU smoke tests:
    2 layers, d_model ≤ 512, ≤ 4 experts (assignment requirement)."""
    kw = dict(n_layers=2, vocab=512, dtype="float32")
    if cfg.attn is not None:
        a = cfg.attn
        g = max(1, a.n_heads // max(a.n_kv_heads, 1))
        n_heads = 4
        head_dim = 32
        if cfg.arch_type == "hybrid":
            head_dim = 2 * 64 // n_heads * 2  # keep n_heads·hd == 2·d_model
        kw["attn"] = dataclasses.replace(
            a, n_heads=n_heads, n_kv_heads=max(1, n_heads // g), head_dim=head_dim,
            kv_lora_rank=32 if a.kv_lora_rank else 0,
            q_lora_rank=32 if a.q_lora_rank else 0,
            qk_rope_head_dim=16 if a.qk_rope_head_dim else 0,
            v_head_dim=32 if a.v_head_dim else 0)
    if cfg.arch_type == "hybrid":
        kw["d_model"] = 64
        kw["attn"] = dataclasses.replace(kw["attn"], head_dim=32,
                                         n_kv_heads=4)  # 4·32 == 2·64
        kw["hybrid_period"] = 1
        kw["d_ff"] = 128
    elif cfg.attn is not None:
        kw["d_model"] = n_heads * 32
        kw["d_ff"] = 256
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=8,
                                        chunk=16)
        if cfg.arch_type == "ssm":
            kw["d_model"] = 64
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_routed=4, n_shared=min(cfg.moe.n_shared, 1),
            top_k=2, d_expert=64, d_dense_ff=128, n_dense_layers=1,
            capacity_factor=4.0)
    if cfg.n_enc_layers:
        kw["n_enc_layers"] = 2
        kw["n_audio_frames"] = 64
    if cfg.n_image_tokens:
        kw["n_image_tokens"] = 16
    return dataclasses.replace(cfg, **kw)
