"""Frozen seed implementations of the hand-written DISTFLASHATTN
schedules (pre-SchedulePlan-IR), kept verbatim SOLELY as differential-test
references for the plan executors (tests/test_schedule_plan.py).

Not used by the library: core/dist_attention.py now builds SchedulePlans
(core/schedule.py) and runs them through the shared step engine.  Do not
extend these — new schedule capabilities go into the plan builders.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from repro import compat
from repro.core import mask as mk
from repro.core.attention import chunk_attn, chunk_attn_bwd, mask_partial, merge


def _tune(spec):
    return dict(scale=spec.scale, impl=spec.impl, block_q=spec.block_q,
                block_kv=spec.block_kv)


def _seg_kw(mask, q_seg, kv_seg):
    if not mask.document or q_seg is None:
        return {}
    return dict(q_segments=q_seg, kv_segments=kv_seg)


def _shift(x, axis, shift, size):
    """ppermute by a fixed shift: device p receives from (p − shift) mod P."""
    perm = [(i, (i + shift) % size) for i in range(size)]
    return compat.tree_map(lambda a: lax.ppermute(a, axis, perm), x)

def _ring_steps(spec: DistAttnSpec, chunk_len: int) -> int:
    """Number of ring steps; truncated by the sliding window (Appendix F)."""
    P_ = spec.axis_size
    n = P_ - 1
    w = spec.mask.window
    if w and w > 0:
        # step t covers query-key distances [(t-1)*Tc+1, (t+1)*Tc-1];
        # it contributes only if the smallest distance is inside the window.
        n = min(n, max(0, -(-(w - 1) // chunk_len)))
    return n

def _fwd_ring(spec, q, k, v, seg=None):
    """Vanilla ring (Alg. 1) — causal, bidirectional, windowed, document."""
    p = lax.axis_index(spec.axis)
    P_, Tc = spec.axis_size, q.shape[1]
    m = spec.mask
    o, s = chunk_attn(q, k, v, mask=m, **_seg_kw(m, seg, seg), **_tune(spec))
    n = _ring_steps(spec, Tc)
    if n == 0:
        return o, s
    kv = _shift((k, v), spec.axis, 1, P_)            # prefetch step 1
    seg_r = _shift(seg, spec.axis, 1, P_) if seg is not None else None
    for t in range(1, n + 1):
        if t < n:                                     # prefetch (overlap)
            kv_next = _shift(kv, spec.axis, 1, P_)
            seg_next = _shift(seg_r, spec.axis, 1, P_) \
                if seg_r is not None else None
        m_t = mk.ring_step(m, t * Tc)
        o_t, s_t = chunk_attn(q, kv[0], kv[1], mask=m_t,
                              **_seg_kw(m_t, seg, seg_r), **_tune(spec))
        if m.causal:
            o_t, s_t = mask_partial(p >= t, o_t, s_t)
        o, s = merge(o, s, o_t, s_t)
        if t < n:
            kv, seg_r = kv_next, seg_next
    return o, s

def _fwd_balanced(spec, q, k, v, seg=None):
    """Load-balanced schedule (Alg. 2). Causal-kind masks, full window."""
    p = lax.axis_index(spec.axis)
    P_, Tc = spec.axis_size, q.shape[1]
    m = spec.mask
    m_x = mk.strict_causal_pair(m)     # off-diagonal pairs: document only
    o, s = chunk_attn(q, k, v, mask=m, **_seg_kw(m, seg, seg), **_tune(spec))
    if P_ == 1:
        return o, s
    T = P_ // 2
    kv = _shift((k, v), spec.axis, 1, P_)            # prefetch step 1
    qb = _shift(q, spec.axis, 1, P_)
    # one traveling segment chunk serves both sides: the helper's q chunk
    # and the worker's kv chunk are the same remote device's tokens
    seg_r = _shift(seg, spec.axis, 1, P_) if seg is not None else None
    for t in range(1, T + 1):
        helpers = (t != T) or (P_ % 2 == 1)
        if t < T:                                     # prefetch step t+1
            kv_next = _shift(kv, spec.axis, 1, P_)
            qb_next = _shift(qb, spec.axis, 1, P_)
            seg_next = _shift(seg_r, spec.axis, 1, P_) \
                if seg_r is not None else None
        is_worker = p >= t
        # one attn kernel per device per step: workers use (q_p, kv_{p−t}),
        # helpers use (q_{(p−t) mod P}, kv_p). No positional mask — strictly
        # causal pairs; document segments still apply.
        q_sel = jnp.where(is_worker, q, qb)
        k_sel = jnp.where(is_worker, kv[0], k)
        v_sel = jnp.where(is_worker, kv[1], v)
        skw = {}
        if seg_r is not None and m.document:
            skw = dict(q_segments=jnp.where(is_worker, seg, seg_r),
                       kv_segments=jnp.where(is_worker, seg_r, seg))
        o_t, s_t = chunk_attn(q_sel, k_sel, v_sel, mask=m_x, **skw,
                              **_tune(spec))
        o_w, s_w = mask_partial(is_worker, o_t, s_t)
        o, s = merge(o, s, o_w, s_w)
        if helpers:
            # helper h computed for worker w=(h−t) mod P: route (o,lse) back
            o_r, s_r = _shift((o_t, s_t), spec.axis, -t, P_)
            o_r, s_r = mask_partial(p >= P_ - t, o_r, s_r)
            o, s = merge(o, s, o_r, s_r)
        if t < T:
            kv, qb = kv_next, qb_next
            seg_r = seg_next if seg_r is not None else None
    return o, s

def _bwd_ring(spec, q, k, v, o, s, do, seg=None):
    p = lax.axis_index(spec.axis)
    P_, Tc = spec.axis_size, q.shape[1]
    m = spec.mask
    f32 = jnp.float32
    delta = jnp.sum(o.astype(f32) * do.astype(f32), axis=-1)  # (B,T,H)
    dq_l, dk_l, dv_l = chunk_attn_bwd(
        q, k, v, o, s, do, mask=m, **_seg_kw(m, seg, seg), **_tune(spec))
    dq = dq_l.astype(f32)
    dkv_home = (dk_l.astype(f32), dv_l.astype(f32))
    n = _ring_steps(spec, Tc)
    if n == 0:
        return dq.astype(q.dtype), dkv_home[0].astype(k.dtype), \
            dkv_home[1].astype(v.dtype)
    # containers: (k, v) data + (dk, dv) accumulators travel together
    kv = _shift((k, v), spec.axis, 1, P_)
    seg_r = _shift(seg, spec.axis, 1, P_) if seg is not None else None
    dkv = compat.tree_map(lambda a: jnp.zeros(a.shape, f32), kv)
    for t in range(1, n + 1):
        if t < n:                                     # prefetch data (overlap)
            kv_nxt = _shift(kv, spec.axis, 1, P_)
            seg_nxt = _shift(seg_r, spec.axis, 1, P_) \
                if seg_r is not None else None
        m_t = mk.ring_step(m, t * Tc)
        dq_t, dk_t, dv_t = chunk_attn_bwd(
            q, kv[0], kv[1], o, s, do, mask=m_t,
            **_seg_kw(m_t, seg, seg_r), **_tune(spec), delta=delta)
        valid = (p >= t) if m.causal else jnp.bool_(True)
        w = valid.astype(f32)
        dq = dq + dq_t.astype(f32) * w
        dkv = (dkv[0] + dk_t.astype(f32) * w, dkv[1] + dv_t.astype(f32) * w)
        if t < n:                                     # accumulators move late
            kv, seg_r = kv_nxt, (seg_nxt if seg_r is not None else None)
            dkv = _shift(dkv, spec.axis, 1, P_)
    # route accumulated dkv home: container at p holds chunk (p−n) mod P
    dkv = _shift(dkv, spec.axis, -n, P_)
    dk = dkv_home[0] + dkv[0]
    dv = dkv_home[1] + dkv[1]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)

def _bwd_balanced(spec, q, k, v, o, s, do, seg=None):
    p = lax.axis_index(spec.axis)
    P_, Tc = spec.axis_size, q.shape[1]
    m = spec.mask
    m_x = mk.strict_causal_pair(m)
    f32 = jnp.float32
    dq_l, dk_l, dv_l = chunk_attn_bwd(q, k, v, o, s, do, mask=m,
                                      **_seg_kw(m, seg, seg), **_tune(spec))
    dq = dq_l.astype(f32)
    dk_home = dk_l.astype(f32)
    dv_home = dv_l.astype(f32)
    if P_ == 1:
        return dq.astype(q.dtype), dk_home.astype(k.dtype), \
            dv_home.astype(v.dtype)
    T = P_ // 2
    delta = jnp.sum(o.astype(f32) * do.astype(f32), axis=-1)
    # traveling containers (ring +1): kv side and q-bundle side
    kv = _shift((k, v), spec.axis, 1, P_)
    dkv = (jnp.zeros(k.shape, f32), jnp.zeros(v.shape, f32))
    qb = _shift((q, do, s, delta), spec.axis, 1, P_)
    seg_r = _shift(seg, spec.axis, 1, P_) if seg is not None else None
    dqb = jnp.zeros(q.shape, f32)
    for t in range(1, T + 1):
        helpers = (t != T) or (P_ % 2 == 1)
        if t < T:                                     # prefetch data (overlap)
            kv_nxt = _shift(kv, spec.axis, 1, P_)
            qb_nxt = _shift(qb, spec.axis, 1, P_)
            seg_nxt = _shift(seg_r, spec.axis, 1, P_) \
                if seg_r is not None else None
        is_worker = p >= t
        q_sel = jnp.where(is_worker, q, qb[0])
        do_sel = jnp.where(is_worker, do, qb[1])
        s_sel = jnp.where(is_worker, s, qb[2])
        k_sel = jnp.where(is_worker, kv[0], k)
        v_sel = jnp.where(is_worker, kv[1], v)
        o_unused = jnp.zeros_like(q_sel)  # delta passed explicitly
        d_sel = jnp.where(is_worker, delta, qb[3])
        skw = {}
        if seg_r is not None and m.document:
            skw = dict(q_segments=jnp.where(is_worker, seg, seg_r),
                       kv_segments=jnp.where(is_worker, seg_r, seg))
        dq_t, dk_t, dv_t = chunk_attn_bwd(
            q_sel, k_sel, v_sel, o_unused, s_sel, do_sel, mask=m_x, **skw,
            **_tune(spec), delta=d_sel)
        w_w = is_worker.astype(f32)
        dq = dq + dq_t.astype(f32) * w_w                 # worker: local dq
        dkv = (dkv[0] + dk_t.astype(f32) * w_w,          # worker: traveling dkv
               dkv[1] + dv_t.astype(f32) * w_w)
        if helpers:
            w_h = (p < t).astype(f32)
            dqb = dqb + dq_t.astype(f32) * w_h           # helper: traveling dq
            dk_home = dk_home + dk_t.astype(f32) * w_h   # helper: local dkv
            dv_home = dv_home + dv_t.astype(f32) * w_h
        if t < T:                                     # accumulators move late
            kv, qb = kv_nxt, qb_nxt
            seg_r = seg_nxt if seg_r is not None else None
            dkv = _shift(dkv, spec.axis, 1, P_)
            dqb = _shift(dqb, spec.axis, 1, P_)
    # route containers home (container at p holds chunk (p−T) mod P)
    dkv = _shift(dkv, spec.axis, -T, P_)
    dqb = _shift(dqb, spec.axis, -T, P_)
    dq = dq + dqb
    dk = dk_home + dkv[0]
    dv = dv_home + dkv[1]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)

def _fwd_zigzag(spec, q, k, v, seg=None):
    p = lax.axis_index(spec.axis)
    P_ = spec.axis_size
    Tl = q.shape[1]
    c = Tl // 2
    m = spec.mask
    m_x = mk.strict_causal_pair(m)
    doc = seg is not None and m.document

    def sk(qs, ks):
        return dict(q_segments=qs, kv_segments=ks) if doc else {}

    q_a, q_b = q[:, :c], q[:, c:]
    k_a, k_b = k[:, :c], k[:, c:]
    v_a, v_b = v[:, :c], v[:, c:]
    s_a_, s_b_ = (seg[:, :c], seg[:, c:]) if seg is not None else (None, None)
    # local step: a×a causal; b̄×a full; b̄×b̄ causal
    o_a, s_a = chunk_attn(q_a, k_a, v_a, mask=m, **sk(s_a_, s_a_),
                          **_tune(spec))
    o_b1, s_b1 = chunk_attn(q_b, k_a, v_a, mask=m_x, **sk(s_b_, s_a_),
                            **_tune(spec))
    o_b2, s_b2 = chunk_attn(q_b, k_b, v_b, mask=m, **sk(s_b_, s_b_),
                            **_tune(spec))
    o_b, s_b = merge(o_b1, s_b1, o_b2, s_b2)
    if P_ == 1:
        return jnp.concatenate([o_a, o_b], 1), jnp.concatenate([s_a, s_b], 1)
    kv = _shift((k, v), spec.axis, 1, P_)
    seg_r = _shift(seg, spec.axis, 1, P_) if seg is not None else None
    for t in range(1, P_):
        if t < P_ - 1:
            kv_next = _shift(kv, spec.axis, 1, P_)
            seg_next = _shift(seg_r, spec.axis, 1, P_) \
                if seg_r is not None else None
        ka_r, kb_r = kv[0][:, :c], kv[0][:, c:]
        va_r, vb_r = kv[1][:, :c], kv[1][:, c:]
        sa_r, sb_r = (seg_r[:, :c], seg_r[:, c:]) if seg_r is not None \
            else (None, None)
        w = p >= t
        # pair 1 -> (q_a if worker else q_b) × kv_a
        q1 = jnp.where(w, q_a, q_b)
        s1q = jnp.where(w, s_a_, s_b_) if doc else None
        o1, s1 = chunk_attn(q1, ka_r, va_r, mask=m_x, **sk(s1q, sa_r),
                            **_tune(spec))
        o1a, s1a = mask_partial(w, o1, s1)
        o_a, s_a = merge(o_a, s_a, o1a, s1a)
        o1b, s1b = mask_partial(~w, o1, s1)
        o_b, s_b = merge(o_b, s_b, o1b, s1b)
        # pair 2 -> q_b × (kv_a if worker else kv_b̄)
        k2 = jnp.where(w, ka_r, kb_r)
        v2 = jnp.where(w, va_r, vb_r)
        s2k = jnp.where(w, sa_r, sb_r) if doc else None
        o2, s2 = chunk_attn(q_b, k2, v2, mask=m_x, **sk(s_b_, s2k),
                            **_tune(spec))
        o_b, s_b = merge(o_b, s_b, o2, s2)
        if t < P_ - 1:
            kv, seg_r = kv_next, (seg_next if seg_r is not None else None)
    return jnp.concatenate([o_a, o_b], 1), jnp.concatenate([s_a, s_b], 1)

def _bwd_zigzag(spec, q, k, v, o, s, do, seg=None):
    p = lax.axis_index(spec.axis)
    P_ = spec.axis_size
    f32 = jnp.float32
    Tl = q.shape[1]
    c = Tl // 2
    sl_a, sl_b = slice(0, c), slice(c, None)
    m = spec.mask
    m_x = mk.strict_causal_pair(m)
    doc = seg is not None and m.document
    delta = jnp.sum(o.astype(f32) * do.astype(f32), axis=-1)

    def cb(qs, ks, vs, ss, dos, ds, mask, qseg=None, kseg=None):
        skw = dict(q_segments=qseg, kv_segments=kseg) if doc else {}
        return chunk_attn_bwd(qs, ks, vs, jnp.zeros_like(qs), ss, dos,
                              mask=mask, **skw, **_tune(spec), delta=ds)

    # local pairs
    dq = jnp.zeros(q.shape, f32)
    dk_h = jnp.zeros(k.shape, f32)
    dv_h = jnp.zeros(v.shape, f32)
    for (qs, ks, mask) in ((sl_a, sl_a, m), (sl_b, sl_a, m_x),
                           (sl_b, sl_b, m)):
        dq_t, dk_t, dv_t = cb(q[:, qs], k[:, ks], v[:, ks], s[:, qs],
                              do[:, qs], delta[:, qs], mask,
                              seg[:, qs] if doc else None,
                              seg[:, ks] if doc else None)
        dq = dq.at[:, qs].add(dq_t.astype(f32))
        dk_h = dk_h.at[:, ks].add(dk_t.astype(f32))
        dv_h = dv_h.at[:, ks].add(dv_t.astype(f32))
    if P_ == 1:
        return dq.astype(q.dtype), dk_h.astype(k.dtype), dv_h.astype(v.dtype)

    q_a, q_b = q[:, sl_a], q[:, sl_b]
    s_a, s_b = s[:, sl_a], s[:, sl_b]
    do_a, do_b = do[:, sl_a], do[:, sl_b]
    de_a, de_b = delta[:, sl_a], delta[:, sl_b]
    sg_a, sg_b = (seg[:, sl_a], seg[:, sl_b]) if doc else (None, None)
    kv = _shift((k, v), spec.axis, 1, P_)
    seg_r = _shift(seg, spec.axis, 1, P_) if seg is not None else None
    dkv = (jnp.zeros(k.shape, f32), jnp.zeros(v.shape, f32))
    for t in range(1, P_):
        if t < P_ - 1:
            kv_nxt = _shift(kv, spec.axis, 1, P_)
            seg_nxt = _shift(seg_r, spec.axis, 1, P_) \
                if seg_r is not None else None
        ka_r, kb_r = kv[0][:, :c], kv[0][:, c:]
        va_r, vb_r = kv[1][:, :c], kv[1][:, c:]
        sa_r, sb_r = (seg_r[:, :c], seg_r[:, c:]) if seg_r is not None \
            else (None, None)
        w = p >= t
        wf = w.astype(f32)
        # pair 1
        q1 = jnp.where(w, q_a, q_b)
        s1 = jnp.where(w, s_a, s_b)
        do1 = jnp.where(w, do_a, do_b)
        de1 = jnp.where(w, de_a, de_b)
        sg1 = jnp.where(w, sg_a, sg_b) if doc else None
        dq1, dk1, dv1 = cb(q1, ka_r, va_r, s1, do1, de1, m_x, sg1, sa_r)
        dq = dq.at[:, sl_a].add(dq1.astype(f32) * wf)
        dq = dq.at[:, sl_b].add(dq1.astype(f32) * (1 - wf))
        dkv = (dkv[0].at[:, sl_a].add(dk1.astype(f32)),
               dkv[1].at[:, sl_a].add(dv1.astype(f32)))
        # pair 2
        k2 = jnp.where(w, ka_r, kb_r)
        v2 = jnp.where(w, va_r, vb_r)
        sg2 = jnp.where(w, sa_r, sb_r) if doc else None
        dq2, dk2, dv2 = cb(q_b, k2, v2, s_b, do_b, de_b, m_x, sg_b, sg2)
        dq = dq.at[:, sl_b].add(dq2.astype(f32))
        dkv = (dkv[0].at[:, sl_a].add(dk2.astype(f32) * wf),
               dkv[1].at[:, sl_a].add(dv2.astype(f32) * wf))
        dkv = (dkv[0].at[:, sl_b].add(dk2.astype(f32) * (1 - wf)),
               dkv[1].at[:, sl_b].add(dv2.astype(f32) * (1 - wf)))
        if t < P_ - 1:
            kv, seg_r = kv_nxt, (seg_nxt if seg_r is not None else None)
            dkv = _shift(dkv, spec.axis, 1, P_)
    # containers at p hold chunk of (p − (P−1)) mod P = (p+1) mod P
    dkv = _shift(dkv, spec.axis, -(P_ - 1), P_)
    dk = dk_h + dkv[0]
    dv = dv_h + dkv[1]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)
