"""Train/serve step factories: jit-compiled with explicit in/out shardings.

``make_train_step`` returns a donated-argument pjit step:
    (params, opt_state, batch) -> (params, opt_state, metrics)
with FSDP parameter/optimizer shardings over (pod, data) and DISTFLASHATTN
sequence parallelism over ``model`` inside the model forward.

Packed-sequence batches flow through unchanged: when the pipeline emits a
``segment_ids`` entry (``ShapeSpec.docs > 1``) it is sharded like the
tokens and the model masks cross-document attention (MaskSpec kind
``document``); the step factories are batch-schema agnostic.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.config import ModelConfig, ShapeSpec, TrainConfig
from repro.data.pipeline import input_specs
from repro.models.transformer import Runtime, build_model
from repro.optim import adamw
from repro.parallel.sharding import param_shardings


def make_train_step(model, tc: TrainConfig):
    """The step carries a non-finite guard: when the loss or any gradient
    leaf is NaN/Inf (loss-scale overflow, poisoned batch, kernel bug) the
    optimizer update is *skipped* — params and optimizer state pass
    through bit-identical (selected leaf-wise, so it composes with
    argument donation) — and the skip is surfaced in the metrics as
    ``skipped_nonfinite`` for the loop to count and log."""
    def step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = model.loss(p, batch)
            return loss, metrics
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        finite = jnp.isfinite(loss)
        for g in jax.tree_util.tree_leaves(grads):
            finite &= jnp.all(jnp.isfinite(g))
        # the update itself runs unconditionally (one trace, no host
        # sync); ``finite`` selects between new and old leaves
        params2, opt2, om = adamw.update(grads, opt_state, params, tc)
        keep = partial(jnp.where, finite)
        params2 = compat.tree_map(keep, params2, params)
        opt2 = adamw.AdamWState(
            step=keep(opt2.step, opt_state.step),
            m=compat.tree_map(keep, opt2.m, opt_state.m),
            v=compat.tree_map(keep, opt2.v, opt_state.v))
        om = {k: keep(v, jnp.zeros_like(v)) for k, v in om.items()}
        return params2, opt2, {"loss": loss, **metrics, **om,
                               "skipped_nonfinite":
                                   (1 - finite).astype(jnp.int32)}
    return step


def jit_train_step(model, tc: TrainConfig, params_sh, batch_sh):
    """jit with explicit shardings + donated params/opt."""
    opt_sh = adamw.AdamWState(
        step=NamedSharding(model.rt.mesh, P()),
        m=params_sh, v=compat.tree_map(lambda s: s, params_sh))
    step = make_train_step(model, tc)
    return jax.jit(step,
                   in_shardings=(params_sh, opt_sh, batch_sh),
                   out_shardings=(params_sh, opt_sh, None),
                   donate_argnums=(0, 1))


def make_decode_step(model):
    def step(params, cache, token, pos):
        logits, cache2 = model.decode(params, cache, {"token": token,
                                                      "pos": pos})
        return logits, cache2
    return step


def make_prefill_step(model):
    def step(params, batch):
        return model.prefill(params, batch)
    return step


def init_sharded(model, tc: TrainConfig, rng):
    """Initialize params + optimizer state directly into their FSDP
    shardings (via jit out_shardings so large models never materialize
    replicated)."""
    rt = model.rt
    shapes = jax.eval_shape(model.init, rng)
    p_sh = param_shardings(shapes, rt.mesh, rt.par)
    params = jax.jit(model.init, out_shardings=p_sh)(rng)
    opt = jax.jit(adamw.init,
                  out_shardings=adamw.AdamWState(
                      step=NamedSharding(rt.mesh, P()), m=p_sh,
                      v=compat.tree_map(lambda s: s, p_sh)))(params)
    return params, opt, p_sh
