"""Measurement-backed autotuner (ROADMAP: "Measurement-backed autotuning").

The repo exposes three families of performance knobs that were, until this
subsystem, driven purely by an uncalibrated analytic roofline:

  * kernel tile shapes — ``block_q``/``block_kv`` hints accepted by every
    ``tunable_blocks`` backend (PR 2);
  * the distributed-attention schedule — ``DistAttnSpec(schedule="auto")``
    ranked candidates by the static :func:`repro.core.schedule.plan_cost`
    comm/compute model (PR 4);
  * the paged-KV-cache ``block_size`` (PR 5).

``repro.tune`` closes the loop with *measurements*:

  * :mod:`repro.tune.sweep` — offline sweep harness (kernel tiles,
    schedule wall times on a host mesh, paged-decode block sizes) driven
    by ``tools/autotune.py``;
  * :mod:`repro.tune.table` — the versioned, host-keyed JSON tuning
    table the sweeps persist winners into, with schema validation,
    nearest-bucket lookup, and env overrides.  Consumers
    (``kernels/registry.block_tuning_kw``, ``choose_schedule``,
    ``PagedKVCache.create``) consult :func:`active_table` when the caller
    passes no explicit value;
  * :mod:`repro.tune.calibrate` — least-squares calibration of the
    schedule cost model's hop-latency / bandwidth / flop coefficients
    against the measured rows (fit residuals and rank correlation are
    recorded in the table).

A default CPU-measured table ships under ``repro/tune/tables/`` and is
auto-loaded on CPU hosts; ``REPRO_TUNE=off`` disables all lookups and
``REPRO_TUNE_TABLE=<path>`` points at a different table (see README
§Autotuning).
"""
from repro.tune.table import (SCHEMA_VERSION, TableError, TuningTable,
                              active_table, set_table)

__all__ = ["SCHEMA_VERSION", "TableError", "TuningTable", "active_table",
           "set_table"]
