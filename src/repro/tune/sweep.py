"""Offline sweep harness: measure → pick winners → tuning-table rows.

Three sweeps, one per tuning surface (driven by ``tools/autotune.py``):

  * :func:`sweep_kernels` — kernel tile shapes.  For every (backend,
    mask kind, head_dim, seq bucket) the candidate ``block_q``×``block_kv``
    tiles race round-robin (:func:`repro.tune.timing.timeit_round_robin`,
    the same interleaved-median clock ``benchmarks/kernel_bench.py``
    uses) and the fastest tile becomes the table row.
  * :func:`sweep_schedules` — distributed-schedule wall time.  A
    subprocess with ``--xla_force_host_platform_device_count=8`` times
    every capable schedule per (mask, seq) on the host mesh — the same
    harness as ``benchmarks/run.py bench_schedules_wall`` — and each row
    keeps the full per-schedule wall map so ``tune/calibrate.py`` can fit
    cost-model coefficients against it.
  * :func:`sweep_paged` — paged-decode ``block_size`` per kv layout via
    ``benchmarks/serving_bench.run_trace`` microtraces with the pool
    token capacity held ~constant across candidate block sizes.

Everything lands in one table document (see :mod:`repro.tune.table`);
``--smoke`` shrinks shapes/iters to CI scale (seconds, not minutes).
"""
from __future__ import annotations

import os
import platform as _platform
import subprocess
import sys
from typing import Dict, List, Optional, Sequence

from repro.tune.table import SCHEMA_VERSION
from repro.tune.timing import timeit_round_robin

_REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__),
                                          "..", "..", ".."))


def host_info() -> dict:
    import jax
    return dict(platform=jax.default_backend(),
                jax=jax.__version__,
                devices=jax.device_count(),
                machine=_platform.machine(),
                python=_platform.python_version())


def new_table_data() -> dict:
    return dict(schema_version=SCHEMA_VERSION,
                generated_by="tools/autotune.py",
                host=host_info(),
                kernel=[], schedule=[], paged=[])


# --------------------------------------------------------------------------
# (a) kernel tile shapes
# --------------------------------------------------------------------------

def _kernel_masks(T: int) -> Dict[str, object]:
    from repro.core import mask as mk
    return {
        "causal": mk.causal(),
        "sliding_window": mk.sliding_window(max(T // 4, 1)),
        "document": mk.document(boundaries=mk.doc_boundaries(T, 4)),
        "full": mk.full(),
    }


def _tile_candidates(backend: str, T: int,
                     blocks: Sequence[int]) -> List[tuple]:
    """(block_q, block_kv) grid.  chunked-lax ignores block_q (its scan
    has a single whole-chunk q block), so only block_kv varies there —
    no point timing the same kernel N times."""
    bs = [b for b in blocks if b <= T] or [T]
    if backend == "chunked-lax":
        return [(bs[-1], bk) for bk in bs]
    return [(bq, bk) for bq in bs for bk in bs]


def _kernel_runner(backend, op, q, k, v, do, mask, bq, bk):
    import jax
    from repro.kernels import ops
    from repro.kernels.chunked import chunked_bwd, chunked_fwd
    if backend == "pallas-interpret":
        if op == "fwd":
            def run():
                o, _ = ops.flash_fwd(q, k, v, mask=mask, block_q=bq,
                                     block_kv=bk, interpret=True)
                jax.block_until_ready(o)
            return run
        o, lse = ops.flash_fwd(q, k, v, mask=mask, interpret=True)

        def run():
            g = ops.flash_bwd(q, k, v, o, lse, do, mask=mask, block_q=bq,
                              block_kv=bk, interpret=True)
            jax.block_until_ready(g)
        return run
    if op == "fwd":
        fn = jax.jit(lambda q, k, v: chunked_fwd(q, k, v, mask=mask,
                                                 block_kv=bk))

        def run():
            jax.block_until_ready(fn(q, k, v))
        return run
    o, lse = chunked_fwd(q, k, v, mask=mask)
    fn = jax.jit(lambda q, k, v, o, lse, do: chunked_bwd(
        q, k, v, o, lse, do, mask=mask, block_kv=bk))

    def run():
        jax.block_until_ready(fn(q, k, v, o, lse, do))
    return run


def sweep_kernels(data: dict, *, smoke: bool = False,
                  log=print) -> None:
    """Race candidate tiles per (backend, mask_kind, head_dim, seq);
    append winner rows to ``data['kernel']``."""
    import jax
    import jax.numpy as jnp
    plat = jax.default_backend()
    if smoke:
        grid = [("chunked-lax", 128, 32), ("pallas-interpret", 64, 32)]
        blocks, iters, H = (16, 32, 64), 2, 2
    else:
        grid = [("chunked-lax", 256, 64), ("chunked-lax", 512, 64),
                ("chunked-lax", 1024, 64),
                ("pallas-interpret", 128, 32), ("pallas-interpret", 256, 32)]
        blocks, iters, H = (32, 64, 128, 256), 3, 4
    for backend, T, D in grid:
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        q, k, v, do = (jax.random.normal(kk, (1, T, H, D), jnp.float32)
                       for kk in ks)
        for mask_kind, m in _kernel_masks(T).items():
            for op in ("fwd", "bwd"):
                cands = _tile_candidates(backend, T, blocks)
                fns = [_kernel_runner(backend, op, q, k, v, do, m, bq, bk)
                       for bq, bk in cands]
                med = timeit_round_robin(fns, iters)
                best = min(range(len(cands)), key=lambda i: med[i])
                bq, bk = cands[best]
                data["kernel"].append(dict(
                    backend=backend, platform=plat, mask_kind=mask_kind,
                    head_dim=D, seq=T, op=op, block_q=bq, block_kv=bk,
                    wall_us=round(med[best], 1),
                    sweep={f"{a}x{b}": round(u, 1)
                           for (a, b), u in zip(cands, med)}))
                log(f"kernel {backend:16s} {mask_kind:15s} T={T:5d} "
                    f"D={D} {op}: best {bq}x{bk} "
                    f"({med[best] / 1e3:.1f}ms)")


# --------------------------------------------------------------------------
# (b) distributed-schedule wall time (8-device host mesh, subprocess)
# --------------------------------------------------------------------------

_SCHED_CODE = """
import time, statistics, numpy as np, jax, jax.numpy as jnp
from repro.core import mask as mk
from repro.core.dist_attention import DistAttnSpec, dist_attn_fwd, zigzag_perm
SEQS = {seqs!r}
SCHEDS = {scheds!r}
REGIMES = {regimes!r}
ITERS = {iters}
mesh = jax.make_mesh((1, 8), ("data", "model"))
B, H, D = 1, 8, 64
def timeit(f, *a):
    jax.block_until_ready(f(*a))
    ts = []
    for _ in range(ITERS):
        t0 = time.perf_counter(); jax.block_until_ready(f(*a))
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts) * 1e6
for N in SEQS:
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (B, N, H, D), jnp.float32) for kk in ks)
    bnd = mk.doc_boundaries(N, 8)
    seg = jnp.asarray(np.tile(mk.segments_from_boundaries(N, bnd), (B, 1)))
    perm = zigzag_perm(N, 8)
    win = N // 8
    specs = dict(causal=(mk.causal(), False),
                 document=(mk.document(), True),
                 sliding_window=(mk.sliding_window(win), False))
    for sched in SCHEDS:
        qq, kk_, vv, ss = (q[:, perm], k[:, perm], v[:, perm],
                           seg[:, perm]) if sched == "zigzag" else (q, k, v,
                                                                    seg)
        for regime in REGIMES:
            m, needs_seg = specs[regime]
            if sched == "rsa" and regime == "sliding_window":
                continue
            spec = DistAttnSpec(axis="model", axis_size=8, schedule=sched,
                                mask=m)
            if needs_seg:
                f = jax.jit(lambda a, b, c, s, _spec=spec: dist_attn_fwd(
                    a, b, c, mesh=mesh, spec=_spec, batch_axes=None,
                    segments=s)[0])
                us = timeit(f, qq, kk_, vv, ss)
            else:
                f = jax.jit(lambda a, b, c, _spec=spec: dist_attn_fwd(
                    a, b, c, mesh=mesh, spec=_spec, batch_axes=None)[0])
                us = timeit(f, qq, kk_, vv)
            print(f"RESULT {{regime}} {{N}} {{win}} {{sched}} {{us:.0f}}",
                  flush=True)
"""


def sweep_schedules(data: dict, *, smoke: bool = False, log=print,
                    seqs: Optional[Sequence[int]] = None) -> None:
    """Measure per-schedule forward wall on the 8-device host mesh and
    append one row per (mask_kind, seq) with the full wall map."""
    if smoke:
        seqs = tuple(seqs or (256,))
        scheds = ("ring", "balanced", "ulysses")
        regimes = ("causal", "sliding_window")
        iters = 2
    else:
        seqs = tuple(seqs or (1024, 2048))
        scheds = ("ring", "balanced", "zigzag", "ulysses", "rsa")
        regimes = ("causal", "document", "sliding_window")
        iters = 3
    code = _SCHED_CODE.format(seqs=tuple(seqs), scheds=tuple(scheds),
                              regimes=tuple(regimes), iters=iters)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_REPO_ROOT, "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=3600)
    if r.returncode != 0:
        raise RuntimeError(f"schedule sweep subprocess failed:\n"
                           f"{r.stderr[-2000:]}")
    rows: Dict[tuple, dict] = {}
    for line in r.stdout.splitlines():
        if not line.startswith("RESULT"):
            continue
        _, regime, N, win, sched, us = line.split()
        key = (regime, int(N))
        row = rows.setdefault(key, dict(
            mask_kind=regime, P=8, seq=int(N), B=1, Hq=8, Hkv=8, Dqk=64,
            bpe=4, window=int(win) if regime == "sliding_window" else None,
            dynamic_seg=regime == "document", best=None, wall_us={}))
        row["wall_us"][sched] = float(us)
    for key in sorted(rows):
        row = rows[key]
        row["best"] = min(row["wall_us"], key=row["wall_us"].get)
        data["schedule"].append(row)
        log(f"schedule {row['mask_kind']:15s} seq={row['seq']:5d}: "
            f"best {row['best']} " + " ".join(
                f"{s}={u / 1e3:.0f}ms"
                for s, u in sorted(row["wall_us"].items())))


# --------------------------------------------------------------------------
# (c) paged-decode block size
# --------------------------------------------------------------------------

def _cache_layout(arch: str) -> str:
    """kv layout label of this arch's paged cache ("mha"/"gqa"/"mla")."""
    from repro.core.config import get_config, smoke_config
    from repro.serve.cache import PagedKVCache
    cfg = smoke_config(get_config(arch))
    return PagedKVCache.create(cfg, block_size=4, n_blocks=2,
                               max_reqs=1).layout


def sweep_paged(data: dict, *, smoke: bool = False, log=print) -> None:
    """Race paged block sizes per kv layout on a serving microtrace; the
    pool's token capacity is held ~constant so candidates differ only in
    granularity (alloc pressure, pad waste), not total memory."""
    if _REPO_ROOT not in sys.path:       # benchmarks/ is repo-root relative
        sys.path.insert(0, _REPO_ROOT)
    from benchmarks.serving_bench import run_trace
    if smoke:
        archs = ("smollm-360m",)
        sizes = (8, 16)
        kw = dict(n_requests=3, max_batch=2, prompt_lens=(8, 12),
                  budgets=(3, 5), mean_gap=1, seed=0)
    else:
        archs = ("smollm-360m", "deepseek-v2-lite-16b")
        sizes = (4, 8, 16, 32)
        kw = dict(n_requests=8, max_batch=4, prompt_lens=(16, 24, 32),
                  budgets=(6, 10, 14), mean_gap=1, seed=0)
    tokens = 17 * 8                       # default pool capacity of the trace
    for arch in archs:
        layout = _cache_layout(arch)
        meas = {}
        for bs in sizes:
            res = run_trace(arch=arch, block_size=bs,
                            n_blocks=max(tokens // bs, 4) + 1, **kw)
            meas[bs] = float(res["tokens_per_s"])
            log(f"paged {arch} ({layout}) block_size={bs}: "
                f"{meas[bs]:.1f} tok/s")
        best = max(meas, key=lambda b: (meas[b], -b))
        data["paged"].append(dict(
            layout=layout, sharding="none", arch=arch, block_size=best,
            tokens_per_s=round(meas[best], 2),
            sweep={str(b): round(t, 2) for b, t in sorted(meas.items())}))
        log(f"paged {arch} ({layout}): best block_size={best}")


# --------------------------------------------------------------------------

def run_sweep(*, smoke: bool = False, parts=("kernel", "schedule", "paged"),
              seqs: Optional[Sequence[int]] = None, log=print) -> dict:
    """Run the requested sweeps into a fresh table document."""
    data = new_table_data()
    if "kernel" in parts:
        sweep_kernels(data, smoke=smoke, log=log)
    if "schedule" in parts:
        sweep_schedules(data, smoke=smoke, log=log, seqs=seqs)
    if "paged" in parts:
        sweep_paged(data, smoke=smoke, log=log)
    return data
