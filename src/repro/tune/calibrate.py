"""Least-squares calibration of the schedule cost model.

``plan_cost()`` predicts per-schedule cost as two analytic terms (kernel
FLOPs, hop-weighted collective bytes) divided by datasheet peak numbers —
a *roofline*, good for on-paper comparisons but uncalibrated against any
real host.  On the CPU test mesh it visibly misranks: ulysses has fewer
comm bytes and comparable FLOPs to balanced at seq 2k × 8 devices, yet
measures ~3.7x slower because one giant ``Tg×Tg`` attention call blows
the cache hierarchy while the ring family streams ``c×c`` chunks.

Calibration fits a 4-feature linear model per measured schedule row

    wall_s ≈ base_s + s_per_flop·flops + s_per_byte·comm_bytes
             + s_per_hop·hops + s_per_elem·score_elems

with nonnegative coefficients (plain ``numpy.linalg.lstsq`` followed by
clamp-negative-and-refit — scipy's ``nnls`` is not a dependency).  The
``score_elems`` feature is the per-kernel-call score-matrix working set
(``B·Hq·c²`` for ring-family plans, ``B·(Hq/P)·Tg²`` for ulysses,
``B·Hq·Tl·Tg`` for the rsa baseline): it is what separates "few big
calls" from "many small calls" regimes that flops/bytes alone cannot.

The fit (coefficients + residual/rank-correlation diagnostics, including
the *uncalibrated* roofline's Spearman for the A/B) is persisted into the
table's ``calibration`` section; ``choose_schedule`` uses the
coefficients to rank candidates whenever the active table carries them
but has no directly-measured row for the requested regime.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

FEATURES = ("flops", "comm_bytes", "hops", "score_elems")
COEFF_OF = {"flops": "s_per_flop", "comm_bytes": "s_per_byte",
            "hops": "s_per_hop", "score_elems": "s_per_elem"}


def mask_for_kind(kind: str, *, T: int, window: Optional[int] = None):
    """Representative MaskSpec for a sweep-row mask kind (feature
    reconstruction only — document boundaries don't change plan_cost)."""
    from repro.core import mask as mk
    if kind == "causal":
        return mk.causal()
    if kind == "full":
        return mk.full()
    if kind == "sliding_window":
        return mk.sliding_window(window or max(T // 8, 1))
    if kind == "document":
        return mk.document()
    if kind == "prefix_lm":
        return mk.prefix_lm(max(T // 4, 1))
    raise ValueError(f"unknown mask kind {kind!r}")


def schedule_features(schedule: str, *, mask_kind: str, P: int, seq: int,
                      B: int = 1, Hq: int = 8, Hkv: Optional[int] = None,
                      Dqk: int = 64, Dv: Optional[int] = None,
                      bpe: int = 4, window: Optional[int] = None,
                      dynamic_seg: bool = False,
                      include_bwd: bool = False) -> Optional[Dict[str, float]]:
    """Feature vector for one (schedule, regime) point; ``seq`` is the
    *global* sequence length (matches the sweep/bench rows).  None when
    the schedule cannot serve the mask (no plan, heads don't divide)."""
    from repro.core import schedule as sp
    Hkv = Hq if Hkv is None else Hkv
    Dv = Dqk if Dv is None else Dv
    Tl = max(seq // P, 1)
    Tg = Tl * P
    m = mask_for_kind(mask_kind, T=seq, window=window)
    if schedule == "ulysses":
        if Hq % P or Hkv % P:
            return None
        cost = sp.ulysses_cost(m, P, Tl=Tl, B=B, Hq=Hq, Hkv=Hkv,
                               Dqk=Dqk, Dv=Dv, bpe=bpe)
        elems = B * (Hq / P) * float(Tg) * Tg
    elif schedule == "rsa":
        # all-gather KV baseline: local Tl×Tg attention over all heads
        # (pairs averaged over ranks — device p sees q offset p·Tl)
        if m.window:
            return None
        pairs = sp._band_pairs(m, Tg, Tg) / P if m.causal \
            else float(Tl) * Tg
        fl = 2.0 * B * Hq * pairs * (Dqk + Dv)
        cb = (P - 1) * B * Tl * Hkv * (Dqk + Dv) * bpe
        if include_bwd:
            fl += 2.0 * B * Hq * pairs * (3 * Dqk + 2 * Dv)
            cb *= 3.0
        return dict(flops=fl, comm_bytes=float(cb), hops=1.0,
                    score_elems=B * Hq * float(Tl) * Tg)
    else:
        if not sp.plan_capable(schedule, m):
            return None
        plan = sp.build_plan(schedule, m, P, Tl)
        cost = sp.plan_cost(plan, B=B, Hq=Hq, Hkv=Hkv, Dqk=Dqk, Dv=Dv,
                            bpe=bpe, dynamic_seg=dynamic_seg)
        c = plan.chunk_len
        elems = B * Hq * float(c) * c
    fl = cost.flops_fwd + (cost.flops_bwd if include_bwd else 0.0)
    cb = cost.comm_bytes_fwd + (cost.comm_bytes_bwd if include_bwd else 0.0)
    return dict(flops=fl, comm_bytes=cb, hops=float(cost.exec_steps),
                score_elems=elems)


def predict_s(feats: Dict[str, float], coeffs: Dict[str, float]) -> float:
    """Calibrated wall-time prediction in seconds."""
    s = coeffs.get("base_s", 0.0)
    for f in FEATURES:
        s += coeffs.get(COEFF_OF[f], 0.0) * feats[f]
    return s


def fit_nonneg(X: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Nonnegative least squares by iterated clamp-and-refit: solve the
    unconstrained problem, zero any negative coefficient, refit over the
    survivors until all remaining coefficients are >= 0.  Not exactly
    Lawson-Hanson, but convergent and dependency-free."""
    n = X.shape[1]
    active = list(range(n))
    w = np.zeros(n)
    for _ in range(n + 1):
        if not active:
            break
        sol, *_ = np.linalg.lstsq(X[:, active], y, rcond=None)
        neg = [a for a, s in zip(active, sol) if s < 0]
        if not neg:
            for a, s in zip(active, sol):
                w[a] = s
            break
        active = [a for a in active if a not in neg]
    return w


def spearman(a: Sequence[float], b: Sequence[float]) -> float:
    """Spearman rank correlation with average ranks on ties (no scipy)."""
    def ranks(v):
        v = np.asarray(v, dtype=float)
        order = np.argsort(v, kind="mergesort")
        r = np.empty(len(v))
        i = 0
        while i < len(v):
            j = i
            while j + 1 < len(v) and v[order[j + 1]] == v[order[i]]:
                j += 1
            r[order[i:j + 1]] = (i + j) / 2.0 + 1.0
            i = j + 1
        return r
    ra, rb = ranks(a), ranks(b)
    ra -= ra.mean()
    rb -= rb.mean()
    denom = math.sqrt(float((ra ** 2).sum() * (rb ** 2).sum()))
    return float((ra * rb).sum() / denom) if denom else 0.0


def _row_points(rows: List[dict]
                ) -> List[Tuple[dict, str, Dict[str, float], float]]:
    """(row, schedule, features, wall_s) for every measured (regime,
    schedule) pair whose features are computable.  Rows whose schedule
    has no feature model (e.g. a plan-incapable mask) are skipped — they
    can't inform the fit."""
    pts = []
    for row in rows:
        for sched, us in sorted(row["wall_us"].items()):
            if not isinstance(us, (int, float)):
                continue
            feats = schedule_features(
                sched, mask_kind=row["mask_kind"], P=int(row["P"]),
                seq=int(row["seq"]), B=int(row.get("B", 1)),
                Hq=int(row.get("Hq", 8)), Hkv=row.get("Hkv"),
                Dqk=int(row.get("Dqk", 64)), bpe=int(row.get("bpe", 4)),
                window=row.get("window"),
                dynamic_seg=bool(row.get("dynamic_seg", False)))
            if feats is not None:
                pts.append((row, sched, feats, float(us) * 1e-6))
    return pts


def roofline_s(feats: Dict[str, float]) -> float:
    """What the uncalibrated model would predict (for the A/B fit stats)."""
    from repro.analysis.roofline import schedule_cost_terms
    return schedule_cost_terms(flops=feats["flops"],
                               comm_bytes=feats["comm_bytes"]
                               )["step_s_lower_bound"]


def calibrate(rows: List[dict]) -> dict:
    """Fit coefficients to the measured schedule rows and compute the
    diagnostics: relative RMS residual, pooled Spearman of calibrated
    predictions vs measured walls, same for the uncalibrated roofline,
    and per-regime best-schedule agreement for both models.  Returns the
    table's ``calibration`` section."""
    pts = _row_points(rows)
    if len(pts) < len(FEATURES) + 1:
        raise ValueError(f"need at least {len(FEATURES) + 1} measured "
                         f"points to calibrate, got {len(pts)}")
    X = np.array([[f[k] for k in FEATURES] + [1.0] for _, _, f, _ in pts])
    y = np.array([w for _, _, _, w in pts])
    scale = X.max(axis=0)
    scale[scale == 0] = 1.0
    w = fit_nonneg(X / scale, y) / scale
    coeffs = {COEFF_OF[k]: float(w[i]) for i, k in enumerate(FEATURES)}
    coeffs["base_s"] = float(w[len(FEATURES)])

    pred = np.array([predict_s(f, coeffs) for _, _, f, _ in pts])
    roof = np.array([roofline_s(f) for _, _, f, _ in pts])
    rel_rms = float(np.sqrt(np.mean(((pred - y) / y) ** 2)))
    sp_cal = spearman(pred, y)
    sp_roof = spearman(roof, y)

    # per-regime: does argmin(prediction) hit the measured-best schedule?
    regimes = {}
    for (row, sched, f, wall), p, r in zip(pts, pred, roof):
        key = (row["mask_kind"], int(row["P"]), int(row["seq"]))
        regimes.setdefault(key, {})[sched] = (wall, float(p), float(r))
    agree = []
    for (mk_, P, seq), by_sched in sorted(regimes.items()):
        agree.append(dict(
            mask_kind=mk_, P=P, seq=seq,
            measured_best=min(by_sched, key=lambda s: by_sched[s][0]),
            calibrated_pick=min(by_sched, key=lambda s: by_sched[s][1]),
            roofline_pick=min(by_sched, key=lambda s: by_sched[s][2])))
    n_cal = sum(a["calibrated_pick"] == a["measured_best"] for a in agree)
    n_roof = sum(a["roofline_pick"] == a["measured_best"] for a in agree)

    return dict(
        coeffs=coeffs,
        fit=dict(n_points=len(pts), rel_rms=round(rel_rms, 4),
                 spearman=round(sp_cal, 4),
                 spearman_roofline=round(sp_roof, 4),
                 best_match=f"{n_cal}/{len(agree)}",
                 best_match_roofline=f"{n_roof}/{len(agree)}",
                 regimes=agree))
