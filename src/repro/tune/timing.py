"""Shared wall-clock timing helpers for sweeps and benches.

Host-CPU timing is noisy (background load, turbo drift), so everything
here reports **medians** and the A/B comparator interleaves its two
variants iteration-by-iteration so slow drift hits both equally.  Moved
here from ``benchmarks/kernel_bench.py`` so the offline sweeps
(:mod:`repro.tune.sweep`) and the tracked benches share one clock.
"""
from __future__ import annotations

import statistics
import time
from typing import Callable, Sequence, Tuple


def timeit_us(fn: Callable[[], object], iters: int = 5) -> float:
    """Median wall µs of ``fn`` over ``iters`` runs after one warmup
    call (which also absorbs jit compilation — callers must block on
    the result inside ``fn``, e.g. ``block_until_ready``)."""
    fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts) * 1e6


def timeit_pair(fn_a: Callable[[], object], fn_b: Callable[[], object],
                iters: int) -> Tuple[float, float]:
    """Median µs of two variants, iterations interleaved A/B so slow drift
    in background load hits both equally (host CPU timing is noisy)."""
    fn_a()                                 # warmup / compile
    fn_b()
    ta, tb = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn_a()
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        fn_b()
        tb.append(time.perf_counter() - t0)
    return statistics.median(ta) * 1e6, statistics.median(tb) * 1e6


def timeit_round_robin(fns: Sequence[Callable[[], object]],
                       iters: int) -> list:
    """N-way generalisation of :func:`timeit_pair`: one pass warms every
    candidate, then each timing iteration visits all of them in order.
    Used by the tile/block-size sweeps where 4-10 variants compete."""
    for fn in fns:
        fn()
    samples = [[] for _ in fns]
    for _ in range(iters):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            fn()
            samples[i].append(time.perf_counter() - t0)
    return [statistics.median(s) * 1e6 for s in samples]
