"""The persisted tuning table: schema, validation, lookup, overrides.

One JSON document holds everything the sweeps measured on one host:

.. code-block:: text

    {
      "schema_version": 1,
      "generated_by": "tools/autotune.py",
      "host": {"platform": "cpu", "jax": "0.4.37", ...},
      "kernel":   [ {backend, platform, mask_kind, head_dim, seq, op,
                     block_q, block_kv, wall_us, sweep: {"64x64": us, ...}} ],
      "schedule": [ {mask_kind, P, seq, Hq, Hkv, Dqk, best,
                     wall_us: {schedule: us}} ],
      "paged":    [ {layout, sharding, block_size, tokens_per_s,
                     sweep: {"8": tok_s, ...}} ],
      "calibration": {coeffs: {s_per_flop, s_per_byte, s_per_hop, base_s},
                      fit: {rel_rms, spearman, spearman_roofline, ...}}
    }

Lookups are **nearest-bucket**: an exact match on the categorical keys
(backend, platform, mask kind, op / schedule P / paged layout) and the
closest measured bucket in log-space on the numeric ones (``seq``,
``head_dim``) — a table swept at 256 and 512 serves a 384-long call from
the 512 row and a 64-long call from the 256 row.  A missing table, a
schema-version mismatch, or a corrupt file degrade to ``None`` (callers
fall back to their built-in heuristics) with one logged warning per
process per path — tuning must never turn into a crash.

Resolution order for :func:`active_table` (cached per process):

  1. an explicit :func:`set_table` (tests, tools);
  2. ``REPRO_TUNE_TABLE=<path>`` env;
  3. the bundled per-platform default ``tables/default_<platform>.json``;
  4. ``None`` (heuristics).  ``REPRO_TUNE=off`` short-circuits to None.

Value overrides sit *between* explicit kwargs and the table:
``REPRO_TUNE_BLOCK_Q`` / ``REPRO_TUNE_BLOCK_KV`` (kernel tiles) and
``REPRO_TUNE_BLOCK_SIZE`` (paged cache) force a value without editing any
call site — see ``kernels/registry.block_tuning_kw`` and
``serve/cache.PagedKVCache.create`` for the full precedence chains.
"""
from __future__ import annotations

import json
import logging
import math
import os
from typing import Dict, List, Optional, Sequence, Tuple

log = logging.getLogger(__name__)

SCHEMA_VERSION = 1

# entry keys required per section (validation rejects rows missing any)
_REQUIRED = {
    "kernel": ("backend", "platform", "mask_kind", "head_dim", "seq", "op",
               "block_q", "block_kv"),
    "schedule": ("mask_kind", "P", "seq", "best", "wall_us"),
    "paged": ("layout", "sharding", "block_size"),
}


class TableError(ValueError):
    """Structured load/validation failure (path + reason)."""

    def __init__(self, path, reason):
        self.path, self.reason = path, reason
        super().__init__(f"tuning table {path!r}: {reason}")


def _log_dist(a: float, b: float) -> float:
    """Distance in log2 space (seq/head_dim buckets are powers-of-two-ish);
    guards zero/negative garbage from hand-edited tables."""
    a, b = max(float(a), 1.0), max(float(b), 1.0)
    return abs(math.log2(a) - math.log2(b))


class TuningTable:
    """In-memory view of one tuning-table document (see module docstring)."""

    def __init__(self, data: dict, path: Optional[str] = None):
        self.data = data
        self.path = path
        errs = self.validate(data)
        if errs:
            raise TableError(path or "<dict>", "; ".join(errs[:3]))

    # ------------------------------------------------------------ schema
    @staticmethod
    def validate(data) -> List[str]:
        """Schema errors ([] = valid).  Checked on load so a corrupt or
        future-versioned table degrades to heuristics instead of crashing
        some resolve() deep inside a jit trace."""
        errs = []
        if not isinstance(data, dict):
            return [f"document is {type(data).__name__}, expected object"]
        v = data.get("schema_version")
        if v != SCHEMA_VERSION:
            errs.append(f"schema_version {v!r} != supported {SCHEMA_VERSION}")
        for section, req in _REQUIRED.items():
            rows = data.get(section, [])
            if not isinstance(rows, list):
                errs.append(f"section {section!r} is not a list")
                continue
            for i, r in enumerate(rows):
                if not isinstance(r, dict):
                    errs.append(f"{section}[{i}] is not an object")
                    continue
                missing = [k for k in req if k not in r]
                if missing:
                    errs.append(f"{section}[{i}] missing {missing}")
        cal = data.get("calibration")
        if cal is not None:
            co = cal.get("coeffs") if isinstance(cal, dict) else None
            if not isinstance(co, dict) or not all(
                    isinstance(co.get(k), (int, float)) for k in
                    ("s_per_flop", "s_per_byte", "s_per_hop", "base_s")):
                errs.append("calibration.coeffs incomplete")
        return errs

    # -------------------------------------------------------- persistence
    @classmethod
    def load(cls, path: str) -> "TuningTable":
        """Parse + validate; raises :class:`TableError` on any problem."""
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            raise TableError(path, f"unreadable ({e})") from e
        return cls(data, path=path)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.data, f, indent=1, sort_keys=False)
            f.write("\n")
        self.path = path

    # ------------------------------------------------------------ lookups
    def best_blocks(self, *, backend: str, platform: str, mask_kind: str,
                    head_dim: int, seq: int,
                    op: str = "fwd") -> Optional[Tuple[int, int]]:
        """Winning ``(block_q, block_kv)`` for the nearest swept bucket:
        exact on (backend, platform, mask_kind, op), nearest in log space
        on (seq, head_dim).  None when no row matches the exact keys."""
        cands = [r for r in self.data.get("kernel", [])
                 if r["backend"] == backend and r["platform"] == platform
                 and r["mask_kind"] == mask_kind and r["op"] == op]
        if not cands:
            return None
        r = min(cands, key=lambda r: (_log_dist(r["seq"], seq)
                                      + _log_dist(r["head_dim"], head_dim),
                                      r["seq"], r["head_dim"]))
        return int(r["block_q"]), int(r["block_kv"])

    def best_schedule(self, *, mask_kind: str, P: int, seq: int,
                      candidates: Optional[Sequence[str]] = None,
                      ) -> Optional[str]:
        """Measured-fastest schedule at the nearest (mask_kind, P, seq)
        bucket, restricted to ``candidates`` (the capable set at this call
        site — the measured global best may be a schedule the caller can't
        run, e.g. zigzag without its layout permutation).  None when no
        row matches mask_kind × P or no candidate was measured."""
        rows = [r for r in self.data.get("schedule", [])
                if r["mask_kind"] == mask_kind and int(r["P"]) == int(P)]
        if not rows:
            return None
        r = min(rows, key=lambda r: (_log_dist(r["seq"], seq), r["seq"]))
        walls = {k: v for k, v in r["wall_us"].items()
                 if isinstance(v, (int, float))}
        if candidates is not None:
            walls = {k: v for k, v in walls.items() if k in candidates}
        if not walls:
            return None
        return min(walls, key=lambda k: (walls[k], k))

    def schedule_rows(self) -> List[dict]:
        return list(self.data.get("schedule", []))

    def best_block_size(self, *, layout: str,
                        sharding: str = "none") -> Optional[int]:
        """Paged-cache block size for (kv layout, pool sharding); falls
        back to the same layout under any sharding when the exact pair
        was not swept."""
        rows = [r for r in self.data.get("paged", [])
                if r["layout"] == layout]
        if not rows:
            return None
        exact = [r for r in rows if r["sharding"] == sharding]
        r = (exact or rows)[0]
        return int(r["block_size"])

    def coeffs(self) -> Optional[Dict[str, float]]:
        """Calibrated cost-model coefficients (None = table not
        calibrated; consumers fall back to the analytic roofline)."""
        cal = self.data.get("calibration")
        if not cal:
            return None
        return dict(cal["coeffs"])

    def fit(self) -> Optional[dict]:
        cal = self.data.get("calibration")
        return dict(cal.get("fit", {})) if cal else None


# ==========================================================================
# Process-wide active table
# ==========================================================================

_UNSET = object()
_ACTIVE = _UNSET                 # cache: TuningTable | None once resolved
_EXPLICIT = _UNSET               # set_table() override
_WARNED = set()                  # one degradation warning per path


def tables_dir() -> str:
    return os.path.join(os.path.dirname(__file__), "tables")


def bundled_default(platform: str) -> Optional[str]:
    p = os.path.join(tables_dir(), f"default_{platform}.json")
    return p if os.path.exists(p) else None


def _load_checked(path: str) -> Optional[TuningTable]:
    """Load-or-degrade: any failure (missing, corrupt, schema mismatch)
    logs one warning per process per path and returns None."""
    try:
        return TuningTable.load(path)
    except TableError as e:
        if path not in _WARNED:
            _WARNED.add(path)
            log.warning("ignoring tuning table %s (%s); falling back to "
                        "built-in heuristics", path, e.reason)
        return None


def set_table(table) -> None:
    """Force the active table: a :class:`TuningTable`, a path, or None
    (= heuristics).  Pass ``table=...UNSET...``?  No — call
    :func:`reset` to return to env/bundled resolution."""
    global _EXPLICIT, _ACTIVE
    if isinstance(table, str):
        table = _load_checked(table)
    _EXPLICIT = table
    _ACTIVE = _UNSET


def reset() -> None:
    """Drop the explicit override and the cached resolution (tests)."""
    global _EXPLICIT, _ACTIVE
    _EXPLICIT = _UNSET
    _ACTIVE = _UNSET


def active_table() -> Optional[TuningTable]:
    """The table consumers consult (see module docstring for the
    resolution order).  Cached; :func:`reset` after changing env vars."""
    global _ACTIVE
    if os.environ.get("REPRO_TUNE", "").lower() in ("off", "0", "false"):
        return None
    if _EXPLICIT is not _UNSET:
        return _EXPLICIT
    if _ACTIVE is _UNSET:
        path = os.environ.get("REPRO_TUNE_TABLE")
        if not path:
            try:
                import jax
                path = bundled_default(jax.default_backend())
            except Exception:        # pragma: no cover - jax always present
                path = None
        _ACTIVE = _load_checked(path) if path else None
    return _ACTIVE


def env_int(name: str) -> Optional[int]:
    """Int env override, or None when unset/garbage (garbage warns once)."""
    v = os.environ.get(name)
    if not v:
        return None
    try:
        return int(v)
    except ValueError:
        if name not in _WARNED:
            _WARNED.add(name)
            log.warning("ignoring non-integer %s=%r", name, v)
        return None
