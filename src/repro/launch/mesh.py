"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: (data=16, model=16) = 256 chips;
multi-pod: (pod=2, data=16, model=16) = 512 chips. The ``model`` axis is
the DISTFLASHATTN sequence-parallel axis.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(seq: int = 1, data: int | None = None):
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    n = len(jax.devices())
    if data is None:
        data = n // seq
    return jax.make_mesh((data, seq), ("data", "model"))


def make_seq2d_mesh(r: int, u: int, data: int = 1):
    """Factored sequence×head mesh for the 2D (ring×ulysses) attention
    plans: ``r·u`` sequence-parallel workers as a (``seq`` = r,
    ``head`` = u) grid, head minor so the head-axis all-to-all stays
    intra-group (intra-node on real hardware — BurstAttention's split).
    Activations shard the sequence over the ("seq", "head") axis *pair*;
    ``parallel.sharding.make_parallel_config`` picks the axes up by name."""
    return jax.make_mesh((data, r, u), ("data", "seq", "head"))
