"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. Single pod: (data=16, model=16) = 256 chips;
multi-pod: (pod=2, data=16, model=16) = 512 chips. The ``model`` axis is
the DISTFLASHATTN sequence-parallel axis.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(seq: int = 1, data: int | None = None):
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    n = len(jax.devices())
    if data is None:
        data = n // seq
    return jax.make_mesh((data, seq), ("data", "model"))
