"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --smoke --steps 50 --seq 256 --batch 4 [--schedule balanced] \
        [--remat remat_aware] [--ckpt-dir ckpts/run0]

Uses whatever devices exist (tests/CPU: a (1,1) or (data,model) local mesh;
on real hardware pass --mesh production). The step is jit-compiled with
explicit FSDP in/out shardings and donated params/optimizer.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.config import (ShapeSpec, TrainConfig, get_config,
                               smoke_config)
from repro.data.pipeline import SyntheticTokens
from repro.io import checkpoint as ckpt_io
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.transformer import Runtime, build_model
from repro.optim import adamw
from repro.parallel.sharding import make_parallel_config, param_shardings
from repro.train.step import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--schedule", default="balanced",
                    choices=("balanced", "ring", "ulysses"))
    ap.add_argument("--remat", default="remat_aware",
                    choices=("remat_aware", "hf", "none"))
    ap.add_argument("--mesh", default="local",
                    choices=("local", "production", "production-multipod"))
    ap.add_argument("--seq-shards", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    if args.mesh == "local":
        mesh = make_local_mesh(seq=args.seq_shards)
    else:
        mesh = make_production_mesh(
            multi_pod=args.mesh.endswith("multipod"))
    shape = ShapeSpec("cli", args.seq, args.batch, "train")
    par = make_parallel_config(mesh, shape, schedule=args.schedule,
                               remat=args.remat)
    rt = Runtime(mesh=mesh, par=par, impl="ref")
    model = build_model(cfg, rt)
    print(f"arch={cfg.name} params≈{cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"schedule={args.schedule} remat={args.remat}")

    params = model.init(jax.random.PRNGKey(0))
    p_sh = param_shardings(jax.eval_shape(lambda: params), mesh, par)
    params = compat.tree_map(
        lambda x, s: jax.device_put(x, s), params, p_sh)
    opt = adamw.init(params)
    tc = TrainConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5 + 1),
                     total_steps=args.steps)
    step = jax.jit(make_train_step(model, tc), donate_argnums=(0, 1))
    ds = SyntheticTokens(cfg, shape, par, mesh)

    t0 = time.time()
    n_skipped = 0
    for i in range(args.steps):
        params, opt, m = step(params, opt, ds.batch(i))
        if int(m["skipped_nonfinite"]):
            # log the first skip loudly, then just count — a burst of bad
            # steps must not flood the log
            if n_skipped == 0:
                print(f"step {i:5d} non-finite loss/grads — optimizer "
                      f"update skipped (params untouched); further skips "
                      f"counted silently", flush=True)
            n_skipped += 1
        if i % args.log_every == 0 or i == args.steps - 1:
            loss = float(m["loss"])
            dt = time.time() - t0
            tok_s = (i + 1) * args.batch * args.seq / max(dt, 1e-9)
            print(f"step {i:5d} loss {loss:.4f} lr {float(m['lr']):.2e} "
                  f"gnorm {float(m['gnorm']):.2f} tok/s {tok_s:.0f}"
                  + (f" skipped {n_skipped}" if n_skipped else ""),
                  flush=True)
        if args.ckpt_dir and args.ckpt_every and \
                (i + 1) % args.ckpt_every == 0:
            ckpt_io.save(args.ckpt_dir, {"params": params}, step=i + 1)
    if args.ckpt_dir:
        ckpt_io.save(args.ckpt_dir, {"params": params}, step=args.steps)
        print(f"saved checkpoint to {args.ckpt_dir}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
