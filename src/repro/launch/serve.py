"""Serving driver: continuous-batching paged-KV serving of synthetic
prompts (default), or the legacy fixed-slot dense-cache engine
(``--fixed-slot``) for A/B comparison.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
        --prompt-len 128 --gen 16 --batch 4 [--window 64] \
        [--block-size 16 --n-blocks 128] [--fixed-slot] \
        [--spec-depth 4 [--self-spec | --draft-config smollm-360m]]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.config import ShapeSpec, get_config, smoke_config
import dataclasses
from repro.data.pipeline import SyntheticTokens
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.transformer import Runtime, build_model
from repro.parallel.sharding import make_parallel_config
from repro.serve.engine import Engine, FixedSlotEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--window", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--mesh", default="local")
    ap.add_argument("--seq-shards", type=int, default=1)
    ap.add_argument("--fixed-slot", action="store_true",
                    help="legacy dense-cache engine instead of paged")
    ap.add_argument("--block-size", type=int, default=0,
                    help="paged block size (0 = tuning-table default)")
    ap.add_argument("--n-blocks", type=int, default=0,
                    help="paged pool size (0 = sized to the workload)")
    ap.add_argument("--spec-depth", type=int, default=0,
                    help="speculative draft depth (0 = vanilla decode)")
    ap.add_argument("--self-spec", action="store_true",
                    help="n-gram prompt-lookup self-speculation (no draft "
                         "model)")
    ap.add_argument("--draft-config", default=None,
                    help="draft arch id for model-based speculation "
                         "(default: configs/spec_pairs.py pairing)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    if args.window:
        cfg = cfg.replace(attn=dataclasses.replace(cfg.attn,
                                                   window=args.window))
    mesh = make_local_mesh(seq=args.seq_shards) if args.mesh == "local" \
        else make_production_mesh(multi_pod="multipod" in args.mesh)
    shape = ShapeSpec("serve", args.prompt_len, args.batch, "prefill")
    par = make_parallel_config(mesh, shape)
    model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))
    params = model.init(jax.random.PRNGKey(0))
    batch = SyntheticTokens(cfg, shape, par, mesh).batch(0)

    if not args.block_size:
        from repro.serve.cache import PagedKVCache
        args.block_size = PagedKVCache.default_block_size(
            cfg.attn, mesh, par.seq_axis)

    if args.fixed_slot:
        eng = FixedSlotEngine(model, params)
        t0 = time.time()
        toks, _ = eng.generate(batch, args.gen, rng=jax.random.PRNGKey(1),
                               temperature=args.temperature)
        dt = time.time() - t0
        tag = "fixed-slot"
    else:
        spec = draft = None
        if args.spec_depth > 0:
            from repro.serve.speculative import ModelDraft, SpecConfig
            if args.self_spec:
                spec = SpecConfig(depth=args.spec_depth, mode="ngram")
            else:
                from repro.configs.spec_pairs import draft_arch_for
                d_arch = args.draft_config or draft_arch_for(cfg.name)
                if d_arch is None:
                    raise SystemExit(
                        f"no draft pairing for {cfg.name!r}; pass "
                        f"--draft-config or --self-spec")
                d_cfg = get_config(d_arch)
                if args.smoke:
                    d_cfg = smoke_config(d_cfg)
                d_model = build_model(d_cfg, Runtime(mesh=mesh, par=par,
                                                     impl="ref"))
                d_params = d_model.init(jax.random.PRNGKey(7))
                spec = SpecConfig(depth=args.spec_depth, mode="model",
                                  draft_arch=d_cfg.name)
                draft = ModelDraft(d_model, d_params,
                                   block_size=args.block_size,
                                   max_batch=args.batch)
        blocks_per_req = -(-(args.prompt_len + args.gen
                             + args.spec_depth) // args.block_size)
        n_blocks = args.n_blocks or args.batch * blocks_per_req + 2
        eng = Engine(model, params, max_batch=args.batch,
                     block_size=args.block_size, n_blocks=n_blocks,
                     spec=spec, draft=draft)
        t0 = time.time()
        toks = eng.generate(batch, args.gen, rng=jax.random.PRNGKey(1),
                            temperature=args.temperature)
        dt = time.time() - t0
        tag = (f"paged bs={args.block_size} pool={n_blocks} "
               f"steps={eng.stats()['steps']} "
               f"preempt={eng.stats()['n_preemptions']}")
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"generated={args.gen} tokens in {dt:.2f}s "
          f"({args.gen * args.batch / dt:.1f} tok/s) [{tag}]")
    if not args.fixed_slot:
        s = eng.stats()
        print("robustness: "
              f"shed={s['shed']} retried={s['retried']} "
              f"quarantined={s['quarantined']} expired={s['expired']} "
              f"failed={s['failed']} watchdog_trips={s['watchdog_trips']} "
              f"audit_passes={s['audit_passes']}")
        if args.spec_depth > 0:
            mode = "ngram" if args.self_spec else "model"
            print("speculative: "
                  f"mode={mode} depth={args.spec_depth} "
                  f"proposed={s['spec_proposed']} "
                  f"accepted={s['spec_accepted']} "
                  f"rejected={s['spec_rejected']} "
                  f"rollbacks={s['spec_rollbacks']} "
                  f"acceptance={s['spec_acceptance']:.2f}")
    print("sampled token ids (first request):",
          [int(t) for t in toks[0][:16]])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
