"""Serving driver: prefill a batch of synthetic prompts and decode N tokens
through the sequence-sharded KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
        --prompt-len 128 --gen 16 --batch 4 [--window 64]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.config import ShapeSpec, get_config, smoke_config
import dataclasses
from repro.data.pipeline import SyntheticTokens
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.transformer import Runtime, build_model
from repro.parallel.sharding import make_parallel_config
from repro.serve.engine import Engine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--window", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--mesh", default="local")
    ap.add_argument("--seq-shards", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    if args.window:
        cfg = cfg.replace(attn=dataclasses.replace(cfg.attn,
                                                   window=args.window))
    mesh = make_local_mesh(seq=args.seq_shards) if args.mesh == "local" \
        else make_production_mesh(multi_pod="multipod" in args.mesh)
    shape = ShapeSpec("serve", args.prompt_len, args.batch, "prefill")
    par = make_parallel_config(mesh, shape)
    model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))
    params = model.init(jax.random.PRNGKey(0))
    batch = SyntheticTokens(cfg, shape, par, mesh).batch(0)

    eng = Engine(model, params)
    t0 = time.time()
    toks, logits = eng.generate(batch, args.gen,
                                rng=jax.random.PRNGKey(1),
                                temperature=args.temperature)
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"generated={args.gen} tokens in {dt:.2f}s "
          f"({args.gen * args.batch / dt:.1f} tok/s)")
    print("sampled token ids (first request):",
          [int(t) for t in toks[0][:16]])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
