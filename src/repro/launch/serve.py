"""Serving driver: continuous-batching paged-KV serving of synthetic
prompts (default), or the legacy fixed-slot dense-cache engine
(``--fixed-slot``) for A/B comparison.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
        --prompt-len 128 --gen 16 --batch 4 [--window 64] \
        [--block-size 16 --n-blocks 128] [--fixed-slot]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.config import ShapeSpec, get_config, smoke_config
import dataclasses
from repro.data.pipeline import SyntheticTokens
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.transformer import Runtime, build_model
from repro.parallel.sharding import make_parallel_config
from repro.serve.engine import Engine, FixedSlotEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=128)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--window", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--mesh", default="local")
    ap.add_argument("--seq-shards", type=int, default=1)
    ap.add_argument("--fixed-slot", action="store_true",
                    help="legacy dense-cache engine instead of paged")
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--n-blocks", type=int, default=0,
                    help="paged pool size (0 = sized to the workload)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    if args.window:
        cfg = cfg.replace(attn=dataclasses.replace(cfg.attn,
                                                   window=args.window))
    mesh = make_local_mesh(seq=args.seq_shards) if args.mesh == "local" \
        else make_production_mesh(multi_pod="multipod" in args.mesh)
    shape = ShapeSpec("serve", args.prompt_len, args.batch, "prefill")
    par = make_parallel_config(mesh, shape)
    model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))
    params = model.init(jax.random.PRNGKey(0))
    batch = SyntheticTokens(cfg, shape, par, mesh).batch(0)

    if args.fixed_slot:
        eng = FixedSlotEngine(model, params)
        t0 = time.time()
        toks, _ = eng.generate(batch, args.gen, rng=jax.random.PRNGKey(1),
                               temperature=args.temperature)
        dt = time.time() - t0
        tag = "fixed-slot"
    else:
        blocks_per_req = -(-(args.prompt_len + args.gen) // args.block_size)
        n_blocks = args.n_blocks or args.batch * blocks_per_req + 2
        eng = Engine(model, params, max_batch=args.batch,
                     block_size=args.block_size, n_blocks=n_blocks)
        t0 = time.time()
        toks = eng.generate(batch, args.gen, rng=jax.random.PRNGKey(1),
                            temperature=args.temperature)
        dt = time.time() - t0
        tag = (f"paged bs={args.block_size} pool={n_blocks} "
               f"steps={eng.stats()['steps']} "
               f"preempt={eng.stats()['n_preemptions']}")
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"generated={args.gen} tokens in {dt:.2f}s "
          f"({args.gen * args.batch / dt:.1f} tok/s) [{tag}]")
    if not args.fixed_slot:
        s = eng.stats()
        print("robustness: "
              f"shed={s['shed']} retried={s['retried']} "
              f"quarantined={s['quarantined']} expired={s['expired']} "
              f"failed={s['failed']} watchdog_trips={s['watchdog_trips']} "
              f"audit_passes={s['audit_passes']}")
    print("sampled token ids (first request):",
          [int(t) for t in toks[0][:16]])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
