import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first
# backend initialization. 512 placeholder host devices stand in for the
# production 2×16×16 multi-pod mesh (dry-run only).

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
on the production meshes and extract memory/cost/collective analyses.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
        --shape train_4k [--multi-pod] [--schedule balanced] [--out f.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all   # driver loop

Success of ``.lower().compile()`` for a pair proves the sharding config is
coherent (no mismatched collectives, divisibility holes, or unsupported
layouts); the printed analyses feed EXPERIMENTS.md §Dry-run and §Roofline.
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.analysis import roofline as R
from repro.core.config import (ARCH_IDS, SHAPES, TrainConfig, get_config,
                               get_shape)
from repro.data.pipeline import input_specs
from repro.launch.mesh import make_production_mesh
from repro.models.transformer import Runtime, build_model
from repro.optim import adamw
from repro.parallel.sharding import make_parallel_config, param_shardings
from repro.train.step import make_train_step

LONG_CTX_WINDOW = 8192   # paper Appendix-F sliding window for long_500k


def prepare(arch: str, shape_name: str, mesh, *, schedule="balanced",
            remat="remat_aware", impl="ref", latent_ring=False):
    """Build (step_fn, arg_structs, in_shardings) for one pair."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if shape_name == "long_500k" and cfg.uses_attention:
        # sub-quadratic requirement: Appendix-F sliding window for the
        # attention families; SSM/hybrid are naturally O(1)-state
        cfg = cfg.replace(attn=dataclasses.replace(cfg.attn,
                                                   window=LONG_CTX_WINDOW))
    par = make_parallel_config(mesh, shape, schedule=schedule, remat=remat)
    rt = Runtime(mesh=mesh, par=par, impl=impl, latent_ring=latent_ring)
    model = build_model(cfg, rt)

    p_struct = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_sh = param_shardings(p_struct, mesh, par)
    batch_struct, batch_spec = input_specs(cfg, shape, par, mesh)
    batch_sh = compat.tree_map(lambda s: NamedSharding(mesh, s), batch_spec,
                            is_leaf=lambda x: isinstance(x, P))

    if shape.kind == "train":
        tc = TrainConfig()
        opt_struct = jax.eval_shape(adamw.init, p_struct)
        opt_sh = adamw.AdamWState(step=NamedSharding(mesh, P()), m=p_sh,
                                  v=compat.tree_map(lambda s: s, p_sh))
        step = make_train_step(model, tc)
        args = (p_struct, opt_struct, batch_struct)
        shardings = (p_sh, opt_sh, batch_sh)
    elif shape.kind == "prefill":
        step = lambda p, b: model.prefill(p, b)[0]
        args = (p_struct, batch_struct)
        shardings = (p_sh, batch_sh)
    else:  # decode
        cache_struct = batch_struct.pop("cache")
        cache_sh = batch_sh.pop("cache")
        step = lambda p, c, b: model.decode(p, c, b)
        args = (p_struct, cache_struct, batch_struct)
        shardings = (p_sh, cache_sh, batch_sh)
    return cfg, shape, step, args, shardings


def _knob_points(cfg):
    """Scan trip-count knobs per arch family for cost extrapolation.

    XLA's cost_analysis counts a ``while`` (scan) body ONCE, so FLOPs /
    bytes / collective counts of an L-layer scanned model are reported as
    if L=1. Layers are homogeneous, so every cost is an affine function of
    the scan trip counts; we compile 2–3 reduced-depth variants, fit the
    affine model exactly, and evaluate it at the true depth. The full-depth
    compile is still performed for memory_analysis + compile success.

    Returns (dims, points, builder): ``dims`` the true knob values, each
    point a knob tuple, ``builder(knobs) -> cfg``.
    """
    at = cfg.arch_type
    if at == "moe":
        nd = cfg.moe.n_dense_layers
        dims = (nd, cfg.n_layers - nd)
        pts = [(2, 2), (3, 2), (2, 3)]

        def build(k):
            return cfg.replace(
                n_layers=k[0] + k[1],
                moe=dataclasses.replace(cfg.moe, n_dense_layers=k[0]))
        return dims, pts, build
    if at == "hybrid":
        period = cfg.hybrid_period
        G = cfg.n_layers // period
        dims = (G, G * period)           # cost = o + G·c_shared + GP·c_ssm
        pts = [(2, 4), (3, 6), (2, 6)]   # (G, G·period) with period 2, 2, 3

        def build(k):
            g, gp = k
            return cfg.replace(n_layers=gp, hybrid_period=gp // g)
        return dims, pts, build
    if at == "audio":
        dims = (cfg.n_enc_layers, cfg.n_layers)
        pts = [(2, 2), (3, 2), (2, 3)]

        def build(k):
            return cfg.replace(n_enc_layers=k[0], n_layers=k[1])
        return dims, pts, build
    dims = (cfg.n_layers,)
    pts = [(2,), (3,)]

    def build(k):
        return cfg.replace(n_layers=k[0])
    return dims, pts, build


def _measure(cfg, shape, mesh, schedule, remat, impl="ref",
             latent_ring=False):
    """(flops, bytes, collective_bytes, hop_bytes) for one concrete cfg,
    compiled with UNROLLED layer scans so cost_analysis sees every layer.
    ``impl="null"`` swaps the attention math for an O(T) stub (collectives
    and all surrounding ops intact) to isolate the kernel's contribution."""
    from repro.models.transformer import set_scan_unroll
    set_scan_unroll(True)
    try:
        return _measure_inner(cfg, shape, mesh, schedule, remat, impl,
                              latent_ring)
    finally:
        set_scan_unroll(False)


def _measure_inner(cfg, shape, mesh, schedule, remat, impl,
                   latent_ring=False):
    par = make_parallel_config(mesh, shape, schedule=schedule, remat=remat)
    rt = Runtime(mesh=mesh, par=par, impl=impl, latent_ring=latent_ring)
    model = build_model(cfg, rt)
    p_struct = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_sh = param_shardings(p_struct, mesh, par)
    batch_struct, batch_spec = input_specs(cfg, shape, par, mesh)
    batch_sh = compat.tree_map(lambda s: NamedSharding(mesh, s), batch_spec,
                            is_leaf=lambda x: isinstance(x, P))
    if shape.kind == "train":
        step = make_train_step(model, TrainConfig())
        opt_struct = jax.eval_shape(adamw.init, p_struct)
        opt_sh = adamw.AdamWState(step=NamedSharding(mesh, P()), m=p_sh,
                                  v=compat.tree_map(lambda s: s, p_sh))
        args, shd = (p_struct, opt_struct, batch_struct), \
            (p_sh, opt_sh, batch_sh)
    elif shape.kind == "prefill":
        step = lambda p, b: model.prefill(p, b)[0]
        args, shd = (p_struct, batch_struct), (p_sh, batch_sh)
    else:
        cache_struct = batch_struct.pop("cache")
        cache_sh = batch_sh.pop("cache")
        step = lambda p, c, b: model.decode(p, c, b)
        args, shd = (p_struct, cache_struct, batch_struct), \
            (p_sh, cache_sh, batch_sh)
    compiled = jax.jit(step, in_shardings=shd).lower(*args).compile()
    cost = compat.cost_analysis(compiled)
    coll = R.collective_stats(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            coll.total_bytes, coll.hop_weighted_bytes, coll)


def extrapolate_costs(cfg, shape, mesh, schedule, remat, impl="ref",
                      latent_ring=False):
    """Affine fit of (flops, bytes, coll, hop) over the scan knobs."""
    import numpy as np
    dims, pts, build = _knob_points(cfg)
    rows, ys = [], []
    last_coll = None
    for k in pts:
        f, b, c, h, coll = _measure(build(k), shape, mesh, schedule, remat,
                                    impl, latent_ring)
        rows.append([1.0] + list(k))
        ys.append([f, b, c, h])
        last_coll = coll
    A = np.array(rows)
    Y = np.array(ys)
    coef, *_ = np.linalg.lstsq(A, Y, rcond=None)
    target = np.array([1.0] + list(dims))
    f, b, c, h = (target @ coef).tolist()
    return {"flops": max(f, 0.0), "bytes": max(b, 0.0),
            "coll_bytes": max(c, 0.0), "hop_bytes": max(h, 0.0),
            "per_knob": coef.tolist(), "knob_dims": list(dims),
            "coll_kinds_at_smallest": last_coll.bytes_by_kind}


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            schedule="balanced", remat="remat_aware",
            latent_ring=False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    cfg, shape, step, args, shardings = prepare(
        arch, shape_name, mesh, schedule=schedule, remat=remat,
        latent_ring=latent_ring)
    t0 = time.time()
    lowered = jax.jit(step, in_shardings=shardings).lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    mem = compiled.memory_analysis()
    cost = compat.cost_analysis(compiled)
    coll = R.collective_stats(compiled.as_text())
    if multi_pod:
        # the multi-pod pass proves the 512-chip sharding lowers+compiles
        # and reports memory; the roofline table is single-pod (§Roofline)
        return {
            "arch": arch, "shape": shape_name, "schedule": schedule,
            "remat": remat, "multi_pod": True, "chips": chips,
            "kind": shape.kind, "lower_s": round(t1 - t0, 2),
            "compile_s": round(t2 - t1, 2),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "peak_device_bytes": (mem.argument_size_in_bytes
                                      + mem.temp_size_in_bytes
                                      + mem.output_size_in_bytes
                                      - mem.alias_size_in_bytes),
            },
            "collective_op_counts_scan_body_once": coll.op_counts,
            "compiled_ok": True,
        }
    # scan-aware extrapolated costs (see _knob_points). NOTE: cfg here
    # already carries the long_500k window override from prepare().
    ext = extrapolate_costs(cfg, shape, mesh, schedule, remat, impl="ref",
                            latent_ring=latent_ring)
    flops = ext["flops"]
    bytes_acc = ext["bytes"]
    # kernel-adjusted terms: null-attention measurement + analytic Pallas
    # kernel costs (the ref path materializes O(T²) scores on CPU, which a
    # TPU flash kernel never writes to HBM — see roofline.py)
    par = make_parallel_config(mesh, shape, schedule=schedule, remat=remat)
    seq_shards = 1
    for ax in par.seq_axes:
        seq_shards *= dict(zip(mesh.axis_names, mesh.devices.shape))[ax]
    batch_shards = 1
    for ax in par.batch_axes:
        batch_shards *= dict(zip(mesh.axis_names, mesh.devices.shape))[ax]
    if cfg.uses_attention:
        ext_null = extrapolate_costs(cfg, shape, mesh, schedule, remat,
                                     impl="null", latent_ring=latent_ring)
        an_f, an_b = R.attention_analytic(cfg, shape, seq_shards=seq_shards,
                                          batch_shards=batch_shards)
        adj_flops = ext_null["flops"] + an_f
        adj_bytes = ext_null["bytes"] + an_b
        adj_coll = ext_null["coll_bytes"]
    else:
        an_f = an_b = 0.0
        adj_flops, adj_bytes, adj_coll = flops, bytes_acc, ext["coll_bytes"]
    mf = R.model_flops(cfg, shape, chips=chips)
    terms = R.roofline_terms(flops, bytes_acc, ext["coll_bytes"])
    terms_adj = R.roofline_terms(adj_flops, adj_bytes, adj_coll)
    rec = {
        "arch": arch, "shape": shape_name, "schedule": schedule,
        "remat": remat, "multi_pod": multi_pod, "chips": chips,
        "kind": shape.kind,
        "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_device_bytes": (mem.argument_size_in_bytes
                                  + mem.temp_size_in_bytes
                                  + mem.output_size_in_bytes
                                  - mem.alias_size_in_bytes),
        },
        "hlo_flops": flops,
        "hlo_bytes": bytes_acc,
        "hlo_flops_scan_body_once": float(cost.get("flops", 0.0)),
        "collectives": {
            "total_bytes": ext["coll_bytes"],
            "hop_weighted_bytes": ext["hop_bytes"],
            "by_kind_scan_body_once": coll.bytes_by_kind,
            "op_counts_scan_body_once": coll.op_counts,
        },
        "extrapolation": {"knob_dims": ext["knob_dims"],
                          "per_knob_coeffs": ext["per_knob"]},
        "model_flops_per_chip": mf,
        "useful_flops_ratio": (mf / flops) if flops else None,
        "attention_analytic": {"flops": an_f, "bytes": an_b},
        "roofline_as_lowered": terms,
        "roofline": terms_adj,
        "adjusted": {"flops": adj_flops, "bytes": adj_bytes,
                     "coll_bytes": adj_coll,
                     "useful_flops_ratio": (mf / adj_flops)
                     if adj_flops else None},
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS + ("llama-7b", "llama-gqa",
                                                  "llama-33h", "llama-16h"))
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--schedule", default="balanced",
                    choices=("balanced", "ring", "rsa", "zigzag",
                             "ulysses"))
    ap.add_argument("--remat", default="remat_aware",
                    choices=("remat_aware", "hf", "none"))
    ap.add_argument("--latent-ring", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape) in subprocesses")
    ap.add_argument("--results-dir", default="results/dryrun")
    args = ap.parse_args(argv)

    if args.all:
        return run_all(args)

    rec = run_one(args.arch, args.shape, multi_pod=args.multi_pod,
                  schedule=args.schedule, remat=args.remat,
                  latent_ring=args.latent_ring)
    js = json.dumps(rec, indent=1)
    print(js)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(js)
    return 0


def run_all(args):
    os.makedirs(args.results_dir, exist_ok=True)
    fails = []
    for multi_pod in (False, True):
        for arch in ARCH_IDS:
            for shape in SHAPES:
                tag = f"{'pod2' if multi_pod else 'pod1'}_{arch}_{shape}"
                out = os.path.join(args.results_dir, tag + ".json")
                if os.path.exists(out):
                    print(f"[skip] {tag}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--out", out,
                       "--schedule", args.schedule, "--remat", args.remat]
                if multi_pod:
                    cmd.append("--multi-pod")
                print(f"[run ] {tag}", flush=True)
                r = subprocess.run(cmd, capture_output=True, text=True)
                if r.returncode != 0:
                    fails.append(tag)
                    print(f"[FAIL] {tag}\n{r.stderr[-2000:]}")
    print(f"done; {len(fails)} failures: {fails}")
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
