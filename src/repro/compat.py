"""JAX version-compatibility layer.

Every API the repo uses that has moved or changed shape across JAX releases
is funneled through this module, so call sites never touch
version-conditional code.

Supported JAX versions (the compat policy, see README §Compat):

* ``>= 0.4.35, < 0.5``  — ``shard_map`` lives in ``jax.experimental``
  (kwarg ``check_rep``), ``Compiled.cost_analysis()`` returns a *list* of
  per-module dicts, Pallas-TPU compiler params are ``TPUCompilerParams``.
* ``>= 0.5``            — ``jax.shard_map`` is public (kwarg ``check_vma``
  from 0.6), ``cost_analysis()`` returns a single dict,
  ``pltpu.CompilerParams``.

Exports:
  shard_map(f, mesh=..., in_specs=..., out_specs=..., check_vma=False)
  tree_map(f, *trees, is_leaf=None)
  cost_analysis(compiled) -> dict        (normalized; {} when unavailable)
  pallas_tpu_compiler_params(dimension_semantics=...) -> params object
  jax_version -> tuple[int, int, int]
"""
from __future__ import annotations

import re
from typing import Any, Callable, Dict, Optional

import jax

__all__ = ["jax_version", "shard_map", "tree_map", "cost_analysis",
           "pallas_tpu_compiler_params"]


def _parse_version(v: str):
    return tuple(int(x) for x in re.findall(r"\d+", v)[:3])


jax_version = _parse_version(jax.__version__)


# ---------------------------------------------------------------- shard_map

if hasattr(jax, "shard_map"):                        # jax >= 0.5
    _shard_map_impl = jax.shard_map
    _REP_KWARG = "check_vma" if jax_version >= (0, 6, 0) else "check_rep"
else:                                                # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl
    _REP_KWARG = "check_rep"


def shard_map(f: Callable, *, mesh, in_specs, out_specs,
              check_vma: bool = True, **kw) -> Callable:
    """Version-stable ``shard_map``. The replication-check flag is accepted
    under its modern name ``check_vma`` and translated to whatever the
    installed JAX calls it (``check_rep`` before 0.6)."""
    kw[_REP_KWARG] = check_vma
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kw)


# ---------------------------------------------------------------- axis_size

if hasattr(jax.lax, "axis_size"):
    axis_size = jax.lax.axis_size
else:
    def axis_size(axis_name) -> int:
        """Static size of a mapped mesh axis, from inside shard_map.
        JAX < 0.4.38 has no ``lax.axis_size``; the frame size is recovered
        from ``psum(1)``, which the tracer resolves to a static int."""
        return jax.lax.psum(1, axis_name)


# ----------------------------------------------------------------- tree_map

try:
    tree_map = jax.tree.map                          # jax >= 0.4.25
except AttributeError:                               # pragma: no cover
    tree_map = jax.tree_util.tree_map


# ------------------------------------------------------------ cost_analysis

def cost_analysis(compiled) -> Dict[str, float]:
    """Normalized ``Compiled.cost_analysis()``.

    JAX 0.4.x returns a list with one properties-dict per compiled module;
    newer versions return the dict directly; some backends return ``None``.
    Always returns a (possibly empty) dict keyed like XLA's properties
    ("flops", "bytes accessed", ...). Multi-module lists are summed per key
    so FLOP accounting stays total."""
    ca = compiled.cost_analysis()
    if ca is None:
        return {}
    if isinstance(ca, dict):
        return dict(ca)
    # list of per-module dicts (0.4.x); usually length 1
    out: Dict[str, float] = {}
    for mod in ca:
        for k, val in mod.items():
            if isinstance(val, (int, float)):
                out[k] = out.get(k, 0.0) + float(val)
            else:                                    # pragma: no cover
                out.setdefault(k, val)
    return out


# ------------------------------------------- Pallas TPU compiler parameters

def pallas_tpu_compiler_params(**kw) -> Any:
    """``pltpu.CompilerParams`` (new name) / ``TPUCompilerParams`` (0.4.x)."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or \
        getattr(pltpu, "TPUCompilerParams")
    return cls(**kw)
