"""Three-term roofline from a compiled dry-run artifact.

    compute_s    = HLO_FLOPs / peak_FLOPs            (per chip)
    memory_s     = HLO_bytes / HBM_bw                (per chip)
    collective_s = collective_link_bytes / ICI_bw    (per chip)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (the SPMD
module is the per-device program, so these are already per chip).
Collective bytes are parsed from the optimized HLO text: for each
collective op we estimate the *per-device link traffic* from the result
shape and replica-group size (ring-algorithm estimates):

    collective-permute : R            (one send + one recv of the result)
    all-gather         : R·(n−1)/n    (R = gathered result)
    all-reduce         : 2·R·(n−1)/n
    reduce-scatter     : R·(n−1)      (R = scattered result; input = n·R)
    all-to-all         : R·(n−1)/n

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
Ring ``ppermute`` steps in DISTFLASHATTN are neighbor exchanges (1 hop);
the balanced schedule's distance-t result send costs t hops on a physical
ring — we report the 1-hop number and note the worst-case hop multiplier
separately (hop_weighted uses the source-target distance when available).
"""
from __future__ import annotations

import re
from dataclasses import dataclass

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+?))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9,]+)\}")
_PAIRS_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)+)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: dict
    total_bytes: float
    hop_weighted_bytes: float
    op_counts: dict


def collective_stats(hlo_text: str) -> CollectiveStats:
    by_kind: dict = {}
    counts: dict = {}
    hopw = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        rtype, kind = m.group(1), m.group(2)
        r = _shape_bytes(rtype)
        if kind in ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all"):
            g = _GROUPS_RE.search(line)
            n = len(g.group(1).split(",")) if g else 2
        else:
            n = 2
        if kind == "collective-permute":
            moved = r
            pm = _PAIRS_RE.search(line)
            if pm:
                pairs = [tuple(map(int, p.split(",")))
                         for p in pm.group(1).strip("{}").split("},{")]
                dmax = max(abs(a - b) for a, b in pairs) if pairs else 1
                hopw += r * dmax
            else:
                hopw += r
        elif kind == "all-gather":
            moved = r * (n - 1) / max(n, 1)
            hopw += moved
        elif kind == "all-reduce":
            moved = 2 * r * (n - 1) / max(n, 1)
            hopw += moved
        elif kind == "reduce-scatter":
            moved = r * (n - 1)
            hopw += moved
        else:  # all-to-all
            moved = r * (n - 1) / max(n, 1)
            hopw += moved
        by_kind[kind] = by_kind.get(kind, 0.0) + moved
        counts[kind] = counts.get(kind, 0) + 1
    return CollectiveStats(by_kind, sum(by_kind.values()), hopw, counts)


def model_flops(cfg, shape, *, chips: int) -> float:
    """MODEL_FLOPS per chip: 6·N_active·tokens (train) / 2·N·tokens
    (prefill) / 2·N·batch (decode, one token)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tok = shape.global_batch * shape.seq_len
        total = 6.0 * n * tok
    elif shape.kind == "prefill":
        tok = shape.global_batch * shape.seq_len
        total = 2.0 * n * tok
    else:
        total = 2.0 * n * shape.global_batch
    return total / chips


def a2a_bytes(nbytes: float, k: int) -> float:
    """Per-device link bytes of a tiled all-to-all over a k-device axis:
    each device keeps 1/k of its payload local and ships the rest —
    factor (k − 1)/k.  Used by core/schedule.plan2d_cost for the head-
    scatter traffic of 2D (seq×head) factorizations."""
    return nbytes * (k - 1) / max(k, 1)


def allgather_bytes(nbytes: float, k: int) -> float:
    """Per-device link bytes of a tiled all-gather over a k-device axis
    (ring algorithm): every device receives the other k − 1 shards —
    factor (k − 1).  ``nbytes`` is one device's shard."""
    return nbytes * (k - 1)


def schedule_cost_terms(*, flops, comm_bytes):
    """Two-term time model for a static schedule-plan cost
    (core/schedule.PlanCost): kernel FLOPs against peak compute, hop-
    weighted ring-link bytes against per-link ICI bandwidth.  This is what
    ``DistAttnSpec(schedule="auto")`` ranks candidate schedules by — HBM
    traffic is schedule-invariant at this granularity (every schedule
    streams the same chunks) so the memory term is omitted."""
    ct = flops / PEAK_FLOPS
    kt = comm_bytes / ICI_BW
    return {"compute_s": ct, "collective_s": kt,
            "bound": "compute" if ct >= kt else "collective",
            "step_s_lower_bound": max(ct, kt)}


def roofline_terms(flops, bytes_accessed, coll_bytes):
    ct = flops / PEAK_FLOPS
    mt = bytes_accessed / HBM_BW
    kt = coll_bytes / ICI_BW
    dom = max((ct, "compute"), (mt, "memory"), (kt, "collective"))
    return {"compute_s": ct, "memory_s": mt, "collective_s": kt,
            "bound": dom[1],
            "step_s_lower_bound": max(ct, mt, kt)}


# --------------------------------------------------------------------------
# Analytic attention-kernel costs (per chip).
#
# The CPU dry-run lowers the pure-jnp reference attention, which materializes
# O(T²) score tiles — faithful in FLOPs but wildly pessimistic in HBM bytes
# vs. the Pallas kernel (which keeps tiles in VMEM). For the kernel-adjusted
# roofline we measure the model with a null-attention stub (all surrounding
# ops + ring collectives intact) and add the kernel's ideal costs computed
# here from first principles.
# --------------------------------------------------------------------------

def _site(flops_fwd, flops_bwd, bytes_fwd, bytes_bwd, train):
    if train:
        return flops_fwd + flops_bwd, bytes_fwd + bytes_bwd
    return flops_fwd, bytes_fwd


def _self_attn_site(*, B_loc, T_glob, P, H, hd_qk, hd_v, Hkv, window,
                    causal, train, bpe=2):
    """One sequence-sharded self-attention site, per chip."""
    if causal:
        w = min(window, T_glob) if window else T_glob
        pairs = B_loc * T_glob * (w / 2 if not window else w) / P
        steps = (P // 2 + 1) if not window else \
            min(P, max(1, -(-w // max(T_glob // P, 1))) + 1)
    else:
        pairs = B_loc * T_glob * T_glob / P
        steps = P
    T_loc = T_glob // P
    f_fwd = 2 * pairs * H * (hd_qk + hd_v)
    f_bwd = 2 * pairs * H * (3 * hd_qk + 2 * hd_v)
    kv_chunk = B_loc * T_loc * Hkv * (hd_qk + hd_v) * bpe
    q_bytes = B_loc * T_loc * H * hd_qk * bpe
    o_bytes = B_loc * T_loc * H * hd_v * bpe
    b_fwd = q_bytes + o_bytes + steps * kv_chunk
    b_bwd = 2 * q_bytes + 2 * o_bytes + 2 * steps * kv_chunk
    return _site(f_fwd, f_bwd, b_fwd, b_bwd, train)


def _decode_attn_site(*, B, S, seq_shards, H, hd_qk, hd_v, Hkv, window,
                      bpe=2):
    w = min(window, S) if window else S
    pairs = B * w / seq_shards
    flops = 2 * pairs * H * (hd_qk + hd_v)
    bytes_ = B * (w / seq_shards) * Hkv * (hd_qk + hd_v) * bpe
    return flops, bytes_


def paged_decode_terms(cfg, *, batch, mean_len, block_size, bpe=2):
    """Roofline terms of ONE paged flash-decode step (all layers) at mean
    in-flight context length ``mean_len``: kernel FLOPs, HBM bytes of the
    block-table gather (KV streamed in whole blocks — the read-side cost of
    paging is the partial last block, reported as ``block_waste``), plus
    the table/q/o traffic.  Feeds the serving bench's predicted tok/s bound
    next to its measured numbers."""
    a = cfg.attn
    if a is None:
        return None
    is_mla = a.is_mla
    if is_mla:
        hd_qk = a.kv_lora_rank + a.qk_rope_head_dim
        hd_v = a.kv_lora_rank
        Hkv = 1
    else:
        hd_qk = hd_v = a.head_dim
        Hkv = a.n_kv_heads
    H = a.n_heads
    L_ = cfg.n_layers
    w = min(a.window, mean_len) if a.window else mean_len
    blocks = -(-w // block_size)
    toks_read = blocks * block_size
    flops = L_ * 2 * batch * w * H * (hd_qk + hd_v)
    kv_bytes = L_ * batch * toks_read * Hkv * (hd_qk + hd_v) * bpe
    table_bytes = L_ * batch * blocks * 4
    qo_bytes = L_ * batch * H * (hd_qk + hd_v) * bpe
    terms = roofline_terms(flops, kv_bytes + table_bytes + qo_bytes, 0.0)
    terms["block_waste"] = toks_read / max(w, 1) - 1.0
    terms["tok_s_bound"] = batch / max(terms["step_s_lower_bound"], 1e-12)
    return terms


def speculative_terms(cfg, *, batch, mean_len, depth, acceptance,
                      block_size, bpe=2, draft_cfg=None):
    """Expected-throughput model of speculative decoding at draft depth
    ``depth`` (= K proposals verified per step) and per-token acceptance
    rate ``acceptance`` (= a).

    With position-independent acceptance the number of tokens committed
    per verify step is ``1 + #accepted prefix`` — a truncated geometric —
    so the expectation is the standard speculative-decoding series

        E[tokens/step] = (1 - a^(K+1)) / (1 - a)      (K+1 at a = 1)

    The verify step itself prices like a paged decode step with K+1 query
    rows per request: attention FLOPs scale with the extra rows while the
    streamed KV bytes barely move (the K+1 rows share one block-table
    gather), which is exactly why verification is cheap in the
    memory-bound decode regime.  When ``draft_cfg`` is given, the draft's
    K single-token decode steps are added to the step lower bound.
    Returns the vanilla terms, the verify terms, E[tokens/step], and the
    speculative / vanilla tokens-per-second bound ratio."""
    if not 0.0 <= acceptance <= 1.0:
        raise ValueError("acceptance must be in [0, 1]")
    if depth < 0:
        raise ValueError("depth must be >= 0")
    K = int(depth)
    a = float(acceptance)
    exp_tokens = (K + 1.0 if a >= 1.0
                  else (1.0 - a ** (K + 1)) / (1.0 - a))
    vanilla = paged_decode_terms(cfg, batch=batch, mean_len=mean_len,
                                 block_size=block_size, bpe=bpe)
    if vanilla is None:
        return None
    # verify = decode with K+1 query rows: q/o traffic and pair count scale
    # by (K+1); the KV stream is the same blocks read once
    at = cfg.attn
    if at.is_mla:
        hd_qk, hd_v, Hkv = (at.kv_lora_rank + at.qk_rope_head_dim,
                            at.kv_lora_rank, 1)
    else:
        hd_qk = hd_v = at.head_dim
        Hkv = at.n_kv_heads
    w = min(at.window, mean_len) if at.window else mean_len
    blocks = -(-w // block_size)
    toks_read = blocks * block_size
    L_ = cfg.n_layers
    flops = L_ * 2 * batch * (K + 1) * w * at.n_heads * (hd_qk + hd_v)
    kv_bytes = L_ * batch * toks_read * Hkv * (hd_qk + hd_v) * bpe
    qo_bytes = L_ * batch * (K + 1) * at.n_heads * (hd_qk + hd_v) * bpe
    table_bytes = L_ * batch * blocks * 4
    verify = roofline_terms(flops, kv_bytes + qo_bytes + table_bytes, 0.0)
    step_lb = verify["step_s_lower_bound"]
    draft_lb = 0.0
    if draft_cfg is not None and K > 0:
        d = paged_decode_terms(draft_cfg, batch=batch, mean_len=mean_len,
                               block_size=block_size, bpe=bpe)
        if d is not None:
            draft_lb = K * d["step_s_lower_bound"]
            step_lb += draft_lb
    tok_s_spec = batch * exp_tokens / max(step_lb, 1e-12)
    return {
        "depth": K,
        "acceptance": a,
        "expected_tokens_per_step": exp_tokens,
        "vanilla": vanilla,
        "verify": verify,
        "draft_s_lower_bound": draft_lb,
        "step_s_lower_bound": step_lb,
        "tok_s_bound": tok_s_spec,
        "speedup_bound": tok_s_spec / max(vanilla["tok_s_bound"], 1e-12),
    }


def prefix_cache_terms(cfg, *, prompt_len, hit_rate, chunk_tokens=0,
                       bpe=2):
    """Analytic prefill cost of ONE request under the content-addressed
    prefix cache: a fraction ``hit_rate`` of the prompt's KV is shared
    from the pool instead of recomputed, so the cold-vs-cached TTFT lower
    bounds differ by the skipped prefill work (model forward FLOPs ∝
    uncached tokens; attention FLOPs quadratic in context but only over
    uncached *query* rows, which still attend the cached KV).  Chunked
    prefill (``chunk_tokens``) spreads the same work over
    ``ceil(uncached / chunk)`` engine steps — it bounds per-step latency
    without changing the total.  Feeds the serving bench's shared-prefix
    A/B next to its measured TTFTs."""
    n_params = cfg.active_param_count()
    a = cfg.attn
    H = a.n_heads if a else 0
    hd = ((a.kv_lora_rank + a.qk_rope_head_dim) if a and a.is_mla
          else (a.head_dim if a else 0))

    def prefill_cost(n_cached):
        q = prompt_len - n_cached             # query rows actually run
        flops = 2 * n_params * q              # matmul forward
        if a:                                 # attention: q rows × full ctx
            kv = prompt_len
            flops += cfg.n_layers * 2 * q * kv * H * 2 * hd
        bytes_ = n_params * bpe + q * cfg.d_model * bpe \
            + 2 * kv * (a.n_kv_heads if a and not a.is_mla else 1) * hd * bpe
        return roofline_terms(flops, bytes_, 0.0)

    cold = prefill_cost(0)
    cached = prefill_cost(int(hit_rate * prompt_len))
    n_chunks = (max(1, -(-prompt_len // chunk_tokens)) if chunk_tokens
                else 1)
    saved = 1 - (cached["compute_s"] / cold["compute_s"]
                 if cold["compute_s"] else 0.0)
    return {
        "ttft_s_lower_bound_cold": cold["step_s_lower_bound"],
        "ttft_s_lower_bound_cached": cached["step_s_lower_bound"],
        "prefill_flops_saved_frac": saved,
        "n_chunks_cold": n_chunks,
        "blocks_saved_frac": hit_rate,        # shared, not re-stored
    }


def attention_analytic(cfg, shape, *, seq_shards, batch_shards):
    """Total analytic kernel (flops, bytes) per chip for all attention
    sites of one (arch × shape)."""
    a = cfg.attn
    if a is None:
        return 0.0, 0.0
    train = shape.kind == "train"
    B_loc = max(shape.global_batch // batch_shards, 1)
    P = seq_shards
    win = a.window
    is_mla = a.is_mla
    hd_qk = (a.qk_nope_head_dim + a.qk_rope_head_dim) if is_mla else a.head_dim
    hd_v = (a.v_head_dim or a.head_dim) if is_mla else a.head_dim
    Hkv = a.n_heads if is_mla else a.n_kv_heads
    fl = by = 0.0

    if shape.kind in ("train", "prefill"):
        T = shape.seq_len
        n_self = cfg.n_layers + (cfg.mtp_depth or 0)
        if cfg.arch_type == "hybrid":
            n_self = cfg.n_layers // cfg.hybrid_period
        if cfg.arch_type == "ssm":
            n_self = 0
        if n_self:
            f, b = _self_attn_site(B_loc=B_loc, T_glob=T, P=P, H=a.n_heads,
                                   hd_qk=hd_qk, hd_v=hd_v, Hkv=Hkv,
                                   window=win, causal=True, train=train)
            fl += n_self * f
            by += n_self * b
        if cfg.arch_type == "audio":
            F = cfg.n_audio_frames
            # encoder self (bidirectional, replicated over the seq axis)
            f, b = _self_attn_site(B_loc=B_loc, T_glob=F, P=1, H=a.n_heads,
                                   hd_qk=hd_qk, hd_v=hd_v, Hkv=a.n_heads,
                                   window=0, causal=False, train=train)
            fl += cfg.n_enc_layers * f
            by += cfg.n_enc_layers * b
            # decoder cross-attention: q sharded, enc kv replicated
            pairs = B_loc * (T // P) * F
            f_fwd = 2 * pairs * a.n_heads * 2 * a.head_dim
            b_fwd = B_loc * F * a.n_heads * a.head_dim * 2 * 2
            fl += cfg.n_layers * (f_fwd * (2.5 if train else 1.0))
            by += cfg.n_layers * (b_fwd * (2.0 if train else 1.0))
        return fl, by

    # decode
    S = shape.seq_len
    B = shape.global_batch
    n_self = cfg.n_layers + (cfg.mtp_depth or 0)
    if cfg.arch_type == "hybrid":
        n_self = cfg.n_layers // cfg.hybrid_period
    if cfg.arch_type == "ssm":
        n_self = 0
    if is_mla:  # absorbed-latent decode: single 576-dim latent head cache
        hd_qk_d = a.kv_lora_rank + a.qk_rope_head_dim
        hd_v_d = a.kv_lora_rank
        f, b = _decode_attn_site(B=B, S=S, seq_shards=seq_shards * batch_shards
                                 if shape.global_batch == 1 else seq_shards,
                                 H=a.n_heads, hd_qk=hd_qk_d, hd_v=hd_v_d,
                                 Hkv=1, window=win)
    else:
        f, b = _decode_attn_site(B=B, S=S, seq_shards=seq_shards,
                                 H=a.n_heads, hd_qk=hd_qk, hd_v=hd_v,
                                 Hkv=Hkv, window=win)
    fl += n_self * f
    by += n_self * b
    if cfg.arch_type == "audio":
        F = cfg.n_audio_frames
        fl += cfg.n_layers * 2 * B * F * a.n_heads * 2 * a.head_dim
        by += cfg.n_layers * B * F * a.n_heads * a.head_dim * 2 * 2
    return fl, by
