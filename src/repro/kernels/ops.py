"""jit-ready wrappers around the Pallas flash-attention kernels.

Public layout is (B, T, H, D) (matching the model code); the kernels use
(B, H, T, D). Masking is a static :class:`repro.core.mask.MaskSpec`
(hashable — it rides through jit as a static argument); document segment
IDs are (B, T) int32 operands. The legacy ``causal``/``rel_offset``/
``window`` kwargs still build the equivalent spec. Block sizes default to
128 (MXU-aligned) and shrink to the chunk size for small test shapes.
``prune`` (default on) enables the static block-sparse grid pruning;
``prune=False`` forces the dense ``nq × nk`` sweep (benchmark baseline /
differential testing).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.mask import MaskSpec, as_spec
from repro.kernels import flash_attention as fa


def _to_bhtd(x):
    return jnp.transpose(x, (0, 2, 1, 3))


@partial(jax.jit, static_argnames=("mask", "causal", "rel_offset", "window",
                                   "scale", "block_q", "block_kv",
                                   "interpret", "prune"))
def flash_fwd(q, k, v, *, mask=None, causal=False, rel_offset=0, window=0,
              scale=None, block_q=128, block_kv=128, interpret=False,
              prune=True, q_segments=None, kv_segments=None):
    """(B,T,H,D) partial attention -> (o (B,T,H,D), lse (B,T,H))."""
    mask = as_spec(mask, causal=causal, window=window,
                   rel_offset=rel_offset)
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    o, lse = fa.flash_fwd_bhtd(
        _to_bhtd(q), _to_bhtd(k), _to_bhtd(v), scale=scale, mask=mask,
        block_q=block_q, block_kv=block_kv, interpret=interpret, prune=prune,
        q_segments=q_segments, kv_segments=kv_segments)
    return _to_bhtd(o), jnp.transpose(lse, (0, 2, 1))


@partial(jax.jit, static_argnames=("mask", "causal", "rel_offset", "window",
                                   "scale", "block_q", "block_kv",
                                   "interpret", "prune"))
def flash_bwd(q, k, v, o, lse, do, *, mask=None, causal=False, rel_offset=0,
              window=0, scale=None, block_q=128, block_kv=128,
              interpret=False, delta=None, prune=True, q_segments=None,
              kv_segments=None):
    """Backward from saved (o, lse). Returns (dq, dk, dv)."""
    mask = as_spec(mask, causal=causal, window=window,
                   rel_offset=rel_offset)
    if scale is None:
        scale = 1.0 / (q.shape[-1] ** 0.5)
    dq, dk, dv = fa.flash_bwd_bhtd(
        _to_bhtd(q), _to_bhtd(k), _to_bhtd(v), _to_bhtd(o),
        jnp.transpose(lse, (0, 2, 1)), _to_bhtd(do), scale=scale, mask=mask,
        block_q=block_q, block_kv=block_kv, interpret=interpret,
        delta=None if delta is None else jnp.transpose(delta, (0, 2, 1)),
        prune=prune, q_segments=q_segments, kv_segments=kv_segments)
    return _to_bhtd(dq), _to_bhtd(dk), _to_bhtd(dv)
