"""``chunked-lax`` attention backend: a ``lax.scan``-blocked online-softmax
implementation that needs no Pallas — fast on CPU/GPU, exact everywhere.

The KV sequence is split into ``block_kv``-sized chunks; a scan walks the
chunks carrying the float32 ``(o, lse)`` accumulator and folds each chunk's
partial result in with the FlashAttention-2 rescale (``merge_ref``). Peak
score memory is O(Tq · block_kv) per step instead of the reference
implementation's O(Tq · Tk) — the same blocking the Pallas kernel does in
VMEM, expressed at the XLA level.

Backward mirrors FA2: dq accumulates across the chunk scan while per-chunk
(dk, dv) are emitted as scan outputs and reassembled, all from the saved
``(o, lse)`` — no forward recompute.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.ref import (NEG_INF, chunk_attn_bwd_ref, chunk_attn_ref,
                               merge_ref)

DEFAULT_BLOCK_KV = 128


def _pick_block(Tk: int, block: int) -> int:
    """Largest divisor of Tk that is ≤ block (scan needs equal chunks).
    When Tk has no useful divisor near the target (prime-ish lengths),
    blocking would degenerate into a near-token-level scan — return Tk
    itself so the caller takes the single-block (reference) path."""
    b = min(block, Tk)
    while Tk % b:
        b -= 1
    if b < min(32, Tk):
        return Tk
    return b


def _blocked(x, nb, bc):
    """(B, Tk, H, D) -> (nb, B, bc, H, D) scan-leading chunk layout."""
    B = x.shape[0]
    return x.reshape(B, nb, bc, *x.shape[2:]).swapaxes(0, 1)


def chunked_fwd(q, k, v, *, causal=False, rel_offset=0, window=0, scale=None,
                block_kv=DEFAULT_BLOCK_KV):
    """Partial attention, chunk_attn semantics: returns (o, lse)."""
    B, Tq, Hq, _ = q.shape
    Tk = k.shape[1]
    Dv = v.shape[-1]
    bc = _pick_block(Tk, block_kv)
    nb = Tk // bc
    if nb == 1:
        return chunk_attn_ref(q, k, v, causal=causal, q_offset=rel_offset,
                              kv_offset=0, window=window, scale=scale)
    blocks = (_blocked(k, nb, bc), _blocked(v, nb, bc),
              jnp.arange(nb, dtype=jnp.int32) * bc)

    def body(carry, blk):
        o_acc, l_acc = carry
        kj, vj, off = blk
        o_j, l_j = chunk_attn_ref(q, kj, vj, causal=causal,
                                  q_offset=rel_offset, kv_offset=off,
                                  window=window, scale=scale)
        o_n, l_n = merge_ref(o_acc, l_acc, o_j.astype(jnp.float32), l_j)
        return (o_n, l_n), None

    init = (jnp.zeros((B, Tq, Hq, Dv), jnp.float32),
            jnp.full((B, Tq, Hq), NEG_INF, jnp.float32))
    (o, lse), _ = lax.scan(body, init, blocks)
    return o.astype(q.dtype), lse


def chunked_bwd(q, k, v, o, lse, do, *, causal=False, rel_offset=0, window=0,
                scale=None, delta=None, block_kv=DEFAULT_BLOCK_KV):
    """FA2 backward from saved (o, lse), blocked over KV chunks.
    Returns (dq, dk, dv)."""
    B, Tq, Hq, _ = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    bc = _pick_block(Tk, block_kv)
    nb = Tk // bc
    if delta is None:
        delta = jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32),
                        axis=-1)
    if nb == 1:
        return chunk_attn_bwd_ref(q, k, v, o, lse, do, causal=causal,
                                  q_offset=rel_offset, kv_offset=0,
                                  window=window, scale=scale, delta=delta)
    blocks = (_blocked(k, nb, bc), _blocked(v, nb, bc),
              jnp.arange(nb, dtype=jnp.int32) * bc)

    def body(dq_acc, blk):
        kj, vj, off = blk
        dq_j, dk_j, dv_j = chunk_attn_bwd_ref(
            q, kj, vj, o, lse, do, causal=causal, q_offset=rel_offset,
            kv_offset=off, window=window, scale=scale, delta=delta)
        return dq_acc + dq_j.astype(jnp.float32), (dk_j, dv_j)

    dq, (dk_b, dv_b) = lax.scan(body, jnp.zeros(q.shape, jnp.float32),
                                blocks)
    dk = dk_b.swapaxes(0, 1).reshape(B, Tk, Hkv, -1)
    dv = dv_b.swapaxes(0, 1).reshape(B, Tk, Hkv, -1)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)
