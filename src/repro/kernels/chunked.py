"""``chunked-lax`` attention backend: a ``lax.scan``-blocked online-softmax
implementation that needs no Pallas — fast on CPU/GPU, exact everywhere.

The KV sequence is split into ``block_kv``-sized chunks; a scan walks the
chunks carrying the float32 ``(o, lse)`` accumulator and folds each chunk's
partial result in with the FlashAttention-2 rescale (``merge_ref``). Peak
score memory is O(Tq · block_kv) per step instead of the reference
implementation's O(Tq · Tk) — the same blocking the Pallas kernel does in
VMEM, expressed at the XLA level.

Masking is a :class:`repro.core.mask.MaskSpec`; document segment IDs ride
the scan as per-chunk slices next to K/V. Block-sparse pruning mirrors the
Pallas kernels: the scan only visits the KV chunks inside
``block_sparse.kv_block_bounds`` (the whole query chunk is one q block
here) — including the document-boundary pruning of packed batches — so CPU
CI exercises the identical block-range logic the TPU grid pruning uses.
Statically all-masked requests short-circuit to the empty partial.

Backward mirrors FA2: dq accumulates across the chunk scan while per-chunk
(dk, dv) are emitted as scan outputs and reassembled (zeros for pruned
chunks), all from the saved ``(o, lse)`` — no forward recompute.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.mask import MaskSpec, as_spec, fold_offsets
from repro.kernels.block_sparse import kv_block_bounds
from repro.kernels.block_sparse import pick_block as _pick_block
from repro.kernels.ref import (NEG_INF, chunk_attn_bwd_ref, chunk_attn_ref,
                               merge_ref)

DEFAULT_BLOCK_KV = 128


def _blocked(x, nb, bc):
    """(B, Tk, ...) -> (nb, B, bc, ...) scan-leading chunk layout."""
    B = x.shape[0]
    return x.reshape(B, nb, bc, *x.shape[2:]).swapaxes(0, 1)


def _valid_span(Tq, Tk, bc, mask: MaskSpec, prune):
    """Inclusive (lo, hi) KV-chunk range for the whole query chunk (one
    br=Tq q block) — the same static range logic the Pallas grids use."""
    nb = Tk // bc
    if not (prune and mask.prunable):
        return 0, nb - 1
    return kv_block_bounds(0, br=Tq, bc=bc, nk=nb, mask=mask)


def _seg_chunks(seg, sl, nv, bc):
    if seg is None:
        return None
    return _blocked(jnp.asarray(seg)[:, sl], nv, bc)


def chunked_fwd(q, k, v, *, mask=None, causal=False, rel_offset=0, window=0,
                scale=None, block_kv=DEFAULT_BLOCK_KV, block_q=None,
                prune=True, q_segments=None, kv_segments=None,
                q_offset=0, kv_offset=0):
    """Partial attention, chunk_attn semantics: returns (o, lse).
    ``block_q`` is accepted for tuning-surface uniformity with the Pallas
    backend (queries are not blocked here). ``q_offset``/``kv_offset`` are
    extra position operands (ints fold into the mask; traced values ride
    through to the reference kernel and disable static pruning)."""
    del block_q
    mask = as_spec(mask, causal=causal, window=window,
                   rel_offset=rel_offset)
    mask, q_offset, kv_offset, dyn = fold_offsets(mask, q_offset, kv_offset)
    B, Tq, Hq, _ = q.shape
    Tk = k.shape[1]
    Dv = v.shape[-1]
    bc = _pick_block(Tk, block_kv)
    # traced offsets leave the band location unknown: no static pruning
    lo, hi = _valid_span(Tq, Tk, bc, mask, prune and not dyn)
    if hi < lo:                                  # statically fully masked
        return (jnp.zeros((B, Tq, Hq, Dv), q.dtype),
                jnp.full((B, Tq, Hq), NEG_INF, jnp.float32))
    nv = hi - lo + 1
    if nv == 1:
        return chunk_attn_ref(q, k[:, lo * bc:(lo + 1) * bc],
                              v[:, lo * bc:(lo + 1) * bc], mask=mask,
                              q_offset=q_offset,
                              kv_offset=kv_offset + lo * bc, scale=scale,
                              q_segments=q_segments,
                              kv_segments=None if kv_segments is None else
                              jnp.asarray(kv_segments)[:,
                                                       lo * bc:(lo + 1) * bc])
    sl = slice(lo * bc, (hi + 1) * bc)
    blocks = (_blocked(k[:, sl], nv, bc), _blocked(v[:, sl], nv, bc),
              (lo + jnp.arange(nv, dtype=jnp.int32)) * bc,
              _seg_chunks(kv_segments, sl, nv, bc))

    def body(carry, blk):
        o_acc, l_acc = carry
        kj, vj, off, sj = blk
        o_j, l_j = chunk_attn_ref(q, kj, vj, mask=mask, q_offset=q_offset,
                                  kv_offset=kv_offset + off,
                                  scale=scale, q_segments=q_segments,
                                  kv_segments=sj)
        o_n, l_n = merge_ref(o_acc, l_acc, o_j.astype(jnp.float32), l_j)
        return (o_n, l_n), None

    init = (jnp.zeros((B, Tq, Hq, Dv), jnp.float32),
            jnp.full((B, Tq, Hq), NEG_INF, jnp.float32))
    (o, lse), _ = lax.scan(body, init, blocks)
    return o.astype(q.dtype), lse


def chunked_bwd(q, k, v, o, lse, do, *, mask=None, causal=False,
                rel_offset=0, window=0, scale=None, delta=None,
                block_kv=DEFAULT_BLOCK_KV, block_q=None, prune=True,
                q_segments=None, kv_segments=None, q_offset=0, kv_offset=0):
    """FA2 backward from saved (o, lse), blocked over KV chunks.
    Returns (dq, dk, dv); dk/dv are zeros on statically-masked chunks."""
    del block_q
    mask = as_spec(mask, causal=causal, window=window,
                   rel_offset=rel_offset)
    mask, q_offset, kv_offset, dyn = fold_offsets(mask, q_offset, kv_offset)
    B, Tq, Hq, _ = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    bc = _pick_block(Tk, block_kv)
    lo, hi = _valid_span(Tq, Tk, bc, mask, prune and not dyn)
    if hi < lo:                                  # statically fully masked
        return (jnp.zeros_like(q), jnp.zeros_like(k), jnp.zeros_like(v))
    if delta is None:
        delta = jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32),
                        axis=-1)
    nv = hi - lo + 1
    sl = slice(lo * bc, (hi + 1) * bc)
    if nv == 1:
        dq, dk_s, dv_s = chunk_attn_bwd_ref(
            q, k[:, sl], v[:, sl], o, lse, do, mask=mask, q_offset=q_offset,
            kv_offset=kv_offset + lo * bc,
            scale=scale, delta=delta, q_segments=q_segments,
            kv_segments=None if kv_segments is None else
            jnp.asarray(kv_segments)[:, sl])
        dk = jnp.zeros_like(k).at[:, sl].set(dk_s)
        dv = jnp.zeros_like(v).at[:, sl].set(dv_s)
        return dq, dk, dv
    blocks = (_blocked(k[:, sl], nv, bc), _blocked(v[:, sl], nv, bc),
              (lo + jnp.arange(nv, dtype=jnp.int32)) * bc,
              _seg_chunks(kv_segments, sl, nv, bc))

    def body(dq_acc, blk):
        kj, vj, off, sj = blk
        dq_j, dk_j, dv_j = chunk_attn_bwd_ref(
            q, kj, vj, o, lse, do, mask=mask, q_offset=q_offset,
            kv_offset=kv_offset + off, scale=scale,
            delta=delta, q_segments=q_segments, kv_segments=sj)
        return dq_acc + dq_j.astype(jnp.float32), (dk_j, dv_j)

    dq, (dk_b, dv_b) = lax.scan(body, jnp.zeros(q.shape, jnp.float32),
                                blocks)
    dk_s = dk_b.swapaxes(0, 1).reshape(B, nv * bc, Hkv, -1)
    dv_s = dv_b.swapaxes(0, 1).reshape(B, nv * bc, Hkv, -1)
    dk = jnp.zeros_like(k).at[:, sl].set(dk_s.astype(k.dtype))
    dv = jnp.zeros_like(v).at[:, sl].set(dv_s.astype(v.dtype))
    return dq.astype(q.dtype), dk, dv
