"""Pallas TPU FlashAttention-2 chunk kernels (forward + backward).

TARGET: TPU MXU/VMEM. Layout inside the kernels is (B, H, T, D); blocks are
``(block_q × head_dim)`` / ``(block_kv × head_dim)`` VMEM tiles with 128-
aligned matmul dims (MXU-native). Validated on CPU with ``interpret=True``
against ``ref.py`` (tests/test_kernels.py).

Chunk semantics match ``repro.core.attention.chunk_attn``: partial attention
with a *static* relative offset (see DESIGN.md §2 — in the ring/balanced
schedules every step's mask depends only on the static chunk distance, so no
scalar prefetch is required).

The backward follows FA2: ``delta = rowsum(do ⊙ o)`` precomputed, then a
dq-kernel (grid over q blocks, sequential kv) and a dkv-kernel (grid over kv
blocks, sequential q) recompute ``p = exp(s − lse)`` blockwise from the saved
logsumexp — the kernel-internal rematerialization the paper's checkpointing
strategy is careful not to duplicate at the layer level (§3.3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from repro import compat
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128  # TPU lane width; stat scratch is lane-replicated


def _pos_mask(i, j, br, bc, rel_offset, causal, window):
    """(br, bc) boolean attend-mask for q block i, kv block j (static args
    except the traced program ids i, j)."""
    qp = rel_offset + i * br + jax.lax.broadcasted_iota(jnp.int32, (br, bc), 0)
    kp = j * bc + jax.lax.broadcasted_iota(jnp.int32, (br, bc), 1)
    m = None
    if causal:
        m = kp <= qp
    if window and window > 0:
        w = qp - kp < window
        m = w if m is None else m & w
    return m


# ---------------------------------------------------------------- forward


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                acc_ref, m_ref, l_ref,
                *, scale, causal, rel_offset, window, n_kv):
    i, j = pl.program_id(2), pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (br, d)
    k = k_ref[0, 0].astype(jnp.float32)              # (bc, d)
    v = v_ref[0, 0].astype(jnp.float32)
    br, bc = q.shape[0], k.shape[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (br,bc)
    mask = _pos_mask(i, j, br, bc, rel_offset, causal, window)
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[:, 0]                             # (br,)
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    m_safe = jnp.maximum(m_new, NEG_INF / 2)
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(m_new[:, None] <= NEG_INF / 2, 0.0, p)
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
    l_new = alpha * l_ref[:, 0] + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(p, v)
    m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(j == n_kv - 1)
    def _finalize():
        l = l_ref[:, 0]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)
        lse = jnp.where(l == 0.0, NEG_INF, m_ref[:, 0] + jnp.log(l_safe))
        lse_ref[0, 0] = jnp.broadcast_to(lse[:, None], lse_ref.shape[2:])


def flash_fwd_bhtd(q, k, v, *, scale, causal, rel_offset, window,
                   block_q=128, block_kv=128, interpret=False):
    """q,k: (B,Hq/Hkv,T,Dk); v: (B,Hkv,Tk,Dv) -> o (B,Hq,Tq,Dv), lse.
    Dv may differ from Dk (MLA)."""
    B, Hq, Tq, D = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    Dv = v.shape[3]
    g = Hq // Hkv
    br = min(block_q, Tq)
    bc = min(block_kv, Tk)
    assert Tq % br == 0 and Tk % bc == 0, (Tq, br, Tk, bc)
    nq, nk = Tq // br, Tk // bc
    grid = (B, Hq, nq, nk)

    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, rel_offset=rel_offset,
        window=window, n_kv=nk)
    o, lse_w = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, br, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bc, D), lambda b, h, i, j: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bc, Dv), lambda b, h, i, j: (b, h // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, br, Dv), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, br, LANES), lambda b, h, i, j: (b, h, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hq, Tq, Dv), q.dtype),
            jax.ShapeDtypeStruct((B, Hq, Tq, LANES), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((br, Dv), jnp.float32),
            pltpu.VMEM((br, LANES), jnp.float32),
            pltpu.VMEM((br, LANES), jnp.float32),
        ],
        compiler_params=compat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return o, lse_w[..., 0]


# ---------------------------------------------------------------- backward


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc_ref, *, scale, causal, rel_offset, window, n_kv):
    i, j = pl.program_id(2), pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0][:, 0]                        # (br,)
    delta = delta_ref[0, 0][:, 0]
    br, bc = q.shape[0], k.shape[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    mask = _pos_mask(i, j, br, bc, rel_offset, causal, window)
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)
    p = jnp.where(lse[:, None] <= NEG_INF / 2, 0.0, jnp.exp(s - lse[:, None]))
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
    ds = p * (dp - delta[:, None]) * scale
    acc_ref[...] += jax.lax.dot(ds, k)

    @pl.when(j == n_kv - 1)
    def _finalize():
        dq_ref[0, 0] = acc_ref[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_acc, dv_acc,
                *, scale, causal, rel_offset, window, n_q):
    j, i = pl.program_id(2), pl.program_id(3)        # kv block j, q block i

    @pl.when(i == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0][:, 0]
    delta = delta_ref[0, 0][:, 0]
    br, bc = q.shape[0], k.shape[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    mask = _pos_mask(i, j, br, bc, rel_offset, causal, window)
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)
    p = jnp.where(lse[:, None] <= NEG_INF / 2, 0.0, jnp.exp(s - lse[:, None]))
    dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
    ds = p * (dp - delta[:, None]) * scale
    dk_acc[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())))

    @pl.when(i == n_q - 1)
    def _finalize():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def flash_bwd_bhtd(q, k, v, o, lse, do, *, scale, causal, rel_offset, window,
                   block_q=128, block_kv=128, interpret=False, delta=None):
    """Backward from saved (o, lse). Layout (B,H,T,D). Returns dq, dk, dv
    (dk/dv summed over the GQA group). ``delta`` (B,H,Tq) may be passed
    precomputed (distributed helper path)."""
    B, Hq, Tq, D = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    Dv = v.shape[3]
    g = Hq // Hkv
    br = min(block_q, Tq)
    bc = min(block_kv, Tk)
    nq, nk = Tq // br, Tk // bc

    if delta is None:
        delta = jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32),
                        axis=-1)
    delta = delta.astype(jnp.float32)
    lse_w = jnp.broadcast_to(lse[..., None], (*lse.shape, LANES))
    delta_w = jnp.broadcast_to(delta[..., None], (*delta.shape, LANES))

    q_spec = pl.BlockSpec((1, 1, br, D), lambda b, h, i, j: (b, h, i, 0))
    kv_spec = pl.BlockSpec((1, 1, bc, D), lambda b, h, i, j: (b, h // g, j, 0))
    v_spec = pl.BlockSpec((1, 1, bc, Dv), lambda b, h, i, j: (b, h // g, j, 0))
    do_spec = pl.BlockSpec((1, 1, br, Dv), lambda b, h, i, j: (b, h, i, 0))
    stat_spec = pl.BlockSpec((1, 1, br, LANES), lambda b, h, i, j: (b, h, i, 0))

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, causal=causal,
                          rel_offset=rel_offset, window=window, n_kv=nk),
        grid=(B, Hq, nq, nk),
        in_specs=[q_spec, kv_spec, v_spec, do_spec, stat_spec, stat_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((br, D), jnp.float32)],
        compiler_params=compat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse_w, delta_w)

    # dkv: grid over kv blocks, sequential q blocks. Output per *query* head,
    # then group-summed below (GQA).
    q_spec2 = pl.BlockSpec((1, 1, br, D), lambda b, h, j, i: (b, h, i, 0))
    kv_spec2 = pl.BlockSpec((1, 1, bc, D), lambda b, h, j, i: (b, h // g, j, 0))
    v_spec2 = pl.BlockSpec((1, 1, bc, Dv), lambda b, h, j, i: (b, h // g, j, 0))
    do_spec2 = pl.BlockSpec((1, 1, br, Dv), lambda b, h, j, i: (b, h, i, 0))
    k_out2 = pl.BlockSpec((1, 1, bc, D), lambda b, h, j, i: (b, h, j, 0))
    v_out2 = pl.BlockSpec((1, 1, bc, Dv), lambda b, h, j, i: (b, h, j, 0))
    stat_spec2 = pl.BlockSpec((1, 1, br, LANES), lambda b, h, j, i: (b, h, i, 0))
    dk_h, dv_h = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, causal=causal,
                          rel_offset=rel_offset, window=window, n_q=nq),
        grid=(B, Hq, nk, nq),
        in_specs=[q_spec2, kv_spec2, v_spec2, do_spec2, stat_spec2, stat_spec2],
        out_specs=[k_out2, v_out2],
        out_shape=[jax.ShapeDtypeStruct((B, Hq, Tk, D), k.dtype),
                   jax.ShapeDtypeStruct((B, Hq, Tk, Dv), v.dtype)],
        scratch_shapes=[pltpu.VMEM((bc, D), jnp.float32),
                        pltpu.VMEM((bc, Dv), jnp.float32)],
        compiler_params=compat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse_w, delta_w)
    if g > 1:
        dk_h = dk_h.reshape(B, Hkv, g, Tk, D).sum(axis=2)
        dv_h = dv_h.reshape(B, Hkv, g, Tk, Dv).sum(axis=2)
    return dq, dk_h.astype(k.dtype), dv_h.astype(v.dtype)
