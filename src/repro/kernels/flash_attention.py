"""Pallas TPU FlashAttention-2 chunk kernels (forward + backward),
block-sparse over the statically-maskable grid.

TARGET: TPU MXU/VMEM. Layout inside the kernels is (B, H, T, D); blocks are
``(block_q × head_dim)`` / ``(block_kv × head_dim)`` VMEM tiles with 128-
aligned matmul dims (MXU-native). Validated on CPU with ``interpret=True``
against ``ref.py`` (tests/test_kernels.py).

Chunk semantics match ``repro.core.attention.chunk_attn``: partial attention
under a static :class:`repro.core.mask.MaskSpec` (see DESIGN.md §2 — in the
ring/balanced schedules every step's mask depends only on the static chunk
distance, so no scalar prefetch is required). Document (packed-sequence)
masking is supported two ways:

  * dynamic ``q_segments``/``kv_segments`` (B, T) int32 arrays enter the
    kernels as narrow ``(1, block)`` blocks next to their q/kv tiles and
    are compared elementwise inside the mask;
  * a static ``mask.boundaries`` layout needs no arrays at all — segment
    IDs become trace-time iota comparisons AND the grid pruning below drops
    cross-document blocks entirely.

Block-sparse grid pruning (README §Block-sparse kernel pruning). Because
the MaskSpec is static, the valid KV-block range of every Q block — and its
transpose for the dkv kernel — is computed at trace time by
``block_sparse.kv_block_bounds`` / ``q_block_bounds``:

  * the sequential grid dimension is **shrunk** to ``max_i count(i)`` (the
    widest row of the trapezoid), not the dense ``nk``;
  * the index map remaps pruned step ``jj`` of row ``i`` to real block
    ``lo(i) + jj``, clamped to the row's last valid block so out-of-range
    steps revisit an already-resident block (no extra DMA) and skip compute
    under ``pl.when``;
  * blocks the mask cannot touch (``interior_kv_bounds``) take a mask-free
    fast path — only diagonal/window-edge/document-boundary tiles pay the
    position mask + where.

The backward follows FA2: ``delta = rowsum(do ⊙ o)`` precomputed, then a
dq-kernel (grid over q blocks, sequential kv) and a dkv-kernel (grid over kv
blocks, sequential q) recompute ``p = exp(s − lse)`` blockwise from the saved
logsumexp. ``lse``/``delta`` enter the kernels as narrow ``(1, 1, block_q)``
blocks of the (B, H, T) arrays — not lane-replicated (B, H, T, 128) float32
broadcasts materialized in HBM. Hardware note: the narrow stat blocks put T
on the lane dimension, so the default ``block_q=128`` stays lane-aligned;
CI validates interpret mode only, and ``test_pruned_flash_compiles_on_tpu``
(TPU-gated) covers the compiled Mosaic lowering of the narrow blocks, the
in-kernel ``lax.cond`` fast path, and the remapped index maps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from repro import compat
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.mask import MaskSpec
from repro.kernels.block_sparse import (interior_kv_bounds, kv_block_bounds,
                                        kv_profile, pick_block,
                                        q_block_bounds, q_profile)

NEG_INF = -1e30
LANES = 128  # TPU lane width; stat scratch is lane-replicated


def _pos_mask(i, j, br, bc, mask: MaskSpec, q_seg=None, kv_seg=None):
    """(br, bc) boolean attend-mask for q block i, kv block j (static args
    except the traced program ids i, j and the segment vectors)."""
    qp = (mask.q_offset + i * br
          + jax.lax.broadcasted_iota(jnp.int32, (br, bc), 0))
    kp = (mask.kv_offset + j * bc
          + jax.lax.broadcasted_iota(jnp.int32, (br, bc), 1))
    qs = None if q_seg is None else q_seg[:, None]
    ks = None if kv_seg is None else kv_seg[None, :]
    return mask.allow(qp, kp, qs, ks)


def _apply_mask(s, i, j, mask: MaskSpec, prune, q_seg=None, kv_seg=None):
    """Mask score tile ``s`` for block (i, j). With pruning, interior blocks
    (mask provably all-True) skip the iota/compare/where entirely via a
    runtime branch — only edge tiles pay for ``_pos_mask``."""
    br, bc = s.shape

    def _m(x):
        return jnp.where(_pos_mask(i, j, br, bc, mask, q_seg, kv_seg),
                         x, NEG_INF)

    if not prune:
        return _m(s)
    lo_f, hi_f = interior_kv_bounds(i, br=br, bc=bc, nk=2 ** 30, mask=mask)
    return jax.lax.cond((j < lo_f) | (j > hi_f), _m, lambda x: x, s)


def _row_span(i, br, bc, nk, mask, prune):
    """(first block, executed count) of the sequential sweep for row ``i``."""
    if not (prune and mask.prunable):
        return 0, nk
    lo, hi = kv_block_bounds(i, br=br, bc=bc, nk=nk, mask=mask)
    return lo, jnp.maximum(hi - lo + 1, 0)


def _kv_index(i, jj, br, bc, nk, mask, prune):
    """Index-map remap: pruned step jj of q-row i → real KV block. Steps
    past the row's range revisit the last valid block (no new DMA)."""
    if not (prune and mask.prunable):
        return jj
    lo, hi = kv_block_bounds(i, br=br, bc=bc, nk=nk, mask=mask)
    return jnp.clip(lo + jj, 0, jnp.maximum(hi, 0))


def _q_row_span(j, br, bc, nq, mask, prune):
    """Transpose of :func:`_row_span` for the dkv orientation: (first q
    block, executed count) of the sequential sweep for kv row ``j``."""
    if not (prune and mask.prunable):
        return 0, nq
    lo, hi = q_block_bounds(j, br=br, bc=bc, nq=nq, mask=mask)
    return lo, jnp.maximum(hi - lo + 1, 0)


def _q_index(j, ii, br, bc, nq, mask, prune):
    """Transpose of :func:`_kv_index`: pruned step ii of kv-row j → real Q
    block, clamped to revisit the row's last valid block."""
    if not (prune and mask.prunable):
        return ii
    lo, hi = q_block_bounds(j, br=br, bc=bc, nq=nq, mask=mask)
    return jnp.clip(lo + ii, 0, jnp.maximum(hi, 0))


def _check_segs(mask: MaskSpec, q_segments, kv_segments) -> bool:
    """True iff segment operands ride this launch; a half-supplied pair or
    a dynamic-document spec without one raises up front (not deep in the
    Pallas setup)."""
    if (q_segments is None) != (kv_segments is None):
        raise ValueError("q_segments and kv_segments must be passed "
                         "together")
    if mask.needs_segments and q_segments is None:
        raise ValueError("document mask without boundaries needs "
                         "q_segments/kv_segments")
    return q_segments is not None


def _seg_specs(br, bc, kv_block, *, dkv=False, q_block=None):
    """BlockSpecs of the (B, T) segment-ID arrays for each grid
    orientation: narrow (1, block) tiles riding next to their q/kv tiles."""
    if not dkv:
        return [pl.BlockSpec((1, br), lambda b, h, i, j: (b, i)),
                pl.BlockSpec((1, bc),
                             lambda b, h, i, j: (b, kv_block(i, j)))]
    return [pl.BlockSpec((1, br), lambda b, h, j, i: (b, q_block(j, i))),
            pl.BlockSpec((1, bc), lambda b, h, j, i: (b, j))]


# ---------------------------------------------------------------- forward


def _fwd_kernel(*refs, scale, mask, nk, prune, has_segs):
    if has_segs:
        (q_ref, k_ref, v_ref, qs_ref, ks_ref,
         o_ref, lse_ref, acc_ref, m_ref, l_ref) = refs
    else:
        q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref = refs
        qs_ref = ks_ref = None
    i, jj = pl.program_id(2), pl.program_id(3)
    br, bc = q_ref.shape[2], k_ref.shape[2]
    lo, count = _row_span(i, br, bc, nk, mask, prune)
    j = lo + jj

    @pl.when(jj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(jj < count)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)              # (br, d)
        k = k_ref[0, 0].astype(jnp.float32)              # (bc, d)
        v = v_ref[0, 0].astype(jnp.float32)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if mask.needs_mask:
            q_seg = None if qs_ref is None else qs_ref[0]
            kv_seg = None if ks_ref is None else ks_ref[0]
            s = _apply_mask(s, i, j, mask, prune, q_seg, kv_seg)

        m_prev = m_ref[:, 0]                             # (br,)
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        m_safe = jnp.maximum(m_new, NEG_INF / 2)
        p = jnp.exp(s - m_safe[:, None])
        p = jnp.where(m_new[:, None] <= NEG_INF / 2, 0.0, p)
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
        l_new = alpha * l_ref[:, 0] + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(p, v)
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    # all-masked rows (count == 0) finalize straight from the init state
    @pl.when(jj == jnp.maximum(count - 1, 0))
    def _finalize():
        l = l_ref[:, 0]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = jnp.where(l == 0.0, NEG_INF, m_ref[:, 0] +
                                  jnp.log(l_safe))


def flash_fwd_bhtd(q, k, v, *, scale, mask: MaskSpec, block_q=128,
                   block_kv=128, interpret=False, prune=True,
                   q_segments=None, kv_segments=None):
    """q,k: (B,Hq/Hkv,T,Dk); v: (B,Hkv,Tk,Dv) -> o (B,Hq,Tq,Dv), lse.
    Dv may differ from Dk (MLA). ``q_segments``/``kv_segments`` are (B, T)
    int32 document IDs (document kind). ``prune=False`` forces the dense
    sweep (benchmark baseline / differential testing)."""
    B, Hq, Tq, D = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    Dv = v.shape[3]
    g = Hq // Hkv
    br = pick_block(Tq, block_q)      # non-dividing hints shrink to a divisor
    bc = pick_block(Tk, block_kv)
    nq, nk = Tq // br, Tk // bc
    has_segs = _check_segs(mask, q_segments, kv_segments)

    seq = nk
    if prune and mask.prunable:
        prof = kv_profile(nq=nq, nk=nk, br=br, bc=bc, mask=mask)
        seq = prof.seq_grid
        if seq == 0:                      # statically fully masked chunk
            return (jnp.zeros((B, Hq, Tq, Dv), q.dtype),
                    jnp.full((B, Hq, Tq), NEG_INF, jnp.float32))
    grid = (B, Hq, nq, seq)

    def kv_block(i, j):
        return _kv_index(i, j, br, bc, nk, mask, prune)

    kernel = functools.partial(
        _fwd_kernel, scale=scale, mask=mask, nk=nk, prune=prune,
        has_segs=has_segs)
    in_specs = [
        pl.BlockSpec((1, 1, br, D), lambda b, h, i, j: (b, h, i, 0)),
        pl.BlockSpec((1, 1, bc, D),
                     lambda b, h, i, j: (b, h // g, kv_block(i, j), 0)),
        pl.BlockSpec((1, 1, bc, Dv),
                     lambda b, h, i, j: (b, h // g, kv_block(i, j), 0)),
    ]
    operands = [q, k, v]
    if has_segs:
        in_specs += _seg_specs(br, bc, kv_block)
        operands += [jnp.asarray(q_segments, jnp.int32),
                     jnp.asarray(kv_segments, jnp.int32)]
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, br, Dv), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, br), lambda b, h, i, j: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hq, Tq, Dv), q.dtype),
            jax.ShapeDtypeStruct((B, Hq, Tq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((br, Dv), jnp.float32),
            pltpu.VMEM((br, LANES), jnp.float32),
            pltpu.VMEM((br, LANES), jnp.float32),
        ],
        compiler_params=compat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(*operands)
    return o, lse


# ---------------------------------------------------------------- backward


def _dq_kernel(*refs, scale, mask, nk, prune, has_segs):
    if has_segs:
        (q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, qs_ref, ks_ref,
         dq_ref, acc_ref) = refs
    else:
        (q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
         dq_ref, acc_ref) = refs
        qs_ref = ks_ref = None
    i, jj = pl.program_id(2), pl.program_id(3)
    br, bc = q_ref.shape[2], k_ref.shape[2]
    lo, count = _row_span(i, br, bc, nk, mask, prune)
    j = lo + jj

    @pl.when(jj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(jj < count)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]                              # (br,)
        delta = delta_ref[0, 0]

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if mask.needs_mask:
            q_seg = None if qs_ref is None else qs_ref[0]
            kv_seg = None if ks_ref is None else ks_ref[0]
            s = _apply_mask(s, i, j, mask, prune, q_seg, kv_seg)
        p = jnp.where(lse[:, None] <= NEG_INF / 2, 0.0,
                      jnp.exp(s - lse[:, None]))
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
        ds = p * (dp - delta[:, None]) * scale
        acc_ref[...] += jax.lax.dot(ds, k)

    @pl.when(jj == jnp.maximum(count - 1, 0))
    def _finalize():
        dq_ref[0, 0] = acc_ref[...].astype(dq_ref.dtype)


def _dkv_kernel(*refs, scale, mask, nq, prune, has_segs):
    if has_segs:
        (q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, qs_ref, ks_ref,
         dk_ref, dv_ref, dk_acc, dv_acc) = refs
    else:
        (q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
         dk_ref, dv_ref, dk_acc, dv_acc) = refs
        qs_ref = ks_ref = None
    j, ii = pl.program_id(2), pl.program_id(3)       # kv block j, q step ii
    br, bc = q_ref.shape[2], k_ref.shape[2]
    lo_q, count = _q_row_span(j, br, bc, nq, mask, prune)
    i = lo_q + ii

    @pl.when(ii == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    @pl.when(ii < count)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if mask.needs_mask:
            q_seg = None if qs_ref is None else qs_ref[0]
            kv_seg = None if ks_ref is None else ks_ref[0]
            s = _apply_mask(s, i, j, mask, prune, q_seg, kv_seg)
        p = jnp.where(lse[:, None] <= NEG_INF / 2, 0.0,
                      jnp.exp(s - lse[:, None]))
        dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
        ds = p * (dp - delta[:, None]) * scale
        dk_acc[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())))

    @pl.when(ii == jnp.maximum(count - 1, 0))
    def _finalize():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def flash_bwd_bhtd(q, k, v, o, lse, do, *, scale, mask: MaskSpec,
                   block_q=128, block_kv=128, interpret=False, delta=None,
                   prune=True, q_segments=None, kv_segments=None):
    """Backward from saved (o, lse). Layout (B,H,T,D). Returns dq, dk, dv
    (dk/dv summed over the GQA group). ``delta`` (B,H,Tq) may be passed
    precomputed (distributed helper path)."""
    B, Hq, Tq, D = q.shape
    Hkv, Tk = k.shape[1], k.shape[2]
    Dv = v.shape[3]
    g = Hq // Hkv
    br = pick_block(Tq, block_q)      # non-dividing hints shrink to a divisor
    bc = pick_block(Tk, block_kv)
    nq, nk = Tq // br, Tk // bc
    has_segs = _check_segs(mask, q_segments, kv_segments)

    if delta is None:
        delta = jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32),
                        axis=-1)
    delta = delta.astype(jnp.float32)
    lse = lse.astype(jnp.float32)

    pruned = prune and mask.prunable
    seq_kv, seq_q = nk, nq
    if pruned:
        seq_kv = kv_profile(nq=nq, nk=nk, br=br, bc=bc, mask=mask).seq_grid
        seq_q = q_profile(nq=nq, nk=nk, br=br, bc=bc, mask=mask).seq_grid
    if pruned and (seq_kv == 0 or seq_q == 0):   # statically fully masked
        return (jnp.zeros(q.shape, q.dtype),
                jnp.zeros((B, Hkv, Tk, D), k.dtype),
                jnp.zeros((B, Hkv, Tk, Dv), v.dtype))

    seg_ops = []
    if has_segs:
        seg_ops = [jnp.asarray(q_segments, jnp.int32),
                   jnp.asarray(kv_segments, jnp.int32)]

    def kv_block(i, j):
        return _kv_index(i, j, br, bc, nk, mask, prune)

    q_spec = pl.BlockSpec((1, 1, br, D), lambda b, h, i, j: (b, h, i, 0))
    kv_spec = pl.BlockSpec(
        (1, 1, bc, D), lambda b, h, i, j: (b, h // g, kv_block(i, j), 0))
    v_spec = pl.BlockSpec(
        (1, 1, bc, Dv), lambda b, h, i, j: (b, h // g, kv_block(i, j), 0))
    do_spec = pl.BlockSpec((1, 1, br, Dv), lambda b, h, i, j: (b, h, i, 0))
    stat_spec = pl.BlockSpec((1, 1, br), lambda b, h, i, j: (b, h, i))

    in_specs = [q_spec, kv_spec, v_spec, do_spec, stat_spec, stat_spec]
    if has_segs:
        in_specs += _seg_specs(br, bc, kv_block)
    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, mask=mask, nk=nk,
                          prune=prune, has_segs=has_segs),
        grid=(B, Hq, nq, seq_kv),
        in_specs=in_specs,
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((br, D), jnp.float32)],
        compiler_params=compat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, delta, *seg_ops)

    # dkv: grid over kv blocks, sequential over the valid q blocks. Output
    # per *query* head, then group-summed below (GQA).
    def q_block(j, i):
        return _q_index(j, i, br, bc, nq, mask, prune)

    q_spec2 = pl.BlockSpec((1, 1, br, D),
                           lambda b, h, j, i: (b, h, q_block(j, i), 0))
    kv_spec2 = pl.BlockSpec((1, 1, bc, D), lambda b, h, j, i: (b, h // g, j, 0))
    v_spec2 = pl.BlockSpec((1, 1, bc, Dv), lambda b, h, j, i: (b, h // g, j, 0))
    do_spec2 = pl.BlockSpec((1, 1, br, Dv),
                            lambda b, h, j, i: (b, h, q_block(j, i), 0))
    k_out2 = pl.BlockSpec((1, 1, bc, D), lambda b, h, j, i: (b, h, j, 0))
    v_out2 = pl.BlockSpec((1, 1, bc, Dv), lambda b, h, j, i: (b, h, j, 0))
    stat_spec2 = pl.BlockSpec((1, 1, br),
                              lambda b, h, j, i: (b, h, q_block(j, i)))
    in_specs2 = [q_spec2, kv_spec2, v_spec2, do_spec2, stat_spec2,
                 stat_spec2]
    if has_segs:
        in_specs2 += _seg_specs(br, bc, kv_block, dkv=True, q_block=q_block)
    dk_h, dv_h = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, mask=mask, nq=nq,
                          prune=prune, has_segs=has_segs),
        grid=(B, Hq, nk, seq_q),
        in_specs=in_specs2,
        out_specs=[k_out2, v_out2],
        out_shape=[jax.ShapeDtypeStruct((B, Hq, Tk, D), k.dtype),
                   jax.ShapeDtypeStruct((B, Hq, Tk, Dv), v.dtype)],
        scratch_shapes=[pltpu.VMEM((bc, D), jnp.float32),
                        pltpu.VMEM((bc, Dv), jnp.float32)],
        compiler_params=compat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, delta, *seg_ops)
    if g > 1:
        dk_h = dk_h.reshape(B, Hkv, g, Tk, D).sum(axis=2)
        dv_h = dv_h.reshape(B, Hkv, g, Tk, Dv).sum(axis=2)
    return dq, dk_h.astype(k.dtype), dv_h.astype(v.dtype)
