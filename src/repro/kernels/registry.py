"""Attention backend registry.

Every chunk-attention implementation is a named :class:`BackendSpec` with a
uniform call signature and explicit capability flags. ``chunk_attn`` /
``chunk_attn_bwd`` (core/attention.py) resolve their ``impl`` string here,
so all schedule / model / launch code selects backends by name only.

Registered backends (see README §Backend registry):

  * ``ref``              — pure-jnp oracle. Ground truth; materializes the
                           full score matrix (O(Tq·Tk) memory).
  * ``chunked-lax``      — ``lax.scan``-blocked online-softmax rescale.
                           Exact, Pallas-free, fast on CPU/GPU.
  * ``pallas``           — compiled Pallas TPU kernel (TPU only).
  * ``pallas-interpret`` — the same kernel body run by the Pallas
                           interpreter; validates the kernel on any host.
  * ``null``             — O(T) shape-correct stub for dry-run cost
                           isolation. NOT exact (never resolves via
                           fallback; must be requested explicitly).

Capabilities are **mask-kind sets**: each backend declares which
:class:`repro.core.mask.MaskSpec` kinds it can serve (``causal``,
``sliding_window``, ``prefix_lm``, ``document``), and
``resolve(impl, platform, mask=spec)`` matches the spec's required kinds
against them, walking each backend's fallback chain when the requested
backend can't run (wrong platform, unsupported mask kind, wrong dtype) and
logging the downgrade — requesting ``pallas`` on CPU runs
``pallas-interpret`` (or ``chunked-lax``) instead of crashing.

Backend names are normalized (``pallas_interpret`` == ``pallas-interpret``)
so the pre-registry spelling keeps working.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Callable, Dict, FrozenSet, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.mask import KINDS, MaskSpec

log = logging.getLogger(__name__)

ALL_PLATFORMS = ("cpu", "gpu", "tpu")
ALL_DTYPES = ("float32", "bfloat16", "float16")
ALL_MASK_KINDS = frozenset(k for k in KINDS if k != "full")


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    """One attention implementation plus its capability envelope.

    ``fwd(q, k, v, *, mask, scale, q_segments, kv_segments) -> (o, lse)``
    ``bwd(q, k, v, o, lse, do, *, mask, scale, delta, q_segments,
        kv_segments) -> (dq, dk, dv)``

    (``mask`` is a static MaskSpec; tunable backends additionally take
    ``block_q``/``block_kv`` hints.)
    """
    name: str
    fwd: Callable
    bwd: Callable
    # capability flags
    mask_kinds: FrozenSet[str] = ALL_MASK_KINDS  # MaskSpec kinds served
    dtypes: Tuple[str, ...] = ALL_DTYPES
    platforms: Tuple[str, ...] = ALL_PLATFORMS
    exact: bool = True             # numerically exact (vs stub)
    # accepts block_q/block_kv tuning hints (fwd/bwd take them as kwargs);
    # chunk_attn only forwards the hints to backends with this flag set, so
    # schedules can pick block shapes per step without knowing the backend
    tunable_blocks: bool = False
    # accepts *traced* q_offset/kv_offset position operands (fwd/bwd take
    # them as kwargs). Needed by schedule steps whose chunk distance
    # depends on the device index (zigzag window bands); static int offsets
    # are folded into the MaskSpec and never reach the backend.
    dynamic_offsets: bool = False
    # paged flash-decode entry point (serving): block-table-gathering
    # one-token decode attention with signature
    # ``paged_fwd(q, k_pool, v_pool, block_table, lengths, *, mask, scale)
    # -> o``; None = backend has no paged path (resolve(paged=True) walks
    # the fallback chain past it).
    paged_fwd: Optional[Callable] = None
    fallback: Tuple[str, ...] = ()  # tried in order when this can't run
    description: str = ""

    def __post_init__(self):
        unknown = frozenset(self.mask_kinds) - ALL_MASK_KINDS
        if unknown:
            raise ValueError(f"unknown mask kinds {sorted(unknown)}; "
                             f"valid: {sorted(ALL_MASK_KINDS)}")
        object.__setattr__(self, "mask_kinds", frozenset(self.mask_kinds))

    # legacy capability views (pre-MaskSpec flag names)
    @property
    def causal(self) -> bool:
        return "causal" in self.mask_kinds

    @property
    def window(self) -> bool:
        return "sliding_window" in self.mask_kinds

    @property
    def rel_offset(self) -> bool:
        return True    # every backend handles static chunk offsets

    @property
    def paged(self) -> bool:
        """Capability flag: serves block-table (paged KV cache) decode."""
        return self.paged_fwd is not None

    def unsupported_reason(self, *, platform: str,
                           mask: Optional[MaskSpec] = None,
                           dtype=None,
                           dynamic_offsets: bool = False,
                           paged: bool = False) -> Optional[str]:
        """None if this backend can serve the request, else why not."""
        if platform not in self.platforms:
            return f"platform {platform!r} not in {self.platforms}"
        if mask is not None:
            missing = mask.kinds - self.mask_kinds
            if missing:
                return (f"mask kind(s) {sorted(missing)} unsupported "
                        f"(has {sorted(self.mask_kinds)})")
        if dtype is not None and jnp.dtype(dtype).name not in self.dtypes:
            return f"dtype {jnp.dtype(dtype).name} not in {self.dtypes}"
        if dynamic_offsets and not self.dynamic_offsets:
            return "traced q_offset/kv_offset operands unsupported"
        if paged and not self.paged:
            return "no paged (block-table) decode path"
        return None


_REGISTRY: Dict[str, BackendSpec] = {}
_DEFAULT = ["ref"]
_WARNED = set()   # (requested, resolved, platform) — log each downgrade once


def _norm(name: str) -> str:
    return name.replace("_", "-").lower()


def register(spec: BackendSpec, overwrite: bool = False) -> BackendSpec:
    key = _norm(spec.name)
    if key in _REGISTRY and not overwrite:
        raise ValueError(f"backend {key!r} already registered")
    _REGISTRY[key] = spec
    return spec


def names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get(name: str) -> BackendSpec:
    key = _norm(name)
    if key not in _REGISTRY:
        raise ValueError(f"unknown attention backend {name!r}; "
                         f"registered: {names()}")
    return _REGISTRY[key]


def set_default(name: str) -> None:
    _DEFAULT[0] = get(name).name


def default_name() -> str:
    return _DEFAULT[0]


def current_platform() -> str:
    return jax.default_backend()


def resolve(impl: Optional[str] = None, platform: Optional[str] = None, *,
            mask: Optional[MaskSpec] = None, dtype=None,
            dynamic_offsets: bool = False,
            paged: bool = False) -> BackendSpec:
    """Return a runnable backend for the request, walking fallbacks.

    ``impl=None`` uses the process default; ``mask`` is the MaskSpec the
    call site will pass; ``dynamic_offsets`` marks a call that carries
    traced position-offset operands; ``paged=True`` requires the backend's
    block-table decode path. A downgrade (requested backend can't
    serve the request) is logged once per (requested, resolved, platform)
    triple; an empty/cyclic fallback chain raises."""
    platform = platform or current_platform()
    want = get(impl if impl is not None else default_name())
    caps = dict(platform=platform, mask=mask, dtype=dtype,
                dynamic_offsets=dynamic_offsets, paged=paged)
    reason = want.unsupported_reason(**caps)
    if reason is None:
        return want
    # transitive breadth-first walk of the fallback chain (cycle-safe)
    seen = {_norm(want.name)}
    queue = [fb for fb in want.fallback]
    tried = [want.name]
    while queue:
        cand = get(queue.pop(0))
        if _norm(cand.name) in seen:
            continue
        seen.add(_norm(cand.name))
        tried.append(cand.name)
        if cand.unsupported_reason(**caps) is None:
            key = (want.name, cand.name, platform)
            if key not in _WARNED:
                _WARNED.add(key)
                log.warning("attention backend %r unavailable (%s); "
                            "downgrading to %r on %s", want.name, reason,
                            cand.name, platform)
            return cand
        queue.extend(cand.fallback)
    raise ValueError(
        f"no runnable attention backend for impl={want.name!r} on "
        f"{platform!r} (mask={mask!r}): {reason}; tried {tried}")


# ==========================================================================
# Built-in backends
# ==========================================================================

def _ref_fwd(q, k, v, *, mask, scale=None, q_segments=None,
             kv_segments=None, q_offset=0, kv_offset=0):
    from repro.kernels.ref import chunk_attn_ref
    return chunk_attn_ref(q, k, v, mask=mask, scale=scale,
                          q_offset=q_offset, kv_offset=kv_offset,
                          q_segments=q_segments, kv_segments=kv_segments)


def _ref_bwd(q, k, v, o, lse, do, *, mask, scale=None, delta=None,
             q_segments=None, kv_segments=None, q_offset=0, kv_offset=0):
    from repro.kernels.ref import chunk_attn_bwd_ref
    return chunk_attn_bwd_ref(q, k, v, o, lse, do, mask=mask, scale=scale,
                              q_offset=q_offset, kv_offset=kv_offset,
                              delta=delta, q_segments=q_segments,
                              kv_segments=kv_segments)


def _chunked_fwd(q, k, v, **kw):
    from repro.kernels.chunked import chunked_fwd
    return chunked_fwd(q, k, v, **kw)


def _chunked_bwd(q, k, v, o, lse, do, **kw):
    from repro.kernels.chunked import chunked_bwd
    return chunked_bwd(q, k, v, o, lse, do, **kw)


def block_tuning_kw(block_q, block_kv, *, backend=None, platform=None,
                    mask_kind=None, head_dim=None, seq=None, op="fwd"):
    """None-filtered {block_q, block_kv} kwargs for tunable backends (shared
    by chunk_attn's hint forwarding and the pallas closures below).

    When the caller passes *neither* block, the tuning chain kicks in:
    ``REPRO_TUNE_BLOCK_Q``/``REPRO_TUNE_BLOCK_KV`` env overrides first,
    then the active tuning table's nearest-bucket winner for the call
    context (requires ``backend`` + shape context — the bare two-arg form
    used inside backend closures never re-consults the table).  Explicit
    kwargs always win wholesale; with no env, no table, and no kwargs the
    kernels keep their built-in defaults."""
    if block_q is None and block_kv is None:
        from repro.tune import table as _tt
        block_q = _tt.env_int("REPRO_TUNE_BLOCK_Q")
        block_kv = _tt.env_int("REPRO_TUNE_BLOCK_KV")
        if block_q is None and block_kv is None and backend is not None:
            tab = _tt.active_table()
            if tab is not None:
                hit = tab.best_blocks(
                    backend=backend, platform=platform or current_platform(),
                    mask_kind=mask_kind or "causal",
                    head_dim=head_dim or 64, seq=seq or 0, op=op)
                if hit is not None:
                    block_q, block_kv = hit
    kw = {}
    if block_q is not None:
        kw["block_q"] = block_q
    if block_kv is not None:
        kw["block_kv"] = block_kv
    return kw


def _pallas_fwd(interpret):
    def fwd(q, k, v, *, mask, scale=None, q_segments=None, kv_segments=None,
            block_q=None, block_kv=None):
        from repro.kernels import ops
        return ops.flash_fwd(q, k, v, mask=mask, scale=scale,
                             interpret=interpret, q_segments=q_segments,
                             kv_segments=kv_segments,
                             **block_tuning_kw(block_q, block_kv))
    return fwd


def _pallas_bwd(interpret):
    def bwd(q, k, v, o, lse, do, *, mask, scale=None, delta=None,
            q_segments=None, kv_segments=None, block_q=None, block_kv=None):
        from repro.kernels import ops
        return ops.flash_bwd(q, k, v, o, lse, do, mask=mask, scale=scale,
                             interpret=interpret, delta=delta,
                             q_segments=q_segments, kv_segments=kv_segments,
                             **block_tuning_kw(block_q, block_kv))
    return bwd


def _paged_ref(q, k_pool, v_pool, block_table, lengths, *, mask, scale=None):
    from repro.kernels.paged import paged_attn_ref
    return paged_attn_ref(q, k_pool, v_pool, block_table, lengths,
                          mask=mask, scale=scale)


def _paged_chunked(q, k_pool, v_pool, block_table, lengths, *, mask,
                   scale=None):
    from repro.kernels.paged import paged_attn_chunked
    return paged_attn_chunked(q, k_pool, v_pool, block_table, lengths,
                              mask=mask, scale=scale)


def _paged_pallas(interpret):
    def fwd(q, k_pool, v_pool, block_table, lengths, *, mask, scale=None):
        from repro.kernels.paged import paged_attn_pallas
        return paged_attn_pallas(q, k_pool, v_pool, block_table, lengths,
                                 mask=mask, scale=scale, interpret=interpret)
    return fwd


def _null_fwd(q, k, v, *, mask=None, scale=None, q_segments=None,
              kv_segments=None):
    # dry-run cost-isolation stub: shape-correct, data-dependent (so XLA
    # cannot fold it away), but O(T) instead of O(T²). The kernel's ideal
    # FLOPs/bytes are added analytically (analysis/roofline.attention_sites).
    B, Tq, Hq, _ = q.shape
    vm = jnp.mean(v.astype(jnp.float32), axis=(1, 2), keepdims=True)
    o = jnp.broadcast_to(vm, (B, Tq, Hq, v.shape[-1])).astype(q.dtype)
    o = o + 0.0 * q[..., :1] * jnp.mean(k)
    lse = jnp.mean(q.astype(jnp.float32), axis=-1)
    return o, lse


def _null_bwd(q, k, v, o, lse, do, *, mask=None, scale=None, delta=None,
              q_segments=None, kv_segments=None):
    s_do = jnp.mean(do.astype(jnp.float32))
    dq = (q.astype(jnp.float32) * 0.0 + s_do).astype(q.dtype)
    dk = (k.astype(jnp.float32) * 0.0 + s_do).astype(k.dtype)
    dv = (v.astype(jnp.float32) * 0.0 + s_do).astype(v.dtype)
    return dq, dk, dv


register(BackendSpec(
    name="ref", fwd=_ref_fwd, bwd=_ref_bwd,
    dynamic_offsets=True, paged_fwd=_paged_ref,
    description="pure-jnp oracle; full score matrix"))

register(BackendSpec(
    name="chunked-lax", fwd=_chunked_fwd, bwd=_chunked_bwd,
    tunable_blocks=True, dynamic_offsets=True, paged_fwd=_paged_chunked,
    fallback=("ref",),
    description="lax.scan-blocked online softmax; Pallas-free"))

register(BackendSpec(
    name="pallas", fwd=_pallas_fwd(False), bwd=_pallas_bwd(False),
    platforms=("tpu",), dtypes=("float32", "bfloat16"),
    tunable_blocks=True, paged_fwd=_paged_pallas(False),
    fallback=("pallas-interpret", "chunked-lax", "ref"),
    description="compiled Pallas TPU FlashAttention-2 kernel"))

register(BackendSpec(
    name="pallas-interpret", fwd=_pallas_fwd(True), bwd=_pallas_bwd(True),
    dtypes=("float32", "bfloat16"),
    tunable_blocks=True, paged_fwd=_paged_pallas(True),
    fallback=("chunked-lax", "ref"),
    description="Pallas kernel body under the interpreter (validation)"))

register(BackendSpec(
    name="null", fwd=_null_fwd, bwd=_null_bwd, exact=False,
    description="O(T) dry-run cost-isolation stub (not exact)"))
