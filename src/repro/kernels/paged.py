"""Paged flash-decode kernels: one-token decode attention that gathers its
KV context through a *block table* instead of a contiguous cache.

The serving engine (serve/engine.py) stores KV in a fixed pool of
``block_size``-token blocks (serve/cache.py); each request owns an ordered
list of (arbitrarily located) block ids.  The new token's K/V is scattered
into the request's current block *before* attention, so the kernels see one
uniform layout:

  q            (B, Tq, Hq, Dq)      the decode-step queries — ``Tq = 1``
                                    for vanilla decode; ``Tq = K+1`` for a
                                    speculative verification chunk (the
                                    queries are the *last Tq tokens* of the
                                    context: row ``t`` sits at position
                                    ``lengths[b] − Tq + t``)
  k_pool       (N, bs, Hkv, Dk)     one layer's key pool (N = pool blocks)
  v_pool       (N, bs, Hkv, Dv)     value pool (MLA: a narrow view of k)
  block_table  (B, nb) int32        request b's i-th block id (0 = the
                                    reserved null block for unused entries)
  lengths      (B,) int32           attendable tokens incl. the new ones;
                                    request b's last query sits at
                                    lengths[b]−1

Masking reuses :class:`repro.core.mask.MaskSpec`, restricted to the two
kinds a decode step can express — ``causal`` (whole context) and
``sliding_window`` — evaluated per batch row against ``lengths`` (token
``j`` of the virtual contiguous context is attendable iff ``j < len_b`` and,
windowed, ``len_b − 1 − j < w``).  Out-of-range table entries point at the
null block and are masked by ``lengths``, so fragmented / out-of-order /
partially-filled tables need no special cases.

Three implementations, registered on the existing backends via the
``paged`` capability flag (kernels/registry.py):

  * :func:`paged_attn_ref`      — pure-jnp oracle: gathers the whole table
                                  and materializes the (B, H, T) scores.
  * :func:`paged_attn_chunked`  — ``lax.scan`` over table entries with the
                                  FA2 online-softmax merge; peak score
                                  memory O(B · block_size).
  * :func:`paged_attn_pallas`   — Pallas TPU kernel; the block table rides
                                  as a scalar-prefetch operand and the KV
                                  BlockSpec index maps gather pool blocks
                                  directly (one DMA per table entry).
                                  ``interpret=True`` validates it anywhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.core import mask as mk
from repro.core.mask import MaskSpec
from repro.kernels.ref import NEG_INF, merge_ref

LANES = 128


def _check(q, k_pool, v_pool, block_table, lengths, mask: MaskSpec):
    if q.shape[1] < 1:
        raise ValueError(f"paged decode takes >= 1 query tokens, got "
                         f"Tq={q.shape[1]}")
    if mask.kinds - {"causal", "sliding_window"}:
        raise ValueError(
            f"paged decode serves causal/sliding_window masks only "
            f"(got {mask.kind!r})")
    if mask.q_offset or mask.kv_offset:
        raise ValueError("paged decode mask must be offset-free — positions "
                         "come from `lengths`")
    if k_pool.shape[:3] != (v_pool.shape[0], v_pool.shape[1],
                            v_pool.shape[2]):
        raise ValueError(f"k_pool/v_pool disagree: {k_pool.shape} vs "
                         f"{v_pool.shape}")
    if q.shape[2] % k_pool.shape[2]:
        raise ValueError(f"Hq={q.shape[2]} not a multiple of "
                         f"Hkv={k_pool.shape[2]}")


def _allow_tokens(mask: MaskSpec, kpos, lengths, Tq: int = 1):
    """(B, Tq, T) attendability of virtual context position ``kpos`` (T,)
    for per-request ``lengths`` (B,): query row ``t`` sits at context
    position ``lengths[b] − Tq + t`` and attends causally (optionally
    windowed) from there."""
    qpos = (lengths[:, None] - Tq
            + jnp.arange(Tq, dtype=jnp.int32)[None, :])       # (B, Tq)
    ok = kpos[None, None, :] <= qpos[:, :, None]
    if mask.window and mask.window > 0:
        ok = ok & (kpos[None, None, :] > qpos[:, :, None] - mask.window)
    return ok


# --------------------------------------------------------------- reference

def paged_attn_ref(q, k_pool, v_pool, block_table, lengths, *, mask=None,
                   scale=None):
    """Oracle: gather the whole table, materialize the scores. Returns
    o (B, Tq, Hq, Dv)."""
    mask = mask if mask is not None else mk.causal()
    _check(q, k_pool, v_pool, block_table, lengths, mask)
    B, Tq, Hq, Dq = q.shape
    nb = block_table.shape[1]
    bs, Hkv = k_pool.shape[1], k_pool.shape[2]
    g = Hq // Hkv
    sc = scale if scale is not None else 1.0 / (Dq ** 0.5)
    kg = k_pool[block_table].reshape(B, nb * bs, Hkv, -1)
    vg = v_pool[block_table].reshape(B, nb * bs, Hkv, -1)
    if g > 1:
        kg = jnp.repeat(kg, g, axis=2)
        vg = jnp.repeat(vg, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kg.astype(jnp.float32)) * sc
    ok = _allow_tokens(mask, jnp.arange(nb * bs), lengths, Tq)
    s = jnp.where(ok[:, None, :, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    m_safe = jnp.maximum(m, NEG_INF / 2)
    p = jnp.where(m[..., None] <= NEG_INF / 2, 0.0,
                  jnp.exp(s - m_safe[..., None]))
    den = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vg.astype(jnp.float32))
    o = o / jnp.where(den == 0.0, 1.0, den).transpose(0, 2, 1)[..., None]
    o = jnp.where((den == 0.0).transpose(0, 2, 1)[..., None], 0.0, o)
    return o.astype(q.dtype)


# ------------------------------------------------------------- chunked-lax

def paged_attn_chunked(q, k_pool, v_pool, block_table, lengths, *,
                       mask=None, scale=None):
    """``lax.scan`` over the table entries with the online-softmax merge —
    the memory-efficient CPU/GPU path (and the reference for the Pallas
    kernel's loop structure)."""
    mask = mask if mask is not None else mk.causal()
    _check(q, k_pool, v_pool, block_table, lengths, mask)
    B, Tq, Hq, Dq = q.shape
    nb = block_table.shape[1]
    bs, Hkv = k_pool.shape[1], k_pool.shape[2]
    Dv = v_pool.shape[-1]
    g = Hq // Hkv
    sc = scale if scale is not None else 1.0 / (Dq ** 0.5)
    qf = q.astype(jnp.float32)
    bt = jnp.swapaxes(jnp.asarray(block_table, jnp.int32), 0, 1)  # (nb, B)
    offs = jnp.arange(nb, dtype=jnp.int32) * bs

    def body(carry, xs):
        o_acc, l_acc = carry
        ids, off = xs
        kj = k_pool[ids]                             # (B, bs, Hkv, Dk)
        vj = v_pool[ids]
        if g > 1:
            kj = jnp.repeat(kj, g, axis=2)
            vj = jnp.repeat(vj, g, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kj.astype(jnp.float32)) * sc
        ok = _allow_tokens(mask, off + jnp.arange(bs), lengths, Tq)
        s = jnp.where(ok[:, None, :, :], s, NEG_INF)
        m = jnp.max(s, axis=-1)
        m_safe = jnp.maximum(m, NEG_INF / 2)
        p = jnp.where(m[..., None] <= NEG_INF / 2, 0.0,
                      jnp.exp(s - m_safe[..., None]))
        den = jnp.sum(p, axis=-1)                     # (B, H, 1)
        o_j = jnp.einsum("bhqk,bkhd->bqhd", p, vj.astype(jnp.float32))
        o_j = o_j / jnp.where(den == 0.0, 1.0,
                              den).transpose(0, 2, 1)[..., None]
        o_j = jnp.where((den == 0.0).transpose(0, 2, 1)[..., None], 0.0, o_j)
        lse_j = jnp.where(den == 0.0, NEG_INF,
                          m_safe + jnp.log(jnp.where(den == 0.0, 1.0, den))
                          ).transpose(0, 2, 1)        # (B, 1, H)
        return merge_ref(o_acc, l_acc, o_j, lse_j), None

    init = (jnp.zeros((B, Tq, Hq, Dv), jnp.float32),
            jnp.full((B, Tq, Hq), NEG_INF, jnp.float32))
    (o, _), _ = lax.scan(body, init, (bt, offs))
    return o.astype(q.dtype)


# ------------------------------------------------------------------ pallas

def _paged_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, scale, mask: MaskSpec, bs, nb,
                  Tq):
    b, i = pl.program_id(0), pl.program_id(2)
    gT = q_ref.shape[2]                  # g · Tq rows: row r = gi·Tq + t

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                     # (gT, Dq)
    k = k_ref[0, 0].astype(jnp.float32)                     # (bs, Dk)
    v = v_ref[0, 0].astype(jnp.float32)                     # (bs, Dv)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    lb = len_ref[b]
    kpos = i * bs + jax.lax.broadcasted_iota(jnp.int32, (gT, bs), 1)
    # row r's query position: lengths[b] − Tq + (r mod Tq)
    qpos = lb - Tq + jax.lax.broadcasted_iota(jnp.int32, (gT, bs), 0) % Tq
    ok = kpos <= qpos
    if mask.window and mask.window > 0:
        ok = ok & (kpos > qpos - mask.window)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    m_safe = jnp.maximum(m_new, NEG_INF / 2)
    p = jnp.exp(s - m_safe[:, None])
    p = jnp.where(m_new[:, None] <= NEG_INF / 2, 0.0, p)
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))
    l_new = alpha * l_ref[:, 0] + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot(p, v)
    m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(i == nb - 1)
    def _finalize():
        l = l_ref[:, 0]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)


def paged_attn_pallas(q, k_pool, v_pool, block_table, lengths, *, mask=None,
                      scale=None, interpret=False):
    """Pallas paged decode: grid (B, Hkv, nb); the block table and lengths
    are scalar-prefetch operands, so each KV block's DMA source address is
    computed from ``block_table[b, i]`` in the BlockSpec index map — the
    gather never materializes outside VMEM."""
    mask = mask if mask is not None else mk.causal()
    _check(q, k_pool, v_pool, block_table, lengths, mask)
    B, Tq, Hq, Dq = q.shape
    nb = block_table.shape[1]
    bs, Hkv = k_pool.shape[1], k_pool.shape[2]
    Dv = v_pool.shape[-1]
    g = Hq // Hkv
    sc = scale if scale is not None else 1.0 / (Dq ** 0.5)

    # head h ↦ kv head h//g; query rows flatten (g, Tq) → row gi·Tq + t
    q_r = q.transpose(0, 2, 1, 3).reshape(B, Hkv, g * Tq, Dq)
    k_r = jnp.swapaxes(k_pool, 1, 2)               # (N, Hkv, bs, Dk)
    v_r = jnp.swapaxes(v_pool, 1, 2)               # (N, Hkv, bs, Dv)

    kernel = functools.partial(_paged_kernel, scale=sc, mask=mask, bs=bs,
                               nb=nb, Tq=Tq)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                     # block_table, lengths
        grid=(B, Hkv, nb),
        in_specs=[
            pl.BlockSpec((1, 1, g * Tq, Dq), lambda b, h, i, bt, ln:
                         (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bs, k_pool.shape[-1]),
                         lambda b, h, i, bt, ln: (bt[b, i], h, 0, 0)),
            pl.BlockSpec((1, 1, bs, Dv),
                         lambda b, h, i, bt, ln: (bt[b, i], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g * Tq, Dv), lambda b, h, i, bt, ln:
                               (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g * Tq, Dv), jnp.float32),
            pltpu.VMEM((g * Tq, LANES), jnp.float32),
            pltpu.VMEM((g * Tq, LANES), jnp.float32),
        ],
    )
    o = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g * Tq, Dv), q.dtype),
        compiler_params=compat.pallas_tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(block_table, jnp.int32), jnp.asarray(lengths, jnp.int32),
      q_r, k_r, v_r)
    return (o.reshape(B, Hkv, g, Tq, Dv).transpose(0, 3, 1, 2, 4)
            .reshape(B, Tq, Hq, Dv))
