"""Static block-sparsity ranges for the chunk-attention kernels.

The schedules guarantee the :class:`repro.core.mask.MaskSpec` of every step
is static (DESIGN.md §2), so for a fixed block tiling the set of (q-block,
kv-block) pairs the mask can reach is computable at trace time. This module
is the single source of truth for those ranges — the Pallas kernels
(``flash_attention.py``), the ``chunked-lax`` backend (``chunked.py``) and
the kernel microbench (``benchmarks/kernel_bench.py``) all derive their
iteration spaces from the same three functions, so CPU CI exercises the
identical block-range logic the TPU kernels run.

Conventions. Q block ``i`` covers absolute query positions
``[mask.q_offset + i*br, mask.q_offset + (i+1)*br - 1]``; KV block ``j``
covers ``[mask.kv_offset + j*bc, mask.kv_offset + (j+1)*bc - 1]``. All
bounds are **inclusive**; an empty range is returned as ``hi < lo``
(callers clamp ``count = max(hi - lo + 1, 0)``).

Mask kinds: causal bounds the high side, the sliding window the low side,
and a ``document`` spec with static ``boundaries`` bounds both — a Q block
can only reach keys in ``[doc_start(qs), doc_end(qe)]``, so cross-document
blocks of a packed batch are pruned at trace time. A ``prefix_lm`` prefix
re-opens blocks the causal/window bounds would drop (the returned range is
the contiguous hull). ``document`` with *dynamic* segment arrays cannot be
bounded statically (``mask.prunable`` is False) — callers fall back to the
dense sweep and mask at runtime.

Every function accepts either Python ints (grid sizing, ``chunked-lax``)
or traced int32 scalars (Pallas kernel bodies and index maps): ``//`` is
floor division in both worlds, and min/max/where dispatch on operand type.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.mask import MaskSpec


def _static(*xs) -> bool:
    return all(isinstance(x, (int, bool, np.integer, np.bool_)) for x in xs)


def _mn(a, b):
    if _static(a, b):
        return min(a, b)
    import jax.numpy as jnp
    return jnp.minimum(a, b)


def _mx(a, b):
    if _static(a, b):
        return max(a, b)
    import jax.numpy as jnp
    return jnp.maximum(a, b)


def _where(cond, a, b):
    if _static(cond):
        return a if cond else b
    import jax.numpy as jnp
    return jnp.where(cond, a, b)


def _cdiv(a, b):
    """Ceil division with floor-div semantics shared by int and traced."""
    return -(-a // b)


def pick_block(T: int, block: int) -> int:
    """Largest divisor of T that is ≤ ``block`` (grids and scans need equal
    blocks, so a non-dividing tuning hint is shrunk, not crashed on). When T
    has no useful divisor near the target (prime-ish lengths), blocking
    would degenerate into a near-token-level sweep — return T itself so the
    caller takes the single-block path."""
    b = min(block, T)
    while T % b:
        b -= 1
    if b < min(32, T):
        return T
    return b


def _prefix_blocks(mask: MaskSpec, bc: int) -> int:
    """Number of KV blocks overlapping the bidirectional prefix (static)."""
    if not mask.prefix_len or mask.prefix_len <= mask.kv_offset:
        return 0
    return _cdiv(mask.prefix_len - mask.kv_offset, bc)


def kv_block_bounds(i, *, br, bc, nk, mask: MaskSpec):
    """Inclusive (lo, hi) of KV blocks that q block ``i`` can attend to.

    A KV block is in range iff *some* (qp, kp) pair in the (br × bc) tile is
    unmasked. ``hi < lo`` means the whole row is masked. ``lo >= 0`` and
    ``hi <= nk - 1`` always; ``hi`` may be negative when even KV block 0 is
    above the causal diagonal.
    """
    qs = mask.q_offset + i * br              # first q position of the block
    qe = qs + br - 1                         # last
    ko = mask.kv_offset
    # causal: block j reachable iff its first key ko + j*bc <= qe
    hi = _mn(nk - 1, (qe - ko) // bc) if mask.causal else nk - 1
    # window: block j reachable iff its last key ko+(j+1)*bc-1 >= qs-window+1
    lo = (_mx(0, _cdiv(qs - mask.window + 2 - ko, bc) - 1)
          if mask.window and mask.window > 0 else 0)
    # prefix re-opens the leading blocks (contiguous hull)
    pb = _prefix_blocks(mask, bc)
    if pb > 0:
        lo = 0
        hi = _mx(hi, _mn(nk - 1, pb - 1))
    # document (static layout): keys confined to [doc_start(qs), doc_end(qe)]
    if mask.document and mask.boundaries is not None:
        lo = _mx(lo, _mx(0, (mask.doc_start(qs) - ko) // bc))
        hi = _mn(hi, (mask.doc_end(qe) - ko) // bc)
    return lo, hi


def interior_kv_bounds(i, *, br, bc, nk, mask: MaskSpec):
    """Inclusive (lo, hi) of KV blocks the mask cannot touch for q block
    ``i`` — *every* (qp, kp) pair in the tile is unmasked, so the kernel may
    skip the position mask entirely. Empty (``hi < lo``) when no interior
    block exists (e.g. the diagonal row of a causal chunk). Conservative
    (never larger than the true interior): a dynamic-segment document spec
    has no static interior at all."""
    qs = mask.q_offset + i * br
    qe = qs + br - 1
    ko = mask.kv_offset
    # causal: fully below the diagonal iff the last key ko+(j+1)*bc-1 <= qs
    hi = _mn(nk - 1, (qs + 1 - ko) // bc - 1) if mask.causal else nk - 1
    # window: fully inside iff the first key ko + j*bc > qe - window
    lo = (_mx(0, (qe - mask.window - ko) // bc + 1)
          if mask.window and mask.window > 0 else 0)
    if mask.document:
        if mask.boundaries is None:
            return 1, 0                      # dynamic segments: no interior
        ds, de = mask.doc_start(qs), mask.doc_end(qs)
        single_doc = mask.doc_start(qe) == ds
        # kv block fully inside the q block's document
        lo = _mx(lo, _mx(0, _cdiv(ds - ko, bc)))
        hi = _mn(hi, (de + 1 - ko) // bc - 1)
        hi = _where(single_doc, hi, -1)      # q spans a boundary: no interior
    return lo, hi


def q_block_bounds(j, *, br, bc, nq, mask: MaskSpec):
    """Inclusive (lo, hi) of Q blocks that can attend to KV block ``j`` —
    the transpose of :func:`kv_block_bounds`, used by the dkv kernel (grid
    over KV blocks, sequential over Q blocks)."""
    ks = mask.kv_offset + j * bc             # first key position of the block
    ke = ks + bc - 1                         # last
    qo = mask.q_offset
    # causal: q block i reachable iff its last query >= ks
    lo = _mx(0, _cdiv(ks - qo + 1, br) - 1) if mask.causal else 0
    # window: q block i reachable iff its first query <= ke + window - 1
    hi = (_mn(nq - 1, (ke + mask.window - 1 - qo) // br)
          if mask.window and mask.window > 0 else nq - 1)
    # a key inside the prefix is visible to every query (hull)
    if mask.prefix_len and _static(ks) and ks < mask.prefix_len:
        return 0, nq - 1
    elif mask.prefix_len and not _static(ks):
        pre = ks < mask.prefix_len
        lo = _where(pre, 0, lo)
        hi = _where(pre, nq - 1, hi)
    if mask.document and mask.boundaries is not None:
        lo = _mx(lo, _mx(0, _cdiv(mask.doc_start(ks) - qo + 1, br) - 1))
        hi = _mn(hi, (mask.doc_end(ke) - qo) // br)
    return lo, hi


# --------------------------------------------------------------- profiles


@dataclasses.dataclass(frozen=True)
class GridProfile:
    """Static work profile of one pruned kernel launch.

    ``rows`` is the parallel grid dimension (q blocks for fwd/dq, kv blocks
    for dkv); ``row_counts[r]`` the number of valid sequential blocks for
    row ``r``. The pruned kernel launches ``rows × seq_grid`` steps and
    executes compute on ``executed_steps`` of them; the dense sweep runs
    ``full_steps``.
    """
    rows: int
    cols: int
    row_counts: tuple
    seq_grid: int          # pruned sequential trip count: max(row_counts)
    full_steps: int        # rows * cols — the dense sweep
    launched_steps: int    # rows * seq_grid
    executed_steps: int    # sum(row_counts) — steps that do MXU work

    @property
    def work_ratio(self) -> float:
        """Dense grid steps per executed pruned step (≥ 1)."""
        if self.executed_steps == 0:
            return float("inf") if self.full_steps else 1.0
        return self.full_steps / self.executed_steps


def _profile(rows, cols, counts) -> GridProfile:
    counts = tuple(int(max(0, c)) for c in counts)
    seq = max(counts) if counts else 0
    return GridProfile(rows=rows, cols=cols, row_counts=counts, seq_grid=seq,
                       full_steps=rows * cols, launched_steps=rows * seq,
                       executed_steps=sum(counts))


def kv_profile(*, nq, nk, br, bc, mask: MaskSpec) -> GridProfile:
    """Work profile of the fwd/dq orientation (rows = q blocks)."""
    counts = []
    for i in range(nq):
        lo, hi = kv_block_bounds(i, br=br, bc=bc, nk=nk, mask=mask)
        counts.append(hi - lo + 1)
    return _profile(nq, nk, counts)


def q_profile(*, nq, nk, br, bc, mask: MaskSpec) -> GridProfile:
    """Work profile of the dkv orientation (rows = kv blocks)."""
    counts = []
    for j in range(nk):
        lo, hi = q_block_bounds(j, br=br, bc=bc, nq=nq, mask=mask)
        counts.append(hi - lo + 1)
    return _profile(nk, nq, counts)
