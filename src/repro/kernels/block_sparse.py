"""Static block-sparsity ranges for the chunk-attention kernels.

The schedules guarantee ``(causal, rel_offset, window)`` are static per step
(DESIGN.md §2), so for a fixed block tiling the set of (q-block, kv-block)
pairs the mask can reach is computable at trace time. This module is the
single source of truth for those ranges — the Pallas kernels
(``flash_attention.py``), the ``chunked-lax`` backend (``chunked.py``) and
the kernel microbench (``benchmarks/kernel_bench.py``) all derive their
iteration spaces from the same three functions, so CPU CI exercises the
identical block-range logic the TPU kernels run.

Conventions. Q block ``i`` covers absolute query positions
``[rel_offset + i*br, rel_offset + (i+1)*br - 1]``; KV block ``j`` covers
``[j*bc, (j+1)*bc - 1]`` (kv offset 0, matching ``chunk_attn`` semantics).
A position pair attends iff ``kp <= qp`` (causal) and ``qp - kp < window``
(window > 0). All bounds are **inclusive**; an empty range is returned as
``hi < lo`` (callers clamp ``count = max(hi - lo + 1, 0)``).

Every function accepts either Python ints (grid sizing, ``chunked-lax``)
or traced int32 scalars (Pallas kernel bodies and index maps): ``//`` is
floor division in both worlds, and min/max dispatch on the operand type.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def _static(*xs) -> bool:
    return all(isinstance(x, (int, np.integer)) for x in xs)


def _mn(a, b):
    if _static(a, b):
        return min(a, b)
    import jax.numpy as jnp
    return jnp.minimum(a, b)


def _mx(a, b):
    if _static(a, b):
        return max(a, b)
    import jax.numpy as jnp
    return jnp.maximum(a, b)


def _cdiv(a, b):
    """Ceil division with floor-div semantics shared by int and traced."""
    return -(-a // b)


def pick_block(T: int, block: int) -> int:
    """Largest divisor of T that is ≤ ``block`` (grids and scans need equal
    blocks, so a non-dividing tuning hint is shrunk, not crashed on). When T
    has no useful divisor near the target (prime-ish lengths), blocking
    would degenerate into a near-token-level sweep — return T itself so the
    caller takes the single-block path."""
    b = min(block, T)
    while T % b:
        b -= 1
    if b < min(32, T):
        return T
    return b


def kv_block_bounds(i, *, br, bc, nk, causal, rel_offset, window):
    """Inclusive (lo, hi) of KV blocks that q block ``i`` can attend to.

    A KV block is in range iff *some* (qp, kp) pair in the (br × bc) tile is
    unmasked. ``hi < lo`` means the whole row is masked. ``lo >= 0`` and
    ``hi <= nk - 1`` always; ``hi`` may be negative when even KV block 0 is
    above the causal diagonal.
    """
    qs = rel_offset + i * br                 # first q position of the block
    qe = qs + br - 1                         # last
    # causal: block j reachable iff its first key j*bc <= the last query qe
    hi = _mn(nk - 1, qe // bc) if causal else nk - 1
    # window: block j reachable iff its last key (j+1)*bc - 1 >= qs - window + 1
    lo = _mx(0, _cdiv(qs - window + 2, bc) - 1) if window and window > 0 else 0
    return lo, hi


def interior_kv_bounds(i, *, br, bc, nk, causal, rel_offset, window):
    """Inclusive (lo, hi) of KV blocks the mask cannot touch for q block
    ``i`` — *every* (qp, kp) pair in the tile is unmasked, so the kernel may
    skip ``_pos_mask`` entirely. Empty (``hi < lo``) when no interior block
    exists (e.g. the diagonal row of a causal chunk)."""
    qs = rel_offset + i * br
    qe = qs + br - 1
    # causal: fully below the diagonal iff the last key (j+1)*bc - 1 <= qs
    hi = _mn(nk - 1, (qs + 1) // bc - 1) if causal else nk - 1
    # window: fully inside iff the first key j*bc > qe - window
    lo = _mx(0, (qe - window) // bc + 1) if window and window > 0 else 0
    return lo, hi


def q_block_bounds(j, *, br, bc, nq, causal, rel_offset, window):
    """Inclusive (lo, hi) of Q blocks that can attend to KV block ``j`` —
    the transpose of :func:`kv_block_bounds`, used by the dkv kernel (grid
    over KV blocks, sequential over Q blocks)."""
    ks = j * bc                              # first key position of the block
    ke = ks + bc - 1                         # last
    # causal: q block i reachable iff its last query >= ks
    lo = (_mx(0, _cdiv(ks - rel_offset + 1, br) - 1) if causal else 0)
    # window: q block i reachable iff its first query <= ke + window - 1
    hi = (_mn(nq - 1, (ke + window - 1 - rel_offset) // br)
          if window and window > 0 else nq - 1)
    return lo, hi


# --------------------------------------------------------------- profiles


@dataclasses.dataclass(frozen=True)
class GridProfile:
    """Static work profile of one pruned kernel launch.

    ``rows`` is the parallel grid dimension (q blocks for fwd/dq, kv blocks
    for dkv); ``row_counts[r]`` the number of valid sequential blocks for
    row ``r``. The pruned kernel launches ``rows × seq_grid`` steps and
    executes compute on ``executed_steps`` of them; the dense sweep runs
    ``full_steps``.
    """
    rows: int
    cols: int
    row_counts: tuple
    seq_grid: int          # pruned sequential trip count: max(row_counts)
    full_steps: int        # rows * cols — the dense sweep
    launched_steps: int    # rows * seq_grid
    executed_steps: int    # sum(row_counts) — steps that do MXU work

    @property
    def work_ratio(self) -> float:
        """Dense grid steps per executed pruned step (≥ 1)."""
        if self.executed_steps == 0:
            return float("inf") if self.full_steps else 1.0
        return self.full_steps / self.executed_steps


def _profile(rows, cols, counts) -> GridProfile:
    counts = tuple(int(max(0, c)) for c in counts)
    seq = max(counts) if counts else 0
    return GridProfile(rows=rows, cols=cols, row_counts=counts, seq_grid=seq,
                       full_steps=rows * cols, launched_steps=rows * seq,
                       executed_steps=sum(counts))


def kv_profile(*, nq, nk, br, bc, causal, rel_offset, window) -> GridProfile:
    """Work profile of the fwd/dq orientation (rows = q blocks)."""
    counts = []
    for i in range(nq):
        lo, hi = kv_block_bounds(i, br=br, bc=bc, nk=nk, causal=causal,
                                 rel_offset=rel_offset, window=window)
        counts.append(hi - lo + 1)
    return _profile(nq, nk, counts)


def q_profile(*, nq, nk, br, bc, causal, rel_offset, window) -> GridProfile:
    """Work profile of the dkv orientation (rows = kv blocks)."""
    counts = []
    for j in range(nk):
        lo, hi = q_block_bounds(j, br=br, bc=bc, nq=nq, causal=causal,
                                rel_offset=rel_offset, window=window)
        counts.append(hi - lo + 1)
    return _profile(nk, nq, counts)
