"""Pure-jnp reference oracle for the chunk attention kernel.

This is the ground truth every Pallas kernel is validated against
(``tests/test_kernels.py``) and the implementation the CPU dry-run lowers
(identical FLOPs to the kernel; see DESIGN.md §6).

Semantics: *partial* (chunk) attention. Given a query chunk and a key/value
chunk with absolute position offsets, return the attention output **and the
log-sum-exp** of the (masked) scores so partial results from different KV
chunks can be merged exactly (FlashAttention-2 online-softmax algebra,
re-associated).

Masking is declarative: ``mask`` is a :class:`repro.core.mask.MaskSpec`
(full / causal / sliding_window / prefix_lm / document); per-token segment
IDs for document masking arrive as ``q_segments``/``kv_segments`` arrays of
shape (B, Tq)/(B, Tk). The pre-MaskSpec ``causal``/``q_offset``/
``kv_offset``/``window`` kwargs still work at this oracle level (they build
the equivalent spec); ``q_offset``/``kv_offset`` passed *alongside* a spec
shift it — that is how the chunked scan walks its KV window with a traced
offset.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import mask as mk
from repro.core.mask import MaskSpec

NEG_INF = -1e30  # large-negative instead of -inf: keeps grads NaN-free


def _allow(spec: MaskSpec, Tq, Tk, q_offset, kv_offset, q_segments,
           kv_segments):
    """Attend-mask (Tq, Tk) or (B, Tq, Tk), or None when nothing is masked.
    ``q_offset``/``kv_offset`` (possibly traced) shift the spec's chunk
    positions."""
    if not spec.needs_mask:
        return None
    q_pos = spec.q_offset + q_offset + jnp.arange(Tq)
    kv_pos = spec.kv_offset + kv_offset + jnp.arange(Tk)
    qs = ks = None
    if spec.document and q_segments is not None and kv_segments is not None:
        qs = jnp.asarray(q_segments)[:, :, None]       # (B, Tq, 1)
        ks = jnp.asarray(kv_segments)[:, None, :]      # (B, 1, Tk)
    return spec.allow(q_pos[:, None], kv_pos[None, :], qs, ks)


def _apply(s, m):
    """Apply attend-mask ``m`` to scores ``s`` (B, H, Tq, Tk)."""
    if m is None:
        return s
    m = m[None, None] if m.ndim == 2 else m[:, None]
    return jnp.where(m, s, NEG_INF)


def chunk_attn_ref(q, k, v, *, mask: MaskSpec | None = None,
                   causal: bool = False, q_offset=0, kv_offset=0,
                   window: int = 0, scale: float | None = None,
                   q_segments=None, kv_segments=None):
    """Partial attention over one (q-chunk, kv-chunk) pair.

    Args:
      q: (B, Tq, Hq, D); k, v: (B, Tk, Hkv, Dk/Dv). Hq % Hkv == 0 (GQA).
      mask: declarative MaskSpec (preferred). Legacy ``causal``/``window``
        kwargs build the equivalent spec when ``mask`` is None.
      q_offset/kv_offset: extra absolute-position shift of each chunk
        (added to the spec's own offsets; may be traced).
      scale: score scale; default 1/sqrt(Dk).
      q_segments/kv_segments: (B, Tq)/(B, Tk) int32 document IDs.

    Returns:
      o:   (B, Tq, Hq, Dv) — softmax(scores) @ v over *this chunk only*
      lse: (B, Tq, Hq)     — log-sum-exp of masked scores (NEG_INF if all
                             masked; o is 0 there).
    """
    spec = mk.as_spec(mask, causal=causal, window=window)
    B, Tq, Hq, D = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if g > 1:
        kf = jnp.repeat(kf, g, axis=2)
        vf = jnp.repeat(vf, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", qf, kf) * scale
    s = _apply(s, _allow(spec, Tq, Tk, q_offset, kv_offset, q_segments,
                         kv_segments))
    mx = jnp.max(s, axis=-1)                         # (B,H,Tq)
    mx_safe = jnp.maximum(mx, NEG_INF / 2)
    p = jnp.exp(s - mx_safe[..., None])
    l = jnp.sum(p, axis=-1)
    lse = jnp.where(mx <= NEG_INF / 2, NEG_INF, mx_safe + jnp.log(l))
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    denom = jnp.where(l == 0.0, 1.0, l)
    o = o / denom.transpose(0, 2, 1)[..., None]
    o = jnp.where((mx <= NEG_INF / 2).transpose(0, 2, 1)[..., None], 0.0, o)
    return o.astype(q.dtype), lse.transpose(0, 2, 1)  # lse: (B,Tq,Hq)


def merge_ref(o1, lse1, o2, lse2):
    """Exact online-softmax merge of two partial results (the paper's
    ``rescale``). Shapes: o (B,T,H,D), lse (B,T,H)."""
    mx = jnp.maximum(lse1, lse2)
    mx = jnp.maximum(mx, NEG_INF)                    # both-empty guard
    w1 = jnp.exp(lse1 - mx)
    w2 = jnp.exp(lse2 - mx)
    den = w1 + w2
    den_safe = jnp.where(den == 0.0, 1.0, den)
    o = (o1.astype(jnp.float32) * w1[..., None] +
         o2.astype(jnp.float32) * w2[..., None]) / den_safe[..., None]
    lse = jnp.where(den == 0.0, NEG_INF, mx + jnp.log(den_safe))
    return o.astype(o1.dtype), lse


def full_attn_ref(q, k, v, *, mask: MaskSpec | None = None,
                  causal: bool = True, window: int = 0,
                  scale: float | None = None, segments=None):
    """Monolithic softmax attention — the end-to-end oracle. ``segments``
    (B, T) applies to both sides (self-attention)."""
    if mask is None:
        mask = MaskSpec(causal=bool(causal), window=int(window or 0))
    o, _ = chunk_attn_ref(q, k, v, mask=mask, scale=scale,
                          q_segments=segments, kv_segments=segments)
    return o


def chunk_attn_bwd_ref(q, k, v, o, lse, do, *, mask: MaskSpec | None = None,
                       causal=False, q_offset=0, kv_offset=0, window=0,
                       scale=None, delta=None, q_segments=None,
                       kv_segments=None):
    """Reference backward for one chunk given saved (o, lse): FA2 bwd math.

    ``delta = rowsum(o ⊙ do)`` (B,T,H) may be precomputed and passed (the
    distributed helper path ships delta instead of the full ``o``, saving
    a factor-D of communication). Returns (dq, dk, dv). Note dk/dv are for
    *this* kv chunk; the distributed layer routes them back to the owner.
    """
    spec = mk.as_spec(mask, causal=causal, window=window)
    B, Tq, Hq, D = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    qf, kf, vf = (x.astype(jnp.float32) for x in (q, k, v))
    of, dof = o.astype(jnp.float32), do.astype(jnp.float32)
    kr = jnp.repeat(kf, g, axis=2) if g > 1 else kf
    vr = jnp.repeat(vf, g, axis=2) if g > 1 else vf
    s = jnp.einsum("bqhd,bkhd->bhqk", qf, kr) * scale
    s = _apply(s, _allow(spec, Tq, Tk, q_offset, kv_offset, q_segments,
                         kv_segments))
    # p = exp(s - lse): rows with lse == NEG_INF contribute 0
    lse_b = lse.transpose(0, 2, 1)[..., None]        # (B,H,Tq,1)
    p = jnp.where(lse_b <= NEG_INF / 2, 0.0, jnp.exp(s - lse_b))
    dv_h = jnp.einsum("bhqk,bqhd->bkhd", p, dof)
    dp = jnp.einsum("bqhd,bkhd->bhqk", dof, vr)
    if delta is None:
        delta = jnp.sum(of * dof, axis=-1)               # (B,Tq,H)
    dlt = delta.astype(jnp.float32).transpose(0, 2, 1)[..., None]  # (B,H,Tq,1)
    ds = p * (dp - dlt) * scale
    dq = jnp.einsum("bhqk,bkhd->bqhd", ds, kr)
    dk_h = jnp.einsum("bhqk,bqhd->bkhd", ds, qf)
    if g > 1:
        dk_h = dk_h.reshape(B, Tk, Hkv, g, D).sum(axis=3)
        dv_h = dv_h.reshape(B, Tk, Hkv, g, -1).sum(axis=3)
    return dq.astype(q.dtype), dk_h.astype(k.dtype), dv_h.astype(v.dtype)
