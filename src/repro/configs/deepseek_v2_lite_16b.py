"""DeepSeek-V2-Lite (16B) — MLA (kv_lora=512) + MoE [arXiv:2405.04434].

Assignment note (DESIGN.md §6): the pool line says both "MoE 64e top-6" and
"160 routed"; real V2-Lite has 64 routed experts (V2-full has 160). We
follow the primary spec: 64 routed + 2 shared, top-6."""
from repro.core.config import AttnConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", arch_type="moe",
    n_layers=27, d_model=2048, d_ff=0, vocab=102400,
    attn=AttnConfig(n_heads=16, n_kv_heads=16, head_dim=128,
                    kv_lora_rank=512, q_lora_rank=0, qk_rope_head_dim=64,
                    v_head_dim=128),
    moe=MoEConfig(n_routed=64, n_shared=2, top_k=6, d_expert=1408,
                  d_dense_ff=10944, n_dense_layers=1),
    citation="arXiv:2405.04434",
)
