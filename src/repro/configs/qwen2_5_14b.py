"""Qwen2.5-14B — dense GQA with QKV bias [hf:Qwen/Qwen2.5-0.5B]."""
from repro.core.config import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", arch_type="dense",
    n_layers=48, d_model=5120, d_ff=13824, vocab=152064,
    attn=AttnConfig(n_heads=40, n_kv_heads=8, head_dim=128, qkv_bias=True,
                    rope_theta=1e6),
    tie_embeddings=False,
    citation="hf:Qwen/Qwen2.5-0.5B",
)
