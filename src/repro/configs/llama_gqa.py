"""LLaMA-GQA — LLaMA-7B with 8 kv heads (paper §4, Table 1)."""
from repro.core.config import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama-gqa", arch_type="dense",
    n_layers=32, d_model=4096, d_ff=11008, vocab=32000,
    attn=AttnConfig(n_heads=32, n_kv_heads=8, head_dim=128),
    tie_embeddings=False,
    citation="paper §4 / arXiv:2305.13245",
)
