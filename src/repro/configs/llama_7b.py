"""LLaMA-7B — the paper's primary evaluation model (§4, Table 1)."""
from repro.core.config import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama-7b", arch_type="dense",
    n_layers=32, d_model=4096, d_ff=11008, vocab=32000,
    attn=AttnConfig(n_heads=32, n_kv_heads=32, head_dim=128),
    tie_embeddings=False,
    citation="arXiv:2302.13971 (paper §4)",
)
