"""DeepSeek-V3 (671B) — MLA + 256-expert MoE + MTP [arXiv:2412.19437]."""
from repro.core.config import AttnConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", arch_type="moe",
    n_layers=61, d_model=7168, d_ff=0, vocab=129280,
    attn=AttnConfig(n_heads=128, n_kv_heads=128, head_dim=128,
                    kv_lora_rank=512, q_lora_rank=1536, qk_rope_head_dim=64,
                    v_head_dim=128),
    moe=MoEConfig(n_routed=256, n_shared=1, top_k=8, d_expert=2048,
                  d_dense_ff=18432, n_dense_layers=3),
    mtp_depth=1,
    citation="arXiv:2412.19437",
)
