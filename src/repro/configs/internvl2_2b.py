"""InternVL2-2B — InternViT (stub frontend) + InternLM2-1.8B decoder
[arXiv:2404.16821]. input_specs provides 256 precomputed patch embeddings."""
from repro.core.config import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", arch_type="vlm",
    n_layers=24, d_model=2048, d_ff=8192, vocab=92553,
    attn=AttnConfig(n_heads=16, n_kv_heads=8, head_dim=128),
    n_image_tokens=256,
    citation="arXiv:2404.16821",
)
