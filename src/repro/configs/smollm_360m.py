"""SmolLM-360M — llama-arch small dense LM [hf:HuggingFaceTB/SmolLM-135M]."""
from repro.core.config import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", arch_type="dense",
    n_layers=32, d_model=960, d_ff=2560, vocab=49152,
    attn=AttnConfig(n_heads=15, n_kv_heads=5, head_dim=64),
    citation="hf:HuggingFaceTB/SmolLM-135M",
)
