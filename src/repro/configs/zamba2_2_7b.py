"""Zamba2-2.7B — Mamba2 backbone + shared attention block every 6 layers,
operating on concat(h, embed) = 2·d_model [arXiv:2411.15242]."""
from repro.core.config import AttnConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", arch_type="hybrid",
    n_layers=54, d_model=2560, d_ff=10240, vocab=32000,
    attn=AttnConfig(n_heads=32, n_kv_heads=32, head_dim=160),  # 32·160 = 2·d
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=128),
    hybrid_period=6,
    citation="arXiv:2411.15242",
)
