"""LLaMA-16H — fewer-heads variant: 16 heads, d=2048, 64 layers (paper §4.2)."""
from repro.core.config import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama-16h", arch_type="dense",
    n_layers=64, d_model=2048, d_ff=11008, vocab=32000,
    attn=AttnConfig(n_heads=16, n_kv_heads=16, head_dim=128),
    tie_embeddings=False,
    citation="paper §4.2 / Liu et al. 2021",
)
