"""LLaMA-33H — LLaMA-7B with 33 heads (irregular head count, paper §4.2)."""
from repro.core.config import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama-33h", arch_type="dense",
    n_layers=32, d_model=4096, d_ff=11008, vocab=32000,
    attn=AttnConfig(n_heads=33, n_kv_heads=33, head_dim=128),
    tie_embeddings=False,
    citation="paper §4.2",
)
