"""Qwen3-8B — dense GQA with qk_norm [hf:Qwen/Qwen3-8B]."""
from repro.core.config import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b", arch_type="dense",
    n_layers=36, d_model=4096, d_ff=12288, vocab=151936,
    attn=AttnConfig(n_heads=32, n_kv_heads=8, head_dim=128, qk_norm=True,
                    rope_theta=1e6),
    tie_embeddings=False,
    citation="hf:Qwen/Qwen3-8B",
)
