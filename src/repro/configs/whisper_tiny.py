"""Whisper-tiny — encoder-decoder audio backbone; mel/conv frontend is a
stub (input_specs provides 1500 frame embeddings, padded to 1536)
[arXiv:2212.04356]."""
from repro.core.config import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", arch_type="audio",
    n_layers=4, n_enc_layers=4, d_model=384, d_ff=1536, vocab=51865,
    attn=AttnConfig(n_heads=6, n_kv_heads=6, head_dim=64),
    n_audio_frames=1536,
    citation="arXiv:2212.04356",
)
