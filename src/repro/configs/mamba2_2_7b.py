"""Mamba2-2.7B — SSD (state-space duality), attention-free [arXiv:2405.21060]."""
from repro.core.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", arch_type="ssm",
    n_layers=64, d_model=2560, d_ff=0, vocab=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=128),
    citation="arXiv:2405.21060",
)
