"""Draft-model pairings for speculative decoding (serve/speculative.py).

A pairing names, for each target architecture in the zoo, the small
config worth drafting with: same tokenizer family / vocab so draft token
ids are target token ids, and 10-20x fewer parameters so a draft step
costs a fraction of a verify row.  The determinism contract makes the
pairing a pure throughput knob — a bad draft lowers tokens/step, never
changes the emitted stream — so pairings are suggestions, not
correctness requirements.

    from repro.configs.spec_pairs import draft_arch_for
    draft_arch_for("llama-7b")   # -> "smollm-360m"
"""
from __future__ import annotations

from typing import Optional

# target arch id -> draft arch id (both resolvable by core.config.get_config)
PAIRS = {
    "llama-7b": "smollm-360m",
    "llama-33h": "smollm-360m",
    "llama-16h": "smollm-360m",
    "llama-gqa": "smollm-360m",
    "qwen3-8b": "smollm-360m",
    "qwen2.5-14b": "smollm-360m",
    "qwen1.5-32b": "smollm-360m",
}


def draft_arch_for(target_arch: str) -> Optional[str]:
    """The paired draft config id for ``target_arch``, or ``None`` when
    the zoo has no sensible pairing (fall back to self-speculation)."""
    return PAIRS.get(target_arch)
