"""Architecture registry: one module per assigned architecture (plus the
paper's own LLaMA-7B evaluation variants). Select with ``--arch <id>``."""
