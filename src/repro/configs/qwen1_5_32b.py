"""Qwen1.5-32B — dense MHA-ish (kv=40) with QKV bias [hf:Qwen/Qwen1.5-0.5B]."""
from repro.core.config import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", arch_type="dense",
    n_layers=64, d_model=5120, d_ff=27392, vocab=152064,
    attn=AttnConfig(n_heads=40, n_kv_heads=40, head_dim=128, qkv_bias=True),
    tie_embeddings=False,
    citation="hf:Qwen/Qwen1.5-0.5B",
)
