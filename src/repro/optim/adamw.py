"""AdamW with warmup-cosine schedule and global-norm clipping (pure JAX).

Optimizer state mirrors the parameter pytree (and therefore the parameter
FSDP sharding — the m/v moments shard identically to their parameter).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.core.config import TrainConfig


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


def init(params) -> AdamWState:
    zeros = compat.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=compat.tree_map(jnp.copy, zeros))


def schedule(step, tc: TrainConfig):
    warm = tc.lr * (step + 1) / max(tc.warmup_steps, 1)
    prog = jnp.clip((step - tc.warmup_steps) /
                    max(tc.total_steps - tc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * tc.lr * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < tc.warmup_steps, warm, cos)


def clip_by_global_norm(grads, max_norm):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-6))
    return compat.tree_map(lambda g: g * scale.astype(g.dtype), grads), gn


def update(grads, state: AdamWState, params, tc: TrainConfig):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, tc.max_grad_norm)
    step = state.step + 1
    lr = schedule(state.step, tc)
    b1, b2 = tc.beta1, tc.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        upd = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + tc.eps)
        upd = upd + tc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * upd).astype(p.dtype), m2, v2

    out = compat.tree_map(upd, params, grads, state.m, state.v)
    new_p = compat.tree_map(lambda t: t[0], out, is_leaf=lambda t:
                         isinstance(t, tuple) and len(t) == 3)
    new_m = compat.tree_map(lambda t: t[1], out, is_leaf=lambda t:
                         isinstance(t, tuple) and len(t) == 3)
    new_v = compat.tree_map(lambda t: t[2], out, is_leaf=lambda t:
                         isinstance(t, tuple) and len(t) == 3)
    return new_p, AdamWState(step, new_m, new_v), {"lr": lr, "gnorm": gnorm}
