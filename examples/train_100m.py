"""End-to-end driver (deliverable b): train a ~100M-parameter LLaMA-family
model for a few hundred steps on the synthetic Markov stream, with the
paper's full configuration — DISTFLASHATTN balanced schedule + overlap +
rematerialization-aware checkpointing — and checkpointing to disk.

    PYTHONPATH=src python examples/train_100m.py [--steps 300] [--fast]

``--fast`` shrinks steps/seq for a quick CPU sanity pass; the default is
the real few-hundred-step run (expect ~1 h on this single-core host).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core.config import AttnConfig, ModelConfig
from repro.launch import train as train_cli


def config_100m():
    return ModelConfig(
        name="llama-100m", arch_type="dense",
        n_layers=12, d_model=768, d_ff=2048, vocab=16384,
        attn=AttnConfig(n_heads=12, n_kv_heads=4, head_dim=64),
        dtype="float32",
        citation="paper §4 scaling family (examples)",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()

    cfg = config_100m()
    print(f"llama-100m params ≈ {cfg.param_count()/1e6:.1f}M")

    # register the config so the generic CLI can load it
    import repro.configs as C
    import types
    mod = types.ModuleType("repro.configs.llama_100m")
    mod.CONFIG = cfg
    sys.modules["repro.configs.llama_100m"] = mod

    steps = 30 if args.fast else args.steps
    seq = 128 if args.fast else 256
    train_cli.main([
        "--arch", "llama-100m", "--steps", str(steps), "--seq", str(seq),
        "--batch", "2", "--lr", "6e-4", "--schedule", "balanced",
        "--remat", "remat_aware", "--ckpt-dir", "ckpts/llama-100m",
        "--ckpt-every", "100", "--log-every", "10",
    ])


if __name__ == "__main__":
    main()
