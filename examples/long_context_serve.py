"""Long-context serving example: batched requests against a sequence-
sharded KV cache, full-attention vs the paper's Appendix-F sliding-window
variant, over 8 (forced host) devices.

    python examples/long_context_serve.py          # sets its own XLA_FLAGS
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
import sys  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time  # noqa: E402

import jax  # noqa: E402

from repro.core.config import ShapeSpec, get_config, smoke_config  # noqa
import dataclasses  # noqa: E402
from repro.data.pipeline import SyntheticTokens  # noqa: E402
from repro.models.transformer import Runtime, build_model  # noqa: E402
from repro.parallel.sharding import make_parallel_config  # noqa: E402
from repro.serve.engine import Engine  # noqa: E402


def run(window: int):
    cfg = smoke_config(get_config("qwen3-8b"))
    if window:
        cfg = cfg.replace(attn=dataclasses.replace(cfg.attn, window=window))
    mesh = jax.make_mesh((2, 4), ("data", "model"))   # 4-way seq parallel
    shape = ShapeSpec("lc", 1024, 4, "prefill")       # 1K-token prompts
    par = make_parallel_config(mesh, shape)
    model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))
    params = model.init(jax.random.PRNGKey(0))
    batch = SyntheticTokens(cfg, shape, par, mesh).batch(0)
    eng = Engine(model, params)
    t0 = time.time()
    toks, _ = eng.generate(batch, n_tokens=8)
    dt = time.time() - t0
    tag = f"window={window}" if window else "full attention"
    print(f"[{tag:>16}] prefill 4×1024 + decode 8 tok: {dt:.2f}s; "
          f"tokens: {[int(t) for t in toks[0]]}")


if __name__ == "__main__":
    run(window=0)
    run(window=256)   # Appendix-F sliding window: ring truncated to
    #                   neighbor shards, decode masks the old cache
