"""Long-context serving example: continuous batching over a paged KV cache
(staggered arrivals, per-request lengths), full-attention vs the paper's
Appendix-F sliding-window variant, over 8 (forced host) devices — plus the
legacy fixed-slot dense-cache engine for an A/B of the same prompts, and a
shared-system-prompt pass showing the content-addressed prefix cache
(identical prefixes stored once, chunked prefill skipping cached blocks).

With ``--chaos-seed N`` the continuous-batching pass runs under a seeded
fault storm (serve/faults.py: pool squeezes, NaN logits, dropped steps,
preemption storms …) with deadlines, admission control, and always-on
invariant auditing — demonstrating that every request still reaches a
definite terminal status and fault-free streams are untouched.

    python examples/long_context_serve.py          # sets its own XLA_FLAGS
    python examples/long_context_serve.py --prefill-chunk-tokens 128
    python examples/long_context_serve.py --no-prefix-cache
    python examples/long_context_serve.py --chaos-seed 7
    python examples/long_context_serve.py --spec-depth 4 --self-spec
    python examples/long_context_serve.py --spec-depth 4 \
        --draft-config smollm-360m
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
import sys  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core.config import ShapeSpec, get_config, smoke_config  # noqa
import dataclasses  # noqa: E402
from repro.data.pipeline import SyntheticTokens  # noqa: E402
from repro.models.transformer import Runtime, build_model  # noqa: E402
from repro.parallel.sharding import make_parallel_config  # noqa: E402
from repro.serve.engine import Engine, FixedSlotEngine  # noqa: E402


def run(window: int, *, chunk_tokens: int = 256, prefix_cache: bool = True,
        chaos_seed: int = None, spec_depth: int = 0, self_spec: bool = False,
        draft_config: str = None):
    cfg = smoke_config(get_config("qwen3-8b"))
    if window:
        cfg = cfg.replace(attn=dataclasses.replace(cfg.attn, window=window))
    mesh = jax.make_mesh((2, 4), ("data", "model"))   # 4-way seq parallel
    shape = ShapeSpec("lc", 1024, 4, "prefill")       # 1K-token prompts
    par = make_parallel_config(mesh, shape)
    model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))
    params = model.init(jax.random.PRNGKey(0))
    batch = SyntheticTokens(cfg, shape, par, mesh).batch(0)
    prompts = np.asarray(batch["tokens"])

    # --- speculative decoding: self-speculation (prompt-lookup n-grams)
    # or a paired draft model with its own paged cache.  The acceptance
    # rule keeps the emitted streams token-identical to vanilla decode —
    # the draft is purely a tokens/step knob
    spec = draft = None
    if spec_depth > 0:
        from repro.serve.speculative import ModelDraft, SpecConfig
        if self_spec or draft_config is None:
            spec = SpecConfig(depth=spec_depth, mode="ngram")
        else:
            d_cfg = smoke_config(get_config(draft_config))
            d_model = build_model(d_cfg, Runtime(mesh=mesh, par=par,
                                                 impl="ref"))
            d_params = d_model.init(jax.random.PRNGKey(7))
            spec = SpecConfig(depth=spec_depth, mode="model",
                              draft_arch=d_cfg.name)
            draft = ModelDraft(d_model, d_params, block_size=64,
                               n_blocks=96, max_batch=4)

    # --- continuous batching: requests arrive over time, with different
    # budgets, into a paged pool (mixed in-flight lengths per step).
    # Under --chaos-seed the same pass runs chaos-hardened: bounded queue,
    # deadlines, retries, quarantine, per-step invariant audit
    faults = None
    if chaos_seed is not None:
        from repro.serve.faults import FaultInjector
        faults = FaultInjector.seeded(chaos_seed, n_steps=24, rate=0.5)
    eng = Engine(model, params, max_batch=4, block_size=64, n_blocks=80,
                 prefill_chunk_tokens=chunk_tokens,
                 prefix_cache=prefix_cache,
                 max_queue=8, audit=chaos_seed is not None, faults=faults,
                 spec=spec, draft=draft)
    t0 = time.time()
    rids = []
    for i in range(prompts.shape[0]):
        rids.append(eng.submit(prompts[i], max_new_tokens=4 + 2 * i,
                               deadline_steps=200 if chaos_seed is not None
                               else None))
        eng.step()                     # staggered: admit + decode as we go
    out = eng.run()
    dt = time.time() - t0
    tag = f"window={window}" if window else "full attention"
    total = sum(len(out[r]) for r in rids)
    print(f"[{tag:>16}] paged: 4×1024-token prompts, staggered, "
          f"{total} tokens in {dt:.2f}s over {eng.stats()['steps']} steps; "
          f"req0: {[int(t) for t in out[rids[0]]]}")
    if spec is not None:
        s = eng.stats()
        print(f"[{tag:>16}] speculative({spec.mode}, depth={spec.depth}): "
              f"proposed={s['spec_proposed']} accepted={s['spec_accepted']} "
              f"rollbacks={s['spec_rollbacks']} "
              f"acceptance={s['spec_acceptance']:.2f} — emitted streams "
              f"identical to vanilla decode by construction")
    if chaos_seed is not None:
        s = eng.stats()
        states = {r: eng.requests[r].state for r in rids}
        print(f"[{tag:>16}] chaos(seed={chaos_seed}): "
              f"faults={s['faults']} terminal={states} "
              f"shed={s['shed']} retried={s['retried']} "
              f"quarantined={s['quarantined']} expired={s['expired']} "
              f"watchdog_trips={s['watchdog_trips']} "
              f"audit_passes={s['audit_passes']}")
        eng.cache.allocator.check_conservation()
        print(f"[{tag:>16}] chaos: every request terminal, allocator "
              f"conservation holds after the storm")

    # --- shared system prompt: the same 1024-token prefix, four different
    # user turns.  With the prefix cache the first request prefills the
    # prefix once; the other three *share* its blocks (chunked prefill
    # starts at the first uncached position) and the engine stores the
    # prefix exactly once
    if prefix_cache:
        system = prompts[0]
        turns = [np.concatenate([system, prompts[1][:64 * (i + 1)]])
                 for i in range(4)]
        t0 = time.time()
        rs = [eng.submit(p, max_new_tokens=4) for p in turns]
        eng.run()
        dt = time.time() - t0
        pc = eng.stats()["prefix_cache"]
        print(f"[{tag:>16}] shared system prompt: 4 turns × "
              f"{len(system)}-token prefix in {dt:.2f}s; "
              f"hit_tokens={pc['hit_tokens']} "
              f"stored_blocks={eng.stats()['cache_blocks']} "
              f"forks={eng.stats()['forks']} "
              f"dedup_swaps={eng.stats()['dedup_swaps']}")

    # --- fixed-slot dense oracle on the same prompts (uniform budget;
    # 1024 + 6 is NOT a multiple of the 4 seq shards — the padded cache
    # rounds itself up)
    t0 = time.time()
    toks, _ = FixedSlotEngine(model, params).generate(batch, n_tokens=6)
    dt = time.time() - t0
    agree = all(int(a) == int(b)
                for a, b in zip(np.asarray(toks)[0], out[rids[0]][:4]))
    print(f"[{tag:>16}] fixed-slot oracle: decode 6 tok: {dt:.2f}s; "
          f"first-request streams agree: {agree}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--prefill-chunk-tokens", type=int, default=256,
                    help="chunked-prefill budget per engine step "
                         "(0 = whole-prompt prefill)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable content-addressed prefix sharing")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="run the continuous-batching pass under a seeded "
                         "fault storm (deterministic; same seed, same "
                         "storm) with auditing + deadlines enabled")
    ap.add_argument("--spec-depth", type=int, default=0,
                    help="speculative draft depth (0 = vanilla decode)")
    ap.add_argument("--self-spec", action="store_true",
                    help="n-gram prompt-lookup self-speculation")
    ap.add_argument("--draft-config", default=None,
                    help="draft arch id (e.g. smollm-360m) for model-based "
                         "speculation; omit for self-speculation")
    args = ap.parse_args()
    kw = dict(chunk_tokens=args.prefill_chunk_tokens,
              prefix_cache=not args.no_prefix_cache,
              chaos_seed=args.chaos_seed, spec_depth=args.spec_depth,
              self_spec=args.self_spec, draft_config=args.draft_config)
    run(window=0, **kw)
    run(window=256, **kw)   # Appendix-F sliding window: prefill ring
    #                         truncated, paged decode masks beyond the
    #                         window per request — and the paged engine
    #                         *reclaims* blocks wholly below the window
