"""Quickstart: train a tiny DISTFLASHATTN-powered LLaMA-family model for a
few steps on CPU, then generate from it.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core.config import ShapeSpec, TrainConfig, get_config, smoke_config
from repro.data.pipeline import SyntheticTokens
from repro.launch.mesh import make_local_mesh
from repro.models.transformer import Runtime, build_model
from repro.optim import adamw
from repro.parallel.sharding import make_parallel_config
from repro.serve.engine import Engine
from repro.train.step import make_train_step


def main():
    cfg = smoke_config(get_config("smollm-360m")).replace(vocab=128)
    mesh = make_local_mesh()
    shape = ShapeSpec("quick", 64, 4, "train")
    # balanced schedule + rematerialization-aware checkpointing — the
    # paper's configuration — are the defaults
    par = make_parallel_config(mesh, shape)
    model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))

    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.init(params)
    step = jax.jit(make_train_step(model, TrainConfig(lr=3e-3,
                                                      warmup_steps=5,
                                                      total_steps=40)))
    ds = SyntheticTokens(cfg, shape, par, mesh)
    for i in range(40):
        params, opt, m = step(params, opt, ds.batch(i))
        if i % 10 == 0 or i == 39:
            print(f"step {i:3d}  loss {float(m['loss']):.4f}")

    print("\ngenerating…")
    eng = Engine(model, params)                  # paged continuous batching
    toks = eng.generate(ds.batch(0), n_tokens=8)
    print("greedy continuation of request 0:", [int(t) for t in toks[0]])


if __name__ == "__main__":
    main()
