"""Ablation example (paper §4.5): the three schedules produce identical
losses while their communication profiles differ; prints the per-schedule
collective bytes of one attention layer from the compiled HLO.

    python examples/schedule_ablation.py           # sets its own XLA_FLAGS
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
import sys  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.analysis.roofline import collective_stats  # noqa: E402
from repro.core.dist_attention import DistAttnSpec, dist_attn_fwd  # noqa
from repro.kernels.ref import full_attn_ref  # noqa: E402
from repro.core import mask as mask_lib  # noqa: E402


def main():
    mesh = jax.make_mesh((1, 8), ("data", "model"))
    B, N, H, D = 1, 2048, 8, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(kk, (B, N, H, D)) for kk in ks)
    o_ref = full_attn_ref(q, k, v, causal=True)
    print(f"{'schedule':>10} {'max err':>12} {'coll bytes/layer':>18} ops")
    for sched in ("ring", "balanced", "ulysses", "rsa"):
        spec = DistAttnSpec(axis="model", axis_size=8, schedule=sched,
                            mask=mask_lib.causal())
        f = jax.jit(lambda q, k, v: dist_attn_fwd(
            q, k, v, mesh=mesh, spec=spec, batch_axes=None)[0])
        txt = f.lower(q, k, v).compile().as_text()
        st = collective_stats(txt)
        err = float(jnp.abs(f(q, k, v) - o_ref).max())
        print(f"{sched:>10} {err:12.2e} {st.total_bytes:18,.0f} "
              f"{st.op_counts}")


if __name__ == "__main__":
    main()
