"""Per-architecture smoke tests (assignment requirement): a REDUCED variant
of each family (2 layers, d_model ≤ 512, ≤ 4 experts) runs one forward +
one train step on CPU; output shapes and finiteness asserted."""
import jax
import jax.numpy as jnp
import pytest

from repro import compat
from repro.core.config import (ARCH_IDS, TrainConfig, get_config,
                               smoke_config, ShapeSpec)
from repro.data.pipeline import SyntheticTokens, cache_specs
from repro.models.transformer import Runtime, build_model
from repro.optim import adamw
from repro.parallel.sharding import make_parallel_config
from repro.train.step import make_train_step

SHAPE = ShapeSpec("smoke", 64, 2, "train")

# the heaviest smoke params (deep MoE/MTP stacks) are slow-marked so the
# default tier-1 run stays under ~5 minutes; tools/run_tier1.sh --all runs
# them too
SLOW_ARCHS = {"deepseek-v2-lite-16b", "deepseek-v3-671b"}
ARCH_PARAMS = [pytest.param(a, marks=pytest.mark.slow) if a in SLOW_ARCHS
               else a for a in ARCH_IDS]


def _setup(arch):
    cfg = smoke_config(get_config(arch))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    par = make_parallel_config(mesh, SHAPE)
    model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))
    params = model.init(jax.random.PRNGKey(0))
    batch = SyntheticTokens(cfg, SHAPE, par, mesh).batch(0)
    return cfg, model, params, batch, mesh, par


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_config_matches_family(arch):
    cfg = smoke_config(get_config(arch))
    full = get_config(arch)
    assert cfg.arch_type == full.arch_type
    assert cfg.n_layers == 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_routed <= 4
    # family-defining features preserved
    if full.attn:
        assert (cfg.attn.is_mla == full.attn.is_mla
                and cfg.attn.qkv_bias == full.attn.qkv_bias
                and cfg.attn.qk_norm == full.attn.qk_norm)


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_forward_and_train_step(arch):
    cfg, model, params, batch, mesh, par = _setup(arch)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == () and bool(jnp.isfinite(loss)), arch
    step = make_train_step(model, TrainConfig(warmup_steps=1, total_steps=10))
    opt = adamw.init(params)
    p2, o2, m = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(m["loss"]))
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(p2)), arch
    # params actually moved
    moved = any(bool(jnp.any(a != b)) for a, b in
                zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved, arch


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_prefill_and_decode_shapes(arch):
    cfg, model, params, batch, mesh, par = _setup(arch)
    B = SHAPE.global_batch
    logits, _ = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (B, 1, cfg.vocab)
    assert not bool(jnp.isnan(logits).any()), arch
    dshape = ShapeSpec("smoke_dec", 64, B, "decode")
    dpar = make_parallel_config(mesh, dshape)
    cstruct, _ = cache_specs(cfg, dshape, dpar)
    cache = compat.tree_map(lambda s: jnp.zeros(s.shape, s.dtype), cstruct)
    dmodel = build_model(cfg, Runtime(mesh=mesh, par=dpar, impl="ref"))
    lg, cache2 = jax.jit(dmodel.decode)(
        params, cache, {"token": jnp.zeros((B, 1), jnp.int32),
                        "pos": jnp.int32(64)})
    assert lg.shape == (B, 1, cfg.vocab) and not bool(jnp.isnan(lg).any())
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


def test_param_counts_match_spec():
    """Full configs approximate their nameplate sizes."""
    expect = {
        "smollm-360m": (0.30e9, 0.50e9),
        "qwen3-8b": (7e9, 9.5e9),
        "qwen2.5-14b": (12e9, 16e9),
        "qwen1.5-32b": (29e9, 36e9),
        "mamba2-2.7b": (2.2e9, 3.2e9),
        "deepseek-v2-lite-16b": (12e9, 18e9),
        "deepseek-v3-671b": (550e9, 720e9),
        "zamba2-2.7b": (2.0e9, 3.3e9),
        "internvl2-2b": (1.5e9, 2.5e9),
        "whisper-tiny": (25e6, 60e6),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
