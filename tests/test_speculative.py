"""Speculative-decoding suite: the accept/reject determinism contract.

Covers the acceptance criteria of the speculative subsystem:

  * **degenerate-tree equivalence** — ``depth=0`` (single-node tree) runs
    the verify path yet emits exactly the vanilla engine's streams;
  * **stream identity** — speculative streams (greedy AND seeded
    sampling, n-gram self-speculation AND a paired draft model) are
    token-identical to the non-speculative engine across the ``ref``,
    ``chunked-lax``, and ``pallas-interpret`` backends;
  * **rollback conservation** — rejected branches leak no blocks: target
    and draft allocators conserve through rollbacks, including under a
    seeded chaos storm;
  * unit tests for the tree-mask helpers (core/mask.tree_spec) and the
    prompt-lookup matcher (NGramDraft).
"""
import types

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import mask as mk
from repro.core.config import ShapeSpec, get_config, smoke_config
from repro.data.pipeline import SyntheticTokens
from repro.models.transformer import Runtime, build_model
from repro.parallel.sharding import make_parallel_config
from repro.serve.engine import Engine
from repro.serve.speculative import (ModelDraft, NGramDraft, NullDraft,
                                     SpecConfig, make_draft)


@pytest.fixture(scope="module")
def served():
    """One smoke model for the whole module (build+init dominates)."""
    cfg = smoke_config(get_config("smollm-360m"))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeSpec("spec", 32, 4, "prefill")
    par = make_parallel_config(mesh, shape)
    model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.asarray(
        SyntheticTokens(cfg, shape, par, mesh).batch(0)["tokens"])
    return cfg, model, params, prompts


def _drive(model, params, specs, *, spec=None, draft=None, n_blocks=32,
           max_batch=4, stagger=0, **ekw):
    """Run a list of (prompt, n, temperature, seed) to completion; returns
    (streams list, engine)."""
    eng = Engine(model, params, max_batch=max_batch, block_size=8,
                 n_blocks=n_blocks, spec=spec, draft=draft, **ekw)
    rids = []
    for prompt, n, temp, seed in specs:
        rids.append(eng.submit(prompt, max_new_tokens=n, temperature=temp,
                               seed=seed))
        for _ in range(stagger):
            eng.step()
    out = eng.run()
    return [np.asarray(out[r]) for r in rids], eng


# ==========================================================================
# unit: SpecConfig / draft sources
# ==========================================================================

def test_spec_config_validation():
    with pytest.raises(ValueError, match="depth"):
        SpecConfig(depth=-1)
    with pytest.raises(ValueError, match="mode"):
        SpecConfig(mode="telepathy")
    with pytest.raises(ValueError, match="ngram"):
        SpecConfig(ngram=0)
    assert isinstance(make_draft(SpecConfig(mode="ngram")), NGramDraft)
    assert isinstance(make_draft(SpecConfig(mode="none")), NullDraft)
    with pytest.raises(ValueError, match="ModelDraft"):
        make_draft(SpecConfig(mode="model"))


def test_ngram_draft_prompt_lookup():
    d = NGramDraft(ngram=3)

    def req(*ctx):
        return types.SimpleNamespace(context=np.asarray(ctx, np.int32))

    # trailing [1,2,3] recurs at index 1 -> propose its continuation
    assert d.propose(req(5, 1, 2, 3, 7, 8, 1, 2, 3), 2) == [7, 8]
    # continuation truncated to k
    assert d.propose(req(5, 1, 2, 3, 7, 8, 1, 2, 3), 1) == [7]
    # rightmost (freshest) earlier occurrence wins
    assert d.propose(req(1, 2, 9, 1, 2, 4, 1, 2), 1) == [4]
    # falls back to shorter n-grams before giving up
    assert d.propose(req(3, 7, 5, 3), 1) == [7]
    # no earlier occurrence of any suffix -> nothing
    assert d.propose(req(1, 2, 3, 4), 3) == []
    assert d.propose(req(1, 2, 3, 1), 0) == []


# ==========================================================================
# unit: tree masks (core/mask)
# ==========================================================================

def test_chain_parents_and_chain_spec():
    assert mk.chain_parents(4) == (-1, 0, 1, 2)
    # a chain (and the single node) degenerates to plain causal
    assert mk.tree_spec(mk.chain_parents(1)) == mk.MaskSpec(causal=True)
    assert mk.tree_spec(mk.chain_parents(5), window=7) == \
        mk.MaskSpec(causal=True, window=7)


@pytest.mark.parametrize("parents", [
    (-1,),                      # single node
    (-1, 0, 1, 2),              # chain
    (-1, 0, -1, 2),             # two branches of 2
    (-1, -1, -1),               # three singleton branches
    (-1, 0, 1, -1, 3),          # branches of 3 and 2
])
def test_tree_spec_matches_ancestor_mask(parents):
    """The MaskSpec's allow() over the verify chunk's absolute positions
    must reproduce the ground-truth ancestor matrix, with the committed
    context attendable by every node."""
    P = 6                                        # committed-context length
    K = len(parents)
    spec = mk.tree_spec(parents, prefix_len=P)
    pos = np.arange(P + K)
    m = np.asarray(spec.allow(pos[:, None], pos[None, :]))
    want = np.zeros((P + K, P + K), bool)
    want[:P, :P] = np.tril(np.ones((P, P), bool))     # context: causal
    want[P:, :P] = True                               # nodes see context
    want[P:, P:] = mk.tree_ancestor_mask(parents)
    np.testing.assert_array_equal(m[P:], want[P:])
    # context rows must never attend draft nodes
    assert not m[:P, P:].any()


def test_tree_spec_rejects_rebranching():
    with pytest.raises(ValueError, match="chains and stars"):
        mk.tree_spec((-1, 0, 0))            # node 2 re-branches off node 0
    with pytest.raises(ValueError, match="empty"):
        mk.tree_spec(())


# ==========================================================================
# degenerate-tree equivalence + stream identity
# ==========================================================================

def _specs(prompts):
    return [(prompts[0][:24], 6, 0.0, 0),       # greedy
            (prompts[1][:17], 5, 0.8, 123),     # seeded sampling
            (prompts[2][:9], 6, 0.8, 7)]


def test_degenerate_tree_equals_vanilla(served):
    """depth=0: the verify path runs (single-node tree) but must emit
    exactly the vanilla engine's streams — the bitwise anchor for the
    whole acceptance scheme."""
    cfg, model, params, prompts = served
    vanilla, _ = _drive(model, params, _specs(prompts))
    degen, eng = _drive(model, params, _specs(prompts),
                        spec=SpecConfig(depth=0, mode="none"))
    for a, b in zip(vanilla, degen):
        np.testing.assert_array_equal(a, b)
    s = eng.stats()
    assert s["spec_proposed"] == 0 and s["spec_rollbacks"] == 0


def test_ngram_speculative_stream_identity(served):
    """Self-speculation at depth 3: token-identical streams (greedy and
    seeded sampling), counters consistent, no allocator damage."""
    cfg, model, params, prompts = served
    vanilla, _ = _drive(model, params, _specs(prompts))
    spec, eng = _drive(model, params, _specs(prompts),
                       spec=SpecConfig(depth=3, mode="ngram"))
    for a, b in zip(vanilla, spec):
        np.testing.assert_array_equal(a, b)
    s = eng.stats()
    assert s["spec_accepted"] + s["spec_rejected"] == s["spec_proposed"]
    assert 0.0 <= s["spec_acceptance"] <= 1.0
    eng.cache.allocator.check_conservation()
    assert eng.cache.allocator.n_free + eng.cache.n_cache_blocks \
        == eng.cache.allocator.n_usable


def test_model_draft_acceptance_and_identity(served):
    """A ModelDraft sharing the target's params (the ceiling regime) must
    actually accept proposals (> 0), emit identical streams, finish in
    fewer engine steps than vanilla, and conserve BOTH allocators —
    including the draft's own pool after its per-request state is
    dropped."""
    cfg, model, params, prompts = served
    specs = [(prompts[0][:24], 8, 0.0, 0), (prompts[1][:17], 8, 0.0, 1)]
    vanilla, veng = _drive(model, params, specs)
    draft = ModelDraft(model, params, block_size=8, n_blocks=32,
                       max_batch=4)
    spec, eng = _drive(model, params, specs,
                       spec=SpecConfig(depth=3, mode="model"), draft=draft)
    for a, b in zip(vanilla, spec):
        np.testing.assert_array_equal(a, b)
    s = eng.stats()
    assert s["spec_accepted"] > 0, "target-params draft must accept"
    assert s["spec_acceptance"] > 0.0
    assert s["steps"] < veng.stats()["steps"], \
        "accepted proposals must reduce engine steps"
    eng.cache.allocator.check_conservation()
    draft.cache.allocator.check_conservation()
    assert not draft._slots, "terminal requests must release draft state"
    assert draft.cache.allocator.n_free + draft.cache.n_cache_blocks \
        == draft.cache.allocator.n_usable


@pytest.mark.parametrize("impl", ["ref", "chunked-lax", "pallas-interpret"])
def test_backend_stream_identity(served, impl):
    """The speculative streams are backend-invariant: each kernel backend
    reproduces the ref backend's vanilla streams exactly (greedy + seeded
    sampling) with speculation on."""
    cfg, model, params, prompts = served
    vanilla, _ = _drive(model, params, _specs(prompts))
    m2 = model if impl == "ref" else build_model(
        cfg, Runtime(mesh=model.rt.mesh, par=model.rt.par, impl=impl))
    spec, _ = _drive(m2, params, _specs(prompts),
                     spec=SpecConfig(depth=3, mode="ngram"))
    for a, b in zip(vanilla, spec):
        np.testing.assert_array_equal(a, b)


# ==========================================================================
# rollbacks under chaos: conservation + stream isolation
# ==========================================================================

@settings(max_examples=3, deadline=None)
@given(chaos_seed=st.integers(0, 10_000))
def test_rollbacks_under_chaos_conserve_and_isolate(served, chaos_seed):
    """A seeded fault storm over a speculating engine: every request
    reaches a terminal state, the allocator conserves through rejected-
    branch rollbacks AND fault recovery, and every request that finishes
    does so with its exact solo non-speculative stream."""
    from repro.serve.faults import FaultInjector
    from repro.serve.scheduler import TERMINAL_STATES
    cfg, model, params, prompts = served
    specs = [(prompts[i % 4][:(9 + 5 * i) % 24 + 4], 4 + i % 3,
              [0.0, 0.8][i % 2], i) for i in range(4)]
    solo = [_drive(model, params, [sp])[0][0] for sp in specs]
    eng = Engine(model, params, max_batch=3, block_size=8, n_blocks=24,
                 prefill_chunk_tokens=8, audit=True, max_retries=6,
                 spec=SpecConfig(depth=2, mode="ngram"),
                 faults=FaultInjector.seeded(chaos_seed, n_steps=16,
                                             rate=0.5))
    rids = [eng.submit(p, max_new_tokens=n, temperature=t, seed=s)
            for p, n, t, s in specs]
    out = eng.run()
    eng.release_faults()
    eng.cache.allocator.check_conservation()
    for rid, sol in zip(rids, solo):
        req = eng.requests[rid]
        assert req.state in TERMINAL_STATES
        got = np.asarray(out[rid])
        # chaos may truncate (expire/quarantine) but never corrupt: any
        # emitted prefix is a prefix of the solo stream
        np.testing.assert_array_equal(got, sol[:len(got)])
        if req.state == "finished" and req.finish_reason == "length":
            assert len(got) == len(sol)


def test_stats_merges_spec_and_robustness_counters(served):
    """Engine.stats() carries the PR-7 robustness counters and the
    speculative counters side by side."""
    cfg, model, params, prompts = served
    _, eng = _drive(model, params, [(prompts[0][:9], 3, 0.0, 0)],
                    spec=SpecConfig(depth=2, mode="ngram"))
    s = eng.stats()
    for k in ("spec_proposed", "spec_accepted", "spec_rejected",
              "spec_rollbacks", "spec_acceptance", "shed", "retried",
              "quarantined", "expired", "failed", "watchdog_trips"):
        assert k in s, k
