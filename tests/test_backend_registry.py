"""Attention backend registry: differential validation of every registered
exact backend against the pure-jnp oracle across mask regimes, rescale-math
property tests, and resolve()/fallback behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import mask as mk
from repro.core.attention import (chunk_attn, chunk_attn_bwd, empty_partial,
                                  merge)
from repro.core.mask import MaskSpec
from repro.kernels import registry
from repro.kernels.ref import chunk_attn_bwd_ref, chunk_attn_ref

EXACT_BACKENDS = [n for n in registry.names() if registry.get(n).exact]

# one MaskSpec per declarative kind (plus offsets): every registered exact
# backend must serve the full kind set
MASK_CASES = {
    "causal":      mk.causal(),
    "non-causal":  mk.full(),
    "rel-offset":  mk.causal(rel_offset=96),
    "window":      mk.sliding_window(40, rel_offset=96),
    "prefix-lm":   mk.prefix_lm(24),
    "document":    mk.document(boundaries=(0, 40, 100, 180)),
    "doc-window":  mk.document(boundaries=(0, 40, 100, 180), window=64),
}


# Tk > chunked.DEFAULT_BLOCK_KV so the chunked-lax legs exercise the real
# blocked-scan path (nb > 1), not its single-block early return
def _mk(seed=0, B=1, Tq=64, Tk=256, Hq=4, Hkv=2, D=32, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, Tq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Tk, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Tk, Hkv, D), dtype)
    do = jax.random.normal(ks[3], (B, Tq, Hq, D), dtype)
    return q, k, v, do


@pytest.mark.parametrize("mask", MASK_CASES, ids=list(MASK_CASES))
@pytest.mark.parametrize("backend", EXACT_BACKENDS)
def test_backend_matches_ref(backend, mask):
    """Every registered exact backend × every MaskSpec kind agrees with the
    oracle within fp32 tolerance, forward and backward. ``pallas`` resolves
    through its CPU fallback chain here — that path must stay exact too."""
    kw = dict(mask=MASK_CASES[mask])
    q, k, v, do = _mk()
    o_r, l_r = chunk_attn_ref(q, k, v, **kw)
    o_b, l_b = chunk_attn(q, k, v, impl=backend, **kw)
    np.testing.assert_allclose(np.asarray(o_b), np.asarray(o_r), atol=1e-5)
    m = (l_r > -1e29) | (l_b > -1e29)
    np.testing.assert_allclose(np.asarray(jnp.where(m, l_b, 0)),
                               np.asarray(jnp.where(m, l_r, 0)), atol=1e-4)
    g_r = chunk_attn_bwd_ref(q, k, v, o_r, l_r, do, **kw)
    g_b = chunk_attn_bwd(q, k, v, o_b, l_b, do, impl=backend, **kw)
    for a, b in zip(g_b, g_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


@pytest.mark.parametrize("backend",
                         [n for n in EXACT_BACKENDS if n != "ref"])
def test_backend_gqa_and_asymmetric_dv(backend):
    """GQA grouping and MLA-style Dk != Dv shapes survive every backend."""
    q, k, _, _ = _mk(seed=3, Hq=4, Hkv=2, D=48)
    v = jax.random.normal(jax.random.PRNGKey(9), (1, 256, 2, 24))
    o_r, l_r = chunk_attn_ref(q, k, v, mask=mk.causal(), scale=0.2)
    o_b, l_b = chunk_attn(q, k, v, mask=mk.causal(), scale=0.2,
                          impl=backend)
    np.testing.assert_allclose(np.asarray(o_b), np.asarray(o_r), atol=1e-5)


def test_chunked_lax_block_picking_and_odd_lengths():
    """Block selection avoids the degenerate near-token-level scan for
    prime-ish KV lengths (falls back to single-block), and the backend
    stays exact at a non-power-of-two length."""
    from repro.kernels.chunked import _pick_block
    assert _pick_block(256, 128) == 128      # clean blocking
    assert _pick_block(96, 128) == 96        # Tk smaller than target
    assert _pick_block(257, 128) == 257      # prime: single block, no bc=1
    assert _pick_block(262, 128) == 262      # 2×131: single block, no bc=2
    q, _, _, do = _mk(seed=5)
    k = jax.random.normal(jax.random.PRNGKey(6), (1, 257, 2, 32))
    v = jax.random.normal(jax.random.PRNGKey(7), (1, 257, 2, 32))
    o_r, l_r = chunk_attn_ref(q, k, v, mask=mk.causal(200))
    o_b, l_b = chunk_attn(q, k, v, mask=mk.causal(200),
                          impl="chunked-lax")
    np.testing.assert_allclose(np.asarray(o_b), np.asarray(o_r), atol=1e-5)


# ------------------------------------------------------------ rescale math

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.sampled_from([2, 3, 4, 5]))
def test_merge_associative_and_order_independent(seed, n):
    """Any merge order/association of the per-chunk partials is identical —
    the invariant that lets the balanced schedule fold helper results in as
    they arrive."""
    B, T, H, D = 1, 8, 2, 4
    rng = jax.random.PRNGKey(seed)
    q = jax.random.normal(jax.random.fold_in(rng, 0), (B, T, H, D))
    parts = []
    for i in range(n):
        k = jax.random.normal(jax.random.fold_in(rng, 2 * i + 1),
                              (B, T, H, D))
        v = jax.random.normal(jax.random.fold_in(rng, 2 * i + 2),
                              (B, T, H, D))
        parts.append(chunk_attn_ref(q, k, v))
    # left fold in order
    o1, l1 = parts[0]
    for o, l in parts[1:]:
        o1, l1 = merge(o1, l1, o, l)
    # fold in a seed-dependent permuted order with different association
    order = list(np.random.RandomState(seed).permutation(n))
    o2, l2 = empty_partial(q)
    for i in order:
        o2, l2 = merge(*parts[i], o2, l2)       # also flips argument order
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_mask_partial_is_merge_identity(seed):
    """mask_partial(False, ·) produces the identity element of merge."""
    from repro.core.attention import mask_partial
    B, T, H, D = 1, 8, 2, 4
    rng = jax.random.PRNGKey(seed)
    q = jax.random.normal(jax.random.fold_in(rng, 0), (B, T, H, D))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, T, H, D))
    o, lse = chunk_attn_ref(q, k, k)
    om, lm = mask_partial(jnp.bool_(False), o, lse)
    e_o, e_l = empty_partial(q)
    np.testing.assert_allclose(np.asarray(om), np.asarray(e_o))
    np.testing.assert_allclose(np.asarray(lm), np.asarray(e_l))
    o2, l2 = merge(om, lm, o, lse)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(o), atol=1e-6)
    np.testing.assert_allclose(np.asarray(l2), np.asarray(lse), atol=1e-6)


# ------------------------------------------------------- resolve / fallback

def test_resolve_pallas_on_cpu_downgrades_not_crashes():
    be = registry.resolve("pallas", platform="cpu")
    assert be.name in ("pallas-interpret", "chunked-lax", "ref")
    assert be.unsupported_reason(platform="cpu") is None
    # the downgrade is recorded (logged once per triple)
    assert ("pallas", be.name, "cpu") in registry._WARNED


def test_resolve_on_tpu_keeps_pallas():
    assert registry.resolve("pallas", platform="tpu").name == "pallas"


def test_resolve_unknown_backend_raises():
    with pytest.raises(ValueError, match="unknown attention backend"):
        registry.resolve("cudnn-flash")


def test_resolve_name_normalization():
    """Pre-registry spelling (underscores) still resolves."""
    assert registry.resolve("pallas_interpret", platform="cpu").name == \
        "pallas-interpret"


def test_resolve_default_roundtrip():
    assert registry.resolve(None).name == registry.default_name()
    old = registry.default_name()
    try:
        registry.set_default("chunked-lax")
        assert registry.resolve(None).name == "chunked-lax"
        with pytest.raises(ValueError):
            registry.set_default("bogus")
    finally:
        registry.set_default(old)


def test_null_backend_is_marked_inexact_and_never_a_fallback():
    assert not registry.get("null").exact
    for name in registry.names():
        assert "null" not in registry.get(name).fallback, name


def test_capability_flags_reported():
    spec = registry.get("chunked-lax")
    assert spec.mask_kinds == frozenset(
        {"causal", "sliding_window", "prefix_lm", "document"})
    assert spec.causal and spec.window and spec.rel_offset  # legacy views
    assert "cpu" in spec.platforms and "tpu" in spec.platforms
    assert registry.get("pallas").platforms == ("tpu",)


def test_resolve_matches_on_mask_kinds():
    """resolve() falls back when a backend lacks a required mask kind."""
    limited = registry.BackendSpec(
        name="no-docs-test", fwd=lambda *a, **k: None,
        bwd=lambda *a, **k: None,
        mask_kinds=frozenset({"causal", "sliding_window"}),
        fallback=("ref",))
    registry.register(limited, overwrite=True)
    try:
        got = registry.resolve("no-docs-test", platform="cpu",
                               mask=mk.document())
        assert got.name == "ref"
        assert registry.resolve("no-docs-test", platform="cpu",
                                mask=mk.causal()).name == "no-docs-test"
        reason = limited.unsupported_reason(platform="cpu",
                                            mask=mk.prefix_lm(8))
        assert "prefix_lm" in reason
    finally:
        registry._REGISTRY.pop("no-docs-test", None)


def test_legacy_kwargs_removed():
    """The pre-MaskSpec kwarg triple is gone from chunk_attn: passing any
    of causal/rel_offset/window — alone or alongside mask= — raises
    ``TypeError`` with the migration hint, and ``mask=None`` keeps its
    full-attention default."""
    q, k, v, _ = _mk(seed=8)
    for kw in (dict(causal=True), dict(window=40), dict(rel_offset=96),
               dict(causal=True, rel_offset=96, window=40),
               dict(mask=mk.causal(), causal=True)):
        with pytest.raises(TypeError, match="was removed.*mask="):
            chunk_attn(q, k, v, impl="ref", **kw)
    o_none, _ = chunk_attn(q, k, v, impl="ref")
    o_full, _ = chunk_attn(q, k, v, mask=mk.full(), impl="ref")
    np.testing.assert_allclose(np.asarray(o_none), np.asarray(o_full))
