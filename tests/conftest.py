"""Shared fixtures. NOTE: no XLA_FLAGS here — unit/smoke tests must see the
real single CPU device (the 512-device override is dryrun.py-only).
Multi-device distribution tests run in subprocesses (see
test_dist_attention.py) so they can set the flag before jax initializes.

Also installs a minimal ``hypothesis`` fallback shim (seeded-random example
generation) when the real package is absent, so the property-test modules
(test_attention_math / test_moe / test_ssm) always collect and run.
"""
import functools
import inspect
import os
import random
import subprocess
import sys
import types

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# --------------------------------------------------------------------------
# hypothesis fallback shim
# --------------------------------------------------------------------------

def _install_hypothesis_shim():
    """Register a tiny stand-in for the ``hypothesis`` API surface the test
    suite uses: ``given``, ``settings``, and ``strategies.{integers,
    sampled_from, booleans, floats}``. Examples are drawn from a
    deterministic per-test RNG (seeded by the test's qualified name), so
    runs are reproducible; ``max_examples`` from ``settings`` is honored.
    """

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw            # rng -> value

    def integers(min_value=0, max_value=2 ** 31 - 1):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def just(value):
        return _Strategy(lambda rng: value)

    _DEFAULT_EXAMPLES = 10

    def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(*arg_strats, **kw_strats):
        if arg_strats:
            raise TypeError("shim supports keyword strategies only")

        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_shim_max_examples", _DEFAULT_EXAMPLES)
                rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
                for _ in range(n):
                    drawn = {name: s.draw(rng)
                             for name, s in kw_strats.items()}
                    fn(*args, **drawn, **kwargs)

            # pytest must not see the strategy-driven parameters as
            # fixtures: expose a signature with them removed.
            sig = inspect.signature(fn)
            params = [p for name, p in sig.parameters.items()
                      if name not in kw_strats]
            wrapper.__signature__ = sig.replace(parameters=params)
            del wrapper.__wrapped__  # stop pytest unwrapping to fn
            return wrapper
        return deco

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.sampled_from = sampled_from
    st_mod.booleans = booleans
    st_mod.floats = floats
    st_mod.just = just

    hyp_mod = types.ModuleType("hypothesis")
    hyp_mod.given = given
    hyp_mod.settings = settings
    hyp_mod.strategies = st_mod
    hyp_mod.__is_repro_shim__ = True

    sys.modules["hypothesis"] = hyp_mod
    sys.modules["hypothesis.strategies"] = st_mod


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_shim()


# --------------------------------------------------------------------------
# multi-device subprocess runner
# --------------------------------------------------------------------------

def run_subprocess(code: str, devices: int = 8) -> str:
    """Run a python snippet with N forced host devices; returns stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, f"subprocess failed:\n{r.stdout}\n{r.stderr}"
    return r.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_subprocess
