"""Shared fixtures. NOTE: no XLA_FLAGS here — unit/smoke tests must see the
real single CPU device (the 512-device override is dryrun.py-only).
Multi-device distribution tests run in subprocesses (see
test_dist_attention.py) so they can set the flag before jax initializes."""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_subprocess(code: str, devices: int = 8) -> str:
    """Run a python snippet with N forced host devices; returns stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=900)
    assert r.returncode == 0, f"subprocess failed:\n{r.stdout}\n{r.stderr}"
    return r.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_subprocess
