"""Continuous-batching engine tests: fixed-slot vs paged ``generate``
equivalence, per-request sampling determinism, stop-token early exit, and
the batch-invariance property suite (staggered arrivals, mixed prompt
lengths, pool-pressure preemption ⇒ every request's greedy stream equals
its solo run — with speculation on, every stream plus its acceptance
history must match the solo NON-speculative run), plus
scheduler/allocator bookkeeping invariants.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.config import ShapeSpec, get_config, smoke_config
from repro.data.pipeline import SyntheticTokens
from repro.models.transformer import Runtime, build_model
from repro.parallel.sharding import make_parallel_config
from repro.serve.engine import Engine, FixedSlotEngine


def _setup(arch, window=0, prompt_len=24, batch=3):
    import dataclasses
    cfg = smoke_config(get_config(arch))
    if window:
        cfg = cfg.replace(attn=dataclasses.replace(cfg.attn, window=window))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeSpec("srv", prompt_len, batch, "prefill")
    par = make_parallel_config(mesh, shape)
    model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))
    params = model.init(jax.random.PRNGKey(0))
    batch_d = SyntheticTokens(cfg, shape, par, mesh).batch(0)
    return cfg, model, params, batch_d


def _prompts(batch_d):
    return np.asarray(batch_d["tokens"])


def _solo_stream(model, params, prompt, *, n, temperature=0.0, seed=0,
                 max_batch=4, block_size=8):
    """The request run alone — whole-prompt prefill, no prefix cache, ample
    pool: the canonical baseline every batched/chunked/cached stream must
    reproduce exactly."""
    eng = Engine(model, params, max_batch=max_batch, block_size=block_size,
                 n_blocks=4 * (len(prompt) + n) // block_size + 8,
                 prefill_chunk_tokens=0, prefix_cache=False)
    rid = eng.submit(prompt, max_new_tokens=n, temperature=temperature,
                     seed=seed)
    return eng.run()[rid]


# ==========================================================================
# engine smoke: old fixed-slot API vs the paged engine
# ==========================================================================

@pytest.mark.parametrize("arch,window",
                         [("llama-gqa", 0), ("llama-gqa", 16),
                          pytest.param("deepseek-v2-lite-16b", 0,
                                       marks=pytest.mark.slow)])
def test_generate_equivalence_fixed_slot_vs_paged(arch, window):
    """Greedy streams of the dense fixed-slot oracle and the paged
    continuous-batching engine must agree (GQA; windowed; MLA+MoE is the
    slow param)."""
    cfg, model, params, batch_d = _setup(arch, window=window)
    n = 6
    toks_fixed, _ = FixedSlotEngine(model, params).generate(batch_d, n)
    eng = Engine(model, params, max_batch=4, block_size=8, n_blocks=32)
    toks_paged = eng.generate(batch_d, n)
    np.testing.assert_array_equal(np.asarray(toks_fixed),
                                  np.asarray(toks_paged))


def test_temperature_sampling_determinism():
    """Same (seed, prompt) ⇒ identical sampled stream — across engine
    instances AND across different batch compositions; different seeds
    diverge."""
    cfg, model, params, batch_d = _setup("llama-gqa")
    prompts = _prompts(batch_d)
    kw = dict(max_new_tokens=6, temperature=0.9)

    def run(extra_load):
        eng = Engine(model, params, max_batch=4, block_size=8, n_blocks=64)
        if extra_load:                       # different batch composition
            eng.submit(prompts[1], max_new_tokens=4, temperature=0.5,
                       seed=7)
        rid = eng.submit(prompts[0], seed=123, **kw)
        return eng.run()[rid]

    a, b, c = run(False), run(False), run(True)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a, c)

    eng = Engine(model, params, max_batch=4, block_size=8, n_blocks=64)
    rid = eng.submit(prompts[0], seed=124, **kw)
    assert not np.array_equal(a, eng.run()[rid])


def test_stop_token_early_exit():
    cfg, model, params, batch_d = _setup("llama-gqa")
    prompt = _prompts(batch_d)[0]
    full = _solo_stream(model, params, prompt, n=8)
    stop = int(full[3])
    eng = Engine(model, params, max_batch=2, block_size=8, n_blocks=32)
    rid = eng.submit(prompt, max_new_tokens=8, stop_tokens=(stop,))
    out = eng.run()[rid]
    req = eng.requests[rid]
    assert req.finish_reason == "stop"
    k = int(np.nonzero(full == stop)[0][0])
    np.testing.assert_array_equal(out, full[:k + 1])
    assert len(out) < 8


# ==========================================================================
# batch-invariance property suite
# ==========================================================================

@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_batch_invariance_under_staggered_arrivals(seed):
    """Hypothesis-driven: random staggered arrivals, mixed prompt lengths
    and budgets, a pool small enough to preempt — every request's greedy
    stream equals its solo (batch-of-one) run, and the allocator conserves
    its blocks."""
    cfg, model, params, batch_d = _setup("smollm-360m", prompt_len=32,
                                         batch=4)
    prompts = _prompts(batch_d)
    rng = np.random.default_rng(seed)
    n_req = int(rng.integers(3, 6))
    specs = []
    for i in range(n_req):
        plen = int(rng.choice([9, 17, 25, 32]))
        specs.append(dict(prompt=prompts[i % len(prompts)][:plen],
                          n=int(rng.integers(3, 8)),
                          arrive=int(rng.integers(0, 4))))
    # pool sized to hold ~2 requests: forces queueing and/or preemption
    eng = Engine(model, params, max_batch=4, block_size=8, n_blocks=14)
    rids = {}
    step = 0
    order = sorted(range(n_req), key=lambda i: (specs[i]["arrive"], i))
    for i in order:
        while step < specs[i]["arrive"]:
            eng.step()
            step += 1
        rids[i] = eng.submit(specs[i]["prompt"],
                             max_new_tokens=specs[i]["n"])
    out = eng.run()
    eng.cache.allocator.check_conservation()
    # after draining, every block is either free or pinned by the prefix
    # cache (retained for future shared-prefix arrivals)
    assert eng.cache.allocator.n_free + eng.cache.n_cache_blocks \
        == eng.cache.allocator.n_usable
    for i, spec in enumerate(specs):
        got = out[rids[i]]
        assert len(got) <= spec["n"]
        solo = _solo_stream(model, params, spec["prompt"], n=spec["n"])
        np.testing.assert_array_equal(got, solo[:len(got)], err_msg=str(i))
        assert len(got) == len(solo)


@settings(max_examples=4, deadline=None)
@given(seed=st.integers(0, 10_000), chunk=st.sampled_from([0, 8, 16]),
       warm=st.booleans())
def test_batch_invariance_across_chunk_size_and_cache_state(seed, chunk,
                                                            warm):
    """A request's stream is unchanged whether its prefix hit or missed
    the cache and whether its prefill ran whole or chunked: random
    overlapping-prefix arrivals under every chunking regime, optionally
    against a pre-warmed cache, all match the cold whole-prefill solo
    baseline."""
    cfg, model, params, batch_d = _setup("smollm-360m", prompt_len=32,
                                         batch=4)
    prompts = _prompts(batch_d)
    rng = np.random.default_rng(seed)
    eng = Engine(model, params, max_batch=3, block_size=8, n_blocks=32,
                 prefill_chunk_tokens=chunk)
    if warm:                                  # populate the prefix cache
        w = eng.submit(prompts[0], max_new_tokens=2)
        eng.run()
        del eng.requests[w]
    # overlapping prompts (prefixes of the same rows) force a mix of full,
    # partial-tail, and missed lookups
    specs = [dict(prompt=prompts[int(rng.integers(0, 2))]
                  [:int(rng.choice([9, 17, 25, 32]))],
                  n=int(rng.integers(3, 7)),
                  arrive=int(rng.integers(0, 4)))
             for _ in range(int(rng.integers(3, 6)))]
    rids = {}
    step = 0
    for i in sorted(range(len(specs)),
                    key=lambda i: (specs[i]["arrive"], i)):
        while step < specs[i]["arrive"]:
            eng.step()
            step += 1
        rids[i] = eng.submit(specs[i]["prompt"],
                             max_new_tokens=specs[i]["n"])
    out = eng.run()
    eng.cache.allocator.check_conservation()
    eng.cache.prefix.check_integrity()
    assert eng.cache.allocator.n_free + eng.cache.n_cache_blocks \
        == eng.cache.allocator.n_usable
    for i, spec in enumerate(specs):
        solo = _solo_stream(model, params, spec["prompt"], n=spec["n"],
                            max_batch=3)
        np.testing.assert_array_equal(out[rids[i]], solo, err_msg=str(i))


@settings(max_examples=3, deadline=None)
@given(seed=st.integers(0, 10_000), depth=st.sampled_from([1, 2, 3]))
def test_spec_batch_invariance_under_staggered_arrivals(seed, depth):
    """Speculative streams are batch- and preemption-invariant: random
    staggered arrivals (mixed lengths, budgets, temperatures) into a
    speculating engine with a pool small enough to preempt — every
    stream equals its solo NON-speculative run, the per-request
    acceptance history is internally consistent, and the allocator
    conserves through every rejected-branch rollback."""
    cfg, model, params, batch_d = _setup("smollm-360m", prompt_len=32,
                                         batch=4)
    from repro.serve.speculative import SpecConfig
    prompts = _prompts(batch_d)
    rng = np.random.default_rng(seed)
    specs = [dict(prompt=prompts[i % len(prompts)]
                  [:int(rng.choice([9, 17, 25, 32]))],
                  n=int(rng.integers(3, 8)),
                  temp=float(rng.choice([0.0, 0.8])),
                  arrive=int(rng.integers(0, 4)))
             for i in range(int(rng.integers(3, 6)))]
    # pool sized to hold ~2 requests incl. lookahead: forces queueing
    # and/or preemption through the speculative path
    eng = Engine(model, params, max_batch=4, block_size=8, n_blocks=16,
                 spec=SpecConfig(depth=depth, mode="ngram"))
    rids = {}
    step = 0
    for i in sorted(range(len(specs)),
                    key=lambda i: (specs[i]["arrive"], i)):
        while step < specs[i]["arrive"]:
            eng.step()
            step += 1
        rids[i] = eng.submit(specs[i]["prompt"],
                             max_new_tokens=specs[i]["n"],
                             temperature=specs[i]["temp"], seed=i)
    out = eng.run()
    eng.cache.allocator.check_conservation()
    assert eng.cache.allocator.n_free + eng.cache.n_cache_blocks \
        == eng.cache.allocator.n_usable
    s = eng.stats()
    assert s["spec_accepted"] + s["spec_rejected"] == s["spec_proposed"]
    for i, spec in enumerate(specs):
        solo = _solo_stream(model, params, spec["prompt"], n=spec["n"],
                            temperature=spec["temp"], seed=i)
        np.testing.assert_array_equal(out[rids[i]], solo, err_msg=str(i))


def test_preemption_of_shared_prefix_request_conserves_blocks():
    """Preempting a request whose blocks are shared (with another live
    request and with the prefix cache) must drop only the victim's refs:
    the survivor keeps streaming correctly, the pool stays conserved, and
    the victim completes after re-admission with its exact solo stream."""
    cfg, model, params, batch_d = _setup("smollm-360m", prompt_len=32,
                                         batch=3)
    prompts = _prompts(batch_d)
    shared = prompts[0][:16]                   # 2 full blocks of prefix
    a = np.concatenate([shared, prompts[1][:9]])
    b = np.concatenate([shared, prompts[2][:9]])
    # 7 usable blocks: each request peaks at 5 (25 prompt + 12 new), so
    # the pair only fits while the prefix is shared — growth must preempt
    eng = Engine(model, params, max_batch=2, block_size=8, n_blocks=8,
                 prefill_chunk_tokens=8)
    r0 = eng.submit(a, max_new_tokens=12)
    r1 = eng.submit(b, max_new_tokens=12)
    out = eng.run()
    assert eng.sched.n_preemptions > 0, \
        "pool was sized so decode growth must preempt the younger request"
    assert eng.stats()["hit_blocks"] > 0 or eng.stats()["dedup_swaps"] > 0, \
        "the common prefix must actually be shared"
    eng.cache.allocator.check_conservation()
    eng.cache.prefix.check_integrity()
    assert eng.cache.allocator.n_free + eng.cache.n_cache_blocks \
        == eng.cache.allocator.n_usable
    for rid, prompt in ((r0, a), (r1, b)):
        assert len(out[rid]) == 12
        solo = _solo_stream(model, params, prompt, n=12, max_batch=2)
        np.testing.assert_array_equal(out[rid], solo)


def test_preemption_requeue_completes_and_matches_solo():
    """Engineered pool pressure: three long-budget requests into a pool
    that holds barely two — preemptions must occur, every request must
    still finish with its full budget, and streams match solo runs."""
    cfg, model, params, batch_d = _setup("smollm-360m", prompt_len=24,
                                         batch=3)
    prompts = _prompts(batch_d)
    eng = Engine(model, params, max_batch=3, block_size=8, n_blocks=10)
    rids = [eng.submit(prompts[i], max_new_tokens=10) for i in range(3)]
    out = eng.run()
    assert eng.sched.n_preemptions > 0, "pool was sized to force preemption"
    eng.cache.allocator.check_conservation()
    assert eng.cache.allocator.n_free + eng.cache.n_cache_blocks \
        == eng.cache.allocator.n_usable
    for i, rid in enumerate(rids):
        assert len(out[rid]) == 10
        solo = _solo_stream(model, params, prompts[i], n=10, max_batch=3)
        np.testing.assert_array_equal(out[rid], solo)


def test_submit_rejects_never_fitting_request():
    """Shedding is a structured status, not an exception: a request that
    could never fit in the pool comes back terminal REJECTED with a
    reason, and the pool is untouched."""
    cfg, model, params, batch_d = _setup("smollm-360m")
    eng = Engine(model, params, max_batch=2, block_size=8, n_blocks=4)
    free_before = eng.cache.allocator.n_free
    rid = eng.submit(_prompts(batch_d)[0], max_new_tokens=32)
    state, reason = eng.status(rid)
    assert state == "rejected" and reason == "never_fits"
    assert eng.cache.allocator.n_free == free_before
    assert eng.stats()["shed"] == 1
    assert eng.sched.idle                 # never entered the queue
    assert eng.run()[rid].size == 0       # drains trivially, empty stream


def test_rejected_at_admission_never_touches_pool():
    """Queue-depth shedding: the shed request is terminal REJECTED at
    submit time and the block pool is bit-identical before and after —
    allocation only ever happens at admission, which it never reaches."""
    cfg, model, params, batch_d = _setup("smollm-360m")
    prompts = _prompts(batch_d)
    eng = Engine(model, params, max_batch=1, block_size=8, n_blocks=24,
                 max_queue=1)
    eng.cache.allocator.check_conservation()
    free_before = eng.cache.allocator.n_free
    keep = eng.submit(prompts[0][:10], max_new_tokens=4)
    shed = eng.submit(prompts[1][:10], max_new_tokens=4)
    assert eng.status(shed) == ("rejected", "queue_full")
    assert eng.cache.allocator.n_free == free_before
    eng.cache.allocator.check_conservation()
    out = eng.run()
    assert eng.status(keep)[0] == "finished" and out[shed].size == 0


def test_deadline_expiry_mid_prefill_returns_partial_stream():
    """A TTL elapsing while the request is still chunk-prefilling ends it
    EXPIRED with its (empty) partial stream, blocks released; an expiry
    landing mid-decode keeps the partial stream, a prefix of the solo
    run."""
    cfg, model, params, batch_d = _setup("smollm-360m")
    prompts = _prompts(batch_d)
    # chunk=2: a 20-token prompt needs ~10 prefill steps; TTL of 3 ticks
    # expires mid-prefill
    eng = Engine(model, params, max_batch=2, block_size=8, n_blocks=32,
                 prefill_chunk_tokens=2)
    rid = eng.submit(prompts[0][:20], max_new_tokens=8, deadline_steps=3)
    out = eng.run()
    req = eng.requests[rid]
    assert (req.state, req.finish_reason) == ("expired", "deadline")
    assert out[rid].size == 0            # never reached decode
    eng.cache.allocator.check_conservation()
    assert eng.cache.allocator.n_free + eng.cache.n_cache_blocks \
        == eng.cache.allocator.n_usable
    # mid-decode expiry: enough ticks to emit a few tokens, not all
    eng2 = Engine(model, params, max_batch=2, block_size=8, n_blocks=32,
                  prefill_chunk_tokens=0)
    rid2 = eng2.submit(prompts[0][:10], max_new_tokens=50,
                       deadline_steps=6)
    out2 = eng2.run()
    req2 = eng2.requests[rid2]
    assert (req2.state, req2.finish_reason) == ("expired", "deadline")
    assert 0 < out2[rid2].size < 50
    solo = _solo_stream(model, params, prompts[0][:10], n=50)
    np.testing.assert_array_equal(out2[rid2], solo[:out2[rid2].size])


@pytest.mark.slow
def test_long_arrival_trace_drains_and_is_invariant():
    """Longer seeded trace (the CI serving bench's shape): a dozen mixed
    requests with Poisson-ish arrivals; drains, conserves blocks, and every
    greedy stream matches solo."""
    cfg, model, params, batch_d = _setup("smollm-360m", prompt_len=32,
                                         batch=4)
    prompts = _prompts(batch_d)
    rng = np.random.default_rng(42)
    eng = Engine(model, params, max_batch=4, block_size=8, n_blocks=24)
    pending = [(int(rng.integers(0, 20)),
                prompts[i % 4][:int(rng.choice([8, 16, 24, 32]))],
                int(rng.integers(2, 9))) for i in range(12)]
    pending.sort(key=lambda t: t[0])
    rids, meta = [], []
    step = 0
    while pending or not eng.sched.idle:
        while pending and pending[0][0] <= step:
            _, pr, n = pending.pop(0)
            rids.append(eng.submit(pr, max_new_tokens=n))
            meta.append((pr, n))
        eng.step()
        step += 1
        assert step < 10_000
    out = {r: np.asarray(eng.requests[r].emitted) for r in rids}
    eng.cache.allocator.check_conservation()
    for rid, (pr, n) in zip(rids, meta):
        solo = _solo_stream(model, params, pr, n=n)
        np.testing.assert_array_equal(out[rid], solo)


# ==========================================================================
# 8-device mesh: engine-level invariance with a sharded pool
# ==========================================================================

def test_engine_8dev_batch_invariance(subproc):
    """Two staggered requests on a (1, 8) sequence-sharded mesh (pool
    block-sharded by GSPMD) produce the same greedy streams as their solo
    runs on the same mesh."""
    out = subproc("""
import numpy as np, jax
from repro.core.config import ShapeSpec, get_config, smoke_config
from repro.data.pipeline import SyntheticTokens
from repro.models.transformer import Runtime, build_model
from repro.parallel.sharding import make_parallel_config
from repro.serve.engine import Engine
cfg = smoke_config(get_config("qwen3-8b"))
mesh = jax.make_mesh((1, 8), ("data", "model"))
shape = ShapeSpec("srv", 32, 2, "prefill")
par = make_parallel_config(mesh, shape)
model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))
params = model.init(jax.random.PRNGKey(0))
prompts = np.asarray(SyntheticTokens(cfg, shape, par, mesh).batch(0)["tokens"])
def solo(p, n):
    e = Engine(model, params, max_batch=2, block_size=8, n_blocks=32)
    r = e.submit(p, max_new_tokens=n)
    return e.run()[r]
eng = Engine(model, params, max_batch=2, block_size=8, n_blocks=32)
r0 = eng.submit(prompts[0], max_new_tokens=4)
eng.step(); eng.step()
r1 = eng.submit(prompts[1], max_new_tokens=4)
out = eng.run()
a0, a1 = solo(prompts[0], 4), solo(prompts[1], 4)
assert np.array_equal(out[r0], a0), (out[r0], a0)
assert np.array_equal(out[r1], a1), (out[r1], a1)
print("OK 8dev engine invariance", list(map(int, out[r0])))
""")
    assert "OK 8dev engine invariance" in out


def test_decode_scalar_pos_shim_warns():
    """model.decode with the legacy scalar position broadcasts with a
    one-shot DeprecationWarning."""
    import warnings
    from repro.core import mask as mkm
    cfg, model, params, batch_d = _setup("smollm-360m", prompt_len=16,
                                         batch=2)
    _, cache = jax.jit(model.prefill)(params, batch_d)
    site = 'decode(batch={"pos": <scalar>})'
    mkm._DEPRECATION_WARNED.discard(site)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        model.decode(params, cache,
                     {"token": jnp.zeros((2, 1), jnp.int32),
                      "pos": jnp.int32(16)})
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)
           and site in str(x.message)]
    assert len(dep) == 1
