"""Property tests of the online-softmax merge algebra and the chunked
decomposition — the invariants the distributed schedules rely on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.attention import empty_partial, mask_partial, merge
from repro.kernels.ref import chunk_attn_ref, full_attn_ref, merge_ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.sampled_from([2, 3, 4]))
def test_merge_associativity(seed, n):
    """merge is associative+commutative over partials: any merge order of
    the per-chunk results gives the same output (this is what lets the
    balanced schedule merge helper results out of order)."""
    B, T, H, D = 1, 8, 2, 4
    q = _rand(seed, B, T, H, D)
    parts = []
    for i in range(n):
        k = _rand(seed + i + 1, B, T, H, D)
        v = _rand(seed + 2 * i + 7, B, T, H, D)
        parts.append(chunk_attn_ref(q, k, v))
    # left fold
    o1, l1 = parts[0]
    for o, l in parts[1:]:
        o1, l1 = merge(o1, l1, o, l)
    # right fold, reversed order
    o2, l2 = parts[-1]
    for o, l in reversed(parts[:-1]):
        o2, l2 = merge(o2, l2, o, l)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_merge_identity(seed):
    """empty_partial is the identity element of merge."""
    B, T, H, D = 1, 8, 2, 4
    q = _rand(seed, B, T, H, D)
    k = _rand(seed + 1, B, T, H, D)
    v = _rand(seed + 2, B, T, H, D)
    o, lse = chunk_attn_ref(q, k, v)
    e_o, e_l = empty_partial(q)
    o2, l2 = merge(e_o, e_l, o, lse)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(l2), atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000),
       chunks=st.sampled_from([2, 4, 8]),
       causal=st.booleans())
def test_chunked_equals_monolithic(seed, chunks, causal):
    """Splitting KV into chunks + merging == monolithic softmax attention."""
    B, T, H, D = 1, 32, 2, 8
    q = _rand(seed, B, T, H, D)
    k = _rand(seed + 1, B, T, H, D)
    v = _rand(seed + 2, B, T, H, D)
    o_full = full_attn_ref(q, k, v, causal=causal)
    Tc = T // chunks
    acc = empty_partial(q)
    for i in range(chunks):
        sl = slice(i * Tc, (i + 1) * Tc)
        o, lse = chunk_attn_ref(q, k[:, sl], v[:, sl], causal=causal,
                                q_offset=0, kv_offset=i * Tc)
        acc = merge(*acc, o, lse)
    np.testing.assert_allclose(np.asarray(acc[0]), np.asarray(o_full),
                               atol=2e-5)


def test_mask_partial_neutralizes():
    B, T, H, D = 1, 4, 1, 4
    q = _rand(0, B, T, H, D)
    o, lse = chunk_attn_ref(q, q, q)
    om, lm = mask_partial(jnp.bool_(False), o, lse)
    base = chunk_attn_ref(q, 2 * q, 3 * q)
    merged = merge(*base, om, lm)
    np.testing.assert_allclose(np.asarray(merged[0]), np.asarray(base[0]),
                               atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), window=st.sampled_from([1, 5, 16, 100]))
def test_window_subset_property(seed, window):
    """A window ≥ T equals full causal attention; window masks monotone."""
    B, T, H, D = 1, 16, 1, 4
    q = _rand(seed, B, T, H, D)
    k = _rand(seed + 1, B, T, H, D)
    v = _rand(seed + 2, B, T, H, D)
    o_w = full_attn_ref(q, k, v, causal=True, window=window)
    if window >= T:
        o_full = full_attn_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(o_w), np.asarray(o_full),
                                   atol=1e-6)
    if window == 1:  # each token attends only itself
        o_self = jnp.repeat(v, 1, axis=2)
        np.testing.assert_allclose(np.asarray(o_w), np.asarray(v), atol=1e-6)


def test_gqa_equals_repeated_kv():
    B, T, Hq, Hkv, D = 1, 16, 4, 2, 8
    q = _rand(0, B, T, Hq, D)
    k = _rand(1, B, T, Hkv, D)
    v = _rand(2, B, T, Hkv, D)
    o_g, lse_g = chunk_attn_ref(q, k, v, causal=True)
    o_r, lse_r = chunk_attn_ref(q, jnp.repeat(k, 2, 2), jnp.repeat(v, 2, 2),
                                causal=True)
    np.testing.assert_allclose(np.asarray(o_g), np.asarray(o_r), atol=1e-6)
