"""Autotuner suite: table resolution, precedence, degradation, and the
acceptance-aware speculative depth controller (satellites of the
measurement-backed autotuner PR).

Covers the acceptance criteria of the tuning subsystem:

  * **precedence** — explicit kwargs > env overrides > table hit >
    built-in heuristics, at every consumer (kernel tiles via
    ``block_tuning_kw``, ``schedule="auto"``, paged ``block_size``);
  * **nearest-bucket** — an unseen seq resolves to the closest measured
    bucket in log space, never to nothing;
  * **degradation** — a schema-version mismatch or corrupt JSON degrades
    to heuristics with one logged warning per process per path, and
    never raises out of a resolve;
  * **adaptive depth** — the controller is a pure function of a
    request's own acceptance history, and an adaptive engine emits
    token-identical streams to the vanilla engine.
"""
import json
import logging
import math

import jax
import numpy as np
import pytest

from repro.core import mask as mk
from repro.core.schedule import choose_schedule
from repro.kernels.registry import block_tuning_kw
from repro.tune import table as tt
from repro.tune.calibrate import (fit_nonneg, mask_for_kind,
                                  schedule_features, spearman)

# --------------------------------------------------------------- fixtures


@pytest.fixture(autouse=True)
def _clean_tuning_state(monkeypatch):
    """Each test starts from env/bundled resolution with no cache and no
    tuning env vars; restores afterwards."""
    for var in ("REPRO_TUNE", "REPRO_TUNE_TABLE", "REPRO_TUNE_BLOCK_Q",
                "REPRO_TUNE_BLOCK_KV", "REPRO_TUNE_BLOCK_SIZE"):
        monkeypatch.delenv(var, raising=False)
    tt.reset()
    yield
    tt.reset()


def sample_table(**over):
    """A minimal valid table: kernel rows at two seq buckets, one schedule
    row, one paged row, calibrated coeffs."""
    data = dict(
        schema_version=tt.SCHEMA_VERSION,
        generated_by="tests",
        host=dict(platform="cpu"),
        kernel=[
            dict(backend="chunked-lax", platform="cpu", mask_kind="causal",
                 head_dim=64, seq=256, op="fwd", block_q=256, block_kv=32,
                 wall_us=10.0),
            dict(backend="chunked-lax", platform="cpu", mask_kind="causal",
                 head_dim=64, seq=1024, op="fwd", block_q=1024, block_kv=128,
                 wall_us=40.0),
        ],
        schedule=[
            dict(mask_kind="causal", P=8, seq=2048, Hq=8, Hkv=8, Dqk=64,
                 B=1, bpe=4, best="balanced",
                 wall_us=dict(zigzag=90.0, balanced=100.0, ring=200.0,
                              ulysses=300.0)),
        ],
        paged=[
            dict(layout="mha", sharding="none", block_size=32,
                 tokens_per_s=100.0),
        ],
        calibration=dict(
            coeffs=dict(s_per_flop=0.0, s_per_byte=0.0, s_per_hop=3e-2,
                        s_per_elem=2e-7, base_s=0.0),
            fit=dict(n_points=15, spearman=0.97, spearman_roofline=-0.07),
        ),
    )
    data.update(over)
    return data


# ==========================================================================
# unit: schema validation + nearest-bucket lookup
# ==========================================================================

def test_valid_table_roundtrip(tmp_path):
    p = tmp_path / "t.json"
    tab = tt.TuningTable(sample_table())
    tab.save(str(p))
    back = tt.TuningTable.load(str(p))
    assert back.data == tab.data
    assert back.path == str(p)


def test_validate_rejects_bad_shapes():
    assert tt.TuningTable.validate([1, 2]) != []
    assert tt.TuningTable.validate(sample_table(schema_version=99)) != []
    bad = sample_table(kernel=[dict(backend="chunked-lax")])
    assert any("missing" in e for e in tt.TuningTable.validate(bad))
    bad = sample_table(calibration=dict(coeffs=dict(s_per_flop="x")))
    assert any("coeffs" in e for e in tt.TuningTable.validate(bad))
    with pytest.raises(tt.TableError, match="schema_version"):
        tt.TuningTable(sample_table(schema_version=99))


def test_nearest_bucket_kernel_lookup():
    tab = tt.TuningTable(sample_table())
    # exact hits
    assert tab.best_blocks(backend="chunked-lax", platform="cpu",
                           mask_kind="causal", head_dim=64,
                           seq=256) == (256, 32)
    # 384 is nearer 256 in log2 space; 768 is nearer 1024
    assert tab.best_blocks(backend="chunked-lax", platform="cpu",
                           mask_kind="causal", head_dim=64,
                           seq=384) == (256, 32)
    assert tab.best_blocks(backend="chunked-lax", platform="cpu",
                           mask_kind="causal", head_dim=64,
                           seq=768) == (1024, 128)
    # categorical keys are exact: unknown backend/mask/op -> None
    assert tab.best_blocks(backend="pallas", platform="cpu",
                           mask_kind="causal", head_dim=64, seq=256) is None
    assert tab.best_blocks(backend="chunked-lax", platform="cpu",
                           mask_kind="sliding_window", head_dim=64,
                           seq=256) is None
    assert tab.best_blocks(backend="chunked-lax", platform="cpu",
                           mask_kind="causal", head_dim=64, seq=256,
                           op="bwd") is None


def test_best_schedule_candidate_restriction():
    tab = tt.TuningTable(sample_table())
    # global winner is zigzag, but restricted to the capable set the
    # fastest candidate wins
    assert tab.best_schedule(mask_kind="causal", P=8, seq=2048) == "zigzag"
    assert tab.best_schedule(mask_kind="causal", P=8, seq=2048,
                             candidates=("balanced", "ring",
                                         "ulysses")) == "balanced"
    # nearest seq bucket serves unseen lengths; P is exact
    assert tab.best_schedule(mask_kind="causal", P=8, seq=4096,
                             candidates=("ring",)) == "ring"
    assert tab.best_schedule(mask_kind="causal", P=4, seq=2048) is None
    assert tab.best_schedule(mask_kind="document", P=8, seq=2048) is None


def test_best_block_size_sharding_fallback():
    tab = tt.TuningTable(sample_table())
    assert tab.best_block_size(layout="mha", sharding="none") == 32
    # unswept sharding falls back to the same layout
    assert tab.best_block_size(layout="mha", sharding="pool") == 32
    assert tab.best_block_size(layout="mla") is None


# ==========================================================================
# unit: degradation — corrupt/mismatched tables never crash a resolve
# ==========================================================================

def test_schema_mismatch_degrades_with_one_warning(tmp_path, caplog,
                                                   monkeypatch):
    p = tmp_path / "future.json"
    p.write_text(json.dumps(sample_table(schema_version=99)))
    monkeypatch.setenv("REPRO_TUNE_TABLE", str(p))
    with caplog.at_level(logging.WARNING, logger="repro.tune.table"):
        assert tt.active_table() is None
        tt.reset()
        assert tt.active_table() is None   # second resolve: no new warning
    warned = [r for r in caplog.records if str(p) in r.getMessage()]
    assert len(warned) == 1
    assert "schema_version" in warned[0].getMessage()


def test_corrupt_json_never_crashes_consumers(tmp_path, monkeypatch):
    p = tmp_path / "corrupt.json"
    p.write_text("{this is not json")
    monkeypatch.setenv("REPRO_TUNE_TABLE", str(p))
    assert tt.active_table() is None
    # every consumer degrades to its built-in heuristic, no raise
    assert block_tuning_kw(None, None, backend="chunked-lax",
                           mask_kind="causal", head_dim=64, seq=256) == {}
    from repro.core.config import get_config
    from repro.serve.cache import PagedKVCache
    assert PagedKVCache.default_block_size(
        get_config("smollm-360m").attn) == 16
    assert choose_schedule(mk.causal(), 8, Tl=32, Hq=8) in (
        "balanced", "ring", "ulysses")
    # explicit set_table with a corrupt path also degrades to None
    tt.set_table(str(p))
    assert tt.active_table() is None


def test_off_switch_disables_table(monkeypatch):
    tt.set_table(tt.TuningTable(sample_table()))
    monkeypatch.setenv("REPRO_TUNE", "off")
    assert tt.active_table() is None
    monkeypatch.delenv("REPRO_TUNE")
    assert tt.active_table() is not None


# ==========================================================================
# precedence: explicit kwarg > env > table > heuristic
# ==========================================================================

def test_kernel_tile_precedence(monkeypatch):
    ctx = dict(backend="chunked-lax", platform="cpu", mask_kind="causal",
               head_dim=64, seq=256)
    tt.set_table(tt.TuningTable(sample_table()))
    # table hit
    assert block_tuning_kw(None, None, **ctx) == dict(block_q=256,
                                                      block_kv=32)
    # env beats table
    monkeypatch.setenv("REPRO_TUNE_BLOCK_KV", "48")
    assert block_tuning_kw(None, None, **ctx) == dict(block_kv=48)
    # explicit kwargs beat both, wholesale (no table fill-in of the other)
    assert block_tuning_kw(16, None, **ctx) == dict(block_q=16)
    assert block_tuning_kw(16, 64, **ctx) == dict(block_q=16, block_kv=64)
    # garbage env is ignored (warn-once), falls through to the table
    monkeypatch.setenv("REPRO_TUNE_BLOCK_KV", "banana")
    assert block_tuning_kw(None, None, **ctx) == dict(block_q=256,
                                                      block_kv=32)
    monkeypatch.delenv("REPRO_TUNE_BLOCK_KV")
    # no table -> heuristics (empty kwargs, kernels keep their defaults)
    tt.set_table(None)
    assert block_tuning_kw(None, None, **ctx) == {}
    # bare two-arg form (inside backend closures) never consults the table
    tt.set_table(tt.TuningTable(sample_table()))
    assert block_tuning_kw(None, None) == {}


def test_paged_block_size_precedence(monkeypatch):
    from repro.core.config import get_config
    from repro.serve.cache import PagedKVCache
    a = get_config("smollm-360m").attn     # mha layout
    tt.set_table(tt.TuningTable(sample_table()))
    assert PagedKVCache.default_block_size(a) == 32
    monkeypatch.setenv("REPRO_TUNE_BLOCK_SIZE", "8")
    assert PagedKVCache.default_block_size(a) == 8
    monkeypatch.delenv("REPRO_TUNE_BLOCK_SIZE")
    tt.set_table(None)
    assert PagedKVCache.default_block_size(a) == 16


def test_paged_create_uses_table_default():
    from repro.core.config import get_config, smoke_config
    from repro.serve.cache import PagedKVCache
    cfg = smoke_config(get_config("smollm-360m"))
    tt.set_table(tt.TuningTable(sample_table()))
    cache = PagedKVCache.create(cfg, n_blocks=4, max_reqs=1)
    assert cache.block_size == 32
    explicit = PagedKVCache.create(cfg, block_size=8, n_blocks=4,
                                   max_reqs=1)
    assert explicit.block_size == 8


# ==========================================================================
# schedule="auto": table hit > calibrated coeffs > roofline
# ==========================================================================

def test_choose_schedule_table_hit():
    tt.set_table(tt.TuningTable(sample_table()))
    # measured row says balanced is the fastest capable schedule (zigzag
    # is excluded from auto's candidate set)
    assert choose_schedule(mk.causal(), 8, Tl=256, Hq=8) == "balanced"
    # head counts not divisible by P: ulysses drops out, table still wins
    assert choose_schedule(mk.causal(), 8, Tl=256, Hq=12,
                           Hkv=12) == "balanced"


def test_choose_schedule_coeffs_fallback_at_unseen_regime():
    tt.set_table(tt.TuningTable(sample_table()))
    # P=4 has no measured row -> calibrated coefficients rank candidates;
    # must return a capable name, deterministically
    picks = {choose_schedule(mk.causal(), 4, Tl=256, Hq=8)
             for _ in range(3)}
    assert len(picks) == 1 and picks.pop() in ("balanced", "ring",
                                               "ulysses")
    # document mask at unseen P likewise
    assert choose_schedule(mk.document(), 4, Tl=256, Hq=8) in (
        "balanced", "ring", "ulysses")


def test_choose_schedule_roofline_without_table():
    tt.set_table(None)
    assert choose_schedule(mk.causal(), 1, Tl=64) == "ring"
    assert choose_schedule(mk.causal(), 8, Tl=256, Hq=8) in (
        "balanced", "ring", "ulysses")


# ==========================================================================
# calibration: feature extraction + nonneg least squares + spearman
# ==========================================================================

def test_mask_for_kind_matches_kinds():
    for kind in ("causal", "full", "sliding_window", "document",
                 "prefix_lm"):
        assert mask_for_kind(kind, T=256).kind == kind


def test_schedule_features_shapes():
    for sched in ("balanced", "ring", "ulysses"):
        f = schedule_features(sched, mask_kind="causal", P=8, seq=2048)
        assert f is not None
        assert set(f) >= {"flops", "comm_bytes", "hops", "score_elems"}
        assert all(v >= 0 for v in f.values())
    # rsa has no sliding-window path
    assert schedule_features("rsa", mask_kind="sliding_window", P=8,
                             seq=2048) is None


def test_fit_nonneg_recovers_synthetic_coeffs():
    rng = np.random.default_rng(0)
    X = np.hstack([rng.uniform(0.1, 1.0, size=(40, 3)),
                   np.ones((40, 1))])            # last column = base term
    y = X @ np.array([2.0, 0.0, 5.0, 0.3])
    w = fit_nonneg(X, y)
    assert np.all(w >= 0)
    assert float(np.max(np.abs(X @ w - y))) < 1e-6
    # a feature anti-correlated with y gets clamped to zero, not negative
    X2 = np.hstack([np.linspace(1, 2, 20)[:, None], np.ones((20, 1))])
    y2 = -3.0 * X2[:, 0] + 10.0
    w2 = fit_nonneg(X2, y2)
    assert np.all(w2 >= 0)


def test_spearman_rank_correlation():
    assert spearman([1, 2, 3, 4], [10, 20, 30, 40]) == pytest.approx(1.0)
    assert spearman([1, 2, 3, 4], [40, 30, 20, 10]) == pytest.approx(-1.0)
    assert abs(spearman([1, 2, 3, 4], [1, 2, 4, 3])) < 1.0


# ==========================================================================
# adaptive speculative depth (satellite of this PR)
# ==========================================================================

def _adaptive_spec(**over):
    from repro.serve.speculative import SpecConfig
    kw = dict(depth=4, mode="ngram", adaptive=True, adapt_window=4,
              adapt_floor=0.25, min_depth=1)
    kw.update(over)
    return SpecConfig(**kw)


def test_spec_config_adaptive_validation():
    from repro.serve.speculative import SpecConfig
    with pytest.raises(ValueError, match="adapt_window"):
        SpecConfig(depth=4, adaptive=True, adapt_window=0)
    with pytest.raises(ValueError, match="adapt_floor"):
        SpecConfig(depth=4, adaptive=True, adapt_floor=1.5)
    with pytest.raises(ValueError, match="min_depth"):
        SpecConfig(depth=4, adaptive=True, min_depth=9)


def test_adaptive_depth_is_pure_function_of_own_history():
    from repro.serve.speculative import AdaptiveDepth
    ad = AdaptiveDepth(_adaptive_spec())
    # optimistic start: no history -> full cap
    assert ad.depth_for(1) == 4
    # full acceptance keeps the cap
    for _ in range(4):
        ad.observe(1, 4, 4)
    assert ad.depth_for(1) == 4
    # zero acceptance floors at min_depth
    for _ in range(4):
        ad.observe(1, 0, 4)
    assert ad.depth_for(1) == 1
    # a == 0.5 -> d* = log(.25)/log(.5) = 2
    ad2 = AdaptiveDepth(_adaptive_spec())
    for _ in range(4):
        ad2.observe(2, 2, 4)
    assert ad2.depth_for(2) == 2
    # other requests' history never leaks: rid 3 untouched -> cap
    assert ad2.depth_for(3) == 4
    # release forgets
    ad2.release(2)
    assert ad2.depth_for(2) == 4
    # zero-proposal steps carry no signal
    ad3 = AdaptiveDepth(_adaptive_spec())
    ad3.observe(5, 0, 0)
    assert ad3.depth_for(5) == 4


def test_adaptive_depth_window_slides():
    from repro.serve.speculative import AdaptiveDepth
    ad = AdaptiveDepth(_adaptive_spec(adapt_window=2))
    for _ in range(10):
        ad.observe(1, 0, 4)
    assert ad.depth_for(1) == 1
    # two perfect steps push the zeros out of the window -> cap again
    ad.observe(1, 4, 4)
    ad.observe(1, 4, 4)
    assert ad.depth_for(1) == 4


@pytest.fixture(scope="module")
def served():
    from repro.core.config import ShapeSpec, get_config, smoke_config
    from repro.data.pipeline import SyntheticTokens
    from repro.models.transformer import Runtime, build_model
    from repro.parallel.sharding import make_parallel_config
    cfg = smoke_config(get_config("smollm-360m"))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeSpec("tune", 32, 4, "prefill")
    par = make_parallel_config(mesh, shape)
    model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.asarray(
        SyntheticTokens(cfg, shape, par, mesh).batch(0)["tokens"])
    return model, params, prompts


def _streams(model, params, prompts, spec):
    from repro.serve.engine import Engine
    eng = Engine(model, params, max_batch=2, block_size=8, n_blocks=32,
                 spec=spec)
    rids = [eng.submit(prompts[i][:24 + 4 * i], max_new_tokens=8,
                       temperature=0.0) for i in range(2)]
    out = eng.run()
    return [np.asarray(out[r]) for r in rids], eng


def test_adaptive_engine_streams_token_identical(served):
    model, params, prompts = served
    base, _ = _streams(model, params, prompts, None)
    adapt, eng = _streams(model, params, prompts, _adaptive_spec())
    for b, a in zip(base, adapt):
        np.testing.assert_array_equal(b, a)
    hist = eng.stats()["spec_depth_hist"]
    assert hist and sum(hist.values()) > 0
    assert all(0 <= k <= 4 for k in hist)
    # determinism of the whole adaptive engine: replay is identical
    adapt2, eng2 = _streams(model, params, prompts, _adaptive_spec())
    for a, a2 in zip(adapt, adapt2):
        np.testing.assert_array_equal(a, a2)
    assert eng2.stats()["spec_depth_hist"] == hist
