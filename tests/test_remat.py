"""Rematerialization-aware checkpointing (§3.3): numerical identity with
the un-checkpointed layer, and the no-FA-recompute property via FLOP
accounting (the paper's 'no numerical difference' claim)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.core import mask as mk
from repro.core.attention import chunk_attn, chunk_attn_bwd
from repro.core.remat import apply_policy, remat_aware

B, T, H, D, DM = 2, 128, 4, 32, 128


def _layer_fns():
    def pre(p, x):
        h = x[0] if isinstance(x, tuple) else x
        q = (h @ p["wq"]).reshape(B, T, H, D)
        k = (h @ p["wk"]).reshape(B, T, H, D)
        v = (h @ p["wv"]).reshape(B, T, H, D)
        return q, k, v

    def attn_fwd(qkv):
        return chunk_attn(*qkv, mask=mk.causal())

    def attn_bwd(qkv, o, lse, do):
        return chunk_attn_bwd(*qkv, o, lse, do, mask=mk.causal())

    def post(p, x, o):
        h = x[0] if isinstance(x, tuple) else x
        h2 = h + o.reshape(B, T, H * D) @ p["wo"]
        return h2 + jax.nn.gelu(h2 @ p["w1"]) @ p["w2"]

    return pre, attn_fwd, attn_bwd, post


def _params(seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    return {
        "wq": jax.random.normal(ks[0], (DM, H * D)) * 0.05,
        "wk": jax.random.normal(ks[1], (DM, H * D)) * 0.05,
        "wv": jax.random.normal(ks[2], (DM, H * D)) * 0.05,
        "wo": jax.random.normal(ks[3], (H * D, DM)) * 0.05,
        "w1": jax.random.normal(ks[4], (DM, 4 * DM)) * 0.05,
        "w2": jax.random.normal(ks[5], (4 * DM, DM)) * 0.05,
    }


def test_remat_aware_value_and_grads_match_plain():
    pre, afwd, abwd, post = _layer_fns()
    params = _params()
    x = jax.random.normal(jax.random.PRNGKey(9), (B, T, DM))

    def plain(p, x):
        o, _ = afwd(pre(p, x))
        return post(p, x, o)

    ra = remat_aware(pre, afwd, abwd, post)

    def loss(f):
        return lambda p, x: jnp.sum(f(p, x) ** 2)

    v1, g1 = jax.value_and_grad(loss(plain))(params, x)
    v2, g2 = jax.value_and_grad(loss(ra))(params, x)
    assert v1 == v2  # forward bit-identical (paper: 'no numerical diff')
    for k in g1:
        np.testing.assert_allclose(np.asarray(g1[k]), np.asarray(g2[k]),
                                   atol=5e-4, rtol=1e-4)


def test_remat_aware_saves_fa_forward_flops():
    """grad-FLOPs ordering: hf (recomputes FA fwd) > remat_aware; and
    remat_aware ≤ none (delta trick)."""
    pre, afwd, abwd, post = _layer_fns()
    params = _params()
    x = jax.random.normal(jax.random.PRNGKey(9), (B, T, DM))

    def plain(p, x):
        o, _ = afwd(pre(p, x))
        return post(p, x, o)

    ra = remat_aware(pre, afwd, abwd, post)

    def gflops(f):
        g = jax.jit(jax.grad(lambda p, x: jnp.sum(f(p, x) ** 2)))
        return compat.cost_analysis(g.lower(params, x).compile())["flops"]

    f_none = gflops(plain)
    f_hf = gflops(apply_policy(plain, "hf"))
    f_ra = gflops(ra)
    assert f_hf > f_ra, (f_hf, f_ra)
    # the saving must be at least one FA forward: 2·2·B·T²·H·D (QK^T + PV)
    fa_fwd = 2 * 2 * B * T * T * H * D
    assert f_hf - f_ra >= 0.9 * fa_fwd, (f_hf, f_ra, fa_fwd)


def test_policy_dispatch():
    pre, afwd, abwd, post = _layer_fns()

    def plain(p, x):
        o, _ = afwd(pre(p, x))
        return post(p, x, o)

    assert apply_policy(plain, "none") is plain
    assert apply_policy(plain, "hf") is not plain
    with pytest.raises(ValueError):
        apply_policy(plain, "bogus")
