"""Dry-run plumbing on a tiny in-process mesh: lower+compile smoke configs
for each step kind and check the analyses surface (the production 512-dev
sweep runs via ``python -m repro.launch.dryrun --all``)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.analysis import roofline as R
from repro.core.config import (ShapeSpec, TrainConfig, get_config,
                               smoke_config)
from repro.data.pipeline import cache_specs, input_specs
from repro.models.transformer import Runtime, build_model
from repro.optim import adamw
from repro.parallel.sharding import make_parallel_config, param_shardings
from repro.train.step import make_train_step


@pytest.mark.parametrize("arch,shape_kind", [
    ("smollm-360m", "train"), ("deepseek-v2-lite-16b", "train"),
    ("mamba2-2.7b", "decode"), ("whisper-tiny", "prefill"),
    ("zamba2-2.7b", "decode"),
])
def test_lower_compile_and_analyses(arch, shape_kind):
    cfg = smoke_config(get_config(arch))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeSpec("lite", 64, 2, shape_kind)
    par = make_parallel_config(mesh, shape)
    model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))
    p_struct = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_sh = param_shardings(p_struct, mesh, par)
    batch_struct, batch_spec = input_specs(cfg, shape, par, mesh)
    batch_sh = compat.tree_map(lambda s: NamedSharding(mesh, s), batch_spec,
                            is_leaf=lambda x: isinstance(x, P))
    if shape_kind == "train":
        step = make_train_step(model, TrainConfig())
        opt_struct = jax.eval_shape(adamw.init, p_struct)
        lowered = jax.jit(step).lower(p_struct, opt_struct, batch_struct)
    elif shape_kind == "prefill":
        lowered = jax.jit(lambda p, b: model.prefill(p, b)[0]).lower(
            p_struct, batch_struct)
    else:
        cache_struct = batch_struct.pop("cache")
        lowered = jax.jit(lambda p, c, b: model.decode(p, c, b)).lower(
            p_struct, cache_struct, batch_struct)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    assert mem.argument_size_in_bytes > 0
    cost = compat.cost_analysis(compiled)
    assert cost.get("flops", 0) > 0


def test_collective_parser_on_known_hlo():
    txt = """
  %x = bf16[16,128]{1,0} collective-permute(%p), source_target_pairs={{0,1},{1,2},{2,3},{3,0}}
  %y = f32[4,256]{1,0} all-gather(%p2), replica_groups={{0,1,2,3}}, dimensions={0}
  %z = f32[64]{0} all-reduce(%p3), replica_groups={{0,1}}
"""
    st = R.collective_stats(txt)
    assert st.op_counts == {"collective-permute": 1, "all-gather": 1,
                            "all-reduce": 1}
    assert st.bytes_by_kind["collective-permute"] == 16 * 128 * 2
    assert abs(st.bytes_by_kind["all-gather"] - 4 * 256 * 4 * 3 / 4) < 1
    assert abs(st.bytes_by_kind["all-reduce"] - 2 * 64 * 4 / 2) < 1


def test_attention_analytic_sane():
    from repro.core.config import get_shape
    cfg = get_config("qwen3-8b")
    fl, by = R.attention_analytic(cfg, get_shape("train_4k"),
                                  seq_shards=16, batch_shards=16)
    # per-chip causal attention flops: L·B_loc·T²/2/P·H·2·2·2hd ~ 1e12 scale
    assert 1e10 < fl < 1e14 and 1e7 < by < 1e12


def test_roofline_terms_bounds():
    t = R.roofline_terms(197e12, 819e9, 50e9)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["collective_s"] == pytest.approx(1.0)
    assert t["step_s_lower_bound"] == pytest.approx(1.0)
