"""MoE layer: dispatch/combine correctness vs a dense loop reference,
router conservation properties, and the decode path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.config import ModelConfig, MoEConfig
from repro.models.layers import rms_norm
from repro.models.moe import moe_params, moe_apply, moe_decode_apply


def _cfg(n_routed=8, n_shared=2, top_k=2, cap=8.0):
    return ModelConfig(
        name="t", arch_type="moe", n_layers=2, d_model=64, d_ff=0,
        vocab=100, dtype="float32",
        moe=MoEConfig(n_routed=n_routed, n_shared=n_shared, top_k=top_k,
                      d_expert=32, d_dense_ff=64, capacity_factor=cap))


def _dense_ref(p, x, cfg):
    m = cfg.moe
    h = rms_norm(x, p["ln"], cfg.norm_eps).reshape(-1, cfg.d_model)
    probs = jax.nn.softmax(h.astype(jnp.float32) @ p["router"], -1)
    tp, te = jax.lax.top_k(probs, m.top_k)
    tp = tp / tp.sum(-1, keepdims=True)
    y = jnp.zeros_like(h)
    for e in range(m.n_routed):
        oe = (jax.nn.silu(h @ p["wg"][e]) * (h @ p["wu"][e])) @ p["wd"][e]
        w = ((te == e).astype(jnp.float32) * tp).sum(-1)
        y = y + oe * w[:, None]
    if m.n_shared:
        y = y + (jax.nn.silu(h @ p["sh_wg"]) * (h @ p["sh_wu"])) @ p["sh_wd"]
    return (x.reshape(-1, cfg.d_model) + y).reshape(x.shape)


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_moe_matches_dense_reference(mesh):
    cfg = _cfg()
    p = moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 64))
    y, aux = jax.jit(lambda p, x: moe_apply(p, x, cfg, mesh=mesh,
                                            batch_axes=("data",)))(p, x)
    y_ref = _dense_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-5)
    assert 0 < float(aux) < 1.0


def test_moe_decode_matches_dense_reference(mesh):
    cfg = _cfg()
    p = moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 1, 64))
    y = jax.jit(lambda p, x: moe_decode_apply(p, x, cfg, mesh=mesh,
                                              batch_axes=("data",)))(p, x)
    y_ref = _dense_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-5)


def test_moe_capacity_drops_fall_back_to_residual(mesh):
    """With capacity_factor → 0, every routed token is dropped: output must
    equal residual + shared experts only (no NaNs, no garbage)."""
    cfg = _cfg(cap=1e-9)
    p = moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, 64))
    y, _ = jax.jit(lambda p, x: moe_apply(p, x, cfg, mesh=mesh,
                                          batch_axes=("data",)))(p, x)
    # cap clamps to ≥4 slots per expert; with 64 tokens×2 some survive. Use
    # finiteness + boundedness as the invariant here.
    assert bool(jnp.isfinite(y).all())


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000))
def test_router_weights_sum_to_one(seed):
    cfg = _cfg()
    m = cfg.moe
    h = jax.random.normal(jax.random.PRNGKey(seed), (16, cfg.d_model))
    router = jax.random.normal(jax.random.PRNGKey(seed + 1),
                               (cfg.d_model, m.n_routed))
    probs = jax.nn.softmax(h @ router, -1)
    tp, _ = jax.lax.top_k(probs, m.top_k)
    tp = tp / tp.sum(-1, keepdims=True)
    np.testing.assert_allclose(np.asarray(tp.sum(-1)), 1.0, atol=1e-6)


def test_moe_grads_flow_through_dispatch(mesh):
    cfg = _cfg()
    p = moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 64))
    g = jax.jit(jax.grad(
        lambda p, x: moe_apply(p, x, cfg, mesh=mesh,
                               batch_axes=("data",))[0].sum()))(p, x)
    for k in ("wg", "wu", "wd", "router", "sh_wg"):
        assert float(jnp.abs(g[k]).sum()) > 0, k
