"""Property/unit suite for the content-addressed, copy-on-write block
layer (serve/cache.py): refcounted allocator invariants under arbitrary
op interleavings, radix-trie lookup/registration/eviction semantics
(chained content hashes, partial-tail matches, dedupe), PagedKVCache-level
sharing/fork/reclaim bookkeeping, and the shared-block preemption-release
conservation fix.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.config import get_config, smoke_config
from repro.serve.cache import (BlockAllocator, PagedKVCache, PoolExhausted,
                               PrefixCache)


# ==========================================================================
# refcounted allocator
# ==========================================================================

def test_share_and_last_owner_free():
    al = BlockAllocator(6)
    ids = al.alloc(1, 2)
    al.share(ids, 2)
    assert al.refcount(ids[0]) == 2 and al.owners(ids[0]) == (1, 2)
    al.free(ids, 1)                       # first owner out: still allocated
    assert al.n_free == 3 and al.refcount(ids[0]) == 1
    al.check_conservation()
    al.free(ids, 2)                       # last owner out: back in the pool
    assert al.n_free == 5 and al.refcount(ids[0]) == 0
    al.check_conservation()


def test_share_errors():
    al = BlockAllocator(6)
    (b,) = al.alloc(1, 1)
    with pytest.raises(ValueError, match="already holds"):
        al.share([b], 1)                  # one ref per owner per block
    with pytest.raises(ValueError, match="free block"):
        al.share([5], 2)                  # sharing a free block
    al.share([b], 2)
    with pytest.raises(ValueError, match="not owned"):
        al.free([b], 3)                   # foreign free
    al.free([b], 2)
    with pytest.raises(ValueError, match="not owned"):
        al.free([b], 2)                   # double free of a dropped ref
    al.check_conservation()


def test_shared_free_releases_exactly_once():
    """A block's slot in the free list reappears exactly once no matter
    how many owners released it (the double-free class of bug)."""
    al = BlockAllocator(8)
    ids = al.alloc(0, 3)
    for o in (1, 2, 3):
        al.share(ids, o)
    for o in (2, 0, 3, 1):
        al.free(ids, o)
    assert sorted(al._free) == list(range(1, 8))
    assert len(al._free) == len(set(al._free))
    al.check_conservation()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 100_000))
def test_allocator_refcount_interleavings(seed):
    """Arbitrary interleavings of alloc/share/free against a mirror model:
    conservation holds after every op, refcounts match the mirror exactly,
    and a block returns to the free list exactly when its last owner
    releases it."""
    rng = np.random.default_rng(seed)
    n_blocks = int(rng.integers(4, 24))
    al = BlockAllocator(n_blocks)
    mirror = {}                            # block -> set(owners)
    owners = list(range(6))
    for _ in range(120):
        op = rng.choice(["alloc", "share", "free"])
        if op == "alloc":
            o = int(rng.choice(owners))
            n = int(rng.integers(1, 3))
            try:
                ids = al.alloc(o, n)
            except PoolExhausted:
                assert al.n_free < n      # raised only when truly short
                continue
            for b in ids:
                assert b not in mirror
                mirror[b] = {o}
        elif op == "share" and mirror:
            b = int(rng.choice(sorted(mirror)))
            o = int(rng.choice(owners))
            if o in mirror[b]:
                with pytest.raises(ValueError):
                    al.share([b], o)
            else:
                al.share([b], o)
                mirror[b].add(o)
        elif op == "free" and mirror:
            b = int(rng.choice(sorted(mirror)))
            legit = rng.random() < 0.8
            o = (int(rng.choice(sorted(mirror[b]))) if legit
                 else max(owners) + 1)
            if o in mirror[b]:
                was_last = mirror[b] == {o}
                free_before = al.n_free
                al.free([b], o)
                mirror[b].discard(o)
                if was_last:
                    del mirror[b]
                    assert al.n_free == free_before + 1
                else:
                    assert al.n_free == free_before
            else:
                with pytest.raises(ValueError):
                    al.free([b], o)
        al.check_conservation()
        for b, who in mirror.items():
            assert al.refcount(b) == len(who)
            assert al.owners(b) == tuple(sorted(who))


# ==========================================================================
# radix trie / content addressing
# ==========================================================================

def _trie(n_blocks=32, bs=4, salt=("t",)):
    al = BlockAllocator(n_blocks)
    return al, PrefixCache(al, bs, salt)


def _registered(al, pc, tokens, rid):
    """Simulate a request having written ``tokens``: alloc its blocks,
    register the full ones, return the block ids."""
    bs = pc.block_size
    n = max(1, -(-len(tokens) // bs))
    ids = al.alloc(rid, n)
    pc.register(tokens, ids[:len(tokens) // bs])
    return ids


def test_lookup_full_and_partial_tail():
    al, pc = _trie(bs=4)
    toks = list(range(100, 111))               # 11 tokens: 2 full blocks
    ids = _registered(al, pc, toks, rid=7)
    # exact full-block prefix
    n, hit = pc.lookup(toks[:8])
    assert n == 8 and hit == ids[:2]
    # longer query: full blocks only (positions 8..10 were never indexed)
    n, hit = pc.lookup(toks)
    assert n == 8 and hit == ids[:2]
    # partial tail: diverges inside block 1 → only block 0 + 2 tail tokens
    q = toks[:6] + [999, 999]
    n, hit = pc.lookup(q)
    assert n == 6 and hit == ids[:2]           # block 1 is a partial match
    assert pc.stats["partial_hits"] == 1
    # full miss
    n, hit = pc.lookup([1, 2, 3, 4])
    assert n == 0 and hit == []


def test_chain_hash_is_prefix_chained_and_salted():
    al1, pc1 = _trie(salt=("a",))
    al2, pc2 = _trie(salt=("b",))
    toks = list(range(8))
    _registered(al1, pc1, toks, 0)
    _registered(al2, pc2, toks, 0)
    n1 = pc1.root.children[tuple(toks[:4])]
    n2 = pc2.root.children[tuple(toks[:4])]
    assert n1.chain_hash == hash((pc1.root.chain_hash, tuple(toks[:4]),
                                  ("a",)))
    assert n1.chain_hash != n2.chain_hash      # same tokens, other salt
    c1 = n1.children[tuple(toks[4:])]
    assert c1.chain_hash == hash((n1.chain_hash, tuple(toks[4:]), ("a",)))


def test_register_dedupes_equal_content():
    al, pc = _trie(bs=4)
    toks = list(range(8))
    ids_a = _registered(al, pc, toks, rid=0)
    ids_b = al.alloc(1, 2)
    swaps = pc.register(toks, ids_b)           # same tokens, other blocks
    assert swaps == [(0, ids_a[0]), (1, ids_a[1])]
    assert pc.stats["deduped"] == 2
    pc.check_integrity()


def test_evict_lru_skips_pinned_blocks():
    al, pc = _trie(n_blocks=32, bs=4)
    a = _registered(al, pc, list(range(0, 8)), rid=0)      # older chain
    b = _registered(al, pc, list(range(50, 58)), rid=1)
    al.free(a, 0)                              # rid 0 done: cache-only now
    # rid 1 still holds its blocks → pinned; only chain a is evictable,
    # leaves first (child before parent)
    assert pc.evict(10) == 2
    assert al.refcount(a[0]) == 0 and al.refcount(a[1]) == 0
    assert al.refcount(b[0]) == 2              # untouched
    n, hit = pc.lookup(list(range(0, 8)))
    assert n == 0                              # chain a is gone
    n, hit = pc.lookup(list(range(50, 58)))
    assert n == 8
    pc.check_integrity()
    al.check_conservation()


def test_evict_prefers_lru_leaf():
    al, pc = _trie(n_blocks=32, bs=4)
    a = _registered(al, pc, list(range(0, 4)), rid=0)
    b = _registered(al, pc, list(range(10, 14)), rid=0)
    al.free(a + b, 0)
    pc.lookup(list(range(0, 4)))               # touch a: b becomes LRU
    assert pc.evict(1) == 1
    assert pc.lookup(list(range(0, 4)))[0] == 4
    assert pc.lookup(list(range(10, 14)))[0] == 0


# ==========================================================================
# PagedKVCache: sharing, copy-on-write, reclamation (host bookkeeping)
# ==========================================================================

def _cache(n_blocks=32, block_size=4, prefix=True, max_reqs=4):
    cfg = smoke_config(get_config("llama-gqa"))
    return PagedKVCache.create(cfg, block_size=block_size,
                               n_blocks=n_blocks, max_reqs=max_reqs,
                               prefix_cache=prefix)


def test_assign_shares_cached_prefix():
    c = _cache()
    toks = list(range(200, 211))               # 11 prefill tokens, bs=4
    c.assign(0, rid=0, n_tokens=len(toks) + 1, tokens=toks)
    c.register_prefix(0, 0, toks, len(toks))   # 2 full blocks indexed
    n_hit = c.assign(1, rid=1, n_tokens=len(toks) + 1, tokens=toks)
    assert n_hit == 8
    assert (c.table[0, :2] == c.table[1, :2]).all()    # shared storage
    assert c.table[0, 2] != c.table[1, 2]              # private tails
    assert c.allocator.refcount(int(c.table[0, 0])) == 3   # rid0+rid1+cache
    c.allocator.check_conservation()
    c.prefix.check_integrity()


def test_ensure_writable_forks_shared_blocks():
    c = _cache()
    toks = list(range(16))
    c.assign(0, rid=0, n_tokens=17, tokens=toks)
    c.register_prefix(0, 0, toks, 16)
    c.assign(1, rid=1, n_tokens=17, tokens=toks)
    b_shared = int(c.table[1, 2])
    assert b_shared == int(c.table[0, 2])
    forks = c.ensure_writable(1, rid=1, p0=9, p1=13)   # blocks 2..3
    assert forks == 2 and c.counters["forks"] == 2
    assert int(c.table[1, 2]) != b_shared              # private copy now
    assert c.allocator.refcount(b_shared) == 2         # rid0 + cache
    assert c.allocator.refcount(int(c.table[1, 2])) == 1
    # unshared block: no-op
    assert c.ensure_writable(1, rid=1, p0=12, p1=13) == 0
    c.allocator.check_conservation()


def test_release_preserves_shared_blocks():
    """Preempting/finishing a request whose blocks are shared must not
    free blocks still referenced by other slots (the conservation fix)."""
    c = _cache()
    toks = list(range(12))
    c.assign(0, rid=0, n_tokens=13, tokens=toks)
    c.register_prefix(0, 0, toks, 12)
    c.assign(1, rid=1, n_tokens=13, tokens=toks)
    shared = [int(b) for b in c.table[1, :3]]
    free_before = c.allocator.n_free
    c.release(0, rid=0)                        # rid 0 preempted
    # rid 1 (and the cache) still hold the shared blocks
    for b in shared:
        assert c.allocator.refcount(b) >= 1
    assert [int(b) for b in c.table[1, :3]] == shared
    c.allocator.check_conservation()
    # only rid 0's private tail block actually returned to the pool
    assert c.allocator.n_free == free_before + 1
    c.release(1, rid=1)
    c.allocator.check_conservation()


def test_reclaim_window_frees_out_of_window_blocks():
    c = _cache(prefix=False)
    c.assign(0, rid=0, n_tokens=20)            # 5 blocks (bs=4)
    free0 = c.allocator.n_free
    # next write at 18, window 6 → floor 13 → blocks 0..2 end ≤ 13? block
    # i is reclaimable iff (i+1)*4 <= 13: blocks 0, 1 and 2 end at 4,8,12
    assert c.reclaim_window(0, rid=0, next_pos=18, window=6) == 3
    assert c.allocator.n_free == free0 + 3
    assert list(c.table[0, :3]) == [0, 0, 0] and c.table[0, 3] != 0
    assert int(c.n_assigned[0]) == 5           # high-water mark unchanged
    # idempotent; later positions reclaim more
    assert c.reclaim_window(0, rid=0, next_pos=18, window=6) == 0
    assert c.reclaim_window(0, rid=0, next_pos=23, window=6) == 1
    c.release(0, rid=0)                        # skips the zeroed entries
    c.allocator.check_conservation()
    assert c.allocator.n_free == c.allocator.n_usable


def test_alloc_evicts_cache_only_blocks_under_pressure():
    c = _cache(n_blocks=9, block_size=4)       # 8 usable
    toks = list(range(28))                     # 7 full blocks
    c.assign(0, rid=0, n_tokens=28, tokens=toks)
    c.register_prefix(0, 0, toks, 28)
    c.release(0, rid=0)                        # all 7 now cache-only
    assert c.n_cache_blocks == 7 and c.allocator.n_free == 1
    # a fresh 3-block request must LRU-evict cache blocks, not fail
    n_hit = c.assign(1, rid=1, n_tokens=12, tokens=[777] * 11)
    assert n_hit == 0 and c.counters["evicted"] == 2
    c.allocator.check_conservation()
    # …but blocks shared with live requests are pinned: a request that
    # can only be satisfied by evicting *shared* blocks still raises
    toks2 = [888] * 20
    c.assign(2, rid=2, n_tokens=20, tokens=toks2)
    c.register_prefix(2, 2, toks2, 20)
    with pytest.raises(PoolExhausted):
        c.assign(3, rid=3, n_tokens=24, tokens=[999] * 23)
    c.allocator.check_conservation()


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 100_000))
def test_paged_cache_interleaving_invariants(seed):
    """Random interleavings of assign(+prefix sharing)/extend/
    fork-on-write/register/reclaim/release across slots keep the
    allocator conserved and the trie consistent after every op —
    the serving step loop's op alphabet, divorced from the model."""
    rng = np.random.default_rng(seed)
    c = _cache(n_blocks=int(rng.integers(10, 40)), block_size=4,
               max_reqs=4)
    vocab = [0, 1]                             # tiny: collisions guaranteed
    live = {}                                  # slot -> (rid, tokens, cached)
    next_rid = 0
    for _ in range(80):
        op = rng.choice(["assign", "extend", "write", "reclaim",
                         "release", "register"])
        if op == "assign" and len(live) < 4:
            slot = next(s for s in range(4) if s not in live)
            toks = [int(rng.choice(vocab)) for _ in
                    range(int(rng.integers(1, 14)))]
            try:
                n_hit = c.assign(slot, rid=next_rid,
                                 n_tokens=len(toks) + 1, tokens=toks)
            except PoolExhausted:
                continue
            live[slot] = [next_rid, toks, n_hit]
            next_rid += 1
        elif op == "extend" and live:
            slot = int(rng.choice(sorted(live)))
            rid, toks, cached = live[slot]
            try:
                c.extend(slot, rid)
            except (PoolExhausted, ValueError):
                pass
        elif op == "write" and live:
            slot = int(rng.choice(sorted(live)))
            rid, toks, cached = live[slot]
            if cached < len(toks):
                end = min(len(toks), cached + int(rng.integers(1, 6)))
                try:
                    c.ensure_writable(slot, rid, cached, end)
                except PoolExhausted:
                    continue
                live[slot][2] = end
        elif op == "register" and live:
            slot = int(rng.choice(sorted(live)))
            rid, toks, cached = live[slot]
            c.register_prefix(slot, rid, toks, cached)
        elif op == "reclaim" and live:
            slot = int(rng.choice(sorted(live)))
            rid, toks, cached = live[slot]
            c.reclaim_window(slot, rid, next_pos=cached,
                             window=int(rng.integers(1, 8)))
        elif op == "release" and live:
            slot = int(rng.choice(sorted(live)))
            rid, toks, _ = live.pop(slot)
            c.release(slot, rid)
        c.allocator.check_conservation()
        c.prefix.check_integrity()
    for slot in sorted(live):
        c.release(slot, live[slot][0])
    c.allocator.check_conservation()
    # drain the cache: every block must come back
    c.prefix.evict(c.allocator.n_usable)
    assert c.allocator.n_free == c.allocator.n_usable
