"""End-to-end training integration: a tiny model trains for a few dozen
steps on the synthetic Markov stream and the loss must drop substantially
(system-level behaviour, paper-faithful config: balanced schedule +
remat-aware checkpointing)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.config import (TrainConfig, get_config, smoke_config,
                               ShapeSpec)
from repro.data.pipeline import SyntheticTokens
from repro.models.transformer import Runtime, build_model
from repro.optim import adamw
from repro.parallel.sharding import make_parallel_config
from repro.train.step import make_train_step


def _train(arch, steps=30, remat="remat_aware", schedule="balanced"):
    cfg = smoke_config(get_config(arch)).replace(vocab=128)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeSpec("ti", 64, 4, "train")
    par = make_parallel_config(mesh, shape, schedule=schedule, remat=remat)
    model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.init(params)
    tc = TrainConfig(lr=3e-3, warmup_steps=5, total_steps=steps)
    step = jax.jit(make_train_step(model, tc))
    ds = SyntheticTokens(cfg, shape, par, mesh)
    losses = []
    for i in range(steps):
        params, opt, m = step(params, opt, ds.batch(i))
        losses.append(float(m["loss"]))
    return losses


def test_loss_decreases_dense():
    losses = _train("smollm-360m")
    assert losses[-1] < losses[0] - 0.5, losses[::10]


def test_loss_decreases_ssm():
    losses = _train("mamba2-2.7b", steps=25)
    assert losses[-1] < losses[0] - 0.5, losses[::8]


def test_remat_policies_agree():
    """The three checkpointing policies give the same loss trajectory
    (the paper's 'no numerical difference' claim, end to end)."""
    base = _train("smollm-360m", steps=4, remat="none")
    for pol in ("hf", "remat_aware"):
        other = _train("smollm-360m", steps=4, remat=pol)
        for a, b in zip(base, other):
            assert abs(a - b) < 2e-3, (pol, base, other)


def test_schedules_agree():
    base = _train("smollm-360m", steps=3, schedule="balanced")
    other = _train("smollm-360m", steps=3, schedule="ring")
    for a, b in zip(base, other):
        assert abs(a - b) < 2e-3


def test_nonfinite_step_is_skipped_params_bit_identical():
    """Non-finite guard: a poisoned step (NaN injected into a param leaf —
    batch tokens are integers, so the NaN enters through the forward the
    same way a poisoned batch would: NaN loss and NaN grads) must skip the
    optimizer update, report ``skipped_nonfinite``, and leave params AND
    optimizer state bit-identical; a healthy step afterwards updates
    normally."""
    cfg = smoke_config(get_config("smollm-360m")).replace(vocab=128)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeSpec("ti", 64, 4, "train")
    par = make_parallel_config(mesh, shape)
    model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.init(params)
    tc = TrainConfig(lr=3e-3, warmup_steps=5, total_steps=10)
    step = jax.jit(make_train_step(model, tc))
    ds = SyntheticTokens(cfg, shape, par, mesh)

    # one healthy step compiles + moves state off the init values
    params, opt, m = step(params, opt, ds.batch(0))
    assert int(m["skipped_nonfinite"]) == 0

    # poison one scalar: the loss and every grad go non-finite
    poisoned = jax.tree_util.tree_map(lambda x: x, params)
    poisoned["embed"] = poisoned["embed"].at[0, 0].set(jnp.nan)
    p2, o2, m2 = step(poisoned, opt, ds.batch(1))
    assert int(m2["skipped_nonfinite"]) == 1
    assert not jnp.isfinite(m2["loss"])
    for a, b in zip(jax.tree_util.tree_leaves(p2),
                    jax.tree_util.tree_leaves(poisoned)):
        assert a.dtype == b.dtype
        assert jnp.array_equal(a, b, equal_nan=True), "params changed " \
            "on a skipped step"
    for a, b in zip(jax.tree_util.tree_leaves(o2),
                    jax.tree_util.tree_leaves(opt)):
        assert jnp.array_equal(a, b, equal_nan=True), "optimizer state " \
            "changed on a skipped step"
    assert int(o2.step) == int(opt.step)

    # recovery: the next healthy step updates params again
    p3, o3, m3 = step(params, opt, ds.batch(2))
    assert int(m3["skipped_nonfinite"]) == 0
    assert int(o3.step) == int(opt.step) + 1
    assert any(not jnp.array_equal(a, b)
               for a, b in zip(jax.tree_util.tree_leaves(p3),
                               jax.tree_util.tree_leaves(params)))
