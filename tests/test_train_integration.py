"""End-to-end training integration: a tiny model trains for a few dozen
steps on the synthetic Markov stream and the loss must drop substantially
(system-level behaviour, paper-faithful config: balanced schedule +
remat-aware checkpointing)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.config import (TrainConfig, get_config, smoke_config,
                               ShapeSpec)
from repro.data.pipeline import SyntheticTokens
from repro.models.transformer import Runtime, build_model
from repro.optim import adamw
from repro.parallel.sharding import make_parallel_config
from repro.train.step import make_train_step


def _train(arch, steps=30, remat="remat_aware", schedule="balanced"):
    cfg = smoke_config(get_config(arch)).replace(vocab=128)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeSpec("ti", 64, 4, "train")
    par = make_parallel_config(mesh, shape, schedule=schedule, remat=remat)
    model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.init(params)
    tc = TrainConfig(lr=3e-3, warmup_steps=5, total_steps=steps)
    step = jax.jit(make_train_step(model, tc))
    ds = SyntheticTokens(cfg, shape, par, mesh)
    losses = []
    for i in range(steps):
        params, opt, m = step(params, opt, ds.batch(i))
        losses.append(float(m["loss"]))
    return losses


def test_loss_decreases_dense():
    losses = _train("smollm-360m")
    assert losses[-1] < losses[0] - 0.5, losses[::10]


def test_loss_decreases_ssm():
    losses = _train("mamba2-2.7b", steps=25)
    assert losses[-1] < losses[0] - 0.5, losses[::8]


def test_remat_policies_agree():
    """The three checkpointing policies give the same loss trajectory
    (the paper's 'no numerical difference' claim, end to end)."""
    base = _train("smollm-360m", steps=4, remat="none")
    for pol in ("hf", "remat_aware"):
        other = _train("smollm-360m", steps=4, remat=pol)
        for a, b in zip(base, other):
            assert abs(a - b) < 2e-3, (pol, base, other)


def test_schedules_agree():
    base = _train("smollm-360m", steps=3, schedule="balanced")
    other = _train("smollm-360m", steps=3, schedule="ring")
    for a, b in zip(base, other):
        assert abs(a - b) < 2e-3
