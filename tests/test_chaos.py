"""Chaos property suite: the serving engine under deterministic fault
injection (serve/faults.py).

Property tests (hypothesis-driven; the conftest shim supplies seeded
example generation when the real package is absent) assert the three
acceptance properties under seeded fault storms:

  1. **definite termination** — every submitted request reaches exactly one
     terminal state (finished / rejected / expired / failed) with a
     structured ``finish_reason``;
  2. **no block leaked or double-freed** — allocator conservation holds at
     exit (and, with ``audit=True``, after *every* step), and every
     non-cache block is back on the free list once the engine drains;
  3. **fault-isolation / batch invariance** — a chaos run's token streams
     agree with the zero-fault run of the same trace on their common
     prefix, and requests that finish under chaos finish with the
     *identical* stream: faults perturb scheduling, never a surviving
     request's numerics.

Engineered-scenario tests then pin down each fault kind's contract: NaN
quarantine hits exactly the poisoned row, corrupted blocks are scrubbed
before re-entering the free list, dropped steps retry with capped backoff
and exhaust into FAILED, preemption storms trip the forward-progress
watchdog into serial admission, squeezes never break conservation, and
the auditor raises structured :class:`AuditFailure`\\ s for seeded
corruption of the bookkeeping itself.
"""
import dataclasses

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.config import ShapeSpec, get_config, smoke_config
from repro.data.pipeline import SyntheticTokens
from repro.models.transformer import Runtime, build_model
from repro.parallel.sharding import make_parallel_config
from repro.serve.engine import Engine
from repro.serve.faults import (FAULT_OWNER, KINDS, AuditFailure, FaultEvent,
                                FaultInjector)
from repro.serve.scheduler import TERMINAL_STATES


@pytest.fixture(scope="module")
def served():
    """One smoke model for the whole module (build+init dominates)."""
    cfg = smoke_config(get_config("smollm-360m"))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeSpec("chaos", 24, 4, "prefill")
    par = make_parallel_config(mesh, shape)
    model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.asarray(
        SyntheticTokens(cfg, shape, par, mesh).batch(0)["tokens"])
    return model, params, prompts


def _engine(model, params, *, faults=None, n_blocks=28, max_batch=3,
            chunk=8, audit=True, **kw):
    return Engine(model, params, max_batch=max_batch, block_size=8,
                  n_blocks=n_blocks, prefill_chunk_tokens=chunk,
                  audit=audit, faults=faults, **kw)


def _trace(eng, prompts, trace_seed, *, n_reqs=5):
    """Submit a deterministic mixed trace: varied prompt lengths, token
    budgets, temperatures, and sprinkled deadlines. Returns rids."""
    rng = np.random.default_rng(trace_seed)
    rids = []
    for i in range(n_reqs):
        p = prompts[i % len(prompts)]
        plen = int(rng.integers(3, len(p)))
        deadline = int(rng.integers(25, 120)) if rng.random() < 0.3 else None
        rids.append(eng.submit(
            p[:plen], max_new_tokens=int(rng.integers(3, 8)),
            temperature=float(rng.choice([0.0, 0.8])), seed=i,
            deadline_steps=deadline))
    return rids


def _storm_run(model, params, prompts, fault_seed, trace_seed, *,
               faulty=True):
    inj = FaultInjector.seeded(fault_seed, n_steps=20,
                               rate=0.5) if faulty else None
    eng = _engine(model, params, faults=inj, max_retries=4,
                  watchdog_window=4, watchdog_threshold=3)
    rids = _trace(eng, prompts, trace_seed)
    out = eng.run(max_steps=3000)
    return eng, rids, out


def _assert_clean_exit(eng):
    """No block leaked or double-freed, nothing left running."""
    eng.cache.allocator.check_conservation()
    assert eng.sched.idle
    a = eng.cache.allocator
    assert a.n_free + eng.cache.n_cache_blocks == a.n_usable, \
        "blocks still held after drain (leak)"


# ==========================================================================
# acceptance properties under seeded storms
# ==========================================================================

@settings(max_examples=6, deadline=None)
@given(fault_seed=st.integers(0, 10_000), trace_seed=st.integers(0, 10_000))
def test_storm_every_request_terminates_and_pool_conserves(
        served, fault_seed, trace_seed):
    """Properties 1+2: any seeded fault schedule → definite terminal
    status for every request, conservation at exit (audit=True also
    checks it after every single step)."""
    model, params, prompts = served
    eng, rids, out = _storm_run(model, params, prompts, fault_seed,
                                trace_seed)
    for rid in rids:
        req = eng.requests[rid]
        assert req.state in TERMINAL_STATES, \
            f"rid {rid} ended in non-terminal state {req.state!r}"
        assert req.finish_reason is not None
    _assert_clean_exit(eng)


@settings(max_examples=4, deadline=None)
@given(fault_seed=st.integers(0, 10_000), trace_seed=st.integers(0, 10_000))
def test_storm_streams_match_zero_fault_run(served, fault_seed, trace_seed):
    """Property 3: chaos streams agree with the zero-fault run of the same
    trace on their common prefix, and chaos-FINISHED requests are
    token-identical — faults never touch a surviving request's numerics."""
    model, params, prompts = served
    chaos, rids, out_c = _storm_run(model, params, prompts, fault_seed,
                                    trace_seed)
    calm, rids2, out_0 = _storm_run(model, params, prompts, fault_seed,
                                    trace_seed, faulty=False)
    assert rids == rids2                       # same trace, same rids
    for rid in rids:
        m = min(out_c[rid].size, out_0[rid].size)
        np.testing.assert_array_equal(out_c[rid][:m], out_0[rid][:m])
        if chaos.requests[rid].state == "finished":
            assert calm.requests[rid].state == "finished"
            np.testing.assert_array_equal(out_c[rid], out_0[rid])


@settings(max_examples=3, deadline=None)
@given(fault_seed=st.integers(0, 10_000), trace_seed=st.integers(0, 10_000))
def test_storm_replays_byte_for_byte(served, fault_seed, trace_seed):
    """Same seed → same storm: the injector fire log, every terminal
    (state, reason), every emitted stream, and the counters replay
    exactly."""
    model, params, prompts = served
    a, rids_a, out_a = _storm_run(model, params, prompts, fault_seed,
                                  trace_seed)
    b, rids_b, out_b = _storm_run(model, params, prompts, fault_seed,
                                  trace_seed)
    assert a.injector.log == b.injector.log
    assert a.injector.counts == b.injector.counts
    for rid in rids_a:
        ra, rb = a.requests[rid], b.requests[rid]
        assert (ra.state, ra.finish_reason) == (rb.state, rb.finish_reason)
        np.testing.assert_array_equal(out_a[rid], out_b[rid])
    sa, sb = a.stats(), b.stats()
    assert sa == sb


# ==========================================================================
# engineered scenarios: one fault kind at a time
# ==========================================================================

def _solo(model, params, prompt, n, *, seed=0):
    eng = _engine(model, params, n_blocks=40, chunk=0, audit=False)
    rid = eng.submit(prompt, max_new_tokens=n, seed=seed)
    return eng.run()[rid]


def test_nan_quarantine_hits_only_the_poisoned_row(served):
    """A NaN-logit fault on one decode row fails exactly that request
    (reason nan_logits, clean partial stream kept); its batchmate streams
    on token-identical to its solo run; blocks are freed, refcounts
    intact."""
    model, params, prompts = served
    inj = FaultInjector([FaultEvent(step=4, kind="nan_logits", target=0)])
    eng = _engine(model, params, faults=inj, chunk=0)
    r0 = eng.submit(prompts[0][:10], max_new_tokens=8, seed=0)
    r1 = eng.submit(prompts[1][:10], max_new_tokens=8, seed=1)
    out = eng.run()
    states = sorted(eng.requests[r].state for r in (r0, r1))
    assert states == ["failed", "finished"]
    failed = r0 if eng.requests[r0].state == "failed" else r1
    ok = r1 if failed == r0 else r0
    assert eng.requests[failed].finish_reason == "nan_logits"
    assert eng.stats()["quarantined"] == 1
    # the poisoned sample was discarded: the kept partial stream is a
    # clean prefix of the victim's solo stream
    solo_f = _solo(model, params, eng.requests[failed].prompt, 8,
                   seed=0 if failed == r0 else 1)
    np.testing.assert_array_equal(out[failed],
                                  solo_f[:out[failed].size])
    assert out[failed].size < 8
    # the survivor is untouched
    solo_ok = _solo(model, params, eng.requests[ok].prompt, 8,
                    seed=0 if ok == r0 else 1)
    np.testing.assert_array_equal(out[ok], solo_ok)
    _assert_clean_exit(eng)


def test_corrupt_block_poisons_exactly_one_request_and_is_scrubbed(served):
    """A corrupted pool block surfaces as NaN logits in the owning request
    → quarantined; the block is zero-scrubbed before returning to the free
    list (no NaN survives for the next tenant)."""
    model, params, prompts = served
    inj = FaultInjector([FaultEvent(step=5, kind="corrupt_block",
                                    target=0)])
    eng = _engine(model, params, faults=inj, chunk=0)
    rid = eng.submit(prompts[0][:12], max_new_tokens=10)
    out = eng.run()
    req = eng.requests[rid]
    assert req.state == "failed" and req.finish_reason == "nan_logits"
    fired = [d for s, k, d in inj.log if k == "corrupt_block"]
    assert fired and fired[0].startswith(f"rid={rid} block=")
    block = int(fired[0].split("block=")[1])
    for pk, pool in eng.cache.pools.items():
        assert np.isfinite(np.asarray(pool[:, block])).all(), \
            f"NaN survived the scrub in {pk}"
    _assert_clean_exit(eng)


def test_drop_step_retries_without_perturbing_the_stream(served):
    """A transient dropped decode step advances nobody; the engine backs
    off and retries, and the final stream is token-identical to the
    fault-free stream (nothing lost, nothing re-sampled)."""
    model, params, prompts = served
    inj = FaultInjector([FaultEvent(step=3, kind="drop_step"),
                         FaultEvent(step=6, kind="drop_step")])
    eng = _engine(model, params, faults=inj, chunk=0)
    rid = eng.submit(prompts[0][:10], max_new_tokens=8)
    out = eng.run()
    assert eng.requests[rid].state == "finished"
    np.testing.assert_array_equal(
        out[rid], _solo(model, params, prompts[0][:10], 8))
    s = eng.stats()
    assert s["retried"] >= 2 and s["faults"]["drop_step"] == 2
    # the first drop opens a backoff window past the fault itself: at
    # least one later (fault-free) step was skipped waiting it out
    assert s["backoff_steps"] > 0
    _assert_clean_exit(eng)


def test_consecutive_drops_exhaust_retries_into_failed(served):
    """Endless transient faults must not spin forever: after max_retries
    dropped attempts a request terminates FAILED(retries_exhausted)."""
    model, params, prompts = served
    inj = FaultInjector([FaultEvent(step=s, kind="drop_step")
                         for s in range(40)])
    eng = _engine(model, params, faults=inj, chunk=0, max_retries=3)
    rid = eng.submit(prompts[0][:8], max_new_tokens=6)
    eng.run()
    req = eng.requests[rid]
    assert req.state == "failed"
    assert req.finish_reason == "retries_exhausted"
    assert req.retries > 3
    # it failed long before the 40-step storm ended: bounded, not a spin
    assert eng.stats()["steps"] < 20
    _assert_clean_exit(eng)


def test_preempt_storm_trips_watchdog_into_serial_admission(served):
    """Livelock pressure: a storm preempting every step with no tokens
    emitted trips the forward-progress watchdog (serial admission); once
    the storm passes, the request completes with an unperturbed stream."""
    model, params, prompts = served
    inj = FaultInjector([FaultEvent(step=s, kind="preempt_storm",
                                    magnitude=2) for s in range(10)])
    eng = Engine(model, params, max_batch=2, block_size=8, n_blocks=28,
                 prefill_chunk_tokens=4, prefix_cache=False, audit=True,
                 faults=inj, watchdog_window=3, watchdog_threshold=2)
    rid = eng.submit(prompts[0][:20], max_new_tokens=5)
    out = eng.run()
    s = eng.stats()
    assert s["watchdog_trips"] >= 1
    assert s["storm_preempts"] > 0
    assert eng.requests[rid].state == "finished"
    np.testing.assert_array_equal(
        out[rid], _solo(model, params, prompts[0][:20], 5))
    _assert_clean_exit(eng)


def test_squeeze_holds_conservation_and_releases(served):
    """Pool squeezes park blocks under FAULT_OWNER — conservation holds
    mid-squeeze (audited every step) and every squeezed block is back on
    the free list once the engine drains."""
    model, params, prompts = served
    inj = FaultInjector([FaultEvent(step=1, kind="squeeze", magnitude=12,
                                    duration=6),
                         FaultEvent(step=3, kind="squeeze", magnitude=8,
                                    duration=2)])
    eng = _engine(model, params, faults=inj, n_blocks=24, chunk=4)
    rids = [eng.submit(prompts[i][:12], max_new_tokens=5) for i in range(3)]
    eng.run()
    for rid in rids:
        assert eng.requests[rid].state in TERMINAL_STATES
    assert not eng.cache.allocator.owned(FAULT_OWNER)
    assert eng.stats()["faults"]["squeeze"] == 2
    _assert_clean_exit(eng)


def test_slow_steps_expire_deadlines_deterministically(served):
    """slow_step burns virtual clock ticks: a request whose TTL would
    comfortably fit in real steps expires under slow faults — EXPIRED,
    partial stream kept."""
    model, params, prompts = served
    inj = FaultInjector([FaultEvent(step=s, kind="slow_step", magnitude=5)
                         for s in range(2, 12)])
    eng = _engine(model, params, faults=inj, chunk=0)
    rid = eng.submit(prompts[0][:10], max_new_tokens=30, deadline_steps=25)
    out = eng.run()
    req = eng.requests[rid]
    assert req.state == "expired" and req.finish_reason == "deadline"
    assert 0 < out[rid].size < 30
    np.testing.assert_array_equal(
        out[rid],
        _solo(model, params, prompts[0][:10], 30)[:out[rid].size])
    _assert_clean_exit(eng)


# ==========================================================================
# the injector itself
# ==========================================================================

def test_seeded_schedule_is_deterministic_and_validated():
    a = FaultInjector.seeded(7, n_steps=50, rate=0.4)
    b = FaultInjector.seeded(7, n_steps=50, rate=0.4)
    assert a.events == b.events and len(a.events) > 0
    assert FaultInjector.seeded(8, n_steps=50, rate=0.4).events != a.events
    assert a.horizon >= max(e.step for e in a.events)
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent(step=0, kind="gamma_ray")
    with pytest.raises(ValueError, match="malformed"):
        FaultEvent(step=-1, kind="squeeze")
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultInjector.seeded(0, kinds=("squeeze", "nope"))


def test_pick_is_stable_modulo_candidates():
    e = FaultEvent(step=0, kind="nan_logits", target=5)
    assert FaultInjector().pick(e, ["a", "b", "c"]) == "c"
    assert FaultInjector().pick(e, []) is None


# ==========================================================================
# the auditor
# ==========================================================================

def test_audit_failure_is_structured(served):
    """Seeded bookkeeping corruption: the auditor names the violated
    invariant in a structured AuditFailure."""
    model, params, prompts = served
    eng = _engine(model, params, chunk=0, audit=True)
    eng.submit(prompts[0][:10], max_new_tokens=4)
    eng.step()
    # corrupt the bookkeeping behind the allocator's back: orphan a block
    # out of the free list
    eng.cache.allocator._free.remove(eng.cache.allocator._free[0])
    with pytest.raises(AuditFailure) as ei:
        eng.step()
    assert ei.value.invariant == "allocator_conservation"
    assert "lost blocks" in ei.value.detail


def test_audit_catches_table_ownership_violation(served):
    model, params, prompts = served
    eng = _engine(model, params, chunk=0, audit=True)
    rid = eng.submit(prompts[0][:10], max_new_tokens=6)
    eng.step()
    slot = eng.requests[rid].slot
    # scribble a block id the request does not own into its table
    eng.cache.table[slot, 0] = eng.cache.allocator._free[0]
    with pytest.raises(AuditFailure) as ei:
        eng.step()
    assert ei.value.invariant == "table_ownership"


def test_audit_passes_are_counted(served):
    model, params, prompts = served
    eng = _engine(model, params, chunk=0, audit=True)
    eng.submit(prompts[0][:8], max_new_tokens=4)
    eng.run()
    s = eng.stats()
    assert s["audit_passes"] == s["steps"] > 0
