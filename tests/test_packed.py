"""Packed-sequence (document-masked) training, end to end: the data
pipeline's packed batches, the model's document masking, and the train step
— differentially against unpacked/per-document oracles. These are the
tier-1 "packed differential" tests CI runs under both JAX versions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mask as mk
from repro.core.config import (ShapeSpec, TrainConfig, get_config,
                               smoke_config)
from repro.data.pipeline import SyntheticTokens, input_specs
from repro.models.transformer import Runtime, build_model
from repro.parallel.sharding import make_parallel_config


def _mesh1():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_doc_boundaries_layout():
    """The shared static layout helper is sane for the shapes the pipeline,
    bench, and kernels all use."""
    for T, n in [(128, 4), (256, 5), (64, 1), (1024, 8), (7, 3)]:
        bnd = mk.doc_boundaries(T, n)
        assert bnd[0] == 0 and list(bnd) == sorted(set(bnd))
        assert bnd[-1] < T
        seg = mk.segments_from_boundaries(T, bnd)
        assert seg.shape == (T,) and seg[0] == 0
        assert seg[-1] == len(bnd) - 1
        assert np.all(np.diff(seg) >= 0)


def test_pipeline_emits_packed_batch():
    """ShapeSpec.docs > 1 → segment_ids present and consistent with the
    static layout; labels end each document with -100 and never cross a
    boundary."""
    cfg = smoke_config(get_config("smollm-360m"))
    shape = ShapeSpec("packed", 96, 2, "train", docs=3)
    mesh = _mesh1()
    par = make_parallel_config(mesh, shape)
    batch = SyntheticTokens(cfg, shape, par, mesh).batch(0)
    assert set(batch) == {"tokens", "labels", "segment_ids"}
    seg = np.asarray(batch["segment_ids"])
    bnd = mk.doc_boundaries(96, 3)
    np.testing.assert_array_equal(seg[0], mk.segments_from_boundaries(96,
                                                                      bnd))
    labels = np.asarray(batch["labels"])
    tokens = np.asarray(batch["tokens"])
    ends = [b - 1 for b in bnd[1:]] + [95]
    assert np.all(labels[:, ends] == -100)         # no cross-doc target
    inner = np.setdiff1d(np.arange(96), ends)
    # within a document the label is the next token
    np.testing.assert_array_equal(labels[:, inner], tokens[:, inner + 1])
    # the spec layer agrees with the batch layer
    specs, shards = input_specs(cfg, shape, par, mesh)
    assert "segment_ids" in specs
    assert specs["segment_ids"].shape == (2, 96)
    # determinism
    b2 = SyntheticTokens(cfg, shape, par, mesh).batch(0)
    np.testing.assert_array_equal(np.asarray(b2["tokens"]), tokens)


def test_packed_loss_equals_per_document_loss():
    """The packed model loss (document mask + -100 boundary labels) equals
    the token-weighted mean of per-document losses computed on separate,
    unpacked batches — the defining property of packed training."""
    cfg = smoke_config(get_config("smollm-360m"))
    T, docs = 96, 3
    shape = ShapeSpec("packed", T, 2, "train", docs=docs)
    mesh = _mesh1()
    par = make_parallel_config(mesh, shape)
    model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))
    params = model.init(jax.random.PRNGKey(0))
    batch = SyntheticTokens(cfg, shape, par, mesh).batch(0)
    packed_loss, _ = jax.jit(model.loss)(params, batch)

    # per-document: run each doc alone (positions reset to 0, which matches
    # the packed batch because our packed layout restarts rope per doc? No —
    # rope positions are global in the packed batch, so replicate that by
    # slicing the packed arrays and keeping the document's own positions
    # masked via a single-doc run of the same length prefix. Instead compute
    # the oracle directly: same packed tokens, block-diagonal mask via
    # segment_ids is already the model path — so cross-check against the
    # mean of losses with all OTHER documents' labels masked out.
    bnd = mk.doc_boundaries(T, docs)
    ends = list(bnd[1:]) + [T]
    labels = np.asarray(batch["labels"])
    totals, counts = [], []
    for b0, b1 in zip(bnd, ends):
        lab = np.full_like(labels, -100)
        lab[:, b0:b1] = labels[:, b0:b1]
        doc_batch = dict(batch)
        doc_batch["labels"] = jnp.asarray(lab)
        doc_loss, _ = jax.jit(model.loss)(params, doc_batch)
        n = int((lab >= 0).sum())
        totals.append(float(doc_loss) * n)
        counts.append(n)
    weighted = sum(totals) / sum(counts)
    assert abs(float(packed_loss) - weighted) < 5e-5, (float(packed_loss),
                                                       weighted)


def test_packed_mask_actually_masks():
    """Dropping segment_ids from the packed batch changes the loss — the
    document mask is load-bearing, not decorative."""
    cfg = smoke_config(get_config("smollm-360m"))
    shape = ShapeSpec("packed", 96, 2, "train", docs=3)
    mesh = _mesh1()
    par = make_parallel_config(mesh, shape)
    model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))
    params = model.init(jax.random.PRNGKey(0))
    batch = SyntheticTokens(cfg, shape, par, mesh).batch(0)
    dense = dict(batch)
    del dense["segment_ids"]
    l_packed, _ = jax.jit(model.loss)(params, batch)
    l_dense, _ = jax.jit(model.loss)(params, dense)
    assert abs(float(l_packed) - float(l_dense)) > 1e-4


def test_packed_grads_flow_all_backends():
    """value_and_grad through the packed loss works for every exact backend
    (the remat-aware combinator must route float0 segment cotangents)."""
    cfg = smoke_config(get_config("smollm-360m"))
    shape = ShapeSpec("packed", 64, 1, "train", docs=2)
    mesh = _mesh1()
    par = make_parallel_config(mesh, shape)
    batch = None
    vals = {}
    for impl in ("ref", "chunked-lax", "pallas-interpret"):
        model = build_model(cfg, Runtime(mesh=mesh, par=par, impl=impl))
        params = model.init(jax.random.PRNGKey(0))
        if batch is None:
            batch = SyntheticTokens(cfg, shape, par, mesh).batch(0)
        (loss, _), grads = jax.jit(jax.value_and_grad(
            model.loss, has_aux=True))(params, batch)
        gnorm = jax.tree_util.tree_reduce(
            lambda a, x: a + float(jnp.sum(jnp.abs(x))), grads, 0.0)
        assert np.isfinite(float(loss)) and np.isfinite(gnorm)
        vals[impl] = (float(loss), gnorm)
    base = vals["ref"]
    for impl, (l, g) in vals.items():
        assert abs(l - base[0]) < 1e-4, (impl, vals)
        assert abs(g - base[1]) < 5e-2 * max(1.0, abs(base[1])), (impl, vals)


def test_packed_rejected_for_unsupported_archs():
    cfg = smoke_config(get_config("mamba2-2.7b"))
    shape = ShapeSpec("packed", 64, 1, "train", docs=2)
    mesh = _mesh1()
    par = make_parallel_config(mesh, shape)
    model = build_model(cfg, Runtime(mesh=mesh, par=par))
    params = model.init(jax.random.PRNGKey(0))
    tok = jnp.zeros((1, 64), jnp.int32)
    batch = {"tokens": tok, "labels": tok,
             "segment_ids": jnp.zeros((1, 64), jnp.int32)}
    with pytest.raises(ValueError, match="packed"):
        model.loss(params, batch)


def test_packed_distributed_matches_single(subproc):
    """ACCEPTANCE (model level): the packed loss+grad on an 8-device CPU
    mesh equals the 1-device value across balanced / ring / zigzag — packed
    batches are exact under every sequence-parallel schedule."""
    out = subproc("""
import jax, jax.numpy as jnp
from repro.core.config import get_config, smoke_config, ShapeSpec
from repro.data.pipeline import SyntheticTokens
from repro.models.transformer import Runtime, build_model
from repro.parallel.sharding import make_parallel_config
cfg = smoke_config(get_config("smollm-360m"))
shape = ShapeSpec("packed", 128, 4, "train", docs=4)
vals = {}
for (d, s, sched) in [(1,1,"balanced"), (2,4,"balanced"), (1,8,"ring"), (1,8,"zigzag")]:
    mesh = jax.make_mesh((d, s), ("data", "model"))
    par = make_parallel_config(mesh, shape, schedule=sched)
    model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))
    params = model.init(jax.random.PRNGKey(0))
    batch = SyntheticTokens(cfg, shape, par, mesh).batch(0)
    (loss, _), grads = jax.jit(jax.value_and_grad(model.loss, has_aux=True))(params, batch)
    gsum = jax.tree_util.tree_reduce(lambda a, x: a + float(jnp.sum(jnp.abs(x))), grads, 0.0)
    vals[(d, s, sched)] = (float(loss), gsum)
base = vals[(1, 1, "balanced")]
for key, (l, g) in vals.items():
    assert abs(l - base[0]) < 5e-3 * max(1, abs(base[0])), (key, vals)
    assert abs(g - base[1]) < 1e-2 * max(1, abs(base[1])), (key, vals)
    print("OK", key, l)
""")
    assert out.count("OK") == 4


def test_packed_train_step_runs():
    """One full jit train step on a packed batch (AdamW update included)."""
    from repro.optim import adamw
    from repro.train.step import make_train_step
    cfg = smoke_config(get_config("smollm-360m"))
    shape = ShapeSpec("packed", 64, 2, "train", docs=2)
    mesh = _mesh1()
    par = make_parallel_config(mesh, shape)
    model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.init(params)
    step = jax.jit(make_train_step(model, TrainConfig()))
    data = SyntheticTokens(cfg, shape, par, mesh)
    l0 = l1 = None
    for i in range(3):
        params, opt, metrics = step(params, opt, data.batch(i))
        l0 = float(metrics["loss"]) if l0 is None else l0
        l1 = float(metrics["loss"])
    assert np.isfinite(l1)
