"""Per-kernel validation: Pallas flash-attention (interpret mode) vs the
pure-jnp oracle, swept over shapes / dtypes / masks / GQA groupings."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops
from repro.kernels.ref import chunk_attn_ref, chunk_attn_bwd_ref

CASES = [
    # (B, Tq, Tk, Hq, Hkv, D, causal, rel, window, dtype)
    (1, 128, 128, 2, 2, 64, True, 0, 0, jnp.float32),
    (2, 128, 128, 4, 2, 64, False, 256, 0, jnp.float32),
    (1, 256, 128, 2, 1, 32, False, 512, 300, jnp.float32),
    (1, 64, 64, 2, 2, 16, True, 0, 0, jnp.float32),
    (1, 128, 256, 8, 8, 128, False, 512, 0, jnp.float32),
    (2, 128, 128, 2, 2, 64, True, 0, 100, jnp.float32),
    (1, 128, 128, 2, 2, 64, True, 0, 0, jnp.bfloat16),
    (1, 256, 256, 3, 1, 64, True, 0, 0, jnp.float32),  # odd heads (33H case)
]


def _mk(B, Tq, Tk, Hq, Hkv, D, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, Tq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Tk, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Tk, Hkv, D), dtype)
    do = jax.random.normal(ks[3], (B, Tq, Hq, D), dtype)
    return q, k, v, do


@pytest.mark.parametrize("case", CASES)
def test_flash_fwd_matches_ref(case):
    B, Tq, Tk, Hq, Hkv, D, causal, rel, window, dtype = case
    q, k, v, _ = _mk(B, Tq, Tk, Hq, Hkv, D, dtype)
    o_r, lse_r = chunk_attn_ref(q, k, v, causal=causal, q_offset=rel,
                                window=window)
    o_p, lse_p = ops.flash_fwd(q, k, v, causal=causal, rel_offset=rel,
                               window=window, interpret=True)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    assert jnp.allclose(o_r.astype(jnp.float32), o_p.astype(jnp.float32),
                        atol=tol, rtol=tol)
    m = (lse_r > -1e29) | (lse_p > -1e29)
    assert jnp.allclose(jnp.where(m, lse_r, 0), jnp.where(m, lse_p, 0),
                        atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("case", CASES)
def test_flash_bwd_matches_ref(case):
    B, Tq, Tk, Hq, Hkv, D, causal, rel, window, dtype = case
    q, k, v, do = _mk(B, Tq, Tk, Hq, Hkv, D, dtype)
    o, lse = chunk_attn_ref(q, k, v, causal=causal, q_offset=rel,
                            window=window)
    ref = chunk_attn_bwd_ref(q, k, v, o, lse, do, causal=causal,
                             q_offset=rel, window=window)
    pal = ops.flash_bwd(q, k, v, o, lse, do, causal=causal, rel_offset=rel,
                        window=window, interpret=True)
    tol = 2e-4 if dtype == jnp.float32 else 5e-2
    for r, p_ in zip(ref, pal):
        assert jnp.allclose(r.astype(jnp.float32), p_.astype(jnp.float32),
                            atol=tol, rtol=tol)


def test_kernel_mla_asymmetric_dims():
    """MLA head shapes: Dk=192-like != Dv (here 48/24), custom scale."""
    q, k, _, _ = _mk(1, 128, 128, 4, 4, 48, jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(7), (1, 128, 4, 24))
    o_r, l_r = chunk_attn_ref(q, k, v, causal=True, scale=0.2)
    o_p, l_p = ops.flash_fwd(q, k, v, causal=True, scale=0.2, interpret=True)
    assert jnp.allclose(o_r, o_p, atol=1e-5)
    do = jax.random.normal(jax.random.PRNGKey(8), o_r.shape)
    r = chunk_attn_bwd_ref(q, k, v, o_r, l_r, do, causal=True, scale=0.2)
    p_ = ops.flash_bwd(q, k, v, o_p, l_p, do, causal=True, scale=0.2,
                       interpret=True)
    for a, b in zip(r, p_):
        assert jnp.allclose(a, b, atol=2e-4)


def test_kernel_block_sizes():
    """Non-default BlockSpec tilings agree with the oracle."""
    q, k, v, _ = _mk(1, 256, 256, 2, 2, 64, jnp.float32)
    o_r, _ = chunk_attn_ref(q, k, v, causal=True)
    for bq, bk in [(64, 128), (128, 64), (256, 256), (64, 64)]:
        o_p, _ = ops.flash_fwd(q, k, v, causal=True, block_q=bq, block_kv=bk,
                               interpret=True)
        assert jnp.allclose(o_r, o_p, atol=1e-5), (bq, bk)


def test_kernel_ref_grad_consistency():
    """ref bwd == jax.grad through monolithic softmax attention."""
    from repro.kernels.ref import full_attn_ref
    q, k, v, _ = _mk(1, 64, 64, 2, 2, 32, jnp.float32)

    def loss(q, k, v):
        return jnp.sum(full_attn_ref(q, k, v, causal=True) ** 2)

    dq_a, dk_a, dv_a = jax.grad(loss, (0, 1, 2))(q, k, v)
    o, lse = chunk_attn_ref(q, k, v, causal=True)
    dq, dk, dv = chunk_attn_bwd_ref(q, k, v, o, lse, 2 * o, causal=True)
    assert jnp.allclose(dq, dq_a, atol=1e-4)
    assert jnp.allclose(dk, dk_a, atol=1e-4)
    assert jnp.allclose(dv, dv_a, atol=1e-4)
