"""Per-kernel validation: Pallas flash-attention (interpret mode) vs the
pure-jnp oracle, swept over shapes / dtypes / masks / GQA groupings."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops
from repro.kernels.ref import chunk_attn_ref, chunk_attn_bwd_ref

CASES = [
    # (B, Tq, Tk, Hq, Hkv, D, causal, rel, window, dtype)
    (1, 128, 128, 2, 2, 64, True, 0, 0, jnp.float32),
    (2, 128, 128, 4, 2, 64, False, 256, 0, jnp.float32),
    (1, 256, 128, 2, 1, 32, False, 512, 300, jnp.float32),
    (1, 64, 64, 2, 2, 16, True, 0, 0, jnp.float32),
    (1, 128, 256, 8, 8, 128, False, 512, 0, jnp.float32),
    (2, 128, 128, 2, 2, 64, True, 0, 100, jnp.float32),
    (1, 128, 128, 2, 2, 64, True, 0, 0, jnp.bfloat16),
    (1, 256, 256, 3, 1, 64, True, 0, 0, jnp.float32),  # odd heads (33H case)
]


def _mk(B, Tq, Tk, Hq, Hkv, D, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, Tq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Tk, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Tk, Hkv, D), dtype)
    do = jax.random.normal(ks[3], (B, Tq, Hq, D), dtype)
    return q, k, v, do


@pytest.mark.parametrize("case", CASES)
def test_flash_fwd_matches_ref(case):
    B, Tq, Tk, Hq, Hkv, D, causal, rel, window, dtype = case
    q, k, v, _ = _mk(B, Tq, Tk, Hq, Hkv, D, dtype)
    o_r, lse_r = chunk_attn_ref(q, k, v, causal=causal, q_offset=rel,
                                window=window)
    o_p, lse_p = ops.flash_fwd(q, k, v, causal=causal, rel_offset=rel,
                               window=window, interpret=True)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    assert jnp.allclose(o_r.astype(jnp.float32), o_p.astype(jnp.float32),
                        atol=tol, rtol=tol)
    m = (lse_r > -1e29) | (lse_p > -1e29)
    assert jnp.allclose(jnp.where(m, lse_r, 0), jnp.where(m, lse_p, 0),
                        atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("case", CASES)
def test_flash_bwd_matches_ref(case):
    B, Tq, Tk, Hq, Hkv, D, causal, rel, window, dtype = case
    q, k, v, do = _mk(B, Tq, Tk, Hq, Hkv, D, dtype)
    o, lse = chunk_attn_ref(q, k, v, causal=causal, q_offset=rel,
                            window=window)
    ref = chunk_attn_bwd_ref(q, k, v, o, lse, do, causal=causal,
                             q_offset=rel, window=window)
    pal = ops.flash_bwd(q, k, v, o, lse, do, causal=causal, rel_offset=rel,
                        window=window, interpret=True)
    tol = 2e-4 if dtype == jnp.float32 else 5e-2
    for r, p_ in zip(ref, pal):
        assert jnp.allclose(r.astype(jnp.float32), p_.astype(jnp.float32),
                            atol=tol, rtol=tol)


def test_kernel_mla_asymmetric_dims():
    """MLA head shapes: Dk=192-like != Dv (here 48/24), custom scale."""
    q, k, _, _ = _mk(1, 128, 128, 4, 4, 48, jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(7), (1, 128, 4, 24))
    o_r, l_r = chunk_attn_ref(q, k, v, causal=True, scale=0.2)
    o_p, l_p = ops.flash_fwd(q, k, v, causal=True, scale=0.2, interpret=True)
    assert jnp.allclose(o_r, o_p, atol=1e-5)
    do = jax.random.normal(jax.random.PRNGKey(8), o_r.shape)
    r = chunk_attn_bwd_ref(q, k, v, o_r, l_r, do, causal=True, scale=0.2)
    p_ = ops.flash_bwd(q, k, v, o_p, l_p, do, causal=True, scale=0.2,
                       interpret=True)
    for a, b in zip(r, p_):
        assert jnp.allclose(a, b, atol=2e-4)


def test_kernel_block_sizes():
    """Non-default BlockSpec tilings agree with the oracle."""
    q, k, v, _ = _mk(1, 256, 256, 2, 2, 64, jnp.float32)
    o_r, _ = chunk_attn_ref(q, k, v, causal=True)
    for bq, bk in [(64, 128), (128, 64), (256, 256), (64, 64)]:
        o_p, _ = ops.flash_fwd(q, k, v, causal=True, block_q=bq, block_kv=bk,
                               interpret=True)
        assert jnp.allclose(o_r, o_p, atol=1e-5), (bq, bk)


# --------------------------------------------------- block-sparse pruning

# causal × window × rel_offset sweep for the pruned grids, including odd
# nq/nk, Tq != Tk, GQA g > 1, and the all-masked / all-unmasked range edges
PRUNE_CASES = [
    # (B, Tq, Tk, Hq, Hkv, D, causal, rel, window, bq, bk)
    (1, 192, 320, 4, 2, 32, True, 0, 0, 64, 64),     # odd nq/nk trapezoid
    (1, 192, 320, 4, 2, 32, True, 128, 48, 64, 64),  # causal + rel + window
    (1, 128, 256, 2, 1, 32, False, 256, 96, 64, 64),  # windowed ring step
    (1, 128, 128, 2, 2, 32, True, -128, 0, 64, 64),  # all blocks masked
    (1, 128, 128, 2, 2, 32, False, 0, 0, 64, 64),    # no mask: prune = noop
    (1, 128, 128, 2, 2, 32, True, -64, 0, 64, 64),   # leading rows masked
    (1, 64, 256, 2, 2, 32, True, 192, 64, 64, 64),   # single-q-block band
    (1, 128, 192, 3, 3, 16, True, 32, 80, 32, 64),   # br != bc, odd heads
]


def _prune_ids(c):
    B, Tq, Tk, Hq, Hkv, D, causal, rel, window, bq, bk = c
    return (f"Tq{Tq}-Tk{Tk}-g{Hq // Hkv}-c{int(causal)}-r{rel}-w{window}"
            f"-b{bq}x{bk}")


@pytest.mark.parametrize("case", PRUNE_CASES, ids=_prune_ids)
def test_pruned_flash_fwd_matches_ref_and_dense(case):
    """Pruned Pallas grids are exact vs the oracle AND bit-consistent with
    the dense (prune=False) sweep of the same kernel."""
    B, Tq, Tk, Hq, Hkv, D, causal, rel, window, bq, bk = case
    q, k, v, _ = _mk(B, Tq, Tk, Hq, Hkv, D, jnp.float32)
    o_r, lse_r = chunk_attn_ref(q, k, v, causal=causal, q_offset=rel,
                                window=window)
    kw = dict(causal=causal, rel_offset=rel, window=window, block_q=bq,
              block_kv=bk, interpret=True)
    o_p, lse_p = ops.flash_fwd(q, k, v, **kw)
    o_d, lse_d = ops.flash_fwd(q, k, v, prune=False, **kw)
    assert jnp.allclose(o_r, o_p, atol=1e-5, rtol=1e-5)
    m = (lse_r > -1e29) | (lse_p > -1e29)
    assert jnp.allclose(jnp.where(m, lse_r, 0), jnp.where(m, lse_p, 0),
                        atol=1e-4, rtol=1e-4)
    assert jnp.allclose(o_p, o_d, atol=1e-6), "prune changed the result"
    assert jnp.allclose(lse_p, lse_d, atol=1e-6)


@pytest.mark.parametrize("case", PRUNE_CASES, ids=_prune_ids)
def test_pruned_flash_bwd_matches_ref_and_dense(case):
    B, Tq, Tk, Hq, Hkv, D, causal, rel, window, bq, bk = case
    q, k, v, do = _mk(B, Tq, Tk, Hq, Hkv, D, jnp.float32)
    o, lse = chunk_attn_ref(q, k, v, causal=causal, q_offset=rel,
                            window=window)
    ref = chunk_attn_bwd_ref(q, k, v, o, lse, do, causal=causal,
                             q_offset=rel, window=window)
    kw = dict(causal=causal, rel_offset=rel, window=window, block_q=bq,
              block_kv=bk, interpret=True)
    pal = ops.flash_bwd(q, k, v, o, lse, do, **kw)
    den = ops.flash_bwd(q, k, v, o, lse, do, prune=False, **kw)
    for r, p_, d_ in zip(ref, pal, den):
        assert jnp.allclose(r, p_, atol=2e-4, rtol=2e-4)
        assert jnp.allclose(p_, d_, atol=1e-6), "prune changed the result"


@pytest.mark.parametrize("case", PRUNE_CASES, ids=_prune_ids)
def test_pruned_chunked_lax_matches_ref(case):
    """The chunked-lax backend prunes its KV scan with the identical
    block-range logic — exact vs the oracle on the same sweep."""
    from repro.kernels.chunked import chunked_bwd, chunked_fwd
    B, Tq, Tk, Hq, Hkv, D, causal, rel, window, bq, bk = case
    q, k, v, do = _mk(B, Tq, Tk, Hq, Hkv, D, jnp.float32)
    o_r, lse_r = chunk_attn_ref(q, k, v, causal=causal, q_offset=rel,
                                window=window)
    kw = dict(causal=causal, rel_offset=rel, window=window, block_kv=bk)
    o_c, lse_c = chunked_fwd(q, k, v, **kw)
    o_d, lse_d = chunked_fwd(q, k, v, prune=False, **kw)
    assert jnp.allclose(o_r, o_c, atol=1e-5, rtol=1e-5)
    assert jnp.allclose(o_c, o_d, atol=1e-6)
    m = (lse_r > -1e29) | (lse_c > -1e29)
    assert jnp.allclose(jnp.where(m, lse_r, 0), jnp.where(m, lse_c, 0),
                        atol=1e-4, rtol=1e-4)
    g_r = chunk_attn_bwd_ref(q, k, v, o_r, lse_r, do, causal=causal,
                             q_offset=rel, window=window)
    g_c = chunked_bwd(q, k, v, o_c, lse_c, do, **kw)
    g_d = chunked_bwd(q, k, v, o_c, lse_c, do, prune=False, **kw)
    for r, c_, d_ in zip(g_r, g_c, g_d):
        assert jnp.allclose(r, c_, atol=2e-4, rtol=2e-4)
        assert jnp.allclose(c_, d_, atol=1e-6)


@pytest.mark.skipif(jax.default_backend() != "tpu",
                    reason="compiled Mosaic lowering needs TPU hardware")
@pytest.mark.parametrize("case", PRUNE_CASES[:3], ids=_prune_ids)
def test_pruned_flash_compiles_on_tpu(case):
    """CI validates the pruned kernels under interpret=True only; on real
    TPU this exercises the compiled lowering of the in-kernel lax.cond,
    the narrow (1,1,br) lse/delta blocks, and the index-map remapping."""
    B, Tq, Tk, Hq, Hkv, D, causal, rel, window, bq, bk = case
    q, k, v, do = _mk(B, Tq, Tk, Hq, Hkv, D, jnp.float32)
    kw = dict(causal=causal, rel_offset=rel, window=window, block_q=bq,
              block_kv=bk)
    o_r, lse_r = chunk_attn_ref(q, k, v, causal=causal, q_offset=rel,
                                window=window)
    o_p, lse_p = ops.flash_fwd(q, k, v, **kw)
    assert jnp.allclose(o_r, o_p, atol=1e-5, rtol=1e-5)
    ref = chunk_attn_bwd_ref(q, k, v, o_r, lse_r, do, causal=causal,
                             q_offset=rel, window=window)
    pal = ops.flash_bwd(q, k, v, o_p, lse_p, do, **kw)
    for r, p_ in zip(ref, pal):
        assert jnp.allclose(r, p_, atol=2e-4, rtol=2e-4)


def test_pruned_grid_is_smaller_where_mask_allows():
    """The windowed regimes actually shrink the sequential grid dimension
    (not just skip compute): seq_grid < nk."""
    from repro.core.mask import MaskSpec
    from repro.kernels.block_sparse import kv_profile
    p = kv_profile(nq=8, nk=8, br=128, bc=128,
                   mask=MaskSpec(window=512, q_offset=1024))
    assert 0 < p.seq_grid < 8
    assert p.executed_steps < p.launched_steps < p.full_steps


# --------------------------------------------- MaskSpec kinds in the kernels

@pytest.mark.parametrize("kind", ["document-boundaries", "document-segments",
                                  "document-window", "prefix-lm"])
def test_mask_kinds_flash_vs_ref(kind):
    """The new MaskSpec kinds (document / prefix_lm) are exact vs the
    oracle in the Pallas kernels (interpret), pruned AND dense, fwd + bwd,
    with GQA."""
    import numpy as np
    from repro.core import mask as mk
    B, Tq, Tk, Hq, Hkv, D = 2, 192, 192, 4, 2, 32
    q, k, v, do = _mk(B, Tq, Tk, Hq, Hkv, D, jnp.float32, seed=11)
    bnd = mk.doc_boundaries(Tk, 4)
    seg = jnp.asarray(np.tile(mk.segments_from_boundaries(Tk, bnd), (B, 1)))
    segs = {}
    if kind == "document-boundaries":
        mask = mk.document(boundaries=bnd)
    elif kind == "document-segments":
        mask = mk.document()
        segs = dict(q_segments=seg, kv_segments=seg)
    elif kind == "document-window":
        mask = mk.document(boundaries=bnd, window=48)
    else:
        mask = mk.prefix_lm(70)
    o_r, lse_r = chunk_attn_ref(q, k, v, mask=mask, **segs)
    kw = dict(mask=mask, block_q=64, block_kv=64, interpret=True, **segs)
    o_p, lse_p = ops.flash_fwd(q, k, v, **kw)
    o_d, lse_d = ops.flash_fwd(q, k, v, prune=False, **kw)
    assert jnp.allclose(o_r, o_p, atol=1e-5, rtol=1e-5), kind
    m = (lse_r > -1e29) | (lse_p > -1e29)
    assert jnp.allclose(jnp.where(m, lse_r, 0), jnp.where(m, lse_p, 0),
                        atol=1e-4, rtol=1e-4)
    assert jnp.allclose(o_p, o_d, atol=1e-6), "prune changed the result"
    ref = chunk_attn_bwd_ref(q, k, v, o_r, lse_r, do, mask=mask, **segs)
    pal = ops.flash_bwd(q, k, v, o_r, lse_r, do, **kw)
    den = ops.flash_bwd(q, k, v, o_r, lse_r, do, prune=False, **kw)
    for r, p_, d_ in zip(ref, pal, den):
        assert jnp.allclose(r, p_, atol=2e-4, rtol=2e-4), kind
        assert jnp.allclose(p_, d_, atol=1e-6), kind


@pytest.mark.parametrize("kind", ["document-boundaries", "document-segments",
                                  "document-window", "prefix-lm"])
def test_mask_kinds_chunked_vs_ref(kind):
    """Same MaskSpec-kind sweep through the chunked-lax scan."""
    import numpy as np
    from repro.core import mask as mk
    from repro.kernels.chunked import chunked_bwd, chunked_fwd
    B, Tq, Tk, Hq, Hkv, D = 2, 128, 256, 4, 2, 32
    q, k, v, do = _mk(B, Tq, Tk, Hq, Hkv, D, jnp.float32, seed=12)
    bnd = mk.doc_boundaries(Tk, 4)
    seg_k = jnp.asarray(np.tile(mk.segments_from_boundaries(Tk, bnd),
                                (B, 1)))
    seg_q = seg_k[:, :Tq]
    segs = {}
    if kind == "document-boundaries":
        mask = mk.document(boundaries=bnd)
    elif kind == "document-segments":
        mask = mk.document()
        segs = dict(q_segments=seg_q, kv_segments=seg_k)
    elif kind == "document-window":
        mask = mk.document(boundaries=bnd, window=48)
    else:
        mask = mk.prefix_lm(70)
    o_r, lse_r = chunk_attn_ref(q, k, v, mask=mask, **segs)
    o_c, lse_c = chunked_fwd(q, k, v, mask=mask, block_kv=64, **segs)
    o_d, _ = chunked_fwd(q, k, v, mask=mask, block_kv=64, prune=False,
                         **segs)
    assert jnp.allclose(o_r, o_c, atol=1e-5, rtol=1e-5), kind
    assert jnp.allclose(o_c, o_d, atol=1e-6), kind
    g_r = chunk_attn_bwd_ref(q, k, v, o_r, lse_r, do, mask=mask, **segs)
    g_c = chunked_bwd(q, k, v, o_c, lse_c, do, mask=mask, block_kv=64,
                      **segs)
    for r, c_ in zip(g_r, g_c):
        assert jnp.allclose(r, c_, atol=2e-4, rtol=2e-4), kind


# ------------------------------------------------------ block tuning surface

def test_chunk_attn_block_hints_reach_tunable_backends():
    """block_q/block_kv flow through chunk_attn to tunable backends and
    stay exact; non-tunable backends silently drop the hints."""
    from repro.core import mask as mkk
    from repro.core.attention import chunk_attn, chunk_attn_bwd
    q, k, v, do = _mk(1, 128, 256, 2, 2, 32, jnp.float32)
    m = mkk.causal(rel_offset=128)
    o_r, lse_r = chunk_attn_ref(q, k, v, mask=m)
    for impl in ("chunked-lax", "pallas-interpret", "ref"):
        # non-dividing hints (96 ∤ 128) must shrink to a divisor, not crash
        o_nd, _ = chunk_attn(q, k, v, mask=m, impl=impl, block_q=96,
                             block_kv=96)
        assert jnp.allclose(o_r, o_nd, atol=1e-5), impl
        o_b, lse_b = chunk_attn(q, k, v, mask=m, impl=impl, block_q=64,
                                block_kv=32)
        assert jnp.allclose(o_r, o_b, atol=1e-5), impl
        g_r = chunk_attn_bwd_ref(q, k, v, o_r, lse_r, do, mask=m)
        g_b = chunk_attn_bwd(q, k, v, o_b, lse_b, do, mask=m, impl=impl,
                             block_q=64, block_kv=32)
        for a, b in zip(g_r, g_b):
            assert jnp.allclose(a, b, atol=2e-4), impl


def test_registry_tunable_flag():
    from repro.kernels import registry
    assert registry.get("pallas").tunable_blocks
    assert registry.get("pallas-interpret").tunable_blocks
    assert registry.get("chunked-lax").tunable_blocks
    assert not registry.get("ref").tunable_blocks
    assert not registry.get("null").tunable_blocks


def test_kernel_ref_grad_consistency():
    """ref bwd == jax.grad through monolithic softmax attention."""
    from repro.kernels.ref import full_attn_ref
    q, k, v, _ = _mk(1, 64, 64, 2, 2, 32, jnp.float32)

    def loss(q, k, v):
        return jnp.sum(full_attn_ref(q, k, v, causal=True) ** 2)

    dq_a, dk_a, dv_a = jax.grad(loss, (0, 1, 2))(q, k, v)
    o, lse = chunk_attn_ref(q, k, v, causal=True)
    dq, dk, dv = chunk_attn_bwd_ref(q, k, v, o, lse, 2 * o, causal=True)
    assert jnp.allclose(dq, dq_a, atol=1e-4)
    assert jnp.allclose(dk, dk_a, atol=1e-4)
    assert jnp.allclose(dv, dv_a, atol=1e-4)
