"""Auto-schedule capability/runtime consistency sweep (the trace-time
filter must agree with execution): every architecture in the config zoo ×
P ∈ {2, 4, 8} × every MaskSpec kind goes through ``choose_schedule``, and
whatever name (or 2D factorization triple) it resolves must be one the
runtime accepts — ``plan_capable`` holds, the plan builds, and the
``DistAttnSpec`` validation that guards execution passes.  A clean
"no capable" ``ValueError`` at trace time is the only acceptable
alternative; the resolved schedule raising later, inside shard_map, is
exactly the bug class this sweep pins down."""
import pytest

from repro.core import dist_attention as da
from repro.core import mask as mk
from repro.core import schedule as sp
from repro.core.config import ARCH_IDS, PAPER_ARCH_IDS, get_config

ALL_ARCHS = ARCH_IDS + PAPER_ARCH_IDS


def _head_shapes():
    """(arch, Hq, Hkv, Dqk) for every config with an attention block."""
    out = []
    for a in ALL_ARCHS:
        cfg = get_config(a)
        if cfg.attn is None:          # mamba2: no attention sites
            continue
        out.append((a, cfg.attn.n_heads, cfg.attn.n_kv_heads,
                    cfg.attn.head_dim))
    assert len(out) >= 10
    return out


def _mask_cases(T):
    """One MaskSpec per declarative kind, plus the dynamic-segment
    document variant (dynamic_seg mirrors segments= at the call site)."""
    return {
        "causal":           (mk.causal(), False),
        "full":             (mk.full(), False),
        "window":           (mk.sliding_window(max(3, T // 4)), False),
        "noncausal-window": (mk.sliding_window(max(3, T // 4),
                                               causal=False), False),
        "prefix":           (mk.prefix_lm(max(2, T // 4)), False),
        "doc-static":       (mk.document(boundaries=(0, T // 2)), False),
        "doc-dynamic":      (mk.document(), True),
    }


def _assert_runtime_accepts(name, mask, P, Hq, Hkv, *, include_bwd):
    """The runtime-side mirror of the trace-time filter.  Any assertion
    tripping here means ``choose_schedule`` resolved a schedule that
    execution would reject — the fix belongs in the filter."""
    if name == "ulysses":
        # head scatter needs exact divisibility on both head counts
        assert Hq % P == 0 and Hkv % P == 0, (Hq, Hkv, P)
        if include_bwd:
            # the ulysses backward reuses the ring plan: masks the ring
            # cannot express must have been filtered out at trace time
            assert not mask.prefix_len, mask
            assert not (mask.window and not mask.causal), mask
    else:
        assert sp.plan_capable(name, mask), (name, mask)
        sp.build_plan(name, mask, P, 64)          # must not raise
    # spec-level validation guards every execution entry point
    da.DistAttnSpec(axis_size=P, schedule=name, mask=mask)


@pytest.mark.parametrize("P", [2, 4, 8])
def test_choose_schedule_consistent_with_runtime_across_zoo(P):
    """ACCEPTANCE (satellite): sweep every config in the zoo × mask kind
    × cost horizon through ``choose_schedule`` and assert the resolved
    schedule never raises at execution time."""
    resolved = 0
    for arch, Hq, Hkv, D in _head_shapes():
        T = P * 32
        for mname, (mask, dyn) in _mask_cases(T).items():
            for include_bwd in (False, True):
                try:
                    name = sp.choose_schedule(
                        mask, P, Tl=T // P, Hq=Hq, Hkv=Hkv, Dqk=D,
                        dynamic_seg=dyn, include_bwd=include_bwd)
                except ValueError as e:
                    # the only legal trace-time outcome besides a name
                    assert "no capable" in str(e), (arch, mname, e)
                    continue
                _assert_runtime_accepts(name, mask, P, Hq, Hkv,
                                        include_bwd=include_bwd)
                resolved += 1
    assert resolved > 0


@pytest.mark.parametrize("P", [2, 4, 8])
def test_factorized_choice_consistent_with_runtime(P):
    """Same sweep over the 2D (r, u) factorization space: every returned
    triple must build (``build_plan2d`` for u > 1, ``build_plan`` for
    u == 1) and pass ``DistAttnSpec`` validation with the matching
    ``Mesh2DSpec``."""
    for arch, Hq, Hkv, D in _head_shapes():
        T = P * 32
        for mname, (mask, dyn) in _mask_cases(T).items():
            for include_bwd in (False, True):
                try:
                    name, r, u = sp.choose_schedule(
                        mask, P, Tl=T // P, Hq=Hq, Hkv=Hkv, Dqk=D,
                        dynamic_seg=dyn, include_bwd=include_bwd,
                        factorize=True)
                except ValueError as e:
                    assert "factorization" in str(e), (arch, mname, e)
                    continue
                assert r * u == P, (name, r, u)
                if u == 1:
                    assert sp.plan_capable(name, mask)
                    sp.build_plan(name, mask, r, 64)
                    da.DistAttnSpec(axis_size=P, schedule=name, mask=mask)
                else:
                    sp.build_plan2d(name, mask, r, u, 64, Hq=Hq, Hkv=Hkv)
                    da.DistAttnSpec(
                        axis="seq", axis_size=P, schedule=name, mask=mask,
                        mesh2d=da.Mesh2DSpec(r=r, u=u))


def test_auto_resolution_executes_on_devices(subproc):
    """Representative end-to-end slice of the sweep on a real 8-device
    mesh: ``schedule="auto"`` traces and runs (fwd, and grads where the
    horizon allows) for divisible, GQA, and indivisible head shapes
    across the mask kinds — and where nothing is capable the failure is
    the clean trace-time ValueError, never a mid-execution raise."""
    out = subproc("""
import jax, jax.numpy as jnp
from repro.core import mask as mk
from repro.core.dist_attention import DistAttnSpec, dist_flash_attn
B,N,D = 1,128,16
mesh = jax.make_mesh((1,8), ("data","model"))
def run(Hq, Hkv, m, seg=None, grad=False):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B,N,Hq,D), jnp.float32)
    k = jax.random.normal(ks[1], (B,N,Hkv,D), jnp.float32)
    v = jax.random.normal(ks[2], (B,N,Hkv,D), jnp.float32)
    spec = DistAttnSpec(axis="model", axis_size=8, schedule="auto", mask=m)
    if grad:
        def loss(q,k,v):
            o,_ = dist_flash_attn(q,k,v,mesh,spec,segments=seg,batch_axes=None)
            return jnp.sum(o**2)
        jax.grad(loss, argnums=(0,1,2))(q,k,v)
    else:
        dist_flash_attn(q,k,v,mesh,spec,segments=seg,batch_axes=None)
seg = jnp.concatenate([jnp.zeros((B,N//2),jnp.int32),
                       jnp.ones((B,N-N//2),jnp.int32)], axis=1)
for (Hq,Hkv) in ((16,16),(32,8),(15,5)):
    for m in (mk.causal(), mk.sliding_window(32), mk.full(),
              mk.document(boundaries=(0, N//2))):
        run(Hq,Hkv,m)
    run(Hq,Hkv,mk.document(),seg=seg)
    run(Hq,Hkv,mk.causal(),grad=True)
    print("OK fwd+grad", Hq, Hkv)
# prefix_lm: forward-capable only through ulysses (divisible heads)...
run(16,16,mk.prefix_lm(32))
print("OK prefix fwd 16/16")
# ...its backward must fail at TRACE time with the clean chooser error
try:
    run(16,16,mk.prefix_lm(32),grad=True)
    raise SystemExit("prefix bwd should have raised")
except ValueError as e:
    assert "no capable" in str(e), e
    print("OK prefix bwd trace-time error")
# indivisible heads + prefix: not even a forward candidate exists
try:
    run(15,5,mk.prefix_lm(32))
    raise SystemExit("prefix fwd 15/5 should have raised")
except ValueError as e:
    assert "no capable" in str(e), e
    print("OK prefix indivisible trace-time error")
""")
    assert out.count("OK") == 6
