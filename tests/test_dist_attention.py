"""Distribution tests: the DISTFLASHATTN schedules against the monolithic
oracle, on 8 forced host devices (subprocess so the main pytest process
keeps its single real device)."""
import pytest


def test_schedules_match_oracle(subproc):
    out = subproc("""
import jax, jax.numpy as jnp
from repro.core import mask as mk
from repro.core.dist_attention import DistAttnSpec, dist_attn_fwd, dist_flash_attn
from repro.kernels.ref import full_attn_ref
mesh = jax.make_mesh((2,4), ("data","model"))
B,N,H,Hkv,D = 4,256,4,2,32
ks = jax.random.split(jax.random.PRNGKey(0),3)
q = jax.random.normal(ks[0],(B,N,H,D)); k = jax.random.normal(ks[1],(B,N,Hkv,D)); v = jax.random.normal(ks[2],(B,N,Hkv,D))
o_ref = full_attn_ref(q,k,v,causal=True)
for sched in ["balanced","ring","rsa"]:
    spec = DistAttnSpec(axis="model", axis_size=4, schedule=sched, mask=mk.causal())
    o,_ = jax.jit(lambda q,k,v: dist_attn_fwd(q,k,v,mesh=mesh,spec=spec,batch_axes=("data",)))(q,k,v)
    err = float(jnp.abs(o-o_ref).max())
    assert err < 2e-5, (sched, err)
    print("OK", sched, err)
# grads via custom_vjp (balanced) vs autodiff oracle
def loss_ref(q,k,v): return jnp.sum(full_attn_ref(q,k,v,causal=True).astype(jnp.float32)**2)
g_ref = jax.grad(loss_ref,(0,1,2))(q,k,v)
spec = DistAttnSpec(axis="model", axis_size=4, schedule="balanced", mask=mk.causal())
def loss_d(q,k,v):
    o,_ = dist_flash_attn(q,k,v,mesh,spec,("data",))
    return jnp.sum(o.astype(jnp.float32)**2)
g_d = jax.jit(jax.grad(loss_d,(0,1,2)))(q,k,v)
for a,b in zip(g_d,g_ref):
    assert float(jnp.abs(a-b).max()) < 5e-5
print("OK grads")
""")
    assert out.count("OK") == 4


def test_window_and_bidirectional(subproc):
    out = subproc("""
import jax, jax.numpy as jnp
from repro.core import mask as mk
from repro.core.dist_attention import DistAttnSpec, dist_attn_fwd
from repro.kernels.ref import full_attn_ref
mesh = jax.make_mesh((1,8), ("data","model"))
B,N,H,D = 2,128,2,16
ks = jax.random.split(jax.random.PRNGKey(1),3)
q,k,v = (jax.random.normal(kk,(B,N,H,D)) for kk in ks)
for window in [10, 40, 200]:
    o_ref = full_attn_ref(q,k,v,causal=True,window=window)
    spec = DistAttnSpec(axis="model", axis_size=8, schedule="ring", mask=mk.sliding_window(window))
    o,_ = jax.jit(lambda q,k,v: dist_attn_fwd(q,k,v,mesh=mesh,spec=spec,batch_axes=("data",)))(q,k,v)
    assert float(jnp.abs(o-o_ref).max()) < 2e-5, window
    print("OK window", window)
o_ref = full_attn_ref(q,k,v,causal=False)
spec = DistAttnSpec(axis="model", axis_size=8, schedule="ring", mask=mk.full())
o,_ = jax.jit(lambda q,k,v: dist_attn_fwd(q,k,v,mesh=mesh,spec=spec,batch_axes=("data",)))(q,k,v)
assert float(jnp.abs(o-o_ref).max()) < 2e-5
print("OK bidir")
""")
    assert out.count("OK") == 4


def test_odd_p_schedule(subproc):
    """Odd worker counts (paper: zero idle when P odd) stay exact."""
    out = subproc("""
import jax, jax.numpy as jnp
from repro.core import mask as mk
from repro.core.dist_attention import DistAttnSpec, dist_attn_fwd
from repro.kernels.ref import full_attn_ref
mesh = jax.make_mesh((1,7), ("data","model"))
B,N,H,D = 2,7*16,2,16
ks = jax.random.split(jax.random.PRNGKey(2),3)
q,k,v = (jax.random.normal(kk,(B,N,H,D)) for kk in ks)
o_ref = full_attn_ref(q,k,v,causal=True)
spec = DistAttnSpec(axis="model", axis_size=7, schedule="balanced", mask=mk.causal())
o,_ = jax.jit(lambda q,k,v: dist_attn_fwd(q,k,v,mesh=mesh,spec=spec,batch_axes=("data",)))(q,k,v)
assert float(jnp.abs(o-o_ref).max()) < 2e-5
print("OK P=7 balanced")
""", devices=7)
    assert "OK" in out


def test_block_tuning_hints_through_schedules(subproc):
    """DistAttnSpec.block_q/block_kv thread through every schedule step's
    chunk_attn call (tunable backends only) and stay exact — forward and
    backward, with and without a sliding window."""
    out = subproc("""
import jax, jax.numpy as jnp
from repro.core import mask as mk
from repro.core.dist_attention import DistAttnSpec, dist_flash_attn
from repro.kernels.ref import full_attn_ref
mesh = jax.make_mesh((1,4), ("data","model"))
B,N,H,D = 1,256,2,16
ks = jax.random.split(jax.random.PRNGKey(5),3)
q,k,v = (jax.random.normal(kk,(B,N,H,D)) for kk in ks)
for sched, window in [("balanced",0), ("ring",40)]:
    spec = DistAttnSpec(axis="model", axis_size=4, schedule=sched,
                        mask=mk.MaskSpec(causal=True, window=window),
                        impl="chunked-lax", block_q=32, block_kv=32)
    o_ref = full_attn_ref(q,k,v,causal=True,window=window)
    def loss(q,k,v):
        o,_ = dist_flash_attn(q,k,v,mesh,spec,("data",))
        return jnp.sum(o.astype(jnp.float32)**2), o
    (l,o), g = jax.jit(jax.value_and_grad(loss,(0,1,2),has_aux=True))(q,k,v)
    assert float(jnp.abs(o-o_ref).max()) < 2e-5, sched
    def loss_ref(q,k,v): return jnp.sum(full_attn_ref(q,k,v,causal=True,window=window).astype(jnp.float32)**2)
    g_ref = jax.grad(loss_ref,(0,1,2))(q,k,v)
    for a,b in zip(g,g_ref):
        assert float(jnp.abs(a-b).max()) < 5e-5, sched
    print("OK tuned", sched)
""", devices=4)
    assert out.count("OK") == 2


def test_decode_attention(subproc):
    out = subproc("""
import jax, jax.numpy as jnp
from repro.core import mask as mk
from repro.core.dist_attention import dist_decode_attn
from repro.kernels.ref import chunk_attn_ref
mesh = jax.make_mesh((2,4), ("data","model"))
B,N,H,Hkv,D = 4,256,4,2,32
ks = jax.random.split(jax.random.PRNGKey(0),6)
k = jax.random.normal(ks[1],(B,N,Hkv,D)); v = jax.random.normal(ks[2],(B,N,Hkv,D))
qd = jax.random.normal(ks[3],(B,1,H,D))
k1 = jax.random.normal(ks[4],(B,1,Hkv,D)); v1 = jax.random.normal(ks[5],(B,1,Hkv,D))
kf = jnp.concatenate([k,k1],1); vf = jnp.concatenate([v,v1],1)
o_ref,_ = chunk_attn_ref(qd,kf,vf)
for axes, bspec in [(("model",),("data",)), (("data","model"),None)]:
    o = jax.jit(lambda *a: dist_decode_attn(*a,mesh=mesh,seq_axes=axes,batch_axes=bspec))(qd,k,v,k1,v1)
    assert float(jnp.abs(o-o_ref).max()) < 2e-5, axes
    print("OK decode", axes)
ow_ref,_ = chunk_attn_ref(qd,kf,vf,causal=False,q_offset=N,window=100)
ow = jax.jit(lambda *a: dist_decode_attn(*a,mesh=mesh,seq_axes=("model",),batch_axes=("data",),mask=mk.sliding_window(100)))(qd,k,v,k1,v1)
assert float(jnp.abs(ow-ow_ref).max()) < 2e-5
print("OK decode window")
""")
    assert out.count("OK") == 3


def test_models_distributed_match_single(subproc):
    """Per-arch loss on an 8-device mesh equals the 1-device value (the
    smoke matrix checked visually during bring-up, now locked in)."""
    out = subproc("""
import jax, jax.numpy as jnp
from repro.core.config import ARCH_IDS, get_config, smoke_config, ShapeSpec
from repro.models.transformer import Runtime, build_model
from repro.parallel.sharding import make_parallel_config
from repro.data.pipeline import SyntheticTokens
shape = ShapeSpec("smoke", 64, 4, "train")
for arch in ["smollm-360m", "deepseek-v2-lite-16b", "zamba2-2.7b", "whisper-tiny"]:
    cfg = smoke_config(get_config(arch))
    losses = {}
    for (d, s) in [(1, 1), (2, 4)]:
        mesh = jax.make_mesh((d, s), ("data", "model"))
        par = make_parallel_config(mesh, shape)
        model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))
        params = model.init(jax.random.PRNGKey(0))
        batch = SyntheticTokens(cfg, shape, par, mesh).batch(0)
        loss, _ = jax.jit(model.loss)(params, batch)
        losses[(d, s)] = float(loss)
    a, b = losses[(1, 1)], losses[(2, 4)]
    assert abs(a - b) < 5e-3 * max(1, abs(a)), (arch, losses)
    print("OK", arch, a, b)
""")
    assert out.count("OK") == 4


def test_zigzag_and_ulysses(subproc):
    """Beyond-paper zigzag placement and the Ulysses baseline are exact."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.core import mask as mk
from repro.core.dist_attention import (DistAttnSpec, dist_attn_fwd,
                                       dist_flash_attn, zigzag_perm)
from repro.kernels.ref import full_attn_ref
mesh = jax.make_mesh((1,8), ("data","model"))
B,N,H,Hkv,D = 2,512,4,2,32
ks = jax.random.split(jax.random.PRNGKey(0),3)
q = jax.random.normal(ks[0],(B,N,H,D)); k = jax.random.normal(ks[1],(B,N,Hkv,D)); v = jax.random.normal(ks[2],(B,N,Hkv,D))
perm = zigzag_perm(N, 8)
o_ref = full_attn_ref(q,k,v,causal=True)
spec = DistAttnSpec(axis="model", axis_size=8, schedule="zigzag", mask=mk.causal())
o,_ = jax.jit(lambda a,b,c: dist_attn_fwd(a,b,c,mesh=mesh,spec=spec,batch_axes=None))(q[:,perm],k[:,perm],v[:,perm])
assert float(jnp.abs(o - o_ref[:,perm]).max()) < 2e-5
print("OK zigzag fwd")
def loss(a,b,c):
    o,_ = dist_flash_attn(a,b,c,mesh,spec,None)
    return jnp.sum(o.astype(jnp.float32)**2)
gz = jax.jit(jax.grad(loss,(0,1,2)))(q[:,perm],k[:,perm],v[:,perm])
gr = jax.grad(lambda a,b,c: jnp.sum(full_attn_ref(a,b,c,causal=True).astype(jnp.float32)**2),(0,1,2))(q,k,v)
inv = np.argsort(perm)
for a,b in zip(gz,gr):
    assert float(jnp.abs(a[:,inv]-b).max()) < 5e-5
print("OK zigzag bwd")
# ulysses (divisible heads)
q8 = jax.random.normal(ks[0],(B,N,8,D)); k8 = jax.random.normal(ks[1],(B,N,8,D)); v8 = jax.random.normal(ks[2],(B,N,8,D))
specu = DistAttnSpec(axis="model", axis_size=8, schedule="ulysses", mask=mk.causal())
ou,_ = jax.jit(lambda a,b,c: dist_attn_fwd(a,b,c,mesh=mesh,spec=specu,batch_axes=None))(q8,k8,v8)
assert float(jnp.abs(ou - full_attn_ref(q8,k8,v8,causal=True)).max()) < 2e-5
print("OK ulysses")
# ulysses head-divisibility failure (paper 4.2/4.6)
q33 = jax.random.normal(ks[0],(B,N,3,D))
try:
    dist_attn_fwd(q33,q33,q33,mesh=mesh,spec=specu,batch_axes=None)
    raise SystemExit("should have raised")
except ValueError:
    print("OK ulysses raises on indivisible heads")
""")
    assert out.count("OK") == 4


@pytest.mark.parametrize("P", [5, 8], ids=["odd-P", "even-P"])
def test_cross_schedule_golden(subproc, P):
    """Golden cross-schedule agreement: balanced vs ring vs single-device
    full attention match within fp32 tolerance on odd and even P, and the
    registry's chunked-lax backend gives the same answer as ref inside the
    distributed schedules."""
    out = subproc(f"""
import jax, jax.numpy as jnp
from repro.core import mask as mk
from repro.core.dist_attention import DistAttnSpec, dist_attn_fwd
from repro.kernels.ref import full_attn_ref
P = {P}
mesh = jax.make_mesh((1, P), ("data", "model"))
B, H, Hkv, D = 2, 4, 2, 16
N = P * 32
ks = jax.random.split(jax.random.PRNGKey(3), 3)
q = jax.random.normal(ks[0], (B, N, H, D))
k = jax.random.normal(ks[1], (B, N, Hkv, D))
v = jax.random.normal(ks[2], (B, N, Hkv, D))
o_single = full_attn_ref(q, k, v, causal=True)   # single-device oracle
outs = {{}}
for sched, impl in [("balanced", None), ("ring", None),
                    ("balanced", "chunked-lax")]:
    spec = DistAttnSpec(axis="model", axis_size=P, schedule=sched,
                        mask=mk.causal(), impl=impl)
    o, _ = jax.jit(lambda a, b, c: dist_attn_fwd(
        a, b, c, mesh=mesh, spec=spec, batch_axes=None))(q, k, v)
    err = float(jnp.abs(o - o_single).max())
    assert err < 2e-5, (sched, impl, err)
    outs[(sched, impl)] = o
    print("OK", sched, impl or "ref", err)
d_sched = float(jnp.abs(outs[("balanced", None)]
                        - outs[("ring", None)]).max())
assert d_sched < 2e-5, d_sched
d_impl = float(jnp.abs(outs[("balanced", None)]
                       - outs[("balanced", "chunked-lax")]).max())
assert d_impl < 2e-5, d_impl
print("OK cross", d_sched, d_impl)
""", devices=P)
    assert out.count("OK") == 4


def test_mla_latent_ring_prefill(subproc):
    """Latent-ring MLA prefill == materialized-KV prefill (model level)."""
    out = subproc("""
import jax, jax.numpy as jnp
from repro.core.config import get_config, smoke_config, ShapeSpec
from repro.models.transformer import Runtime, build_model
from repro.parallel.sharding import make_parallel_config
from repro.data.pipeline import SyntheticTokens
cfg = smoke_config(get_config("deepseek-v2-lite-16b"))
mesh = jax.make_mesh((2,4), ("data","model"))
shape = ShapeSpec("z", 64, 4, "prefill")
outs = {}
for name, sched, lat in [("base","balanced",False), ("latent","zigzag",True)]:
    par = make_parallel_config(mesh, shape, schedule=sched)
    model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref", latent_ring=lat))
    params = model.init(jax.random.PRNGKey(0))
    batch = SyntheticTokens(cfg, shape, par, mesh).batch(0)
    logits, _ = jax.jit(model.prefill)(params, batch)
    outs[name] = logits
d = float(jnp.abs(outs["base"]-outs["latent"]).max())
assert d < 5e-5, d
print("OK latent ring", d)
""")
    assert "OK" in out


# ------------------------------------------------------- MaskSpec era tests

def test_spec_validation_and_removed_kwargs():
    """Satellite: schedule typos raise at spec construction (no silent ring
    fallthrough), schedule-capability mismatches raise, and the removed
    pre-MaskSpec causal/window kwargs raise ``TypeError`` with the
    migration hint (they were deprecation shims for five PRs with zero
    in-repo callers).  Plan-IR era: balanced/zigzag accept sliding windows
    (plans truncate) and the ring family accepts static document
    boundaries (executors derive per-shard segment IDs) — those
    constructions must NOT raise."""
    import pytest as pt

    from repro.core import mask as mk
    from repro.core import dist_attention as da

    with pt.raises(ValueError, match="unknown schedule"):
        da.DistAttnSpec(schedule="blanced")
    with pt.raises(ValueError, match="unknown schedule"):
        da.DistAttnSpec(schedule="rsa ")
    with pt.raises(ValueError, match="causal-kind"):
        da.DistAttnSpec(axis_size=8, schedule="zigzag", mask=mk.full())
    with pt.raises(ValueError, match="causal-kind"):
        da.DistAttnSpec(axis_size=8, schedule="balanced",
                        mask=mk.prefix_lm(64))
    with pt.raises(ValueError, match="prefix_lm"):
        da.DistAttnSpec(axis_size=8, schedule="ring", mask=mk.prefix_lm(64))
    with pt.raises(ValueError, match="sliding-window"):
        da.DistAttnSpec(axis_size=8, schedule="rsa",
                        mask=mk.sliding_window(64))
    # a non-causal band has future-direction pairs no ring step can see
    with pt.raises(ValueError, match="future-direction"):
        da.DistAttnSpec(axis_size=8, schedule="ring",
                        mask=mk.sliding_window(64, causal=False))
    # plan-era capability widenings: these construct fine now
    da.DistAttnSpec(axis_size=8, schedule="balanced",
                    mask=mk.sliding_window(64))
    da.DistAttnSpec(axis_size=8, schedule="zigzag",
                    mask=mk.sliding_window(64))
    da.DistAttnSpec(axis_size=8, schedule="ring",
                    mask=mk.document(boundaries=(0, 64)))
    da.DistAttnSpec(axis_size=8, schedule="auto", mask=mk.prefix_lm(8))
    # prefix_lm has no distributed backward anywhere (the baselines reuse
    # the ring backward, which can't see absolute positions)
    spec_p = da.DistAttnSpec(axis_size=8, schedule="ulysses",
                             mask=mk.prefix_lm(8))
    with pt.raises(ValueError, match="prefix_lm"):
        da._bwd_local(spec_p, *([None] * 6))
    # rsa must demand segments for a dynamic-segment document mask, like
    # every other schedule does (via the backends)
    spec_r = da.DistAttnSpec(axis_size=8, schedule="rsa", mask=mk.document())
    with pt.raises(ValueError, match="segments"):
        da._fwd_local(spec_r, None, None, None, None)
    # the removed legacy kwargs are hard errors now — alone or mixed
    with pt.raises(TypeError, match="was removed"):
        da.DistAttnSpec(schedule="ring", mask=mk.causal(), causal=True)
    with pt.raises(TypeError, match="mask=repro.core.mask"):
        da.DistAttnSpec(axis_size=8, schedule="ring", causal=True,
                        window=40)
    with pt.raises(TypeError, match="was removed"):
        da.DistAttnSpec(window=40)
    # the mask=None default stays causal — and balanced accepts it
    assert da.DistAttnSpec(axis_size=8).mask == mk.causal()
    # the decode entry point's window= kwarg is removed too
    import jax
    import jax.numpy as jnp
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    z4 = jnp.zeros((1, 1, 2, 8))
    zc = jnp.zeros((1, 4, 2, 8))
    with pt.raises(TypeError, match=r"dist_decode_attn\(window=\) was "
                                    r"removed"):
        da.dist_decode_attn(z4, zc, zc, z4, z4, mesh=mesh,
                            seq_axes=("model",), batch_axes=None, window=2)
    with pt.raises(TypeError, match="was removed"):
        da.dist_decode_attn(z4, zc, zc, z4, z4, mesh=mesh,
                            seq_axes=("model",), batch_axes=None,
                            mask=mk.causal(), window=2)
    with pt.raises(ValueError, match="causal/sliding_window"):
        da.dist_decode_attn(z4, zc, zc, z4, z4, mesh=mesh,
                            seq_axes=("model",), batch_axes=None,
                            mask=mk.document())
    # 2D (seq×head) factorization validation
    with pt.raises(ValueError, match="must equal"):
        da.DistAttnSpec(axis_size=8, mesh2d=da.Mesh2DSpec(r=2, u=2))
    with pt.raises(ValueError, match="ring-family plans only"):
        da.DistAttnSpec(axis_size=8, schedule="ulysses", mask=mk.causal(),
                        mesh2d=da.Mesh2DSpec(r=4, u=2))
    with pt.raises(ValueError, match="distinct"):
        da.Mesh2DSpec(r=2, u=4, seq_axis="x", head_axis="x")
    # prefix_lm: rejected on a multi-shard seq sub-axis, served at r == 1
    # (head-only scatter — the local kernel sees absolute positions)
    with pt.raises(ValueError, match="prefix_lm"):
        da.DistAttnSpec(axis_size=8, schedule="ring", mask=mk.prefix_lm(8),
                        mesh2d=da.Mesh2DSpec(r=4, u=2))
    da.DistAttnSpec(axis_size=8, schedule="ring", mask=mk.prefix_lm(8),
                    mesh2d=da.Mesh2DSpec(r=1, u=8))


def test_document_mask_all_schedules(subproc):
    """ACCEPTANCE: packed-sequence (document) masking is differentially
    exact vs the oracle across ring / balanced / zigzag (and the ulysses /
    rsa baselines), forward and backward, with segment IDs traveling the
    ring alongside KV. Boundaries intentionally do not align with shards."""
    out = subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import mask as mk
from repro.core.dist_attention import (DistAttnSpec, dist_attn_fwd,
                                       dist_flash_attn, zigzag_perm)
from repro.kernels.ref import full_attn_ref
mesh = jax.make_mesh((1,8), ("data","model"))
B,N,H,Hkv,D = 2,512,4,2,32
ks = jax.random.split(jax.random.PRNGKey(0),3)
q = jax.random.normal(ks[0],(B,N,H,D)); k = jax.random.normal(ks[1],(B,N,Hkv,D)); v = jax.random.normal(ks[2],(B,N,Hkv,D))
bnd = mk.doc_boundaries(N, 5)
seg = jnp.asarray(np.tile(mk.segments_from_boundaries(N, bnd), (B,1)))
o_ref = full_attn_ref(q,k,v, mask=mk.document(), segments=seg)
perm = zigzag_perm(N, 8); inv = np.argsort(perm)
for sched in ["ring","balanced","zigzag","rsa"]:
    spec = DistAttnSpec(axis="model", axis_size=8, schedule=sched, mask=mk.document())
    if sched == "zigzag":
        o,_ = jax.jit(lambda a,b,c,s: dist_attn_fwd(a,b,c,mesh=mesh,spec=spec,batch_axes=None,segments=s))(q[:,perm],k[:,perm],v[:,perm],seg[:,perm])
        err = float(jnp.abs(o - o_ref[:,perm]).max())
    else:
        o,_ = jax.jit(lambda a,b,c,s: dist_attn_fwd(a,b,c,mesh=mesh,spec=spec,batch_axes=None,segments=s))(q,k,v,seg)
        err = float(jnp.abs(o - o_ref).max())
    assert err < 2e-5, (sched, err)
    print("OK doc fwd", sched, err)
# ulysses (divisible heads)
q8 = jax.random.normal(ks[0],(B,N,8,D))
specu = DistAttnSpec(axis="model", axis_size=8, schedule="ulysses", mask=mk.document())
ou,_ = jax.jit(lambda a,s: dist_attn_fwd(a,a,a,mesh=mesh,spec=specu,batch_axes=None,segments=s))(q8,seg)
erru = float(jnp.abs(ou - full_attn_ref(q8,q8,q8, mask=mk.document(), segments=seg)).max())
assert erru < 2e-5, erru
print("OK doc fwd ulysses", erru)
# grads via the seg-aware custom_vjp
g_ref = jax.grad(lambda a,b,c: jnp.sum(full_attn_ref(a,b,c, mask=mk.document(), segments=seg).astype(jnp.float32)**2),(0,1,2))(q,k,v)
for sched in ["ring","balanced","zigzag"]:
    spec = DistAttnSpec(axis="model", axis_size=8, schedule=sched, mask=mk.document())
    if sched == "zigzag":
        def loss(a,b,c):
            o,_ = dist_flash_attn(a,b,c,mesh,spec,None,seg[:,perm])
            return jnp.sum(o.astype(jnp.float32)**2)
        g = jax.jit(jax.grad(loss,(0,1,2)))(q[:,perm],k[:,perm],v[:,perm])
        err = max(float(jnp.abs(a[:,inv]-b).max()) for a,b in zip(g,g_ref))
    else:
        def loss(a,b,c):
            o,_ = dist_flash_attn(a,b,c,mesh,spec,None,seg)
            return jnp.sum(o.astype(jnp.float32)**2)
        g = jax.jit(jax.grad(loss,(0,1,2)))(q,k,v)
        err = max(float(jnp.abs(a-b).max()) for a,b in zip(g,g_ref))
    assert err < 5e-5, (sched, err)
    print("OK doc bwd", sched, err)
""")
    assert out.count("OK") == 8


def test_windowed_decode_vs_bruteforce(subproc):
    """Satellite: windowed dist_decode_attn against a brute-force oracle —
    window sizes from sub-shard to beyond-context, on 1D and 2D sequence
    sharding."""
    out = subproc("""
import jax, jax.numpy as jnp
from repro.core import mask as mk
from repro.core.dist_attention import dist_decode_attn
from repro.kernels.ref import chunk_attn_ref
mesh = jax.make_mesh((2,4), ("data","model"))
B,N,H,Hkv,D = 2,256,4,2,32
ks = jax.random.split(jax.random.PRNGKey(3),6)
k = jax.random.normal(ks[1],(B,N,Hkv,D)); v = jax.random.normal(ks[2],(B,N,Hkv,D))
qd = jax.random.normal(ks[3],(B,1,H,D))
k1 = jax.random.normal(ks[4],(B,1,Hkv,D)); v1 = jax.random.normal(ks[5],(B,1,Hkv,D))
kf = jnp.concatenate([k,k1],1); vf = jnp.concatenate([v,v1],1)
for axes, bspec in [(("model",),("data",)), (("data","model"),None)]:
    for window in [1, 7, 64, 100, 257, 10_000]:
        # brute force: the new token sits at absolute position N; the
        # window keeps keys with position > N - window
        o_ref,_ = chunk_attn_ref(qd, kf, vf, mask=mk.MaskSpec(window=window, q_offset=N))
        o = jax.jit(lambda *a: dist_decode_attn(*a, mesh=mesh, seq_axes=axes,
                    batch_axes=bspec, mask=mk.sliding_window(window)))(qd,k,v,k1,v1)
        err = float(jnp.abs(o-o_ref).max())
        assert err < 2e-5, (axes, window, err)
    print("OK windowed decode", axes)
""")
    assert out.count("OK") == 2


def test_ulysses_head_divisibility_error_paths(subproc):
    """Satellite: the ulysses ValueError fires for indivisible Hq, for
    indivisible Hkv (GQA), and inside jit tracing — and never fires when
    both divide P."""
    out = subproc("""
import jax, jax.numpy as jnp
from repro.core import mask as mk
from repro.core.dist_attention import DistAttnSpec, dist_attn_fwd
mesh = jax.make_mesh((1,4), ("data","model"))
B,N,D = 1,128,16
spec = DistAttnSpec(axis="model", axis_size=4, schedule="ulysses", mask=mk.causal())
def run(Hq, Hkv):
    q = jax.random.normal(jax.random.PRNGKey(0),(B,N,Hq,D))
    kv = jax.random.normal(jax.random.PRNGKey(1),(B,N,Hkv,D))
    return dist_attn_fwd(q,kv,kv,mesh=mesh,spec=spec,batch_axes=None)
for Hq, Hkv, ok in [(8,4,True), (6,4,False), (8,2,False), (3,3,False)]:
    try:
        jax.jit(lambda: run(Hq,Hkv))()
        assert ok, (Hq,Hkv)
        print("OK ulysses runs", Hq, Hkv)
    except ValueError as e:
        assert not ok and "heads % P" in str(e), (Hq,Hkv,e)
        print("OK ulysses raises", Hq, Hkv)
""", devices=4)
    assert out.count("OK") == 4
