"""Distribution tests: the DISTFLASHATTN schedules against the monolithic
oracle, on 8 forced host devices (subprocess so the main pytest process
keeps its single real device)."""
import pytest


def test_schedules_match_oracle(subproc):
    out = subproc("""
import jax, jax.numpy as jnp
from repro.core.dist_attention import DistAttnSpec, dist_attn_fwd, dist_flash_attn
from repro.kernels.ref import full_attn_ref
mesh = jax.make_mesh((2,4), ("data","model"))
B,N,H,Hkv,D = 4,256,4,2,32
ks = jax.random.split(jax.random.PRNGKey(0),3)
q = jax.random.normal(ks[0],(B,N,H,D)); k = jax.random.normal(ks[1],(B,N,Hkv,D)); v = jax.random.normal(ks[2],(B,N,Hkv,D))
o_ref = full_attn_ref(q,k,v,causal=True)
for sched in ["balanced","ring","rsa"]:
    spec = DistAttnSpec(axis="model", axis_size=4, schedule=sched, causal=True)
    o,_ = jax.jit(lambda q,k,v: dist_attn_fwd(q,k,v,mesh=mesh,spec=spec,batch_axes=("data",)))(q,k,v)
    err = float(jnp.abs(o-o_ref).max())
    assert err < 2e-5, (sched, err)
    print("OK", sched, err)
# grads via custom_vjp (balanced) vs autodiff oracle
def loss_ref(q,k,v): return jnp.sum(full_attn_ref(q,k,v,causal=True).astype(jnp.float32)**2)
g_ref = jax.grad(loss_ref,(0,1,2))(q,k,v)
spec = DistAttnSpec(axis="model", axis_size=4, schedule="balanced", causal=True)
def loss_d(q,k,v):
    o,_ = dist_flash_attn(q,k,v,mesh,spec,("data",))
    return jnp.sum(o.astype(jnp.float32)**2)
g_d = jax.jit(jax.grad(loss_d,(0,1,2)))(q,k,v)
for a,b in zip(g_d,g_ref):
    assert float(jnp.abs(a-b).max()) < 5e-5
print("OK grads")
""")
    assert out.count("OK") == 4


def test_window_and_bidirectional(subproc):
    out = subproc("""
import jax, jax.numpy as jnp
from repro.core.dist_attention import DistAttnSpec, dist_attn_fwd
from repro.kernels.ref import full_attn_ref
mesh = jax.make_mesh((1,8), ("data","model"))
B,N,H,D = 2,128,2,16
ks = jax.random.split(jax.random.PRNGKey(1),3)
q,k,v = (jax.random.normal(kk,(B,N,H,D)) for kk in ks)
for window in [10, 40, 200]:
    o_ref = full_attn_ref(q,k,v,causal=True,window=window)
    spec = DistAttnSpec(axis="model", axis_size=8, schedule="ring", causal=True, window=window)
    o,_ = jax.jit(lambda q,k,v: dist_attn_fwd(q,k,v,mesh=mesh,spec=spec,batch_axes=("data",)))(q,k,v)
    assert float(jnp.abs(o-o_ref).max()) < 2e-5, window
    print("OK window", window)
o_ref = full_attn_ref(q,k,v,causal=False)
spec = DistAttnSpec(axis="model", axis_size=8, schedule="ring", causal=False)
o,_ = jax.jit(lambda q,k,v: dist_attn_fwd(q,k,v,mesh=mesh,spec=spec,batch_axes=("data",)))(q,k,v)
assert float(jnp.abs(o-o_ref).max()) < 2e-5
print("OK bidir")
""")
    assert out.count("OK") == 4


def test_odd_p_schedule(subproc):
    """Odd worker counts (paper: zero idle when P odd) stay exact."""
    out = subproc("""
import jax, jax.numpy as jnp
from repro.core.dist_attention import DistAttnSpec, dist_attn_fwd
from repro.kernels.ref import full_attn_ref
mesh = jax.make_mesh((1,7), ("data","model"))
B,N,H,D = 2,7*16,2,16
ks = jax.random.split(jax.random.PRNGKey(2),3)
q,k,v = (jax.random.normal(kk,(B,N,H,D)) for kk in ks)
o_ref = full_attn_ref(q,k,v,causal=True)
spec = DistAttnSpec(axis="model", axis_size=7, schedule="balanced", causal=True)
o,_ = jax.jit(lambda q,k,v: dist_attn_fwd(q,k,v,mesh=mesh,spec=spec,batch_axes=("data",)))(q,k,v)
assert float(jnp.abs(o-o_ref).max()) < 2e-5
print("OK P=7 balanced")
""", devices=7)
    assert "OK" in out


def test_block_tuning_hints_through_schedules(subproc):
    """DistAttnSpec.block_q/block_kv thread through every schedule step's
    chunk_attn call (tunable backends only) and stay exact — forward and
    backward, with and without a sliding window."""
    out = subproc("""
import jax, jax.numpy as jnp
from repro.core.dist_attention import DistAttnSpec, dist_flash_attn
from repro.kernels.ref import full_attn_ref
mesh = jax.make_mesh((1,4), ("data","model"))
B,N,H,D = 1,256,2,16
ks = jax.random.split(jax.random.PRNGKey(5),3)
q,k,v = (jax.random.normal(kk,(B,N,H,D)) for kk in ks)
for sched, window in [("balanced",0), ("ring",40)]:
    spec = DistAttnSpec(axis="model", axis_size=4, schedule=sched, causal=True,
                        window=window, impl="chunked-lax", block_q=32, block_kv=32)
    o_ref = full_attn_ref(q,k,v,causal=True,window=window)
    def loss(q,k,v):
        o,_ = dist_flash_attn(q,k,v,mesh,spec,("data",))
        return jnp.sum(o.astype(jnp.float32)**2), o
    (l,o), g = jax.jit(jax.value_and_grad(loss,(0,1,2),has_aux=True))(q,k,v)
    assert float(jnp.abs(o-o_ref).max()) < 2e-5, sched
    def loss_ref(q,k,v): return jnp.sum(full_attn_ref(q,k,v,causal=True,window=window).astype(jnp.float32)**2)
    g_ref = jax.grad(loss_ref,(0,1,2))(q,k,v)
    for a,b in zip(g,g_ref):
        assert float(jnp.abs(a-b).max()) < 5e-5, sched
    print("OK tuned", sched)
""", devices=4)
    assert out.count("OK") == 2


def test_decode_attention(subproc):
    out = subproc("""
import jax, jax.numpy as jnp
from repro.core.dist_attention import dist_decode_attn
from repro.kernels.ref import chunk_attn_ref
mesh = jax.make_mesh((2,4), ("data","model"))
B,N,H,Hkv,D = 4,256,4,2,32
ks = jax.random.split(jax.random.PRNGKey(0),6)
k = jax.random.normal(ks[1],(B,N,Hkv,D)); v = jax.random.normal(ks[2],(B,N,Hkv,D))
qd = jax.random.normal(ks[3],(B,1,H,D))
k1 = jax.random.normal(ks[4],(B,1,Hkv,D)); v1 = jax.random.normal(ks[5],(B,1,Hkv,D))
kf = jnp.concatenate([k,k1],1); vf = jnp.concatenate([v,v1],1)
o_ref,_ = chunk_attn_ref(qd,kf,vf)
for axes, bspec in [(("model",),("data",)), (("data","model"),None)]:
    o = jax.jit(lambda *a: dist_decode_attn(*a,mesh=mesh,seq_axes=axes,batch_axes=bspec))(qd,k,v,k1,v1)
    assert float(jnp.abs(o-o_ref).max()) < 2e-5, axes
    print("OK decode", axes)
ow_ref,_ = chunk_attn_ref(qd,kf,vf,causal=False,q_offset=N,window=100)
ow = jax.jit(lambda *a: dist_decode_attn(*a,mesh=mesh,seq_axes=("model",),batch_axes=("data",),window=100))(qd,k,v,k1,v1)
assert float(jnp.abs(ow-ow_ref).max()) < 2e-5
print("OK decode window")
""")
    assert out.count("OK") == 3


def test_models_distributed_match_single(subproc):
    """Per-arch loss on an 8-device mesh equals the 1-device value (the
    smoke matrix checked visually during bring-up, now locked in)."""
    out = subproc("""
import jax, jax.numpy as jnp
from repro.core.config import ARCH_IDS, get_config, smoke_config, ShapeSpec
from repro.models.transformer import Runtime, build_model
from repro.parallel.sharding import make_parallel_config
from repro.data.pipeline import SyntheticTokens
shape = ShapeSpec("smoke", 64, 4, "train")
for arch in ["smollm-360m", "deepseek-v2-lite-16b", "zamba2-2.7b", "whisper-tiny"]:
    cfg = smoke_config(get_config(arch))
    losses = {}
    for (d, s) in [(1, 1), (2, 4)]:
        mesh = jax.make_mesh((d, s), ("data", "model"))
        par = make_parallel_config(mesh, shape)
        model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))
        params = model.init(jax.random.PRNGKey(0))
        batch = SyntheticTokens(cfg, shape, par, mesh).batch(0)
        loss, _ = jax.jit(model.loss)(params, batch)
        losses[(d, s)] = float(loss)
    a, b = losses[(1, 1)], losses[(2, 4)]
    assert abs(a - b) < 5e-3 * max(1, abs(a)), (arch, losses)
    print("OK", arch, a, b)
""")
    assert out.count("OK") == 4


def test_zigzag_and_ulysses(subproc):
    """Beyond-paper zigzag placement and the Ulysses baseline are exact."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.core.dist_attention import (DistAttnSpec, dist_attn_fwd,
                                       dist_flash_attn, zigzag_perm)
from repro.kernels.ref import full_attn_ref
mesh = jax.make_mesh((1,8), ("data","model"))
B,N,H,Hkv,D = 2,512,4,2,32
ks = jax.random.split(jax.random.PRNGKey(0),3)
q = jax.random.normal(ks[0],(B,N,H,D)); k = jax.random.normal(ks[1],(B,N,Hkv,D)); v = jax.random.normal(ks[2],(B,N,Hkv,D))
perm = zigzag_perm(N, 8)
o_ref = full_attn_ref(q,k,v,causal=True)
spec = DistAttnSpec(axis="model", axis_size=8, schedule="zigzag", causal=True)
o,_ = jax.jit(lambda a,b,c: dist_attn_fwd(a,b,c,mesh=mesh,spec=spec,batch_axes=None))(q[:,perm],k[:,perm],v[:,perm])
assert float(jnp.abs(o - o_ref[:,perm]).max()) < 2e-5
print("OK zigzag fwd")
def loss(a,b,c):
    o,_ = dist_flash_attn(a,b,c,mesh,spec,None)
    return jnp.sum(o.astype(jnp.float32)**2)
gz = jax.jit(jax.grad(loss,(0,1,2)))(q[:,perm],k[:,perm],v[:,perm])
gr = jax.grad(lambda a,b,c: jnp.sum(full_attn_ref(a,b,c,causal=True).astype(jnp.float32)**2),(0,1,2))(q,k,v)
inv = np.argsort(perm)
for a,b in zip(gz,gr):
    assert float(jnp.abs(a[:,inv]-b).max()) < 5e-5
print("OK zigzag bwd")
# ulysses (divisible heads)
q8 = jax.random.normal(ks[0],(B,N,8,D)); k8 = jax.random.normal(ks[1],(B,N,8,D)); v8 = jax.random.normal(ks[2],(B,N,8,D))
specu = DistAttnSpec(axis="model", axis_size=8, schedule="ulysses", causal=True)
ou,_ = jax.jit(lambda a,b,c: dist_attn_fwd(a,b,c,mesh=mesh,spec=specu,batch_axes=None))(q8,k8,v8)
assert float(jnp.abs(ou - full_attn_ref(q8,k8,v8,causal=True)).max()) < 2e-5
print("OK ulysses")
# ulysses head-divisibility failure (paper 4.2/4.6)
q33 = jax.random.normal(ks[0],(B,N,3,D))
try:
    dist_attn_fwd(q33,q33,q33,mesh=mesh,spec=specu,batch_axes=None)
    raise SystemExit("should have raised")
except ValueError:
    print("OK ulysses raises on indivisible heads")
""")
    assert out.count("OK") == 4


@pytest.mark.parametrize("P", [5, 8], ids=["odd-P", "even-P"])
def test_cross_schedule_golden(subproc, P):
    """Golden cross-schedule agreement: balanced vs ring vs single-device
    full attention match within fp32 tolerance on odd and even P, and the
    registry's chunked-lax backend gives the same answer as ref inside the
    distributed schedules."""
    out = subproc(f"""
import jax, jax.numpy as jnp
from repro.core.dist_attention import DistAttnSpec, dist_attn_fwd
from repro.kernels.ref import full_attn_ref
P = {P}
mesh = jax.make_mesh((1, P), ("data", "model"))
B, H, Hkv, D = 2, 4, 2, 16
N = P * 32
ks = jax.random.split(jax.random.PRNGKey(3), 3)
q = jax.random.normal(ks[0], (B, N, H, D))
k = jax.random.normal(ks[1], (B, N, Hkv, D))
v = jax.random.normal(ks[2], (B, N, Hkv, D))
o_single = full_attn_ref(q, k, v, causal=True)   # single-device oracle
outs = {{}}
for sched, impl in [("balanced", None), ("ring", None),
                    ("balanced", "chunked-lax")]:
    spec = DistAttnSpec(axis="model", axis_size=P, schedule=sched,
                        causal=True, impl=impl)
    o, _ = jax.jit(lambda a, b, c: dist_attn_fwd(
        a, b, c, mesh=mesh, spec=spec, batch_axes=None))(q, k, v)
    err = float(jnp.abs(o - o_single).max())
    assert err < 2e-5, (sched, impl, err)
    outs[(sched, impl)] = o
    print("OK", sched, impl or "ref", err)
d_sched = float(jnp.abs(outs[("balanced", None)]
                        - outs[("ring", None)]).max())
assert d_sched < 2e-5, d_sched
d_impl = float(jnp.abs(outs[("balanced", None)]
                       - outs[("balanced", "chunked-lax")]).max())
assert d_impl < 2e-5, d_impl
print("OK cross", d_sched, d_impl)
""", devices=P)
    assert out.count("OK") == 4


def test_mla_latent_ring_prefill(subproc):
    """Latent-ring MLA prefill == materialized-KV prefill (model level)."""
    out = subproc("""
import jax, jax.numpy as jnp
from repro.core.config import get_config, smoke_config, ShapeSpec
from repro.models.transformer import Runtime, build_model
from repro.parallel.sharding import make_parallel_config
from repro.data.pipeline import SyntheticTokens
cfg = smoke_config(get_config("deepseek-v2-lite-16b"))
mesh = jax.make_mesh((2,4), ("data","model"))
shape = ShapeSpec("z", 64, 4, "prefill")
outs = {}
for name, sched, lat in [("base","balanced",False), ("latent","zigzag",True)]:
    par = make_parallel_config(mesh, shape, schedule=sched)
    model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref", latent_ring=lat))
    params = model.init(jax.random.PRNGKey(0))
    batch = SyntheticTokens(cfg, shape, par, mesh).batch(0)
    logits, _ = jax.jit(model.prefill)(params, batch)
    outs[name] = logits
d = float(jnp.abs(outs["base"]-outs["latent"]).max())
assert d < 5e-5, d
print("OK latent ring", d)
""")
    assert "OK" in out
