"""Substrate tests: optimizer, data pipeline, checkpoint I/O, sharding
rules, config registry."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import (ARCH_IDS, SHAPES, TrainConfig, get_config,
                               get_shape)
from repro.data.pipeline import SyntheticTokens
from repro.io import checkpoint as ckpt
from repro.optim import adamw
from repro.parallel.sharding import make_parallel_config, param_shardings


def test_adamw_converges_on_quadratic():
    tc = TrainConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                     total_steps=200)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = adamw.init(params)
    for _ in range(150):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt, m = adamw.update(g, opt, params, tc)
    assert float(jnp.abs(params["w"]).max()) < 0.05
    assert m["gnorm"] >= 0


def test_adamw_clips_gradients():
    tc = TrainConfig(max_grad_norm=1.0)
    g = {"w": jnp.full((4,), 100.0)}
    clipped, gn = adamw.clip_by_global_norm(g, tc.max_grad_norm)
    assert float(jnp.linalg.norm(clipped["w"])) <= 1.0 + 1e-5
    assert float(gn) == pytest.approx(200.0)


def test_synthetic_data_deterministic_and_learnable():
    cfg = get_config("smollm-360m")
    from repro.core.config import smoke_config, ShapeSpec
    cfg = smoke_config(cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeSpec("s", 32, 2, "train")
    par = make_parallel_config(mesh, shape)
    ds = SyntheticTokens(cfg, shape, par, mesh, seed=7)
    b1 = ds.batch(3)
    b2 = ds.batch(3)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = ds.batch(4)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # markov structure: next-token often equals (31·x+7) mod v
    t = np.asarray(b1["tokens"])[0]
    l = np.asarray(b1["labels"])[0]
    v = min(cfg.vocab, 1024)
    frac = np.mean(l == (t * 31 + 7) % v)
    assert frac > 0.5


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    ckpt.save(str(tmp_path / "x"), tree, step=17)
    back = ckpt.restore(str(tmp_path / "x"), tree)
    assert ckpt.latest_step(str(tmp_path / "x")) == 17
    np.testing.assert_array_equal(np.asarray(back["a"]),
                                  np.asarray(tree["a"]))
    assert back["b"]["c"].dtype == jnp.bfloat16


def test_parallel_config_resolution():
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        axis_names = ("pod", "data", "model")

        class devices:
            shape = (2, 16, 16)
    for name, shape in SHAPES.items():
        par = make_parallel_config(FakeMesh, shape)
        if name == "train_4k":
            assert par.batch_axes == ("pod", "data")
        if name == "long_500k":
            assert par.batch_axes == () and "data" in par.extra_seq_axes
        if name == "decode_32k":
            assert par.batch_axes == ("pod", "data")


def test_param_shardings_cover_all_leaves():
    from repro.core.config import smoke_config, ShapeSpec
    from repro.models.transformer import Runtime, build_model
    cfg = smoke_config(get_config("deepseek-v2-lite-16b"))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    par = make_parallel_config(mesh, ShapeSpec("s", 32, 2, "train"))
    model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))
    ps = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    sh = param_shardings(ps, mesh, par)
    assert jax.tree.structure(ps) == jax.tree.structure(sh)


def test_registry_loads_all_archs():
    for a in ARCH_IDS:
        cfg = get_config(a)
        assert cfg.name == a and cfg.citation
    assert get_shape("train_4k").global_batch == 256
