"""Schedule-plan IR tests.

Three layers:

1. **Property tests** (pure python, no devices): every causal plan covers
   each (q-chunk × kv-chunk) causal pair **exactly once** for P ∈ 1..8 —
   even and odd P, zigzag's 2P half-chunking included — via the
   ``plan_coverage`` simulator, which walks the executor's routing and
   evaluates every Work item's mask exactly as the kernel would.  Windowed
   and document plans additionally prove that **skipped steps are
   provably all-masked**: coverage still equals the global mask exactly
   even though steps were dropped.

2. **Differential tests vs the frozen seed implementations**
   (core/legacy_schedules.py): the plan executors reproduce the
   hand-written ring/balanced/zigzag loops bit-for-bit on 8 host devices,
   forward and backward, causal and document.

3. **Oracle differentials for the new capabilities**: windowed
   balanced/zigzag (strictly fewer ring steps than causal), static
   document boundaries on the ring family (no segment arrays shipped),
   and ``schedule="auto"`` resolution across every supported mask kind,
   forward and grads, on 1- and 8-device meshes.
"""
import numpy as np
import pytest

from repro.core import mask as mk
from repro.core import schedule as sp


# --------------------------------------------------------------------------
# 1. Exactly-once coverage properties (no devices needed)
# --------------------------------------------------------------------------

def _assert_exact(plan, segments=None):
    T = plan.P * plan.Tl
    cov = sp.plan_coverage(plan, segments=segments)
    truth = sp.global_allow(plan.mask, T, segments=segments).astype(np.int64)
    assert np.array_equal(cov, truth), (
        plan.name, plan.P, plan.mask,
        np.argwhere(cov != truth)[:4].tolist())


@pytest.mark.parametrize("sched", ["ring", "balanced", "zigzag"])
@pytest.mark.parametrize("P", list(range(1, 9)))
def test_causal_coverage_exactly_once(sched, P):
    """ACCEPTANCE: every causal (q, kv) pair computed exactly once, and no
    non-causal pair ever, for P ∈ 1..8 (zigzag splits into 2P chunks)."""
    _assert_exact(sp.build_plan(sched, mk.causal(), P, 8))


@pytest.mark.parametrize("sched", ["ring", "balanced", "zigzag"])
@pytest.mark.parametrize("P", [1, 3, 4, 7, 8])
@pytest.mark.parametrize("w", [1, 3, 9, 24, 1000])
def test_windowed_coverage_and_step_skipping(sched, P, w):
    """Windowed plans skip provably all-masked steps — coverage stays
    exactly-once against the banded global mask, and the executed step
    count shrinks when the window allows."""
    m = mk.sliding_window(w)
    plan = sp.build_plan(sched, m, P, 8)
    _assert_exact(plan)
    assert plan.exec_steps <= plan.total_steps
    if P >= 4 and w <= 3:
        # window inside one chunk: at most the distance-1 neighbours remain
        causal_steps = sp.build_plan(sched, mk.causal(), P, 8).exec_steps
        assert plan.exec_steps < causal_steps, (sched, P, w)


@pytest.mark.parametrize("sched", ["ring", "balanced", "zigzag"])
@pytest.mark.parametrize("P", [1, 2, 5, 8])
@pytest.mark.parametrize("n_docs", [1, 3, 6])
def test_document_boundary_coverage_and_pruning(sched, P, n_docs):
    """Static document boundaries: coverage is exact with no segment
    arrays at all, and steps no document spans are statically pruned."""
    Tl = 8
    T = P * Tl
    bnd = mk.doc_boundaries(T, n_docs)
    m = mk.document(boundaries=bnd)
    plan = sp.build_plan(sched, m, P, Tl)
    _assert_exact(plan)
    if sched in ("ring", "balanced") and P == 8 and n_docs == 6:
        # short docs cannot span distant chunk pairs: steps must drop
        assert plan.exec_steps < plan.total_steps


@pytest.mark.parametrize("sched", ["ring", "balanced", "zigzag"])
@pytest.mark.parametrize("P", [2, 5, 8])
def test_dynamic_segment_coverage(sched, P):
    """Dynamic (runtime segment-ID) document masks: the plan can't prune,
    but per-step segment shipping still yields exactly-once coverage."""
    Tl = 8
    T = P * Tl
    seg = mk.segments_from_boundaries(T, mk.doc_boundaries(T, 4))
    plan = sp.build_plan(sched, mk.document(), P, Tl)
    _assert_exact(plan, segments=seg)
    assert plan.exec_steps == plan.total_steps   # nothing provable


def test_windowed_document_combined_coverage():
    """window ∧ document compose: both pruning sources apply."""
    P, Tl = 8, 8
    bnd = mk.doc_boundaries(P * Tl, 4)
    m = mk.document(boundaries=bnd, window=10)
    for sched in ("ring", "balanced", "zigzag"):
        plan = sp.build_plan(sched, m, P, Tl)
        _assert_exact(plan)
        assert plan.exec_steps < plan.total_steps, sched


def test_full_mask_ring_coverage():
    """Bidirectional (encoder) ring: P steps cover everything once."""
    for P in (1, 3, 8):
        _assert_exact(sp.build_plan("ring", mk.full(), P, 8))


def test_plan_static_shape_properties():
    """Plan bookkeeping the benchmarks publish: step counts, kernel
    calls, container usage."""
    p_c = sp.build_plan("balanced", mk.causal(), 8, 8)
    assert (p_c.exec_steps, p_c.total_steps) == (4, 4)
    assert p_c.ship_q and p_c.uses_ring
    p_w = sp.build_plan("balanced", mk.sliding_window(17), 8, 8)
    assert p_w.exec_steps == 2 and not p_w.ship_q  # helper-free band
    p_z = sp.build_plan("zigzag", mk.causal(), 8, 8)
    assert p_z.n_chunks == 2 and not p_z.ship_q
    p_r = sp.build_plan("ring", mk.sliding_window(1), 8, 8)
    assert p_r.exec_steps == 0                     # diagonal-only window
    # multi-hop shift folding: skipped steps accumulate into shifts
    p_zw = sp.build_plan("zigzag", mk.sliding_window(9), 8, 16)
    assert sum(s.shift for s in p_zw.steps) <= p_zw.total_steps
    assert p_zw.exec_steps < p_zw.total_steps


def test_plan_cost_model_sanity():
    """Cost model: windowed plans are strictly cheaper than causal on the
    same schedule; balanced ships more bytes but runs fewer steps than
    ring; auto picks a capable schedule for every supported kind."""
    kw = dict(B=1, Hq=8, Hkv=8, Dqk=64, Dv=64, bpe=2)
    c_bal = sp.build_plan("balanced", mk.causal(), 8, 1024).cost(**kw)
    c_ring = sp.build_plan("ring", mk.causal(), 8, 1024).cost(**kw)
    assert c_bal.exec_steps < c_ring.exec_steps
    assert c_bal.flops_fwd < c_ring.flops_fwd      # helpers rebalance
    w_bal = sp.build_plan("balanced", mk.sliding_window(512), 8,
                          1024).cost(**kw)
    assert w_bal.flops_fwd < c_bal.flops_fwd
    assert w_bal.comm_bytes_fwd < c_bal.comm_bytes_fwd
    t = c_bal.time_estimate()
    assert t["step_s_lower_bound"] >= max(0.0, t["compute_s"] * 0.99)
    for m, seg in [(mk.causal(), False), (mk.sliding_window(64), False),
                   (mk.full(), False), (mk.document(), True),
                   (mk.document(boundaries=(0, 512)), False)]:
        name = sp.choose_schedule(m, 8, Tl=1024, Hq=6, Hkv=3, Dqk=64,
                                  dynamic_seg=seg)
        assert name in ("balanced", "ring", "ulysses")
    # prefix_lm: only ulysses can serve; heads must divide P
    assert sp.choose_schedule(mk.prefix_lm(8), 8, Tl=64, Hq=8,
                              Hkv=8) == "ulysses"
    with pytest.raises(ValueError, match="auto"):
        sp.choose_schedule(mk.prefix_lm(8), 8, Tl=64, Hq=6, Hkv=3)


# --------------------------------------------------------------------------
# 2. Differential vs the frozen seed implementations (8 host devices)
# --------------------------------------------------------------------------

def test_plans_match_seed_implementations(subproc):
    """ACCEPTANCE: the plan executors reproduce the seed hand-written
    schedule loops (core/legacy_schedules.py) — forward, lse, and
    backward — for ring/balanced/zigzag × causal/windowed/document."""
    out = subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro import compat
from repro.core import mask as mk
from repro.core import legacy_schedules as LS
from repro.core.dist_attention import (DistAttnSpec, dist_attn_fwd,
                                       dist_attn_bwd, zigzag_perm)
mesh = jax.make_mesh((1,8), ("data","model"))
PS = jax.sharding.PartitionSpec
B,N,H,Hkv,D = 2,512,4,2,32
ks = jax.random.split(jax.random.PRNGKey(0),4)
q = jax.random.normal(ks[0],(B,N,H,D)); k = jax.random.normal(ks[1],(B,N,Hkv,D))
v = jax.random.normal(ks[2],(B,N,Hkv,D)); do = jax.random.normal(ks[3],(B,N,H,D))
bnd = mk.doc_boundaries(N, 5)
seg = jnp.asarray(np.tile(mk.segments_from_boundaries(N, bnd), (B,1)))
perm = zigzag_perm(N, 8)
qs = PS(None,"model",None,None); ls = PS(None,"model",None); gs = PS(None,"model")
def smap(f, ins, outs):
    return compat.shard_map(f, mesh=mesh, in_specs=ins, out_specs=outs,
                            check_vma=False)
cases = [
    ("ring", LS._fwd_ring, LS._bwd_ring, mk.causal(), False, False),
    ("ring", LS._fwd_ring, LS._bwd_ring, mk.sliding_window(100), False, False),
    ("ring", LS._fwd_ring, LS._bwd_ring, mk.full(), False, False),
    ("ring", LS._fwd_ring, LS._bwd_ring, mk.document(), True, False),
    ("balanced", LS._fwd_balanced, LS._bwd_balanced, mk.causal(), False, False),
    ("balanced", LS._fwd_balanced, LS._bwd_balanced, mk.document(), True, False),
    ("zigzag", LS._fwd_zigzag, LS._bwd_zigzag, mk.causal(), False, True),
    ("zigzag", LS._fwd_zigzag, LS._bwd_zigzag, mk.document(), True, True),
]
for sched, lf, lb, m, use_seg, zz in cases:
    spec = DistAttnSpec(axis="model", axis_size=8, schedule=sched, mask=m)
    qq,kk_,vv,dd = (tuple(x[:,perm] for x in (q,k,v,do)) if zz
                    else (q,k,v,do))
    ss = seg[:,perm] if zz else seg
    if use_seg:
        fl = smap(lambda a,b,c,s: lf(spec,a,b,c,s), (qs,)*3+(gs,), (qs,ls))
        o_l, s_l = jax.jit(fl)(qq,kk_,vv,ss)
    else:
        fl = smap(lambda a,b,c: lf(spec,a,b,c), (qs,)*3, (qs,ls))
        o_l, s_l = jax.jit(fl)(qq,kk_,vv)
    segarg = ss if use_seg else None
    o_n, s_n = jax.jit(lambda *a: dist_attn_fwd(*a[:3], mesh=mesh, spec=spec,
        batch_axes=None, segments=segarg))(qq,kk_,vv)
    ef = float(jnp.abs(o_n-o_l).max()); es = float(jnp.abs(s_n-s_l).max())
    if use_seg:
        bl = smap(lambda a,b,c,o,s,d,g: lb(spec,a,b,c,o,s,d,g),
                  (qs,)*4+(ls,qs,gs), (qs,)*3)
        g_l = jax.jit(bl)(qq,kk_,vv,o_l,s_l,dd,ss)
    else:
        bl = smap(lambda a,b,c,o,s,d: lb(spec,a,b,c,o,s,d),
                  (qs,)*4+(ls,qs), (qs,)*3)
        g_l = jax.jit(bl)(qq,kk_,vv,o_l,s_l,dd)
    g_n = jax.jit(lambda *a: dist_attn_bwd(*a, mesh=mesh, spec=spec,
        batch_axes=None, segments=segarg))(qq,kk_,vv,o_l,s_l,dd)
    eb = max(float(jnp.abs(x-y).max()) for x,y in zip(g_n,g_l))
    assert max(ef,es,eb) < 5e-5, (sched, m.kind, ef, es, eb)
    print("OK seed-diff", sched, m.kind, ef, es, eb)
""")
    assert out.count("OK") == 8


# --------------------------------------------------------------------------
# 3. Oracle differentials for the new capabilities
# --------------------------------------------------------------------------

def test_windowed_balanced_zigzag_vs_oracle(subproc):
    """ACCEPTANCE: windowed balanced/zigzag (new with the plan IR) match
    the oracle forward + grads on 8 devices, and execute strictly fewer
    ring steps than their causal plans."""
    out = subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import mask as mk
from repro.core import schedule as sp
from repro.core.dist_attention import (DistAttnSpec, dist_flash_attn,
                                       zigzag_perm)
from repro.kernels.ref import full_attn_ref
mesh = jax.make_mesh((1,8), ("data","model"))
B,N,H,Hkv,D = 2,512,4,2,32
ks = jax.random.split(jax.random.PRNGKey(1),3)
q = jax.random.normal(ks[0],(B,N,H,D)); k = jax.random.normal(ks[1],(B,N,Hkv,D))
v = jax.random.normal(ks[2],(B,N,Hkv,D))
perm = zigzag_perm(N, 8); inv = np.argsort(perm)
for w in (10, 60, 300):
    m = mk.sliding_window(w)
    g_ref = jax.grad(lambda a,b,c: jnp.sum(full_attn_ref(a,b,c,mask=m)
        .astype(jnp.float32)**2),(0,1,2))(q,k,v)
    o_ref = full_attn_ref(q,k,v,mask=m)
    for sched, zz in (("balanced",False), ("zigzag",True)):
        plan = sp.build_plan(sched, m, 8, N//8)
        causal = sp.build_plan(sched, mk.causal(), 8, N//8)
        # bands smaller than a shard must prune steps (zigzag keeps both
        # sequence-end steps, so its cut needs w below the half-chunk span)
        if w <= 60:
            assert plan.exec_steps < causal.exec_steps, (sched, w)
        assert plan.exec_steps <= causal.exec_steps, (sched, w)
        spec = DistAttnSpec(axis="model", axis_size=8, schedule=sched, mask=m)
        a,b,c = ((q[:,perm],k[:,perm],v[:,perm]) if zz else (q,k,v))
        def loss(a,b,c):
            o,_ = dist_flash_attn(a,b,c,mesh,spec,None)
            return jnp.sum(o.astype(jnp.float32)**2), o
        (l,o), g = jax.jit(jax.value_and_grad(loss,(0,1,2),has_aux=True))(a,b,c)
        if zz:
            eo = float(jnp.abs(o[:,inv]-o_ref).max())
            eg = max(float(jnp.abs(x[:,inv]-y).max()) for x,y in zip(g,g_ref))
        else:
            eo = float(jnp.abs(o-o_ref).max())
            eg = max(float(jnp.abs(x-y).max()) for x,y in zip(g,g_ref))
        assert max(eo,eg) < 5e-5, (sched, w, eo, eg)
        print("OK windowed", sched, w, plan.exec_steps, "/", plan.total_steps)
""")
    assert out.count("OK") == 6


def test_boundary_documents_on_ring_family(subproc):
    """ACCEPTANCE: document(boundaries=…) now runs on ring/balanced/zigzag
    with NO segment arrays — executors derive per-shard segment IDs from
    the static layout — matching the segment-array oracle, fwd + grads."""
    out = subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import mask as mk
from repro.core.dist_attention import (DistAttnSpec, dist_flash_attn,
                                       zigzag_perm)
from repro.kernels.ref import full_attn_ref
mesh = jax.make_mesh((1,8), ("data","model"))
B,N,H,Hkv,D = 2,512,4,2,32
ks = jax.random.split(jax.random.PRNGKey(2),3)
q = jax.random.normal(ks[0],(B,N,H,D)); k = jax.random.normal(ks[1],(B,N,Hkv,D))
v = jax.random.normal(ks[2],(B,N,Hkv,D))
bnd = mk.doc_boundaries(N, 5)
seg = jnp.asarray(np.tile(mk.segments_from_boundaries(N, bnd), (B,1)))
m = mk.document(boundaries=bnd)
o_ref = full_attn_ref(q,k,v, mask=mk.document(), segments=seg)
g_ref = jax.grad(lambda a,b,c: jnp.sum(full_attn_ref(a,b,c,
    mask=mk.document(), segments=seg).astype(jnp.float32)**2),(0,1,2))(q,k,v)
perm = zigzag_perm(N, 8); inv = np.argsort(perm)
for sched, zz in (("ring",False), ("balanced",False), ("zigzag",True)):
    spec = DistAttnSpec(axis="model", axis_size=8, schedule=sched, mask=m)
    a,b,c = ((q[:,perm],k[:,perm],v[:,perm]) if zz else (q,k,v))
    def loss(a,b,c):
        o,_ = dist_flash_attn(a,b,c,mesh,spec,None)   # NO segments arg
        return jnp.sum(o.astype(jnp.float32)**2), o
    (l,o), g = jax.jit(jax.value_and_grad(loss,(0,1,2),has_aux=True))(a,b,c)
    if zz:
        eo = float(jnp.abs(o[:,inv]-o_ref).max())
        eg = max(float(jnp.abs(x[:,inv]-y).max()) for x,y in zip(g,g_ref))
    else:
        eo = float(jnp.abs(o-o_ref).max())
        eg = max(float(jnp.abs(x-y).max()) for x,y in zip(g,g_ref))
    assert max(eo,eg) < 5e-5, (sched, eo, eg)
    print("OK bnd-doc", sched, eo, eg)
""")
    assert out.count("OK") == 3


def test_auto_schedule_resolution(subproc):
    """ACCEPTANCE: schedule="auto" resolves to a valid schedule for every
    supported mask kind (exact vs oracle, fwd + grads where a distributed
    backward exists) and raises nowhere the explicit names succeed."""
    out = subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import mask as mk
from repro.core.dist_attention import (DistAttnSpec, dist_attn_fwd,
                                       dist_flash_attn)
from repro.kernels.ref import full_attn_ref
mesh = jax.make_mesh((1,8), ("data","model"))
B,N,H,D = 2,512,8,32
ks = jax.random.split(jax.random.PRNGKey(3),3)
q,k,v = (jax.random.normal(kk,(B,N,H,D)) for kk in ks)
bnd = mk.doc_boundaries(N, 5)
seg = jnp.asarray(np.tile(mk.segments_from_boundaries(N, bnd), (B,1)))
cases = [
    (mk.causal(), None, full_attn_ref(q,k,v,causal=True)),
    (mk.sliding_window(64), None, full_attn_ref(q,k,v,mask=mk.sliding_window(64))),
    (mk.full(), None, full_attn_ref(q,k,v,causal=False)),
    (mk.document(), seg, full_attn_ref(q,k,v,mask=mk.document(),segments=seg)),
    (mk.document(boundaries=bnd), None,
     full_attn_ref(q,k,v,mask=mk.document(),segments=seg)),
    (mk.prefix_lm(100), None, full_attn_ref(q,k,v,mask=mk.prefix_lm(100))),
]
for m, segarg, o_ref in cases:
    spec = DistAttnSpec(axis="model", axis_size=8, schedule="auto", mask=m)
    o,_ = jax.jit(lambda *a: dist_attn_fwd(*a, mesh=mesh, spec=spec,
        batch_axes=None, segments=segarg))(q,k,v)
    err = float(jnp.abs(o-o_ref).max())
    assert err < 2e-5, (m.kind, err)
    print("OK auto fwd", m.kind, err)
# grads through auto (causal — the training path)
spec = DistAttnSpec(axis="model", axis_size=8, schedule="auto",
                    mask=mk.causal())
g = jax.jit(jax.grad(lambda a,b,c: jnp.sum(dist_flash_attn(a,b,c,mesh,spec,
    None)[0].astype(jnp.float32)**2),(0,1,2)))(q,k,v)
g_ref = jax.grad(lambda a,b,c: jnp.sum(full_attn_ref(a,b,c,causal=True)
    .astype(jnp.float32)**2),(0,1,2))(q,k,v)
err = max(float(jnp.abs(x-y).max()) for x,y in zip(g,g_ref))
assert err < 5e-5, err
print("OK auto grads", err)
# auto must not raise where explicit names succeed: GQA heads that break
# ulysses still resolve (to a plan schedule)
kg = jax.random.normal(ks[1],(B,N,2,D))
spec = DistAttnSpec(axis="model", axis_size=8, schedule="auto",
                    mask=mk.causal())
o,_ = jax.jit(lambda a,b,c: dist_attn_fwd(a,b,c, mesh=mesh, spec=spec,
    batch_axes=None))(q,kg,kg)
print("OK auto gqa")
""")
    assert out.count("OK") == 8


def test_single_device_mesh_plan_paths(subproc):
    """Differential on a 1-device mesh: every schedule (and auto)
    collapses to the local kernel with identical results."""
    out = subproc("""
import jax, jax.numpy as jnp
from repro.core import mask as mk
from repro.core.dist_attention import DistAttnSpec, dist_attn_fwd
from repro.kernels.ref import full_attn_ref
mesh = jax.make_mesh((1,1), ("data","model"))
B,N,H,D = 2,128,4,16
ks = jax.random.split(jax.random.PRNGKey(4),3)
q,k,v = (jax.random.normal(kk,(B,N,H,D)) for kk in ks)
o_ref = full_attn_ref(q,k,v,causal=True)
for sched in ("auto","balanced","ring","zigzag","ulysses","rsa"):
    spec = DistAttnSpec(axis="model", axis_size=1, schedule=sched,
                        mask=mk.causal())
    o,_ = jax.jit(lambda a,b,c: dist_attn_fwd(a,b,c, mesh=mesh, spec=spec,
        batch_axes=None))(q,k,v)
    err = float(jnp.abs(o-o_ref).max())
    assert err < 2e-5, (sched, err)
    print("OK 1dev", sched)
""", devices=1)
    assert out.count("OK") == 6
